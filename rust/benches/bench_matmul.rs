//! Matmul kernels: the L3 engine hot path. Naive baseline vs the blocked/
//! unrolled kernels in tensor::matmul (§Perf records the progression).

use zeroquant_fp::bench_harness::Bench;
use zeroquant_fp::rng::Rng;
use zeroquant_fp::tensor::{matmul, Matrix};

fn main() {
    let mut rng = Rng::seeded(11);
    let mut bench = Bench::default();
    for (m, k, n) in [(128, 128, 128), (128, 512, 128), (256, 256, 256), (512, 512, 512)] {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let bt = b.transpose();
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        println!("-- {m}x{k}x{n} ({:.1} MFLOP) --", flops / 1e6);
        if m <= 256 {
            bench.run(format!("naive      {m}x{k}x{n}"), flops, "FLOP", || {
                matmul::matmul_naive(&a, &b)
            });
        }
        bench.run(format!("blocked    {m}x{k}x{n}"), flops, "FLOP", || a.matmul(&b));
        bench.run(format!("bt-fused   {m}x{k}x{n}"), flops, "FLOP", || {
            a.matmul_t(&bt)
        });
        if let Some(s) = bench.speedup(
            &format!("blocked    {m}x{k}x{n}"),
            &format!("naive      {m}x{k}x{n}"),
        ) {
            println!("   blocked vs naive: {s:.2}x");
        }
        println!();
    }
}
