//! Matmul kernels: the L3 engine hot path. Naive baseline vs the blocked/
//! unrolled dense kernels (§Perf records the progression), then the fused
//! dequant-GEMV through both kernel tiers — the weight-quant config driven
//! off the `w4a8-fp` recipe preset so this bench measures exactly the codes
//! the serving stack packs, and can't drift from the serving configuration.
//! Writes `bench_results/bench_matmul.json` for the perf trajectory.

use std::path::Path;

use zeroquant_fp::bench_harness::Bench;
use zeroquant_fp::kernels::{FastKernels, Kernels, OracleKernels};
use zeroquant_fp::quant::{quantize_weight_rtn, PackedWeight, WeightQuantConfig};
use zeroquant_fp::recipe::QuantRecipe;
use zeroquant_fp::rng::Rng;
use zeroquant_fp::tensor::packed_matmul::GemvScratch;
use zeroquant_fp::tensor::{matmul, Matrix};

fn main() {
    let mut rng = Rng::seeded(11);
    let mut bench = Bench::default();
    for (m, k, n) in [(128, 128, 128), (128, 512, 128), (256, 256, 256), (512, 512, 512)] {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let bt = b.transpose();
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        println!("-- {m}x{k}x{n} ({:.1} MFLOP) --", flops / 1e6);
        if m <= 256 {
            bench.run(format!("naive      {m}x{k}x{n}"), flops, "FLOP", || {
                matmul::matmul_naive(&a, &b)
            });
        }
        bench.run(format!("blocked    {m}x{k}x{n}"), flops, "FLOP", || a.matmul(&b));
        bench.run(format!("bt-fused   {m}x{k}x{n}"), flops, "FLOP", || {
            a.matmul_t(&bt)
        });
        if let Some(s) = bench.speedup(
            &format!("blocked    {m}x{k}x{n}"),
            &format!("naive      {m}x{k}x{n}"),
        ) {
            println!("   blocked vs naive: {s:.2}x");
        }
        println!();
    }

    // ---- fused dequant-GEMV: oracle vs fast tier --------------------------
    // The packed plan's hot path, quantized exactly as the `w4a8-fp` preset
    // quantizes it (weight format, group size and scale constraint read off
    // the recipe; RTN codes), at decode-like batch widths. B=1 is the
    // decode-loop shape where row decode dominates; B=8 amortizes decode
    // and isolates the dot engines (serial 4-term chain vs 8 lanes).
    let recipe = QuantRecipe::preset("w4a8-fp").unwrap();
    let wcfg = WeightQuantConfig::new(recipe.scheme.weight)
        .with_group_size(recipe.group_size)
        .with_constraint(recipe.constraint);
    let (rows, cols) = (256usize, 512usize);
    let wm = Matrix::randn(rows, cols, 0.05, &mut rng);
    let w = PackedWeight::from_quantized(&quantize_weight_rtn(&wm, &wcfg));
    println!(
        "-- fused dequant-GEMV {rows}x{cols}, {} codes (group {}, {}) --",
        recipe.scheme.name(),
        recipe.group_size,
        recipe.constraint.label()
    );
    let oracle = OracleKernels::new(1);
    let fast = FastKernels::new(1);
    for b in [1usize, 8] {
        let x = Matrix::randn(b, cols, 0.5, &mut rng);
        let mut out = Matrix::zeros(b, rows);
        let mut s = GemvScratch::sized(cols, 0);
        let flops = 2.0 * (b * rows * cols) as f64;
        bench.run(format!("gemv oracle B={b}"), flops, "FLOP", || {
            out.data.fill(0.0);
            oracle.packed_gemv(&x, &w, None, &mut out, &mut s);
        });
        bench.run(format!("gemv fast   B={b}"), flops, "FLOP", || {
            out.data.fill(0.0);
            fast.packed_gemv(&x, &w, None, &mut out, &mut s);
        });
        if let Some(sp) =
            bench.speedup(&format!("gemv fast   B={b}"), &format!("gemv oracle B={b}"))
        {
            println!("   fast vs oracle tier (B={b}): {sp:.2}x");
        }
        println!();
    }

    let out = Path::new("bench_results/bench_matmul.json");
    match bench.write_json("bench_matmul", out) {
        Ok(()) => println!("[json -> {}]", out.display()),
        Err(e) => println!("[json write failed: {e}]"),
    }
}
