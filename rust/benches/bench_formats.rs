//! Codec throughput: fake-quantization rates per format — the L3 hot-path
//! primitive (token-wise activation quant runs on every linear input).
//! The engine-hot-path section sweeps the activation format of every
//! recipe preset (read off [`QuantRecipe::preset`], so the bench can't
//! drift from the formats the serving stack actually configures).
//! §Perf baseline/after numbers live in EXPERIMENTS.md; writes
//! `bench_results/bench_formats.json` for the perf trajectory.

use std::path::Path;

use zeroquant_fp::bench_harness::Bench;
use zeroquant_fp::formats::{FpFormat, NumericFormat};
use zeroquant_fp::quant::{fake_quant_tokenwise, ActQuantConfig};
use zeroquant_fp::recipe::{PRESET_NAMES, QuantRecipe};
use zeroquant_fp::rng::Rng;
use zeroquant_fp::tensor::Matrix;

fn main() {
    let mut rng = Rng::seeded(7);
    let n = 1usize << 16;
    let data: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 0.1).collect();
    let mut bench = Bench::default();

    println!("-- scalar fake-quant throughput ({} elements) --", n);
    for fmt in [
        NumericFormat::FP8_E4M3,
        NumericFormat::FP8_E5M2,
        NumericFormat::FP4_E2M1,
        NumericFormat::FP4_E3M0,
        NumericFormat::INT8,
        NumericFormat::INT4,
    ] {
        let mut buf = data.clone();
        bench.run(format!("fake_quant_slice {}", fmt.name()), n as f64, "elt", || {
            buf.copy_from_slice(&data);
            fmt.fake_quant_slice_dynamic(&mut buf);
        });
    }

    println!("\n-- encode/decode roundtrip --");
    for fmt in [FpFormat::E4M3, FpFormat::E2M1] {
        bench.run(format!("encode+decode {}", fmt.name()), n as f64, "elt", || {
            let mut acc = 0.0f32;
            for &x in &data {
                acc += fmt.decode(fmt.encode(x));
            }
            acc
        });
    }

    // ---- token-wise activation quant per recipe preset --------------------
    // The engine hot path exactly as each preset configures it: the
    // activation format is read off `QuantRecipe::preset`, not a local
    // list. Presets sharing a format share one row (the label names the
    // first preset that selects it).
    println!("\n-- token-wise activation quant per recipe preset, [128 x 512] --");
    let x0 = Matrix::randn(128, 512, 0.1, &mut rng);
    let mut seen: Vec<String> = Vec::new();
    for name in PRESET_NAMES {
        let recipe = QuantRecipe::preset(name).unwrap();
        let fmt = recipe.scheme.activation;
        if seen.contains(&fmt.name()) {
            continue;
        }
        seen.push(fmt.name());
        let cfg = ActQuantConfig::new(fmt);
        let mut x = x0.clone();
        bench.run(
            format!("tokenwise {} ({name})", fmt.name()),
            (128 * 512) as f64,
            "elt",
            || {
                x.data.copy_from_slice(&x0.data);
                fake_quant_tokenwise(&mut x, &cfg);
            },
        );
    }

    let out = Path::new("bench_results/bench_formats.json");
    match bench.write_json("bench_formats", out) {
        Ok(()) => println!("\n[json -> {}]", out.display()),
        Err(e) => println!("\n[json write failed: {e}]"),
    }
}
