//! Decode throughput: the reference string-keyed engine vs the prepacked
//! compiled plan, per activation scheme — the headline measurement of the
//! compiled-execution-plan PR (EXPERIMENTS.md §Perf), plus the PJRT HLO
//! path when artifacts are present.
//!
//! Always runs (no artifacts needed for the engine/compiled sections) and
//! writes `bench_results/bench_engine.json` so future PRs have a perf
//! trajectory: tokens/s for `engine fwd act=*` vs `compiled fwd act=*`.

use std::path::Path;

use zeroquant_fp::bench_harness::Bench;
use zeroquant_fp::coordinator::ServingStack;
use zeroquant_fp::engine::{Engine, EngineOpts, KernelTier};
use zeroquant_fp::formats::NumericFormat;
use zeroquant_fp::kernels::{FastKernels, Kernels, OracleKernels};
use zeroquant_fp::lorc::LorcConfig;
use zeroquant_fp::model::{Arch, Checkpoint, ModelConfig};
use zeroquant_fp::plan::CompiledModel;
use zeroquant_fp::quant::{
    quantize_weight_rtn, PackedWeight, ScaleConstraint, Scheme, WeightQuantConfig,
};
use zeroquant_fp::recipe::json::Json;
use zeroquant_fp::recipe::QuantRecipe;
use zeroquant_fp::rng::Rng;
use zeroquant_fp::runtime::{act_tag, score_artifact_name, HloScorer, SCORE_BATCH};
use zeroquant_fp::tensor::packed_matmul::GemvScratch;
use zeroquant_fp::tensor::Matrix;

const FORMATS: [NumericFormat; 3] =
    [NumericFormat::F16, NumericFormat::INT8, NumericFormat::FP8_E4M3];

fn main() {
    let mut rng = Rng::seeded(17);
    let fam = ModelConfig::family(Arch::Opt);
    let (cfg, _) = &fam[2]; // opt-m
    let ck = Checkpoint::random(cfg, &mut rng);
    let seq = cfg.max_seq;
    let window: Vec<u16> = (0..seq).map(|_| rng.below(cfg.vocab_size) as u16).collect();
    let mut bench = Bench::default();

    println!(
        "-- reference engine forward, {} (d={}, L={}), {} tokens --",
        cfg.name, cfg.d_model, cfg.n_layers, seq
    );
    for fmt in FORMATS {
        let opts = EngineOpts::with_act(fmt);
        let engine = Engine::with_opts(&ck, opts);
        bench.run(
            format!("engine fwd act={}", fmt.name()),
            seq as f64,
            "tok",
            || engine.forward(&window),
        );
    }

    println!("\n-- compiled plan forward (prepacked, arena, LUT actq) --");
    for fmt in FORMATS {
        let opts = EngineOpts::with_act(fmt);
        let model = CompiledModel::compile(&ck, opts);
        let mut scratch = model.scratch();
        bench.run(
            format!("compiled fwd act={}", fmt.name()),
            seq as f64,
            "tok",
            || {
                std::hint::black_box(model.forward(&window, &mut scratch));
            },
        );
    }

    println!();
    for fmt in FORMATS {
        if let Some(s) = bench.speedup(
            &format!("compiled fwd act={}", fmt.name()),
            &format!("engine fwd act={}", fmt.name()),
        ) {
            println!("compiled vs reference (act={}): {s:.2}x", fmt.name());
        }
    }

    // ---- packed W4 plan: memory footprint + tokens/s vs the f32 plan ----
    // (same quantized checkpoint; the packed plan stores bit-packed codes
    // and decodes through the fused shift-dequant GEMV)
    println!("\n-- packed W4 plan (bit-packed codes, fused dequant GEMV) --");
    let recipe = QuantRecipe::builder(Scheme::parse("w4a8-fp-fp").unwrap())
        .constraint(ScaleConstraint::M2 { rows: 32 })
        .use_gptq(false) // RTN: codes only, no calibration passes
        .packed(1)
        .build()
        .unwrap();
    let stack = ServingStack::build(&ck, &[], &recipe).unwrap();
    let dense_q = stack.compile_dense();
    let packed_q = stack.compile();
    let (db, pb) = (dense_q.linear_weight_bytes(), packed_q.linear_weight_bytes());
    bench.note("f32 plan linear weight bytes", db as f64);
    bench.note("packed plan linear weight bytes", pb as f64);
    bench.note("packed/f32 weight bytes ratio", pb as f64 / db.max(1) as f64);
    {
        let mut ds = dense_q.scratch();
        bench.run("compiled fwd w4a8 f32-plan", seq as f64, "tok", || {
            std::hint::black_box(dense_q.forward(&window, &mut ds));
        });
        let mut ps = packed_q.scratch();
        bench.run("compiled fwd w4a8 packed-plan", seq as f64, "tok", || {
            std::hint::black_box(packed_q.forward(&window, &mut ps));
        });
        if let Some(sp) =
            bench.speedup("compiled fwd w4a8 packed-plan", "compiled fwd w4a8 f32-plan")
        {
            println!("packed vs f32 plan (w4a8 fwd): {sp:.2}x");
        }
        // packed logits must match the f32 plan bit-for-bit
        let a = dense_q.forward(&window, &mut ds).clone();
        let b = packed_q.forward(&window, &mut ps);
        assert!(
            a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits()),
            "packed plan diverged from the f32 plan"
        );
        println!("packed bit-identity check: OK");
    }

    // ---- packed W4 + LoRC: factor bytes + the compensation's fwd cost ----
    // (rank-8 FP8 factors riding along the packed codes; the GEMV folds
    // the rank-r error into each decoded row, bit-identical to the dense
    // plan over the LoRC-folded checkpoint)
    println!("\n-- packed W4 + LoRC (rank 8, FP8 factors) --");
    let lorc_recipe = QuantRecipe::builder(recipe.scheme)
        .constraint(ScaleConstraint::M2 { rows: 32 })
        .use_gptq(false)
        .lorc(LorcConfig { rank: 8, factor_format: NumericFormat::FP8_E4M3 })
        .packed(1)
        .build()
        .unwrap();
    let lstack = ServingStack::build(&ck, &[], &lorc_recipe).unwrap();
    let dense_l = lstack.compile_dense();
    let packed_l = lstack.compile();
    let lorc_factor_bytes: usize = lstack.report.layers.iter().map(|l| l.lorc_bytes).sum();
    bench.note("packed+lorc plan linear weight bytes", packed_l.linear_weight_bytes() as f64);
    bench.note("lorc factor bytes (rank 8 fp8)", lorc_factor_bytes as f64);
    bench.note(
        "packed+lorc/f32 weight bytes ratio",
        packed_l.linear_weight_bytes() as f64 / dense_l.linear_weight_bytes().max(1) as f64,
    );
    {
        let mut ps = packed_l.scratch();
        bench.run("compiled fwd w4a8 packed-lorc-plan", seq as f64, "tok", || {
            std::hint::black_box(packed_l.forward(&window, &mut ps));
        });
        if let Some(sp) =
            bench.speedup("compiled fwd w4a8 packed-lorc-plan", "compiled fwd w4a8 packed-plan")
        {
            println!("lorc-on vs lorc-off packed fwd: {sp:.2}x");
        }
        // packed+LoRC logits must match the dense plan over the folded
        // effective checkpoint bit-for-bit
        let mut ds = dense_l.scratch();
        let a = dense_l.forward(&window, &mut ds).clone();
        let b = packed_l.forward(&window, &mut ps);
        assert!(
            a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits()),
            "packed+lorc plan diverged from the folded f32 plan"
        );
        println!("packed+lorc bit-identity check: OK");
    }

    // ---- kernel tiers: oracle vs fast over the same packed stack ----------
    // The fast tier is the same serving plan one recipe knob away
    // (`kernel_tier: fast`): 8-lane dequant-GEMV + persistent worker pool,
    // tolerance-gated by tests/kernel_tolerance.rs instead of bit-identity.
    // Forward-level rows first, then the kernel-level batch-8 GEMV
    // microbench whose speedup BENCH_TRAJECTORY.json tracks across PRs.
    println!("\n-- kernel tiers: oracle vs fast (w4a8 packed plan) --");
    let fast_recipe = QuantRecipe::builder(recipe.scheme)
        .constraint(ScaleConstraint::M2 { rows: 32 })
        .use_gptq(false)
        .packed(1)
        .kernels(KernelTier::Fast)
        .build()
        .unwrap();
    let fast_q = stack.with_recipe(&fast_recipe).unwrap().compile();
    {
        let mut fs = fast_q.scratch();
        bench.run("compiled fwd w4a8 fast-tier", seq as f64, "tok", || {
            std::hint::black_box(fast_q.forward(&window, &mut fs));
        });
        if let Some(sp) =
            bench.speedup("compiled fwd w4a8 fast-tier", "compiled fwd w4a8 packed-plan")
        {
            println!("fast vs oracle tier (w4a8 fwd): {sp:.2}x");
        }
    }
    let gemv_speedup = gemv_tier_microbench(&mut bench, &mut rng);
    trajectory_gate(&mut bench, gemv_speedup);

    // sanity: compiled logits must match the reference bit-for-bit
    let opts = EngineOpts::with_act(NumericFormat::FP8_E4M3);
    let reference = Engine::with_opts(&ck, opts).forward(&window);
    let compiled = CompiledModel::compile(&ck, opts).forward_alloc(&window);
    assert_eq!(
        reference.data.len(),
        compiled.data.len(),
        "logit shape mismatch"
    );
    let identical = reference
        .data
        .iter()
        .zip(&compiled.data)
        .all(|(a, b)| a.to_bits() == b.to_bits());
    assert!(identical, "compiled path diverged from the reference engine");
    println!("bit-identity check: OK");

    pjrt_section(&mut bench, cfg, &ck, &mut rng, seq);

    let out = Path::new("bench_results/bench_engine.json");
    match bench.write_json("bench_engine", out) {
        Ok(()) => println!("\n[json -> {}]", out.display()),
        Err(e) => println!("\n[json write failed: {e}]"),
    }
}

/// The kernel-level trajectory number: fast vs oracle fused dequant-GEMV
/// at batch 8 over one 256x512 W4 linear. Batch 8 amortizes the (shared)
/// row-decode cost over eight dots, so the ratio isolates the dot engines:
/// the oracle's serial 4-term accumulator chain against the fast tier's
/// eight independent lanes.
fn gemv_tier_microbench(bench: &mut Bench, rng: &mut Rng) -> f64 {
    println!("\n-- packed GEMV microbench, batch 8, 256x512 W4 codes --");
    let (rows, cols) = (256usize, 512usize);
    let wm = Matrix::randn(rows, cols, 0.05, rng);
    let q = quantize_weight_rtn(
        &wm,
        &WeightQuantConfig::new(NumericFormat::FP4_E2M1).with_group_size(64),
    );
    let w = PackedWeight::from_quantized(&q);
    let x = Matrix::randn(8, cols, 0.5, rng);
    let mut out = Matrix::zeros(8, rows);
    let mut s = GemvScratch::sized(cols, 0);
    let flops = 2.0 * (8 * rows * cols) as f64;
    let oracle = OracleKernels::new(1);
    bench.run("packed gemv B=8 (oracle)", flops, "FLOP", || {
        out.data.fill(0.0);
        oracle.packed_gemv(&x, &w, None, &mut out, &mut s);
    });
    let fast = FastKernels::new(1);
    bench.run("packed gemv B=8 (fast)", flops, "FLOP", || {
        out.data.fill(0.0);
        fast.packed_gemv(&x, &w, None, &mut out, &mut s);
    });
    let sp = bench
        .speedup("packed gemv B=8 (fast)", "packed gemv B=8 (oracle)")
        .unwrap_or(1.0);
    println!("fast vs oracle packed GEMV (B=8): {sp:.2}x");
    bench.note("fast gemv speedup B=8", sp);
    sp
}

/// `BENCH_TRAJECTORY.json` (repo root): the committed fast-tier perf
/// trajectory. Each entry records one PR's fast-vs-oracle packed-GEMV
/// speedup. The gate fails the bench (exit 1) when the measured speedup
/// drops below the last committed entry's `floor` (default: 10% under its
/// recorded speedup) — the fast tier is not allowed to silently regress
/// toward the oracle. The file is shared with other benches (bench_serving
/// gates `spec_decode_speedup` entries), so the gate keys on the last
/// entry that actually carries `fast_gemv_speedup`. Run with
/// `ZQFP_APPEND_TRAJECTORY=1` to append this run's measurement as a new
/// entry (`ZQFP_TRAJECTORY_TAG` labels it).
fn trajectory_gate(bench: &mut Bench, measured: f64) {
    let path = Path::new("../BENCH_TRAJECTORY.json");
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            println!("[trajectory gate skipped: {}: {e}]", path.display());
            return;
        }
    };
    let doc = match Json::parse(&text) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("trajectory gate: {} is unreadable: {e}", path.display());
            std::process::exit(1);
        }
    };
    let Some(Json::Arr(entries)) = doc.get("entries") else {
        eprintln!("trajectory gate: {} has no entries array", path.display());
        std::process::exit(1);
    };
    if let Some(last) = entries.iter().rev().find(|e| e.get("fast_gemv_speedup").is_some()) {
        let recorded = last.get("fast_gemv_speedup").and_then(Json::as_f64).unwrap_or(1.0);
        // Per-entry floors absorb runner-to-runner variance (shared CI
        // machines differ widely in autovectorization win and load).
        let floor = last.get("floor").and_then(Json::as_f64).unwrap_or(0.9 * recorded);
        bench.note("trajectory floor", floor);
        if measured < floor {
            eprintln!(
                "trajectory gate FAILED: fast GEMV speedup {measured:.2}x < floor {floor:.2}x \
                 (last committed entry: {recorded:.2}x)"
            );
            std::process::exit(1);
        }
        println!(
            "trajectory gate OK: {measured:.2}x >= floor {floor:.2}x (last entry {recorded:.2}x)"
        );
    }
    if std::env::var("ZQFP_APPEND_TRAJECTORY").as_deref() == Ok("1") {
        append_trajectory(path, doc, measured);
    }
}

/// Append `measured` as a new trajectory entry and rewrite the file
/// pretty-printed (the shape `Json::parse` round-trips).
fn append_trajectory(path: &Path, doc: Json, measured: f64) {
    let tag = std::env::var("ZQFP_TRAJECTORY_TAG").unwrap_or_else(|_| "local".to_string());
    let Json::Obj(mut kv) = doc else { return };
    for (key, value) in kv.iter_mut() {
        if key == "entries" {
            if let Json::Arr(entries) = value {
                let rounded = (measured * 100.0).round() / 100.0;
                entries.push(Json::Obj(vec![
                    ("tag".to_string(), Json::Str(tag.clone())),
                    ("fast_gemv_speedup".to_string(), Json::Num(rounded)),
                ]));
            }
        }
    }
    match std::fs::write(path, Json::Obj(kv).pretty() + "\n") {
        Ok(()) => println!("[trajectory entry appended -> {}]", path.display()),
        Err(e) => println!("[trajectory append failed: {e}]"),
    }
}

fn pjrt_section(
    bench: &mut Bench,
    cfg: &ModelConfig,
    ck: &Checkpoint,
    rng: &mut Rng,
    seq: usize,
) {
    let artifacts = Path::new("artifacts");
    let a16 = artifacts.join(score_artifact_name(cfg, "a16"));
    if !a16.exists() {
        println!("\n[pjrt section skipped: run `make artifacts`]");
        return;
    }
    println!("\n-- pjrt hlo scorer, batch {} --", SCORE_BATCH);
    let batch_tokens: Vec<u16> = (0..SCORE_BATCH * seq)
        .map(|_| rng.below(cfg.vocab_size) as u16)
        .collect();
    for fmt in FORMATS {
        let opts = EngineOpts::with_act(fmt);
        let path = artifacts.join(score_artifact_name(cfg, act_tag(&opts).unwrap()));
        let scorer = match HloScorer::load(&path, SCORE_BATCH, seq) {
            Ok(s) => s,
            Err(e) => {
                println!("[pjrt act={} skipped: {e}]", fmt.name());
                continue;
            }
        };
        let weights = scorer.upload_weights(ck).expect("weights upload");
        bench.run(
            format!("pjrt score act={}", fmt.name()),
            (SCORE_BATCH * seq) as f64,
            "tok",
            || scorer.score_batch(&batch_tokens, &weights).unwrap(),
        );
    }
    if let Some(s) = bench.speedup("pjrt score act=F16", "engine fwd act=F16") {
        println!("\npjrt vs engine (per token, F16): {s:.1}x");
    }
}
