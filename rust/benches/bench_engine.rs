//! Decode throughput: the reference string-keyed engine vs the prepacked
//! compiled plan, per activation scheme — the headline measurement of the
//! compiled-execution-plan PR (EXPERIMENTS.md §Perf), plus the PJRT HLO
//! path when artifacts are present.
//!
//! Always runs (no artifacts needed for the engine/compiled sections) and
//! writes `bench_results/bench_engine.json` so future PRs have a perf
//! trajectory: tokens/s for `engine fwd act=*` vs `compiled fwd act=*`.

use std::path::Path;

use zeroquant_fp::bench_harness::Bench;
use zeroquant_fp::engine::{Engine, EngineOpts};
use zeroquant_fp::formats::NumericFormat;
use zeroquant_fp::model::{Arch, Checkpoint, ModelConfig};
use zeroquant_fp::plan::CompiledModel;
use zeroquant_fp::quant::ActQuantConfig;
use zeroquant_fp::rng::Rng;
use zeroquant_fp::runtime::{act_tag, score_artifact_name, HloScorer, SCORE_BATCH};

const FORMATS: [NumericFormat; 3] =
    [NumericFormat::F16, NumericFormat::INT8, NumericFormat::FP8_E4M3];

fn main() {
    let mut rng = Rng::seeded(17);
    let fam = ModelConfig::family(Arch::Opt);
    let (cfg, _) = &fam[2]; // opt-m
    let ck = Checkpoint::random(cfg, &mut rng);
    let seq = cfg.max_seq;
    let window: Vec<u16> = (0..seq).map(|_| rng.below(cfg.vocab_size) as u16).collect();
    let mut bench = Bench::default();

    println!(
        "-- reference engine forward, {} (d={}, L={}), {} tokens --",
        cfg.name, cfg.d_model, cfg.n_layers, seq
    );
    for fmt in FORMATS {
        let opts = EngineOpts { act: ActQuantConfig::new(fmt) };
        let engine = Engine::with_opts(&ck, opts);
        bench.run(
            format!("engine fwd act={}", fmt.name()),
            seq as f64,
            "tok",
            || engine.forward(&window),
        );
    }

    println!("\n-- compiled plan forward (prepacked, arena, LUT actq) --");
    for fmt in FORMATS {
        let opts = EngineOpts { act: ActQuantConfig::new(fmt) };
        let model = CompiledModel::compile(&ck, opts);
        let mut scratch = model.scratch();
        bench.run(
            format!("compiled fwd act={}", fmt.name()),
            seq as f64,
            "tok",
            || {
                std::hint::black_box(model.forward(&window, &mut scratch));
            },
        );
    }

    println!();
    for fmt in FORMATS {
        if let Some(s) = bench.speedup(
            &format!("compiled fwd act={}", fmt.name()),
            &format!("engine fwd act={}", fmt.name()),
        ) {
            println!("compiled vs reference (act={}): {s:.2}x", fmt.name());
        }
    }

    // sanity: compiled logits must match the reference bit-for-bit
    let opts = EngineOpts { act: ActQuantConfig::new(NumericFormat::FP8_E4M3) };
    let reference = Engine::with_opts(&ck, opts).forward(&window);
    let compiled = CompiledModel::compile(&ck, opts).forward_alloc(&window);
    assert_eq!(
        reference.data.len(),
        compiled.data.len(),
        "logit shape mismatch"
    );
    let identical = reference
        .data
        .iter()
        .zip(&compiled.data)
        .all(|(a, b)| a.to_bits() == b.to_bits());
    assert!(identical, "compiled path diverged from the reference engine");
    println!("bit-identity check: OK");

    pjrt_section(&mut bench, cfg, &ck, &mut rng, seq);

    let out = Path::new("bench_results/bench_engine.json");
    match bench.write_json("bench_engine", out) {
        Ok(()) => println!("\n[json -> {}]", out.display()),
        Err(e) => println!("\n[json write failed: {e}]"),
    }
}

fn pjrt_section(
    bench: &mut Bench,
    cfg: &ModelConfig,
    ck: &Checkpoint,
    rng: &mut Rng,
    seq: usize,
) {
    let artifacts = Path::new("artifacts");
    let a16 = artifacts.join(score_artifact_name(cfg, "a16"));
    if !a16.exists() {
        println!("\n[pjrt section skipped: run `make artifacts`]");
        return;
    }
    println!("\n-- pjrt hlo scorer, batch {} --", SCORE_BATCH);
    let batch_tokens: Vec<u16> = (0..SCORE_BATCH * seq)
        .map(|_| rng.below(cfg.vocab_size) as u16)
        .collect();
    for fmt in FORMATS {
        let opts = EngineOpts { act: ActQuantConfig::new(fmt) };
        let path = artifacts.join(score_artifact_name(cfg, act_tag(&opts).unwrap()));
        let scorer = match HloScorer::load(&path, SCORE_BATCH, seq) {
            Ok(s) => s,
            Err(e) => {
                println!("[pjrt act={} skipped: {e}]", fmt.name());
                continue;
            }
        };
        let weights = scorer.upload_weights(ck).expect("weights upload");
        bench.run(
            format!("pjrt score act={}", fmt.name()),
            (SCORE_BATCH * seq) as f64,
            "tok",
            || scorer.score_batch(&batch_tokens, &weights).unwrap(),
        );
    }
    if let Some(s) = bench.speedup("pjrt score act=F16", "engine fwd act=F16") {
        println!("\npjrt vs engine (per token, F16): {s:.1}x");
    }
}
