//! Decode throughput: the reference string-keyed engine vs the prepacked
//! compiled plan, per activation scheme — the headline measurement of the
//! compiled-execution-plan PR (EXPERIMENTS.md §Perf), plus the PJRT HLO
//! path when artifacts are present.
//!
//! Always runs (no artifacts needed for the engine/compiled sections) and
//! writes `bench_results/bench_engine.json` so future PRs have a perf
//! trajectory: tokens/s for `engine fwd act=*` vs `compiled fwd act=*`.

use std::path::Path;

use zeroquant_fp::bench_harness::Bench;
use zeroquant_fp::coordinator::ServingStack;
use zeroquant_fp::engine::{Engine, EngineOpts};
use zeroquant_fp::formats::NumericFormat;
use zeroquant_fp::lorc::LorcConfig;
use zeroquant_fp::model::{Arch, Checkpoint, ModelConfig};
use zeroquant_fp::plan::CompiledModel;
use zeroquant_fp::quant::{ScaleConstraint, Scheme};
use zeroquant_fp::recipe::QuantRecipe;
use zeroquant_fp::rng::Rng;
use zeroquant_fp::runtime::{act_tag, score_artifact_name, HloScorer, SCORE_BATCH};

const FORMATS: [NumericFormat; 3] =
    [NumericFormat::F16, NumericFormat::INT8, NumericFormat::FP8_E4M3];

fn main() {
    let mut rng = Rng::seeded(17);
    let fam = ModelConfig::family(Arch::Opt);
    let (cfg, _) = &fam[2]; // opt-m
    let ck = Checkpoint::random(cfg, &mut rng);
    let seq = cfg.max_seq;
    let window: Vec<u16> = (0..seq).map(|_| rng.below(cfg.vocab_size) as u16).collect();
    let mut bench = Bench::default();

    println!(
        "-- reference engine forward, {} (d={}, L={}), {} tokens --",
        cfg.name, cfg.d_model, cfg.n_layers, seq
    );
    for fmt in FORMATS {
        let opts = EngineOpts::with_act(fmt);
        let engine = Engine::with_opts(&ck, opts);
        bench.run(
            format!("engine fwd act={}", fmt.name()),
            seq as f64,
            "tok",
            || engine.forward(&window),
        );
    }

    println!("\n-- compiled plan forward (prepacked, arena, LUT actq) --");
    for fmt in FORMATS {
        let opts = EngineOpts::with_act(fmt);
        let model = CompiledModel::compile(&ck, opts);
        let mut scratch = model.scratch();
        bench.run(
            format!("compiled fwd act={}", fmt.name()),
            seq as f64,
            "tok",
            || {
                std::hint::black_box(model.forward(&window, &mut scratch));
            },
        );
    }

    println!();
    for fmt in FORMATS {
        if let Some(s) = bench.speedup(
            &format!("compiled fwd act={}", fmt.name()),
            &format!("engine fwd act={}", fmt.name()),
        ) {
            println!("compiled vs reference (act={}): {s:.2}x", fmt.name());
        }
    }

    // ---- packed W4 plan: memory footprint + tokens/s vs the f32 plan ----
    // (same quantized checkpoint; the packed plan stores bit-packed codes
    // and decodes through the fused shift-dequant GEMV)
    println!("\n-- packed W4 plan (bit-packed codes, fused dequant GEMV) --");
    let recipe = QuantRecipe::builder(Scheme::parse("w4a8-fp-fp").unwrap())
        .constraint(ScaleConstraint::M2 { rows: 32 })
        .use_gptq(false) // RTN: codes only, no calibration passes
        .packed(1)
        .build()
        .unwrap();
    let stack = ServingStack::build(&ck, &[], &recipe).unwrap();
    let dense_q = stack.compile_dense();
    let packed_q = stack.compile();
    let (db, pb) = (dense_q.linear_weight_bytes(), packed_q.linear_weight_bytes());
    bench.note("f32 plan linear weight bytes", db as f64);
    bench.note("packed plan linear weight bytes", pb as f64);
    bench.note("packed/f32 weight bytes ratio", pb as f64 / db.max(1) as f64);
    {
        let mut ds = dense_q.scratch();
        bench.run("compiled fwd w4a8 f32-plan", seq as f64, "tok", || {
            std::hint::black_box(dense_q.forward(&window, &mut ds));
        });
        let mut ps = packed_q.scratch();
        bench.run("compiled fwd w4a8 packed-plan", seq as f64, "tok", || {
            std::hint::black_box(packed_q.forward(&window, &mut ps));
        });
        if let Some(sp) =
            bench.speedup("compiled fwd w4a8 packed-plan", "compiled fwd w4a8 f32-plan")
        {
            println!("packed vs f32 plan (w4a8 fwd): {sp:.2}x");
        }
        // packed logits must match the f32 plan bit-for-bit
        let a = dense_q.forward(&window, &mut ds).clone();
        let b = packed_q.forward(&window, &mut ps);
        assert!(
            a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits()),
            "packed plan diverged from the f32 plan"
        );
        println!("packed bit-identity check: OK");
    }

    // ---- packed W4 + LoRC: factor bytes + the compensation's fwd cost ----
    // (rank-8 FP8 factors riding along the packed codes; the GEMV folds
    // the rank-r error into each decoded row, bit-identical to the dense
    // plan over the LoRC-folded checkpoint)
    println!("\n-- packed W4 + LoRC (rank 8, FP8 factors) --");
    let lorc_recipe = QuantRecipe::builder(recipe.scheme)
        .constraint(ScaleConstraint::M2 { rows: 32 })
        .use_gptq(false)
        .lorc(LorcConfig { rank: 8, factor_format: NumericFormat::FP8_E4M3 })
        .packed(1)
        .build()
        .unwrap();
    let lstack = ServingStack::build(&ck, &[], &lorc_recipe).unwrap();
    let dense_l = lstack.compile_dense();
    let packed_l = lstack.compile();
    let lorc_factor_bytes: usize = lstack.report.layers.iter().map(|l| l.lorc_bytes).sum();
    bench.note("packed+lorc plan linear weight bytes", packed_l.linear_weight_bytes() as f64);
    bench.note("lorc factor bytes (rank 8 fp8)", lorc_factor_bytes as f64);
    bench.note(
        "packed+lorc/f32 weight bytes ratio",
        packed_l.linear_weight_bytes() as f64 / dense_l.linear_weight_bytes().max(1) as f64,
    );
    {
        let mut ps = packed_l.scratch();
        bench.run("compiled fwd w4a8 packed-lorc-plan", seq as f64, "tok", || {
            std::hint::black_box(packed_l.forward(&window, &mut ps));
        });
        if let Some(sp) =
            bench.speedup("compiled fwd w4a8 packed-lorc-plan", "compiled fwd w4a8 packed-plan")
        {
            println!("lorc-on vs lorc-off packed fwd: {sp:.2}x");
        }
        // packed+LoRC logits must match the dense plan over the folded
        // effective checkpoint bit-for-bit
        let mut ds = dense_l.scratch();
        let a = dense_l.forward(&window, &mut ds).clone();
        let b = packed_l.forward(&window, &mut ps);
        assert!(
            a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits()),
            "packed+lorc plan diverged from the folded f32 plan"
        );
        println!("packed+lorc bit-identity check: OK");
    }

    // sanity: compiled logits must match the reference bit-for-bit
    let opts = EngineOpts::with_act(NumericFormat::FP8_E4M3);
    let reference = Engine::with_opts(&ck, opts).forward(&window);
    let compiled = CompiledModel::compile(&ck, opts).forward_alloc(&window);
    assert_eq!(
        reference.data.len(),
        compiled.data.len(),
        "logit shape mismatch"
    );
    let identical = reference
        .data
        .iter()
        .zip(&compiled.data)
        .all(|(a, b)| a.to_bits() == b.to_bits());
    assert!(identical, "compiled path diverged from the reference engine");
    println!("bit-identity check: OK");

    pjrt_section(&mut bench, cfg, &ck, &mut rng, seq);

    let out = Path::new("bench_results/bench_engine.json");
    match bench.write_json("bench_engine", out) {
        Ok(()) => println!("\n[json -> {}]", out.display()),
        Err(e) => println!("\n[json write failed: {e}]"),
    }
}

fn pjrt_section(
    bench: &mut Bench,
    cfg: &ModelConfig,
    ck: &Checkpoint,
    rng: &mut Rng,
    seq: usize,
) {
    let artifacts = Path::new("artifacts");
    let a16 = artifacts.join(score_artifact_name(cfg, "a16"));
    if !a16.exists() {
        println!("\n[pjrt section skipped: run `make artifacts`]");
        return;
    }
    println!("\n-- pjrt hlo scorer, batch {} --", SCORE_BATCH);
    let batch_tokens: Vec<u16> = (0..SCORE_BATCH * seq)
        .map(|_| rng.below(cfg.vocab_size) as u16)
        .collect();
    for fmt in FORMATS {
        let opts = EngineOpts::with_act(fmt);
        let path = artifacts.join(score_artifact_name(cfg, act_tag(&opts).unwrap()));
        let scorer = match HloScorer::load(&path, SCORE_BATCH, seq) {
            Ok(s) => s,
            Err(e) => {
                println!("[pjrt act={} skipped: {e}]", fmt.name());
                continue;
            }
        };
        let weights = scorer.upload_weights(ck).expect("weights upload");
        bench.run(
            format!("pjrt score act={}", fmt.name()),
            (SCORE_BATCH * seq) as f64,
            "tok",
            || scorer.score_batch(&batch_tokens, &weights).unwrap(),
        );
    }
    if let Some(s) = bench.speedup("pjrt score act=F16", "engine fwd act=F16") {
        println!("\npjrt vs engine (per token, F16): {s:.1}x");
    }
}
