//! Scoring throughput: the interpretive Rust engine vs the PJRT HLO path,
//! per activation scheme — quantifies why the table harness runs on PJRT
//! and what the A8 fake-quant costs end to end.
//!
//! Requires `make artifacts`; engine-only numbers print regardless.

use std::path::Path;

use zeroquant_fp::bench_harness::Bench;
use zeroquant_fp::engine::{Engine, EngineOpts};
use zeroquant_fp::formats::NumericFormat;
use zeroquant_fp::model::{Arch, Checkpoint, ModelConfig};
use zeroquant_fp::quant::ActQuantConfig;
use zeroquant_fp::rng::Rng;
use zeroquant_fp::runtime::{act_tag, score_artifact_name, HloScorer, SCORE_BATCH};

fn main() {
    let mut rng = Rng::seeded(17);
    let fam = ModelConfig::family(Arch::Opt);
    let (cfg, _) = &fam[2]; // opt-m
    let ck = Checkpoint::random(cfg, &mut rng);
    let seq = cfg.max_seq;
    let window: Vec<u16> = (0..seq).map(|_| rng.below(cfg.vocab_size) as u16).collect();
    let mut bench = Bench::default();

    println!("-- rust engine forward, {} (d={}, L={}), {} tokens --",
             cfg.name, cfg.d_model, cfg.n_layers, seq);
    for fmt in [NumericFormat::F16, NumericFormat::INT8, NumericFormat::FP8_E4M3] {
        let opts = EngineOpts { act: ActQuantConfig::new(fmt) };
        let engine = Engine::with_opts(&ck, opts);
        bench.run(
            format!("engine fwd act={}", fmt.name()),
            seq as f64,
            "tok",
            || engine.forward(&window),
        );
    }

    let artifacts = Path::new("artifacts");
    let a16 = artifacts.join(score_artifact_name(cfg, "a16"));
    if !a16.exists() {
        println!("\n[pjrt section skipped: run `make artifacts`]");
        return;
    }
    println!("\n-- pjrt hlo scorer, batch {} --", SCORE_BATCH);
    let batch_tokens: Vec<u16> = (0..SCORE_BATCH * seq)
        .map(|_| rng.below(cfg.vocab_size) as u16)
        .collect();
    for fmt in [NumericFormat::F16, NumericFormat::INT8, NumericFormat::FP8_E4M3] {
        let opts = EngineOpts { act: ActQuantConfig::new(fmt) };
        let scorer = HloScorer::load(
            &artifacts.join(score_artifact_name(cfg, act_tag(&opts).unwrap())),
            SCORE_BATCH,
            seq,
        )
        .expect("artifact loads");
        let weights = scorer.upload_weights(&ck).unwrap();
        bench.run(
            format!("pjrt score act={}", fmt.name()),
            (SCORE_BATCH * seq) as f64,
            "tok",
            || scorer.score_batch(&batch_tokens, &weights).unwrap(),
        );
    }
    if let Some(s) = bench.speedup("pjrt score act=F16", "engine fwd act=F16") {
        println!("\npjrt vs engine (per token, F16): {s:.1}x");
    }
}
