//! Serving-policy sweep: dynamic-batching window vs latency/throughput on
//! the coordinator — the L3 batching dial (§Perf).
//!
//! Runs on whichever backend is available: PJRT when `make artifacts` has
//! produced the scoring executable (and the `pjrt` feature is on),
//! otherwise the prepacked compiled in-process engine — so the sweep (and
//! the reference-vs-compiled decode comparison below it) works on a fresh
//! clone. Writes `bench_results/bench_serving.json` with decode tokens/s
//! so future PRs have a perf trajectory.

use std::path::Path;
use std::time::Duration;

use zeroquant_fp::bench_harness::Bench;
use zeroquant_fp::coordinator::{
    pick_backend, BatchPolicy, Coordinator, CoordinatorConfig, ScoreBackend,
};
use zeroquant_fp::engine::{Engine, EngineOpts};
use zeroquant_fp::formats::NumericFormat;
use zeroquant_fp::model::{Arch, Checkpoint, ModelConfig};
use zeroquant_fp::plan::CompiledModel;
use zeroquant_fp::quant::ActQuantConfig;
use zeroquant_fp::rng::Rng;
use zeroquant_fp::runtime::SCORE_BATCH;

fn main() {
    let fam = ModelConfig::family(Arch::Opt);
    let (cfg, _) = &fam[0]; // opt-xs: fastest, isolates coordinator overhead
    let mut rng = Rng::seeded(19);
    let ck = Checkpoint::random(cfg, &mut rng);
    let seq = cfg.max_seq;
    let n_requests = 160usize;
    let windows: Vec<Vec<u16>> = (0..n_requests)
        .map(|_| (0..seq).map(|_| rng.below(cfg.vocab_size) as u16).collect())
        .collect();

    let opts = EngineOpts::default();
    let backend = pick_backend(Path::new("artifacts"), &ck, &opts);
    // The batching-window dial only exists on the PJRT backend (a batched
    // GEMM to fill); the compiled backend decodes per request and drains the
    // queue eagerly, so sweeping wait_ms there would print a dead dial.
    let waits: &[u64] = match &backend {
        ScoreBackend::Pjrt { .. } => {
            println!("backend: pjrt");
            &[0, 1, 2, 5, 10]
        }
        ScoreBackend::Compiled => {
            println!("backend: compiled in-process engine (no batching dial — clients sweep only)");
            &[0]
        }
    };

    println!(
        "{:>10} {:>10} {:>12} {:>10} {:>10} {:>10}",
        "wait(ms)", "clients", "req/s", "p50(ms)", "p95(ms)", "batch"
    );
    for &wait_ms in waits {
        for clients in [1usize, 4, 8] {
            let coord = Coordinator::new(CoordinatorConfig {
                backend: backend.clone(),
                ck: ck.clone(),
                opts,
                policy: BatchPolicy {
                    max_batch: SCORE_BATCH,
                    max_wait: Duration::from_millis(wait_ms),
                },
            });
            let mut handles = Vec::new();
            for c in 0..clients {
                let client = coord.client();
                let mine: Vec<Vec<u16>> =
                    windows.iter().skip(c).step_by(clients).cloned().collect();
                handles.push(std::thread::spawn(move || {
                    for w in mine {
                        client.score(w).unwrap();
                    }
                }));
            }
            let report = coord.run().unwrap();
            for h in handles {
                h.join().unwrap();
            }
            println!(
                "{:>10} {:>10} {:>12.1} {:>10.2} {:>10.2} {:>10.2}",
                wait_ms,
                clients,
                report.throughput_rps(),
                report.latency.percentile_ms(50.0),
                report.latency.percentile_ms(95.0),
                report.mean_batch_size
            );
        }
    }
    if matches!(backend, ScoreBackend::Pjrt { .. }) {
        println!("\n(the latency/throughput dial: longer windows fill batches at the cost of p50)");
    }

    // ---- reference vs compiled decode, the serving-side perf trajectory --
    println!("\n-- reference engine vs compiled plan decode ({}, A8 FP) --", cfg.name);
    let mut bench = Bench::default();
    let window = &windows[0];
    for fmt in [NumericFormat::F16, NumericFormat::FP8_E4M3] {
        let opts = EngineOpts { act: ActQuantConfig::new(fmt) };
        let engine = Engine::with_opts(&ck, opts);
        bench.run(
            format!("engine decode act={}", fmt.name()),
            seq as f64,
            "tok",
            || engine.forward(window),
        );
        let model = CompiledModel::compile(&ck, opts);
        let mut scratch = model.scratch();
        bench.run(
            format!("compiled decode act={}", fmt.name()),
            seq as f64,
            "tok",
            || {
                std::hint::black_box(model.forward(window, &mut scratch));
            },
        );
        if let Some(s) = bench.speedup(
            &format!("compiled decode act={}", fmt.name()),
            &format!("engine decode act={}", fmt.name()),
        ) {
            println!("   compiled vs reference (act={}): {s:.2}x", fmt.name());
        }
    }

    let out = Path::new("bench_results/bench_serving.json");
    match bench.write_json("bench_serving", out) {
        Ok(()) => println!("\n[json -> {}]", out.display()),
        Err(e) => println!("\n[json write failed: {e}]"),
    }
}
