//! Serving-stack sweep: the batching dial, KV-cached vs full-recompute
//! decode, and the continuous-batching batch-size curve (§Perf).
//!
//! Four sections, all on whichever backend a fresh clone has (PJRT when
//! `make artifacts` produced the scoring executable and the `pjrt` feature
//! is on, otherwise the prepacked compiled in-process engine):
//!
//! 1. coordinator scoring sweep — the dynamic-batching wait window;
//! 2. full-recompute vs KV-cached generation — the `O(n²)` → `O(n)`
//!    attention win of `prefill` + `decode_step`;
//! 3. model-level batched decode, `B ∈ {1,2,4,8}` — decode tokens/s vs
//!    batch width (weight-streaming amortization, the continuous-batching
//!    rationale);
//! 4. coordinator continuous-batching generation, `max_batch ∈ {1,2,4,8}`
//!    — the same curve end to end through the request queue;
//! 5. self-speculative decoding — draft/target recipe pairs at
//!    `max_batch ∈ {1,2,4}`: effective decode tokens/s and acceptance rate
//!    vs the target-only baseline over identical traffic, with the B=1
//!    speedup gated against the `spec_decode_speedup` entries of
//!    `BENCH_TRAJECTORY.json` (floor 1.0: speculation must never decode
//!    slower than the target alone);
//! 6. multi-turn chat — the same dialogs replayed as fresh full-history
//!    prefills vs persistent-session delta prefills at `turns ∈ {2,4,8}`:
//!    prefilled tokens, the savings ratio, and the restore/eviction
//!    counters, recorded as JSON notes.
//!
//! Writes `bench_results/bench_serving.json` (decode tokens/s in the
//! `throughput` fields) so future PRs have a perf trajectory.

use std::path::Path;
use std::time::Duration;

use zeroquant_fp::bench_harness::{Bench, Measurement};
use zeroquant_fp::coordinator::{pick_backend, ScoreBackend, ServingStack};
use zeroquant_fp::engine::{Engine, EngineOpts, KernelTier};
use zeroquant_fp::formats::NumericFormat;
use zeroquant_fp::lorc::LorcConfig;
use zeroquant_fp::model::{Arch, Checkpoint, ModelConfig};
use zeroquant_fp::plan::{argmax, CompiledModel, KvCache};
use zeroquant_fp::quant::{ScaleConstraint, Scheme};
use zeroquant_fp::recipe::json::Json;
use zeroquant_fp::recipe::{QuantRecipe, SpeculateConfig};
use zeroquant_fp::rng::Rng;
use zeroquant_fp::runtime::SCORE_BATCH;

fn main() {
    let fam = ModelConfig::family(Arch::Opt);
    let (cfg, _) = &fam[0]; // opt-xs: fastest, isolates coordinator overhead
    let mut rng = Rng::seeded(19);
    let ck = Checkpoint::random(cfg, &mut rng);
    let seq = cfg.max_seq;
    let n_requests = 160usize;
    let windows: Vec<Vec<u16>> = (0..n_requests)
        .map(|_| (0..seq).map(|_| rng.below(cfg.vocab_size) as u16).collect())
        .collect();

    let opts = EngineOpts::default();
    let backend = pick_backend(Path::new("artifacts"), &ck, &opts);
    // The batching-window dial only exists on the PJRT backend (a batched
    // GEMM to fill); the compiled backend joins sequences mid-flight
    // instead of waiting, so sweeping wait_ms there would print a dead
    // dial — its batching curve is section 4.
    let waits: &[u64] = match &backend {
        ScoreBackend::Pjrt { .. } => {
            println!("backend: pjrt");
            &[0, 1, 2, 5, 10]
        }
        ScoreBackend::Compiled => {
            println!("backend: compiled in-process engine (scoring: clients sweep only)");
            &[0]
        }
    };

    println!(
        "{:>10} {:>10} {:>12} {:>10} {:>10} {:>10}",
        "wait(ms)", "clients", "req/s", "p50(ms)", "p95(ms)", "batch"
    );
    // The W16 no-op preset with per-run batching overrides: the benches
    // drive the same recipe → ServingStack path the CLI and the e2e
    // example use, so the sweep also covers that wiring.
    let w16 = QuantRecipe::preset("w16").unwrap();
    for &wait_ms in waits {
        for clients in [1usize, 4, 8] {
            let mut r = w16.clone();
            r.max_batch = SCORE_BATCH;
            r.max_wait_ms = wait_ms;
            let coord = ServingStack::build(&ck, &[], &r)
                .unwrap()
                .coordinator_with_backend(backend.clone());
            let mut handles = Vec::new();
            for c in 0..clients {
                let client = coord.client().unwrap();
                let mine: Vec<Vec<u16>> =
                    windows.iter().skip(c).step_by(clients).cloned().collect();
                handles.push(std::thread::spawn(move || {
                    for w in mine {
                        client.score(w).unwrap();
                    }
                }));
            }
            let report = coord.run().unwrap();
            for h in handles {
                h.join().unwrap();
            }
            println!(
                "{:>10} {:>10} {:>12.1} {:>10.2} {:>10.2} {:>10.2}",
                wait_ms,
                clients,
                report.throughput_rps(),
                report.latency.percentile_ms(50.0),
                report.latency.percentile_ms(95.0),
                report.mean_batch_size
            );
        }
    }
    if matches!(backend, ScoreBackend::Pjrt { .. }) {
        println!("\n(the latency/throughput dial: longer windows fill batches at the cost of p50)");
    }

    let mut bench = Bench::default();

    // ---- reference vs compiled decode, the serving-side perf trajectory --
    println!("\n-- reference engine vs compiled plan forward ({}, A8 FP) --", cfg.name);
    let window = &windows[0];
    for fmt in [NumericFormat::F16, NumericFormat::FP8_E4M3] {
        let opts = EngineOpts::with_act(fmt);
        let engine = Engine::with_opts(&ck, opts);
        bench.run(
            format!("engine decode act={}", fmt.name()),
            seq as f64,
            "tok",
            || engine.forward(window),
        );
        let model = CompiledModel::compile(&ck, opts);
        let mut scratch = model.scratch();
        bench.run(
            format!("compiled decode act={}", fmt.name()),
            seq as f64,
            "tok",
            || {
                std::hint::black_box(model.forward(window, &mut scratch));
            },
        );
        if let Some(s) = bench.speedup(
            &format!("compiled decode act={}", fmt.name()),
            &format!("engine decode act={}", fmt.name()),
        ) {
            println!("   compiled vs reference (act={}): {s:.2}x", fmt.name());
        }
    }

    // ---- full-recompute vs KV-cached generation ---------------------------
    // The tentpole number: generating n tokens by re-running forward over
    // the growing window is O(n²·d) in attention; prefill + decode_step is
    // O(n·d) per token. Both sides produce bit-identical tokens.
    println!("\n-- full-recompute vs kv-cached generation ({}, 64-token prompt) --", cfg.name);
    let model = CompiledModel::compile(&ck, opts);
    let mut scratch = model.scratch();
    let prompt = &windows[0][..64];
    bench.run("gen 64 (full-recompute fwd)", 64.0, "tok", || {
        let mut window: Vec<u16> = prompt.to_vec();
        for _ in 0..64 {
            let logits = model.forward(&window, &mut scratch);
            let next = argmax(logits.row(logits.rows - 1)) as u16;
            window.push(next);
        }
        std::hint::black_box(window.len());
    });
    let mut cache = model.kv_cache();
    bench.run("gen 64 (kv-cached decode)", 64.0, "tok", || {
        cache.reset();
        let logits = model.prefill(prompt, &mut cache, &mut scratch);
        let mut next = argmax(logits.row(logits.rows - 1)) as u16;
        for _ in 0..63 {
            let row = model.decode_step(next, &mut cache, &mut scratch);
            next = argmax(row.row(0)) as u16;
        }
        std::hint::black_box(next);
    });
    if let Some(s) = bench.speedup("gen 64 (kv-cached decode)", "gen 64 (full-recompute fwd)") {
        println!("   kv cache vs full recompute: {s:.2}x");
    }

    // ---- batched decode: tokens/s vs batch width --------------------------
    // One decode_step_batch call runs every linear as a [B, ·] matmul, so
    // each layer's weights stream from memory once per step for B
    // sequences instead of once per sequence — decode tokens/s should rise
    // with B. (Per-sequence logits stay bit-identical to solo decode.)
    println!("\n-- batched kv decode: tokens/s vs batch width --");
    for b in [1usize, 2, 4, 8] {
        let mut caches: Vec<KvCache> = (0..b).map(|_| model.kv_cache()).collect();
        let mut toks: Vec<u16> = vec![0; b];
        bench.run(format!("batched decode B={b} (ctx 16+48)"), (b * 48) as f64, "tok", || {
            for (i, c) in caches.iter_mut().enumerate() {
                c.reset();
                model.prefill(&windows[i][..16], c, &mut scratch);
            }
            for (i, t) in toks.iter_mut().enumerate() {
                *t = windows[i][16];
            }
            for _ in 0..48 {
                let logits = model.decode_step_batch(&toks, &mut caches, &mut scratch);
                for (i, t) in toks.iter_mut().enumerate() {
                    *t = argmax(logits.row(i)) as u16;
                }
            }
        });
    }

    // ---- packed W4 plan vs f32 plan: decode tokens/s + weight bytes -------
    // The deployment question the packed layout answers: same bits out,
    // how much less memory streamed and how many tokens/s? Recorded in
    // the JSON artifact (measurements + notes) as the packed-vs-f32 perf
    // trajectory.
    println!("\n-- packed W4 plan vs f32 plan (w4a8, batched kv decode) --");
    let w4_recipe = QuantRecipe::builder(Scheme::parse("w4a8-fp-fp").unwrap())
        .constraint(ScaleConstraint::M2 { rows: 32 })
        .use_gptq(false) // RTN: codes only, no calibration passes
        .packed(1)
        .build()
        .unwrap();
    let w4_stack = ServingStack::build(&ck, &[], &w4_recipe).unwrap();
    let dense_q = w4_stack.compile_dense();
    let packed_q = w4_stack.compile();
    let (db, pb) = (dense_q.linear_weight_bytes(), packed_q.linear_weight_bytes());
    bench.note("f32 plan linear weight bytes", db as f64);
    bench.note("packed plan linear weight bytes", pb as f64);
    bench.note("packed/f32 weight bytes ratio", pb as f64 / db.max(1) as f64);
    for (tag, m) in [("f32-plan", &dense_q), ("packed-plan", &packed_q)] {
        let mut qscratch = m.scratch();
        let mut caches: Vec<KvCache> = (0..4).map(|_| m.kv_cache()).collect();
        let mut toks: Vec<u16> = vec![0; 4];
        bench.run(format!("w4a8 decode B=4 ({tag})"), (4 * 48) as f64, "tok", || {
            for (i, c) in caches.iter_mut().enumerate() {
                c.reset();
                m.prefill(&windows[i][..16], c, &mut qscratch);
            }
            for (i, t) in toks.iter_mut().enumerate() {
                *t = windows[i][16];
            }
            for _ in 0..48 {
                let logits = m.decode_step_batch(&toks, &mut caches, &mut qscratch);
                for (i, t) in toks.iter_mut().enumerate() {
                    *t = argmax(logits.row(i)) as u16;
                }
            }
        });
    }
    if let Some(sp) = bench.speedup("w4a8 decode B=4 (packed-plan)", "w4a8 decode B=4 (f32-plan)") {
        println!("   packed vs f32 plan decode: {sp:.2}x");
    }

    // fast tier on the same stack: the tolerance-gated 8-lane GEMV +
    // persistent worker pool, one recipe knob (`kernel_tier: fast`) away
    // from the oracle packed-plan row above — the serving-side view of the
    // kernel-level trajectory number bench_engine gates.
    let fast_recipe = QuantRecipe::builder(w4_recipe.scheme)
        .constraint(ScaleConstraint::M2 { rows: 32 })
        .use_gptq(false)
        .packed(1)
        .kernels(KernelTier::Fast)
        .build()
        .unwrap();
    let fast_q = w4_stack.with_recipe(&fast_recipe).unwrap().compile();
    {
        let mut qscratch = fast_q.scratch();
        let mut caches: Vec<KvCache> = (0..4).map(|_| fast_q.kv_cache()).collect();
        let mut toks: Vec<u16> = vec![0; 4];
        bench.run("w4a8 decode B=4 (fast-tier)", (4 * 48) as f64, "tok", || {
            for (i, c) in caches.iter_mut().enumerate() {
                c.reset();
                fast_q.prefill(&windows[i][..16], c, &mut qscratch);
            }
            for (i, t) in toks.iter_mut().enumerate() {
                *t = windows[i][16];
            }
            for _ in 0..48 {
                let logits = fast_q.decode_step_batch(&toks, &mut caches, &mut qscratch);
                for (i, t) in toks.iter_mut().enumerate() {
                    *t = argmax(logits.row(i)) as u16;
                }
            }
        });
    }
    if let Some(sp) =
        bench.speedup("w4a8 decode B=4 (fast-tier)", "w4a8 decode B=4 (packed-plan)")
    {
        println!("   fast vs oracle tier decode: {sp:.2}x");
    }

    // ---- packed W4A8 + LoRC: the compensation's decode cost ---------------
    // LoRC-on vs LoRC-off on the same packed layout. The GEMV materializes
    // each weight row's rank-r error in the fold's accumulation order (the
    // price of bit-identity with the dense effective checkpoint — see
    // ARCHITECTURE.md §LoRC runtime path), so decode pays ~rank extra MACs
    // per weight; this section records how that lands in tokens/s, plus
    // the factor-byte overhead, in the JSON artifact.
    println!("\n-- packed W4A8 + LoRC (rank 8, FP8 factors): decode cost of compensation --");
    let lorc_recipe = QuantRecipe::builder(w4_recipe.scheme)
        .constraint(ScaleConstraint::M2 { rows: 32 })
        .use_gptq(false)
        .lorc(LorcConfig { rank: 8, factor_format: NumericFormat::FP8_E4M3 })
        .packed(1)
        .build()
        .unwrap();
    let lorc_stack = ServingStack::build(&ck, &[], &lorc_recipe).unwrap();
    let packed_lorc = lorc_stack.compile();
    let lorc_factor_bytes: usize = lorc_stack.report.layers.iter().map(|l| l.lorc_bytes).sum();
    bench.note("packed+lorc plan linear weight bytes", packed_lorc.linear_weight_bytes() as f64);
    bench.note("lorc factor bytes (rank 8 fp8)", lorc_factor_bytes as f64);
    {
        let mut qscratch = packed_lorc.scratch();
        let mut caches: Vec<KvCache> = (0..4).map(|_| packed_lorc.kv_cache()).collect();
        let mut toks: Vec<u16> = vec![0; 4];
        bench.run("w4a8 decode B=4 (packed-lorc-plan)", (4 * 48) as f64, "tok", || {
            for (i, c) in caches.iter_mut().enumerate() {
                c.reset();
                packed_lorc.prefill(&windows[i][..16], c, &mut qscratch);
            }
            for (i, t) in toks.iter_mut().enumerate() {
                *t = windows[i][16];
            }
            for _ in 0..48 {
                let logits = packed_lorc.decode_step_batch(&toks, &mut caches, &mut qscratch);
                for (i, t) in toks.iter_mut().enumerate() {
                    *t = argmax(logits.row(i)) as u16;
                }
            }
        });
    }
    if let Some(sp) =
        bench.speedup("w4a8 decode B=4 (packed-lorc-plan)", "w4a8 decode B=4 (packed-plan)")
    {
        println!(
            "   lorc-on vs lorc-off packed decode: {sp:.2}x ({} factor B on top of packed codes)",
            lorc_factor_bytes
        );
    }

    // ---- the same curve end to end: coordinator continuous batching -------
    println!("\n-- coordinator continuous-batching generation (8 clients, 48 requests) --");
    for max_batch in [1usize, 2, 4, 8] {
        let mut r = w16.clone();
        r.max_batch = max_batch;
        r.max_wait_ms = 0;
        let coord = ServingStack::build(&ck, &[], &r).unwrap().coordinator();
        let mut handles = Vec::new();
        for c in 0..8usize {
            let client = coord.gen_client().unwrap();
            let mine: Vec<Vec<u16>> = windows
                .iter()
                .skip(c)
                .step_by(8)
                .take(6)
                .map(|w| w[..64].to_vec())
                .collect();
            handles.push(std::thread::spawn(move || {
                for p in mine {
                    client.generate(p, 32).unwrap();
                }
            }));
        }
        let report = coord.run().unwrap();
        for h in handles {
            h.join().unwrap();
        }
        let m = Measurement {
            name: format!("coord gen max_batch={max_batch}"),
            iters: report.decode_steps,
            mean: report.decode_wall / report.decode_steps.max(1) as u32,
            stddev: Duration::ZERO,
            min: report.decode_wall / report.decode_steps.max(1) as u32,
            work_per_iter: Some(report.mean_decode_batch()),
            work_unit: "tok",
        };
        println!("{}", m.report());
        println!(
            "   max_batch={max_batch}: decode {:.0} tok/s aggregate, mean in-flight {:.2}",
            report.decode_tok_s(),
            report.mean_decode_batch()
        );
        bench.results.push(m);
    }

    // ---- overload drill: bounded admission + deadlines under pressure ----
    // A deliberately tiny queue and a tight deadline against a thundering
    // herd: the interesting numbers are the robustness counters (how much
    // load was shed typed instead of queued unbounded), recorded as JSON
    // notes so the perf trajectory also tracks shedding behavior.
    println!("\n-- overload drill (queue_depth=4, deadline 20ms, 8 clients) --");
    {
        let mut r = w16.clone();
        r.max_batch = 4;
        r.max_wait_ms = 0;
        r.queue_depth = 4;
        r.deadline_ms = 20;
        let coord = ServingStack::build(&ck, &[], &r).unwrap().coordinator();
        let mut handles = Vec::new();
        for c in 0..8usize {
            let client = coord.client().unwrap();
            let mine: Vec<Vec<u16>> =
                windows.iter().skip(c).step_by(8).take(12).cloned().collect();
            handles.push(std::thread::spawn(move || {
                let mut ok = 0usize;
                let mut degraded = 0usize;
                for w in mine {
                    match client.score(w) {
                        Ok(_) => ok += 1,
                        Err(_) => degraded += 1,
                    }
                }
                (ok, degraded)
            }));
        }
        let report = coord.run().unwrap();
        let (mut ok, mut degraded) = (0usize, 0usize);
        for h in handles {
            let (o, d) = h.join().unwrap();
            ok += o;
            degraded += d;
        }
        println!(
            "   {ok} ok, {degraded} degraded (shed {}, expired {} at admission + {} mid-flight)",
            report.shed_overloaded, report.expired_admission, report.expired_midflight
        );
        bench.note("overload shed_overloaded", report.shed_overloaded as f64);
        bench.note("overload expired_admission", report.expired_admission as f64);
        bench.note("overload expired_midflight", report.expired_midflight as f64);
        bench.note("overload ok_requests", ok as f64);
        bench.note("overload degraded_requests", degraded as f64);
    }

    // ---- mixed-length traffic: ring vs paged KV residency -----------------
    // The paged pool's reason to exist: with rings every admitted sequence
    // pins a full max_seq ring regardless of its actual length, so a mix of
    // short and long prompts pays peak bytes proportional to slots; pages
    // make the peak track live tokens. Three runs over identical traffic —
    // ring, paged with the auto (ring-equivalent) budget, and paged with a
    // deliberately tight budget that forces preemption — recorded as JSON
    // notes so the trajectory tracks residency and preemption behavior.
    println!("\n-- mixed-length traffic: ring vs paged kv residency (8 clients, 16/64-token prompts) --");
    {
        let page_positions = 16usize;
        let page_bytes =
            cfg.n_layers * 2 * page_positions * cfg.d_model * std::mem::size_of::<f32>();
        // half of the auto budget (max_batch × pages-per-ring): long
        // sequences must collide with it and preempt
        let tight_budget = 4 * cfg.max_seq.div_ceil(page_positions) / 2 * page_bytes;
        for (tag, page, budget) in [
            ("ring", 0usize, 0usize),
            ("paged-auto", page_positions, 0),
            ("paged-tight", page_positions, tight_budget),
        ] {
            let mut r = w16.clone();
            r.max_batch = 4;
            r.max_wait_ms = 0;
            r.kv_page_positions = page;
            r.kv_budget_bytes = budget;
            let coord = ServingStack::build(&ck, &[], &r).unwrap().coordinator();
            let mut handles = Vec::new();
            for c in 0..8usize {
                let client = coord.gen_client().unwrap();
                let mine: Vec<(Vec<u16>, usize)> = windows
                    .iter()
                    .skip(c)
                    .step_by(8)
                    .take(4)
                    .enumerate()
                    .map(|(i, w)| {
                        // alternate short (16 + 16 new) and long (64 + 32 new)
                        if i % 2 == 0 {
                            (w[..16].to_vec(), 16)
                        } else {
                            (w[..64].to_vec(), 32)
                        }
                    })
                    .collect();
                handles.push(std::thread::spawn(move || {
                    for (p, n) in mine {
                        client.generate(p, n).unwrap();
                    }
                }));
            }
            let report = coord.run().unwrap();
            for h in handles {
                h.join().unwrap();
            }
            println!(
                "   {tag:>11}: peak kv {:>9} B, pooled {:>9} B, preemptions {}, requeues {}",
                report.kv_peak_bytes, report.kv_pool_bytes, report.kv_preemptions, report.kv_requeues
            );
            bench.note(format!("mixed {tag} kv peak bytes"), report.kv_peak_bytes as f64);
            bench.note(format!("mixed {tag} kv pool bytes"), report.kv_pool_bytes as f64);
            bench.note(format!("mixed {tag} kv preemptions"), report.kv_preemptions as f64);
            bench.note(format!("mixed {tag} kv requeues"), report.kv_requeues as f64);
        }
    }

    // ---- self-speculative decoding: cheap-plan draft, target verify -------
    // Two plans of the same checkpoint: the draft proposes k tokens, the
    // target verifies all k+1 positions in one batched prefill pass and
    // commits the agreeing prefix. Output is exactly target-only greedy
    // decode (tests/speculative.rs holds the parity), so the only question
    // is throughput: effective decode tok/s and acceptance rate per
    // draft/target pair, against the target-only baseline over identical
    // traffic. The B=1 speedup of the headline pair (rank-0 fast-tier
    // draft under the packed LoRC target) is the `spec_decode_speedup`
    // trajectory number.
    println!("\n-- self-speculative decoding: draft/target recipe pairs (k=4) --");
    {
        let w4 = Scheme::parse("w4a8-fp-fp").unwrap();
        // Headline pair: the target serves packed W4+LoRC on the bit-exact
        // oracle tier; the draft strips the rank-8 correction and decodes
        // through the tolerance-gated 8-lane GEMV — materially cheaper per
        // step, close enough for high greedy agreement.
        let lorc_target = QuantRecipe::builder(w4)
            .constraint(ScaleConstraint::M2 { rows: 32 })
            .use_gptq(false)
            .lorc(LorcConfig { rank: 8, factor_format: NumericFormat::FP8_E4M3 })
            .packed(1)
            .build()
            .unwrap();
        let rank0_fast_draft = QuantRecipe::builder(w4)
            .constraint(ScaleConstraint::M2 { rows: 32 })
            .use_gptq(false)
            .packed(1)
            .kernels(KernelTier::Fast)
            .build()
            .unwrap();
        // Contrast pair: dense W16 target with a dense W4-scheme draft.
        // The draft differs only on activation numerics (same dense
        // weights), so acceptance is near-total but each drafted token
        // costs about a target step — the honest overhead floor of the
        // draft/verify loop itself.
        let dense_w4_draft = QuantRecipe::builder(w4)
            .constraint(ScaleConstraint::M2 { rows: 32 })
            .use_gptq(false)
            .build()
            .unwrap();
        let mut spec_b1_speedup = None;
        for (pair, target, draft) in [
            ("lorc+rank0fast", &lorc_target, &rank0_fast_draft),
            ("w16+densew4", &w16, &dense_w4_draft),
        ] {
            for b in [1usize, 2, 4] {
                let mut base_tok_s = 0.0f64;
                for spec_on in [false, true] {
                    let mut r = target.clone();
                    r.max_batch = b;
                    r.max_wait_ms = 0;
                    r.speculate = spec_on
                        .then(|| SpeculateConfig { draft: Box::new(draft.clone()), k: 4 });
                    let coord = ServingStack::build(&ck, &[], &r).unwrap().coordinator();
                    let mut handles = Vec::new();
                    for c in 0..4usize {
                        let client = coord.gen_client().unwrap();
                        let mine: Vec<Vec<u16>> = windows
                            .iter()
                            .skip(c)
                            .step_by(4)
                            .take(3)
                            .map(|w| w[..16].to_vec())
                            .collect();
                        handles.push(std::thread::spawn(move || {
                            for p in mine {
                                client.generate(p, 24).unwrap();
                            }
                        }));
                    }
                    let report = coord.run().unwrap();
                    for h in handles {
                        h.join().unwrap();
                    }
                    let tok_s = report.decode_tok_s();
                    if spec_on {
                        let speedup = tok_s / base_tok_s.max(1e-9);
                        println!(
                            "   {pair:>15} B={b}: spec {tok_s:>7.0} tok/s vs target-only \
                             {base_tok_s:>7.0} ({speedup:.2}x), acceptance {:.2}, \
                             {:.2} tok/round, {} fallbacks",
                            report.spec_acceptance_rate(),
                            report.spec_tokens_per_round(),
                            report.spec_fallbacks
                        );
                        bench.note(format!("spec {pair} B={b} decode speedup"), speedup);
                        bench.note(
                            format!("spec {pair} B={b} acceptance"),
                            report.spec_acceptance_rate(),
                        );
                        bench.note(
                            format!("spec {pair} B={b} tokens per round"),
                            report.spec_tokens_per_round(),
                        );
                        if pair == "lorc+rank0fast" && b == 1 {
                            spec_b1_speedup = Some(speedup);
                        }
                    } else {
                        base_tok_s = tok_s;
                    }
                }
            }
        }
        if let Some(speedup) = spec_b1_speedup {
            bench.note("spec decode speedup B=1", speedup);
            spec_trajectory_gate(&mut bench, speedup);
        }
    }

    // ---- multi-turn chat: fresh prefill vs session kv reuse ---------------
    // The session subsystem's reason to exist, measured: the same four
    // dialogs (64 prompt tokens, 32 generated) replayed two ways. The
    // fresh leg re-sends the whole accumulated history to `generate` every
    // turn, so prefill work grows quadratically with turn count; the
    // session leg sends only each turn's delta against the resident KV
    // cache, so prefill work stays linear. Greedy decode makes both legs
    // token-identical — the only difference is the prefill bill.
    println!("\n-- multi-turn chat: fresh prefill vs session kv reuse (4 dialogs, 64+32 tok) --");
    for turns in [2usize, 4, 8] {
        let mut prefill_by_leg = [0usize; 2];
        for (leg, (tag, reuse)) in [("fresh", false), ("session", true)].into_iter().enumerate() {
            let mut r = w16.clone();
            r.max_batch = 4;
            r.max_wait_ms = 0;
            let coord = ServingStack::build(&ck, &[], &r).unwrap().coordinator();
            let mut handles = Vec::new();
            for c in 0..4usize {
                let prompt = windows[c][..64].to_vec();
                if reuse {
                    let client = coord.session_client().unwrap();
                    handles.push(std::thread::spawn(move || {
                        let id = format!("dialog-{c}");
                        client.open(&id).unwrap();
                        for t in 0..turns {
                            let delta = prompt[t * 64 / turns..(t + 1) * 64 / turns].to_vec();
                            let quota = (t + 1) * 32 / turns - t * 32 / turns;
                            client.turn(&id, delta, quota).unwrap();
                        }
                    }));
                } else {
                    let client = coord.gen_client().unwrap();
                    handles.push(std::thread::spawn(move || {
                        let mut hist: Vec<u16> = Vec::new();
                        for t in 0..turns {
                            hist.extend_from_slice(&prompt[t * 64 / turns..(t + 1) * 64 / turns]);
                            let quota = (t + 1) * 32 / turns - t * 32 / turns;
                            let g = client.generate(hist.clone(), quota).unwrap();
                            hist.extend_from_slice(&g.tokens);
                        }
                    }));
                }
            }
            let report = coord.run().unwrap();
            for h in handles {
                h.join().unwrap();
            }
            prefill_by_leg[leg] = report.prefill_tokens;
            println!(
                "   turns={turns} {tag:>7}: prefilled {:>6} tok, decode {:>6.0} tok/s, \
                 restores {}, evicted {}",
                report.prefill_tokens,
                report.decode_tok_s(),
                report.session_restores,
                report.sessions_evicted
            );
            bench.note(
                format!("chat turns={turns} {tag} prefill tokens"),
                report.prefill_tokens as f64,
            );
            if reuse {
                bench.note(
                    format!("chat turns={turns} session restores"),
                    report.session_restores as f64,
                );
                bench.note(
                    format!("chat turns={turns} sessions evicted"),
                    report.sessions_evicted as f64,
                );
            }
        }
        let [fresh, session] = prefill_by_leg;
        let savings = 1.0 - session as f64 / fresh.max(1) as f64;
        println!("   turns={turns}: delta prefill saves {:.0}% of prefilled tokens", savings * 100.0);
        bench.note(format!("chat turns={turns} prefill savings"), savings);
    }

    let out = Path::new("bench_results/bench_serving.json");
    match bench.write_json("bench_serving", out) {
        Ok(()) => println!("\n[json -> {}]", out.display()),
        Err(e) => println!("\n[json write failed: {e}]"),
    }
}

/// The speculative-decode arm of `BENCH_TRAJECTORY.json` (repo root,
/// shared with bench_engine's `fast_gemv_speedup` gate). Each entry here
/// records one PR's B=1 speculative-vs-target-only decode speedup for the
/// headline pair; the gate fails the bench (exit 1) when the measured
/// speedup drops below the last `spec_decode_speedup` entry's `floor`
/// (default 1.0 — speculation is never allowed to decode slower than the
/// target alone). Run with `ZQFP_APPEND_TRAJECTORY=1` to append this
/// run's measurement (`ZQFP_TRAJECTORY_TAG` labels it).
fn spec_trajectory_gate(bench: &mut Bench, measured: f64) {
    let path = Path::new("../BENCH_TRAJECTORY.json");
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            println!("[spec trajectory gate skipped: {}: {e}]", path.display());
            return;
        }
    };
    let doc = match Json::parse(&text) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("spec trajectory gate: {} is unreadable: {e}", path.display());
            std::process::exit(1);
        }
    };
    let Some(Json::Arr(entries)) = doc.get("entries") else {
        eprintln!("spec trajectory gate: {} has no entries array", path.display());
        std::process::exit(1);
    };
    if let Some(last) = entries.iter().rev().find(|e| e.get("spec_decode_speedup").is_some()) {
        let recorded = last.get("spec_decode_speedup").and_then(Json::as_f64).unwrap_or(1.0);
        let floor = last.get("floor").and_then(Json::as_f64).unwrap_or(1.0);
        bench.note("spec trajectory floor", floor);
        if measured < floor {
            eprintln!(
                "spec trajectory gate FAILED: speculative B=1 decode speedup {measured:.2}x \
                 < floor {floor:.2}x (last committed entry: {recorded:.2}x)"
            );
            std::process::exit(1);
        }
        println!(
            "spec trajectory gate OK: {measured:.2}x >= floor {floor:.2}x \
             (last entry {recorded:.2}x)"
        );
    }
    if std::env::var("ZQFP_APPEND_TRAJECTORY").as_deref() == Ok("1") {
        append_spec_trajectory(path, doc, measured);
    }
}

/// Append `measured` as a new `spec_decode_speedup` trajectory entry and
/// rewrite the file pretty-printed (the shape `Json::parse` round-trips).
/// The floor stays pinned at 1.0: the invariant is "no slower than the
/// target alone", not a ratchet on runner-dependent speedups.
fn append_spec_trajectory(path: &Path, doc: Json, measured: f64) {
    let tag = std::env::var("ZQFP_TRAJECTORY_TAG").unwrap_or_else(|_| "local".to_string());
    let Json::Obj(mut kv) = doc else { return };
    for (key, value) in kv.iter_mut() {
        if key == "entries" {
            if let Json::Arr(entries) = value {
                let rounded = (measured * 100.0).round() / 100.0;
                entries.push(Json::Obj(vec![
                    ("tag".to_string(), Json::Str(tag.clone())),
                    ("spec_decode_speedup".to_string(), Json::Num(rounded)),
                    ("floor".to_string(), Json::Num(1.0)),
                ]));
            }
        }
    }
    match std::fs::write(path, Json::Obj(kv).pretty() + "\n") {
        Ok(()) => println!("[spec trajectory entry appended -> {}]", path.display()),
        Err(e) => println!("[spec trajectory append failed: {e}]"),
    }
}
