//! Serving-policy sweep: dynamic-batching window vs latency/throughput on
//! the coordinator — the L3 batching dial (§Perf). Requires artifacts.

use std::path::Path;
use std::time::{Duration, Instant};

use zeroquant_fp::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig};
use zeroquant_fp::engine::EngineOpts;
use zeroquant_fp::model::{Arch, Checkpoint, ModelConfig};
use zeroquant_fp::rng::Rng;
use zeroquant_fp::runtime::{score_artifact_name, SCORE_BATCH};

fn main() {
    let fam = ModelConfig::family(Arch::Opt);
    let (cfg, _) = &fam[0]; // opt-xs: fastest, isolates coordinator overhead
    let artifacts = Path::new("artifacts");
    if !artifacts.join(score_artifact_name(cfg, "a16")).exists() {
        println!("[skipped: run `make artifacts`]");
        return;
    }
    let mut rng = Rng::seeded(19);
    let ck = Checkpoint::random(cfg, &mut rng);
    let seq = cfg.max_seq;
    let n_requests = 160usize;
    let windows: Vec<Vec<u16>> = (0..n_requests)
        .map(|_| (0..seq).map(|_| rng.below(cfg.vocab_size) as u16).collect())
        .collect();

    println!(
        "{:>10} {:>10} {:>12} {:>10} {:>10} {:>10}",
        "wait(ms)", "clients", "req/s", "p50(ms)", "p95(ms)", "batch"
    );
    for wait_ms in [0u64, 1, 2, 5, 10] {
        for clients in [1usize, 4, 8] {
            let coord = Coordinator::new(CoordinatorConfig {
                artifacts: artifacts.to_path_buf(),
                ck: ck.clone(),
                opts: EngineOpts::default(),
                policy: BatchPolicy {
                    max_batch: SCORE_BATCH,
                    max_wait: Duration::from_millis(wait_ms),
                },
            });
            let _t0 = Instant::now();
            let mut handles = Vec::new();
            for c in 0..clients {
                let client = coord.client();
                let mine: Vec<Vec<u16>> =
                    windows.iter().skip(c).step_by(clients).cloned().collect();
                handles.push(std::thread::spawn(move || {
                    for w in mine {
                        client.score(w).unwrap();
                    }
                }));
            }
            let report = coord.run().unwrap();
            for h in handles {
                h.join().unwrap();
            }
            println!(
                "{:>10} {:>10} {:>12.1} {:>10.2} {:>10.2} {:>10.2}",
                wait_ms,
                clients,
                report.throughput_rps(),
                report.latency.percentile_ms(50.0),
                report.latency.percentile_ms(95.0),
                report.mean_batch_size
            );
        }
    }
    println!("\n(the latency/throughput dial: longer windows fill batches at the cost of p50)");
}
