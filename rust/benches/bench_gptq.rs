//! GPTQ pipeline cost: Hessian accumulation, Cholesky inversion, and the
//! column sweep, per layer size — the PTQ wall-time the paper's Appendix A
//! reports as "a single V100" (ours: a single CPU core).
//!
//! The per-preset section derives every knob (weight format, group size,
//! scale constraint, FP4→E5M2 cast, GPTQ damping, LoRC rank/format) from
//! the recipe layer — the exact `QuantRecipe` fields
//! `pipeline::quantize_checkpoint` reads — so the bench cannot drift from
//! what the quantize/serve pipeline actually runs. Writes
//! `bench_results/bench_gptq.json` so future PRs have a PTQ-cost
//! trajectory alongside the serving and kernel benches.

use std::path::Path;

use zeroquant_fp::bench_harness::Bench;
use zeroquant_fp::gptq::{gptq_quantize, HessianAccumulator};
use zeroquant_fp::linalg;
use zeroquant_fp::lorc::LorcFactors;
use zeroquant_fp::quant::{quantize_weight_rtn, WeightQuantConfig};
use zeroquant_fp::recipe::{QuantRecipe, PRESET_NAMES};
use zeroquant_fp::rng::Rng;
use zeroquant_fp::tensor::Matrix;

/// The PTQ-side weight config a recipe pins — the same derivation as
/// `pipeline::quantize_checkpoint` (format, grouping, scale constraint,
/// optional FP4→E5M2 scale cast).
fn weight_config(recipe: &QuantRecipe) -> WeightQuantConfig {
    WeightQuantConfig::new(recipe.scheme.weight)
        .with_group_size(recipe.group_size)
        .with_constraint(recipe.constraint)
        .with_cast(recipe.cast_fp4_to_e5m2)
}

fn main() {
    let mut rng = Rng::seeded(13);
    let mut bench = Bench::quick();

    // ---- recipe-independent stages: Hessian + Cholesky per layer size ----
    // (the calibration cost every GPTQ recipe pays, whatever its knobs)
    for dim in [128usize, 256, 512] {
        let x = Matrix::randn(512, dim, 1.0, &mut rng);
        println!("-- calibration [{dim}x{dim}], 512 tokens --");
        bench.run(
            format!("hessian accumulate d={dim}"),
            (512 * dim * dim) as f64 / 2.0,
            "MAC",
            || {
                let mut acc = HessianAccumulator::new(dim);
                acc.add_batch(&x);
                acc.finalize()
            },
        );
        let mut acc = HessianAccumulator::new(dim);
        acc.add_batch(&x);
        let h = acc.finalize();
        bench.run(format!("cholesky-inverse   d={dim}"), (dim * dim * dim) as f64, "op", || {
            let mut hd = h.clone();
            for i in 0..dim {
                *hd.at_mut(i, i) += 0.01;
            }
            linalg::cholesky_inverse_upper(&hd).unwrap()
        });
        println!();
    }

    // ---- per-preset PTQ cost on one 256x256 layer ------------------------
    // Every quantizing preset, knobs straight off the recipe: the GPTQ
    // sweep (or the RTN baseline for non-GPTQ recipes) plus the LoRC SVD
    // when the recipe compensates. W16 quantizes nothing and is skipped.
    let dim = 256usize;
    let w = Matrix::randn(dim, dim, 0.05, &mut rng);
    let x = Matrix::randn(512, dim, 1.0, &mut rng);
    let mut acc = HessianAccumulator::new(dim);
    acc.add_batch(&x);
    let h = acc.finalize();
    println!("-- per-preset PTQ cost, layer [{dim}x{dim}], calib 512 tokens --");
    for name in PRESET_NAMES {
        let recipe = QuantRecipe::preset(name).unwrap();
        if recipe.scheme.weight.bits() >= 16 {
            println!("   {name}: dense no-op preset, nothing to quantize");
            continue;
        }
        let wcfg = weight_config(&recipe);
        let q = if recipe.use_gptq {
            bench.run(
                format!("gptq sweep {name:<12} d={dim}"),
                (dim * dim * dim) as f64 / 2.0,
                "op",
                || gptq_quantize(&w, &h, &wcfg, &recipe.gptq).unwrap(),
            );
            gptq_quantize(&w, &h, &wcfg, &recipe.gptq).unwrap().weight
        } else {
            bench.run(
                format!("rtn        {name:<12} d={dim}"),
                (dim * dim) as f64,
                "elt",
                || quantize_weight_rtn(&w, &wcfg),
            );
            quantize_weight_rtn(&w, &wcfg)
        };
        if let Some(lcfg) = &recipe.lorc {
            let deq = q.dequantize();
            bench.run(format!("lorc svd r{} {name:<12} d={dim}", lcfg.rank), 0.0, "", || {
                LorcFactors::compute(&w, &deq, lcfg).unwrap()
            });
        }
    }

    let out = Path::new("bench_results/bench_gptq.json");
    match bench.write_json("bench_gptq", out) {
        Ok(()) => println!("\n[json -> {}]", out.display()),
        Err(e) => println!("\n[json write failed: {e}]"),
    }
}
