//! GPTQ pipeline cost: Hessian accumulation, Cholesky inversion, and the
//! column sweep, per layer size — the PTQ wall-time the paper's Appendix A
//! reports as "a single V100" (ours: a single CPU core).

use zeroquant_fp::bench_harness::Bench;
use zeroquant_fp::formats::NumericFormat;
use zeroquant_fp::gptq::{gptq_quantize, GptqConfig, HessianAccumulator};
use zeroquant_fp::linalg;
use zeroquant_fp::lorc::{LorcConfig, LorcFactors};
use zeroquant_fp::quant::{quantize_weight_rtn, WeightQuantConfig};
use zeroquant_fp::rng::Rng;
use zeroquant_fp::tensor::Matrix;

fn main() {
    let mut rng = Rng::seeded(13);
    let mut bench = Bench::quick();
    for dim in [128usize, 256, 512] {
        let rows = dim;
        let w = Matrix::randn(rows, dim, 0.05, &mut rng);
        let x = Matrix::randn(512, dim, 1.0, &mut rng);
        println!("-- layer [{}x{}], calib 512 tokens --", rows, dim);
        bench.run(format!("hessian accumulate d={dim}"), (512 * dim * dim) as f64 / 2.0, "MAC", || {
            let mut acc = HessianAccumulator::new(dim);
            acc.add_batch(&x);
            acc.finalize()
        });
        let mut acc = HessianAccumulator::new(dim);
        acc.add_batch(&x);
        let h = acc.finalize();
        bench.run(format!("cholesky-inverse   d={dim}"), (dim * dim * dim) as f64, "op", || {
            let mut hd = h.clone();
            for i in 0..dim {
                *hd.at_mut(i, i) += 0.01;
            }
            linalg::cholesky_inverse_upper(&hd).unwrap()
        });
        let wcfg = WeightQuantConfig::new(NumericFormat::FP4_E2M1).with_group_size(64);
        bench.run(format!("gptq sweep         d={dim}"), (rows * dim * dim) as f64 / 2.0, "op", || {
            gptq_quantize(&w, &h, &wcfg, &GptqConfig::default()).unwrap()
        });
        bench.run(format!("rtn (baseline)     d={dim}"), (rows * dim) as f64, "elt", || {
            quantize_weight_rtn(&w, &wcfg)
        });
        let q = quantize_weight_rtn(&w, &wcfg);
        let deq = q.dequantize();
        bench.run(format!("lorc svd rank8     d={dim}"), 0.0, "", || {
            LorcFactors::compute(&w, &deq, &LorcConfig::default()).unwrap()
        });
        println!();
    }
}
