//! The end-to-end PTQ pipeline: calibrate → GPTQ/RTN → scale constraints →
//! (optional) LoRC → effective checkpoint + report.
//!
//! This is the orchestration a downstream user runs (`zqfp quantize …`):
//! feed a trained checkpoint, calibration data and a
//! [`QuantRecipe`](crate::recipe::QuantRecipe), get back a [`PtqOutput`]:
//! (a) a checkpoint whose transformer linears carry the *effective*
//! (fake-quantized, LoRC-compensated) weights for engine/PJRT replay,
//! (b) the quantized-artifact sidecar (codes + optional LoRC factors per
//! linear) the packed serving plan compiles from, and (c) a [`PtqReport`]
//! with per-layer losses and size accounting.
//!
//! [`ptq`] is the **single** PTQ entry point (the old four-way
//! `quantize_checkpoint*` family collapsed into it): pass
//! `hessians: None` to calibrate from `calib_seqs` in place, or
//! `Some(&hessians)` to reuse Hessians finalized once and swept across
//! many recipes (the table-harness pattern — the Hessian depends only on
//! the model + calibration data, never on the target format).

use std::collections::HashMap;
use std::time::Instant;

use crate::engine::{LinearSite, Site};
use crate::formats::NumericFormat;
use crate::gptq::{gptq_quantize, HessianAccumulator};
use crate::lorc::LorcFactors;
use crate::model::{Arch, Checkpoint};
use crate::plan::CompiledModel;
use crate::quant::{quantize_weight_rtn, QuantSidecar, WeightQuantConfig};
use crate::recipe::QuantRecipe;
use crate::tensor::Matrix;

/// Per-weight-tensor outcome.
#[derive(Debug, Clone)]
pub struct LayerReport {
    pub tensor: String,
    pub gptq_loss: f64,
    /// ‖W − Ŵ‖²/n after (optional) LoRC.
    pub weight_mse: f64,
    pub packed_bytes: usize,
    pub lorc_bytes: usize,
}

/// Whole-model PTQ outcome.
#[derive(Debug, Clone)]
pub struct PtqReport {
    pub scheme_name: String,
    pub layers: Vec<LayerReport>,
    /// Bytes of the quantized linears at FP16.
    pub fp16_bytes: usize,
    /// Bytes after quantization (codes + scales + LoRC factors).
    pub quant_bytes: usize,
    pub calib_tokens: usize,
    pub wall_ms: u128,
}

impl PtqReport {
    /// FP16-bytes : quantized-bytes ratio of the transformer linears.
    /// A W16 run quantizes nothing (`fp16_bytes == 0`), so its compression
    /// is the identity `1.0` — not the `0.0` the plain ratio would yield.
    pub fn compression(&self) -> f64 {
        if self.fp16_bytes == 0 {
            return 1.0;
        }
        self.fp16_bytes as f64 / self.quant_bytes.max(1) as f64
    }

    pub fn total_weight_mse(&self) -> f64 {
        self.layers.iter().map(|l| l.weight_mse).sum::<f64>() / self.layers.len().max(1) as f64
    }
}

/// Everything one PTQ run produces.
///
/// Under LoRC the *effective* checkpoint carries the dense fold
/// `Ŵ + E₁E₂` — the reference engine path and the Table-2/3 numbers are
/// unchanged — while the sidecar keeps the codes and factors separate so
/// the packed runtime can reproduce the same bits at packed-memory
/// footprint (`entry.weight.dequantize() + entry.lorc.approx_error()`
/// equals the effective weight bit-for-bit; `tests/lorc_equivalence.rs`).
/// The sidecar is empty only for W16 (nothing quantized).
#[derive(Debug, Clone)]
pub struct PtqOutput {
    /// The effective checkpoint: quantized linears replaced by their
    /// dequantized + LoRC-compensated values; everything else untouched.
    pub checkpoint: Checkpoint,
    /// One [`crate::quant::SidecarEntry`] per transformer linear — the
    /// input the packed execution plan compiles from
    /// ([`CompiledModel::compile_quantized`]).
    pub sidecar: QuantSidecar,
    pub report: PtqReport,
}

/// The quantizable linear tensors of one layer, with their Hessian site.
pub fn quantizable_tensors(arch: Arch, layer: usize) -> Vec<(String, LinearSite)> {
    let p = format!("layers.{layer}");
    let mut v = vec![
        (format!("{p}.attn.q.w"), LinearSite::Qkv),
        (format!("{p}.attn.k.w"), LinearSite::Qkv),
        (format!("{p}.attn.v.w"), LinearSite::Qkv),
        (format!("{p}.attn.o.w"), LinearSite::OutProj),
    ];
    match arch {
        Arch::Opt => {
            v.push((format!("{p}.mlp.fc1.w"), LinearSite::Fc1));
            v.push((format!("{p}.mlp.fc2.w"), LinearSite::Fc2));
        }
        Arch::Llama => {
            v.push((format!("{p}.mlp.gate.w"), LinearSite::Fc1));
            v.push((format!("{p}.mlp.up.w"), LinearSite::Fc1));
            v.push((format!("{p}.mlp.down.w"), LinearSite::Fc2));
        }
    }
    v
}

/// Run calibration forward passes and accumulate per-site Hessians.
/// Calibration uses full-precision activations (the GPTQ-repo protocol).
///
/// Runs on the prepacked [`CompiledModel`] path: weights are transposed
/// once for the whole calibration set (the reference engine re-transposes
/// per linear call) and the scratch arena is reused across sequences, so
/// even single-row calibration batches allocate nothing per pass. The
/// observed activations are bit-identical to `Engine::forward_observed`.
pub fn calibrate(ck: &Checkpoint, calib_seqs: &[Vec<u16>]) -> HashMap<Site, HessianAccumulator> {
    let model = CompiledModel::compile(ck, crate::engine::EngineOpts::default());
    let mut scratch = model.scratch();
    let mut accs: HashMap<Site, HessianAccumulator> = HashMap::new();
    for seq in calib_seqs {
        model.forward_observed(seq, &mut scratch, &mut |site, x: &Matrix| {
            accs.entry(site)
                .or_insert_with(|| HessianAccumulator::new(x.cols))
                .add_batch(x);
        });
    }
    accs
}

/// Finalized per-site Hessians ready for reuse across many recipes (the
/// Hessian depends only on the model + calibration data, not on the target
/// format — the table harness calibrates once per model and sweeps formats).
pub type FinalizedHessians = HashMap<Site, Matrix>;

/// Calibrate and finalize in one step.
pub fn calibrate_finalized(ck: &Checkpoint, calib_seqs: &[Vec<u16>]) -> FinalizedHessians {
    calibrate(ck, calib_seqs)
        .into_iter()
        .map(|(site, acc)| (site, acc.finalize()))
        .collect()
}

/// Quantize a checkpoint under `recipe` — the one PTQ entry point.
///
/// * `calib_seqs` is the calibration set. With `hessians: None` and a
///   GPTQ recipe it is forward-passed through [`calibrate_finalized`];
///   RTN and W16 recipes never touch it (pass `&[]`). Either way its
///   token count is recorded in the report.
/// * `hessians: Some(h)` reuses Hessians finalized once by the caller
///   (swept across recipes by the table harness).
///
/// The recipe must come from a validation gate
/// ([`crate::recipe::RecipeBuilder::build`], a preset, or
/// `QuantRecipe::from_json`); a hand-mutated invalid recipe panics here
/// rather than producing an artifact no serving path can load.
pub fn ptq(
    ck: &Checkpoint,
    calib_seqs: &[Vec<u16>],
    hessians: Option<&FinalizedHessians>,
    recipe: &QuantRecipe,
) -> PtqOutput {
    recipe
        .validate()
        .expect("invalid recipe: construct through RecipeBuilder::build / preset / from_json");
    let t0 = Instant::now();
    let calib_tokens: usize = calib_seqs.iter().map(|s| s.len()).sum();
    let mut out = ck.clone();
    let mut sidecar = QuantSidecar::new();
    let mut layers = Vec::new();
    let mut fp16_bytes = 0usize;
    let mut quant_bytes = 0usize;

    if matches!(recipe.scheme.weight, NumericFormat::F16) {
        // W16: nothing to quantize; report is trivially empty.
        return PtqOutput {
            checkpoint: out,
            sidecar,
            report: PtqReport {
                scheme_name: recipe.scheme.name(),
                layers,
                fp16_bytes: 0,
                quant_bytes: 0,
                calib_tokens,
                wall_ms: t0.elapsed().as_millis(),
            },
        };
    }

    let owned_hessians;
    let hessians: &FinalizedHessians = match hessians {
        Some(h) => h,
        None => {
            owned_hessians = if recipe.needs_calibration() {
                calibrate_finalized(ck, calib_seqs)
            } else {
                HashMap::new()
            };
            &owned_hessians
        }
    };

    let wcfg = WeightQuantConfig::new(recipe.scheme.weight)
        .with_group_size(recipe.group_size)
        .with_constraint(recipe.constraint)
        .with_cast(recipe.cast_fp4_to_e5m2);

    for layer in 0..ck.config.n_layers {
        for (tensor, site) in quantizable_tensors(ck.config.arch, layer) {
            let w = ck.get(&tensor);
            fp16_bytes += w.data.len() * 2;
            let (qw, gptq_loss) = if recipe.use_gptq {
                let h = hessians
                    .get(&Site { layer, site })
                    .unwrap_or_else(|| panic!("no hessian for {tensor}"));
                let r = gptq_quantize(w, h, &wcfg, &recipe.gptq)
                    .expect("gptq failed even with escalated damping");
                (r.weight, r.loss)
            } else {
                (quantize_weight_rtn(w, &wcfg), 0.0)
            };
            quant_bytes += qw.packed_bytes();
            let mut effective = qw.dequantize();
            let mut lorc_bytes = 0usize;
            let mut factors = None;
            if let Some(lcfg) = &recipe.lorc {
                let f = LorcFactors::compute(w, &effective, lcfg).expect("lorc svd failed");
                lorc_bytes = f.packed_bytes();
                quant_bytes += lorc_bytes;
                effective = f.apply(&effective);
                factors = Some(f);
            }
            let weight_mse = effective.mse(w);
            *out.get_mut(&tensor) = effective;
            layers.push(LayerReport {
                tensor: tensor.clone(),
                gptq_loss,
                weight_mse,
                packed_bytes: qw.packed_bytes(),
                lorc_bytes,
            });
            // The sidecar stays populated under LoRC: codes + factors
            // reproduce the folded effective weight bit-for-bit, which is
            // what lets `--packed --lorc` serve the paper's best small-
            // model configuration at packed-memory footprint.
            sidecar.insert_with_lorc(tensor, qw, factors);
        }
    }

    PtqOutput {
        checkpoint: out,
        sidecar,
        report: PtqReport {
            scheme_name: recipe.scheme.name(),
            layers,
            fp16_bytes,
            quant_bytes,
            calib_tokens,
            wall_ms: t0.elapsed().as_millis(),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Engine;
    use crate::formats::NumericFormat;
    use crate::lorc::LorcConfig;
    use crate::model::ModelConfig;
    use crate::quant::{ScaleConstraint, Scheme};
    use crate::recipe::QuantRecipe;
    use crate::rng::Rng;

    fn tiny_ck(arch: Arch) -> Checkpoint {
        let cfg = ModelConfig {
            name: "pipe-test".into(),
            arch,
            vocab_size: 48,
            d_model: 24,
            n_heads: 2,
            n_layers: 2,
            d_ff: 48,
            max_seq: 16,
        };
        let mut rng = Rng::seeded(131);
        Checkpoint::random(&cfg, &mut rng)
    }

    fn calib_seqs(n: usize, len: usize) -> Vec<Vec<u16>> {
        let mut rng = Rng::seeded(132);
        (0..n)
            .map(|_| (0..len).map(|_| rng.below(48) as u16).collect())
            .collect()
    }

    fn recipe(scheme: &str) -> QuantRecipe {
        QuantRecipe::builder(Scheme::parse(scheme).unwrap()).build().unwrap()
    }

    #[test]
    fn w16_is_identity() {
        let ck = tiny_ck(Arch::Opt);
        let out = ptq(&ck, &calib_seqs(2, 8), None, &QuantRecipe::preset("w16").unwrap());
        for (name, m) in &ck.tensors {
            assert_eq!(m, out.checkpoint.get(name), "{name}");
        }
        assert_eq!(out.report.quant_bytes, 0);
        assert!(out.sidecar.is_empty());
    }

    #[test]
    fn w4a8_pipeline_produces_close_model() {
        for arch in [Arch::Opt, Arch::Llama] {
            let ck = tiny_ck(arch);
            let r = recipe("w4a8-fp-fp");
            let seqs = calib_seqs(4, 12);
            let out = ptq(&ck, &seqs, None, &r);
            // all quantizable tensors replaced, compression ~3-4x
            assert_eq!(out.report.layers.len(), 2 * quantizable_tensors(arch, 0).len());
            assert!(out.report.compression() > 2.5, "{}", out.report.compression());
            // function approximately preserved
            let toks: Vec<u16> = (0..12).map(|i| (i * 5 % 48) as u16).collect();
            let base = Engine::new(&ck).forward(&toks);
            let quant = Engine::with_opts(&out.checkpoint, r.engine_opts()).forward(&toks);
            let rel = base.sub(&quant).fro_norm() / base.fro_norm();
            assert!(rel < 0.35, "{arch:?}: rel={rel}");
        }
    }

    #[test]
    fn lorc_reduces_weight_mse() {
        let ck = tiny_ck(Arch::Opt);
        let seqs = calib_seqs(4, 12);
        let base = recipe("w4a8-fp-fp");
        // rank 2: on 24-dim toy matrices rank-8 factors would rival the
        // codes themselves; real dims amortize this (see examples/).
        let lorc = QuantRecipe::builder(base.scheme)
            .lorc(LorcConfig { rank: 2, factor_format: NumericFormat::FP8_E4M3 })
            .build()
            .unwrap();
        let r0 = ptq(&ck, &seqs, None, &base).report;
        let r1 = ptq(&ck, &seqs, None, &lorc).report;
        assert!(r1.total_weight_mse() < r0.total_weight_mse());
        assert!(r1.quant_bytes > r0.quant_bytes); // factors cost something
        assert!(r1.quant_bytes < r0.quant_bytes * 2); // ...but not much
    }

    #[test]
    fn rtn_vs_gptq_ablation() {
        let ck = tiny_ck(Arch::Opt);
        let seqs = calib_seqs(6, 12);
        let gptq = recipe("w4a8-int-int");
        let rtn = QuantRecipe::builder(gptq.scheme).use_gptq(false).build().unwrap();
        let eval: Vec<u16> = {
            let mut rng = Rng::seeded(133);
            (0..160).map(|_| rng.below(48) as u16).collect()
        };
        let ppl_of = |r: &QuantRecipe| {
            let out = ptq(&ck, &seqs, None, r);
            crate::eval::perplexity(&out.checkpoint, r.engine_opts(), &eval, 16).ppl()
        };
        let ppl_gptq = ppl_of(&gptq);
        let ppl_rtn = ppl_of(&rtn);
        assert!(ppl_gptq.is_finite() && ppl_rtn.is_finite());
        // On a random (untrained) model the ordering is noisy, but both
        // must stay within a sane band of the FP16 model.
        let ppl_fp =
            crate::eval::perplexity(&ck, crate::engine::EngineOpts::default(), &eval, 16).ppl();
        assert!(ppl_gptq < ppl_fp * 3.0);
        assert!(ppl_rtn < ppl_fp * 3.0);
    }

    #[test]
    fn hessian_reuse_matches_inline_calibration() {
        // the Some(hessians) path must produce the same artifacts as the
        // None path over the same calibration set (the table harness
        // depends on this equivalence)
        let ck = tiny_ck(Arch::Llama);
        let seqs = calib_seqs(3, 10);
        let r = recipe("w4a8-fp-fp");
        let inline = ptq(&ck, &seqs, None, &r);
        let hessians = calibrate_finalized(&ck, &seqs);
        let reused = ptq(&ck, &seqs, Some(&hessians), &r);
        assert_eq!(inline.report.calib_tokens, reused.report.calib_tokens);
        for (name, m) in &inline.checkpoint.tensors {
            let other = reused.checkpoint.get(name);
            for (a, b) in m.data.iter().zip(&other.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "{name}");
            }
        }
    }

    #[test]
    fn sidecar_codes_reproduce_effective_weights() {
        let ck = tiny_ck(Arch::Llama);
        let seqs = calib_seqs(3, 10);
        let r = QuantRecipe::builder(Scheme::parse("w4a8-fp-fp").unwrap())
            .constraint(ScaleConstraint::M2 { rows: 8 })
            .build()
            .unwrap();
        let out = ptq(&ck, &seqs, None, &r);
        assert_eq!(out.sidecar.len(), out.report.layers.len());
        assert!(!out.sidecar.has_lorc());
        for (name, entry) in out.sidecar.iter() {
            let effective = out.checkpoint.get(name);
            let deq = entry.weight.dequantize();
            for (a, b) in effective.data.iter().zip(&deq.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "{name}");
            }
            assert_eq!(entry.weight.constraint, ScaleConstraint::M2 { rows: 8 });
            assert!(entry.lorc.is_none());
        }
        // Under LoRC the sidecar stays populated: codes + factors together
        // reproduce the folded effective weights bit-for-bit.
        let lr = QuantRecipe::builder(r.scheme)
            .constraint(ScaleConstraint::M2 { rows: 8 })
            .lorc(LorcConfig { rank: 2, factor_format: NumericFormat::FP8_E4M3 })
            .build()
            .unwrap();
        let lout = ptq(&ck, &seqs, None, &lr);
        assert_eq!(lout.sidecar.len(), lout.report.layers.len());
        assert!(lout.sidecar.has_lorc());
        for (name, entry) in lout.sidecar.iter() {
            let effective = lout.checkpoint.get(name);
            let factors = entry.lorc.as_ref().expect("lorc factors in sidecar");
            let rebuilt = factors.apply(&entry.weight.dequantize());
            for (a, b) in effective.data.iter().zip(&rebuilt.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "{name} (codes + factors)");
            }
        }
    }

    #[test]
    fn w16_compression_is_identity() {
        // regression: fp16_bytes == 0 used to make compression() report
        // 0.0x for a run that quantized nothing
        let ck = tiny_ck(Arch::Opt);
        let report = ptq(&ck, &calib_seqs(2, 8), None, &QuantRecipe::preset("w16").unwrap()).report;
        assert_eq!(report.fp16_bytes, 0);
        assert_eq!(report.compression(), 1.0);
        // quantized runs still report the true ratio
        let r = ptq(&ck, &calib_seqs(2, 8), None, &recipe("w4a8-fp-fp")).report;
        assert!(r.compression() > 1.0);
    }

    #[test]
    fn constraints_flow_through() {
        let ck = tiny_ck(Arch::Opt);
        let seqs = calib_seqs(3, 10);
        let m1 = QuantRecipe::builder(Scheme::parse("w4a8-fp-fp").unwrap())
            .constraint(ScaleConstraint::M1)
            .build()
            .unwrap();
        let out = ptq(&ck, &seqs, None, &m1);
        assert!(out.report.total_weight_mse() > 0.0);
        // spot check: effective weights differ from unconstrained run
        let out0 = ptq(&ck, &seqs, None, &recipe("w4a8-fp-fp"));
        assert_ne!(
            out.checkpoint.get("layers.0.attn.q.w").data,
            out0.checkpoint.get("layers.0.attn.q.w").data
        );
    }
}
