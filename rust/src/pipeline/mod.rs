//! The end-to-end PTQ pipeline: calibrate → GPTQ/RTN → scale constraints →
//! (optional) LoRC → effective checkpoint + report.
//!
//! This is the orchestration a downstream user runs (`zqfp quantize …`):
//! feed a trained checkpoint and a calibration stream, get back (a) a
//! checkpoint whose transformer linears carry the *effective* (fake-
//! quantized, LoRC-compensated) weights for engine/PJRT replay, (b) the
//! quantized-artifact sidecar (codes + optional LoRC factors per linear)
//! the packed serving plan compiles from, and (c) a [`PtqReport`] with
//! per-layer losses and size accounting.

use std::collections::HashMap;
use std::time::Instant;

use crate::engine::{LinearSite, Site};
use crate::formats::NumericFormat;
use crate::gptq::{gptq_quantize, GptqConfig, HessianAccumulator};
use crate::lorc::{LorcConfig, LorcFactors};
use crate::model::{Arch, Checkpoint};
use crate::plan::CompiledModel;
use crate::quant::{
    quantize_weight_rtn, QuantSidecar, ScaleConstraint, Scheme, WeightQuantConfig,
};
use crate::tensor::Matrix;

/// Full PTQ configuration (one Table-2/3 cell).
#[derive(Debug, Clone)]
pub struct PtqConfig {
    pub scheme: Scheme,
    /// FGQ group size along input dims (paper: 256; our dims are smaller so
    /// the default is 64 — same groups-per-row ratio).
    pub group_size: usize,
    pub constraint: ScaleConstraint,
    /// Footnote-4 cast: requantize dequantized FP4 weights to E5M2.
    pub cast_fp4_to_e5m2: bool,
    /// GPTQ (true) or plain RTN (false, ablation baseline).
    pub use_gptq: bool,
    pub gptq: GptqConfig,
    pub lorc: Option<LorcConfig>,
}

impl PtqConfig {
    pub fn new(scheme: Scheme) -> Self {
        PtqConfig {
            scheme,
            group_size: 64,
            constraint: ScaleConstraint::None,
            cast_fp4_to_e5m2: false,
            use_gptq: true,
            gptq: GptqConfig::default(),
            lorc: None,
        }
    }

    pub fn with_lorc(mut self, lorc: LorcConfig) -> Self {
        self.lorc = Some(lorc);
        self
    }

    pub fn with_constraint(mut self, c: ScaleConstraint) -> Self {
        self.constraint = c;
        self
    }

    /// Engine options matching this scheme's activation side.
    pub fn engine_opts(&self) -> crate::engine::EngineOpts {
        crate::engine::EngineOpts::with_act(self.scheme.activation)
    }

    fn weight_cfg(&self) -> WeightQuantConfig {
        WeightQuantConfig::new(self.scheme.weight)
            .with_group_size(self.group_size)
            .with_constraint(self.constraint)
            .with_cast(self.cast_fp4_to_e5m2)
    }
}

/// Per-weight-tensor outcome.
#[derive(Debug, Clone)]
pub struct LayerReport {
    pub tensor: String,
    pub gptq_loss: f64,
    /// ‖W − Ŵ‖²/n after (optional) LoRC.
    pub weight_mse: f64,
    pub packed_bytes: usize,
    pub lorc_bytes: usize,
}

/// Whole-model PTQ outcome.
#[derive(Debug, Clone)]
pub struct PtqReport {
    pub scheme_name: String,
    pub layers: Vec<LayerReport>,
    /// Bytes of the quantized linears at FP16.
    pub fp16_bytes: usize,
    /// Bytes after quantization (codes + scales + LoRC factors).
    pub quant_bytes: usize,
    pub calib_tokens: usize,
    pub wall_ms: u128,
}

impl PtqReport {
    /// FP16-bytes : quantized-bytes ratio of the transformer linears.
    /// A W16 run quantizes nothing (`fp16_bytes == 0`), so its compression
    /// is the identity `1.0` — not the `0.0` the plain ratio would yield.
    pub fn compression(&self) -> f64 {
        if self.fp16_bytes == 0 {
            return 1.0;
        }
        self.fp16_bytes as f64 / self.quant_bytes.max(1) as f64
    }

    pub fn total_weight_mse(&self) -> f64 {
        self.layers.iter().map(|l| l.weight_mse).sum::<f64>() / self.layers.len().max(1) as f64
    }
}

/// The quantizable linear tensors of one layer, with their Hessian site.
pub fn quantizable_tensors(arch: Arch, layer: usize) -> Vec<(String, LinearSite)> {
    let p = format!("layers.{layer}");
    let mut v = vec![
        (format!("{p}.attn.q.w"), LinearSite::Qkv),
        (format!("{p}.attn.k.w"), LinearSite::Qkv),
        (format!("{p}.attn.v.w"), LinearSite::Qkv),
        (format!("{p}.attn.o.w"), LinearSite::OutProj),
    ];
    match arch {
        Arch::Opt => {
            v.push((format!("{p}.mlp.fc1.w"), LinearSite::Fc1));
            v.push((format!("{p}.mlp.fc2.w"), LinearSite::Fc2));
        }
        Arch::Llama => {
            v.push((format!("{p}.mlp.gate.w"), LinearSite::Fc1));
            v.push((format!("{p}.mlp.up.w"), LinearSite::Fc1));
            v.push((format!("{p}.mlp.down.w"), LinearSite::Fc2));
        }
    }
    v
}

/// Run calibration forward passes and accumulate per-site Hessians.
/// Calibration uses full-precision activations (the GPTQ-repo protocol).
///
/// Runs on the prepacked [`CompiledModel`] path: weights are transposed
/// once for the whole calibration set (the reference engine re-transposes
/// per linear call) and the scratch arena is reused across sequences, so
/// even single-row calibration batches allocate nothing per pass. The
/// observed activations are bit-identical to `Engine::forward_observed`.
pub fn calibrate(ck: &Checkpoint, calib_seqs: &[Vec<u16>]) -> HashMap<Site, HessianAccumulator> {
    let model = CompiledModel::compile(ck, crate::engine::EngineOpts::default());
    let mut scratch = model.scratch();
    let mut accs: HashMap<Site, HessianAccumulator> = HashMap::new();
    for seq in calib_seqs {
        model.forward_observed(seq, &mut scratch, &mut |site, x: &Matrix| {
            accs.entry(site)
                .or_insert_with(|| HessianAccumulator::new(x.cols))
                .add_batch(x);
        });
    }
    accs
}

/// Finalized per-site Hessians ready for reuse across many schemes (the
/// Hessian depends only on the model + calibration data, not on the target
/// format — the table harness calibrates once per model and sweeps formats).
pub type FinalizedHessians = HashMap<Site, Matrix>;

/// Calibrate and finalize in one step.
pub fn calibrate_finalized(ck: &Checkpoint, calib_seqs: &[Vec<u16>]) -> FinalizedHessians {
    calibrate(ck, calib_seqs)
        .into_iter()
        .map(|(site, acc)| (site, acc.finalize()))
        .collect()
}

/// Quantize a checkpoint under `cfg`. Returns the *effective* checkpoint
/// (quantized linears replaced by their dequantized + LoRC-compensated
/// values; everything else untouched) and the report.
pub fn quantize_checkpoint(
    ck: &Checkpoint,
    calib_seqs: &[Vec<u16>],
    cfg: &PtqConfig,
) -> (Checkpoint, PtqReport) {
    let (qck, _, report) = quantize_checkpoint_full(ck, calib_seqs, cfg);
    (qck, report)
}

/// Like [`quantize_checkpoint`], additionally returning the quantized
/// **sidecar**: one [`crate::quant::SidecarEntry`] per transformer linear
/// (codes + the LoRC factors when the run used LoRC), the input the packed
/// execution plan compiles from ([`CompiledModel::compile_quantized`]).
/// The sidecar is empty only for W16 (nothing quantized). Under LoRC the
/// *effective* checkpoint still carries the dense fold `Ŵ + E₁E₂` — the
/// reference engine path and the Table-2/3 numbers are unchanged — while
/// the sidecar keeps the codes and factors separate so the packed runtime
/// can reproduce the same bits at packed-memory footprint
/// (`entry.weight.dequantize() + entry.lorc.approx_error()` equals the
/// effective weight bit-for-bit; `tests/lorc_equivalence.rs`).
pub fn quantize_checkpoint_full(
    ck: &Checkpoint,
    calib_seqs: &[Vec<u16>],
    cfg: &PtqConfig,
) -> (Checkpoint, QuantSidecar, PtqReport) {
    let calib_tokens: usize = calib_seqs.iter().map(|s| s.len()).sum();
    let needs_hessians = cfg.use_gptq && !matches!(cfg.scheme.weight, NumericFormat::F16);
    let hessians = if needs_hessians {
        calibrate_finalized(ck, calib_seqs)
    } else {
        HashMap::new()
    };
    quantize_checkpoint_with_hessians_full(ck, &hessians, calib_tokens, cfg)
}

/// Same, with pre-computed Hessians (reused across schemes).
pub fn quantize_checkpoint_with_hessians(
    ck: &Checkpoint,
    hessians: &FinalizedHessians,
    calib_tokens: usize,
    cfg: &PtqConfig,
) -> (Checkpoint, PtqReport) {
    let (qck, _, report) = quantize_checkpoint_with_hessians_full(ck, hessians, calib_tokens, cfg);
    (qck, report)
}

/// The full-result form of [`quantize_checkpoint_with_hessians`]; see
/// [`quantize_checkpoint_full`] for the sidecar contract.
pub fn quantize_checkpoint_with_hessians_full(
    ck: &Checkpoint,
    hessians: &FinalizedHessians,
    calib_tokens: usize,
    cfg: &PtqConfig,
) -> (Checkpoint, QuantSidecar, PtqReport) {
    let t0 = Instant::now();
    let mut out = ck.clone();
    let mut sidecar = QuantSidecar::new();
    let mut layers = Vec::new();
    let mut fp16_bytes = 0usize;
    let mut quant_bytes = 0usize;

    if matches!(cfg.scheme.weight, NumericFormat::F16) {
        // W16: nothing to quantize; report is trivially empty.
        return (
            out,
            sidecar,
            PtqReport {
                scheme_name: cfg.scheme.name(),
                layers,
                fp16_bytes: 0,
                quant_bytes: 0,
                calib_tokens,
                wall_ms: t0.elapsed().as_millis(),
            },
        );
    }

    let wcfg = cfg.weight_cfg();

    for layer in 0..ck.config.n_layers {
        for (tensor, site) in quantizable_tensors(ck.config.arch, layer) {
            let w = ck.get(&tensor);
            fp16_bytes += w.data.len() * 2;
            let (qw, gptq_loss) = if cfg.use_gptq {
                let h = hessians
                    .get(&Site { layer, site })
                    .unwrap_or_else(|| panic!("no hessian for {tensor}"));
                let r = gptq_quantize(w, h, &wcfg, &cfg.gptq)
                    .expect("gptq failed even with escalated damping");
                (r.weight, r.loss)
            } else {
                (quantize_weight_rtn(w, &wcfg), 0.0)
            };
            quant_bytes += qw.packed_bytes();
            let mut effective = qw.dequantize();
            let mut lorc_bytes = 0usize;
            let mut factors = None;
            if let Some(lcfg) = &cfg.lorc {
                let f = LorcFactors::compute(w, &effective, lcfg)
                    .expect("lorc svd failed");
                lorc_bytes = f.packed_bytes();
                quant_bytes += lorc_bytes;
                effective = f.apply(&effective);
                factors = Some(f);
            }
            let weight_mse = effective.mse(w);
            *out.get_mut(&tensor) = effective;
            layers.push(LayerReport {
                tensor: tensor.clone(),
                gptq_loss,
                weight_mse,
                packed_bytes: qw.packed_bytes(),
                lorc_bytes,
            });
            // The sidecar stays populated under LoRC: codes + factors
            // reproduce the folded effective weight bit-for-bit, which is
            // what lets `--packed --lorc` serve the paper's best small-
            // model configuration at packed-memory footprint.
            sidecar.insert_with_lorc(tensor, qw, factors);
        }
    }

    (
        out,
        sidecar,
        PtqReport {
            scheme_name: cfg.scheme.name(),
            layers,
            fp16_bytes,
            quant_bytes,
            calib_tokens,
            wall_ms: t0.elapsed().as_millis(),
        },
    )
}

/// Convenience: quantize + evaluate perplexity on a token stream.
pub fn quantize_and_eval(
    ck: &Checkpoint,
    calib_seqs: &[Vec<u16>],
    eval_tokens: &[u16],
    seq_len: usize,
    cfg: &PtqConfig,
) -> (f64, PtqReport) {
    let (qck, report) = quantize_checkpoint(ck, calib_seqs, cfg);
    let ppl = crate::eval::perplexity(&qck, cfg.engine_opts(), eval_tokens, seq_len).ppl();
    (ppl, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Engine;
    use crate::model::ModelConfig;
    use crate::rng::Rng;

    fn tiny_ck(arch: Arch) -> Checkpoint {
        let cfg = ModelConfig {
            name: "pipe-test".into(),
            arch,
            vocab_size: 48,
            d_model: 24,
            n_heads: 2,
            n_layers: 2,
            d_ff: 48,
            max_seq: 16,
        };
        let mut rng = Rng::seeded(131);
        Checkpoint::random(&cfg, &mut rng)
    }

    fn calib_seqs(n: usize, len: usize) -> Vec<Vec<u16>> {
        let mut rng = Rng::seeded(132);
        (0..n)
            .map(|_| (0..len).map(|_| rng.below(48) as u16).collect())
            .collect()
    }

    #[test]
    fn w16_is_identity() {
        let ck = tiny_ck(Arch::Opt);
        let cfg = PtqConfig::new(Scheme::W16A16);
        let (qck, report) = quantize_checkpoint(&ck, &calib_seqs(2, 8), &cfg);
        for (name, m) in &ck.tensors {
            assert_eq!(m, qck.get(name), "{name}");
        }
        assert_eq!(report.quant_bytes, 0);
    }

    #[test]
    fn w4a8_pipeline_produces_close_model() {
        for arch in [Arch::Opt, Arch::Llama] {
            let ck = tiny_ck(arch);
            let cfg = PtqConfig::new(Scheme::parse("w4a8-fp-fp").unwrap());
            let seqs = calib_seqs(4, 12);
            let (qck, report) = quantize_checkpoint(&ck, &seqs, &cfg);
            // all quantizable tensors replaced, compression ~3-4x
            assert_eq!(
                report.layers.len(),
                2 * quantizable_tensors(arch, 0).len()
            );
            assert!(report.compression() > 2.5, "{}", report.compression());
            // function approximately preserved
            let toks: Vec<u16> = (0..12).map(|i| (i * 5 % 48) as u16).collect();
            let base = Engine::new(&ck).forward(&toks);
            let quant = Engine::with_opts(&qck, cfg.engine_opts()).forward(&toks);
            let rel = base.sub(&quant).fro_norm() / base.fro_norm();
            assert!(rel < 0.35, "{arch:?}: rel={rel}");
        }
    }

    #[test]
    fn lorc_reduces_weight_mse() {
        let ck = tiny_ck(Arch::Opt);
        let seqs = calib_seqs(4, 12);
        let base_cfg = PtqConfig::new(Scheme::parse("w4a8-fp-fp").unwrap());
        // rank 2: on 24-dim toy matrices rank-8 factors would rival the
        // codes themselves; real dims amortize this (see examples/).
        let lorc_cfg = base_cfg
            .clone()
            .with_lorc(LorcConfig { rank: 2, factor_format: NumericFormat::FP8_E4M3 });
        let (_, r0) = quantize_checkpoint(&ck, &seqs, &base_cfg);
        let (_, r1) = quantize_checkpoint(&ck, &seqs, &lorc_cfg);
        assert!(r1.total_weight_mse() < r0.total_weight_mse());
        assert!(r1.quant_bytes > r0.quant_bytes); // factors cost something
        assert!(r1.quant_bytes < r0.quant_bytes * 2); // ...but not much
    }

    #[test]
    fn rtn_vs_gptq_ablation() {
        let ck = tiny_ck(Arch::Opt);
        let seqs = calib_seqs(6, 12);
        let mut cfg = PtqConfig::new(Scheme::parse("w4a8-int-int").unwrap());
        let eval: Vec<u16> = {
            let mut rng = Rng::seeded(133);
            (0..160).map(|_| rng.below(48) as u16).collect()
        };
        let (ppl_gptq, _) = quantize_and_eval(&ck, &seqs, &eval, 16, &cfg);
        cfg.use_gptq = false;
        let (ppl_rtn, _) = quantize_and_eval(&ck, &seqs, &eval, 16, &cfg);
        assert!(ppl_gptq.is_finite() && ppl_rtn.is_finite());
        // On a random (untrained) model the ordering is noisy, but both
        // must stay within a sane band of the FP16 model.
        let ppl_fp = crate::eval::perplexity(
            &ck,
            crate::engine::EngineOpts::default(),
            &eval,
            16,
        )
        .ppl();
        assert!(ppl_gptq < ppl_fp * 3.0);
        assert!(ppl_rtn < ppl_fp * 3.0);
    }

    #[test]
    fn sidecar_codes_reproduce_effective_weights() {
        let ck = tiny_ck(Arch::Llama);
        let seqs = calib_seqs(3, 10);
        let cfg = PtqConfig::new(Scheme::parse("w4a8-fp-fp").unwrap())
            .with_constraint(ScaleConstraint::M2 { rows: 8 });
        let (qck, sidecar, report) = quantize_checkpoint_full(&ck, &seqs, &cfg);
        assert_eq!(sidecar.len(), report.layers.len());
        assert!(!sidecar.has_lorc());
        for (name, entry) in sidecar.iter() {
            let effective = qck.get(name);
            let deq = entry.weight.dequantize();
            for (a, b) in effective.data.iter().zip(&deq.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "{name}");
            }
            assert_eq!(entry.weight.constraint, ScaleConstraint::M2 { rows: 8 });
            assert!(entry.lorc.is_none());
        }
        // Under LoRC the sidecar stays populated: codes + factors together
        // reproduce the folded effective weights bit-for-bit.
        let lorc_cfg = cfg
            .clone()
            .with_lorc(LorcConfig { rank: 2, factor_format: NumericFormat::FP8_E4M3 });
        let (lck, sidecar, lreport) = quantize_checkpoint_full(&ck, &seqs, &lorc_cfg);
        assert_eq!(sidecar.len(), lreport.layers.len());
        assert!(sidecar.has_lorc());
        for (name, entry) in sidecar.iter() {
            let effective = lck.get(name);
            let factors = entry.lorc.as_ref().expect("lorc factors in sidecar");
            let rebuilt = factors.apply(&entry.weight.dequantize());
            for (a, b) in effective.data.iter().zip(&rebuilt.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "{name} (codes + factors)");
            }
        }
    }

    #[test]
    fn w16_compression_is_identity() {
        // regression: fp16_bytes == 0 used to make compression() report
        // 0.0x for a run that quantized nothing
        let ck = tiny_ck(Arch::Opt);
        let (_, report) =
            quantize_checkpoint(&ck, &calib_seqs(2, 8), &PtqConfig::new(Scheme::W16A16));
        assert_eq!(report.fp16_bytes, 0);
        assert_eq!(report.compression(), 1.0);
        // quantized runs still report the true ratio
        let (_, r) = quantize_checkpoint(
            &ck,
            &calib_seqs(2, 8),
            &PtqConfig::new(Scheme::parse("w4a8-fp-fp").unwrap()),
        );
        assert!(r.compression() > 1.0);
    }

    #[test]
    fn constraints_flow_through() {
        let ck = tiny_ck(Arch::Opt);
        let seqs = calib_seqs(3, 10);
        let cfg = PtqConfig::new(Scheme::parse("w4a8-fp-fp").unwrap())
            .with_constraint(ScaleConstraint::M1);
        let (qck, report) = quantize_checkpoint(&ck, &seqs, &cfg);
        assert!(report.total_weight_mse() > 0.0);
        // spot check: effective weights differ from unconstrained run
        let (qck0, _) =
            quantize_checkpoint(&ck, &seqs, &PtqConfig::new(Scheme::parse("w4a8-fp-fp").unwrap()));
        assert_ne!(
            qck.get("layers.0.attn.q.w").data,
            qck0.get("layers.0.attn.q.w").data
        );
    }
}
