//! Micro-benchmark substrate (criterion is not in the offline vendor set).
//!
//! Wall-clock timing with warmup, adaptive iteration counts, and
//! mean/stddev/percentile reporting; `cargo bench` targets are plain
//! `harness = false` mains built on this.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub stddev: Duration,
    pub min: Duration,
    /// Optional work-per-iteration for derived throughput (e.g. FLOPs).
    pub work_per_iter: Option<f64>,
    pub work_unit: &'static str,
}

impl Measurement {
    /// Work units per second (e.g. GFLOP/s when work is FLOPs / 1e9).
    pub fn throughput(&self) -> Option<f64> {
        self.work_per_iter
            .map(|w| w / self.mean.as_secs_f64().max(1e-12))
    }

    pub fn report(&self) -> String {
        let tp = match self.throughput() {
            Some(t) if t >= 1e9 => format!("  {:8.2} G{}/s", t / 1e9, self.work_unit),
            Some(t) if t >= 1e6 => format!("  {:8.2} M{}/s", t / 1e6, self.work_unit),
            Some(t) if t >= 1e3 => format!("  {:8.2} K{}/s", t / 1e3, self.work_unit),
            Some(t) => format!("  {:8.2} {}/s", t, self.work_unit),
            None => String::new(),
        };
        format!(
            "{:<44} {:>10.3?} ±{:>9.3?} (min {:>9.3?}, n={}){}",
            self.name, self.mean, self.stddev, self.min, self.iters, tp
        )
    }
}

/// Benchmark runner with a global time budget per case.
pub struct Bench {
    /// Target wall time spent measuring each case.
    pub budget: Duration,
    /// Warmup time before measurement.
    pub warmup: Duration,
    pub results: Vec<Measurement>,
    /// Named scalar facts recorded alongside the measurements (memory
    /// footprints, ratios, …) — serialized under `"notes"` in the JSON
    /// artifact so perf trajectories can track more than wall time.
    pub notes: Vec<(String, f64)>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            budget: Duration::from_millis(600),
            warmup: Duration::from_millis(150),
            results: Vec::new(),
            notes: Vec::new(),
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Bench {
            budget: Duration::from_millis(200),
            warmup: Duration::from_millis(50),
            results: Vec::new(),
            notes: Vec::new(),
        }
    }

    /// Record a named scalar fact (printed immediately, kept for the JSON
    /// artifact).
    pub fn note(&mut self, name: impl Into<String>, value: f64) {
        let name = name.into();
        println!("{name}: {value}");
        self.notes.push((name, value));
    }

    /// Time `f` repeatedly; `work` is the per-iteration work amount for
    /// throughput reporting (pass 0.0 to skip).
    pub fn run<R>(
        &mut self,
        name: impl Into<String>,
        work: f64,
        unit: &'static str,
        mut f: impl FnMut() -> R,
    ) -> &Measurement {
        // warmup
        let w0 = Instant::now();
        let mut warm_iters = 0usize;
        while w0.elapsed() < self.warmup || warm_iters == 0 {
            std::hint::black_box(f());
            warm_iters += 1;
        }
        // choose batch size so one batch is ~1/20 of budget
        let per = w0.elapsed().as_secs_f64() / warm_iters as f64;
        let batch = ((self.budget.as_secs_f64() / 20.0 / per.max(1e-9)).ceil() as usize).max(1);
        let mut samples: Vec<f64> = Vec::new();
        let t0 = Instant::now();
        while t0.elapsed() < self.budget || samples.len() < 3 {
            let s = Instant::now();
            for _ in 0..batch {
                std::hint::black_box(f());
            }
            samples.push(s.elapsed().as_secs_f64() / batch as f64);
            if samples.len() > 10_000 {
                break;
            }
        }
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
        let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
        let m = Measurement {
            name: name.into(),
            iters: samples.len() * batch,
            mean: Duration::from_secs_f64(mean),
            stddev: Duration::from_secs_f64(var.sqrt()),
            min: Duration::from_secs_f64(min),
            work_per_iter: if work > 0.0 { Some(work) } else { None },
            work_unit: unit,
        };
        println!("{}", m.report());
        self.results.push(m);
        self.results.last().unwrap()
    }

    /// Comparison line: how much faster is `a` than `b` (by name)?
    pub fn speedup(&self, a: &str, b: &str) -> Option<f64> {
        let fa = self.results.iter().find(|m| m.name == a)?;
        let fb = self.results.iter().find(|m| m.name == b)?;
        Some(fb.mean.as_secs_f64() / fa.mean.as_secs_f64())
    }

    /// All measurements as a JSON document:
    /// `{"bench": <name>, "results": [{name, iters, mean_ns, stddev_ns,
    /// min_ns, throughput, unit}, ...], "notes": [{name, value}, ...]}`
    /// (`notes` only when present). Hand-rolled (serde is not in the
    /// offline vendor set); names are escaped for quotes/backslashes.
    pub fn to_json(&self, bench_name: &str) -> String {
        fn esc(s: &str) -> String {
            s.replace('\\', "\\\\").replace('"', "\\\"")
        }
        let mut out = String::new();
        out.push_str(&format!("{{\"bench\": \"{}\", \"results\": [", esc(bench_name)));
        for (i, m) in self.results.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            let tp = match m.throughput() {
                Some(t) => format!("{t:.3}"),
                None => "null".to_string(),
            };
            out.push_str(&format!(
                "{{\"name\": \"{}\", \"iters\": {}, \"mean_ns\": {}, \"stddev_ns\": {}, \
                 \"min_ns\": {}, \"throughput\": {}, \"unit\": \"{}\"}}",
                esc(&m.name),
                m.iters,
                m.mean.as_nanos(),
                m.stddev.as_nanos(),
                m.min.as_nanos(),
                tp,
                esc(m.work_unit),
            ));
        }
        out.push(']');
        if !self.notes.is_empty() {
            out.push_str(", \"notes\": [");
            for (i, (name, value)) in self.notes.iter().enumerate() {
                if i > 0 {
                    out.push_str(", ");
                }
                let v = if value.is_finite() { format!("{value}") } else { "null".to_string() };
                out.push_str(&format!("{{\"name\": \"{}\", \"value\": {v}}}", esc(name)));
            }
            out.push(']');
        }
        out.push_str("}\n");
        out
    }

    /// Write the JSON report to `path`, creating parent directories. Bench
    /// mains call this so every run leaves a machine-readable perf trace
    /// (the perf trajectory EXPERIMENTS.md §Perf tracks across PRs).
    pub fn write_json(&self, bench_name: &str, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json(bench_name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench {
            budget: Duration::from_millis(30),
            warmup: Duration::from_millis(5),
            ..Bench::default()
        };
        // black_box the *input* so release mode cannot constant-fold the
        // loop away to a true 0ns no-op.
        let data: Vec<u64> = (0..512).collect();
        let m = b.run("sum512", 512.0, "op", || {
            std::hint::black_box(&data).iter().sum::<u64>()
        });
        assert!(m.iters > 0);
        assert!(m.mean > Duration::ZERO);
        assert!(m.throughput().unwrap() > 0.0);
    }

    #[test]
    fn json_report_is_well_formed() {
        let mut b = Bench {
            budget: Duration::from_millis(10),
            warmup: Duration::from_millis(1),
            ..Bench::default()
        };
        let data: Vec<u64> = (0..64).collect();
        b.run("sum \"quoted\"", 64.0, "op", || {
            std::hint::black_box(&data).iter().sum::<u64>()
        });
        b.run("no-throughput", 0.0, "", || 1 + 1);
        let j = b.to_json("bench_test");
        assert!(j.starts_with("{\"bench\": \"bench_test\""));
        assert!(j.contains("\"name\": \"sum \\\"quoted\\\"\""));
        assert!(j.contains("\"throughput\": null"));
        assert!(j.trim_end().ends_with("]}"));
        // balanced braces/brackets — cheap structural sanity check
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    #[test]
    fn notes_serialize() {
        let mut b = Bench::default();
        b.note("packed bytes", 1234.0);
        b.note("ratio \"x\"", 0.125);
        let j = b.to_json("bench_notes");
        assert!(j.contains("\"notes\": ["));
        assert!(j.contains("\"name\": \"packed bytes\", \"value\": 1234"));
        assert!(j.contains("0.125"));
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    #[test]
    fn speedup_compares() {
        let mut b = Bench {
            budget: Duration::from_millis(20),
            warmup: Duration::from_millis(2),
            ..Bench::default()
        };
        let small: Vec<u64> = (0..8).collect();
        let big: Vec<u64> = (0..20_000).collect();
        b.run("fast", 0.0, "", || std::hint::black_box(&small).iter().sum::<u64>());
        b.run("slow", 0.0, "", || std::hint::black_box(&big).iter().sum::<u64>());
        assert!(b.speedup("fast", "slow").unwrap() > 1.0);
        assert!(b.speedup("fast", "missing").is_none());
    }
}
