//! # zeroquant-fp
//!
//! A from-scratch reproduction of **ZeroQuant-FP** (Wu, Yao & He, 2023):
//! post-training W4A8 quantization of transformer LMs using floating-point
//! formats (FP8/FP4) — GPTQ weight optimization, fine-grained group-wise
//! (FGQ) weight quantization, token-wise activation quantization, LoRC
//! low-rank compensation, and power-of-2 scale constraints (M1/M2) for the
//! FP4→FP8 bit-shift cast.
//!
//! Architecture (see DESIGN.md): a Rust coordinator/PTQ-pipeline (this
//! crate) drives AOT-compiled JAX/Pallas computations through PJRT; Python
//! exists only at build time. The serving stack — request queue, dynamic
//! batcher, KV-cached incremental decode with continuous batching, and
//! metrics — is documented end to end in the repo-root `ARCHITECTURE.md`
//! (and `README.md` maps the crate); the load-bearing modules are
//! [`coordinator`], [`plan`] and [`plan::kv`]. W4 deployment is *real*
//! here, not just accounted for: [`quant::packed`] bit-packs codes two
//! per byte and [`tensor::packed_matmul`] fuses shift-dequant into the
//! GEMV, bit-identical to the fake-quant reference
//! (`tests/packed_equivalence.rs`).

// The numeric kernels are written as explicit index loops on purpose: the
// compiled fast path must be bit-identical to the reference engine, so the
// floating-point operation order is part of the contract and iterator
// rewrites that obscure it are not wanted here.
#![allow(clippy::needless_range_loop)]

pub mod bench_harness;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod error;
pub mod eval;
pub mod experiments;
pub mod formats;
pub mod gptq;
pub mod kernels;
pub mod linalg;
pub mod lorc;
pub mod model;
pub mod pipeline;
pub mod plan;
pub mod quant;
pub mod recipe;
pub mod rng;
pub mod runtime;
pub mod tensor;
