//! LoRC — Low Rank Compensation (ZeroQuant-V2, adopted by this paper).
//!
//! After quantizing `W` to `Ŵ`, the residual `E = W − Ŵ` is approximated by
//! a rank-`r` factorization obtained from its SVD:
//!
//! ```text
//!   E ≈ Ê = E₁·E₂,   E₁ = U_r·Σ_r^{1/2}  [out × r],   E₂ = Σ_r^{1/2}·V_rᵀ  [r × out_in]
//! ```
//!
//! and the deployed weight is `Ŵ + Ê`. The factors are tiny (r ≤ 64 ≪ dims)
//! and stored in a higher-precision format (FP8/FP16), so the memory
//! overhead is negligible while a large share of the quantization error —
//! especially its low-rank structure — is recovered. The paper finds LoRC
//! most effective for smaller models and for mitigating the loss from scale
//! constraints (Tables 2 & 3).

use crate::formats::NumericFormat;
use crate::linalg::{jacobi_svd, truncate_svd, LinalgError};
use crate::tensor::Matrix;

/// LoRC configuration.
#[derive(Debug, Clone, Copy)]
pub struct LorcConfig {
    /// Rank of the compensation factors. The paper uses 8 for LLaMA and
    /// 16–56 for OPT; ZeroQuant-V2 reports insensitivity above 8.
    pub rank: usize,
    /// Storage format for the factors (quantized on store). FP8 E4M3 by
    /// default; `F16` keeps them unquantized.
    pub factor_format: NumericFormat,
}

impl Default for LorcConfig {
    fn default() -> Self {
        LorcConfig { rank: 8, factor_format: NumericFormat::FP8_E4M3 }
    }
}

/// The stored low-rank compensation factors for one layer.
#[derive(Debug, Clone)]
pub struct LorcFactors {
    /// `[out, r]`
    pub e1: Matrix,
    /// `[r, in]`
    pub e2: Matrix,
    pub format: NumericFormat,
}

impl LorcFactors {
    /// Compute factors for the error `E = w − ŵ`.
    pub fn compute(
        w: &Matrix,
        dequantized: &Matrix,
        cfg: &LorcConfig,
    ) -> Result<LorcFactors, LinalgError> {
        let err = w.sub(dequantized);
        let svd = jacobi_svd(&err)?;
        let (mut e1, mut e2) = truncate_svd(&svd, cfg.rank);
        // Factors are themselves stored low-precision (per-tensor absmax —
        // they are small and well-conditioned).
        if !matches!(cfg.factor_format, NumericFormat::F16) {
            cfg.factor_format.fake_quant_slice_dynamic(&mut e1.data);
            cfg.factor_format.fake_quant_slice_dynamic(&mut e2.data);
        }
        Ok(LorcFactors { e1, e2, format: cfg.factor_format })
    }

    /// `Ê = E₁·E₂`.
    pub fn approx_error(&self) -> Matrix {
        self.e1.matmul(&self.e2)
    }

    /// Apply to a dequantized weight: `Ŵ + Ê`.
    pub fn apply(&self, dequantized: &Matrix) -> Matrix {
        let mut out = dequantized.clone();
        out.add_assign(&self.approx_error());
        out
    }

    /// Extra bytes the factors cost at their storage precision.
    pub fn packed_bytes(&self) -> usize {
        let elems = self.e1.data.len() + self.e2.data.len();
        elems * self.format.bits() as usize / 8
    }

    pub fn rank(&self) -> usize {
        self.e1.cols
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize_weight_rtn, WeightQuantConfig};
    use crate::rng::Rng;

    #[test]
    fn lorc_reduces_weight_error() {
        let mut rng = Rng::seeded(81);
        let w = Matrix::randn(64, 96, 0.1, &mut rng);
        let q = quantize_weight_rtn(
            &w,
            &WeightQuantConfig::new(NumericFormat::FP4_E2M1).with_group_size(32),
        );
        let deq = q.dequantize();
        let before = deq.mse(&w);
        let lorc = LorcFactors::compute(&w, &deq, &LorcConfig { rank: 16, factor_format: NumericFormat::F16 }).unwrap();
        let after = lorc.apply(&deq).mse(&w);
        assert!(after < before, "after={after} before={before}");
    }

    #[test]
    fn higher_rank_recovers_more() {
        let mut rng = Rng::seeded(82);
        let w = Matrix::randn(48, 48, 0.1, &mut rng);
        let q = quantize_weight_rtn(&w, &WeightQuantConfig::new(NumericFormat::INT4));
        let deq = q.dequantize();
        let mut last = f64::INFINITY;
        for rank in [2, 8, 32] {
            let lorc = LorcFactors::compute(
                &w,
                &deq,
                &LorcConfig { rank, factor_format: NumericFormat::F16 },
            )
            .unwrap();
            let e = lorc.apply(&deq).mse(&w);
            assert!(e <= last + 1e-12, "rank {rank}: {e} vs {last}");
            last = e;
        }
    }

    #[test]
    fn quantized_factors_still_help() {
        // the paper stores factors cheaply; FP8 factors must retain most of
        // the benefit
        let mut rng = Rng::seeded(83);
        let w = Matrix::randn(64, 64, 0.1, &mut rng);
        let q = quantize_weight_rtn(&w, &WeightQuantConfig::new(NumericFormat::FP4_E2M1));
        let deq = q.dequantize();
        let before = deq.mse(&w);
        let lorc8 = LorcFactors::compute(&w, &deq, &LorcConfig::default()).unwrap();
        let after8 = lorc8.apply(&deq).mse(&w);
        assert!(after8 < before * 0.9, "after8={after8} before={before}");
    }

    #[test]
    fn overhead_accounting() {
        let mut rng = Rng::seeded(84);
        let w = Matrix::randn(256, 256, 0.1, &mut rng);
        let q = quantize_weight_rtn(&w, &WeightQuantConfig::new(NumericFormat::FP4_E2M1));
        let lorc = LorcFactors::compute(&w, &q.dequantize(), &LorcConfig::default()).unwrap();
        // rank-8 FP8 factors on 256²: 2*256*8 bytes = 4096 ≪ 256*256/2 = 32768
        assert_eq!(lorc.packed_bytes(), 2 * 256 * 8);
        assert!(lorc.packed_bytes() < q.packed_bytes() / 4);
        assert_eq!(lorc.rank(), 8);
    }

    #[test]
    fn rank_clamps_to_matrix_size() {
        let mut rng = Rng::seeded(85);
        let w = Matrix::randn(8, 6, 0.1, &mut rng);
        let q = quantize_weight_rtn(&w, &WeightQuantConfig::new(NumericFormat::INT4));
        let lorc = LorcFactors::compute(
            &w,
            &q.dequantize(),
            &LorcConfig { rank: 999, factor_format: NumericFormat::F16 },
        )
        .unwrap();
        assert_eq!(lorc.rank(), 6);
        // full-rank compensation recovers the weight exactly
        assert!(lorc.apply(&q.dequantize()).mse(&w) < 1e-10);
    }
}
