//! LoRC — Low Rank Compensation (ZeroQuant-V2, adopted by this paper).
//!
//! After quantizing `W` to `Ŵ`, the residual `E = W − Ŵ` is approximated by
//! a rank-`r` factorization obtained from its SVD:
//!
//! ```text
//!   E ≈ Ê = E₁·E₂,   E₁ = U_r·Σ_r^{1/2}  [out × r],   E₂ = Σ_r^{1/2}·V_rᵀ  [r × out_in]
//! ```
//!
//! and the deployed weight is `Ŵ + Ê`. The factors are tiny (r ≤ 64 ≪ dims)
//! and stored in a higher-precision format (FP8/FP16), so the memory
//! overhead is negligible while a large share of the quantization error —
//! especially its low-rank structure — is recovered. The paper finds LoRC
//! most effective for smaller models and for mitigating the loss from scale
//! constraints (Tables 2 & 3).
//!
//! Two representations live here:
//!
//! * [`LorcFactors`] — the PTQ-time container: the fake-quantized f32
//!   factor matrices (what the pipeline folds into the *effective*
//!   checkpoint for the reference engine) **plus** the true low-bit codes
//!   they decode from. For ≤ 8-bit FP factor formats the codes are the
//!   storage (`value == format.decode(code) · scale` bit-for-bit, by
//!   construction); `F16` factors stay unquantized f32, matching the fold.
//! * [`PackedLorc`] — the serving-time representation the packed execution
//!   plan attaches to each linear: codes + per-tensor scales only (the
//!   dense f32 matrices are dropped), with the fused q|k|v / gate|up
//!   stacking of the compiled plan (per-sub-tensor E₁ blocks row-stacked,
//!   per-sub-tensor E₂ kept separate), and the two runtime applications —
//!   the exact per-weight-row error materialization the fused GEMV uses
//!   ([`PackedLorc::err_row_into`], bit-identical to the pipeline fold)
//!   and the cheap activation-side `acc += E₁·(E₂·x)`
//!   ([`PackedLorc::apply_into`]). See the module docs of
//!   [`crate::tensor::packed_matmul`] and ARCHITECTURE.md §LoRC runtime
//!   path for why the serving path uses the former.

use crate::formats::{FpFormat, NumericFormat};
use crate::linalg::{jacobi_svd, truncate_svd, LinalgError};
use crate::tensor::Matrix;

/// LoRC configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LorcConfig {
    /// Rank of the compensation factors. The paper uses 8 for LLaMA and
    /// 16–56 for OPT; ZeroQuant-V2 reports insensitivity above 8.
    pub rank: usize,
    /// Storage format for the factors (quantized on store). FP8 E4M3 by
    /// default; `F16` keeps them unquantized.
    pub factor_format: NumericFormat,
}

impl Default for LorcConfig {
    fn default() -> Self {
        LorcConfig { rank: 8, factor_format: NumericFormat::FP8_E4M3 }
    }
}

/// The code-level storage of one factor matrix: one byte per element plus a
/// per-tensor scale, produced when the factor format is an FP format of at
/// most 8 code bits. `None` means the f32 values are the storage (the `F16`
/// passthrough, non-FP formats, and the degenerate non-finite-absmax case).
#[derive(Debug, Clone)]
struct FactorCodes {
    fmt: FpFormat,
    codes: Vec<u8>,
    scale: f32,
}

/// Quantize `data` in place to `fmt` under a per-tensor absmax scale,
/// returning the codes. The written values satisfy
/// `data[i] == fmt.decode(codes[i]) · scale` **bit-for-bit**, and are
/// bit-identical to `NumericFormat::fake_quant_slice_dynamic` over the same
/// slice (same absmax scan, same scale derivation, and
/// `decode(encode(y)) == quantize(y)` for every finite `y` — `encode`
/// computes `quantize` and the roundtrip is exact on representable values).
fn encode_factor(fmt: FpFormat, data: &mut [f32]) -> Option<FactorCodes> {
    if fmt.total_bits() > 8 {
        return None; // wider-than-byte codes: keep the f32 values
    }
    // The one shared absmax-scan/scale derivation (formats/mod.rs) — the
    // same params fake_quant_slice_dynamic would use, so the codes decode
    // to exactly the values the pipeline folds. None = degenerate tensor,
    // which the dynamic path leaves untouched.
    let scale = NumericFormat::Fp(fmt).dynamic_symmetric_params(data)?.scale;
    if scale == 0.0 || !scale.is_finite() {
        // subnormal/degenerate absmax: the division-based codec misbehaves
        // identically on both paths — keep the historical fake-quant one
        return None;
    }
    let mut codes = Vec::with_capacity(data.len());
    for x in data.iter_mut() {
        let code = fmt.encode(*x / scale);
        codes.push(code as u8);
        *x = fmt.decode(code) * scale;
    }
    Some(FactorCodes { fmt, codes, scale })
}

impl FactorCodes {
    /// Decode element `i` — bit-identical to the fake-quantized f32 value
    /// the pipeline folded (see [`encode_factor`]).
    #[inline]
    fn get(&self, i: usize) -> f32 {
        self.fmt.decode(self.codes[i] as u16) * self.scale
    }
}

/// The stored low-rank compensation factors for one layer.
#[derive(Debug, Clone)]
pub struct LorcFactors {
    /// `[out, r]`, fake-quantized to `format`.
    pub e1: Matrix,
    /// `[r, in]`, fake-quantized to `format`.
    pub e2: Matrix,
    pub format: NumericFormat,
    /// True low-bit codes of `e1` (present for ≤ 8-bit FP formats).
    e1_codes: Option<FactorCodes>,
    /// True low-bit codes of `e2`.
    e2_codes: Option<FactorCodes>,
}

impl LorcFactors {
    /// Compute factors for the error `E = w − ŵ`.
    pub fn compute(
        w: &Matrix,
        dequantized: &Matrix,
        cfg: &LorcConfig,
    ) -> Result<LorcFactors, LinalgError> {
        let err = w.sub(dequantized);
        let svd = jacobi_svd(&err)?;
        let (mut e1, mut e2) = truncate_svd(&svd, cfg.rank);
        // Factors are themselves stored low-precision (per-tensor absmax —
        // they are small and well-conditioned). FP formats of ≤ 8 bits
        // produce true codes; anything else falls back to the fake-quant
        // slice path with f32 storage.
        let (mut e1_codes, mut e2_codes) = (None, None);
        match cfg.factor_format {
            NumericFormat::F16 => {}
            NumericFormat::Fp(f) => {
                e1_codes = encode_factor(f, &mut e1.data);
                e2_codes = encode_factor(f, &mut e2.data);
                if e1_codes.is_none() || e2_codes.is_none() {
                    // byte codes unavailable (wide format / degenerate
                    // tensor): apply the plain fake-quant so the values
                    // match the historical behavior exactly
                    if e1_codes.is_none() {
                        cfg.factor_format.fake_quant_slice_dynamic(&mut e1.data);
                    }
                    if e2_codes.is_none() {
                        cfg.factor_format.fake_quant_slice_dynamic(&mut e2.data);
                    }
                    e1_codes = None;
                    e2_codes = None;
                }
            }
            _ => {
                cfg.factor_format.fake_quant_slice_dynamic(&mut e1.data);
                cfg.factor_format.fake_quant_slice_dynamic(&mut e2.data);
            }
        }
        Ok(LorcFactors { e1, e2, format: cfg.factor_format, e1_codes, e2_codes })
    }

    /// `Ê = E₁·E₂`.
    pub fn approx_error(&self) -> Matrix {
        self.e1.matmul(&self.e2)
    }

    /// Apply to a dequantized weight: `Ŵ + Ê`. This is the pipeline's fold
    /// and the bit-level reference for the runtime path
    /// ([`PackedLorc::err_row_into`] + the fused GEMV's per-row add).
    pub fn apply(&self, dequantized: &Matrix) -> Matrix {
        let mut out = dequantized.clone();
        out.add_assign(&self.approx_error());
        out
    }

    /// Serialized size the factors cost at their storage precision (the
    /// PTQ report's accounting; [`PackedLorc::mem_bytes`] reports the
    /// actual resident bytes of the serving representation).
    pub fn packed_bytes(&self) -> usize {
        let elems = self.e1.data.len() + self.e2.data.len();
        elems * self.format.bits() as usize / 8
    }

    pub fn rank(&self) -> usize {
        self.e1.cols
    }

    /// True when the factors are stored as true byte codes (≤ 8-bit FP
    /// formats) rather than f32 values.
    pub fn has_codes(&self) -> bool {
        self.e1_codes.is_some() && self.e2_codes.is_some()
    }
}

/// One factor matrix as the serving path holds it.
#[derive(Debug, Clone)]
enum FactorStore {
    /// Byte codes + per-tensor scale: 1 B/element resident,
    /// `decode(code) · scale` reproduces the folded f32 value bit-for-bit.
    Codes(FactorCodes),
    /// f32 values (F16 factors stay unquantized, matching the fold; also
    /// the fallback for non-FP or wide formats).
    Dense(Vec<f32>),
}

impl FactorStore {
    fn from_factors(codes: &Option<FactorCodes>, values: &Matrix) -> FactorStore {
        match codes {
            Some(c) => FactorStore::Codes(c.clone()),
            None => FactorStore::Dense(values.data.clone()),
        }
    }

    #[inline]
    fn get(&self, i: usize) -> f32 {
        match self {
            FactorStore::Codes(c) => c.get(i),
            FactorStore::Dense(v) => v[i],
        }
    }

    /// Actual resident bytes (codes are 1 B each + the f32 scale; dense
    /// values are honest f32 — F16 factors are *accounted* at 2 B by
    /// `LorcFactors::packed_bytes` but resident as f32, like the packed
    /// weights' f32 scales).
    fn mem_bytes(&self) -> usize {
        match self {
            FactorStore::Codes(c) => c.codes.len() + 4,
            FactorStore::Dense(v) => 4 * v.len(),
        }
    }
}

/// One fused sub-tensor's factors inside a [`PackedLorc`].
#[derive(Debug, Clone)]
struct LorcPart {
    /// First fused output row this part covers.
    row0: usize,
    /// Output rows of this part.
    rows: usize,
    /// Compensation rank (0 ⇒ no factors for this part; contributes no
    /// error).
    rank: usize,
    /// `[rows, rank]`.
    e1: FactorStore,
    /// `[rank, d_in]`.
    e2: FactorStore,
    /// Offset of this part's decoded E₂ rows in the shared scratch strip.
    e2_off: usize,
}

/// Runtime LoRC attachment of one (possibly fused) packed linear: the
/// low-rank factors at code precision, ready for the fused dequant GEMV.
///
/// ## Fused-slot stacking
///
/// A fused q|k|v (or gate|up) linear stacks its sub-tensors' weight rows;
/// the factors follow the same geometry: each sub-tensor's `E₁` block
/// covers its own row range (`row0 .. row0 + rows`), while each keeps its
/// **own** `E₂` (the factorizations are per-tensor — there is no shared
/// rank-r basis across q, k and v).
///
/// ## Accumulation-order contract
///
/// [`err_row_into`](Self::err_row_into) reproduces row `j` of
/// `E₁·E₂` exactly as `Matrix::matmul` computes it (4-term groups over the
/// rank with the zero-skip singles tail of
/// [`matmul_into`](crate::tensor::matmul::matmul_into)), so
/// `decoded Ŵ row + err row` equals the pipeline-folded effective weight
/// row **bit-for-bit** — which is what makes the packed+LoRC plan
/// bit-identical to the dense effective-checkpoint engine on every
/// execution path (`tests/lorc_equivalence.rs`).
///
/// [`apply_into`](Self::apply_into) is the cheap `O(r·(in+out))`
/// activation-side application (`acc += E₁·(E₂·x)`), deterministic in the
/// same accumulation-order discipline — but *not* bit-equal to the fold
/// (f32 addition is not associative across the two groupings), which is
/// why the serving path does not use it. It exists for callers that trade
/// the fold-equality contract for the low-rank FLOP count.
#[derive(Debug, Clone)]
pub struct PackedLorc {
    pub d_in: usize,
    pub d_out: usize,
    parts: Vec<LorcPart>,
    /// Total decoded-E₂ scratch elements (`Σ rank · d_in` over parts).
    e2_elems: usize,
    max_rank: usize,
}

impl PackedLorc {
    /// Pack the factors of one or more fused sub-tensors. `parts` pairs
    /// each sub-tensor's output-row count with its factors (`None` ⇒ that
    /// part carries no compensation); at least one part must have factors.
    pub fn pack(parts: &[(usize, Option<&LorcFactors>)]) -> PackedLorc {
        let d_in = parts
            .iter()
            .find_map(|(_, f)| f.map(|f| f.e2.cols))
            .expect("PackedLorc::pack needs at least one factored part");
        let mut out_parts = Vec::with_capacity(parts.len());
        let mut row0 = 0usize;
        let mut e2_off = 0usize;
        let mut max_rank = 0usize;
        for &(rows, f) in parts {
            let part = match f {
                Some(f) => {
                    assert_eq!(f.e1.rows, rows, "E1 rows must match the weight rows");
                    assert_eq!(f.e2.cols, d_in, "fused parts must share the input dim");
                    assert_eq!(f.e1.cols, f.e2.rows, "factor rank mismatch");
                    let rank = f.rank();
                    max_rank = max_rank.max(rank);
                    let p = LorcPart {
                        row0,
                        rows,
                        rank,
                        e1: FactorStore::from_factors(&f.e1_codes, &f.e1),
                        e2: FactorStore::from_factors(&f.e2_codes, &f.e2),
                        e2_off,
                    };
                    e2_off += rank * d_in;
                    p
                }
                None => LorcPart {
                    row0,
                    rows,
                    rank: 0,
                    e1: FactorStore::Dense(Vec::new()),
                    e2: FactorStore::Dense(Vec::new()),
                    e2_off,
                },
            };
            row0 += rows;
            out_parts.push(part);
        }
        PackedLorc { d_in, d_out: row0, parts: out_parts, e2_elems: e2_off, max_rank }
    }

    /// Scratch elements [`decode_e2_into`](Self::decode_e2_into) needs.
    pub fn e2_elems(&self) -> usize {
        self.e2_elems
    }

    /// Largest per-part rank.
    pub fn max_rank(&self) -> usize {
        self.max_rank
    }

    /// Actual resident bytes of the factors (codes/values + scales).
    pub fn mem_bytes(&self) -> usize {
        self.parts.iter().map(|p| p.e1.mem_bytes() + p.e2.mem_bytes()).sum()
    }

    /// Decode every part's E₂ rows into `strip` (once per GEMV call; the
    /// strip is then shared read-only by all row workers). Each decoded
    /// value is bit-identical to the folded factor value.
    pub fn decode_e2_into(&self, strip: &mut [f32]) {
        assert!(strip.len() >= self.e2_elems, "E2 decode strip too small");
        for p in &self.parts {
            for i in 0..p.rank * self.d_in {
                strip[p.e2_off + i] = p.e2.get(i);
            }
        }
    }

    /// Materialize row `j` of `Ê = E₁·E₂` into `err[..d_in]`, reading E₂
    /// from the predecoded strip — the exact accumulation order of
    /// `Matrix::matmul` (zeroed output, 4-term groups over the rank,
    /// zero-skip singles tail), so `ŵ_row + err_row` reproduces the
    /// pipeline fold bit-for-bit.
    pub fn err_row_into(&self, j: usize, e2_strip: &[f32], err: &mut [f32]) {
        let n = self.d_in;
        let err = &mut err[..n];
        err.fill(0.0);
        let part = self
            .parts
            .iter()
            .find(|p| j >= p.row0 && j < p.row0 + p.rows)
            .expect("row out of range");
        let r = j - part.row0;
        let k = part.rank;
        let e2 = &e2_strip[part.e2_off..part.e2_off + k * n];
        let mut kk = 0usize;
        while kk + 4 <= k {
            let a0 = part.e1.get(r * k + kk);
            let a1 = part.e1.get(r * k + kk + 1);
            let a2 = part.e1.get(r * k + kk + 2);
            let a3 = part.e1.get(r * k + kk + 3);
            let b0 = &e2[kk * n..kk * n + n];
            let b1 = &e2[(kk + 1) * n..(kk + 1) * n + n];
            let b2 = &e2[(kk + 2) * n..(kk + 2) * n + n];
            let b3 = &e2[(kk + 3) * n..(kk + 3) * n + n];
            for c in 0..n {
                err[c] += a0 * b0[c] + a1 * b1[c] + a2 * b2[c] + a3 * b3[c];
            }
            kk += 4;
        }
        while kk < k {
            let av = part.e1.get(r * k + kk);
            if av != 0.0 {
                let b = &e2[kk * n..kk * n + n];
                for c in 0..n {
                    err[c] += av * b[c];
                }
            }
            kk += 1;
        }
    }

    /// Fused activation-side application: `acc += E₁·(E₂·x)`, i.e.
    /// `tmp = x·E₂ᵀ` (per part) followed by `acc[:, part] += tmp·E₁ᵀ`,
    /// each stage accumulating in the exact 4-term-group + zero-skip-tail
    /// order of [`matmul_into`](crate::tensor::matmul::matmul_into) — so
    /// the result is deterministic and row-local (batch splits cannot
    /// change any row's bits). `tmp_r` is a caller scratch reshaped to
    /// `[x.rows, rank]` (no allocation once its capacity covers
    /// `x.rows · max_rank`).
    ///
    /// Costs `O(r·(d_in + d_out))` per activation row — the low-rank FLOP
    /// count — but is **not** bit-equal to folding `E₁·E₂` into the weight
    /// (different f32 addition grouping), so the serving plan uses
    /// [`err_row_into`](Self::err_row_into) instead; see the type docs.
    pub fn apply_into(&self, x: &Matrix, tmp_r: &mut Matrix, acc: &mut Matrix) {
        assert_eq!(x.cols, self.d_in, "lorc input dim mismatch");
        assert_eq!(acc.rows, x.rows);
        assert_eq!(acc.cols, self.d_out);
        let n = self.d_in;
        for part in &self.parts {
            let k = part.rank;
            if k == 0 {
                continue;
            }
            // ---- stage 1: tmp[t][q] = Σ_c x[t][c] · E₂[q][c] ----
            tmp_r.resize_to(x.rows, k);
            for t in 0..x.rows {
                let xrow = x.row(t);
                let trow = tmp_r.row_mut(t);
                for (q, tv) in trow.iter_mut().enumerate() {
                    let mut accq = *tv; // zero from resize_to
                    let mut c = 0usize;
                    while c + 4 <= n {
                        accq += xrow[c] * part.e2.get(q * n + c)
                            + xrow[c + 1] * part.e2.get(q * n + c + 1)
                            + xrow[c + 2] * part.e2.get(q * n + c + 2)
                            + xrow[c + 3] * part.e2.get(q * n + c + 3);
                        c += 4;
                    }
                    while c < n {
                        let av = xrow[c];
                        if av != 0.0 {
                            accq += av * part.e2.get(q * n + c);
                        }
                        c += 1;
                    }
                    *tv = accq;
                }
            }
            // ---- stage 2: acc[t][row0 + j] += Σ_q tmp[t][q] · E₁[j][q] ----
            for t in 0..x.rows {
                let trow = tmp_r.row(t);
                let arow = &mut acc.row_mut(t)[part.row0..part.row0 + part.rows];
                for (j, av) in arow.iter_mut().enumerate() {
                    let mut s = *av;
                    let mut q = 0usize;
                    while q + 4 <= k {
                        s += trow[q] * part.e1.get(j * k + q)
                            + trow[q + 1] * part.e1.get(j * k + q + 1)
                            + trow[q + 2] * part.e1.get(j * k + q + 2)
                            + trow[q + 3] * part.e1.get(j * k + q + 3);
                        q += 4;
                    }
                    while q < k {
                        let tv = trow[q];
                        if tv != 0.0 {
                            s += tv * part.e1.get(j * k + q);
                        }
                        q += 1;
                    }
                    *av = s;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize_weight_rtn, WeightQuantConfig};
    use crate::rng::Rng;
    use crate::tensor::matmul::matmul_into;

    #[test]
    fn lorc_reduces_weight_error() {
        let mut rng = Rng::seeded(81);
        let w = Matrix::randn(64, 96, 0.1, &mut rng);
        let q = quantize_weight_rtn(
            &w,
            &WeightQuantConfig::new(NumericFormat::FP4_E2M1).with_group_size(32),
        );
        let deq = q.dequantize();
        let before = deq.mse(&w);
        let lorc = LorcFactors::compute(&w, &deq, &LorcConfig { rank: 16, factor_format: NumericFormat::F16 }).unwrap();
        let after = lorc.apply(&deq).mse(&w);
        assert!(after < before, "after={after} before={before}");
    }

    #[test]
    fn higher_rank_recovers_more() {
        let mut rng = Rng::seeded(82);
        let w = Matrix::randn(48, 48, 0.1, &mut rng);
        let q = quantize_weight_rtn(&w, &WeightQuantConfig::new(NumericFormat::INT4));
        let deq = q.dequantize();
        let mut last = f64::INFINITY;
        for rank in [2, 8, 32] {
            let lorc = LorcFactors::compute(
                &w,
                &deq,
                &LorcConfig { rank, factor_format: NumericFormat::F16 },
            )
            .unwrap();
            let e = lorc.apply(&deq).mse(&w);
            assert!(e <= last + 1e-12, "rank {rank}: {e} vs {last}");
            last = e;
        }
    }

    #[test]
    fn quantized_factors_still_help() {
        // the paper stores factors cheaply; FP8 factors must retain most of
        // the benefit
        let mut rng = Rng::seeded(83);
        let w = Matrix::randn(64, 64, 0.1, &mut rng);
        let q = quantize_weight_rtn(&w, &WeightQuantConfig::new(NumericFormat::FP4_E2M1));
        let deq = q.dequantize();
        let before = deq.mse(&w);
        let lorc8 = LorcFactors::compute(&w, &deq, &LorcConfig::default()).unwrap();
        let after8 = lorc8.apply(&deq).mse(&w);
        assert!(after8 < before * 0.9, "after8={after8} before={before}");
    }

    #[test]
    fn overhead_accounting() {
        let mut rng = Rng::seeded(84);
        let w = Matrix::randn(256, 256, 0.1, &mut rng);
        let q = quantize_weight_rtn(&w, &WeightQuantConfig::new(NumericFormat::FP4_E2M1));
        let lorc = LorcFactors::compute(&w, &q.dequantize(), &LorcConfig::default()).unwrap();
        // rank-8 FP8 factors on 256²: 2*256*8 bytes = 4096 ≪ 256*256/2 = 32768
        assert_eq!(lorc.packed_bytes(), 2 * 256 * 8);
        assert!(lorc.packed_bytes() < q.packed_bytes() / 4);
        assert_eq!(lorc.rank(), 8);
        // the serving representation: codes + one f32 scale per factor
        let p = PackedLorc::pack(&[(256, Some(&lorc))]);
        assert_eq!(p.mem_bytes(), 2 * 256 * 8 + 2 * 4);
        assert_eq!((p.d_out, p.d_in), (256, 256));
        assert_eq!(p.e2_elems(), 8 * 256);
    }

    #[test]
    fn rank_clamps_to_matrix_size() {
        let mut rng = Rng::seeded(85);
        let w = Matrix::randn(8, 6, 0.1, &mut rng);
        let q = quantize_weight_rtn(&w, &WeightQuantConfig::new(NumericFormat::INT4));
        let lorc = LorcFactors::compute(
            &w,
            &q.dequantize(),
            &LorcConfig { rank: 999, factor_format: NumericFormat::F16 },
        )
        .unwrap();
        assert_eq!(lorc.rank(), 6);
        // full-rank compensation recovers the weight exactly
        assert!(lorc.apply(&q.dequantize()).mse(&w) < 1e-10);
    }

    #[test]
    fn fp8_codes_reproduce_factor_values_bitwise() {
        // the code-storage invariant everything downstream rests on:
        // decode(code) · scale IS the fake-quantized value, bit for bit
        let mut rng = Rng::seeded(86);
        let w = Matrix::randn(24, 40, 0.1, &mut rng);
        let q = quantize_weight_rtn(&w, &WeightQuantConfig::new(NumericFormat::FP4_E2M1));
        for fmt in [NumericFormat::FP8_E4M3, NumericFormat::FP8_E5M2, NumericFormat::FP4_E2M1] {
            let lorc = LorcFactors::compute(
                &w,
                &q.dequantize(),
                &LorcConfig { rank: 4, factor_format: fmt },
            )
            .unwrap();
            assert!(lorc.has_codes(), "{}", fmt.name());
            let p = PackedLorc::pack(&[(24, Some(&lorc))]);
            let mut strip = vec![0.0f32; p.e2_elems()];
            p.decode_e2_into(&mut strip);
            for (i, &v) in strip.iter().enumerate() {
                assert_eq!(
                    v.to_bits(),
                    lorc.e2.data[i].to_bits(),
                    "{} e2[{i}]",
                    fmt.name()
                );
            }
        }
        // F16 factors carry no codes (stored f32, matching the fold)
        let f16 = LorcFactors::compute(
            &w,
            &q.dequantize(),
            &LorcConfig { rank: 4, factor_format: NumericFormat::F16 },
        )
        .unwrap();
        assert!(!f16.has_codes());
    }

    #[test]
    fn err_row_matches_fold_bitwise() {
        // err_row_into must reproduce each row of e1.matmul(&e2) exactly —
        // including non-multiple-of-4 ranks (singles tail)
        let mut rng = Rng::seeded(87);
        let w = Matrix::randn(16, 33, 0.1, &mut rng); // odd in-dim
        let q = quantize_weight_rtn(
            &w,
            &WeightQuantConfig::new(NumericFormat::FP4_E2M1).with_group_size(16),
        );
        for (rank, fmt) in [
            (2usize, NumericFormat::FP8_E4M3),
            (5, NumericFormat::FP8_E4M3),
            (8, NumericFormat::F16),
        ] {
            let lorc = LorcFactors::compute(
                &w,
                &q.dequantize(),
                &LorcConfig { rank, factor_format: fmt },
            )
            .unwrap();
            let reference = lorc.approx_error();
            let p = PackedLorc::pack(&[(16, Some(&lorc))]);
            let mut strip = vec![0.0f32; p.e2_elems()];
            p.decode_e2_into(&mut strip);
            let mut err = vec![7.0f32; 33]; // stale garbage must be overwritten
            for j in 0..16 {
                p.err_row_into(j, &strip, &mut err);
                for (c, &v) in err[..33].iter().enumerate() {
                    assert_eq!(
                        v.to_bits(),
                        reference.at(j, c).to_bits(),
                        "rank {rank} {} row {j} col {c}",
                        fmt.name()
                    );
                }
            }
        }
    }

    #[test]
    fn fused_stacking_keeps_per_part_factors() {
        // q|k|v-style fusion: E₁ blocks row-stacked, per-part E₂ kept
        let mut rng = Rng::seeded(88);
        let cfg = WeightQuantConfig::new(NumericFormat::FP4_E2M1).with_group_size(16);
        let lcfg = LorcConfig { rank: 3, factor_format: NumericFormat::FP8_E4M3 };
        let wa = Matrix::randn(6, 32, 0.1, &mut rng);
        let wb = Matrix::randn(4, 32, 0.1, &mut rng);
        let qa = quantize_weight_rtn(&wa, &cfg);
        let qb = quantize_weight_rtn(&wb, &cfg);
        let la = LorcFactors::compute(&wa, &qa.dequantize(), &lcfg).unwrap();
        let lb = LorcFactors::compute(&wb, &qb.dequantize(), &lcfg).unwrap();
        let ea = la.approx_error();
        let eb = lb.approx_error();
        let p = PackedLorc::pack(&[(6, Some(&la)), (4, Some(&lb))]);
        assert_eq!((p.d_out, p.d_in), (10, 32));
        assert_eq!(p.e2_elems(), 2 * 3 * 32);
        let mut strip = vec![0.0f32; p.e2_elems()];
        p.decode_e2_into(&mut strip);
        let mut err = vec![0.0f32; 32];
        for j in 0..10 {
            p.err_row_into(j, &strip, &mut err);
            let want = if j < 6 { ea.row(j) } else { eb.row(j - 6) };
            for (c, &v) in err.iter().enumerate() {
                assert_eq!(v.to_bits(), want[c].to_bits(), "fused row {j} col {c}");
            }
        }
        // a part without factors contributes exactly zero
        let p0 = PackedLorc::pack(&[(6, Some(&la)), (4, None)]);
        p0.decode_e2_into(&mut strip[..p0.e2_elems()]);
        p0.err_row_into(8, &strip, &mut err);
        assert!(err.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn apply_into_matches_two_stage_matmul_reference() {
        // apply_into's own contract: bit-equal to matmul_into over the
        // prepacked transposes (tmp = x·E₂ᵀ, acc += tmp·E₁ᵀ), and
        // row-local (batch splits don't change bits)
        let mut rng = Rng::seeded(89);
        let w = Matrix::randn(12, 20, 0.1, &mut rng);
        let q = quantize_weight_rtn(
            &w,
            &WeightQuantConfig::new(NumericFormat::FP4_E2M1).with_group_size(10),
        );
        let lorc = LorcFactors::compute(
            &w,
            &q.dequantize(),
            &LorcConfig { rank: 5, factor_format: NumericFormat::FP8_E4M3 },
        )
        .unwrap();
        let p = PackedLorc::pack(&[(12, Some(&lorc))]);
        let x = Matrix::randn(3, 20, 1.0, &mut rng);
        let seed = Matrix::randn(3, 12, 0.5, &mut rng);

        // reference: the same two stages through the reference kernel
        let e2t = lorc.e2.transpose();
        let mut tmp = Matrix::zeros(3, 5);
        matmul_into(&x, &e2t, &mut tmp);
        let e1t = lorc.e1.transpose();
        let mut want = seed.clone();
        matmul_into(&tmp, &e1t, &mut want);

        let mut got = seed.clone();
        let mut scratch = Matrix::zeros(0, 0);
        p.apply_into(&x, &mut scratch, &mut got);
        for (i, (a, b)) in want.data.iter().zip(&got.data).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "elem {i}: {a} vs {b}");
        }

        // row-locality: applying each activation row alone gives the same bits
        for t in 0..3 {
            let xr = Matrix::from_vec(1, 20, x.row(t).to_vec());
            let mut acc = Matrix::from_vec(1, 12, seed.row(t).to_vec());
            p.apply_into(&xr, &mut scratch, &mut acc);
            for (c, v) in acc.row(0).iter().enumerate() {
                assert_eq!(v.to_bits(), got.at(t, c).to_bits(), "row {t} col {c}");
            }
        }
    }
}
