//! Minimal JSON encode/decode for recipe artifacts (serde is not in the
//! offline vendor set — this is the reading half of the crate's JSON shim;
//! the writing-only half lives in [`crate::bench_harness`]).
//!
//! Supports exactly the subset a [`crate::recipe::QuantRecipe`] needs:
//! objects, arrays, strings (with the standard escapes incl. `\uXXXX`),
//! f64 numbers, booleans and null. Parsing is strict — trailing input,
//! unterminated literals and malformed numbers are errors with a byte
//! offset, because a recipe file is a reproducibility artifact and a
//! half-read one must never silently become a different configuration.

use std::fmt;

/// One parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a complete JSON document (strict: no trailing input).
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(format!("trailing input at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Object field lookup (`None` for non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Non-negative integer view of a number (`None` if fractional,
    /// negative, or not a number).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= usize::MAX as f64 => {
                Some(*n as usize)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Pretty-print with two-space indentation (`zqfp recipe show`).
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        let pad = |out: &mut String, d: usize| {
            for _ in 0..d {
                out.push_str("  ");
            }
        };
        match self {
            Json::Arr(items) if !items.is_empty() => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    out.push_str(if i == 0 { "\n" } else { ",\n" });
                    pad(out, depth + 1);
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                pad(out, depth);
                out.push(']');
            }
            Json::Obj(kv) if !kv.is_empty() => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    out.push_str(if i == 0 { "\n" } else { ",\n" });
                    pad(out, depth + 1);
                    write_string(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                pad(out, depth);
                out.push('}');
            }
            other => {
                let _ = fmt::Write::write_fmt(out, format_args!("{other}"));
            }
        }
    }
}

/// Compact single-line serialization; `Json::parse` round-trips it.
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.write_compact(f)
    }
}

impl Json {
    fn write_compact(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.is_finite() {
                    write!(f, "{n}")
                } else {
                    // JSON has no Inf/NaN; a recipe never contains one, but
                    // degrade to null rather than emit an unparseable token.
                    f.write_str("null")
                }
            }
            Json::Str(s) => {
                let mut buf = String::new();
                write_string(&mut buf, s);
                f.write_str(&buf)
            }
            Json::Arr(items) => {
                f.write_str("[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        f.write_str(", ")?;
                    }
                    v.write_compact(f)?;
                }
                f.write_str("]")
            }
            Json::Obj(kv) => {
                f.write_str("{")?;
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        f.write_str(", ")?;
                    }
                    let mut buf = String::new();
                    write_string(&mut buf, k);
                    f.write_str(&buf)?;
                    f.write_str(": ")?;
                    v.write_compact(f)?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(format!("unexpected '{}' at byte {}", c as char, self.pos)),
            None => Err(format!("unexpected end of input at byte {}", self.pos)),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).expect("ascii number");
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number {text:?} at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".to_string()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err("truncated \\u escape".to_string());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| "bad \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| format!("bad \\u escape {hex:?}"))?;
                            // Surrogate pairs are not needed for recipe
                            // content; map them to the replacement char.
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.pos)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy one UTF-8 scalar (multi-byte aware)
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| "invalid utf-8 in string".to_string())?;
                    let c = rest.chars().next().expect("non-empty");
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut kv = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            if kv.iter().any(|(k, _)| *k == key) {
                return Err(format!("duplicate key {key:?}"));
            }
            kv.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(kv));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-0.5e2").unwrap(), Json::Num(-50.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2], "b": {"c": null}, "s": "x\"y"}"#).unwrap();
        assert_eq!(v.get("a").unwrap(), &Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)]));
        assert!(v.get("b").unwrap().get("c").unwrap().is_null());
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "x\"y");
    }

    #[test]
    fn rejects_malformed() {
        for bad in [
            "", "{", "[1,", "{\"a\" 1}", "tru", "1 2", "{\"a\":1,}", "\"unterminated",
            "{\"a\":1,\"a\":2}",
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?}");
        }
    }

    #[test]
    fn display_round_trips() {
        let src = r#"{"name": "w4a8-fp", "n": 0.01, "on": true, "x": null, "arr": [1, "two"]}"#;
        let v = Json::parse(src).unwrap();
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
        // pretty form parses back to the same value too
        let pretty = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, pretty);
    }

    #[test]
    fn usize_view_is_strict() {
        assert_eq!(Json::Num(8.0).as_usize(), Some(8));
        assert_eq!(Json::Num(8.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Str("8".into()).as_usize(), None);
    }

    #[test]
    fn escapes_control_chars() {
        let v = Json::Str("a\u{1}b\tc".into());
        let s = v.to_string();
        assert_eq!(s, "\"a\\u0001b\\tc\"");
        assert_eq!(Json::parse(&s).unwrap(), v);
    }
}
