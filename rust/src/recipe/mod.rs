//! `QuantRecipe` — the one typed, serializable configuration for the whole
//! stack, from CLI flags to the compiled serving plan.
//!
//! The paper's result grid is a cross-product of knobs: weight format
//! (FP4/INT4/W8), activation format (FP8/INT8), FGQ group size, M1/M2
//! power-of-2 scale constraints, RTN vs GPTQ, LoRC rank/format — plus the
//! serving-side choices (dense vs bit-packed weight layout, GEMV shard
//! count, KV-cache quantization, batching limits). A recipe captures every
//! one of them in a single struct that is
//!
//! * **built once** via [`RecipeBuilder`] (or a named
//!   [`QuantRecipe::preset`] mirroring the paper's tables) and
//!   **validated once** at
//!   construction — every previously scattered rejection (the
//!   packed-needs-codes W16 rule, LoRC rank/format rules, zero-sized
//!   groups/batches) is a typed [`RecipeError`] here, nowhere else;
//! * **serializable**: [`QuantRecipe::to_json`] /
//!   [`QuantRecipe::from_json`] round-trip bit-exactly through the
//!   in-crate JSON shim ([`json`]), so a serve/eval/bench run can be
//!   reproduced from one artifact instead of a flag soup;
//! * **the source of derived views**: [`QuantRecipe::engine_opts`],
//!   [`QuantRecipe::batch_policy`] and
//!   [`QuantRecipe::coordinator_config`] are thin projections — the old
//!   config structs still exist but are no longer hand-assembled at every
//!   call site.
//!
//! Downstream, [`crate::pipeline::ptq`] consumes a recipe to produce the
//! quantized checkpoint + sidecar + report, and
//! [`crate::coordinator::ServingStack::build`] carries the same recipe on
//! through plan compilation to a running [`crate::coordinator::Coordinator`].

pub mod json;

use std::fmt;
use std::time::Duration;

use crate::cli::Args;
use crate::engine::{EngineOpts, KernelTier, WeightLayout};
use crate::formats::{FpFormat, NumericFormat};
use crate::gptq::GptqConfig;
use crate::lorc::LorcConfig;
use crate::quant::{ScaleConstraint, Scheme};

use self::json::Json;

/// The in-tree presets, mirroring the paper's tables: the W4A8 FP-FP
/// headline row (Table 2), its M1/M2 scale-constraint variants (Table 3,
/// with the footnote-4 E5M2 cast on), the LoRC variant, the W8A8 INT-INT
/// baseline, and the W16 no-op.
pub const PRESET_NAMES: [&str; 6] =
    ["w4a8-fp", "w4a8-fp-m1", "w4a8-fp-m2", "w4a8-fp-lorc", "w8a8-int", "w16"];

/// Every invalid knob combination a recipe can reject, in one place.
/// (Before the recipe API these lived in `cli/commands.rs`, the serve
/// command and the packed compile path, each with its own wording.)
#[derive(Debug, Clone, PartialEq)]
pub enum RecipeError {
    /// Packed weight layout with W16 weights: nothing is quantized, so
    /// there are no codes to pack.
    PackedNeedsCodes,
    /// LoRC compensates quantization error; W16 weights have none.
    LorcNeedsQuantizedWeights,
    /// LoRC rank must be at least 1.
    LorcRankZero,
    /// LoRC factors are stored FP or F16, never integer.
    LorcFactorFormatNotFp(NumericFormat),
    /// FGQ group size must be at least 1.
    GroupSizeZero,
    /// An M2 compute group of zero rows is meaningless.
    M2ZeroRows,
    /// GPTQ dampening must be a finite non-negative fraction (negative
    /// damping never converges; NaN poisons the Cholesky).
    GptqPercdampInvalid,
    /// The GPTQ column sweep needs blocks of at least 1 column.
    GptqBlockSizeZero,
    /// The KV cache quantizes through an FP format (or not at all).
    KvCacheNotFp(NumericFormat),
    /// The coordinator needs at least one in-flight slot.
    MaxBatchZero,
    /// The admission queue needs at least one slot (depth 0 would shed
    /// every request).
    QueueDepthZero,
    /// A KV byte budget only means something to the paged pool — with
    /// rings the bound is `max_batch × max_seq` by construction.
    KvBudgetNeedsPaging,
    /// Not one of [`PRESET_NAMES`].
    UnknownPreset(String),
    /// Malformed JSON, an unknown key, or an unparseable field value.
    BadJson(String),
    /// The speculative draft window must be at least 1 token.
    SpeculateKZero,
    /// The draft recipe itself failed validation.
    SpeculateDraft(Box<RecipeError>),
    /// A draft recipe that speculates in turn: one level only.
    SpeculateNested,
    /// The draft plan must be strictly cheaper than the target on the
    /// accuracy/cost grid (weight bits, LoRC rank, layout, kernel tier)
    /// — a draft as expensive as the target can only add overhead.
    SpeculateDraftNotCheaper,
    /// A packed draft compiles from the target PTQ run's quantized codes;
    /// a W16 target quantizes nothing, so there are none.
    SpeculateDraftNeedsTargetCodes,
    /// Sampling temperature must be a finite non-negative number
    /// (0 = greedy).
    SamplingTemperatureInvalid,
    /// Nucleus mass must be in (0, 1] — `top_p = 0` would keep no
    /// candidates and `> 1` is a typo'd percentage.
    SamplingTopPInvalid,
    /// The speculative parity contract is *greedy* parity
    /// (`tests/speculative.rs`); a sampling recipe cannot speculate.
    SpeculateNeedsGreedy,
    /// The session LRU needs at least one resident slot.
    MaxSessionsZero,
}

impl fmt::Display for RecipeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RecipeError::PackedNeedsCodes => f.write_str(
                "--packed needs quantized codes: pick a quantized scheme \
                 (W16 leaves nothing to pack)",
            ),
            RecipeError::LorcNeedsQuantizedWeights => {
                f.write_str("lorc compensates quantization error: W16 weights have none")
            }
            RecipeError::LorcRankZero => f.write_str("lorc rank must be at least 1"),
            RecipeError::LorcFactorFormatNotFp(fmt_) => write!(
                f,
                "lorc factors are stored FP or F16, not integer: {}",
                fmt_.name()
            ),
            RecipeError::GroupSizeZero => f.write_str("group size must be at least 1"),
            RecipeError::M2ZeroRows => {
                f.write_str("m2 compute groups need at least 1 row (m2:0 is meaningless)")
            }
            RecipeError::GptqPercdampInvalid => {
                f.write_str("gptq percdamp must be a finite non-negative fraction")
            }
            RecipeError::GptqBlockSizeZero => {
                f.write_str("gptq block size must be at least 1")
            }
            RecipeError::KvCacheNotFp(fmt_) => {
                write!(f, "kv cache quantizes through an FP format, not {}", fmt_.name())
            }
            RecipeError::MaxBatchZero => f.write_str("max_batch must be at least 1"),
            RecipeError::QueueDepthZero => {
                f.write_str("queue_depth must be at least 1 (0 would shed every request)")
            }
            RecipeError::KvBudgetNeedsPaging => f.write_str(
                "kv_budget_bytes needs the paged pool: set kv_page_positions \
                 (--kv-page) too",
            ),
            RecipeError::UnknownPreset(name) => {
                write!(f, "unknown preset {name:?} (try: {})", PRESET_NAMES.join(", "))
            }
            RecipeError::BadJson(msg) => write!(f, "recipe json: {msg}"),
            RecipeError::SpeculateKZero => {
                f.write_str("speculate: the draft window k must be at least 1")
            }
            RecipeError::SpeculateDraft(inner) => write!(f, "speculate draft recipe: {inner}"),
            RecipeError::SpeculateNested => {
                f.write_str("speculate: the draft recipe must not itself speculate")
            }
            RecipeError::SpeculateDraftNotCheaper => f.write_str(
                "speculate: the draft must be strictly cheaper than the target \
                 (fewer weight bits, lower lorc rank, packed vs dense, or fast \
                 vs oracle kernels — and no axis more expensive)",
            ),
            RecipeError::SpeculateDraftNeedsTargetCodes => f.write_str(
                "speculate: a packed draft needs the target's quantized codes \
                 (a W16 target quantizes nothing — use a dense draft layout)",
            ),
            RecipeError::SamplingTemperatureInvalid => {
                f.write_str("sampling temperature must be finite and >= 0 (0 = greedy)")
            }
            RecipeError::SamplingTopPInvalid => {
                f.write_str("sampling top_p must be in (0, 1] (1 = no nucleus cut)")
            }
            RecipeError::SpeculateNeedsGreedy => f.write_str(
                "speculate proves exact greedy parity only: set temperature 0 \
                 (or drop --speculate) to sample",
            ),
            RecipeError::MaxSessionsZero => {
                f.write_str("max_sessions must be at least 1 (the session LRU needs a slot)")
            }
        }
    }
}

// `?`-compatibility with the crate error shim (and std error chains).
impl std::error::Error for RecipeError {}

/// One fully-specified quantization + serving configuration.
///
/// Fields are public for ergonomic read access (and for tests that sweep
/// the grid), but construct through [`QuantRecipe::builder`],
/// [`QuantRecipe::preset`] or [`QuantRecipe::from_json`] — those are the
/// validation gates. After mutating fields directly, call
/// [`validate`](Self::validate) before handing the recipe to the pipeline.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantRecipe {
    /// Display label: a preset name, or "custom".
    pub name: String,
    /// Weight + activation formats (one Table-2 cell).
    pub scheme: Scheme,
    /// FGQ group size along input dims (paper: 256; our dims are smaller
    /// so the default is 64 — same groups-per-row ratio).
    pub group_size: usize,
    /// Power-of-2 scale constraint (Table 3's ✗ / M1 / M2).
    pub constraint: ScaleConstraint,
    /// Footnote-4 cast: requantize dequantized FP4 weights to E5M2.
    pub cast_fp4_to_e5m2: bool,
    /// GPTQ (true) or plain RTN (false, ablation baseline).
    pub use_gptq: bool,
    pub gptq: GptqConfig,
    /// Low-rank compensation (`None` = off).
    pub lorc: Option<LorcConfig>,
    /// Serving weight layout: dense f32 or bit-packed codes with
    /// `threads` GEMV shards.
    pub weights: WeightLayout,
    /// `Some(fmt)` ⇒ generation K/V caches are fake-quantized to this FP
    /// format; `None` = exact f32 caches.
    pub kv_quant: Option<FpFormat>,
    /// Positions per KV page. `> 0` ⇒ generation K/V storage is the
    /// block-paged [`crate::plan::KvPagePool`] (resident bytes scale with
    /// live tokens); `0` = per-sequence contiguous rings.
    pub kv_page_positions: usize,
    /// Byte budget of the paged KV pool (admission + preemption bound).
    /// `0` = auto: the ring plan's worst case, `max_batch` full-length
    /// sequences. Requires `kv_page_positions > 0`.
    pub kv_budget_bytes: usize,
    /// Coordinator: max in-flight sequences / max scoring batch.
    pub max_batch: usize,
    /// Coordinator: dynamic-batching wait window (PJRT scoring backend).
    pub max_wait_ms: u64,
    /// Coordinator: bound of the admission queue — submissions beyond it
    /// shed with a typed `Overloaded` instead of queueing unbounded
    /// latency.
    pub queue_depth: usize,
    /// Coordinator: default per-request deadline in milliseconds
    /// (0 = none). Checked at admission, during prefill, and between
    /// decode steps.
    pub deadline_ms: u64,
    /// Kernel backend tier of the compiled plan: the bit-exact scalar
    /// `oracle` (default) or the tolerance-gated `fast` tier
    /// (8-lane GEMV + persistent decode worker pool).
    pub kernel_tier: KernelTier,
    /// Self-speculative decoding: draft tokens with a second, strictly
    /// cheaper plan of the *same* artifacts and verify them in one
    /// batched target pass (`None` = off). Greedy output is exactly the
    /// target-only stream — see `plan/speculate.rs`.
    pub speculate: Option<SpeculateConfig>,
    /// Decode-time sampling knobs (temperature / top-k / top-p / seed).
    /// The default is greedy (`temperature = 0`), bit-for-bit the
    /// historical argmax path — see `coordinator/sampling.rs`.
    pub sampling: crate::coordinator::SamplingConfig,
    /// Coordinator: resident-cache bound of the session LRU — idle
    /// sessions beyond it drop their KV state (pages return to the pool)
    /// and transparently re-prefill on next touch.
    pub max_sessions: usize,
}

/// Default draft window when `--speculate` is given without `--draft-k`.
pub const DEFAULT_DRAFT_K: usize = 4;

/// The speculative-decoding knobs of a recipe: which cheaper view of the
/// target's PTQ artifacts drafts, and how many tokens per verify pass.
///
/// The draft recipe's PTQ-side fields (scheme, LoRC, layout, kernel tier)
/// select the *view* — the coordinator compiles it from the target run's
/// checkpoint + sidecar (a rank-0 packed draft of a LoRC target strips the
/// factors; see `ServingStack::compile_draft`). The draft's serving-side
/// fields (batching, KV paging, deadlines) are ignored: both caches of a
/// sequence live under the target's KV configuration, and the paged pool
/// is sized so two caches per sequence always fit.
#[derive(Debug, Clone, PartialEq)]
pub struct SpeculateConfig {
    /// The draft plan's recipe (boxed: a recipe contains its draft).
    pub draft: Box<QuantRecipe>,
    /// Draft window: tokens proposed per verify pass. Per-sequence
    /// adaptive k treats this as the ceiling.
    pub k: usize,
}

/// Chainable construction for [`QuantRecipe`]; `build()` validates.
#[derive(Debug, Clone)]
pub struct RecipeBuilder {
    r: QuantRecipe,
}

impl RecipeBuilder {
    pub fn new(scheme: Scheme) -> RecipeBuilder {
        RecipeBuilder {
            r: QuantRecipe {
                name: "custom".to_string(),
                scheme,
                group_size: 64,
                constraint: ScaleConstraint::None,
                cast_fp4_to_e5m2: false,
                use_gptq: true,
                gptq: GptqConfig::default(),
                lorc: None,
                weights: WeightLayout::Dense,
                kv_quant: None,
                kv_page_positions: 0,
                kv_budget_bytes: 0,
                max_batch: crate::runtime::SCORE_BATCH,
                max_wait_ms: 2,
                queue_depth: crate::coordinator::DEFAULT_QUEUE_DEPTH,
                deadline_ms: 0,
                kernel_tier: KernelTier::Oracle,
                speculate: None,
                sampling: crate::coordinator::SamplingConfig::default(),
                max_sessions: crate::coordinator::DEFAULT_MAX_SESSIONS,
            },
        }
    }

    pub fn name(mut self, name: impl Into<String>) -> Self {
        self.r.name = name.into();
        self
    }

    pub fn group_size(mut self, g: usize) -> Self {
        self.r.group_size = g;
        self
    }

    pub fn constraint(mut self, c: ScaleConstraint) -> Self {
        self.r.constraint = c;
        self
    }

    pub fn cast_fp4_to_e5m2(mut self, on: bool) -> Self {
        self.r.cast_fp4_to_e5m2 = on;
        self
    }

    pub fn use_gptq(mut self, on: bool) -> Self {
        self.r.use_gptq = on;
        self
    }

    pub fn gptq(mut self, g: GptqConfig) -> Self {
        self.r.gptq = g;
        self
    }

    pub fn lorc(mut self, l: LorcConfig) -> Self {
        self.r.lorc = Some(l);
        self
    }

    /// Bit-packed serving layout with `threads` GEMV shards (clamped ≥ 1
    /// so the layout round-trips through JSON unchanged).
    pub fn packed(mut self, threads: usize) -> Self {
        self.r.weights = WeightLayout::Packed { threads: threads.max(1) };
        self
    }

    pub fn dense(mut self) -> Self {
        self.r.weights = WeightLayout::Dense;
        self
    }

    pub fn kv_quant(mut self, f: Option<FpFormat>) -> Self {
        self.r.kv_quant = f;
        self
    }

    /// Positions per KV page (0 = contiguous rings, no paging).
    pub fn kv_page(mut self, positions: usize) -> Self {
        self.r.kv_page_positions = positions;
        self
    }

    /// Byte budget of the paged KV pool (0 = auto ring-equivalent).
    pub fn kv_budget(mut self, bytes: usize) -> Self {
        self.r.kv_budget_bytes = bytes;
        self
    }

    pub fn max_batch(mut self, b: usize) -> Self {
        self.r.max_batch = b;
        self
    }

    pub fn max_wait_ms(mut self, ms: u64) -> Self {
        self.r.max_wait_ms = ms;
        self
    }

    pub fn queue_depth(mut self, depth: usize) -> Self {
        self.r.queue_depth = depth;
        self
    }

    /// Default per-request deadline in ms (0 = none).
    pub fn deadline_ms(mut self, ms: u64) -> Self {
        self.r.deadline_ms = ms;
        self
    }

    /// Kernel backend tier (`oracle` default, `fast`).
    pub fn kernels(mut self, tier: KernelTier) -> Self {
        self.r.kernel_tier = tier;
        self
    }

    /// Self-speculative decoding: draft with `draft` (a strictly cheaper
    /// recipe of the same artifacts), `k` tokens per verify pass.
    pub fn speculate(mut self, draft: QuantRecipe, k: usize) -> Self {
        self.r.speculate = Some(SpeculateConfig { draft: Box::new(draft), k });
        self
    }

    /// Decode-time sampling knobs (default greedy, `temperature = 0`).
    pub fn sampling(mut self, cfg: crate::coordinator::SamplingConfig) -> Self {
        self.r.sampling = cfg;
        self
    }

    /// Resident-cache bound of the session LRU.
    pub fn max_sessions(mut self, n: usize) -> Self {
        self.r.max_sessions = n;
        self
    }

    /// Validate and return the recipe.
    pub fn build(self) -> Result<QuantRecipe, RecipeError> {
        self.r.validate()?;
        Ok(self.r)
    }
}

impl QuantRecipe {
    pub fn builder(scheme: Scheme) -> RecipeBuilder {
        RecipeBuilder::new(scheme)
    }

    /// A named in-tree preset ([`PRESET_NAMES`]).
    pub fn preset(name: &str) -> Result<QuantRecipe, RecipeError> {
        let b = |s: &str| RecipeBuilder::new(Scheme::parse(s).expect("preset scheme"));
        let builder = match name {
            "w4a8-fp" => b("w4a8-fp-fp"),
            "w4a8-fp-m1" => b("w4a8-fp-fp")
                .constraint(ScaleConstraint::M1)
                .cast_fp4_to_e5m2(true),
            "w4a8-fp-m2" => b("w4a8-fp-fp")
                .constraint(ScaleConstraint::M2 { rows: 32 })
                .cast_fp4_to_e5m2(true),
            "w4a8-fp-lorc" => b("w4a8-fp-fp").lorc(LorcConfig::default()),
            "w8a8-int" => b("w8a8-int-int"),
            "w16" => b("w16a16"),
            other => return Err(RecipeError::UnknownPreset(other.to_string())),
        };
        builder.name(name).build()
    }

    /// The single validation gate — every construction path funnels here.
    pub fn validate(&self) -> Result<(), RecipeError> {
        if self.group_size == 0 {
            return Err(RecipeError::GroupSizeZero);
        }
        if matches!(self.constraint, ScaleConstraint::M2 { rows: 0 }) {
            return Err(RecipeError::M2ZeroRows);
        }
        if !self.gptq.percdamp.is_finite() || self.gptq.percdamp < 0.0 {
            return Err(RecipeError::GptqPercdampInvalid);
        }
        if self.gptq.block_size == 0 {
            return Err(RecipeError::GptqBlockSizeZero);
        }
        let w16 = matches!(self.scheme.weight, NumericFormat::F16);
        if !self.weights.is_dense() && w16 {
            return Err(RecipeError::PackedNeedsCodes);
        }
        if let Some(l) = &self.lorc {
            if w16 {
                return Err(RecipeError::LorcNeedsQuantizedWeights);
            }
            if l.rank == 0 {
                return Err(RecipeError::LorcRankZero);
            }
            match l.factor_format {
                NumericFormat::F16 | NumericFormat::Fp(_) => {}
                other => return Err(RecipeError::LorcFactorFormatNotFp(other)),
            }
        }
        if self.max_batch == 0 {
            return Err(RecipeError::MaxBatchZero);
        }
        if self.queue_depth == 0 {
            return Err(RecipeError::QueueDepthZero);
        }
        if self.kv_budget_bytes > 0 && self.kv_page_positions == 0 {
            return Err(RecipeError::KvBudgetNeedsPaging);
        }
        if !self.sampling.temperature.is_finite() || self.sampling.temperature < 0.0 {
            return Err(RecipeError::SamplingTemperatureInvalid);
        }
        if !(self.sampling.top_p > 0.0 && self.sampling.top_p <= 1.0) {
            return Err(RecipeError::SamplingTopPInvalid);
        }
        if self.max_sessions == 0 {
            return Err(RecipeError::MaxSessionsZero);
        }
        if let Some(sc) = &self.speculate {
            if sc.k == 0 {
                return Err(RecipeError::SpeculateKZero);
            }
            // the speculative suite pins *greedy* parity; sampled draws
            // over draft-vs-target logits have no such contract
            if !self.sampling.is_greedy() {
                return Err(RecipeError::SpeculateNeedsGreedy);
            }
            if sc.draft.speculate.is_some() {
                return Err(RecipeError::SpeculateNested);
            }
            sc.draft
                .validate()
                .map_err(|e| RecipeError::SpeculateDraft(Box::new(e)))?;
            if w16 && !sc.draft.weights.is_dense() {
                return Err(RecipeError::SpeculateDraftNeedsTargetCodes);
            }
            // The draft must sit strictly below the target on the
            // accuracy/cost grid. Accuracy axes (weight bits, LoRC rank —
            // the bits actually served) must be no heavier; "strictly
            // cheaper" is any accuracy axis lower, or a pure speed win at
            // equal accuracy (packed layout under a dense target, fast
            // kernels under an oracle target). A draft exactly as
            // expensive as the target can only slow the round down.
            let dw = sc.draft.scheme.weight.bits();
            let tw = self.scheme.weight.bits();
            let dr = sc.draft.lorc.as_ref().map_or(0, |l| l.rank);
            let tr = self.lorc.as_ref().map_or(0, |l| l.rank);
            let no_worse = dw <= tw && dr <= tr;
            let cheaper = dw < tw
                || dr < tr
                || (self.weights.is_dense() && !sc.draft.weights.is_dense())
                || (!self.kernel_tier.is_fast() && sc.draft.kernel_tier.is_fast());
            if !(no_worse && cheaper) {
                return Err(RecipeError::SpeculateDraftNotCheaper);
            }
        }
        Ok(())
    }

    /// True when PTQ under this recipe consumes calibration data (GPTQ on
    /// a quantized weight format — RTN and W16 runs need none).
    pub fn needs_calibration(&self) -> bool {
        self.use_gptq && !matches!(self.scheme.weight, NumericFormat::F16)
    }

    /// Derived view: engine/plan options (activation fake-quant + weight
    /// layout) for this recipe.
    pub fn engine_opts(&self) -> EngineOpts {
        let mut opts = EngineOpts::with_act(self.scheme.activation);
        opts.weights = self.weights;
        opts.kernels = self.kernel_tier;
        opts
    }

    /// Derived view: the coordinator's batching policy.
    pub fn batch_policy(&self) -> crate::coordinator::BatchPolicy {
        crate::coordinator::BatchPolicy {
            max_batch: self.max_batch,
            max_wait: Duration::from_millis(self.max_wait_ms),
        }
    }

    /// Derived view: a full [`crate::coordinator::CoordinatorConfig`] over
    /// an already-quantized checkpoint + sidecar (the compiled in-process
    /// backend; [`crate::coordinator::ServingStack::build`] is the usual
    /// way to get here).
    pub fn coordinator_config(
        &self,
        ck: crate::model::Checkpoint,
        sidecar: Option<crate::quant::QuantSidecar>,
    ) -> crate::coordinator::CoordinatorConfig {
        crate::coordinator::CoordinatorConfig {
            backend: crate::coordinator::ScoreBackend::Compiled,
            ck,
            opts: self.engine_opts(),
            policy: self.batch_policy(),
            kv_quant: self.kv_quant,
            kv_page_positions: self.kv_page_positions,
            kv_budget_bytes: self.kv_budget_bytes,
            sidecar: if self.weights.is_dense() { None } else { sidecar },
            queue_depth: self.queue_depth,
            deadline: if self.deadline_ms > 0 {
                Some(Duration::from_millis(self.deadline_ms))
            } else {
                None
            },
            // fault schedules are a harness knob, never part of a recipe
            faults: None,
            speculate: self.speculate.clone(),
            sampling: self.sampling,
            max_sessions: self.max_sessions,
        }
    }

    /// One-line human summary (`zqfp recipe list`).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{}  group {}  constraint {}  {}",
            self.scheme.name(),
            self.group_size,
            self.constraint.label(),
            if self.use_gptq { "gptq" } else { "rtn" },
        );
        if self.cast_fp4_to_e5m2 {
            s.push_str("  cast-e5m2");
        }
        if let Some(l) = &self.lorc {
            s.push_str(&format!("  lorc r{}/{}", l.rank, format_label(l.factor_format)));
        }
        match self.weights {
            WeightLayout::Dense => s.push_str("  dense"),
            WeightLayout::Packed { threads } => {
                s.push_str(&format!("  packed x{}", threads.max(1)))
            }
        }
        if let Some(f) = self.kv_quant {
            s.push_str(&format!("  kv {}", f.name().to_ascii_lowercase()));
        }
        if self.kv_page_positions > 0 {
            s.push_str(&format!("  paged:{}", self.kv_page_positions));
            if self.kv_budget_bytes > 0 {
                s.push_str(&format!("/{}B", self.kv_budget_bytes));
            }
        }
        // the tier is always shown — a summary that only mentioned the
        // fast tier made "oracle" ambiguous with "tier unknown" in
        // `zqfp recipe list` output
        s.push_str(&format!("  kernels={}", self.kernel_tier.name()));
        if let Some(sc) = &self.speculate {
            s.push_str(&format!("  speculate={}/k{}", sc.draft.name, sc.k));
        }
        if !self.sampling.is_greedy() {
            s.push_str(&format!(
                "  sample T={} k={} p={} seed={}",
                self.sampling.temperature,
                self.sampling.top_k,
                self.sampling.top_p,
                self.sampling.seed
            ));
        }
        if self.max_sessions != crate::coordinator::DEFAULT_MAX_SESSIONS {
            s.push_str(&format!("  sessions {}", self.max_sessions));
        }
        s
    }

    /// Resolve a preset name or a JSON file path (the `--recipe` flag and
    /// `zqfp recipe show` share this).
    pub fn load(spec: &str) -> Result<QuantRecipe, String> {
        if PRESET_NAMES.contains(&spec) {
            return QuantRecipe::preset(spec).map_err(|e| e.to_string());
        }
        match std::fs::read_to_string(spec) {
            Ok(text) => QuantRecipe::from_json(&text).map_err(|e| format!("{spec}: {e}")),
            Err(io) => Err(format!(
                "{spec}: not a preset ({}) and not a readable recipe file: {io}",
                PRESET_NAMES.join(", ")
            )),
        }
    }

    // ---- JSON ------------------------------------------------------------

    /// Serialize to a compact JSON document; [`from_json`](Self::from_json)
    /// round-trips it field-for-field.
    pub fn to_json(&self) -> String {
        self.to_json_value().to_string()
    }

    /// Pretty two-space-indented form (`zqfp recipe show`).
    pub fn to_json_pretty(&self) -> String {
        self.to_json_value().pretty()
    }

    fn to_json_value(&self) -> Json {
        let lorc = match &self.lorc {
            None => Json::Null,
            Some(l) => Json::Obj(vec![
                ("rank".to_string(), Json::Num(l.rank as f64)),
                ("format".to_string(), Json::Str(format_label(l.factor_format))),
            ]),
        };
        let kv = match self.kv_quant {
            None => Json::Null,
            Some(f) => Json::Str(f.name().to_ascii_lowercase()),
        };
        let layout = match self.weights {
            WeightLayout::Dense => "dense",
            WeightLayout::Packed { .. } => "packed",
        };
        let speculate = match &self.speculate {
            None => Json::Null,
            Some(sc) => Json::Obj(vec![
                // the full draft document, not just a name: a custom draft
                // must round-trip field-for-field like everything else
                ("draft".to_string(), sc.draft.to_json_value()),
                ("k".to_string(), Json::Num(sc.k as f64)),
            ]),
        };
        Json::Obj(vec![
            ("name".to_string(), Json::Str(self.name.clone())),
            ("weight".to_string(), Json::Str(format_label(self.scheme.weight))),
            ("act".to_string(), Json::Str(format_label(self.scheme.activation))),
            ("group_size".to_string(), Json::Num(self.group_size as f64)),
            ("constraint".to_string(), Json::Str(self.constraint.label())),
            ("cast_fp4_to_e5m2".to_string(), Json::Bool(self.cast_fp4_to_e5m2)),
            ("gptq".to_string(), Json::Bool(self.use_gptq)),
            ("gptq_percdamp".to_string(), Json::Num(self.gptq.percdamp)),
            ("gptq_block_size".to_string(), Json::Num(self.gptq.block_size as f64)),
            ("lorc".to_string(), lorc),
            ("layout".to_string(), Json::Str(layout.to_string())),
            ("gemv_threads".to_string(), Json::Num(self.weights.threads() as f64)),
            ("kernels".to_string(), Json::Str(self.kernel_tier.name().to_string())),
            ("kv_cache".to_string(), kv),
            ("kv_page_positions".to_string(), Json::Num(self.kv_page_positions as f64)),
            ("kv_budget_bytes".to_string(), Json::Num(self.kv_budget_bytes as f64)),
            ("max_batch".to_string(), Json::Num(self.max_batch as f64)),
            ("max_wait_ms".to_string(), Json::Num(self.max_wait_ms as f64)),
            ("queue_depth".to_string(), Json::Num(self.queue_depth as f64)),
            ("deadline_ms".to_string(), Json::Num(self.deadline_ms as f64)),
            ("speculate".to_string(), speculate),
            (
                "sampling".to_string(),
                Json::Obj(vec![
                    (
                        "temperature".to_string(),
                        Json::Num(self.sampling.temperature as f64),
                    ),
                    ("top_k".to_string(), Json::Num(self.sampling.top_k as f64)),
                    ("top_p".to_string(), Json::Num(self.sampling.top_p as f64)),
                    // seeds above 2^53 would lose bits through the f64
                    // number representation; the validate/round-trip tests
                    // pin the practical range
                    ("seed".to_string(), Json::Num(self.sampling.seed as f64)),
                ]),
            ),
            ("max_sessions".to_string(), Json::Num(self.max_sessions as f64)),
        ])
    }

    /// Parse + validate a recipe document. Unknown keys are rejected (a
    /// typo in a reproducibility artifact must not silently change the
    /// run); absent keys take the [`RecipeBuilder`] defaults.
    pub fn from_json(text: &str) -> Result<QuantRecipe, RecipeError> {
        let doc = Json::parse(text).map_err(RecipeError::BadJson)?;
        Self::from_json_value(&doc)
    }

    /// The document-level parser behind [`from_json`](Self::from_json) —
    /// also the recursive entry point for the nested `speculate.draft`
    /// document.
    fn from_json_value(doc: &Json) -> Result<QuantRecipe, RecipeError> {
        const KEYS: [&str; 23] = [
            "name",
            "weight",
            "act",
            "group_size",
            "constraint",
            "cast_fp4_to_e5m2",
            "gptq",
            "gptq_percdamp",
            "gptq_block_size",
            "lorc",
            "layout",
            "gemv_threads",
            "kernels",
            "kv_cache",
            "kv_page_positions",
            "kv_budget_bytes",
            "max_batch",
            "max_wait_ms",
            "queue_depth",
            "deadline_ms",
            "speculate",
            "sampling",
            "max_sessions",
        ];
        let obj = match doc {
            Json::Obj(kv) => kv,
            _ => return Err(RecipeError::BadJson("top level must be an object".to_string())),
        };
        for (k, _) in obj {
            if !KEYS.contains(&k.as_str()) {
                return Err(RecipeError::BadJson(format!("unknown key {k:?}")));
            }
        }
        let bad = RecipeError::BadJson;
        let str_field = |key: &str| -> Result<Option<String>, RecipeError> {
            match doc.get(key) {
                None => Ok(None),
                Some(v) => v
                    .as_str()
                    .map(|s| Some(s.to_string()))
                    .ok_or_else(|| bad(format!("{key} must be a string"))),
            }
        };
        let usize_field = |key: &str, default: usize| -> Result<usize, RecipeError> {
            match doc.get(key) {
                None => Ok(default),
                Some(v) => v
                    .as_usize()
                    .ok_or_else(|| bad(format!("{key} must be a non-negative integer"))),
            }
        };
        let bool_field = |key: &str, default: bool| -> Result<bool, RecipeError> {
            match doc.get(key) {
                None => Ok(default),
                Some(v) => v.as_bool().ok_or_else(|| bad(format!("{key} must be a boolean"))),
            }
        };
        let format_field = |key: &str| -> Result<Option<NumericFormat>, RecipeError> {
            match str_field(key)? {
                None => Ok(None),
                Some(s) => NumericFormat::parse(&s)
                    .map(Some)
                    .ok_or_else(|| bad(format!("{key}: unknown format {s:?}"))),
            }
        };

        let weight = format_field("weight")?.unwrap_or(NumericFormat::FP4_E2M1);
        let act = format_field("act")?.unwrap_or(NumericFormat::FP8_E4M3);
        let mut b = RecipeBuilder::new(Scheme { weight, activation: act });
        if let Some(name) = str_field("name")? {
            b = b.name(name);
        }
        b = b.group_size(usize_field("group_size", 64)?);
        if let Some(c) = str_field("constraint")? {
            let parsed = ScaleConstraint::parse(&c)
                .ok_or_else(|| bad(format!("constraint: unknown label {c:?}")))?;
            b = b.constraint(parsed);
        }
        b = b.cast_fp4_to_e5m2(bool_field("cast_fp4_to_e5m2", false)?);
        b = b.use_gptq(bool_field("gptq", true)?);
        let mut gptq = GptqConfig::default();
        if let Some(v) = doc.get("gptq_percdamp") {
            gptq.percdamp = v
                .as_f64()
                .filter(|d| d.is_finite() && *d >= 0.0)
                .ok_or_else(|| bad("gptq_percdamp must be a non-negative number".to_string()))?;
        }
        gptq.block_size = usize_field("gptq_block_size", gptq.block_size)?;
        b = b.gptq(gptq);
        match doc.get("lorc") {
            None => {}
            Some(v) if v.is_null() => {}
            Some(v @ Json::Obj(kv)) => {
                for (k, _) in kv {
                    if k != "rank" && k != "format" {
                        return Err(bad(format!("lorc: unknown key {k:?}")));
                    }
                }
                let rank = match v.get("rank") {
                    None => LorcConfig::default().rank,
                    Some(r) => r.as_usize().ok_or_else(|| {
                        bad("lorc.rank must be a non-negative integer".to_string())
                    })?,
                };
                let factor_format = match v.get("format") {
                    None => LorcConfig::default().factor_format,
                    Some(f) => {
                        let s = f
                            .as_str()
                            .ok_or_else(|| bad("lorc.format must be a string".to_string()))?;
                        NumericFormat::parse(s)
                            .ok_or_else(|| bad(format!("lorc.format: unknown format {s:?}")))?
                    }
                };
                b = b.lorc(LorcConfig { rank, factor_format });
            }
            Some(_) => return Err(bad("lorc must be an object or null".to_string())),
        }
        let threads = usize_field("gemv_threads", 1)?;
        match str_field("layout")?.as_deref() {
            None | Some("dense") => {}
            Some("packed") => b = b.packed(threads),
            Some(other) => {
                return Err(bad(format!("layout: expected dense|packed, got {other:?}")))
            }
        }
        if let Some(tier) = str_field("kernels")? {
            let parsed = KernelTier::parse(&tier)
                .ok_or_else(|| bad(format!("kernels: expected oracle|fast, got {tier:?}")))?;
            b = b.kernels(parsed);
        }
        match doc.get("kv_cache") {
            None => {}
            Some(v) if v.is_null() => {}
            Some(v) => {
                let s = v
                    .as_str()
                    .ok_or_else(|| bad("kv_cache must be a format string or null".to_string()))?;
                match s {
                    // the CLI spelling of "off" is accepted in the file too
                    // (NumericFormat::parse would read "none" as F16 and
                    // produce a misleading rejection)
                    "none" | "off" => {}
                    _ => match NumericFormat::parse(s) {
                        Some(NumericFormat::Fp(f)) => b = b.kv_quant(Some(f)),
                        Some(other) => return Err(RecipeError::KvCacheNotFp(other)),
                        None => return Err(bad(format!("kv_cache: unknown format {s:?}"))),
                    },
                }
            }
        }
        b = b.kv_page(usize_field("kv_page_positions", 0)?);
        b = b.kv_budget(usize_field("kv_budget_bytes", 0)?);
        b = b.max_batch(usize_field("max_batch", crate::runtime::SCORE_BATCH)?);
        b = b.max_wait_ms(usize_field("max_wait_ms", 2)? as u64);
        b = b.queue_depth(usize_field(
            "queue_depth",
            crate::coordinator::DEFAULT_QUEUE_DEPTH,
        )?);
        b = b.deadline_ms(usize_field("deadline_ms", 0)? as u64);
        match doc.get("speculate") {
            None => {}
            Some(v) if v.is_null() => {}
            Some(v @ Json::Obj(kv)) => {
                for (k, _) in kv {
                    if k != "draft" && k != "k" {
                        return Err(bad(format!("speculate: unknown key {k:?}")));
                    }
                }
                let draft = match v.get("draft") {
                    None => return Err(bad("speculate needs a draft recipe".to_string())),
                    // a preset name is accepted as shorthand for its document
                    Some(Json::Str(name)) => QuantRecipe::preset(name)
                        .map_err(|e| RecipeError::SpeculateDraft(Box::new(e)))?,
                    Some(d @ Json::Obj(_)) => Self::from_json_value(d)
                        .map_err(|e| RecipeError::SpeculateDraft(Box::new(e)))?,
                    Some(_) => {
                        return Err(bad(
                            "speculate.draft must be a recipe object or a preset name".to_string(),
                        ))
                    }
                };
                let k = match v.get("k") {
                    None => DEFAULT_DRAFT_K,
                    Some(n) => n.as_usize().ok_or_else(|| {
                        bad("speculate.k must be a non-negative integer".to_string())
                    })?,
                };
                b = b.speculate(draft, k);
            }
            Some(_) => return Err(bad("speculate must be an object or null".to_string())),
        }
        match doc.get("sampling") {
            None => {}
            Some(v) if v.is_null() => {}
            Some(v @ Json::Obj(kv)) => {
                for (k, _) in kv {
                    if !["temperature", "top_k", "top_p", "seed"].contains(&k.as_str()) {
                        return Err(bad(format!("sampling: unknown key {k:?}")));
                    }
                }
                let mut sc = crate::coordinator::SamplingConfig::default();
                if let Some(t) = v.get("temperature") {
                    sc.temperature = t
                        .as_f64()
                        .ok_or_else(|| bad("sampling.temperature must be a number".to_string()))?
                        as f32;
                }
                if let Some(k) = v.get("top_k") {
                    sc.top_k = k.as_usize().ok_or_else(|| {
                        bad("sampling.top_k must be a non-negative integer".to_string())
                    })?;
                }
                if let Some(p) = v.get("top_p") {
                    sc.top_p = p
                        .as_f64()
                        .ok_or_else(|| bad("sampling.top_p must be a number".to_string()))?
                        as f32;
                }
                if let Some(s) = v.get("seed") {
                    sc.seed = s.as_usize().ok_or_else(|| {
                        bad("sampling.seed must be a non-negative integer".to_string())
                    })? as u64;
                }
                b = b.sampling(sc);
            }
            Some(_) => return Err(bad("sampling must be an object or null".to_string())),
        }
        b = b.max_sessions(usize_field(
            "max_sessions",
            crate::coordinator::DEFAULT_MAX_SESSIONS,
        )?);
        b.build()
    }

    // ---- CLI translation -------------------------------------------------

    /// The one flag→recipe translation shared by `zqfp quantize`, `eval`
    /// and `serve` (previously each subcommand reassembled its own config,
    /// and the serve/eval paths had drifted).
    ///
    /// Precedence: explicit flags override the `--recipe <path|preset>`
    /// base, which overrides the per-command `default` preset. LoRC knobs
    /// (`--lorc-rank`, `--lorc-format`, the historical `--rank`) require
    /// LoRC to be on (via `--lorc` or the base recipe). Every boolean
    /// knob has a symmetric off-switch so a base recipe is fully
    /// overridable: `--no-lorc`, `--no-cast`, `--dense` (vs `--packed`),
    /// `--gptq` (vs `--rtn`), `--kv-cache none`; contradictory pairs are
    /// an error, not a silent winner.
    pub fn from_args(args: &Args, default: &str) -> Result<QuantRecipe, String> {
        // a valueless `--recipe` would silently fall back to the default
        // preset (Args stores a sentinel `get` reports as absent) — the
        // one flag whose whole point is pinning the run must not be
        // droppable
        if args.flag("recipe") && args.get("recipe").is_none() {
            return Err("--recipe needs a value (a preset name or a file path)".to_string());
        }
        let mut r = match args.get("recipe") {
            Some(spec) => QuantRecipe::load(&spec)?,
            None => QuantRecipe::preset(default).map_err(|e| e.to_string())?,
        };

        if let Some(s) = args.get("scheme") {
            r.scheme = Scheme::parse(&s).ok_or(format!("bad --scheme {s}"))?;
        }
        r.group_size = args.get_usize("group", r.group_size)?;
        let rtn = args.flag("rtn");
        let gptq_flag = args.flag("gptq");
        if rtn && gptq_flag {
            return Err("--rtn and --gptq are contradictory".to_string());
        }
        if rtn {
            r.use_gptq = false;
        }
        if gptq_flag {
            r.use_gptq = true;
        }
        let cast = args.flag("cast");
        let no_cast = args.flag("no-cast");
        if cast && no_cast {
            return Err("--cast and --no-cast are contradictory".to_string());
        }
        if cast {
            r.cast_fp4_to_e5m2 = true;
        }
        if no_cast {
            r.cast_fp4_to_e5m2 = false;
        }
        if let Some(c) = args.get("constraint") {
            r.constraint = ScaleConstraint::parse(&c).ok_or(format!("bad --constraint {c}"))?;
        }

        // LoRC: consume every knob up front so `Args::finish` never
        // reports a knob this function already judged.
        let no_lorc = args.flag("no-lorc");
        let lorc_flag = args.flag("lorc");
        if no_lorc && lorc_flag {
            return Err("--lorc and --no-lorc are contradictory".to_string());
        }
        let lorc_on = lorc_flag || (r.lorc.is_some() && !no_lorc);
        if lorc_on {
            // a valueless `--lorc-rank`/`--lorc-format`/`--rank` would
            // silently fall back to the base value (Args stores a sentinel
            // `get` reports as absent) — reject instead of guessing
            for knob in ["lorc-rank", "lorc-format", "rank"] {
                if args.flag(knob) && args.get(knob).is_none() {
                    return Err(format!("--{knob} needs a value"));
                }
            }
            let base = r.lorc.unwrap_or_default();
            // --rank is the historical spelling; --lorc-rank wins when
            // both are given.
            let rank = args.get_usize("lorc-rank", args.get_usize("rank", base.rank)?)?;
            let factor_format = match args.get("lorc-format") {
                None => base.factor_format,
                Some(s) => match NumericFormat::parse(&s) {
                    Some(f @ (NumericFormat::F16 | NumericFormat::Fp(_))) => f,
                    Some(other) => {
                        return Err(RecipeError::LorcFactorFormatNotFp(other).to_string())
                    }
                    None => return Err(format!("bad --lorc-format {s}")),
                },
            };
            r.lorc = Some(LorcConfig { rank, factor_format });
        } else {
            let _ = args.get_usize("rank", 8)?; // historical knob: consumed leniently
            // the targeted knobs without LoRC are almost certainly a
            // dropped flag — silently serving without compensation would
            // be a quality surprise. (`flag`, not `get`: a valueless knob
            // must trip this too.)
            if args.flag("lorc-rank") || args.flag("lorc-format") {
                return Err(
                    "--lorc-rank/--lorc-format have no effect without --lorc".to_string()
                );
            }
            r.lorc = None;
        }

        // Serving side. `--dense` is the off-switch for a packed base
        // recipe (the layout analogue of --no-lorc/--no-cast).
        let dense_flag = args.flag("dense");
        let packed_flag = args.flag("packed");
        if dense_flag && packed_flag {
            return Err("--dense and --packed are contradictory".to_string());
        }
        let gemv_given = args.flag("gemv-threads");
        if gemv_given && args.get("gemv-threads").is_none() {
            return Err("--gemv-threads needs a value".to_string());
        }
        let gemv = args.get_usize("gemv-threads", r.weights.threads())?;
        if dense_flag {
            if gemv_given {
                return Err("--gemv-threads has no effect on the dense layout".to_string());
            }
            r.weights = WeightLayout::Dense;
        } else if packed_flag || !r.weights.is_dense() {
            r.weights = WeightLayout::Packed { threads: gemv.max(1) };
        } else if gemv_given {
            // a targeted knob without its enabling flag is almost certainly
            // a dropped --packed — same policy as the LoRC knobs above
            return Err("--gemv-threads has no effect without --packed".to_string());
        }
        if let Some(s) = args.get("kv-cache") {
            r.kv_quant = match s.as_str() {
                "none" | "off" => None,
                _ => match NumericFormat::parse(&s) {
                    Some(NumericFormat::Fp(f)) => Some(f),
                    Some(other) => return Err(RecipeError::KvCacheNotFp(other).to_string()),
                    None => return Err(format!("--kv-cache: not an FP format: {s}")),
                },
            };
        }
        // Paged KV pool: a valueless knob is rejected, not defaulted, and
        // a budget without paging is the typed validation error below.
        for knob in ["kv-page", "kv-budget"] {
            if args.flag(knob) && args.get(knob).is_none() {
                return Err(format!("--{knob} needs a value"));
            }
        }
        r.kv_page_positions = args.get_usize("kv-page", r.kv_page_positions)?;
        r.kv_budget_bytes = args.get_usize("kv-budget", r.kv_budget_bytes)?;
        // Kernel tier: a valueless `--kernels` must not silently keep the
        // base tier (same policy as --recipe / --gemv-threads).
        if args.flag("kernels") && args.get("kernels").is_none() {
            return Err("--kernels needs a value (oracle or fast)".to_string());
        }
        if let Some(tier) = args.get("kernels") {
            r.kernel_tier = KernelTier::parse(&tier)
                .ok_or(format!("--kernels: expected oracle or fast, got {tier}"))?;
        }
        r.max_batch = args.get_usize("max-batch", r.max_batch)?;
        r.max_wait_ms = args.get_usize("max-wait-ms", r.max_wait_ms as usize)? as u64;
        r.queue_depth = args.get_usize("queue-depth", r.queue_depth)?;
        r.deadline_ms = args.get_usize("deadline-ms", r.deadline_ms as usize)? as u64;

        // Speculative decoding: `--speculate <preset|path>` selects the
        // draft recipe, `--draft-k` the window, `--no-speculate` strips a
        // speculating base recipe — same policies as every knob above
        // (valueless flags rejected, contradictions are errors, targeted
        // knobs need their enabler).
        let no_spec = args.flag("no-speculate");
        let spec_flag = args.flag("speculate");
        if no_spec && spec_flag {
            return Err("--speculate and --no-speculate are contradictory".to_string());
        }
        if spec_flag && args.get("speculate").is_none() {
            return Err("--speculate needs a value (a preset name or a recipe file)".to_string());
        }
        if args.flag("draft-k") && args.get("draft-k").is_none() {
            return Err("--draft-k needs a value".to_string());
        }
        if no_spec {
            if args.flag("draft-k") {
                return Err("--draft-k has no effect with --no-speculate".to_string());
            }
            r.speculate = None;
        } else if let Some(spec) = args.get("speculate") {
            let draft = QuantRecipe::load(&spec)?;
            let k = args.get_usize(
                "draft-k",
                r.speculate.as_ref().map_or(DEFAULT_DRAFT_K, |s| s.k),
            )?;
            r.speculate = Some(SpeculateConfig { draft: Box::new(draft), k });
        } else if r.speculate.is_some() {
            let sc = r.speculate.as_mut().expect("checked above");
            sc.k = args.get_usize("draft-k", sc.k)?;
        } else if args.flag("draft-k") {
            return Err("--draft-k has no effect without --speculate".to_string());
        }

        // Sampling + sessions: valueless knobs are rejected (same policy
        // as --recipe / --kernels), and the targeted knobs need sampling
        // actually on — `--top-k` under greedy decode would silently do
        // nothing, which is almost certainly a dropped --temperature.
        for knob in ["temperature", "top-k", "top-p", "seed", "max-sessions"] {
            if args.flag(knob) && args.get(knob).is_none() {
                return Err(format!("--{knob} needs a value"));
            }
        }
        r.sampling.temperature = args.get_f32("temperature", r.sampling.temperature)?;
        r.sampling.top_k = args.get_usize("top-k", r.sampling.top_k)?;
        r.sampling.top_p = args.get_f32("top-p", r.sampling.top_p)?;
        r.sampling.seed = args.get_usize("seed", r.sampling.seed as usize)? as u64;
        if r.sampling.is_greedy()
            && (args.flag("top-k") || args.flag("top-p") || args.flag("seed"))
        {
            return Err(
                "--top-k/--top-p/--seed have no effect at temperature 0: add --temperature"
                    .to_string(),
            );
        }
        r.max_sessions = args.get_usize("max-sessions", r.max_sessions)?;

        r.validate().map_err(|e| e.to_string())?;
        Ok(r)
    }
}

/// Canonical, parseable label for a format (`NumericFormat::parse`
/// round-trips every label this emits — asserted by the recipe round-trip
/// tests).
fn format_label(f: NumericFormat) -> String {
    match f {
        NumericFormat::F16 => "f16".to_string(),
        NumericFormat::Fp(fp) => fp.name().to_ascii_lowercase(),
        NumericFormat::Int(i) => i.name().to_ascii_lowercase(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn every_preset_builds_and_is_distinct() {
        let mut seen = Vec::new();
        for name in PRESET_NAMES {
            let r = QuantRecipe::preset(name).unwrap();
            assert_eq!(r.name, name);
            r.validate().unwrap();
            assert!(!seen.contains(&r), "{name} duplicates another preset");
            seen.push(r);
        }
        assert!(matches!(
            QuantRecipe::preset("w2a2"),
            Err(RecipeError::UnknownPreset(_))
        ));
    }

    #[test]
    fn builder_validates_at_construction() {
        let w4 = Scheme::parse("w4a8-fp-fp").unwrap();
        let w16 = Scheme::parse("w16a16").unwrap();
        assert_eq!(
            QuantRecipe::builder(w4).group_size(0).build(),
            Err(RecipeError::GroupSizeZero)
        );
        assert_eq!(
            QuantRecipe::builder(w4)
                .constraint(ScaleConstraint::M2 { rows: 0 })
                .build(),
            Err(RecipeError::M2ZeroRows)
        );
        assert_eq!(
            QuantRecipe::builder(w16).packed(2).build(),
            Err(RecipeError::PackedNeedsCodes)
        );
        assert_eq!(
            QuantRecipe::builder(w16).lorc(LorcConfig::default()).build(),
            Err(RecipeError::LorcNeedsQuantizedWeights)
        );
        assert_eq!(
            QuantRecipe::builder(w4)
                .lorc(LorcConfig { rank: 0, factor_format: NumericFormat::FP8_E4M3 })
                .build(),
            Err(RecipeError::LorcRankZero)
        );
        assert_eq!(
            QuantRecipe::builder(w4)
                .lorc(LorcConfig { rank: 4, factor_format: NumericFormat::INT8 })
                .build(),
            Err(RecipeError::LorcFactorFormatNotFp(NumericFormat::INT8))
        );
        assert_eq!(
            QuantRecipe::builder(w4).max_batch(0).build(),
            Err(RecipeError::MaxBatchZero)
        );
        assert_eq!(
            QuantRecipe::builder(w4).queue_depth(0).build(),
            Err(RecipeError::QueueDepthZero)
        );
        // and the happy path still builds
        QuantRecipe::builder(w4)
            .constraint(ScaleConstraint::M2 { rows: 8 })
            .lorc(LorcConfig::default())
            .packed(2)
            .kv_quant(Some(FpFormat::E4M3))
            .build()
            .unwrap();
    }

    #[test]
    fn engine_opts_view_carries_act_and_layout() {
        let r = QuantRecipe::builder(Scheme::parse("w4a8-fp-fp").unwrap())
            .packed(3)
            .build()
            .unwrap();
        let opts = r.engine_opts();
        assert_eq!(opts.act.format, NumericFormat::FP8_E4M3);
        assert_eq!(opts.weights, WeightLayout::Packed { threads: 3 });
        let d = QuantRecipe::preset("w16").unwrap().engine_opts();
        assert!(d.weights.is_dense());
        assert_eq!(d.act.format, NumericFormat::F16);
    }

    #[test]
    fn from_args_base_defaults_and_overrides() {
        // no flags: the per-command default preset
        let r = QuantRecipe::from_args(&argv(&[]), "w4a8-fp-m2").unwrap();
        assert_eq!(r, QuantRecipe::preset("w4a8-fp-m2").unwrap());
        // --recipe overrides the default; flags override the recipe
        let a = argv(&["--recipe", "w4a8-fp-m2", "--constraint", "m1", "--rtn"]);
        let r = QuantRecipe::from_args(&a, "w16").unwrap();
        assert_eq!(r.constraint, ScaleConstraint::M1);
        assert!(!r.use_gptq);
        assert!(r.cast_fp4_to_e5m2, "unoverridden preset fields survive");
        assert!(a.finish().is_ok());
        // a valueless --recipe must not silently fall back to the default
        // preset — the pin is the whole point of the flag
        assert!(QuantRecipe::from_args(&argv(&["--recipe"]), "w16").is_err());
        assert!(QuantRecipe::from_args(&argv(&["--recipe", "--rtn"]), "w16").is_err());
        // contradictory GPTQ directions are an error, not a silent winner
        assert!(QuantRecipe::from_args(&argv(&["--rtn", "--gptq"]), "w16").is_err());
        assert!(QuantRecipe::from_args(&argv(&["--gptq"]), "w16").unwrap().use_gptq);
        // every boolean knob has a working off-switch (and its pair errors)
        let r = QuantRecipe::from_args(&argv(&["--recipe", "w4a8-fp-m2", "--no-cast"]), "w16");
        assert!(!r.unwrap().cast_fp4_to_e5m2);
        assert!(QuantRecipe::from_args(&argv(&["--cast", "--no-cast"]), "w4a8-fp").is_err());
        assert!(QuantRecipe::from_args(&argv(&["--packed", "--dense"]), "w4a8-fp").is_err());
        // a targeted gemv knob without a packed layout is a dropped flag
        assert!(QuantRecipe::from_args(&argv(&["--gemv-threads", "2"]), "w4a8-fp").is_err());
        assert!(QuantRecipe::from_args(&argv(&["--packed", "--gemv-threads"]), "w4a8-fp")
            .is_err());
    }

    #[test]
    fn from_args_lorc_knob_rules() {
        let base: &[&str] = &["--scheme", "w4a8-fp-fp"];
        let with = |extra: &[&str]| {
            let mut v = base.to_vec();
            v.extend_from_slice(extra);
            QuantRecipe::from_args(&argv(&v), "w16")
        };
        let l = with(&["--lorc", "--lorc-rank", "16", "--lorc-format", "f16"])
            .unwrap()
            .lorc
            .unwrap();
        assert_eq!(l.rank, 16);
        assert_eq!(l.factor_format, NumericFormat::F16);
        // the historical --rank spelling still works (and FP8 E4M3 stays
        // the default factor format)
        let l = with(&["--lorc", "--rank", "4"]).unwrap().lorc.unwrap();
        assert_eq!(l.rank, 4);
        assert_eq!(l.factor_format, NumericFormat::FP8_E4M3);
        // integer factor formats and rank 0 are rejected
        assert!(with(&["--lorc", "--lorc-format", "int8"]).is_err());
        assert!(with(&["--lorc", "--lorc-rank", "0"]).is_err());
        // LoRC knobs without LoRC are a dropped-flag mistake, not a no-op
        // — with a value or bare (the bare form parses as a sentinel flag)
        assert!(with(&["--lorc-rank", "4"]).is_err());
        assert!(with(&["--lorc-format"]).is_err());
        // a valueless knob under --lorc is rejected, not defaulted
        assert!(with(&["--lorc", "--lorc-rank"]).is_err());
        // ...but the bare run (no LoRC flags at all) stays clean
        assert!(with(&[]).unwrap().lorc.is_none());
        // a LoRC base recipe keeps its factors, knobs adjust them without
        // restating --lorc, and --no-lorc strips them
        let a = argv(&["--recipe", "w4a8-fp-lorc", "--lorc-rank", "2"]);
        assert_eq!(QuantRecipe::from_args(&a, "w16").unwrap().lorc.unwrap().rank, 2);
        let a = argv(&["--recipe", "w4a8-fp-lorc", "--no-lorc"]);
        assert!(QuantRecipe::from_args(&a, "w16").unwrap().lorc.is_none());
        let a = argv(&["--lorc", "--no-lorc"]);
        assert!(QuantRecipe::from_args(&a, "w16").is_err());
    }

    #[test]
    fn from_args_constraint_and_serving_knobs() {
        let r = QuantRecipe::from_args(
            &argv(&["--scheme", "w4a8-fp-fp", "--constraint", "m2:16"]),
            "w16",
        )
        .unwrap();
        assert_eq!(r.constraint, ScaleConstraint::M2 { rows: 16 });
        // zero-row compute groups are rejected with a parse error
        assert!(QuantRecipe::from_args(
            &argv(&["--scheme", "w4a8-fp-fp", "--constraint", "m2:0"]),
            "w16"
        )
        .is_err());
        // default stays the paper's 32-row group
        let r = QuantRecipe::from_args(
            &argv(&["--scheme", "w4a8-fp-fp", "--constraint", "m2"]),
            "w16",
        )
        .unwrap();
        assert_eq!(r.constraint, ScaleConstraint::M2 { rows: 32 });
        // packed/kv/batching knobs land in the recipe
        let r = QuantRecipe::from_args(
            &argv(&[
                "--scheme",
                "w4a8-fp-fp",
                "--packed",
                "--gemv-threads",
                "3",
                "--kv-cache",
                "e5m2",
                "--max-batch",
                "4",
                "--max-wait-ms",
                "0",
                "--queue-depth",
                "12",
                "--deadline-ms",
                "250",
            ]),
            "w16",
        )
        .unwrap();
        assert_eq!(r.weights, WeightLayout::Packed { threads: 3 });
        assert_eq!(r.kv_quant, Some(FpFormat::E5M2));
        assert_eq!(r.max_batch, 4);
        assert_eq!(r.max_wait_ms, 0);
        assert_eq!(r.queue_depth, 12);
        assert_eq!(r.deadline_ms, 250);
        // the robustness knobs survive a JSON round trip
        let back = QuantRecipe::from_json(&r.to_json()).unwrap();
        assert_eq!(back, r);
        assert_eq!(back.queue_depth, 12);
        assert_eq!(back.deadline_ms, 250);
        // defaults: a recipe without the knobs keeps the crate defaults
        let idle = QuantRecipe::preset("w16").unwrap();
        assert_eq!(idle.queue_depth, crate::coordinator::DEFAULT_QUEUE_DEPTH);
        assert_eq!(idle.deadline_ms, 0);
        // a zero queue depth is rejected through the flag path too
        assert!(QuantRecipe::from_args(&argv(&["--queue-depth", "0"]), "w16").is_err());
        // an integer cache format is the typed rejection; --kv-cache none
        // clears a base recipe's cache format
        assert!(QuantRecipe::from_args(&argv(&["--kv-cache", "int8"]), "w4a8-fp").is_err());
        let dir = std::env::temp_dir().join("zqfp_recipe_kv");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("kv.json");
        let with_kv = QuantRecipe::builder(Scheme::parse("w4a8-fp-fp").unwrap())
            .kv_quant(Some(FpFormat::E4M3))
            .build()
            .unwrap();
        std::fs::write(&path, with_kv.to_json()).unwrap();
        let a = argv(&["--recipe", path.to_str().unwrap(), "--kv-cache", "none"]);
        assert_eq!(QuantRecipe::from_args(&a, "w16").unwrap().kv_quant, None);
        // packed + W16 is the typed rejection, end to end through flags
        assert!(QuantRecipe::from_args(&argv(&["--packed"]), "w16").is_err());
    }

    #[test]
    fn kernels_knob_flags_json_and_views() {
        // default: every construction path lands on the oracle tier
        let base = QuantRecipe::preset("w4a8-fp").unwrap();
        assert_eq!(base.kernel_tier, KernelTier::Oracle);
        assert_eq!(base.engine_opts().kernels, KernelTier::Oracle);
        // the summary names the tier even at the default — "oracle" must
        // not be ambiguous with "not shown"
        assert!(base.summary().contains("kernels=oracle"));
        // --kernels fast threads through the recipe into the engine opts
        let r = QuantRecipe::from_args(
            &argv(&["--scheme", "w4a8-fp-fp", "--packed", "--kernels", "fast"]),
            "w16",
        )
        .unwrap();
        assert_eq!(r.kernel_tier, KernelTier::Fast);
        assert_eq!(r.engine_opts().kernels, KernelTier::Fast);
        assert!(r.summary().contains("kernels=fast"));
        // the tier survives a JSON round trip field-for-field
        let back = QuantRecipe::from_json(&r.to_json()).unwrap();
        assert_eq!(back, r);
        assert_eq!(back.kernel_tier, KernelTier::Fast);
        // bad values and a valueless flag are rejected, not defaulted
        assert!(QuantRecipe::from_args(&argv(&["--kernels", "turbo"]), "w16").is_err());
        assert!(QuantRecipe::from_args(&argv(&["--kernels"]), "w16").is_err());
        assert!(QuantRecipe::from_json(r#"{"kernels":"turbo"}"#).is_err());
        // explicit oracle is accepted and is the same as the default
        let r = QuantRecipe::from_args(&argv(&["--kernels", "oracle"]), "w16").unwrap();
        assert_eq!(r.kernel_tier, KernelTier::Oracle);
    }

    #[test]
    fn kv_paging_knob_flags_json_and_views() {
        // default: rings everywhere, no budget, no paged summary tag
        let base = QuantRecipe::preset("w4a8-fp").unwrap();
        assert_eq!(base.kv_page_positions, 0);
        assert_eq!(base.kv_budget_bytes, 0);
        assert!(!base.summary().contains("paged"));
        // --kv-page / --kv-budget thread through to the coordinator view
        let r = QuantRecipe::from_args(
            &argv(&["--kv-page", "16", "--kv-budget", "65536"]),
            "w4a8-fp",
        )
        .unwrap();
        assert_eq!(r.kv_page_positions, 16);
        assert_eq!(r.kv_budget_bytes, 65536);
        assert!(r.summary().contains("paged:16/65536B"));
        // and survive a JSON round trip field-for-field
        let back = QuantRecipe::from_json(&r.to_json()).unwrap();
        assert_eq!(back, r);
        // budget without paging is the typed rejection, on every path
        assert_eq!(
            QuantRecipe::builder(Scheme::parse("w4a8-fp-fp").unwrap())
                .kv_budget(4096)
                .build(),
            Err(RecipeError::KvBudgetNeedsPaging)
        );
        assert!(QuantRecipe::from_args(&argv(&["--kv-budget", "4096"]), "w4a8-fp").is_err());
        assert!(QuantRecipe::from_json(r#"{"kv_budget_bytes":4096}"#).is_err());
        // valueless knobs are rejected, not defaulted
        assert!(QuantRecipe::from_args(&argv(&["--kv-page"]), "w4a8-fp").is_err());
        assert!(QuantRecipe::from_args(&argv(&["--kv-page", "8", "--kv-budget"]), "w4a8-fp")
            .is_err());
        // paging without a budget is fine (auto ring-equivalent bound)
        let r = QuantRecipe::from_args(&argv(&["--kv-page", "8"]), "w4a8-fp").unwrap();
        assert_eq!(r.kv_page_positions, 8);
        assert_eq!(r.kv_budget_bytes, 0);
        assert!(r.summary().contains("paged:8"));
    }

    #[test]
    fn sampling_and_session_knob_flags_json_and_views() {
        use crate::coordinator::SamplingConfig;
        // default: greedy decode, default LRU bound, no summary tags
        let base = QuantRecipe::preset("w4a8-fp").unwrap();
        assert!(base.sampling.is_greedy());
        assert_eq!(base.max_sessions, crate::coordinator::DEFAULT_MAX_SESSIONS);
        assert!(!base.summary().contains("sample"));
        // the serve flags thread through
        let r = QuantRecipe::from_args(
            &argv(&[
                "--temperature",
                "0.8",
                "--top-k",
                "40",
                "--top-p",
                "0.95",
                "--seed",
                "7",
                "--max-sessions",
                "4",
            ]),
            "w4a8-fp",
        )
        .unwrap();
        assert_eq!(
            r.sampling,
            SamplingConfig { temperature: 0.8, top_k: 40, top_p: 0.95, seed: 7 }
        );
        assert_eq!(r.max_sessions, 4);
        assert!(r.summary().contains("sample T=0.8 k=40 p=0.95 seed=7"));
        assert!(r.summary().contains("sessions 4"));
        // and survive a JSON round trip field-for-field
        let back = QuantRecipe::from_json(&r.to_json()).unwrap();
        assert_eq!(back, r);
        // targeted knobs without sampling on are almost certainly a
        // dropped --temperature — rejected, not silently inert
        assert!(QuantRecipe::from_args(&argv(&["--top-k", "5"]), "w4a8-fp").is_err());
        assert!(QuantRecipe::from_args(&argv(&["--seed", "3"]), "w4a8-fp").is_err());
        // valueless knobs are rejected, not defaulted
        assert!(QuantRecipe::from_args(&argv(&["--temperature"]), "w4a8-fp").is_err());
        assert!(QuantRecipe::from_args(
            &argv(&["--temperature", "0.5", "--top-k"]),
            "w4a8-fp"
        )
        .is_err());
        // range validation is the same typed error on every path
        let mut bad = base.clone();
        bad.sampling.temperature = -1.0;
        assert_eq!(bad.validate(), Err(RecipeError::SamplingTemperatureInvalid));
        let mut bad = base.clone();
        bad.sampling.top_p = 0.0;
        assert_eq!(bad.validate(), Err(RecipeError::SamplingTopPInvalid));
        let mut bad = base.clone();
        bad.max_sessions = 0;
        assert_eq!(bad.validate(), Err(RecipeError::MaxSessionsZero));
        assert!(QuantRecipe::from_args(&argv(&["--temperature", "-2"]), "w4a8-fp").is_err());
        assert!(QuantRecipe::from_json(r#"{"sampling":{"top_p":1.5}}"#).is_err());
        assert!(QuantRecipe::from_json(r#"{"max_sessions":0}"#).is_err());
        // unknown nested keys are rejected like any other
        assert!(QuantRecipe::from_json(r#"{"sampling":{"temp":1}}"#).is_err());
        // a sampling recipe cannot speculate: the parity contract is greedy
        let cheap = QuantRecipe::preset("w4a8-fp").unwrap();
        let mut r = QuantRecipe::preset("w4a8-fp-lorc").unwrap();
        r.speculate = Some(SpeculateConfig { draft: Box::new(cheap), k: 2 });
        r.sampling.temperature = 0.7;
        assert_eq!(r.validate(), Err(RecipeError::SpeculateNeedsGreedy));
        assert!(QuantRecipe::from_args(
            &argv(&["--speculate", "w4a8-fp", "--temperature", "0.7"]),
            "w4a8-fp-lorc"
        )
        .is_err());
    }

    #[test]
    fn speculate_validation_rules() {
        let target = QuantRecipe::preset("w4a8-fp-lorc").unwrap();
        let cheap = QuantRecipe::preset("w4a8-fp").unwrap();
        // the happy path: rank-0 draft under a LoRC target
        let mut r = target.clone();
        r.speculate = Some(SpeculateConfig { draft: Box::new(cheap.clone()), k: 4 });
        r.validate().unwrap();
        // k = 0 is rejected
        r.speculate.as_mut().unwrap().k = 0;
        assert_eq!(r.validate(), Err(RecipeError::SpeculateKZero));
        // a draft identical to the target can only add overhead
        let mut r = cheap.clone();
        r.speculate = Some(SpeculateConfig { draft: Box::new(cheap.clone()), k: 2 });
        assert_eq!(r.validate(), Err(RecipeError::SpeculateDraftNotCheaper));
        // ...but the same bits with a pure speed win (packed layout or
        // fast kernels) is a legitimate draft
        let mut packed_fast = cheap.clone();
        packed_fast.weights = WeightLayout::Packed { threads: 1 };
        packed_fast.kernel_tier = KernelTier::Fast;
        let mut r = cheap.clone();
        r.speculate = Some(SpeculateConfig { draft: Box::new(packed_fast), k: 2 });
        r.validate().unwrap();
        // a draft heavier than the target is rejected (w16 drafting w4)
        let mut r = cheap.clone();
        r.speculate =
            Some(SpeculateConfig { draft: Box::new(QuantRecipe::preset("w16").unwrap()), k: 2 });
        assert_eq!(r.validate(), Err(RecipeError::SpeculateDraftNotCheaper));
        // a packed draft under a W16 target has no codes to pack
        let mut packed = cheap.clone();
        packed.weights = WeightLayout::Packed { threads: 1 };
        let mut r = QuantRecipe::preset("w16").unwrap();
        r.speculate = Some(SpeculateConfig { draft: Box::new(packed), k: 2 });
        assert_eq!(r.validate(), Err(RecipeError::SpeculateDraftNeedsTargetCodes));
        // one level of speculation only
        let mut nested = cheap.clone();
        nested.speculate = Some(SpeculateConfig {
            draft: Box::new(QuantRecipe::preset("w4a8-fp-m1").unwrap()),
            k: 1,
        });
        let mut r = target.clone();
        r.speculate = Some(SpeculateConfig { draft: Box::new(nested), k: 2 });
        assert_eq!(r.validate(), Err(RecipeError::SpeculateNested));
        // an invalid draft recipe surfaces as the wrapped error
        let mut broken = cheap.clone();
        broken.group_size = 0;
        let mut r = target.clone();
        r.speculate = Some(SpeculateConfig { draft: Box::new(broken), k: 2 });
        assert_eq!(
            r.validate(),
            Err(RecipeError::SpeculateDraft(Box::new(RecipeError::GroupSizeZero)))
        );
    }

    #[test]
    fn speculate_json_flags_and_summary() {
        // full JSON round trip with a nested draft document
        let mut r = QuantRecipe::preset("w4a8-fp-lorc").unwrap();
        let mut draft = QuantRecipe::preset("w4a8-fp").unwrap();
        draft.weights = WeightLayout::Packed { threads: 2 };
        draft.kernel_tier = KernelTier::Fast;
        r.speculate = Some(SpeculateConfig { draft: Box::new(draft), k: 3 });
        r.validate().unwrap();
        let back = QuantRecipe::from_json(&r.to_json()).unwrap();
        assert_eq!(back, r);
        // a preset name is accepted as draft shorthand
        let short = QuantRecipe::from_json(
            r#"{"weight":"fp4_e2m1","act":"fp8_e4m3","lorc":{"rank":8},
                "speculate":{"draft":"w4a8-fp","k":2}}"#,
        )
        .unwrap();
        assert_eq!(short.speculate.as_ref().unwrap().k, 2);
        assert_eq!(short.speculate.as_ref().unwrap().draft.name, "w4a8-fp");
        // k defaults when absent; unknown nested keys are rejected
        let d = QuantRecipe::from_json(
            r#"{"lorc":{"rank":8},"speculate":{"draft":"w4a8-fp"}}"#,
        )
        .unwrap();
        assert_eq!(d.speculate.unwrap().k, DEFAULT_DRAFT_K);
        assert!(QuantRecipe::from_json(r#"{"speculate":{"draft":"w4a8-fp","kk":2}}"#).is_err());
        assert!(QuantRecipe::from_json(r#"{"speculate":{"k":2}}"#).is_err());
        assert!(QuantRecipe::from_json(r#"{"speculate":"w4a8-fp"}"#).is_err());
        // the flag path: --speculate / --draft-k / --no-speculate
        let a = argv(&["--recipe", "w4a8-fp-lorc", "--speculate", "w4a8-fp", "--draft-k", "2"]);
        let r = QuantRecipe::from_args(&a, "w16").unwrap();
        let sc = r.speculate.as_ref().unwrap();
        assert_eq!((sc.draft.name.as_str(), sc.k), ("w4a8-fp", 2));
        assert!(a.finish().is_ok(), "speculate knobs are consumed");
        assert!(r.summary().contains("speculate=w4a8-fp/k2"));
        // --draft-k defaults to 4 when --speculate is given alone
        let a = argv(&["--recipe", "w4a8-fp-lorc", "--speculate", "w4a8-fp"]);
        assert_eq!(QuantRecipe::from_args(&a, "w16").unwrap().speculate.unwrap().k, 4);
        // knob rules: valueless, contradictory, targeted-without-enabler
        assert!(QuantRecipe::from_args(&argv(&["--speculate"]), "w16").is_err());
        assert!(QuantRecipe::from_args(&argv(&["--draft-k", "2"]), "w16").is_err());
        assert!(QuantRecipe::from_args(
            &argv(&["--speculate", "w4a8-fp", "--no-speculate"]),
            "w4a8-fp-lorc"
        )
        .is_err());
        assert!(QuantRecipe::from_args(
            &argv(&["--speculate", "w4a8-fp", "--draft-k"]),
            "w4a8-fp-lorc"
        )
        .is_err());
        // --no-speculate strips a speculating base recipe
        let dir = std::env::temp_dir().join("zqfp_recipe_spec");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("spec.json");
        let mut speculating = QuantRecipe::preset("w4a8-fp-lorc").unwrap();
        speculating.speculate = Some(SpeculateConfig {
            draft: Box::new(QuantRecipe::preset("w4a8-fp").unwrap()),
            k: 4,
        });
        std::fs::write(&path, speculating.to_json()).unwrap();
        let a = argv(&["--recipe", path.to_str().unwrap(), "--no-speculate"]);
        assert!(QuantRecipe::from_args(&a, "w16").unwrap().speculate.is_none());
        // ...and --draft-k alone adjusts the base recipe's window
        let a = argv(&["--recipe", path.to_str().unwrap(), "--draft-k", "1"]);
        assert_eq!(QuantRecipe::from_args(&a, "w16").unwrap().speculate.unwrap().k, 1);
    }

    #[test]
    fn load_resolves_presets_and_files() {
        let r = QuantRecipe::load("w8a8-int").unwrap();
        assert_eq!(r.name, "w8a8-int");
        let dir = std::env::temp_dir().join("zqfp_recipe_unit");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("r.json");
        std::fs::write(&path, QuantRecipe::preset("w4a8-fp-lorc").unwrap().to_json()).unwrap();
        let from_file = QuantRecipe::load(path.to_str().unwrap()).unwrap();
        assert_eq!(from_file, QuantRecipe::preset("w4a8-fp-lorc").unwrap());
        assert!(QuantRecipe::load("/nonexistent/nope.json").is_err());
    }
}
