//! Per-sequence K/V storage — the state that makes decode incremental —
//! in two layouts: a contiguous per-sequence **ring** and a block-**paged**
//! layout backed by a shared [`KvPagePool`].
//!
//! # Why
//!
//! [`CompiledModel::forward`](super::CompiledModel::forward) recomputes
//! attention over the **entire** token window for every forward call:
//! generating one token after `n` costs `O(n²·d)` in attention alone. The
//! serving decode loop instead carries a [`KvCache`] and calls
//! [`prefill`](super::CompiledModel::prefill) once per prompt and
//! [`decode_step`](super::CompiledModel::decode_step) once per generated
//! token — each step computes the q/k/v projections for the *new* position
//! only and attends against the cached keys/values, `O(n·d)` per token.
//!
//! # Ring layout
//!
//! One ring per layer, two matrices per ring:
//!
//! ```text
//!   k[layer]: [max_seq, d_model]   row p = key   vector of position p
//!   v[layer]: [max_seq, d_model]   row p = value vector of position p
//! ```
//!
//! Rows are stored head-interleaved exactly as the fused q|k|v projection
//! emits them (head `h` occupies columns `h·dh .. (h+1)·dh`), so the cached
//! attention kernel walks the same unit-stride slices as the full-recompute
//! kernel — this is what makes the bit-equivalence contract (below) cheap.
//!
//! Every ring buffer is allocated once at construction and sized to the
//! model's `max_seq`; appending rows and [`reset`](KvCache::reset) never
//! touch the heap, so the serving loop's steady state stays allocation-free
//! (asserted by `tests/plan_alloc.rs`).
//!
//! # Paged layout
//!
//! A ring pins `max_seq × d_model` per layer for the whole life of a
//! sequence, so resident serving memory is `max_batch × max_seq` even when
//! prompts are short. The paged layout instead stores position `p` in row
//! `p % P` of page `p / P`, where a **page** ([`PageBuf`]) holds `P`
//! positions × `d_model` for *every* layer, and a sequence's page list **is**
//! its page table (pages in position order). Pages come from a
//! [`KvPagePool`]: all pages are allocated eagerly at pool construction from
//! a byte budget and recycle through a free list, so resident bytes scale
//! with tokens actually live and steady-state page churn performs zero heap
//! allocations (`tests/plan_alloc.rs` extends the counting-allocator
//! contract to reserve/release cycles).
//!
//! Within a row both layouts are byte-identical — same head-interleaved
//! `d_model` slice, same [`FpQuantLut`] quantization on append, same
//! per-position attention walk ([`KvLayerView`] only redirects *which*
//! buffer a row lives in, never the arithmetic over it) — which is why
//! paged prefill+decode is bit-identical to the ring plan
//! (`tests/kv_paged.rs`).
//!
//! # Eviction and reset rules
//!
//! Capacity is bounded by `max_seq` — the hard window of the learned
//! position table — so a *single* sequence can never overflow it: the write
//! cursor advances from 0 to at most `max_seq` and `prefill`/`decode_step`
//! assert before ever wrapping a live sequence (evicting position 0
//! mid-sequence would silently change attention semantics, and the position
//! table has no row to give the overflowing token anyway). Eviction is
//! therefore always *whole-sequence*: [`reset`](KvCache::reset) rewinds the
//! cursor to slot 0 and the next sequence lazily overwrites the stale rows —
//! no zeroing pass. The serving coordinator keeps finished sequences' caches
//! in a bounded free pool and recycles them via `reset`; paged caches
//! additionally return their pages to the pool via
//! [`KvPagePool::release`] (see `coordinator/`).
//!
//! # Quarantine and page leaks
//!
//! A panic that unwinds out of a layer walk leaves staged rows in an
//! unknown state, so the coordinator [`quarantine`](KvCache::quarantine)s
//! the cache (sticky — `reset` does not clear it). Releasing a quarantined
//! *paged* cache deliberately **leaks exactly its own pages**: the buffers
//! are dropped rather than recycled (a later sequence must never decode
//! through them) and the pool counts them in
//! [`leaked_pages`](KvPagePool::leaked_pages) so accounting stays balanced:
//! `free + resident + leaked == total`, always.
//!
//! # FP8 quantization (the paper's formats, applied to the cache)
//!
//! [`KvCache::quantized`] (and [`KvPagePool::new`] with a format) stores
//! every appended K/V row through the same [`FpQuantLut`] fast path the A8
//! activation hot loop uses: one absmax scan + LUT quantize per row
//! (token-wise scaling, exactly `NumericFormat::fake_quant_slice_dynamic`
//! semantics). This halves the dominant serving memory stream the way
//! ZeroQuant-FP's W4A8 formats are meant to be deployed, at the cost of
//! leaving the bit-equivalence contract: a quantized cache is **not**
//! bit-identical to full-recompute `forward` (the reference keeps exact f32
//! K/V). What it *does* keep is split-invariance — where the prompt/decode
//! boundary falls cannot change the logits, because rows are quantized
//! independently of when they were appended (`tests/kv_equivalence.rs`
//! asserts both properties). Note fake-quant stores f32 either way, so page
//! byte accounting is always `4` bytes per element.

use super::FpQuantLut;
use crate::formats::FpFormat;
use crate::model::ModelConfig;
use crate::tensor::Matrix;

/// One fixed-size block of K/V storage: `P` positions × `d_model` for
/// every layer. The unit of allocation, recycling and leakage in a
/// [`KvPagePool`].
#[derive(Debug, Clone)]
pub struct PageBuf {
    /// Per-layer key rows `[page_positions, d_model]`.
    k: Vec<Matrix>,
    /// Per-layer value rows `[page_positions, d_model]`.
    v: Vec<Matrix>,
}

impl PageBuf {
    fn new(n_layers: usize, positions: usize, d_model: usize) -> PageBuf {
        PageBuf {
            k: (0..n_layers).map(|_| Matrix::zeros(positions, d_model)).collect(),
            v: (0..n_layers).map(|_| Matrix::zeros(positions, d_model)).collect(),
        }
    }
}

/// The two storage layouts behind a [`KvCache`]. The cursor/staging
/// contract is identical for both; only row addressing differs.
#[derive(Debug, Clone)]
enum Store {
    /// Contiguous per-layer rings sized to `max_seq`.
    Ring {
        /// Per-layer key rows `[capacity, d_model]`.
        k: Vec<Matrix>,
        /// Per-layer value rows `[capacity, d_model]`.
        v: Vec<Matrix>,
    },
    /// Block-paged: position `p` lives in row `p % page_positions` of
    /// `pages[p / page_positions]`. The Vec **is** the page table; pages
    /// are owned here (checked out of a [`KvPagePool`]) so the plan's
    /// layer walk needs no pool access.
    Paged { page_positions: usize, pages: Vec<PageBuf> },
}

/// Per-sequence K/V storage (ring or paged). See the module docs for
/// layout, reset/eviction rules and the quantization contract.
#[derive(Debug, Clone)]
pub struct KvCache {
    /// Positions currently storable: ring capacity, or reserved pages × P.
    capacity: usize,
    /// Valid positions: rows `0..len` hold live K/V.
    len: usize,
    store: Store,
    /// `Some` ⇒ every stored row is token-wise fake-quantized on append.
    quant: Option<FpQuantLut>,
    /// Sticky poison flag: a cache whose layer walk panicked mid-flight
    /// must never serve another sequence (see
    /// [`quarantine`](Self::quarantine)).
    quarantined: bool,
}

impl KvCache {
    /// An exact (f32) ring cache: decode through it is bit-identical to
    /// `CompiledModel::forward` over the same window.
    pub fn new(cfg: &ModelConfig) -> KvCache {
        KvCache::build(cfg, None)
    }

    /// A ring cache that fake-quantizes every stored K/V row to `fmt`
    /// (token-wise absmax scaling through the LUT fast path).
    pub fn quantized(cfg: &ModelConfig, fmt: FpFormat) -> KvCache {
        KvCache::build(cfg, Some(FpQuantLut::new(fmt)))
    }

    fn build(cfg: &ModelConfig, quant: Option<FpQuantLut>) -> KvCache {
        let capacity = cfg.max_seq;
        let d = cfg.d_model;
        KvCache {
            capacity,
            len: 0,
            store: Store::Ring {
                k: (0..cfg.n_layers).map(|_| Matrix::zeros(capacity, d)).collect(),
                v: (0..cfg.n_layers).map(|_| Matrix::zeros(capacity, d)).collect(),
            },
            quant,
            quarantined: false,
        }
    }

    /// Number of cached positions (the next token decodes at this position).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Positions currently storable. For a ring this is the model's
    /// `max_seq`; for a paged cache it is reserved pages × page size and
    /// grows/shrinks with [`KvPagePool::reserve`] / [`release`](KvPagePool::release).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Positions still available before reserved storage is full.
    pub fn remaining(&self) -> usize {
        self.capacity - self.len
    }

    /// The storage format of appended rows (`None` = exact f32).
    pub fn quant_format(&self) -> Option<FpFormat> {
        self.quant.as_ref().map(|lut| lut.format())
    }

    /// `true` if this cache stores positions in pool pages rather than a
    /// private ring.
    pub fn is_paged(&self) -> bool {
        matches!(self.store, Store::Paged { .. })
    }

    /// Pages currently held (always 0 for a ring cache).
    pub fn pages_held(&self) -> usize {
        match &self.store {
            Store::Ring { .. } => 0,
            Store::Paged { pages, .. } => pages.len(),
        }
    }

    /// Rewind the write cursor to slot 0, invalidating every cached
    /// position. Stale rows are overwritten lazily by the next sequence —
    /// no zeroing pass, no allocation. A paged cache keeps its reserved
    /// pages; return them with [`KvPagePool::release`] instead if the
    /// sequence is done.
    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Roll the write cursor back to `new_len` positions, invalidating
    /// every later position — the speculative-decode rejection path
    /// (`plan/speculate.rs`): draft continuations past the accepted prefix
    /// are discarded and the next append overwrites them lazily, exactly
    /// like [`reset`](Self::reset) but partial. Storage is untouched, so
    /// positions `0..new_len` keep serving attention bit-for-bit.
    ///
    /// A paged cache keeps every reserved page (capacity is unchanged);
    /// use [`KvPagePool::truncate`] instead to also return now-empty
    /// trailing pages to the pool.
    pub fn truncate(&mut self, new_len: usize) {
        assert!(new_len <= self.len, "truncate({new_len}) past len {}", self.len);
        assert!(!self.quarantined, "truncate() on a quarantined cache");
        self.len = new_len;
    }

    /// Mark this cache poisoned. A panic that unwinds out of a layer walk
    /// leaves the walk's staged rows in an unknown state; the serving
    /// coordinator quarantines such a cache so a later sequence cannot
    /// decode through it — a ring is dropped, a paged cache's pages are
    /// leaked by [`KvPagePool::release`]. Sticky: [`reset`](Self::reset)
    /// does **not** clear it, and the plan's decode entry points assert
    /// against quarantined caches.
    pub fn quarantine(&mut self) {
        self.quarantined = true;
    }

    pub fn is_quarantined(&self) -> bool {
        self.quarantined
    }

    /// Store the K/V rows of one position in one layer (quantizing if
    /// configured). Does **not** advance the cursor: callers stage every
    /// layer's rows for a token first and [`advance`](Self::advance) once.
    pub(super) fn store(&mut self, layer: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert!(pos < self.capacity, "kv store past reserved capacity");
        let (kr, vr): (&mut [f32], &mut [f32]) = match &mut self.store {
            Store::Ring { k, v } => (k[layer].row_mut(pos), v[layer].row_mut(pos)),
            Store::Paged { page_positions, pages } => {
                let page = &mut pages[pos / *page_positions];
                let row = pos % *page_positions;
                (page.k[layer].row_mut(row), page.v[layer].row_mut(row))
            }
        };
        kr.copy_from_slice(k_row);
        vr.copy_from_slice(v_row);
        if let Some(lut) = self.quant.as_ref() {
            lut.fake_quant_row(kr);
            lut.fake_quant_row(vr);
        }
    }

    /// A read view over one layer's K/V rows; positions `0..len()` are live
    /// (plus any rows staged by [`store`](Self::store) ahead of the
    /// cursor).
    pub(super) fn layer(&self, layer: usize) -> KvLayerView<'_> {
        match &self.store {
            Store::Ring { k, v } => KvLayerView::Ring { k: &k[layer], v: &v[layer] },
            Store::Paged { page_positions, pages } => {
                KvLayerView::Paged { pages, layer, page_positions: *page_positions }
            }
        }
    }

    /// Commit `n` staged positions.
    pub(super) fn advance(&mut self, n: usize) {
        self.len += n;
        debug_assert!(self.len <= self.capacity, "kv cache overfull");
    }
}

/// A borrowed view of one layer's cached K/V rows, independent of storage
/// layout. The attention kernel reads rows exclusively through
/// [`k_row`](Self::k_row)/[`v_row`](Self::v_row), so relocating a row into
/// a page cannot change any arithmetic over it — the foundation of the
/// paged-≡-ring bit-equivalence contract.
#[derive(Clone, Copy)]
pub(super) enum KvLayerView<'a> {
    Ring { k: &'a Matrix, v: &'a Matrix },
    Paged { pages: &'a [PageBuf], layer: usize, page_positions: usize },
}

impl<'a> KvLayerView<'a> {
    /// The key row of position `j` (head-interleaved, `d_model` wide).
    #[inline(always)]
    pub(super) fn k_row(&self, j: usize) -> &'a [f32] {
        match self {
            KvLayerView::Ring { k, .. } => k.row(j),
            KvLayerView::Paged { pages, layer, page_positions } => {
                pages[j / page_positions].k[*layer].row(j % page_positions)
            }
        }
    }

    /// The value row of position `j` (head-interleaved, `d_model` wide).
    #[inline(always)]
    pub(super) fn v_row(&self, j: usize) -> &'a [f32] {
        match self {
            KvLayerView::Ring { v, .. } => v.row(j),
            KvLayerView::Paged { pages, layer, page_positions } => {
                pages[j / page_positions].v[*layer].row(j % page_positions)
            }
        }
    }
}

/// A shared pool of fixed-size K/V pages plus the accounting that makes a
/// byte budget enforceable: every page the pool ever owned is either on
/// the free list, resident in some sequence's cache, or leaked by a
/// quarantine — `free + resident + leaked == total`, always.
///
/// All pages are allocated eagerly at construction (clamped up so at least
/// one `max_seq` sequence always fits); [`reserve`](Self::reserve) and
/// [`release`](Self::release) only move `PageBuf`s between the free list
/// and caches, so steady-state page churn never touches the heap.
#[derive(Debug)]
pub struct KvPagePool {
    /// Recycled pages ready for checkout.
    free: Vec<PageBuf>,
    /// Pages allocated at construction (the budget, in pages).
    total_pages: usize,
    /// Pages permanently lost to quarantined caches.
    leaked: usize,
    /// High-water mark of checked-out (resident) pages.
    peak_resident: usize,
    /// Positions per page (`P`).
    page_positions: usize,
    n_layers: usize,
    d_model: usize,
    max_seq: usize,
    /// `Some` ⇒ caches minted by this pool quantize rows on append.
    quant: Option<FpFormat>,
}

impl KvPagePool {
    /// Build a pool of `P`-position pages holding as many whole pages as
    /// `budget_bytes` buys, clamped up so one full `max_seq` sequence
    /// always fits (`budget_bytes == 0` ⇒ exactly that minimum). `quant`
    /// selects FP8 fake-quant on append for every cache the pool mints.
    pub fn new(
        cfg: &ModelConfig,
        page_positions: usize,
        budget_bytes: usize,
        quant: Option<FpFormat>,
    ) -> KvPagePool {
        KvPagePool::sized_for(cfg, page_positions, budget_bytes, quant, 1)
    }

    /// Like [`new`](Self::new), but clamp the budget up so `min_sequences`
    /// concurrent `max_seq` sequences always fit (each cache rounds its
    /// page count up independently, so the clamp is per-sequence, not on
    /// the position sum). Speculative serving uses `min_sequences = 2`:
    /// every in-flight sequence carries a draft cache *and* a target
    /// cache, and admission must never deadlock on the second cache.
    pub fn sized_for(
        cfg: &ModelConfig,
        page_positions: usize,
        budget_bytes: usize,
        quant: Option<FpFormat>,
        min_sequences: usize,
    ) -> KvPagePool {
        assert!(page_positions > 0, "page size must be at least one position");
        let mut pool = KvPagePool {
            free: Vec::new(),
            total_pages: 0,
            leaked: 0,
            peak_resident: 0,
            page_positions,
            n_layers: cfg.n_layers,
            d_model: cfg.d_model,
            max_seq: cfg.max_seq,
            quant,
        };
        let min_pages = pool.pages_for(cfg.max_seq) * min_sequences.max(1);
        let total = (budget_bytes / pool.page_bytes()).max(min_pages);
        pool.free =
            (0..total).map(|_| PageBuf::new(cfg.n_layers, page_positions, cfg.d_model)).collect();
        pool.total_pages = total;
        pool
    }

    /// Positions per page (`P`).
    pub fn page_positions(&self) -> usize {
        self.page_positions
    }

    /// Pages needed to hold `positions` (ceiling division; 0 ⇒ 0).
    pub fn pages_for(&self, positions: usize) -> usize {
        positions.div_ceil(self.page_positions)
    }

    /// Bytes of one page: `n_layers × 2 (K,V) × P × d_model × 4`. Storage
    /// is f32 even under FP8 fake-quant.
    pub fn page_bytes(&self) -> usize {
        self.n_layers * 2 * self.page_positions * self.d_model * std::mem::size_of::<f32>()
    }

    pub fn total_pages(&self) -> usize {
        self.total_pages
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// Pages currently checked out into live caches.
    pub fn resident_pages(&self) -> usize {
        self.total_pages - self.free.len() - self.leaked
    }

    /// Pages permanently lost to quarantined caches.
    pub fn leaked_pages(&self) -> usize {
        self.leaked
    }

    /// High-water mark of [`resident_pages`](Self::resident_pages).
    pub fn peak_resident_pages(&self) -> usize {
        self.peak_resident
    }

    pub fn total_bytes(&self) -> usize {
        self.total_pages * self.page_bytes()
    }

    pub fn resident_bytes(&self) -> usize {
        self.resident_pages() * self.page_bytes()
    }

    pub fn peak_resident_bytes(&self) -> usize {
        self.peak_resident * self.page_bytes()
    }

    /// `true` if `positions` more positions' worth of pages can be
    /// checked out right now.
    pub fn can_reserve(&self, positions: usize) -> bool {
        self.pages_for(positions) <= self.free.len()
    }

    /// Mint an empty paged cache bound to this pool's page size and quant
    /// format. The page-table Vec is pre-sized for a full `max_seq`
    /// sequence so later [`reserve`](Self::reserve) pushes never
    /// reallocate; holds no pages until reserved.
    pub fn new_cache(&self) -> KvCache {
        let table_slots = self.pages_for(self.max_seq);
        KvCache {
            capacity: 0,
            len: 0,
            store: Store::Paged {
                page_positions: self.page_positions,
                pages: Vec::with_capacity(table_slots),
            },
            quant: self.quant.map(FpQuantLut::new),
            quarantined: false,
        }
    }

    /// Ensure `cache` can store `positions` more rows past its current
    /// `len()`, checking out pages from the free list as needed. Returns
    /// `false` — taking nothing — if the free list cannot cover the
    /// shortfall (the caller preempts or requeues). All-or-nothing, never
    /// allocates.
    pub fn reserve(&mut self, cache: &mut KvCache, positions: usize) -> bool {
        let pages = match &mut cache.store {
            Store::Ring { .. } => panic!("reserve() on a ring cache"),
            Store::Paged { pages, .. } => pages,
        };
        let needed_pages = self.pages_for(cache.len + positions);
        let shortfall = needed_pages.saturating_sub(pages.len());
        if shortfall > self.free.len() {
            return false;
        }
        for _ in 0..shortfall {
            pages.push(self.free.pop().expect("shortfall checked against free list"));
        }
        cache.capacity = pages.len() * self.page_positions;
        self.peak_resident = self.peak_resident.max(self.resident_pages());
        true
    }

    /// Roll `cache` back to `new_len` positions and return every trailing
    /// page that no longer holds a live position to the free list — the
    /// paged form of [`KvCache::truncate`], used by the speculative-decode
    /// rejection path. Pages `0..pages_for(new_len)` stay checked out (the
    /// last may be partially filled; its stale tail rows are overwritten
    /// lazily); accounting stays balanced because pages only move between
    /// the cache and the free list.
    pub fn truncate(&mut self, cache: &mut KvCache, new_len: usize) {
        assert!(!cache.quarantined, "truncate() on a quarantined cache");
        assert!(new_len <= cache.len, "truncate({new_len}) past len {}", cache.len);
        let pages = match &mut cache.store {
            Store::Ring { .. } => panic!("pool truncate() on a ring cache"),
            Store::Paged { pages, .. } => pages,
        };
        let keep = self.pages_for(new_len);
        while pages.len() > keep {
            self.free.push(pages.pop().expect("len checked above"));
        }
        cache.capacity = pages.len() * self.page_positions;
        cache.len = new_len;
    }

    /// Duplicate `src` into a fresh cache of this pool: reserve exactly
    /// the pages its live positions occupy and copy their rows byte-for-
    /// byte (no re-quantization — stored rows are already through the
    /// LUT, and a fork must be bit-identical to its source). Returns
    /// `None` — taking nothing — when the free list cannot cover the
    /// copy; the caller falls back to dropping the fork's cache and
    /// re-prefilling on first touch, exactly like an evicted session.
    ///
    /// This is the session `fork` primitive. Pages are *copied*, not
    /// refcount-shared: true copy-on-write prefix sharing across the pool
    /// is ROADMAP item 2 and must not pre-empt its `free + resident +
    /// leaked == total` bookkeeping here — a fork's pages are ordinary
    /// resident pages that release like any other.
    pub fn fork_cache(&mut self, src: &KvCache) -> Option<KvCache> {
        assert!(!src.quarantined, "fork_cache() on a quarantined cache");
        let src_pages = match &src.store {
            Store::Ring { .. } => panic!("fork_cache() on a ring cache (Clone it instead)"),
            Store::Paged { pages, .. } => pages,
        };
        let mut dst = self.new_cache();
        if !self.reserve(&mut dst, src.len) {
            return None;
        }
        let dst_pages = match &mut dst.store {
            Store::Ring { .. } => unreachable!("new_cache mints paged caches"),
            Store::Paged { pages, .. } => pages,
        };
        // dst holds pages_for(src.len) pages; src may hold more (reserved
        // ahead of its cursor) — zip stops at the live prefix, and stale
        // tail rows within the last page copy harmlessly.
        for (d, s) in dst_pages.iter_mut().zip(src_pages.iter()) {
            for layer in 0..self.n_layers {
                for r in 0..self.page_positions {
                    d.k[layer].row_mut(r).copy_from_slice(s.k[layer].row(r));
                    d.v[layer].row_mut(r).copy_from_slice(s.v[layer].row(r));
                }
            }
        }
        dst.len = src.len;
        Some(dst)
    }

    /// Take back every page `cache` holds and rewind it to empty, leaving
    /// the husk (page-table Vec capacity, quant LUT) recyclable. Pages
    /// from a healthy cache return to the free list; pages from a
    /// **quarantined** cache are dropped and counted as leaked — they must
    /// never store another sequence, and only the poisoned sequence's own
    /// pages are lost.
    pub fn release(&mut self, cache: &mut KvCache) {
        let pages = match &mut cache.store {
            Store::Ring { .. } => panic!("release() on a ring cache"),
            Store::Paged { pages, .. } => pages,
        };
        if cache.quarantined {
            self.leaked += pages.len();
            pages.clear(); // buffers dropped, never recycled
        } else {
            self.free.append(pages);
        }
        cache.capacity = 0;
        cache.len = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Arch;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "kv-test".into(),
            arch: Arch::Opt,
            vocab_size: 32,
            d_model: 8,
            n_heads: 2,
            n_layers: 3,
            d_ff: 16,
            max_seq: 4,
        }
    }

    #[test]
    fn store_and_readback() {
        let cfg = cfg();
        let mut c = KvCache::new(&cfg);
        assert_eq!((c.len(), c.capacity(), c.remaining()), (0, 4, 4));
        assert!(c.is_empty());
        assert!(!c.is_paged());
        let krow: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let vrow: Vec<f32> = (0..8).map(|i| -(i as f32)).collect();
        for layer in 0..3 {
            c.store(layer, 0, &krow, &vrow);
        }
        c.advance(1);
        assert_eq!(c.len(), 1);
        let view = c.layer(2);
        assert_eq!(view.k_row(0), &krow[..]);
        assert_eq!(view.v_row(0), &vrow[..]);
    }

    #[test]
    fn reset_rewinds_without_clearing_storage() {
        let cfg = cfg();
        let mut c = KvCache::new(&cfg);
        let row = [1.0f32; 8];
        c.store(0, 0, &row, &row);
        c.advance(1);
        c.reset();
        assert_eq!(c.len(), 0);
        assert_eq!(c.remaining(), 4);
        // lazily overwritten on the next sequence — old bytes may linger
        let row2 = [2.0f32; 8];
        c.store(0, 0, &row2, &row2);
        c.advance(1);
        assert_eq!(c.layer(0).k_row(0), &row2[..]);
    }

    #[test]
    fn quantized_store_applies_the_tokenwise_lut_path() {
        let cfg = cfg();
        let fmt = FpFormat::E4M3;
        let mut c = KvCache::quantized(&cfg, fmt);
        assert_eq!(c.quant_format(), Some(fmt));
        let krow = [0.1f32, -1.7, 3.14, 0.0, 42.0, -0.003, 7.5, 1.0];
        let vrow = [9.0f32, -0.25, 0.6, 2.0, -8.0, 0.01, -1.0, 5.0];
        c.store(0, 0, &krow, &vrow);
        c.advance(1);
        // stored rows must be exactly fake_quant_row of the inputs
        let lut = FpQuantLut::new(fmt);
        let mut ek = krow;
        lut.fake_quant_row(&mut ek);
        let mut ev = vrow;
        lut.fake_quant_row(&mut ev);
        let view = c.layer(0);
        for i in 0..8 {
            assert_eq!(view.k_row(0)[i].to_bits(), ek[i].to_bits());
            assert_eq!(view.v_row(0)[i].to_bits(), ev[i].to_bits());
        }
        // and quantization actually engaged (some element moved)
        assert!(view.k_row(0).iter().zip(&krow).any(|(a, b)| a.to_bits() != b.to_bits()));
    }

    #[test]
    fn exact_cache_reports_no_format() {
        assert_eq!(KvCache::new(&cfg()).quant_format(), None);
    }

    #[test]
    fn quarantine_is_sticky_across_reset() {
        let mut c = KvCache::new(&cfg());
        assert!(!c.is_quarantined());
        c.quarantine();
        assert!(c.is_quarantined());
        c.reset(); // reset recycles the ring, not the poison flag
        assert!(c.is_quarantined());
    }

    #[test]
    fn paged_store_matches_ring_bytes_across_page_boundaries() {
        let cfg = cfg();
        // P = 3 with max_seq = 4: position 3 crosses into a second page.
        let mut pool = KvPagePool::new(&cfg, 3, 0, None);
        let mut ring = KvCache::new(&cfg);
        let mut paged = pool.new_cache();
        assert!(paged.is_paged());
        assert_eq!(paged.capacity(), 0, "no pages before reserve");
        assert!(pool.reserve(&mut paged, cfg.max_seq));
        assert_eq!(paged.capacity(), 6, "2 pages × 3 positions");
        for pos in 0..cfg.max_seq {
            let krow: Vec<f32> = (0..8).map(|i| (pos * 8 + i) as f32).collect();
            let vrow: Vec<f32> = krow.iter().map(|x| -x).collect();
            for layer in 0..3 {
                ring.store(layer, pos, &krow, &vrow);
                paged.store(layer, pos, &krow, &vrow);
            }
            ring.advance(1);
            paged.advance(1);
        }
        for layer in 0..3 {
            let rv = ring.layer(layer);
            let pv = paged.layer(layer);
            for pos in 0..cfg.max_seq {
                assert_eq!(rv.k_row(pos), pv.k_row(pos), "k layer {layer} pos {pos}");
                assert_eq!(rv.v_row(pos), pv.v_row(pos), "v layer {layer} pos {pos}");
            }
        }
        pool.release(&mut paged);
        assert_eq!(paged.len(), 0);
    }

    #[test]
    fn pool_reserve_is_all_or_nothing_and_accounting_balances() {
        let cfg = cfg();
        let mut pool = KvPagePool::new(&cfg, 2, 0, None);
        assert_eq!(pool.total_pages(), 2, "budget 0 clamps to one max_seq sequence");
        assert_eq!(pool.page_bytes(), 3 * 2 * 2 * 8 * 4);
        assert_eq!(pool.total_bytes(), 2 * pool.page_bytes());

        let mut a = pool.new_cache();
        let mut b = pool.new_cache();
        assert!(pool.reserve(&mut a, 2)); // 1 page
        assert_eq!((pool.free_pages(), pool.resident_pages(), pool.leaked_pages()), (1, 1, 0));
        assert!(!pool.reserve(&mut b, 3), "2 pages needed, 1 free");
        assert_eq!(b.pages_held(), 0, "failed reserve takes nothing");
        assert!(pool.reserve(&mut b, 2));
        assert_eq!(pool.free_pages(), 0);
        assert!(!pool.can_reserve(1));

        // grow `a` past its page: fails dry, succeeds after b releases
        a.advance(2);
        assert_eq!(a.remaining(), 0);
        assert!(!pool.reserve(&mut a, 1));
        pool.release(&mut b);
        assert!(pool.reserve(&mut a, 1));
        assert_eq!(a.capacity(), 4);

        assert_eq!(pool.peak_resident_pages(), 2);
        assert_eq!(
            pool.free_pages() + pool.resident_pages() + pool.leaked_pages(),
            pool.total_pages()
        );
    }

    #[test]
    fn quarantined_release_leaks_only_its_own_pages() {
        let cfg = cfg();
        let mut pool = KvPagePool::new(&cfg, 1, 16 * 1024, None);
        let total = pool.total_pages();
        assert!(total >= cfg.max_seq);

        let mut healthy = pool.new_cache();
        let mut poisoned = pool.new_cache();
        assert!(pool.reserve(&mut healthy, 3));
        assert!(pool.reserve(&mut poisoned, 2));
        poisoned.quarantine();
        pool.release(&mut poisoned);
        assert_eq!(pool.leaked_pages(), 2, "exactly the poisoned cache's pages");
        assert_eq!(pool.resident_pages(), 3, "healthy pages untouched");
        pool.release(&mut healthy);
        assert_eq!(pool.free_pages(), total - 2);
        assert_eq!(
            pool.free_pages() + pool.resident_pages() + pool.leaked_pages(),
            pool.total_pages()
        );
    }

    #[test]
    fn ring_truncate_rewinds_partially_and_keeps_prefix_rows() {
        let cfg = cfg();
        let mut c = KvCache::new(&cfg);
        for pos in 0..3 {
            let row = [pos as f32; 8];
            c.store(0, pos, &row, &row);
            c.advance(1);
        }
        c.truncate(1);
        assert_eq!((c.len(), c.capacity()), (1, 4), "ring capacity is untouched");
        assert_eq!(c.layer(0).k_row(0), &[0.0f32; 8][..], "accepted prefix survives");
        // rejected positions are overwritten lazily, exactly like reset
        let row = [9.0f32; 8];
        c.store(0, 1, &row, &row);
        c.advance(1);
        assert_eq!(c.layer(0).k_row(1), &row[..]);
    }

    #[test]
    #[should_panic(expected = "truncate(3) past len 1")]
    fn ring_truncate_past_len_panics() {
        let cfg = cfg();
        let mut c = KvCache::new(&cfg);
        c.advance(1);
        c.truncate(3);
    }

    #[test]
    fn pool_truncate_frees_trailing_pages_and_books_balance() {
        let cfg = cfg();
        // P = 1 so every position is its own page.
        let mut pool = KvPagePool::new(&cfg, 1, 0, None);
        let total = pool.total_pages();
        let mut c = pool.new_cache();
        assert!(pool.reserve(&mut c, 4));
        c.advance(4);
        pool.truncate(&mut c, 1);
        assert_eq!((c.len(), c.pages_held(), c.capacity()), (1, 1, 1));
        assert_eq!(pool.free_pages(), total - 1, "trailing pages returned");
        assert_eq!(
            pool.free_pages() + pool.resident_pages() + pool.leaked_pages(),
            pool.total_pages()
        );
        // truncate to zero releases every page but keeps the husk usable
        pool.truncate(&mut c, 0);
        assert_eq!((c.len(), c.pages_held(), c.capacity()), (0, 0, 0));
        assert_eq!(pool.free_pages(), total);
        assert!(pool.reserve(&mut c, 2), "husk is still reservable");
    }

    #[test]
    fn fork_cache_copies_bits_and_books_balance() {
        let cfg = cfg();
        // P = 3 so the fork's last page is partially filled.
        let mut pool = KvPagePool::sized_for(&cfg, 3, 0, None, 3);
        let total = pool.total_pages();
        let mut src = pool.new_cache();
        assert!(pool.reserve(&mut src, 4));
        for pos in 0..4 {
            let krow: Vec<f32> = (0..8).map(|i| (pos * 8 + i) as f32).collect();
            let vrow: Vec<f32> = krow.iter().map(|x| -x).collect();
            for layer in 0..3 {
                src.store(layer, pos, &krow, &vrow);
            }
            src.advance(1);
        }
        let fork = pool.fork_cache(&src).expect("pool has room");
        assert_eq!((fork.len(), fork.pages_held()), (4, 2));
        for layer in 0..3 {
            for pos in 0..4 {
                assert_eq!(
                    src.layer(layer).k_row(pos),
                    fork.layer(layer).k_row(pos),
                    "k layer {layer} pos {pos}"
                );
                assert_eq!(src.layer(layer).v_row(pos), fork.layer(layer).v_row(pos));
            }
        }
        assert_eq!(pool.resident_pages(), 4, "source + fork pages both resident");
        assert_eq!(
            pool.free_pages() + pool.resident_pages() + pool.leaked_pages(),
            pool.total_pages()
        );
        // a dry pool forks nothing and takes nothing
        let mut hog = pool.new_cache();
        assert!(pool.reserve(&mut hog, (total - 4) * 3));
        assert!(pool.fork_cache(&src).is_none());
        assert_eq!(pool.free_pages(), 0);
        pool.release(&mut hog);
        let mut f2 = pool.fork_cache(&src).expect("room again");
        pool.release(&mut f2);
        pool.release(&mut src);
        assert_eq!(pool.free_pages(), total);
    }

    #[test]
    fn sized_for_clamps_to_two_sequences() {
        let cfg = cfg();
        let one = KvPagePool::new(&cfg, 3, 0, None);
        let two = KvPagePool::sized_for(&cfg, 3, 0, None, 2);
        assert_eq!(one.total_pages(), 2, "max_seq 4 over P=3 is 2 pages");
        assert_eq!(two.total_pages(), 4, "per-sequence round-up, not position sum");
    }

    #[test]
    fn quantized_pool_mints_quantizing_caches() {
        let cfg = cfg();
        let mut pool = KvPagePool::new(&cfg, 4, 0, Some(FpFormat::E5M2));
        let mut c = pool.new_cache();
        assert_eq!(c.quant_format(), Some(FpFormat::E5M2));
        assert!(pool.reserve(&mut c, 1));
        let krow = [0.1f32, -1.7, 3.14, 0.0, 42.0, -0.003, 7.5, 1.0];
        c.store(0, 0, &krow, &krow);
        c.advance(1);
        let lut = FpQuantLut::new(FpFormat::E5M2);
        let mut expect = krow;
        lut.fake_quant_row(&mut expect);
        for i in 0..8 {
            assert_eq!(c.layer(0).k_row(0)[i].to_bits(), expect[i].to_bits());
        }
    }
}
