//! Per-sequence K/V cache — the state that makes decode incremental.
//!
//! # Why
//!
//! [`CompiledModel::forward`](super::CompiledModel::forward) recomputes
//! attention over the **entire** token window for every forward call:
//! generating one token after `n` costs `O(n²·d)` in attention alone. The
//! serving decode loop instead carries a [`KvCache`] and calls
//! [`prefill`](super::CompiledModel::prefill) once per prompt and
//! [`decode_step`](super::CompiledModel::decode_step) once per generated
//! token — each step computes the q/k/v projections for the *new* position
//! only and attends against the cached keys/values, `O(n·d)` per token.
//!
//! # Layout
//!
//! One ring per layer, two matrices per ring:
//!
//! ```text
//!   k[layer]: [max_seq, d_model]   row p = key   vector of position p
//!   v[layer]: [max_seq, d_model]   row p = value vector of position p
//! ```
//!
//! Rows are stored head-interleaved exactly as the fused q|k|v projection
//! emits them (head `h` occupies columns `h·dh .. (h+1)·dh`), so the cached
//! attention kernel walks the same unit-stride slices as the full-recompute
//! kernel — this is what makes the bit-equivalence contract (below) cheap.
//!
//! Every buffer is allocated once at construction and sized to the model's
//! `max_seq`; appending rows and [`reset`](KvCache::reset) never
//! touch the heap, so the serving loop's steady state stays allocation-free
//! (asserted by `tests/plan_alloc.rs`).
//!
//! # Eviction and reset rules
//!
//! The ring is sized to `max_seq` — the hard window of the learned position
//! table — so a *single* sequence can never overflow it: the write cursor
//! advances from 0 to at most `max_seq` and `prefill`/`decode_step` assert
//! before ever wrapping a live sequence (evicting position 0 mid-sequence
//! would silently change attention semantics, and the position table has no
//! row to give the overflowing token anyway). Eviction is therefore always
//! *whole-sequence*: [`reset`](KvCache::reset) rewinds the cursor to slot 0
//! and the next sequence lazily overwrites the stale rows — no zeroing
//! pass. The serving coordinator keeps finished sequences' caches in a free
//! pool and recycles them via `reset` (see `coordinator/`).
//!
//! # FP8 quantization (the paper's formats, applied to the cache)
//!
//! [`KvCache::quantized`] stores every appended K/V row through the same
//! [`FpQuantLut`] fast path the A8 activation hot loop uses: one absmax
//! scan + LUT quantize per row (token-wise scaling, exactly
//! `NumericFormat::fake_quant_slice_dynamic` semantics). This halves the
//! dominant serving memory stream the way ZeroQuant-FP's W4A8 formats are
//! meant to be deployed, at the cost of leaving the bit-equivalence
//! contract: a quantized cache is **not** bit-identical to
//! full-recompute `forward` (the reference keeps exact f32 K/V). What it
//! *does* keep is split-invariance — where the prompt/decode boundary falls
//! cannot change the logits, because rows are quantized independently of
//! when they were appended (`tests/kv_equivalence.rs` asserts both
//! properties).

use super::FpQuantLut;
use crate::formats::FpFormat;
use crate::model::ModelConfig;
use crate::tensor::Matrix;

/// Per-layer K/V rings for one sequence. See the module docs for layout,
/// reset/eviction rules and the quantization contract.
#[derive(Debug, Clone)]
pub struct KvCache {
    /// Ring capacity in positions (= the model's `max_seq`).
    capacity: usize,
    /// Valid positions: rows `0..len` of every ring hold live K/V.
    len: usize,
    /// Per-layer key rows `[capacity, d_model]`.
    k: Vec<Matrix>,
    /// Per-layer value rows `[capacity, d_model]`.
    v: Vec<Matrix>,
    /// `Some` ⇒ every stored row is token-wise fake-quantized on append.
    quant: Option<FpQuantLut>,
    /// Sticky poison flag: a cache whose layer walk panicked mid-flight
    /// must never serve another sequence (see
    /// [`quarantine`](Self::quarantine)).
    quarantined: bool,
}

impl KvCache {
    /// An exact (f32) cache: decode through it is bit-identical to
    /// `CompiledModel::forward` over the same window.
    pub fn new(cfg: &ModelConfig) -> KvCache {
        KvCache::build(cfg, None)
    }

    /// A cache that fake-quantizes every stored K/V row to `fmt` (token-wise
    /// absmax scaling through the LUT fast path).
    pub fn quantized(cfg: &ModelConfig, fmt: FpFormat) -> KvCache {
        KvCache::build(cfg, Some(FpQuantLut::new(fmt)))
    }

    fn build(cfg: &ModelConfig, quant: Option<FpQuantLut>) -> KvCache {
        let capacity = cfg.max_seq;
        let d = cfg.d_model;
        KvCache {
            capacity,
            len: 0,
            k: (0..cfg.n_layers).map(|_| Matrix::zeros(capacity, d)).collect(),
            v: (0..cfg.n_layers).map(|_| Matrix::zeros(capacity, d)).collect(),
            quant,
            quarantined: false,
        }
    }

    /// Number of cached positions (the next token decodes at this position).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Ring capacity in positions (= the model's `max_seq`).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Positions still available before the ring is full.
    pub fn remaining(&self) -> usize {
        self.capacity - self.len
    }

    /// The storage format of appended rows (`None` = exact f32).
    pub fn quant_format(&self) -> Option<FpFormat> {
        self.quant.as_ref().map(|lut| lut.format())
    }

    /// Rewind the write cursor to slot 0, invalidating every cached
    /// position. Stale rows are overwritten lazily by the next sequence —
    /// no zeroing pass, no allocation.
    pub fn reset(&mut self) {
        self.len = 0;
    }

    /// Mark this cache poisoned. A panic that unwinds out of a layer walk
    /// leaves the walk's staged rows in an unknown state; the serving
    /// coordinator quarantines (drops, never recycles) such a cache so a
    /// later sequence cannot decode through it. Sticky:
    /// [`reset`](Self::reset) does **not** clear it, and the plan's
    /// decode entry points assert against quarantined caches.
    pub fn quarantine(&mut self) {
        self.quarantined = true;
    }

    pub fn is_quarantined(&self) -> bool {
        self.quarantined
    }

    /// Store the K/V rows of one position in one layer's ring (quantizing
    /// if configured). Does **not** advance the cursor: callers stage every
    /// layer's rows for a token first and [`advance`](Self::advance) once.
    pub(super) fn store(&mut self, layer: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert!(pos < self.capacity, "kv store past ring capacity");
        let KvCache { k, v, quant, .. } = self;
        let kr = k[layer].row_mut(pos);
        kr.copy_from_slice(k_row);
        if let Some(lut) = quant.as_ref() {
            lut.fake_quant_row(kr);
        }
        let vr = v[layer].row_mut(pos);
        vr.copy_from_slice(v_row);
        if let Some(lut) = quant.as_ref() {
            lut.fake_quant_row(vr);
        }
    }

    /// One layer's (K, V) rings; rows `0..len()` are live (plus any rows
    /// staged by [`store`](Self::store) ahead of the cursor).
    pub(super) fn layer(&self, layer: usize) -> (&Matrix, &Matrix) {
        (&self.k[layer], &self.v[layer])
    }

    /// Commit `n` staged positions.
    pub(super) fn advance(&mut self, n: usize) {
        self.len += n;
        debug_assert!(self.len <= self.capacity, "kv ring overfull");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Arch;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "kv-test".into(),
            arch: Arch::Opt,
            vocab_size: 32,
            d_model: 8,
            n_heads: 2,
            n_layers: 3,
            d_ff: 16,
            max_seq: 4,
        }
    }

    #[test]
    fn store_and_readback() {
        let cfg = cfg();
        let mut c = KvCache::new(&cfg);
        assert_eq!((c.len(), c.capacity(), c.remaining()), (0, 4, 4));
        assert!(c.is_empty());
        let krow: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let vrow: Vec<f32> = (0..8).map(|i| -(i as f32)).collect();
        for layer in 0..3 {
            c.store(layer, 0, &krow, &vrow);
        }
        c.advance(1);
        assert_eq!(c.len(), 1);
        let (k, v) = c.layer(2);
        assert_eq!(k.row(0), &krow[..]);
        assert_eq!(v.row(0), &vrow[..]);
    }

    #[test]
    fn reset_rewinds_without_clearing_storage() {
        let cfg = cfg();
        let mut c = KvCache::new(&cfg);
        let row = [1.0f32; 8];
        c.store(0, 0, &row, &row);
        c.advance(1);
        c.reset();
        assert_eq!(c.len(), 0);
        assert_eq!(c.remaining(), 4);
        // lazily overwritten on the next sequence — old bytes may linger
        let row2 = [2.0f32; 8];
        c.store(0, 0, &row2, &row2);
        c.advance(1);
        assert_eq!(c.layer(0).0.row(0), &row2[..]);
    }

    #[test]
    fn quantized_store_applies_the_tokenwise_lut_path() {
        let cfg = cfg();
        let fmt = FpFormat::E4M3;
        let mut c = KvCache::quantized(&cfg, fmt);
        assert_eq!(c.quant_format(), Some(fmt));
        let krow = [0.1f32, -1.7, 3.14, 0.0, 42.0, -0.003, 7.5, 1.0];
        let vrow = [9.0f32, -0.25, 0.6, 2.0, -8.0, 0.01, -1.0, 5.0];
        c.store(0, 0, &krow, &vrow);
        c.advance(1);
        // stored rows must be exactly fake_quant_row of the inputs
        let lut = FpQuantLut::new(fmt);
        let mut ek = krow;
        lut.fake_quant_row(&mut ek);
        let mut ev = vrow;
        lut.fake_quant_row(&mut ev);
        let (k, v) = c.layer(0);
        for i in 0..8 {
            assert_eq!(k.row(0)[i].to_bits(), ek[i].to_bits());
            assert_eq!(v.row(0)[i].to_bits(), ev[i].to_bits());
        }
        // and quantization actually engaged (some element moved)
        assert!(k.row(0).iter().zip(&krow).any(|(a, b)| a.to_bits() != b.to_bits()));
    }

    #[test]
    fn exact_cache_reports_no_format() {
        assert_eq!(KvCache::new(&cfg()).quant_format(), None);
    }

    #[test]
    fn quarantine_is_sticky_across_reset() {
        let mut c = KvCache::new(&cfg());
        assert!(!c.is_quarantined());
        c.quarantine();
        assert!(c.is_quarantined());
        c.reset(); // reset recycles the ring, not the poison flag
        assert!(c.is_quarantined());
    }
}
