//! LUT-based fast path for the ExMy codecs.
//!
//! [`FpFormat::quantize`] is the crate's bit-exactness oracle: per scalar it
//! widens to f64, extracts the binade, divides by the quantum, rounds
//! ties-to-even and narrows back. Correct, and far too slow for the A8 hot
//! path where it runs on every element of every linear input.
//!
//! [`FpQuantLut`] precomputes, for each of the 256 possible f32 exponent
//! buckets, the rounding quantum of that binade and its reciprocal — both
//! exact powers of two — derived from the format's enumerated value set
//! ([`FpFormat::positive_values`], which advertises exactly this use). A
//! quantize is then four f32 ops and one table load:
//!
//! ```text
//!   q = rte(|x| * inv_quantum[exp(x)]) * quantum[exp(x)]   (copysign x)
//! ```
//!
//! **Bit-exactness argument.** Every scaling step multiplies an f32 by a
//! power of two whose product stays in range, so no rounding occurs before
//! the `round_ties_even`, and the rounded integer (≤ 2^(m+1)) and its
//! rescaling are exact in f32. The oracle performs the same real-number
//! computation in f64 on exactly-widened inputs, so both paths round the
//! same real value at the same single point — the results are bit-identical.
//! `lut_matches_oracle_*` in `tests/plan_equivalence.rs` verifies this over
//! every exponent bucket and every 16-bit code pattern.

use crate::formats::{pow2, FpFormat, GroupParams};

/// Per-exponent-bucket quantization table for one [`FpFormat`].
#[derive(Debug, Clone)]
pub struct FpQuantLut {
    fmt: FpFormat,
    /// `max_finite()` narrowed to f32 (exact for every supported format).
    max: f32,
    /// Rounding quantum of the binade `[2^(e8-127), 2^(e8-126))`.
    quantum: [f32; 256],
    /// `1 / quantum` (exact: quanta are powers of two).
    inv_quantum: [f32; 256],
}

impl FpQuantLut {
    /// Build the table from the format's enumerated value set.
    pub fn new(fmt: FpFormat) -> FpQuantLut {
        let vals = fmt.positive_values();
        assert!(vals.len() >= 2 && vals[0] == 0.0, "degenerate format");
        let max = *vals.last().unwrap();
        let top_step = f64::from(vals[vals.len() - 1]) - f64::from(vals[vals.len() - 2]);
        let mut quantum = [0.0f32; 256];
        let mut inv_quantum = [0.0f32; 256];
        for e8 in 0..256usize {
            // Probe the low edge of the binade; the spacing of representable
            // values is constant within a binade (and within the whole
            // subnormal range), so the gap around the probe IS the quantum.
            let probe = pow2(e8 as i32 - 127);
            let q = if probe >= f64::from(max) {
                // Bucket fully saturates — entry unreachable (the |x| >= max
                // check fires first); keep the top-binade spacing anyway.
                top_step
            } else {
                let idx = vals.partition_point(|&v| f64::from(v) <= probe);
                // idx >= 1 because vals[0] = 0 <= probe, and idx < len
                // because probe < max.
                f64::from(vals[idx]) - f64::from(vals[idx - 1])
            };
            debug_assert!(q > 0.0 && q.log2().fract() == 0.0, "quantum must be a power of two");
            quantum[e8] = q as f32;
            inv_quantum[e8] = (1.0 / q) as f32;
        }
        FpQuantLut { fmt, max, quantum, inv_quantum }
    }

    pub fn format(&self) -> FpFormat {
        self.fmt
    }

    /// Largest representable magnitude, as f32.
    pub fn max_finite(&self) -> f32 {
        self.max
    }

    /// Quantize one value to the nearest representable point of the format.
    /// Bit-identical to [`FpFormat::quantize`].
    #[inline]
    pub fn quantize(&self, x: f32) -> f32 {
        if x.is_nan() {
            return f32::NAN;
        }
        let a = x.abs();
        if a >= self.max {
            return self.max.copysign(x);
        }
        let e8 = ((a.to_bits() >> 23) & 0xff) as usize;
        let r = (a * self.inv_quantum[e8]).round_ties_even() * self.quantum[e8];
        r.copysign(x)
    }

    /// Fake-quantize a slice under fixed group params, mirroring
    /// [`crate::formats::NumericFormat::fake_quant_slice`] for FP formats.
    #[inline]
    pub fn fake_quant_slice(&self, xs: &mut [f32], p: GroupParams) {
        // f32 division (not reciprocal-multiply), same as the oracle slice
        // quantizer — required for bit-identity.
        for x in xs.iter_mut() {
            *x = self.quantize(*x / p.scale) * p.scale;
        }
    }

    /// One token row of the A8 hot path: fused absmax scan + LUT quantize,
    /// bit-identical to `NumericFormat::Fp(fmt).fake_quant_slice_dynamic`.
    /// Returns the scale used (1.0 for the degenerate identity cases).
    #[inline]
    pub fn fake_quant_row(&self, xs: &mut [f32]) -> f32 {
        let mut am = 0.0f32;
        for &x in xs.iter() {
            am = am.max(x.abs());
        }
        if !am.is_finite() {
            return 1.0; // identity, matching the oracle's non-finite guard
        }
        // Same expression as NumericFormat::group_params for Fp.
        let scale = if am > 0.0 { am / self.max } else { 1.0 };
        self.fake_quant_slice(xs, GroupParams { scale, zero_point: 0 });
        scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lut_quantizes_own_values_exactly() {
        for fmt in [FpFormat::E4M3, FpFormat::E5M2, FpFormat::E2M1, FpFormat::E3M0] {
            let lut = FpQuantLut::new(fmt);
            for v in fmt.positive_values() {
                assert_eq!(lut.quantize(v), v, "{} value {v}", fmt.name());
                assert_eq!(lut.quantize(-v), -v, "{} value -{v}", fmt.name());
            }
        }
    }

    #[test]
    fn lut_matches_oracle_on_random_samples() {
        let mut rng = crate::rng::Rng::seeded(77);
        for fmt in [FpFormat::E4M3, FpFormat::E5M2, FpFormat::E2M1, FpFormat::E3M0] {
            let lut = FpQuantLut::new(fmt);
            for _ in 0..5000 {
                let x = rng.normal_f32() * fmt.max_finite() as f32 * 0.5;
                let a = lut.quantize(x);
                let b = fmt.quantize(x);
                assert_eq!(a.to_bits(), b.to_bits(), "{}: x={x} lut={a} oracle={b}", fmt.name());
            }
        }
    }

    #[test]
    fn lut_handles_specials_like_oracle() {
        let lut = FpQuantLut::new(FpFormat::E4M3);
        let f = FpFormat::E4M3;
        for x in [0.0f32, -0.0, 1e-30, -1e-30, 1e30, -1e30, f32::INFINITY, f32::NEG_INFINITY] {
            assert_eq!(lut.quantize(x).to_bits(), f.quantize(x).to_bits(), "x={x}");
        }
        assert!(lut.quantize(f32::NAN).is_nan());
    }

    #[test]
    fn row_path_matches_dynamic_oracle() {
        let mut rng = crate::rng::Rng::seeded(78);
        let lut = FpQuantLut::new(FpFormat::E4M3);
        let fmt = crate::formats::NumericFormat::FP8_E4M3;
        for len in [1usize, 7, 64, 513] {
            let mut a: Vec<f32> = (0..len).map(|_| rng.normal_f32() * 3.0).collect();
            let mut b = a.clone();
            let s = lut.fake_quant_row(&mut a);
            let p = fmt.fake_quant_slice_dynamic(&mut b);
            assert_eq!(s, p.scale);
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }
}
