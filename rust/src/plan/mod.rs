//! Prepacked execution plans — the allocation-free fast path of the engine.
//!
//! [`crate::engine::Engine`] is the *reference* implementation: it resolves
//! every tensor through `format!`-built string keys on each forward pass and
//! re-transposes each weight matrix per linear call. That is the right shape
//! for an oracle, and the wrong shape for a decode loop.
//!
//! [`CompiledModel::compile`] runs all of that work **once** per
//! `(Checkpoint, EngineOpts)`:
//!
//! * every tensor is resolved out of the `BTreeMap` into per-layer structs —
//!   the decode loop performs zero string formatting and zero map lookups;
//! * weights are prepacked transposed (`[in, out]`), the layout the axpy
//!   kernel [`crate::tensor::matmul::matmul_into`] streams unit-stride; the
//!   q/k/v projections (and llama's gate/up) are fused into one wide matmul;
//! * biases are fused into the matmul epilogue by seeding the accumulator,
//!   eliminating the separate bias pass;
//! * FP8/FP4 token-wise activation fake-quant runs through the
//!   [`FpQuantLut`] table instead of the per-scalar f64 oracle codec;
//! * all intermediates live in a [`DecodeScratch`] arena sized once for
//!   `max_seq` — steady-state decode performs **zero heap allocations**
//!   (asserted by `tests/plan_alloc.rs` with a counting allocator);
//! * serving decode is **incremental**: [`CompiledModel::prefill`] runs the
//!   prompt once and stashes every layer's K/V rows in a [`KvCache`], and
//!   [`CompiledModel::decode_step`] /
//!   [`CompiledModel::decode_step_batch`] then compute attention only for
//!   the new position(s) — `O(n·d)` per token instead of the
//!   `O(n²·d)` full-window recompute that [`CompiledModel::forward`]
//!   performs (`forward` remains the oracle and the calibration path; see
//!   [`kv`] for the cache design).
//!
//! The compiled path is **bit-identical** to the reference engine: every
//! float is produced by the same operation sequence (fusing q/k/v widens the
//! matmul but preserves each output scalar's accumulation order, and the LUT
//! quantizer is bit-equal to the oracle codec by construction). The
//! equivalence is enforced across architectures, activation formats and
//! sequence lengths by `tests/plan_equivalence.rs`.
//!
//! The same contract extends to the cached decode path: `forward`,
//! `prefill` and `decode_step` all execute **one** layer walk
//! (`run_mode`), differing only in where attention sources K/V, and every
//! per-row operation (norms, linears, activation fake-quant, MLP, logits)
//! is row-local — so `prefill + N × decode_step` over a window produces
//! logits bit-identical to one `forward` over that window (asserted across
//! architectures, activation formats and prompt/decode split points by
//! `tests/kv_equivalence.rs`). An FP8-quantized cache deliberately leaves
//! this contract — see the [`kv`] module docs for what it preserves
//! instead.

pub mod kv;
mod lut;
pub mod speculate;

pub use kv::{KvCache, KvPagePool};
pub use lut::FpQuantLut;

use kv::KvLayerView;

use std::sync::Arc;

use crate::engine::{EngineOpts, LinearSite, Site, WeightLayout};
use crate::formats::{FpFormat, NumericFormat};
use crate::kernels::Kernels;
use crate::lorc::PackedLorc;
use crate::model::{Arch, Checkpoint, ModelConfig};
use crate::quant::{PackedWeight, QuantSidecar};
use crate::tensor::packed_matmul::GemvScratch;
use crate::tensor::{matmul, Matrix};

/// A linear layer prepacked for the axpy kernel: transposed weight
/// (`[d_in, d_out]`) plus an optional fused bias. Several source linears
/// sharing one input may be packed side by side into a single wide matmul.
#[derive(Debug, Clone)]
pub struct PackedLinear {
    pub d_in: usize,
    pub d_out: usize,
    /// `[d_in, d_out]` — column `j` is output feature `j`.
    wt: Matrix,
    /// Fused bias (`d_out`), or empty when every packed source is bias-free.
    bias: Vec<f32>,
}

impl PackedLinear {
    /// Pack one or more `[out, in]` weight matrices (with optional biases)
    /// that share the same input into one transposed, fused linear.
    /// Either every source has a bias or none does.
    fn pack(parts: &[(&Matrix, Option<&Matrix>)]) -> PackedLinear {
        let d_in = parts[0].0.cols;
        let d_out: usize = parts.iter().map(|(w, _)| w.rows).sum();
        let n_biased = parts.iter().filter(|(_, b)| b.is_some()).count();
        assert!(
            n_biased == 0 || n_biased == parts.len(),
            "cannot fuse biased with bias-free linears"
        );
        let mut wt = Matrix::zeros(d_in, d_out);
        let mut bias = Vec::new();
        let mut off = 0usize;
        for (w, b) in parts {
            assert_eq!(w.cols, d_in, "fused linears must share the input dim");
            // Blocked transpose-copy into the fused layout.
            const BLK: usize = 32;
            for rb in (0..w.rows).step_by(BLK) {
                for cb in (0..w.cols).step_by(BLK) {
                    for r in rb..(rb + BLK).min(w.rows) {
                        for c in cb..(cb + BLK).min(w.cols) {
                            wt.data[c * d_out + off + r] = w.data[r * w.cols + c];
                        }
                    }
                }
            }
            if let Some(b) = b {
                assert_eq!(b.data.len(), w.rows, "bias shape mismatch");
                bias.extend_from_slice(&b.data);
            }
            off += w.rows;
        }
        PackedLinear { d_in, d_out, wt, bias }
    }

    /// `out = bias + x @ wt` into a scratch buffer (resized, no allocation
    /// when the buffer's capacity suffices). Bias seeds the accumulator —
    /// the same operation order as the reference engine's linear. The GEMV
    /// itself dispatches through the kernel backend (both tiers default to
    /// the reference axpy kernel, so the dense path stays bit-identical).
    pub fn run_into(&self, x: &Matrix, out: &mut Matrix, k: &dyn Kernels) {
        assert_eq!(x.cols, self.d_in, "linear input dim mismatch");
        if self.bias.is_empty() {
            out.resize_to(x.rows, self.d_out); // zeroed accumulation base
        } else {
            // Seed the accumulator with the bias directly — one write pass
            // instead of a zero fill followed by a bias copy.
            out.resize_rows_to(x.rows, &self.bias);
        }
        k.gemv(x, &self.wt, out);
    }
}

/// A linear whose weights live as bit-packed low-bit codes, executed by
/// the fused dequant GEMV ([`crate::tensor::packed_matmul`]). Same fusion
/// rules as [`PackedLinear`] (q|k|v and gate|up row-stacked), same bias
/// seeding, bit-identical output. When the PTQ run used LoRC the slot also
/// carries the [`PackedLorc`] factors (per-sub-tensor E₁ blocks stacked in
/// the fused row order, per-sub-tensor E₂), and the GEMV folds the
/// compensation into each decoded row — output bit-identical to the dense
/// plan over the *folded* effective checkpoint.
#[derive(Debug, Clone)]
pub struct PackedQLinear {
    pub d_in: usize,
    pub d_out: usize,
    w: PackedWeight,
    lorc: Option<PackedLorc>,
    bias: Vec<f32>,
}

/// One fused source of a packed slot: quantized codes, optional LoRC
/// factors, optional bias.
type QPart<'a> = (
    &'a crate::quant::QuantizedWeight,
    Option<&'a crate::lorc::LorcFactors>,
    Option<&'a Matrix>,
);

impl PackedQLinear {
    fn pack(parts: &[QPart<'_>]) -> PackedQLinear {
        let qs: Vec<&crate::quant::QuantizedWeight> = parts.iter().map(|(q, _, _)| *q).collect();
        let n_biased = parts.iter().filter(|(_, _, b)| b.is_some()).count();
        assert!(
            n_biased == 0 || n_biased == parts.len(),
            "cannot fuse biased with bias-free linears"
        );
        let mut bias = Vec::new();
        for (q, _, b) in parts {
            if let Some(b) = b {
                assert_eq!(b.data.len(), q.rows, "bias shape mismatch");
                bias.extend_from_slice(&b.data);
            }
        }
        let w = PackedWeight::pack(&qs);
        let lorc = if parts.iter().any(|(_, l, _)| l.is_some()) {
            let lparts: Vec<(usize, Option<&crate::lorc::LorcFactors>)> =
                parts.iter().map(|(q, l, _)| (q.rows, *l)).collect();
            let pl = PackedLorc::pack(&lparts);
            assert_eq!((pl.d_out, pl.d_in), (w.rows, w.cols), "lorc factor geometry mismatch");
            Some(pl)
        } else {
            None
        };
        PackedQLinear { d_in: w.cols, d_out: w.rows, w, lorc, bias }
    }

    /// `out = bias + x @ (dequant(w) + E₁E₂)ᵀ`, decoded (and compensated)
    /// on the fly by the kernel backend. `s` holds the arena's decode
    /// strips; allocation-free on both tiers' single-worker paths.
    pub fn run_into(&self, x: &Matrix, out: &mut Matrix, s: &mut GemvScratch, k: &dyn Kernels) {
        assert_eq!(x.cols, self.d_in, "linear input dim mismatch");
        if self.bias.is_empty() {
            out.resize_to(x.rows, self.d_out);
        } else {
            out.resize_rows_to(x.rows, &self.bias);
        }
        k.packed_gemv(x, &self.w, self.lorc.as_ref(), out, s);
    }

    /// Resident bytes of the packed weight payload (codes + scales +
    /// tables + shift metadata + LoRC factor codes; bias excluded).
    pub fn weight_bytes(&self) -> usize {
        self.w.mem_bytes() + self.lorc.as_ref().map_or(0, |l| l.mem_bytes())
    }

    /// Decoded-E₂ scratch elements this slot's LoRC attachment needs.
    fn lorc_e2_elems(&self) -> usize {
        self.lorc.as_ref().map_or(0, |l| l.e2_elems())
    }
}

/// One linear slot of a compiled layer: the dense f32 prepack or the
/// packed low-bit codes, selected by [`EngineOpts::weights`]. Both
/// variants produce bit-identical outputs for the same source weights.
#[derive(Debug, Clone)]
pub enum LayerWeights {
    Dense(PackedLinear),
    Packed(PackedQLinear),
}

impl LayerWeights {
    fn run_into(&self, x: &Matrix, out: &mut Matrix, s: &mut GemvScratch, k: &dyn Kernels) {
        match self {
            LayerWeights::Dense(l) => l.run_into(x, out, k),
            LayerWeights::Packed(l) => l.run_into(x, out, s, k),
        }
    }

    /// Resident bytes of the weight payload (weights + LoRC factors +
    /// bias).
    fn weight_bytes(&self) -> usize {
        match self {
            LayerWeights::Dense(l) => 4 * (l.wt.data.len() + l.bias.len()),
            LayerWeights::Packed(l) => l.weight_bytes() + 4 * l.bias.len(),
        }
    }

    /// Decoded-E₂ scratch elements the slot needs (0 without LoRC).
    fn lorc_e2_elems(&self) -> usize {
        match self {
            LayerWeights::Dense(_) => 0,
            LayerWeights::Packed(l) => l.lorc_e2_elems(),
        }
    }
}

/// A resolved norm: LayerNorm (gain + bias, Opt) or RMSNorm (gain, Llama).
#[derive(Debug, Clone)]
struct CompiledNorm {
    gain: Vec<f32>,
    /// `Some` for LayerNorm, `None` for RMSNorm.
    bias: Option<Vec<f32>>,
}

impl CompiledNorm {
    fn from_ck(ck: &Checkpoint, prefix: &str) -> CompiledNorm {
        let gain = ck.get(&format!("{prefix}.g")).data.clone();
        let bias = match ck.config.arch {
            Arch::Opt => Some(ck.get(&format!("{prefix}.b")).data.clone()),
            Arch::Llama => None,
        };
        CompiledNorm { gain, bias }
    }

    /// Normalize `x` into `out` — the exact arithmetic of `Engine::norm`.
    /// RMSNorm dispatches through the kernel backend (both tiers default
    /// to the oracle arithmetic); LayerNorm has no backend override yet
    /// and runs the reference loop inline.
    fn run_into(&self, x: &Matrix, out: &mut Matrix, k: &dyn Kernels) {
        match &self.bias {
            Some(bias) => {
                out.resize_to(x.rows, x.cols);
                let eps = 1e-5f32;
                for r in 0..x.rows {
                    let row = x.row(r);
                    let mean = row.iter().sum::<f32>() / row.len() as f32;
                    let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>()
                        / row.len() as f32;
                    let inv = 1.0 / (var + eps).sqrt();
                    let orow = out.row_mut(r);
                    for c in 0..row.len() {
                        orow[c] = (row[c] - mean) * inv * self.gain[c] + bias[c];
                    }
                }
            }
            None => k.rms_norm(x, &self.gain, out),
        }
    }
}

/// The MLP of one block, prepacked.
#[derive(Debug, Clone)]
enum CompiledMlp {
    /// Opt: fc1 → relu → fc2.
    Relu { fc1: LayerWeights, fc2: LayerWeights },
    /// Llama: fused gate|up → silu·mul → down.
    GatedSilu { gate_up: LayerWeights, down: LayerWeights },
}

/// One transformer block with every tensor resolved and prepacked.
#[derive(Debug, Clone)]
struct CompiledLayer {
    ln1: CompiledNorm,
    /// Fused q|k|v projection: `[d, 3d]`.
    qkv: LayerWeights,
    out_proj: LayerWeights,
    ln2: CompiledNorm,
    mlp: CompiledMlp,
}

impl CompiledLayer {
    fn weight_bytes(&self) -> usize {
        let mlp = match &self.mlp {
            CompiledMlp::Relu { fc1, fc2 } => fc1.weight_bytes() + fc2.weight_bytes(),
            CompiledMlp::GatedSilu { gate_up, down } => {
                gate_up.weight_bytes() + down.weight_bytes()
            }
        };
        self.qkv.weight_bytes() + self.out_proj.weight_bytes() + mlp
    }

    /// Largest decoded-E₂ scratch any of this layer's slots needs.
    fn lorc_e2_elems(&self) -> usize {
        let mlp = match &self.mlp {
            CompiledMlp::Relu { fc1, fc2 } => fc1.lorc_e2_elems().max(fc2.lorc_e2_elems()),
            CompiledMlp::GatedSilu { gate_up, down } => {
                gate_up.lorc_e2_elems().max(down.lorc_e2_elems())
            }
        };
        self.qkv.lorc_e2_elems().max(self.out_proj.lorc_e2_elems()).max(mlp)
    }
}

/// How token-wise activation fake-quant executes in the compiled path.
#[derive(Debug, Clone)]
enum ActPath {
    /// F16 passthrough — no-op.
    Noop,
    /// FP formats: fused absmax + LUT quantize (bit-equal to the oracle).
    Lut(FpQuantLut),
    /// INT formats: the oracle slice quantizer (already single-pass).
    Oracle(NumericFormat),
}

/// A checkpoint compiled into an execution plan for the decode loop.
#[derive(Debug, Clone)]
pub struct CompiledModel {
    pub config: ModelConfig,
    pub opts: EngineOpts,
    embed: Matrix,
    pos: Matrix,
    layers: Vec<CompiledLayer>,
    final_norm: CompiledNorm,
    act: ActPath,
    /// The kernel backend every primitive dispatches through, selected by
    /// [`EngineOpts::kernels`] at compile time. Shared by `Arc` so cloning
    /// a plan shares one worker pool rather than spawning another.
    kernels: Arc<dyn Kernels>,
}

/// Reusable activation arena: every buffer is sized for `max_seq` rows at
/// construction, then reshaped (never reallocated) per call. One arena
/// serves every execution mode — a full-window `forward` uses `seq` rows,
/// an incremental `decode_step` uses 1, and a continuous-batching
/// `decode_step_batch` uses one row per in-flight sequence (so any batch
/// width up to `max_seq` stays inside the preallocated capacity).
#[derive(Debug, Clone)]
pub struct DecodeScratch {
    /// Residual stream `[rows, d]`.
    x: Matrix,
    /// Norm output / quantized linear input `[rows, d]`.
    nrm: Matrix,
    /// Fused q|k|v activations `[rows, 3d]`.
    qkv: Matrix,
    /// Attention context `[rows, d]`.
    ctx: Matrix,
    /// Residual-branch projection output `[rows, d]`.
    proj: Matrix,
    /// MLP hidden: `[rows, ff]` (Opt) or fused gate|up `[rows, 2ff]` (Llama).
    hidden: Matrix,
    /// Llama silu(gate)·up `[rows, ff]` (empty for Opt).
    act2: Matrix,
    /// Attention score row (`max_seq`) — shared by the full-recompute and
    /// the KV-cached attention kernels (one query row at a time each).
    scores: Vec<f32>,
    /// Decode strips of the packed GEMV: the weight-row strip (`max(d,
    /// ff)`), the LoRC error-row strip (same length) and the decoded-E₂
    /// strip (sized by [`CompiledModel::scratch`] to the largest LoRC
    /// attachment in the plan — the arena's rank-r strip, so LoRC decode
    /// stays allocation-free). Unused by the dense layout.
    gemv: GemvScratch,
    /// Output logits `[rows, vocab]`.
    logits: Matrix,
}

/// Where the unified layer walk (`CompiledModel::run_mode`) sources
/// attention K/V — and, implicitly, how token positions are assigned.
enum KvMode<'a> {
    /// Full-window recompute: K/V live in the fused qkv scratch buffer,
    /// token `t` sits at position `t`. (`forward` / calibration / scoring.)
    Off,
    /// One sequence extending through a cache: `tokens` is the next
    /// contiguous chunk, token `t` sits at position `cache.len() + t`.
    /// (`prefill`, and `decode_step` as the 1-token case.)
    Seq(&'a mut KvCache),
    /// One token from each of several independent sequences (continuous
    /// batching): token `b` sits at position `caches[b].len()`.
    Batch(&'a mut [KvCache]),
}

impl DecodeScratch {
    pub fn new(cfg: &ModelConfig) -> DecodeScratch {
        Self::with_lorc_capacity(cfg, 0)
    }

    /// Arena with the decoded-E₂ strip sized for `e2_elems` elements (the
    /// largest LoRC attachment of the plan; 0 for LoRC-free plans).
    /// [`CompiledModel::scratch`] computes the right capacity — use that.
    pub fn with_lorc_capacity(cfg: &ModelConfig, e2_elems: usize) -> DecodeScratch {
        let s = cfg.max_seq;
        let d = cfg.d_model;
        let (hidden_cols, act2_rows) = match cfg.arch {
            Arch::Opt => (cfg.d_ff, 0),
            Arch::Llama => (2 * cfg.d_ff, s),
        };
        DecodeScratch {
            x: Matrix::zeros(s, d),
            nrm: Matrix::zeros(s, d),
            qkv: Matrix::zeros(s, 3 * d),
            ctx: Matrix::zeros(s, d),
            proj: Matrix::zeros(s, d),
            hidden: Matrix::zeros(s, hidden_cols),
            act2: Matrix::zeros(act2_rows, cfg.d_ff),
            scores: vec![0.0; s],
            gemv: GemvScratch::sized(d.max(cfg.d_ff), e2_elems),
            logits: Matrix::zeros(s, cfg.vocab_size),
        }
    }
}

impl CompiledModel {
    /// Resolve + prepack a checkpoint under the given engine options.
    /// All string-keyed lookups, transposes and LUT builds happen here.
    /// Dense layout only — the packed layout needs the quantized-code
    /// sidecar, so use [`compile_quantized`](Self::compile_quantized).
    pub fn compile(ck: &Checkpoint, opts: EngineOpts) -> CompiledModel {
        assert!(
            opts.weights.is_dense(),
            "packed weight layout needs the quantized-code sidecar: \
             use CompiledModel::compile_quantized"
        );
        Self::build(ck, None, opts)
    }

    /// Like [`compile`](Self::compile), but with the PTQ run's
    /// quantized-artifact sidecar
    /// ([`crate::pipeline::ptq`]). When
    /// `opts.weights` selects [`WeightLayout::Packed`], every transformer
    /// linear is stored as bit-packed codes and executed by the fused
    /// dequant GEMV — bit-identical to the dense plan over the same
    /// (fake-quantized) checkpoint, at a fraction of the resident weight
    /// bytes (`tests/packed_equivalence.rs` enforces both claims). Sidecar
    /// entries carrying LoRC factors attach them to their slot: the GEMV
    /// folds the low-rank compensation into each decoded row, so a
    /// packed+LoRC plan stays bit-identical to the dense plan over the
    /// LoRC-*folded* effective checkpoint on every execution path
    /// (`tests/lorc_equivalence.rs`). With a dense layout the sidecar is
    /// ignored (the effective checkpoint already carries the fold).
    pub fn compile_quantized(
        ck: &Checkpoint,
        sidecar: &QuantSidecar,
        opts: EngineOpts,
    ) -> CompiledModel {
        Self::build(ck, Some(sidecar), opts)
    }

    fn build(ck: &Checkpoint, sidecar: Option<&QuantSidecar>, opts: EngineOpts) -> CompiledModel {
        let cfg = ck.config.clone();
        let threads = opts.weights.threads();
        // One linear slot: dense prepack, or packed codes (+ optional LoRC
        // factors) from the sidecar.
        let linear = |parts: &[(String, Option<String>)]| -> LayerWeights {
            match (&opts.weights, sidecar) {
                (WeightLayout::Packed { .. }, Some(sc)) => {
                    let qparts: Vec<QPart<'_>> = parts
                        .iter()
                        .map(|(w, b)| {
                            let e = sc.entry(w.as_str()).unwrap_or_else(|| {
                                panic!(
                                    "packed layout: no quantized codes for {w} in the sidecar \
                                     (a W16 scheme quantizes nothing and cannot pack)"
                                )
                            });
                            (&e.weight, e.lorc.as_ref(), b.as_ref().map(|b| ck.get(b)))
                        })
                        .collect();
                    LayerWeights::Packed(PackedQLinear::pack(&qparts))
                }
                (WeightLayout::Packed { .. }, None) => {
                    panic!("packed weight layout needs the quantized-code sidecar")
                }
                (WeightLayout::Dense, _) => {
                    let dparts: Vec<(&Matrix, Option<&Matrix>)> = parts
                        .iter()
                        .map(|(w, b)| (ck.get(w), b.as_ref().map(|b| ck.get(b))))
                        .collect();
                    LayerWeights::Dense(PackedLinear::pack(&dparts))
                }
            }
        };
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for layer in 0..cfg.n_layers {
            let p = format!("layers.{layer}");
            let ln1 = CompiledNorm::from_ck(ck, &format!("{p}.ln1"));
            let qkv = linear(&[
                (format!("{p}.attn.q.w"), Some(format!("{p}.attn.q.b"))),
                (format!("{p}.attn.k.w"), Some(format!("{p}.attn.k.b"))),
                (format!("{p}.attn.v.w"), Some(format!("{p}.attn.v.b"))),
            ]);
            let out_proj = linear(&[(format!("{p}.attn.o.w"), Some(format!("{p}.attn.o.b")))]);
            let ln2 = CompiledNorm::from_ck(ck, &format!("{p}.ln2"));
            let mlp = match cfg.arch {
                Arch::Opt => CompiledMlp::Relu {
                    fc1: linear(&[(format!("{p}.mlp.fc1.w"), Some(format!("{p}.mlp.fc1.b")))]),
                    fc2: linear(&[(format!("{p}.mlp.fc2.w"), Some(format!("{p}.mlp.fc2.b")))]),
                },
                Arch::Llama => CompiledMlp::GatedSilu {
                    gate_up: linear(&[
                        (format!("{p}.mlp.gate.w"), None),
                        (format!("{p}.mlp.up.w"), None),
                    ]),
                    down: linear(&[(format!("{p}.mlp.down.w"), Some(format!("{p}.mlp.down.b")))]),
                },
            };
            layers.push(CompiledLayer { ln1, qkv, out_proj, ln2, mlp });
        }
        let act = match opts.act.format {
            NumericFormat::F16 => ActPath::Noop,
            NumericFormat::Fp(f) => ActPath::Lut(FpQuantLut::new(f)),
            other => ActPath::Oracle(other),
        };
        CompiledModel {
            embed: ck.get("embed").clone(),
            pos: ck.get("pos_embed").clone(),
            final_norm: CompiledNorm::from_ck(ck, "final_norm"),
            config: cfg,
            opts,
            layers,
            act,
            kernels: crate::kernels::for_tier(opts.kernels, threads),
        }
    }

    /// The kernel backend this plan executes through (tier selected by
    /// [`EngineOpts::kernels`]).
    pub fn kernels(&self) -> &dyn Kernels {
        self.kernels.as_ref()
    }

    /// Resident bytes of the transformer linears' weight payloads (the
    /// part the packed layout shrinks; embeddings and norms are identical
    /// across layouts and excluded so the dense-vs-packed ratio is the
    /// honest one).
    pub fn linear_weight_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.weight_bytes()).sum()
    }

    /// A fresh arena sized for this model's `max_seq` — including the
    /// decoded-E₂ strip for the largest LoRC attachment in the plan, so
    /// LoRC decode is allocation-free from the first call.
    pub fn scratch(&self) -> DecodeScratch {
        let e2 = self.layers.iter().map(|l| l.lorc_e2_elems()).max().unwrap_or(0);
        DecodeScratch::with_lorc_capacity(&self.config, e2)
    }

    /// A fresh exact (f32) K/V cache sized for this model's `max_seq`.
    pub fn kv_cache(&self) -> KvCache {
        KvCache::new(&self.config)
    }

    /// A fresh K/V cache that stores rows fake-quantized to `fmt` (e.g.
    /// [`FpFormat::E4M3`] for an FP8 cache). See [`kv`] for the contract.
    pub fn kv_cache_quantized(&self, fmt: FpFormat) -> KvCache {
        KvCache::quantized(&self.config, fmt)
    }

    /// A shared block-paged K/V pool for this model: `page_positions`
    /// positions per page, as many pages as `budget_bytes` buys (clamped so
    /// one `max_seq` sequence always fits), minting caches that quantize to
    /// `quant` on append. Decode through pool-minted caches is bit-identical
    /// to the per-sequence rings — see [`kv`] for the paged layout and
    /// accounting contract.
    pub fn kv_page_pool(
        &self,
        page_positions: usize,
        budget_bytes: usize,
        quant: Option<FpFormat>,
    ) -> KvPagePool {
        KvPagePool::new(&self.config, page_positions, budget_bytes, quant)
    }

    /// Full-window forward pass into the arena; returns the logits buffer
    /// `[seq, vocab]`. Allocation-free once `s` is warm.
    ///
    /// This recomputes attention over the whole window — it is the oracle
    /// the incremental path is checked against and the scoring/calibration
    /// entry point. The serving *decode* loop should use
    /// [`prefill`](Self::prefill) + [`decode_step`](Self::decode_step),
    /// which produce bit-identical logits in `O(n·d)` per token.
    pub fn forward<'s>(&self, tokens: &[u16], s: &'s mut DecodeScratch) -> &'s Matrix {
        self.forward_observed(tokens, s, &mut |_, _| {})
    }

    /// Forward pass reporting every linear input (pre activation-quant) to
    /// `observe` — the calibration entry point (GPTQ Hessian accumulation),
    /// mirroring `Engine::forward_observed` site for site.
    pub fn forward_observed<'s>(
        &self,
        tokens: &[u16],
        s: &'s mut DecodeScratch,
        observe: &mut dyn FnMut(Site, &Matrix),
    ) -> &'s Matrix {
        self.run_mode(tokens, KvMode::Off, s, observe)
    }

    /// Run the prompt through the model, appending every layer's K/V rows
    /// for `tokens` to `cache`; returns the logits buffer `[seq, vocab]`.
    ///
    /// The cache may already hold earlier positions (chunked prefill): the
    /// new tokens are treated as the next contiguous chunk of the same
    /// sequence and attend over everything cached so far. With an exact
    /// cache, `prefill` over a whole window is bit-identical to
    /// [`forward`](Self::forward) over that window, and any
    /// `prefill`/`decode_step` split of the window produces the same bits
    /// (`tests/kv_equivalence.rs`). Allocation-free once warm.
    pub fn prefill<'s>(
        &self,
        tokens: &[u16],
        cache: &mut KvCache,
        s: &'s mut DecodeScratch,
    ) -> &'s Matrix {
        self.run_mode(tokens, KvMode::Seq(cache), s, &mut |_, _| {})
    }

    /// [`prefill`](Self::prefill) in deadline-checkable chunks: runs the
    /// prompt `chunk` tokens at a time and calls `probe(tokens_done)`
    /// before each chunk; a `false` return abandons the prefill and
    /// yields `None` (the cache then holds only the chunks committed so
    /// far — callers reset before reuse). Because any prefill split of a
    /// window produces the same bits (the chunked-prefill contract
    /// asserted by `tests/kv_equivalence.rs`), the completed path is
    /// bit-identical to a one-shot `prefill` regardless of `chunk`.
    pub fn prefill_with_probe<'s>(
        &self,
        tokens: &[u16],
        cache: &mut KvCache,
        s: &'s mut DecodeScratch,
        chunk: usize,
        probe: &mut dyn FnMut(usize) -> bool,
    ) -> Option<&'s Matrix> {
        assert!(chunk >= 1, "prefill chunk must be at least 1 token");
        assert!(!tokens.is_empty(), "prefill needs at least one token");
        let mut done = 0usize;
        while tokens.len() - done > chunk {
            if !probe(done) {
                return None;
            }
            let _ = self.run_mode(
                &tokens[done..done + chunk],
                KvMode::Seq(cache),
                &mut *s,
                &mut |_, _| {},
            );
            done += chunk;
        }
        if !probe(done) {
            return None;
        }
        Some(self.run_mode(&tokens[done..], KvMode::Seq(cache), s, &mut |_, _| {}))
    }

    /// Prefill only the *delta* of a sequence the cache already partially
    /// holds: given the full token history, runs the suffix
    /// `full_tokens[cache.len()..]` through
    /// [`prefill_with_probe`](Self::prefill_with_probe) — the multi-turn
    /// session entry point. The cache must hold a strict prefix of
    /// `full_tokens` (the session layer maintains that invariant; an
    /// evicted/empty cache degenerates to a full prefill of the whole
    /// history).
    ///
    /// By the chunked-prefill split-invariance contract
    /// (`tests/kv_equivalence.rs`), the logits of the final chunk — and
    /// every K/V row appended — are bit-identical to a fresh one-shot
    /// prefill of `full_tokens`, no matter where previous turns left the
    /// prefix boundary. That identity is what makes a session turn
    /// token-for-token equal to a one-shot generate over the
    /// concatenated conversation.
    pub fn prefill_delta<'s>(
        &self,
        full_tokens: &[u16],
        cache: &mut KvCache,
        s: &'s mut DecodeScratch,
        chunk: usize,
        probe: &mut dyn FnMut(usize) -> bool,
    ) -> Option<&'s Matrix> {
        assert!(
            cache.len() < full_tokens.len(),
            "prefill_delta: cache holds {} of {} tokens — nothing new to prefill",
            cache.len(),
            full_tokens.len()
        );
        self.prefill_with_probe(&full_tokens[cache.len()..], cache, s, chunk, probe)
    }

    /// Decode one token at the next position of `cache`'s sequence,
    /// computing attention only for that position; returns the logits row
    /// `[1, vocab]`. Bit-identical to the corresponding row of a
    /// full-window [`forward`](Self::forward) (exact cache). Zero heap
    /// allocations once `s` and `cache` are warm (`tests/plan_alloc.rs`).
    pub fn decode_step<'s>(
        &self,
        token: u16,
        cache: &mut KvCache,
        s: &'s mut DecodeScratch,
    ) -> &'s Matrix {
        self.run_mode(std::slice::from_ref(&token), KvMode::Seq(cache), s, &mut |_, _| {})
    }

    /// One interleaved decode step for several independent sequences
    /// (continuous batching): row `b` of the returned `[B, vocab]` logits
    /// is the next-token distribution of the sequence behind `caches[b]`.
    ///
    /// Each row is bit-identical to a solo [`decode_step`](Self::decode_step)
    /// of that sequence — batching exists purely to amortize weight-matrix
    /// streaming across sequences (every linear runs as one `[B, ·]` matmul
    /// instead of `B` single-row matmuls), which is where CPU decode
    /// throughput comes from (§Perf in EXPERIMENTS.md sweeps `B`).
    pub fn decode_step_batch<'s>(
        &self,
        tokens: &[u16],
        caches: &mut [KvCache],
        s: &'s mut DecodeScratch,
    ) -> &'s Matrix {
        self.run_mode(tokens, KvMode::Batch(caches), s, &mut |_, _| {})
    }

    /// The single layer walk behind `forward`, `prefill` and `decode_step*`:
    /// one code path, so the bit-equivalence between the full-recompute and
    /// cached-decode paths is structural rather than re-implemented. The
    /// modes differ only in token positions and in where attention reads
    /// K/V; every other operation is row-local (see `tests/kv_equivalence.rs`
    /// for the enforced contract).
    fn run_mode<'s>(
        &self,
        tokens: &[u16],
        mut kv: KvMode<'_>,
        s: &'s mut DecodeScratch,
        observe: &mut dyn FnMut(Site, &Matrix),
    ) -> &'s Matrix {
        let cfg = &self.config;
        let k = self.kernels.as_ref();
        let rows = tokens.len();
        let d = cfg.d_model;
        match &kv {
            KvMode::Off => {
                assert!(rows <= cfg.max_seq, "sequence {rows} exceeds max_seq {}", cfg.max_seq);
            }
            KvMode::Seq(cache) => {
                assert!(rows >= 1, "prefill/decode needs at least one token");
                assert!(
                    !cache.is_quarantined(),
                    "refusing to decode through a quarantined kv cache"
                );
                assert!(
                    cache.len() + rows <= cfg.max_seq,
                    "{} cached + {rows} new tokens exceeds max_seq {}",
                    cache.len(),
                    cfg.max_seq
                );
                // a ring always has max_seq reserved; a paged cache only
                // what the pool checked out (KvPagePool::reserve first)
                assert!(
                    rows <= cache.remaining(),
                    "{rows} new tokens exceed the cache's reserved capacity \
                     ({} of {} positions free)",
                    cache.remaining(),
                    cache.capacity()
                );
            }
            KvMode::Batch(caches) => {
                assert!(rows >= 1, "decode batch must be non-empty");
                assert_eq!(rows, caches.len(), "decode batch needs one cache per sequence");
                // the arena is pre-sized for max_seq rows; a wider batch
                // would silently reallocate every buffer per step
                assert!(rows <= cfg.max_seq, "decode batch {rows} exceeds max_seq {}", cfg.max_seq);
                for c in caches.iter() {
                    assert!(
                        !c.is_quarantined(),
                        "refusing to decode through a quarantined kv cache"
                    );
                    assert!(c.len() < cfg.max_seq, "a batched sequence is already at max_seq");
                    assert!(
                        c.remaining() >= 1,
                        "a batched sequence has no reserved position left \
                         (KvPagePool::reserve before each step)"
                    );
                }
            }
        }

        s.x.resize_to(rows, d);
        for (t, &tok) in tokens.iter().enumerate() {
            let pos = match &kv {
                KvMode::Off => t,
                KvMode::Seq(cache) => cache.len() + t,
                KvMode::Batch(caches) => caches[t].len(),
            };
            let e = self.embed.row(tok as usize);
            let p = self.pos.row(pos);
            let row = s.x.row_mut(t);
            for i in 0..d {
                row[i] = e[i] + p[i];
            }
        }

        for (layer, cl) in self.layers.iter().enumerate() {
            // ---- attention ----
            cl.ln1.run_into(&s.x, &mut s.nrm, k);
            observe(Site { layer, site: LinearSite::Qkv }, &s.nrm);
            self.actq(&mut s.nrm);
            cl.qkv.run_into(&s.nrm, &mut s.qkv, &mut s.gemv, k);
            match &mut kv {
                KvMode::Off => {
                    attention_into(cfg, &s.qkv, &mut s.ctx, &mut s.scores, k);
                }
                KvMode::Seq(cache) => {
                    // stage the new K/V rows, then attend each new position
                    // over the cache (which now includes them)
                    let base = cache.len();
                    for t in 0..rows {
                        let row = s.qkv.row(t);
                        cache.store(layer, base + t, &row[d..2 * d], &row[2 * d..]);
                    }
                    s.ctx.resize_to(rows, d);
                    let view = cache.layer(layer);
                    for t in 0..rows {
                        attend_cached_row(
                            cfg,
                            &s.qkv.row(t)[..d],
                            view,
                            base + t,
                            s.ctx.row_mut(t),
                            &mut s.scores,
                            k,
                        );
                    }
                }
                KvMode::Batch(caches) => {
                    s.ctx.resize_to(rows, d);
                    for t in 0..rows {
                        let pos = caches[t].len();
                        let row = s.qkv.row(t);
                        caches[t].store(layer, pos, &row[d..2 * d], &row[2 * d..]);
                        let view = caches[t].layer(layer);
                        attend_cached_row(
                            cfg,
                            &s.qkv.row(t)[..d],
                            view,
                            pos,
                            s.ctx.row_mut(t),
                            &mut s.scores,
                            k,
                        );
                    }
                }
            }
            observe(Site { layer, site: LinearSite::OutProj }, &s.ctx);
            self.actq(&mut s.ctx);
            cl.out_proj.run_into(&s.ctx, &mut s.proj, &mut s.gemv, k);
            s.x.add_assign(&s.proj);
            // ---- mlp ----
            cl.ln2.run_into(&s.x, &mut s.nrm, k);
            observe(Site { layer, site: LinearSite::Fc1 }, &s.nrm);
            self.actq(&mut s.nrm);
            match &cl.mlp {
                CompiledMlp::Relu { fc1, fc2 } => {
                    fc1.run_into(&s.nrm, &mut s.hidden, &mut s.gemv, k);
                    for v in s.hidden.data.iter_mut() {
                        *v = v.max(0.0); // relu
                    }
                    observe(Site { layer, site: LinearSite::Fc2 }, &s.hidden);
                    self.actq(&mut s.hidden);
                    fc2.run_into(&s.hidden, &mut s.proj, &mut s.gemv, k);
                }
                CompiledMlp::GatedSilu { gate_up, down } => {
                    gate_up.run_into(&s.nrm, &mut s.hidden, &mut s.gemv, k); // [rows, 2ff]
                    let ff = cfg.d_ff;
                    s.act2.resize_to(rows, ff);
                    for r in 0..rows {
                        let hrow = s.hidden.row(r);
                        let arow = s.act2.row_mut(r);
                        for c in 0..ff {
                            let g = hrow[c];
                            let u = hrow[ff + c];
                            let sl = g / (1.0 + (-g).exp()); // silu
                            arow[c] = sl * u;
                        }
                    }
                    observe(Site { layer, site: LinearSite::Fc2 }, &s.act2);
                    self.actq(&mut s.act2);
                    down.run_into(&s.act2, &mut s.proj, &mut s.gemv, k);
                }
            }
            s.x.add_assign(&s.proj);
        }

        // commit the staged cache positions
        match &mut kv {
            KvMode::Off => {}
            KvMode::Seq(cache) => cache.advance(rows),
            KvMode::Batch(caches) => {
                for c in caches.iter_mut() {
                    c.advance(1);
                }
            }
        }

        self.final_norm.run_into(&s.x, &mut s.nrm, k);
        // tied LM head: logits = x @ embedᵀ — the embed matrix is already in
        // the `[n, k]` layout the bt kernel wants, no prepack needed.
        s.logits.resize_to(rows, cfg.vocab_size);
        matmul::matmul_bt_into(&s.nrm, &self.embed, &mut s.logits);
        &s.logits
    }

    /// Convenience for tests/one-shot callers: forward with a throwaway
    /// arena, returning owned logits.
    pub fn forward_alloc(&self, tokens: &[u16]) -> Matrix {
        let mut s = self.scratch();
        self.forward(tokens, &mut s);
        s.logits
    }

    /// Summed teacher-forced NLL of one window (positions `1..len` scored),
    /// the quantity the serving scorer returns per request. Allocation-free.
    pub fn score_nll(&self, window: &[u16], s: &mut DecodeScratch) -> f32 {
        assert!(window.len() >= 2, "scoring needs at least 2 tokens");
        let logits = self.forward(window, s);
        logits_nll(logits, window) as f32
    }

    /// Token-wise activation fake-quant, dispatched through the plan's
    /// precompiled path. Bit-identical to the reference engine's
    /// `fake_quant_tokenwise` for every `NumericFormat`.
    fn actq(&self, m: &mut Matrix) {
        match &self.act {
            ActPath::Noop => {}
            ActPath::Lut(lut) => {
                for r in 0..m.rows {
                    lut.fake_quant_row(m.row_mut(r));
                }
            }
            ActPath::Oracle(fmt) => {
                for r in 0..m.rows {
                    fmt.fake_quant_slice_dynamic(m.row_mut(r));
                }
            }
        }
    }
}

/// Summed teacher-forced NLL of `window` from its already-computed logits
/// (`logits.row(t)` predicts `window[t+1]`): the crate's one definition of
/// the per-window scoring quantity, shared by [`CompiledModel::score_nll`]
/// and callers that already hold the logits.
pub fn logits_nll(logits: &Matrix, window: &[u16]) -> f64 {
    debug_assert!(logits.rows + 1 >= window.len());
    let mut nll_sum = 0.0f64;
    for (t, &target) in window[1..].iter().enumerate() {
        let row = logits.row(t);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse: f64 = row.iter().map(|&x| ((x - mx) as f64).exp()).sum::<f64>().ln() + mx as f64;
        nll_sum += lse - row[target as usize] as f64;
    }
    nll_sum
}

/// Multi-head causal self-attention over the fused q|k|v buffer `[seq, 3d]`
/// (q at column 0, k at `d`, v at `2d`), writing `[seq, d]` into `ctx`.
/// The exact arithmetic of `Engine::attention`.
fn attention_into(
    cfg: &ModelConfig,
    qkv: &Matrix,
    ctx: &mut Matrix,
    scores: &mut [f32],
    k: &dyn Kernels,
) {
    let seq = qkv.rows;
    let d = cfg.d_model;
    let h = cfg.n_heads;
    let dh = cfg.head_dim();
    let scale = 1.0 / (dh as f32).sqrt();
    ctx.resize_to(seq, d);
    let scores = &mut scores[..seq];
    for head in 0..h {
        let off = head * dh;
        for i in 0..seq {
            let qrow = &qkv.row(i)[off..off + dh];
            // scores over j <= i
            for (j, sc) in scores.iter_mut().enumerate().take(i + 1) {
                let krow = &qkv.row(j)[d + off..d + off + dh];
                let mut dot = 0.0f32;
                for t in 0..dh {
                    dot += qrow[t] * krow[t];
                }
                *sc = dot * scale;
            }
            // The backend's softmax replicates the original inline
            // max/exp/normalize operation order, so extracting it keeps
            // the attention weights bit-identical (the normalized weight
            // `p` below equals the old `exp · inv` product exactly).
            k.softmax(&mut scores[..i + 1]);
            let crow = &mut ctx.row_mut(i)[off..off + dh];
            for (j, &p) in scores.iter().enumerate().take(i + 1) {
                let vrow = &qkv.row(j)[2 * d + off..2 * d + off + dh];
                for t in 0..dh {
                    crow[t] += p * vrow[t];
                }
            }
        }
    }
}

/// Causal attention for **one** query row at absolute position `pos`,
/// reading K/V rows `0..=pos` from a cache layer view and accumulating into
/// the (zeroed) context row. This is the per-`(head, i)` body of
/// [`attention_into`] with the K/V loads redirected at the cache — the same
/// dot/softmax/weighted-sum operations in the same order, which is what
/// makes cached decode bit-identical to full recompute (exact cache). The
/// view resolves each position to its row (ring offset or page cell)
/// *outside* the arithmetic, so the ring and paged layouts produce
/// identical bits by construction.
fn attend_cached_row(
    cfg: &ModelConfig,
    qrow: &[f32],
    kv: KvLayerView<'_>,
    pos: usize,
    crow: &mut [f32],
    scores: &mut [f32],
    k: &dyn Kernels,
) {
    let dh = cfg.head_dim();
    let scale = 1.0 / (dh as f32).sqrt();
    let scores = &mut scores[..pos + 1];
    for head in 0..cfg.n_heads {
        let off = head * dh;
        let q = &qrow[off..off + dh];
        for (j, sc) in scores.iter_mut().enumerate() {
            let krow = &kv.k_row(j)[off..off + dh];
            let mut dot = 0.0f32;
            for t in 0..dh {
                dot += q[t] * krow[t];
            }
            *sc = dot * scale;
        }
        // Same bit-preserving softmax extraction as `attention_into`.
        k.softmax(scores);
        let c = &mut crow[off..off + dh];
        for (j, &p) in scores.iter().enumerate() {
            let vrow = &kv.v_row(j)[off..off + dh];
            for t in 0..dh {
                c[t] += p * vrow[t];
            }
        }
    }
}

/// Greedy sampling: index of the largest logit (lowest index wins ties —
/// deterministic, so coordinator-served generation can be checked against a
/// direct decode loop).
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &x) in row.iter().enumerate() {
        if x > best_v {
            best_v = x;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn tiny(arch: Arch) -> ModelConfig {
        ModelConfig {
            name: "plan-test".into(),
            arch,
            vocab_size: 48,
            d_model: 24,
            n_heads: 3,
            n_layers: 2,
            d_ff: 48,
            max_seq: 16,
        }
    }

    #[test]
    fn pack_matches_transpose() {
        let mut rng = Rng::seeded(211);
        let w1 = Matrix::randn(5, 7, 1.0, &mut rng);
        let w2 = Matrix::randn(3, 7, 1.0, &mut rng);
        let b1 = Matrix::randn(1, 5, 1.0, &mut rng);
        let b2 = Matrix::randn(1, 3, 1.0, &mut rng);
        let p = PackedLinear::pack(&[(&w1, Some(&b1)), (&w2, Some(&b2))]);
        assert_eq!((p.d_in, p.d_out), (7, 8));
        let t1 = w1.transpose();
        let t2 = w2.transpose();
        for k in 0..7 {
            for j in 0..5 {
                assert_eq!(p.wt.at(k, j), t1.at(k, j));
            }
            for j in 0..3 {
                assert_eq!(p.wt.at(k, 5 + j), t2.at(k, j));
            }
        }
        assert_eq!(&p.bias[..5], &b1.data[..]);
        assert_eq!(&p.bias[5..], &b2.data[..]);
    }

    #[test]
    fn run_into_equals_unfused_linears() {
        let mut rng = Rng::seeded(212);
        let w1 = Matrix::randn(6, 10, 0.3, &mut rng);
        let w2 = Matrix::randn(4, 10, 0.3, &mut rng);
        let x = Matrix::randn(9, 10, 1.0, &mut rng);
        let p = PackedLinear::pack(&[(&w1, None), (&w2, None)]);
        let mut out = Matrix::zeros(0, 0);
        p.run_into(&x, &mut out, &crate::kernels::OracleKernels::new(1));
        let y1 = x.matmul(&w1.transpose());
        let y2 = x.matmul(&w2.transpose());
        for r in 0..9 {
            for c in 0..6 {
                assert_eq!(out.at(r, c), y1.at(r, c));
            }
            for c in 0..4 {
                assert_eq!(out.at(r, 6 + c), y2.at(r, c));
            }
        }
    }

    #[test]
    fn forward_shapes_and_finite() {
        for arch in [Arch::Opt, Arch::Llama] {
            let mut rng = Rng::seeded(213);
            let ck = Checkpoint::random(&tiny(arch), &mut rng);
            let model = CompiledModel::compile(&ck, EngineOpts::default());
            let mut s = model.scratch();
            let logits = model.forward(&[1, 2, 3, 4, 5], &mut s);
            assert_eq!((logits.rows, logits.cols), (5, 48));
            assert!(logits.data.iter().all(|x| x.is_finite()));
        }
    }

    #[test]
    fn scratch_is_reusable_across_lengths() {
        let mut rng = Rng::seeded(214);
        let ck = Checkpoint::random(&tiny(Arch::Llama), &mut rng);
        let model = CompiledModel::compile(&ck, EngineOpts::default());
        let mut s = model.scratch();
        let long = model.forward(&[1, 2, 3, 4, 5, 6, 7, 8], &mut s).clone();
        let _short = model.forward(&[9, 9], &mut s);
        let long2 = model.forward(&[1, 2, 3, 4, 5, 6, 7, 8], &mut s);
        assert_eq!(&long.data, &long2.data, "scratch reuse must not leak state");
    }

    #[test]
    fn observer_sees_all_sites() {
        let mut rng = Rng::seeded(215);
        let ck = Checkpoint::random(&tiny(Arch::Opt), &mut rng);
        let model = CompiledModel::compile(&ck, EngineOpts::default());
        let mut s = model.scratch();
        let mut seen = std::collections::HashSet::new();
        model.forward_observed(&[1, 2, 3], &mut s, &mut |site, x| {
            assert_eq!(x.rows, 3);
            seen.insert(site);
        });
        assert_eq!(seen.len(), 2 * 4);
    }

    #[test]
    fn score_nll_matches_eval_cross_entropy() {
        let mut rng = Rng::seeded(216);
        let ck = Checkpoint::random(&tiny(Arch::Opt), &mut rng);
        let model = CompiledModel::compile(&ck, EngineOpts::default());
        let mut s = model.scratch();
        let window = [3u16, 1, 4, 1, 5, 9, 2, 6];
        let nll = model.score_nll(&window, &mut s) as f64;
        let logits = model.forward_alloc(&window);
        let pred = Matrix::from_vec(
            window.len() - 1,
            logits.cols,
            logits.data[..(window.len() - 1) * logits.cols].to_vec(),
        );
        let r = crate::eval::cross_entropy(&pred, &window[1..]);
        assert!((nll - r.nll_sum).abs() < 1e-4, "{nll} vs {}", r.nll_sum);
    }

    #[test]
    fn argmax_picks_lowest_index_on_ties() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
        assert_eq!(argmax(&[f32::NEG_INFINITY, 0.0]), 1);
    }

    #[test]
    fn prefill_then_decode_matches_forward_smoke() {
        // the exhaustive property test lives in tests/kv_equivalence.rs;
        // this is the in-crate smoke check.
        let mut rng = Rng::seeded(217);
        let ck = Checkpoint::random(&tiny(Arch::Llama), &mut rng);
        let model = CompiledModel::compile(&ck, EngineOpts::default());
        let mut s = model.scratch();
        let window = [3u16, 1, 4, 1, 5, 9, 2, 6];
        let full = model.forward(&window, &mut s).clone();
        let mut cache = model.kv_cache();
        let pre = model.prefill(&window[..5], &mut cache, &mut s).clone();
        for (t, row) in pre.data.chunks_exact(pre.cols).enumerate() {
            for (a, b) in row.iter().zip(full.row(t)) {
                assert_eq!(a.to_bits(), b.to_bits(), "prefill row {t}");
            }
        }
        for (t, &tok) in window[5..].iter().enumerate() {
            let step = model.decode_step(tok, &mut cache, &mut s);
            for (a, b) in step.row(0).iter().zip(full.row(5 + t)) {
                assert_eq!(a.to_bits(), b.to_bits(), "decode row {}", 5 + t);
            }
        }
        assert_eq!(cache.len(), window.len());
    }

    #[test]
    fn batched_decode_matches_sequential_decode() {
        let mut rng = Rng::seeded(218);
        let ck = Checkpoint::random(&tiny(Arch::Opt), &mut rng);
        let model = CompiledModel::compile(&ck, EngineOpts::default());
        let mut s = model.scratch();
        // two sequences with different prompts and lengths
        let p0: Vec<u16> = vec![1, 2, 3];
        let p1: Vec<u16> = vec![7, 8, 9, 10, 11];
        let mut solo0 = model.kv_cache();
        let mut solo1 = model.kv_cache();
        model.prefill(&p0, &mut solo0, &mut s);
        model.prefill(&p1, &mut solo1, &mut s);
        let a0 = model.decode_step(4, &mut solo0, &mut s).clone();
        let a1 = model.decode_step(12, &mut solo1, &mut s).clone();

        let mut caches = vec![model.kv_cache(), model.kv_cache()];
        model.prefill(&p0, &mut caches[0], &mut s);
        model.prefill(&p1, &mut caches[1], &mut s);
        let b = model.decode_step_batch(&[4, 12], &mut caches, &mut s);
        assert_eq!(b.rows, 2);
        for (x, y) in b.row(0).iter().zip(&a0.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (x, y) in b.row(1).iter().zip(&a1.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!((caches[0].len(), caches[1].len()), (4, 6));
    }

    #[test]
    fn probed_prefill_matches_one_shot_and_aborts_cleanly() {
        let mut rng = Rng::seeded(219);
        let ck = Checkpoint::random(&tiny(Arch::Opt), &mut rng);
        let model = CompiledModel::compile(&ck, EngineOpts::default());
        let mut s = model.scratch();
        let window = [3u16, 1, 4, 1, 5, 9, 2, 6];
        let mut oracle = model.kv_cache();
        let full = model.prefill(&window, &mut oracle, &mut s).clone();

        // completed probe runs are bit-identical for every chunk size
        for chunk in [1usize, 3, 8, 100] {
            let mut cache = model.kv_cache();
            let mut probes = Vec::new();
            let logits = model
                .prefill_with_probe(&window, &mut cache, &mut s, chunk, &mut |done| {
                    probes.push(done);
                    true
                })
                .expect("probe never aborts");
            for (a, b) in logits.row(logits.rows - 1).iter().zip(full.row(full.rows - 1)) {
                assert_eq!(a.to_bits(), b.to_bits(), "chunk {chunk}");
            }
            assert_eq!(cache.len(), window.len());
            assert_eq!(probes[0], 0, "probed before any work");
            assert!(probes.len() >= window.len().div_ceil(chunk));
        }

        // an aborting probe stops the walk; the cache holds only the
        // committed chunks and a reset makes it reusable
        let mut cache = model.kv_cache();
        let out = model.prefill_with_probe(&window, &mut cache, &mut s, 3, &mut |done| done < 3);
        assert!(out.is_none());
        assert_eq!(cache.len(), 3, "one 3-token chunk committed before the abort");
        cache.reset();
        let again = model.prefill(&window, &mut cache, &mut s);
        for (a, b) in again.row(again.rows - 1).iter().zip(full.row(full.rows - 1)) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    #[should_panic(expected = "quarantined")]
    fn decode_refuses_quarantined_cache() {
        let mut rng = Rng::seeded(220);
        let ck = Checkpoint::random(&tiny(Arch::Opt), &mut rng);
        let model = CompiledModel::compile(&ck, EngineOpts::default());
        let mut s = model.scratch();
        let mut cache = model.kv_cache();
        model.prefill(&[1, 2, 3], &mut cache, &mut s);
        cache.quarantine();
        let _ = model.decode_step(4, &mut cache, &mut s);
    }
}
