//! Self-speculative decoding: draft tokens on a cheap plan of a
//! checkpoint, verify them in one batched pass on the target plan, accept
//! the agreeing prefix — **exact greedy parity by construction**.
//!
//! # The loop
//!
//! Draft and target are two compiled plans of the *same* checkpoint (e.g.
//! packed rank-0 W4 with fast kernels drafting for the dense W4+LoRC
//! target — see `ServingStack::compile_draft` in the coordinator). Each
//! sequence carries **two** KV caches, one per plan. A round:
//!
//! 1. **Draft** `k` tokens greedily with the cheap plan, appending to the
//!    draft cache (`O(k)` cheap steps).
//! 2. **Verify** all of them in *one* target pass: feed the chunk
//!    `[last committed token, draft₁ .. draft_k]` through
//!    [`CompiledModel::prefill`] on the target cache. Row `i` of the
//!    `k+1` logits rows is the target's next-token distribution after
//!    accepting `i` draft tokens — the chunked-prefill contract
//!    (`tests/kv_equivalence.rs`) guarantees each row is bit-identical to
//!    the corresponding solo `decode_step`, which is what makes the
//!    single batched pass a *verifier* and not an approximation.
//! 3. **Accept** the longest prefix where `draft_i == argmax(row_{i-1})`.
//!    The first disagreeing position commits the target's own argmax
//!    instead, so every round commits at least one token; a fully
//!    accepted round commits `k+1` (the bonus token from the last row).
//! 4. **Roll back** both caches to the committed length
//!    ([`KvCache::truncate`] / [`KvPagePool::truncate`]): rejected draft
//!    positions are invalidated and trailing paged pages return to the
//!    pool. Storage for the accepted prefix is untouched, so the next
//!    round attends over exactly the bits a target-only decode would
//!    have cached.
//!
//! # Why the output is exactly greedy target decode
//!
//! Every committed token is the argmax of a target logits row over the
//! committed history — either a verified draft token (agreed with that
//! argmax) or the target's own correction/bonus. By induction the token
//! stream equals target-only greedy decode **token for token**; the draft
//! plan can only change *how fast* tokens commit, never *which* tokens.
//! `tests/speculative.rs` asserts this with `assert_eq!` on whole
//! streams, including against adversarial drafts from a different
//! checkpoint. The speedup comes from the verify pass amortizing one
//! weight-matrix stream over `k+1` positions (like batching, but along
//! the sequence axis) while the cheap plan pays the per-token cost.
//!
//! # Adaptive k
//!
//! A sequence that keeps disagreeing wastes draft work and rollbacks, so
//! [`AdaptiveK`] halves `k` after a zero-acceptance round and creeps back
//! up by one after a fully accepted round, clamped to `[1, configured k]`
//! — per sequence, because acceptance is a property of the text, not the
//! fleet.

use super::{argmax, CompiledModel, DecodeScratch, KvCache, KvPagePool};

/// Per-sequence draft-window controller: multiplicative decrease on full
/// rejection, additive increase on full acceptance, clamped to
/// `[1, configured k]`.
#[derive(Debug, Clone, Copy)]
pub struct AdaptiveK {
    k: usize,
    max: usize,
}

impl AdaptiveK {
    /// Start at the configured window (`max >= 1`).
    pub fn new(max: usize) -> AdaptiveK {
        assert!(max >= 1, "draft window must be at least 1");
        AdaptiveK { k: max, max }
    }

    /// The window the next round should draft.
    pub fn current(&self) -> usize {
        self.k
    }

    /// Feed back one round's outcome.
    pub fn observe(&mut self, drafted: usize, agreed: usize) {
        if agreed == drafted {
            self.k = (self.k + 1).min(self.max);
        } else if agreed == 0 {
            self.k = (self.k / 2).max(1);
        }
        // partial acceptance: the window is about right — keep it
    }
}

/// The draft cache's catch-up state for one sequence. The invariant
/// between rounds: `draft_cache.len() + pending().len()` equals the
/// committed token count, and `pending()` ends with the most recently
/// committed token (the one the next round drafts from). After a fully
/// accepted round the draft cache is one position behind the bonus token,
/// so `pending()` is two tokens; otherwise one.
#[derive(Debug, Clone)]
pub struct SpecSequence {
    pending: Vec<u16>,
}

impl SpecSequence {
    /// Start speculating a sequence whose prompt is already prefilled
    /// into **both** caches and whose first token (`first`) came from the
    /// target prefill.
    pub fn start(first: u16) -> SpecSequence {
        SpecSequence { pending: vec![first] }
    }

    /// Committed tokens the draft cache has not consumed yet.
    pub fn pending(&self) -> &[u16] {
        &self.pending
    }

    /// Record a token committed *outside* a speculative round (the
    /// coordinator falls back to a plain target `decode_step` when a paged
    /// reserve for the round fails). The draft cache did not see it, so it
    /// joins the catch-up chunk the next round prefills.
    pub fn append_committed(&mut self, tok: u16) {
        self.pending.push(tok);
    }

    /// Positions a round with window `k` appends to the **draft** cache
    /// (reserve this before [`speculative_round`] on a paged cache).
    pub fn draft_positions(&self, k: usize) -> usize {
        self.pending.len() + k - 1
    }

    /// Positions a round with window `k` appends to the **target** cache
    /// before rollback (reserve this before [`speculative_round`]).
    pub fn verify_positions(&self, k: usize) -> usize {
        k + 1
    }
}

/// One round's result.
#[derive(Debug, Clone)]
pub struct RoundOutcome {
    /// Tokens committed to the output stream this round (`1 ..= k+1`,
    /// always at least one).
    pub committed: Vec<u16>,
    /// Tokens the draft plan proposed (`== k`).
    pub drafted: usize,
    /// Proposed tokens the target agreed with (`committed` is these plus
    /// one correction or bonus token).
    pub agreed: usize,
    /// KV positions truncated from the two caches (0 on full acceptance).
    pub rolled_back: usize,
}

/// Running totals across rounds — the numbers `ServeReport` aggregates.
#[derive(Debug, Clone, Copy, Default)]
pub struct SpecStats {
    pub rounds: usize,
    pub drafted: usize,
    pub accepted: usize,
    pub rolled_back: usize,
}

impl SpecStats {
    pub fn record(&mut self, out: &RoundOutcome) {
        self.rounds += 1;
        self.drafted += out.drafted;
        self.accepted += out.agreed;
        self.rolled_back += out.rolled_back;
    }

    /// Fraction of drafted tokens the target accepted (0 when nothing
    /// was drafted).
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }
}

/// Truncate through the pool when the cache is paged (frees trailing
/// pages), directly otherwise.
fn rollback(cache: &mut KvCache, pool: Option<&mut KvPagePool>, new_len: usize) {
    match pool {
        Some(p) if cache.is_paged() => p.truncate(cache, new_len),
        _ => cache.truncate(new_len),
    }
}

/// Phase 1 of a round: catch the draft cache up on
/// [`pending`](SpecSequence::pending) and propose `k` tokens greedily
/// with the cheap plan. The catch-up chunk and the first proposal come
/// out of one prefill — chunked-prefill exactness applies to the draft
/// cache too. Mutates only the **draft** cache (by
/// [`draft_positions`](SpecSequence::draft_positions) rows), so the
/// coordinator can guard it separately: a draft-plan panic poisons
/// nothing the target decode needs.
pub fn draft_propose(
    draft: &CompiledModel,
    draft_cache: &mut KvCache,
    seq: &SpecSequence,
    k: usize,
    draft_scratch: &mut DecodeScratch,
) -> Vec<u16> {
    assert!(k >= 1, "a round must draft at least one token");
    let mut drafts: Vec<u16> = Vec::with_capacity(k);
    let logits = draft.prefill(&seq.pending, draft_cache, draft_scratch);
    drafts.push(argmax(logits.row(logits.rows - 1)) as u16);
    for _ in 1..k {
        let logits = draft.decode_step(*drafts.last().unwrap(), draft_cache, draft_scratch);
        drafts.push(argmax(logits.row(0)) as u16);
    }
    drafts
}

/// Phase 2 of a round: verify `drafts` in one batched target pass, commit
/// the agreeing prefix plus the target's correction/bonus token, and roll
/// both caches back to the committed length. On entry the caches satisfy
/// the [`SpecSequence`] invariant (draft cache already advanced by
/// [`draft_propose`]); on exit they satisfy it again for the committed
/// stream.
#[allow(clippy::too_many_arguments)]
pub fn verify_commit(
    target: &CompiledModel,
    target_cache: &mut KvCache,
    draft_cache: &mut KvCache,
    mut pool: Option<&mut KvPagePool>,
    seq: &mut SpecSequence,
    drafts: &[u16],
    target_scratch: &mut DecodeScratch,
) -> RoundOutcome {
    let k = drafts.len();
    assert!(k >= 1, "a round must draft at least one token");
    let last = *seq.pending.last().expect("SpecSequence always holds the last token");
    let committed_before = target_cache.len() + 1; // the invariant: len == C - 1

    // verify all k+1 positions in one batched target pass
    let mut chunk: Vec<u16> = Vec::with_capacity(k + 1);
    chunk.push(last);
    chunk.extend_from_slice(drafts);
    let logits = target.prefill(&chunk, target_cache, target_scratch);
    let targets: Vec<u16> = (0..logits.rows).map(|i| argmax(logits.row(i)) as u16).collect();

    // accept the agreeing prefix plus the target's correction/bonus
    let mut agreed = 0usize;
    while agreed < k && drafts[agreed] == targets[agreed] {
        agreed += 1;
    }
    let mut committed = drafts[..agreed].to_vec();
    committed.push(targets[agreed]); // agreed == k ⇒ the bonus token

    // roll both caches back to the committed length
    let mut rolled_back = 0usize;
    if agreed < k {
        let target_len = committed_before + agreed; // C' - 1
        rolled_back += target_cache.len() - target_len;
        rollback(target_cache, pool.as_deref_mut(), target_len);
        let draft_len = committed_before + agreed; // pending' is one token
        rolled_back += draft_cache.len() - draft_len;
        rollback(draft_cache, pool, draft_len);
        seq.pending.clear();
        seq.pending.push(targets[agreed]);
    } else {
        // full acceptance: nothing to roll back; the draft cache is one
        // position (d_k) behind and must also catch up on the bonus
        seq.pending.clear();
        seq.pending.push(drafts[k - 1]);
        seq.pending.push(targets[k]);
    }
    RoundOutcome { committed, drafted: k, agreed, rolled_back }
}

/// One draft/verify/accept/rollback round — [`draft_propose`] then
/// [`verify_commit`] (the module docs walk through the phases; the
/// coordinator calls the two halves itself so each runs under its own
/// fault guard). Paged callers must reserve
/// [`SpecSequence::draft_positions`] /
/// [`verify_positions`](SpecSequence::verify_positions) first; `k` must
/// leave the verify chunk inside `max_seq`
/// (`target_cache.len() + k + 1 <= max_seq`).
#[allow(clippy::too_many_arguments)]
pub fn speculative_round(
    target: &CompiledModel,
    draft: &CompiledModel,
    target_cache: &mut KvCache,
    draft_cache: &mut KvCache,
    mut pool: Option<&mut KvPagePool>,
    seq: &mut SpecSequence,
    k: usize,
    target_scratch: &mut DecodeScratch,
    draft_scratch: &mut DecodeScratch,
) -> RoundOutcome {
    let drafts = draft_propose(draft, draft_cache, seq, k, draft_scratch);
    verify_commit(
        target,
        target_cache,
        draft_cache,
        pool.as_deref_mut(),
        seq,
        &drafts,
        target_scratch,
    )
}

/// Full greedy speculative generation of one sequence — the standalone
/// driver `tests/speculative.rs` and `bench_serving` exercise (the
/// coordinator interleaves [`speculative_round`] across its in-flight set
/// instead). Both caches must be fresh; paged caches must come from
/// `pool`, which the driver reserves from as it goes. Returns the token
/// stream (`max_new` tokens, identical to target-only greedy decode) and
/// the round totals.
#[allow(clippy::too_many_arguments)]
pub fn generate_speculative(
    target: &CompiledModel,
    draft: &CompiledModel,
    prompt: &[u16],
    max_new: usize,
    k: usize,
    target_cache: &mut KvCache,
    draft_cache: &mut KvCache,
    mut pool: Option<&mut KvPagePool>,
) -> (Vec<u16>, SpecStats) {
    assert!(!prompt.is_empty() && max_new >= 1);
    assert!(
        prompt.len() + max_new <= target.config.max_seq,
        "prompt + max_new exceeds max_seq"
    );
    let mut ts = target.scratch();
    let mut ds = draft.scratch();
    if let Some(p) = pool.as_deref_mut() {
        assert!(p.reserve(target_cache, prompt.len()), "pool too small for the prompt");
        assert!(p.reserve(draft_cache, prompt.len()), "pool too small for the draft prompt");
    }
    let logits = target.prefill(prompt, target_cache, &mut ts);
    let first = argmax(logits.row(logits.rows - 1)) as u16;
    let _ = draft.prefill(prompt, draft_cache, &mut ds);

    let mut generated = vec![first];
    let mut seq = SpecSequence::start(first);
    let mut window = AdaptiveK::new(k);
    let mut stats = SpecStats::default();
    while generated.len() < max_new {
        let remaining = max_new - generated.len();
        let kr = window.current().min(remaining);
        if let Some(p) = pool.as_deref_mut() {
            assert!(p.reserve(target_cache, seq.verify_positions(kr)), "pool exhausted");
            assert!(p.reserve(draft_cache, seq.draft_positions(kr)), "pool exhausted");
        }
        let out = speculative_round(
            target,
            draft,
            target_cache,
            draft_cache,
            pool.as_deref_mut(),
            &mut seq,
            kr,
            &mut ts,
            &mut ds,
        );
        stats.record(&out);
        window.observe(out.drafted, out.agreed);
        generated.extend_from_slice(&out.committed);
    }
    generated.truncate(max_new); // a fully accepted last round overshoots by the bonus
    (generated, stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adaptive_k_halves_on_rejection_and_creeps_back() {
        let mut w = AdaptiveK::new(4);
        assert_eq!(w.current(), 4);
        w.observe(4, 0);
        assert_eq!(w.current(), 2);
        w.observe(2, 0);
        w.observe(1, 0);
        assert_eq!(w.current(), 1, "floor is 1");
        w.observe(1, 1);
        w.observe(2, 2);
        assert_eq!(w.current(), 3);
        w.observe(3, 2); // partial acceptance holds the window
        assert_eq!(w.current(), 3);
        w.observe(3, 3);
        w.observe(4, 4);
        assert_eq!(w.current(), 4, "ceiling is the configured k");
    }

    #[test]
    fn spec_sequence_accounts_round_appends() {
        let seq = SpecSequence::start(7);
        assert_eq!(seq.pending(), &[7]);
        assert_eq!(seq.draft_positions(4), 4);
        assert_eq!(seq.verify_positions(4), 5);
    }

    #[test]
    fn stats_acceptance_rate() {
        let mut s = SpecStats::default();
        assert_eq!(s.acceptance_rate(), 0.0);
        s.record(&RoundOutcome { committed: vec![1, 2, 3], drafted: 4, agreed: 2, rolled_back: 3 });
        s.record(&RoundOutcome { committed: vec![9], drafted: 4, agreed: 4, rolled_back: 0 });
        assert_eq!((s.rounds, s.drafted, s.accepted, s.rolled_back), (2, 8, 6, 3));
        assert!((s.acceptance_rate() - 0.75).abs() < 1e-12);
    }
}
