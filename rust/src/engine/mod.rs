//! The pure-Rust inference engine: a decoder-only transformer forward pass
//! with token-wise activation fake-quantization hooks.
//!
//! Roles:
//! 1. **calibration** — [`Engine::forward_observed`] streams every linear
//!    layer's input into an observer (the GPTQ Hessian accumulators);
//! 2. **evaluation** — perplexity of any (possibly quantized) checkpoint
//!    under any activation scheme, f32 reference semantics;
//! 3. **oracle** — the PJRT/HLO path in [`crate::runtime`] is cross-checked
//!    against this engine (same checkpoint ⇒ same logits).
//!
//! The engine evaluates *simulated* quantization exactly like the paper's
//! GPU harness (qtorch fake-quant in an FP16 pipeline): weights arrive
//! already fake-quantized in the checkpoint; activations are fake-quantized
//! token-wise at each linear input when [`EngineOpts::act`] says so.
//!
//! This is the *reference* implementation: tensors are resolved through
//! string keys and weights are transposed per call, uniformly for every
//! batch size (the old `mm_wt` small-batch heuristic is gone — the serving
//! path that cares about speed is [`crate::plan::CompiledModel`], which
//! prepacks all of this once and must match these logits bit-for-bit; see
//! `tests/plan_equivalence.rs`).

use crate::model::{Arch, Checkpoint};
use crate::quant::{fake_quant_tokenwise, ActQuantConfig};
use crate::tensor::Matrix;

/// Where in a block a linear layer sits. `Qkv` is the shared input of the
/// q/k/v projections (the paper's `attn.q_proj` histogram); `Fc1` is the
/// shared input of gate/up for the gated variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LinearSite {
    Qkv,
    OutProj,
    Fc1,
    Fc2,
}

impl LinearSite {
    pub const ALL: [LinearSite; 4] =
        [LinearSite::Qkv, LinearSite::OutProj, LinearSite::Fc1, LinearSite::Fc2];

    /// The paper's module names (Figure 1 column headers).
    pub fn paper_name(&self) -> &'static str {
        match self {
            LinearSite::Qkv => "attn.q_proj",
            LinearSite::OutProj => "attn.out_proj",
            LinearSite::Fc1 => "fc1",
            LinearSite::Fc2 => "fc2",
        }
    }
}

/// A (layer, site) address for observers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Site {
    pub layer: usize,
    pub site: LinearSite,
}

/// How the compiled plan ([`crate::plan::CompiledModel`]) stores and
/// executes its weight matrices. The reference [`Engine`] always runs the
/// dense f32 layout — it is the oracle the packed path is checked against
/// (`tests/packed_equivalence.rs`), so this knob only changes *where the
/// same bits come from*, never what they are.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WeightLayout {
    /// Dense f32, prepacked transposed — the reference layout.
    #[default]
    Dense,
    /// Bit-packed low-bit codes (two 4-bit codes per byte) decoded on the
    /// fly by the fused dequant GEMV, with the output rows sharded across
    /// `threads` workers (1 = inline; the zero-allocation decode contract
    /// holds only at 1). Requires the quantized-code sidecar:
    /// `CompiledModel::compile_quantized`.
    Packed {
        /// GEMV row shards (clamped to ≥ 1).
        threads: usize,
    },
}

impl WeightLayout {
    pub fn is_dense(&self) -> bool {
        matches!(self, WeightLayout::Dense)
    }

    /// Worker count for the packed GEMV (1 for the dense layout).
    pub fn threads(&self) -> usize {
        match self {
            WeightLayout::Dense => 1,
            WeightLayout::Packed { threads } => (*threads).max(1),
        }
    }
}

/// Which kernel backend the compiled plan executes through (the
/// two-tier contract of [`crate::kernels`]). The reference [`Engine`]
/// ignores this — it *is* the scalar arithmetic both tiers are measured
/// against.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelTier {
    /// The scalar reference path — bit-identical to the reference engine
    /// on every execution mode (the existing equivalence-suite contract).
    #[default]
    Oracle,
    /// Blocked 8-lane dequant-GEMV + persistent decode worker pool.
    /// Not bit-identical to the oracle; gated by the differential
    /// ULP/NLL tolerance suite (`tests/kernel_tolerance.rs`) and
    /// bit-deterministic across worker counts.
    Fast,
}

impl KernelTier {
    /// Parse a CLI/JSON tier name (`"oracle"` / `"fast"`).
    pub fn parse(s: &str) -> Option<KernelTier> {
        match s {
            "oracle" => Some(KernelTier::Oracle),
            "fast" => Some(KernelTier::Fast),
            _ => None,
        }
    }

    /// The canonical lowercase name (inverse of [`parse`](Self::parse)).
    pub fn name(&self) -> &'static str {
        match self {
            KernelTier::Oracle => "oracle",
            KernelTier::Fast => "fast",
        }
    }

    /// `true` for the tolerance-gated fast tier.
    pub fn is_fast(&self) -> bool {
        matches!(self, KernelTier::Fast)
    }
}

/// Engine options.
#[derive(Debug, Clone, Copy)]
pub struct EngineOpts {
    /// Token-wise activation fake-quant applied at every linear input
    /// (the paper's A8; `F16` = off).
    pub act: ActQuantConfig,
    /// Weight storage/execution layout of the compiled plan (the
    /// reference engine ignores this — it is always dense).
    pub weights: WeightLayout,
    /// Kernel backend of the compiled plan (the reference engine ignores
    /// this — it is always the scalar oracle arithmetic).
    pub kernels: KernelTier,
}

impl Default for EngineOpts {
    fn default() -> Self {
        EngineOpts::with_act(crate::formats::NumericFormat::F16)
    }
}

impl EngineOpts {
    /// Options with the given activation format and the default dense
    /// weight layout — the common construction across tests and benches.
    pub fn with_act(fmt: crate::formats::NumericFormat) -> EngineOpts {
        EngineOpts {
            act: ActQuantConfig::new(fmt),
            weights: WeightLayout::Dense,
            kernels: KernelTier::Oracle,
        }
    }

    /// Switch to the packed weight layout with `threads` GEMV shards.
    pub fn packed(mut self, threads: usize) -> EngineOpts {
        self.weights = WeightLayout::Packed { threads: threads.max(1) };
        self
    }

    /// Select the kernel backend tier of the compiled plan.
    pub fn kernels(mut self, tier: KernelTier) -> EngineOpts {
        self.kernels = tier;
        self
    }
}

/// The inference engine, borrowing a checkpoint.
pub struct Engine<'a> {
    pub ck: &'a Checkpoint,
    pub opts: EngineOpts,
}

impl<'a> Engine<'a> {
    pub fn new(ck: &'a Checkpoint) -> Self {
        Engine { ck, opts: EngineOpts::default() }
    }

    pub fn with_opts(ck: &'a Checkpoint, opts: EngineOpts) -> Self {
        Engine { ck, opts }
    }

    /// Forward pass over one token sequence; returns logits `[seq, vocab]`.
    pub fn forward(&self, tokens: &[u16]) -> Matrix {
        self.forward_observed(tokens, &mut |_, _| {})
    }

    /// Forward pass that reports every linear input (pre activation-quant)
    /// to `observe`.
    pub fn forward_observed(
        &self,
        tokens: &[u16],
        observe: &mut dyn FnMut(Site, &Matrix),
    ) -> Matrix {
        let cfg = &self.ck.config;
        assert!(
            tokens.len() <= cfg.max_seq,
            "sequence {} exceeds max_seq {}",
            tokens.len(),
            cfg.max_seq
        );
        let seq = tokens.len();
        let d = cfg.d_model;
        let embed = self.ck.get("embed");
        let pos = self.ck.get("pos_embed");
        let mut x = Matrix::zeros(seq, d);
        for (t, &tok) in tokens.iter().enumerate() {
            let e = embed.row(tok as usize);
            let p = pos.row(t);
            let row = x.row_mut(t);
            for i in 0..d {
                row[i] = e[i] + p[i];
            }
        }

        for layer in 0..cfg.n_layers {
            let pfx = format!("layers.{layer}");
            // ---- attention ----
            let a = self.norm(&x, &format!("{pfx}.ln1"));
            observe(Site { layer, site: LinearSite::Qkv }, &a);
            let a = self.actq(a);
            let q = self.linear(&a, &format!("{pfx}.attn.q"));
            let k = self.linear(&a, &format!("{pfx}.attn.k"));
            let v = self.linear(&a, &format!("{pfx}.attn.v"));
            let ctx = self.attention(&q, &k, &v);
            observe(Site { layer, site: LinearSite::OutProj }, &ctx);
            let ctx = self.actq(ctx);
            let o = self.linear(&ctx, &format!("{pfx}.attn.o"));
            x.add_assign(&o);
            // ---- mlp ----
            let m = self.norm(&x, &format!("{pfx}.ln2"));
            observe(Site { layer, site: LinearSite::Fc1 }, &m);
            let m = self.actq(m);
            let mlp = match cfg.arch {
                Arch::Opt => {
                    let mut h = self.linear(&m, &format!("{pfx}.mlp.fc1"));
                    for v in h.data.iter_mut() {
                        *v = v.max(0.0); // relu
                    }
                    observe(Site { layer, site: LinearSite::Fc2 }, &h);
                    let h = self.actq(h);
                    self.linear(&h, &format!("{pfx}.mlp.fc2"))
                }
                Arch::Llama => {
                    let mut g = self.linear_nobias(&m, &format!("{pfx}.mlp.gate.w"));
                    let u = self.linear_nobias(&m, &format!("{pfx}.mlp.up.w"));
                    for (gv, uv) in g.data.iter_mut().zip(&u.data) {
                        let s = *gv / (1.0 + (-*gv).exp()); // silu
                        *gv = s * uv;
                    }
                    observe(Site { layer, site: LinearSite::Fc2 }, &g);
                    let g = self.actq(g);
                    self.linear(&g, &format!("{pfx}.mlp.down"))
                }
            };
            x.add_assign(&mlp);
        }
        let x = self.norm(&x, "final_norm");
        // tied LM head: logits = x @ embedᵀ
        x.matmul_t(embed)
    }

    fn actq(&self, mut m: Matrix) -> Matrix {
        if !self.opts.act.is_noop() {
            fake_quant_tokenwise(&mut m, &self.opts.act);
        }
        m
    }

    /// `y = b + x @ wᵀ`, bias seeding the accumulator of the axpy kernel.
    ///
    /// This is the engine's *numeric contract*, shared bit-for-bit with the
    /// prepacked fast path ([`crate::plan::CompiledModel`]): one kernel
    /// ([`crate::tensor::matmul::matmul_into`]) for every batch size, bias
    /// fused as the accumulation base. The reference engine re-derives `wᵀ`
    /// per call (it is the slow oracle); the compiled path packs it once.
    fn linear(&self, x: &Matrix, prefix: &str) -> Matrix {
        let w = self.ck.get(&format!("{prefix}.w"));
        let b = self.ck.get(&format!("{prefix}.b"));
        let wt = w.transpose();
        let mut y = Matrix::zeros(x.rows, w.rows);
        for r in 0..y.rows {
            y.row_mut(r).copy_from_slice(&b.data);
        }
        crate::tensor::matmul::matmul_into(x, &wt, &mut y);
        y
    }

    fn linear_nobias(&self, x: &Matrix, wname: &str) -> Matrix {
        x.matmul(&self.ck.get(wname).transpose())
    }

    fn norm(&self, x: &Matrix, prefix: &str) -> Matrix {
        let g = self.ck.get(&format!("{prefix}.g"));
        let eps = 1e-5f32;
        let mut out = Matrix::zeros(x.rows, x.cols);
        match self.ck.config.arch {
            Arch::Opt => {
                let b = self.ck.get(&format!("{prefix}.b"));
                for r in 0..x.rows {
                    let row = x.row(r);
                    let mean = row.iter().sum::<f32>() / row.len() as f32;
                    let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>()
                        / row.len() as f32;
                    let inv = 1.0 / (var + eps).sqrt();
                    let orow = out.row_mut(r);
                    for c in 0..row.len() {
                        orow[c] = (row[c] - mean) * inv * g.data[c] + b.data[c];
                    }
                }
            }
            Arch::Llama => {
                for r in 0..x.rows {
                    let row = x.row(r);
                    let ms = row.iter().map(|&v| v * v).sum::<f32>() / row.len() as f32;
                    let inv = 1.0 / (ms + eps).sqrt();
                    let orow = out.row_mut(r);
                    for c in 0..row.len() {
                        orow[c] = row[c] * inv * g.data[c];
                    }
                }
            }
        }
        out
    }

    /// Multi-head causal self-attention (f32; BMMs are not quantized, as in
    /// ZeroQuant's W·A scheme which targets the weight GEMMs).
    fn attention(&self, q: &Matrix, k: &Matrix, v: &Matrix) -> Matrix {
        let cfg = &self.ck.config;
        let seq = q.rows;
        let h = cfg.n_heads;
        let dh = cfg.head_dim();
        let scale = 1.0 / (dh as f32).sqrt();
        let mut ctx = Matrix::zeros(seq, cfg.d_model);
        let mut scores = vec![0.0f32; seq];
        for head in 0..h {
            let off = head * dh;
            for i in 0..seq {
                let qrow = &q.row(i)[off..off + dh];
                // scores over j <= i
                let mut mx = f32::NEG_INFINITY;
                for (j, s) in scores.iter_mut().enumerate().take(i + 1) {
                    let krow = &k.row(j)[off..off + dh];
                    let mut dot = 0.0f32;
                    for t in 0..dh {
                        dot += qrow[t] * krow[t];
                    }
                    *s = dot * scale;
                    mx = mx.max(*s);
                }
                let mut denom = 0.0f32;
                for s in scores.iter_mut().take(i + 1) {
                    *s = (*s - mx).exp();
                    denom += *s;
                }
                let inv = 1.0 / denom;
                let crow = &mut ctx.row_mut(i)[off..off + dh];
                for (j, &p) in scores.iter().enumerate().take(i + 1) {
                    let w = p * inv;
                    let vrow = &v.row(j)[off..off + dh];
                    for t in 0..dh {
                        crow[t] += w * vrow[t];
                    }
                }
            }
        }
        ctx
    }
}

/// Accumulates per-(layer, site) activation statistics — backs Figure 1
/// (distribution histograms) and the outlier metrics in tests.
#[derive(Debug, Default)]
pub struct ActivationCapture {
    /// (site, min, max, sum, sumsq, count, histogram)
    pub stats: std::collections::HashMap<Site, SiteStats>,
}

#[derive(Debug, Clone)]
pub struct SiteStats {
    pub min: f32,
    pub max: f32,
    pub sum: f64,
    pub sumsq: f64,
    pub count: usize,
    /// Fixed 100-bin histogram over a lazily-set range (first batch's
    /// min/max, expanded by 2× margin) — matches the paper's bin=100 plots.
    pub hist: Vec<u64>,
    pub hist_lo: f32,
    pub hist_hi: f32,
}

impl SiteStats {
    fn new() -> Self {
        SiteStats {
            min: f32::INFINITY,
            max: f32::NEG_INFINITY,
            sum: 0.0,
            sumsq: 0.0,
            count: 0,
            hist: vec![0; 100],
            hist_lo: 0.0,
            hist_hi: 0.0,
        }
    }

    pub fn rms(&self) -> f64 {
        (self.sumsq / self.count.max(1) as f64).sqrt()
    }

    pub fn mean(&self) -> f64 {
        self.sum / self.count.max(1) as f64
    }

    /// max(|min|, |max|) / rms — the outlier severity metric.
    pub fn peak_to_rms(&self) -> f64 {
        (self.min.abs().max(self.max.abs()) as f64) / self.rms().max(1e-12)
    }
}

impl ActivationCapture {
    pub fn record(&mut self, site: Site, x: &Matrix) {
        let st = self.stats.entry(site).or_insert_with(SiteStats::new);
        if st.count == 0 {
            let (mn, mx) = x.min_max();
            let span = (mx - mn).max(1e-6);
            st.hist_lo = mn - span * 0.5;
            st.hist_hi = mx + span * 0.5;
        }
        let nbins = st.hist.len() as f32;
        let w = (st.hist_hi - st.hist_lo).max(1e-12);
        for &v in &x.data {
            st.min = st.min.min(v);
            st.max = st.max.max(v);
            st.sum += v as f64;
            st.sumsq += (v as f64) * (v as f64);
            st.count += 1;
            let b = (((v - st.hist_lo) / w) * nbins).floor();
            let b = (b.max(0.0) as usize).min(st.hist.len() - 1);
            st.hist[b] += 1;
        }
    }

    /// Max peak-to-rms over all layers for one site kind.
    pub fn peak_to_rms(&self, kind: LinearSite) -> f64 {
        self.stats
            .iter()
            .filter(|(s, _)| s.site == kind)
            .map(|(_, st)| st.peak_to_rms())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Arch, Checkpoint, ModelConfig};
    use crate::rng::Rng;

    fn tiny(arch: Arch) -> ModelConfig {
        ModelConfig {
            name: "engine-test".into(),
            arch,
            vocab_size: 48,
            d_model: 24,
            n_heads: 3,
            n_layers: 2,
            d_ff: 48,
            max_seq: 16,
        }
    }

    #[test]
    fn forward_shapes_and_finite() {
        for arch in [Arch::Opt, Arch::Llama] {
            let mut rng = Rng::seeded(111);
            let ck = Checkpoint::random(&tiny(arch), &mut rng);
            let eng = Engine::new(&ck);
            let logits = eng.forward(&[1, 2, 3, 4, 5]);
            assert_eq!((logits.rows, logits.cols), (5, 48));
            assert!(logits.data.iter().all(|x| x.is_finite()));
        }
    }

    #[test]
    fn causality() {
        // changing a future token must not affect past logits
        let mut rng = Rng::seeded(112);
        let ck = Checkpoint::random(&tiny(Arch::Opt), &mut rng);
        let eng = Engine::new(&ck);
        let l1 = eng.forward(&[5, 6, 7, 8]);
        let l2 = eng.forward(&[5, 6, 7, 40]);
        for t in 0..3 {
            for c in 0..48 {
                assert_eq!(l1.at(t, c), l2.at(t, c), "t={t}");
            }
        }
        // ...but it does affect its own position's logits upstream of it
        assert_ne!(l1.row(3), l2.row(3));
    }

    #[test]
    fn observer_sees_all_sites() {
        let mut rng = Rng::seeded(113);
        let ck = Checkpoint::random(&tiny(Arch::Opt), &mut rng);
        let eng = Engine::new(&ck);
        let mut seen = std::collections::HashSet::new();
        eng.forward_observed(&[1, 2, 3], &mut |site, x| {
            assert_eq!(x.rows, 3);
            seen.insert(site);
        });
        assert_eq!(seen.len(), 2 * 4); // 2 layers x 4 sites
    }

    #[test]
    fn activation_quant_perturbs_but_tracks() {
        let mut rng = Rng::seeded(114);
        let ck = Checkpoint::random(&tiny(Arch::Opt), &mut rng);
        let base = Engine::new(&ck).forward(&[3, 1, 4, 1, 5]);
        let opts = EngineOpts::with_act(crate::formats::NumericFormat::FP8_E4M3);
        let q = Engine::with_opts(&ck, opts).forward(&[3, 1, 4, 1, 5]);
        let rel = base.sub(&q).fro_norm() / base.fro_norm();
        assert!(rel > 0.0, "quantization must do something");
        assert!(rel < 0.05, "FP8 activations should track closely: {rel}");
    }

    #[test]
    fn int8_worse_than_fp8_with_outliers() {
        // engine-level Table 1 mechanism
        let mut rng = Rng::seeded(115);
        let mut ck = Checkpoint::random(&tiny(Arch::Opt), &mut rng);
        crate::model::inject_outliers(
            &mut ck,
            crate::model::OutlierSpec { alpha: 64.0, channels: 3 },
            &mut rng,
        );
        let tokens = [3u16, 1, 4, 1, 5, 9, 2, 6];
        let base = Engine::new(&ck).forward(&tokens);
        let err = |fmt| {
            let opts = EngineOpts::with_act(fmt);
            let l = Engine::with_opts(&ck, opts).forward(&tokens);
            l.sub(&base).fro_norm() / base.fro_norm()
        };
        let e_int = err(crate::formats::NumericFormat::INT8);
        let e_fp = err(crate::formats::NumericFormat::FP8_E4M3);
        assert!(e_fp < e_int, "fp={e_fp} int={e_int}");
    }

    #[test]
    fn capture_histograms_fill() {
        let mut rng = Rng::seeded(116);
        let ck = Checkpoint::random(&tiny(Arch::Opt), &mut rng);
        let eng = Engine::new(&ck);
        let mut cap = ActivationCapture::default();
        eng.forward_observed(&[1, 2, 3, 4], &mut |s, x| cap.record(s, x));
        let st = cap.stats.get(&Site { layer: 0, site: LinearSite::Fc1 }).unwrap();
        assert_eq!(st.count, 4 * 24);
        assert_eq!(st.hist.iter().sum::<u64>(), st.count as u64);
        assert!(st.rms() > 0.0);
    }
}
