//! Matmul kernels — the L3 hot path.
//!
//! Single-core target (this testbed exposes one CPU), so the optimization
//! levers are loop order, register blocking, and cache blocking rather than
//! threading. Two kernels:
//!
//! * [`matmul_into`]  — C += A·B with an i-k-j loop (unit-stride inner loop
//!   over B's rows) plus 4-wide k unrolling. Auto-vectorizes well.
//! * [`matmul_bt_into`] — C = A·Bᵀ as blocked dot products (both operands
//!   walk unit-stride), used where the engine naturally holds Bᵀ (weight
//!   matrices are stored [out, in]).
//!
//! §Perf in EXPERIMENTS.md records the measured GFLOP/s of each variant and
//! the naive baseline they replaced.

use super::Matrix;

/// `out = a @ b` (out must be zeroed or hold the accumulation base).
pub fn matmul_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    assert_eq!(a.cols, b.rows);
    assert_eq!(out.rows, a.rows);
    assert_eq!(out.cols, b.cols);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    // Cache-block over k so b's working set stays in L1/L2.
    const KB: usize = 256;
    for kb in (0..k).step_by(KB) {
        let kend = (kb + KB).min(k);
        for i in 0..m {
            let arow = &a.data[i * k..(i + 1) * k];
            let orow = &mut out.data[i * n..(i + 1) * n];
            let mut kk = kb;
            // 4-wide unroll over k: each step is an axpy over the out row.
            while kk + 4 <= kend {
                let a0 = arow[kk];
                let a1 = arow[kk + 1];
                let a2 = arow[kk + 2];
                let a3 = arow[kk + 3];
                let b0 = &b.data[kk * n..kk * n + n];
                let b1 = &b.data[(kk + 1) * n..(kk + 1) * n + n];
                let b2 = &b.data[(kk + 2) * n..(kk + 2) * n + n];
                let b3 = &b.data[(kk + 3) * n..(kk + 3) * n + n];
                for j in 0..n {
                    orow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                }
                kk += 4;
            }
            while kk < kend {
                let av = arow[kk];
                if av != 0.0 {
                    let brow = &b.data[kk * n..kk * n + n];
                    for j in 0..n {
                        orow[j] += av * brow[j];
                    }
                }
                kk += 1;
            }
        }
    }
}

/// `out = a @ bᵀ` where `b` is `[n, k]` (i.e. rows of `b` are the columns of
/// the logical right operand). Both inner loops are unit-stride.
pub fn matmul_bt_into(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    assert_eq!(a.cols, b.cols);
    assert_eq!(out.rows, a.rows);
    assert_eq!(out.cols, b.rows);
    let (m, k, n) = (a.rows, a.cols, b.rows);
    // Register-block 1x4 over output columns: 4 dot products share one read
    // of the a-row.
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let mut j = 0;
        while j + 4 <= n {
            let b0 = &b.data[j * k..j * k + k];
            let b1 = &b.data[(j + 1) * k..(j + 1) * k + k];
            let b2 = &b.data[(j + 2) * k..(j + 2) * k + k];
            let b3 = &b.data[(j + 3) * k..(j + 3) * k + k];
            let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for kk in 0..k {
                let av = arow[kk];
                s0 += av * b0[kk];
                s1 += av * b1[kk];
                s2 += av * b2[kk];
                s3 += av * b3[kk];
            }
            let base = i * n + j;
            out.data[base] = s0;
            out.data[base + 1] = s1;
            out.data[base + 2] = s2;
            out.data[base + 3] = s3;
            j += 4;
        }
        while j < n {
            let brow = &b.data[j * k..j * k + k];
            let mut s = 0.0f32;
            for kk in 0..k {
                s += arow[kk] * brow[kk];
            }
            out.data[i * n + j] = s;
            j += 1;
        }
    }
}

/// Reference (naive triple loop) kernel kept for correctness testing and as
/// the §Perf baseline.
pub fn matmul_naive(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows);
    let mut out = Matrix::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for j in 0..b.cols {
            let mut s = 0.0f32;
            for kk in 0..a.cols {
                s += a.at(i, kk) * b.at(kk, j);
            }
            *out.at_mut(i, j) = s;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn optimized_matches_naive() {
        let mut rng = Rng::seeded(21);
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (16, 16, 16), (33, 65, 17), (64, 256, 48)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let fast = a.matmul(&b);
            let slow = matmul_naive(&a, &b);
            assert!(fast.mse(&slow) < 1e-8, "({m},{k},{n}) mse={}", fast.mse(&slow));
        }
    }

    #[test]
    fn bt_matches_naive() {
        let mut rng = Rng::seeded(22);
        for (m, k, n) in [(2, 3, 4), (17, 31, 9), (40, 128, 40)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let bt = Matrix::randn(n, k, 1.0, &mut rng);
            let fast = a.matmul_t(&bt);
            let slow = matmul_naive(&a, &bt.transpose());
            assert!(fast.mse(&slow) < 1e-8);
        }
    }

    #[test]
    fn accumulation_base_is_respected() {
        let mut rng = Rng::seeded(23);
        let a = Matrix::randn(4, 4, 1.0, &mut rng);
        let b = Matrix::randn(4, 4, 1.0, &mut rng);
        let mut out = Matrix::eye(4);
        matmul_into(&a, &b, &mut out);
        let expect = {
            let mut e = a.matmul(&b);
            e.add_assign(&Matrix::eye(4));
            e
        };
        assert!(out.mse(&expect) < 1e-10);
    }
}
