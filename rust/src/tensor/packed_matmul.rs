//! Fused dequant-GEMV over [`PackedWeight`] — the packed plan's hot path.
//!
//! Computes `out += x · Wᵀ` with `W` stored as bit-packed codes: each
//! weight row (one output feature) is decoded into a small scratch strip
//! through the per-group dequant tables (exponent-add when the scale
//! tensor allows, multiply otherwise — see [`crate::quant::packed`]) and
//! immediately dotted against every activation row while it is L1-hot.
//! Memory traffic per weight drops from 4 bytes (dense f32 plan) to
//! ~0.56 bytes (W4 codes + f32 group scales), which is the whole game for
//! a bandwidth-bound decode loop.
//!
//! ## Bit-identity contract
//!
//! The result is bit-identical to seeding `out` the same way and calling
//! [`matmul_into`](super::matmul::matmul_into)`(x, dequantize(W)ᵀ, out)` —
//! the dense compiled plan's exact kernel. Two facts make this hold:
//!
//! 1. the decoded strip is bit-equal to the dequantized weight row
//!    ([`PackedWeight::dequant_row_into`]'s contract), and
//! 2. the accumulation order is identical: `matmul_into` k-blocks by
//!    `KB = 256` and 4-way unrolls inside each block. Because `KB` is a
//!    multiple of 4, its 4-term groups sit at `k ≡ 0 (mod 4)` globally
//!    with only the final `k mod 4` elements handled singly (with the
//!    same `a != 0` skip) — exactly the flat loop below.
//!
//! ## LoRC on the packed path
//!
//! A packed linear may carry a [`PackedLorc`] attachment (the runtime form
//! of the paper's low-rank compensation, `Ŵ + E₁E₂`). The GEMV then
//! extends the contract to the *effective* weight: after decoding weight
//! row `j`, the row of `E₁·E₂` is materialized into the `err` strip in the
//! exact accumulation order of the pipeline's fold
//! ([`PackedLorc::err_row_into`]) and added elementwise — so the strip the
//! activations are dotted against is bit-equal to the folded effective
//! weight row, and packed+LoRC logits are bit-identical to the dense
//! effective-checkpoint plan (`tests/lorc_equivalence.rs`). E₂ is decoded
//! **once per call** into the scratch's `e2` strip and shared read-only by
//! all row workers. The cost is `rank` extra multiply-adds per weight —
//! the price of fold-equality; the cheap `O(r·(in+out))` activation-side
//! application exists as [`PackedLorc::apply_into`] but deliberately does
//! not serve (its addition grouping differs from the fold by rounding).
//!
//! `tests/packed_equivalence.rs` and `tests/lorc_equivalence.rs` enforce
//! the end-to-end versions of these claims across architectures, formats
//! and scale constraints.
//!
//! ## Sharding
//!
//! With `threads > 1` the weight rows (output features) are sharded across
//! `std::thread` workers — each worker decodes only its own rows, so the
//! dequant (and LoRC error) work parallelizes with the FLOPs. Each worker
//! accumulates into a private `[batch, shard]` strip that is scattered
//! into `out` after the join, keeping the hot loops free of sharing. The
//! threaded path spawns (and therefore allocates) per call; the
//! zero-allocation decode contract (`tests/plan_alloc.rs`) applies to
//! `threads == 1`, the default.

use crate::lorc::PackedLorc;
use crate::quant::PackedWeight;

use super::Matrix;

/// The caller-owned scratch strips of the fused GEMV: the decoded
/// weight-row strip, the decoded-E₂ strip and the LoRC error-row strip
/// (both empty-capable when the plan carries no LoRC). Lives in the
/// decode arena (`plan::DecodeScratch`) so steady-state decode stays
/// allocation-free.
#[derive(Debug, Clone, Default)]
pub struct GemvScratch {
    /// Decoded weight row (`len >= w.cols`).
    pub deq: Vec<f32>,
    /// Decoded E₂ rows of the current linear's LoRC attachment
    /// (`len >= lorc.e2_elems()`).
    pub e2: Vec<f32>,
    /// LoRC error-row accumulator (`len >= w.cols`).
    pub err: Vec<f32>,
}

impl GemvScratch {
    /// Strips sized for matrices up to `cols` input features and LoRC
    /// attachments up to `e2_elems` decoded-E₂ elements. LoRC-free plans
    /// (`e2_elems == 0`) get empty LoRC strips — only compensated linears
    /// ever read them (and the GEMV grows them on demand as a fallback).
    pub fn sized(cols: usize, e2_elems: usize) -> GemvScratch {
        let lorc_cols = if e2_elems > 0 { cols } else { 0 };
        GemvScratch {
            deq: vec![0.0; cols],
            e2: vec![0.0; e2_elems],
            err: vec![0.0; lorc_cols],
        }
    }
}

/// `out += x · wᵀ` over packed codes, with `lorc` compensation folded into
/// each decoded row when present. `out` must be pre-seeded (zeroed or bias
/// rows) and shaped `[x.rows, w.rows]`; `s` is the caller's scratch with
/// `s.deq`/`s.err` at least `w.cols` long and `s.e2` at least
/// `lorc.e2_elems()` (the `deq`/`err` strips are unused when
/// `threads > 1`, where each worker owns private strips).
pub fn packed_matmul_into(
    x: &Matrix,
    w: &PackedWeight,
    lorc: Option<&PackedLorc>,
    out: &mut Matrix,
    s: &mut GemvScratch,
    threads: usize,
) {
    assert_eq!(x.cols, w.cols, "gemv input dim mismatch");
    assert_eq!(out.rows, x.rows);
    assert_eq!(out.cols, w.rows);
    if x.rows == 0 || w.rows == 0 {
        return; // nothing to accumulate (and nothing to decode or shard)
    }
    if let Some(l) = lorc {
        assert_eq!((l.d_out, l.d_in), (w.rows, w.cols), "lorc factor shape mismatch");
        // A cfg-only arena (DecodeScratch::new) cannot know the plan's
        // attachment sizes — grow once here instead of panicking deep in
        // the decode. CompiledModel::scratch presizes both strips, so the
        // steady state (and the zero-alloc contract) never hits this.
        if s.e2.len() < l.e2_elems() {
            s.e2.resize(l.e2_elems(), 0.0);
        }
        if s.err.len() < w.cols {
            s.err.resize(w.cols, 0.0);
        }
        l.decode_e2_into(&mut s.e2);
    }
    let threads = threads.max(1).min(w.rows);
    if threads == 1 {
        let (deq, err) = (&mut s.deq[..w.cols], &mut s.err[..]);
        packed_rows_into(x, w, lorc, 0..w.rows, deq, &s.e2, err, &mut out.data, w.rows, 0);
        return;
    }

    // Shard the GEMV rows (output features) across workers. Each worker
    // copies its columns' seeds out of `out`, accumulates into a private
    // [batch, span] strip (so the accumulator chain — seed first, then the
    // k-groups — is the same as the inline path, keeping the result
    // bit-identical to threads == 1), and the strips are scattered back
    // after the join. The decoded-E₂ strip is shared read-only.
    let n = w.rows;
    let chunk = n.div_ceil(threads);
    let ranges: Vec<(usize, usize)> = (0..threads)
        .map(|t| (t * chunk, ((t + 1) * chunk).min(n)))
        .filter(|(a, b)| a < b)
        .collect();
    let parts: Vec<(usize, Vec<f32>)> = {
        let out_data: &[f32] = &out.data;
        let e2: &[f32] = &s.e2;
        std::thread::scope(|sc| {
            let handles: Vec<_> = ranges
                .iter()
                .map(|&(j0, j1)| {
                    sc.spawn(move || {
                        let span = j1 - j0;
                        let mut strip = vec![0.0f32; x.rows * span];
                        for r in 0..x.rows {
                            strip[r * span..(r + 1) * span]
                                .copy_from_slice(&out_data[r * n + j0..r * n + j1]);
                        }
                        let mut deq = vec![0.0f32; w.cols];
                        // only LoRC-attached linears read the error strip
                        let mut err =
                            vec![0.0f32; if lorc.is_some() { w.cols } else { 0 }];
                        packed_rows_into(
                            x, w, lorc, j0..j1, &mut deq, e2, &mut err, &mut strip, span, j0,
                        );
                        (j0, strip)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("gemv worker panicked")).collect()
        })
    };
    for (j0, strip) in parts {
        let span = strip.len() / x.rows;
        for r in 0..x.rows {
            out.data[r * n + j0..r * n + j0 + span]
                .copy_from_slice(&strip[r * span..(r + 1) * span]);
        }
    }
}

/// Decode-and-dot for one contiguous range of weight rows, accumulating
/// into `sink` laid out `[x.rows, sink_cols]` at column `j - col_off`.
/// The inner accumulation replicates `matmul_into`'s order exactly (see
/// module docs). When `lorc` is present, each decoded row gets the
/// fold-ordered `E₁·E₂` row added before the dot, making the strip
/// bit-equal to the effective (folded) weight row.
#[allow(clippy::too_many_arguments)]
fn packed_rows_into(
    x: &Matrix,
    w: &PackedWeight,
    lorc: Option<&PackedLorc>,
    rows: std::ops::Range<usize>,
    deq: &mut [f32],
    e2: &[f32],
    err: &mut [f32],
    sink: &mut [f32],
    sink_cols: usize,
    col_off: usize,
) {
    let k = w.cols;
    let deq = &mut deq[..k];
    for j in rows {
        w.dequant_row_into(j, deq);
        if let Some(l) = lorc {
            // effective row = Ŵ row + (E₁·E₂) row — the same elementwise
            // add (and the same err-row accumulation order) as the
            // pipeline's `LorcFactors::apply`, hence bit-equal to the
            // folded checkpoint's weight row.
            l.err_row_into(j, e2, err);
            for (d, e) in deq.iter_mut().zip(&err[..k]) {
                *d += e;
            }
        }
        for r in 0..x.rows {
            let xrow = &x.data[r * k..(r + 1) * k];
            let mut acc = sink[r * sink_cols + (j - col_off)];
            let mut kk = 0usize;
            // 4-term groups, matching matmul_into's unroll (left-assoc sum
            // added to the accumulator as one expression).
            while kk + 4 <= k {
                acc += xrow[kk] * deq[kk]
                    + xrow[kk + 1] * deq[kk + 1]
                    + xrow[kk + 2] * deq[kk + 2]
                    + xrow[kk + 3] * deq[kk + 3];
                kk += 4;
            }
            // tail: singles with the reference kernel's zero skip
            while kk < k {
                let av = xrow[kk];
                if av != 0.0 {
                    acc += av * deq[kk];
                }
                kk += 1;
            }
            sink[r * sink_cols + (j - col_off)] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::NumericFormat;
    use crate::lorc::{LorcConfig, LorcFactors};
    use crate::quant::{quantize_weight_rtn, ScaleConstraint, WeightQuantConfig};
    use crate::rng::Rng;
    use crate::tensor::matmul::matmul_into;

    fn reference(x: &Matrix, wt: &Matrix, seed: &Matrix) -> Matrix {
        let mut out = seed.clone();
        matmul_into(x, wt, &mut out);
        out
    }

    #[test]
    fn fused_gemv_bit_identical_to_dense_kernel() {
        let mut rng = Rng::seeded(0x6E3);
        // shapes exercise the 4-wide body, the mod-4 tail and odd cols
        for (rows, cols, batch) in [(8, 64, 1), (7, 65, 3), (12, 130, 2), (5, 33, 4)] {
            for fmt in [
                NumericFormat::FP4_E2M1,
                NumericFormat::INT4,
                NumericFormat::FP8_E4M3,
            ] {
                for cst in [ScaleConstraint::None, ScaleConstraint::M1] {
                    let wm = Matrix::randn(rows, cols, 0.05, &mut rng);
                    let q = quantize_weight_rtn(
                        &wm,
                        &WeightQuantConfig::new(fmt).with_group_size(32).with_constraint(cst),
                    );
                    let w = PackedWeight::from_quantized(&q);
                    let x = Matrix::randn(batch, cols, 1.0, &mut rng);
                    let seed = Matrix::randn(batch, rows, 0.1, &mut rng); // bias rows
                    let want = reference(&x, &w.dequantize().transpose(), &seed);
                    let mut got = seed.clone();
                    let mut s = GemvScratch::sized(cols, 0);
                    packed_matmul_into(&x, &w, None, &mut got, &mut s, 1);
                    for (i, (a, b)) in want.data.iter().zip(&got.data).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "{} {} [{rows}x{cols}]x{batch} elem {i}: {a} vs {b}",
                            fmt.name(),
                            cst.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn zero_activation_tail_skip_matches() {
        // the tail's `av != 0.0` skip must mirror the dense kernel even
        // when activations contain exact zeros
        let mut rng = Rng::seeded(0x6E4);
        let wm = Matrix::randn(6, 39, 0.05, &mut rng); // 39 = 4·9 + 3 tail
        let q = quantize_weight_rtn(
            &wm,
            &WeightQuantConfig::new(NumericFormat::FP4_E2M1).with_group_size(16),
        );
        let w = PackedWeight::from_quantized(&q);
        let mut x = Matrix::randn(2, 39, 1.0, &mut rng);
        for c in [0, 5, 36, 37, 38] {
            x.data[c] = 0.0;
            x.data[39 + c] = 0.0;
        }
        let seed = Matrix::zeros(2, 6);
        let want = reference(&x, &w.dequantize().transpose(), &seed);
        let mut got = seed.clone();
        let mut s = GemvScratch::sized(39, 0);
        packed_matmul_into(&x, &w, None, &mut got, &mut s, 1);
        assert_eq!(
            want.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            got.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn sharded_gemv_matches_single_thread() {
        let mut rng = Rng::seeded(0x6E5);
        let wm = Matrix::randn(21, 64, 0.05, &mut rng);
        let q = quantize_weight_rtn(
            &wm,
            &WeightQuantConfig::new(NumericFormat::INT4).with_group_size(32),
        );
        let w = PackedWeight::from_quantized(&q);
        let x = Matrix::randn(3, 64, 1.0, &mut rng);
        let seed = Matrix::randn(3, 21, 0.1, &mut rng);
        let mut solo = seed.clone();
        let mut s = GemvScratch::sized(64, 0);
        packed_matmul_into(&x, &w, None, &mut solo, &mut s, 1);
        for threads in [2usize, 3, 5, 64] {
            let mut sharded = seed.clone();
            packed_matmul_into(&x, &w, None, &mut sharded, &mut s, threads);
            assert_eq!(
                solo.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                sharded.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "threads={threads}"
            );
        }
        // empty activation batch: a no-op on every thread count
        let empty = Matrix::zeros(0, 64);
        let mut empty_out = Matrix::zeros(0, 21);
        packed_matmul_into(&empty, &w, None, &mut empty_out, &mut s, 1);
        packed_matmul_into(&empty, &w, None, &mut empty_out, &mut s, 3);
    }

    #[test]
    fn lorc_gemv_bit_identical_to_folded_dense_kernel() {
        // the packed+LoRC contract at kernel scale: the GEMV over
        // (codes, factors) must reproduce the dense kernel over the
        // *folded* effective matrix `Ŵ + E₁E₂`, bit for bit — solo and
        // sharded, even and odd dims, FP8 and F16 factors
        let mut rng = Rng::seeded(0x6E6);
        for (rows, cols, batch) in [(10, 64, 1), (9, 33, 3)] {
            for (rank, ffmt) in [
                (2usize, NumericFormat::FP8_E4M3),
                (8, NumericFormat::FP8_E4M3),
                (5, NumericFormat::F16),
            ] {
                let wm = Matrix::randn(rows, cols, 0.05, &mut rng);
                let q = quantize_weight_rtn(
                    &wm,
                    &WeightQuantConfig::new(NumericFormat::FP4_E2M1)
                        .with_group_size(16)
                        .with_constraint(ScaleConstraint::M1),
                );
                let lorc = LorcFactors::compute(
                    &wm,
                    &q.dequantize(),
                    &LorcConfig { rank, factor_format: ffmt },
                )
                .unwrap();
                let effective = lorc.apply(&q.dequantize()); // the pipeline's fold
                let w = PackedWeight::from_quantized(&q);
                let pl = PackedLorc::pack(&[(rows, Some(&lorc))]);
                let x = Matrix::randn(batch, cols, 1.0, &mut rng);
                let seed = Matrix::randn(batch, rows, 0.1, &mut rng);
                let want = reference(&x, &effective.transpose(), &seed);
                for threads in [1usize, 3] {
                    let mut got = seed.clone();
                    let mut s = GemvScratch::sized(cols, pl.e2_elems());
                    packed_matmul_into(&x, &w, Some(&pl), &mut got, &mut s, threads);
                    for (i, (a, b)) in want.data.iter().zip(&got.data).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "rank {rank} {} threads {threads} elem {i}: {a} vs {b}",
                            ffmt.name(),
                        );
                    }
                }
            }
        }
    }
}
