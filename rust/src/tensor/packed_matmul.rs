//! Fused dequant-GEMV over [`PackedWeight`] — the packed plan's hot path.
//!
//! Computes `out += x · Wᵀ` with `W` stored as bit-packed codes: each
//! weight row (one output feature) is decoded into a small scratch strip
//! through the per-group dequant tables (exponent-add when the scale
//! tensor allows, multiply otherwise — see [`crate::quant::packed`]) and
//! immediately dotted against every activation row while it is L1-hot.
//! Memory traffic per weight drops from 4 bytes (dense f32 plan) to
//! ~0.56 bytes (W4 codes + f32 group scales), which is the whole game for
//! a bandwidth-bound decode loop.
//!
//! ## Bit-identity contract
//!
//! The result is bit-identical to seeding `out` the same way and calling
//! [`matmul_into`](super::matmul::matmul_into)`(x, dequantize(W)ᵀ, out)` —
//! the dense compiled plan's exact kernel. Two facts make this hold:
//!
//! 1. the decoded strip is bit-equal to the dequantized weight row
//!    ([`PackedWeight::dequant_row_into`]'s contract), and
//! 2. the accumulation order is identical: `matmul_into` k-blocks by
//!    `KB = 256` and 4-way unrolls inside each block. Because `KB` is a
//!    multiple of 4, its 4-term groups sit at `k ≡ 0 (mod 4)` globally
//!    with only the final `k mod 4` elements handled singly (with the
//!    same `a != 0` skip) — exactly the flat loop below.
//!
//! `tests/packed_equivalence.rs` enforces the end-to-end version of this
//! across architectures, formats and scale constraints.
//!
//! ## Sharding
//!
//! With `threads > 1` the weight rows (output features) are sharded across
//! `std::thread` workers — each worker decodes only its own rows, so the
//! dequant work parallelizes with the FLOPs. Each worker accumulates into
//! a private `[batch, shard]` strip that is scattered into `out` after the
//! join, keeping the hot loops free of sharing. The threaded path spawns
//! (and therefore allocates) per call; the zero-allocation decode contract
//! (`tests/plan_alloc.rs`) applies to `threads == 1`, the default.

use crate::quant::PackedWeight;

use super::Matrix;

/// `out += x · wᵀ` over packed codes. `out` must be pre-seeded (zeroed or
/// bias rows) and shaped `[x.rows, w.rows]`; `deq` is the caller's decode
/// scratch with `deq.len() >= w.cols` (unused when `threads > 1`, where
/// each worker owns a private strip).
pub fn packed_matmul_into(
    x: &Matrix,
    w: &PackedWeight,
    out: &mut Matrix,
    deq: &mut [f32],
    threads: usize,
) {
    assert_eq!(x.cols, w.cols, "gemv input dim mismatch");
    assert_eq!(out.rows, x.rows);
    assert_eq!(out.cols, w.rows);
    if x.rows == 0 || w.rows == 0 {
        return; // nothing to accumulate (and nothing to shard)
    }
    let threads = threads.max(1).min(w.rows);
    if threads == 1 {
        packed_rows_into(x, w, 0..w.rows, &mut deq[..w.cols], &mut out.data, w.rows, 0);
        return;
    }

    // Shard the GEMV rows (output features) across workers. Each worker
    // copies its columns' seeds out of `out`, accumulates into a private
    // [batch, span] strip (so the accumulator chain — seed first, then the
    // k-groups — is the same as the inline path, keeping the result
    // bit-identical to threads == 1), and the strips are scattered back
    // after the join.
    let n = w.rows;
    let chunk = n.div_ceil(threads);
    let ranges: Vec<(usize, usize)> = (0..threads)
        .map(|t| (t * chunk, ((t + 1) * chunk).min(n)))
        .filter(|(a, b)| a < b)
        .collect();
    let parts: Vec<(usize, Vec<f32>)> = {
        let out_data: &[f32] = &out.data;
        std::thread::scope(|s| {
            let handles: Vec<_> = ranges
                .iter()
                .map(|&(j0, j1)| {
                    s.spawn(move || {
                        let span = j1 - j0;
                        let mut strip = vec![0.0f32; x.rows * span];
                        for r in 0..x.rows {
                            strip[r * span..(r + 1) * span]
                                .copy_from_slice(&out_data[r * n + j0..r * n + j1]);
                        }
                        let mut deq = vec![0.0f32; w.cols];
                        packed_rows_into(x, w, j0..j1, &mut deq, &mut strip, span, j0);
                        (j0, strip)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("gemv worker panicked")).collect()
        })
    };
    for (j0, strip) in parts {
        let span = strip.len() / x.rows;
        for r in 0..x.rows {
            out.data[r * n + j0..r * n + j0 + span]
                .copy_from_slice(&strip[r * span..(r + 1) * span]);
        }
    }
}

/// Decode-and-dot for one contiguous range of weight rows, accumulating
/// into `sink` laid out `[x.rows, sink_cols]` at column `j - col_off`.
/// The inner accumulation replicates `matmul_into`'s order exactly (see
/// module docs).
fn packed_rows_into(
    x: &Matrix,
    w: &PackedWeight,
    rows: std::ops::Range<usize>,
    deq: &mut [f32],
    sink: &mut [f32],
    sink_cols: usize,
    col_off: usize,
) {
    let k = w.cols;
    let deq = &mut deq[..k];
    for j in rows {
        w.dequant_row_into(j, deq);
        for r in 0..x.rows {
            let xrow = &x.data[r * k..(r + 1) * k];
            let mut acc = sink[r * sink_cols + (j - col_off)];
            let mut kk = 0usize;
            // 4-term groups, matching matmul_into's unroll (left-assoc sum
            // added to the accumulator as one expression).
            while kk + 4 <= k {
                acc += xrow[kk] * deq[kk]
                    + xrow[kk + 1] * deq[kk + 1]
                    + xrow[kk + 2] * deq[kk + 2]
                    + xrow[kk + 3] * deq[kk + 3];
                kk += 4;
            }
            // tail: singles with the reference kernel's zero skip
            while kk < k {
                let av = xrow[kk];
                if av != 0.0 {
                    acc += av * deq[kk];
                }
                kk += 1;
            }
            sink[r * sink_cols + (j - col_off)] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::NumericFormat;
    use crate::quant::{quantize_weight_rtn, ScaleConstraint, WeightQuantConfig};
    use crate::rng::Rng;
    use crate::tensor::matmul::matmul_into;

    fn reference(x: &Matrix, w: &PackedWeight, seed: &Matrix) -> Matrix {
        let wt = w.dequantize().transpose();
        let mut out = seed.clone();
        matmul_into(x, &wt, &mut out);
        out
    }

    #[test]
    fn fused_gemv_bit_identical_to_dense_kernel() {
        let mut rng = Rng::seeded(0x6E3);
        // shapes exercise the 4-wide body, the mod-4 tail and odd cols
        for (rows, cols, batch) in [(8, 64, 1), (7, 65, 3), (12, 130, 2), (5, 33, 4)] {
            for fmt in [
                NumericFormat::FP4_E2M1,
                NumericFormat::INT4,
                NumericFormat::FP8_E4M3,
            ] {
                for cst in [ScaleConstraint::None, ScaleConstraint::M1] {
                    let wm = Matrix::randn(rows, cols, 0.05, &mut rng);
                    let q = quantize_weight_rtn(
                        &wm,
                        &WeightQuantConfig::new(fmt).with_group_size(32).with_constraint(cst),
                    );
                    let w = PackedWeight::from_quantized(&q);
                    let x = Matrix::randn(batch, cols, 1.0, &mut rng);
                    let seed = Matrix::randn(batch, rows, 0.1, &mut rng); // bias rows
                    let want = reference(&x, &w, &seed);
                    let mut got = seed.clone();
                    let mut deq = vec![0.0f32; cols];
                    packed_matmul_into(&x, &w, &mut got, &mut deq, 1);
                    for (i, (a, b)) in want.data.iter().zip(&got.data).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "{} {} [{rows}x{cols}]x{batch} elem {i}: {a} vs {b}",
                            fmt.name(),
                            cst.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn zero_activation_tail_skip_matches() {
        // the tail's `av != 0.0` skip must mirror the dense kernel even
        // when activations contain exact zeros
        let mut rng = Rng::seeded(0x6E4);
        let wm = Matrix::randn(6, 39, 0.05, &mut rng); // 39 = 4·9 + 3 tail
        let q = quantize_weight_rtn(
            &wm,
            &WeightQuantConfig::new(NumericFormat::FP4_E2M1).with_group_size(16),
        );
        let w = PackedWeight::from_quantized(&q);
        let mut x = Matrix::randn(2, 39, 1.0, &mut rng);
        for c in [0, 5, 36, 37, 38] {
            x.data[c] = 0.0;
            x.data[39 + c] = 0.0;
        }
        let seed = Matrix::zeros(2, 6);
        let want = reference(&x, &w, &seed);
        let mut got = seed.clone();
        let mut deq = vec![0.0f32; 39];
        packed_matmul_into(&x, &w, &mut got, &mut deq, 1);
        assert_eq!(
            want.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            got.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn sharded_gemv_matches_single_thread() {
        let mut rng = Rng::seeded(0x6E5);
        let wm = Matrix::randn(21, 64, 0.05, &mut rng);
        let q = quantize_weight_rtn(
            &wm,
            &WeightQuantConfig::new(NumericFormat::INT4).with_group_size(32),
        );
        let w = PackedWeight::from_quantized(&q);
        let x = Matrix::randn(3, 64, 1.0, &mut rng);
        let seed = Matrix::randn(3, 21, 0.1, &mut rng);
        let mut solo = seed.clone();
        let mut deq = vec![0.0f32; 64];
        packed_matmul_into(&x, &w, &mut solo, &mut deq, 1);
        for threads in [2usize, 3, 5, 64] {
            let mut sharded = seed.clone();
            packed_matmul_into(&x, &w, &mut sharded, &mut deq, threads);
            assert_eq!(
                solo.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                sharded.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "threads={threads}"
            );
        }
        // empty activation batch: a no-op on every thread count
        let empty = Matrix::zeros(0, 64);
        let mut empty_out = Matrix::zeros(0, 21);
        packed_matmul_into(&empty, &w, &mut empty_out, &mut deq, 1);
        packed_matmul_into(&empty, &w, &mut empty_out, &mut deq, 3);
    }
}
