//! Dense f32 tensor substrate.
//!
//! [`Matrix`] is a row-major 2-D tensor; this module supplies the handful of
//! dense ops the engine/GPTQ/LoRC layers need (matmul, transpose, row/col
//! reductions, norms). The matmul hot path lives in [`matmul`] and is the
//! subject of the L3 perf pass (see EXPERIMENTS.md §Perf).

pub mod matmul;
pub mod packed_matmul;

use crate::rng::Rng;

/// A row-major 2-D f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    /// Reshape in place to `[rows, cols]` with all entries zeroed.
    ///
    /// This is the arena primitive behind [`crate::plan`]'s scratch buffers:
    /// when the new element count fits the existing `Vec` capacity (always
    /// true for buffers pre-sized to `max_seq`), no heap allocation happens —
    /// steady-state decode reuses the same backing storage every call.
    pub fn resize_to(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
    }

    /// Reshape in place to `[rows, row.len()]` with every row initialized
    /// from `row` — a single write pass (no intermediate zero fill), for
    /// bias-seeded matmul accumulators. Same no-allocation guarantee as
    /// [`resize_to`](Self::resize_to) when capacity suffices.
    pub fn resize_rows_to(&mut self, rows: usize, row: &[f32]) {
        self.rows = rows;
        self.cols = row.len();
        self.data.clear();
        for _ in 0..rows {
            self.data.extend_from_slice(row);
        }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Matrix { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        Matrix::from_fn(n, n, |r, c| if r == c { 1.0 } else { 0.0 })
    }

    /// Random N(0, std²) matrix (deterministic under the given rng).
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_normal(&mut m.data, std);
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self.at(r, c)).collect()
    }

    pub fn set_col(&mut self, c: usize, vals: &[f32]) {
        assert_eq!(vals.len(), self.rows);
        for (r, &v) in vals.iter().enumerate() {
            *self.at_mut(r, c) = v;
        }
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        // blocked transpose for cache friendliness on larger matrices
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        t.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        t
    }

    /// `self @ other` via the optimized kernel.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        matmul::matmul_into(self, other, &mut out);
        out
    }

    /// `self @ otherᵀ` without materializing the transpose.
    pub fn matmul_t(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols, "matmul_t shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.rows);
        matmul::matmul_bt_into(self, other, &mut out);
        out
    }

    pub fn add_assign(&mut self, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }

    pub fn scale(&mut self, s: f32) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Mean squared difference against another matrix.
    pub fn mse(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum::<f64>()
            / self.data.len().max(1) as f64
    }

    /// (min, max) over all entries.
    pub fn min_max(&self) -> (f32, f32) {
        let mut mn = f32::INFINITY;
        let mut mx = f32::NEG_INFINITY;
        for &x in &self.data {
            mn = mn.min(x);
            mx = mx.max(x);
        }
        (mn, mx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small_exact() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Matrix::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_t_matches_explicit_transpose() {
        let mut rng = Rng::seeded(11);
        let a = Matrix::randn(17, 23, 1.0, &mut rng);
        let b = Matrix::randn(19, 23, 1.0, &mut rng);
        let c1 = a.matmul_t(&b);
        let c2 = a.matmul(&b.transpose());
        assert!(c1.mse(&c2) < 1e-10);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::seeded(12);
        let a = Matrix::randn(33, 65, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::seeded(13);
        let a = Matrix::randn(8, 8, 1.0, &mut rng);
        let i = Matrix::eye(8);
        assert!(a.matmul(&i).mse(&a) < 1e-12);
        assert!(i.matmul(&a).mse(&a) < 1e-12);
    }

    #[test]
    fn col_roundtrip() {
        let mut rng = Rng::seeded(14);
        let mut a = Matrix::randn(5, 4, 1.0, &mut rng);
        let c = a.col(2);
        a.set_col(2, &c);
        assert_eq!(a.col(2), c);
    }

    #[test]
    fn resize_to_reuses_capacity() {
        let mut m = Matrix::zeros(8, 16);
        let cap = m.data.capacity();
        let ptr = m.data.as_ptr();
        m.row_mut(3).iter_mut().for_each(|v| *v = 7.0);
        m.resize_to(4, 16);
        assert_eq!((m.rows, m.cols), (4, 16));
        assert!(m.data.iter().all(|&v| v == 0.0), "resize_to must zero");
        assert_eq!(m.data.capacity(), cap);
        assert_eq!(m.data.as_ptr(), ptr, "shrinking reshape must not realloc");
        m.resize_to(8, 16);
        assert_eq!(m.data.as_ptr(), ptr, "growing back within capacity must not realloc");
    }

    #[test]
    fn resize_rows_to_broadcasts_row() {
        let mut m = Matrix::zeros(4, 6);
        let ptr = m.data.as_ptr();
        let bias = [1.0f32, 2.0, 3.0];
        m.resize_rows_to(4, &bias);
        assert_eq!((m.rows, m.cols), (4, 3));
        for r in 0..4 {
            assert_eq!(m.row(r), &bias);
        }
        assert_eq!(m.data.as_ptr(), ptr, "within-capacity reshape must not realloc");
    }

    #[test]
    fn fro_norm_known() {
        let a = Matrix::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((a.fro_norm() - 5.0).abs() < 1e-12);
    }
}
