//! The real PJRT execution path (requires the `pjrt` feature and the
//! vendored xla_extension bindings — see Cargo.toml).

use std::path::{Path, PathBuf};

use crate::error::{Context, Result};
use crate::model::{Checkpoint, ModelConfig};
use crate::{anyhow, bail};

use super::SCORE_BATCH;
use crate::engine::EngineOpts;
use crate::eval::PplResult;

thread_local! {
    // One PJRT CPU client per thread, kept alive for the thread's lifetime:
    // xla_extension 0.5.1 segfaults when a client is destroyed and a new one
    // created in the same process, so we never drop it. `PjRtClient` is an
    // `Rc` handle, so clones are cheap and share the underlying client.
    static CPU_CLIENT: std::cell::RefCell<Option<xla::PjRtClient>> =
        const { std::cell::RefCell::new(None) };
}

/// The shared per-thread PJRT CPU client.
pub fn cpu_client() -> Result<xla::PjRtClient> {
    CPU_CLIENT.with(|c| {
        let mut slot = c.borrow_mut();
        if slot.is_none() {
            *slot = Some(xla::PjRtClient::cpu()?);
        }
        Ok(slot.as_ref().unwrap().clone())
    })
}

/// A compiled scoring executable bound to a PJRT CPU client.
pub struct HloScorer {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    pub batch: usize,
    pub seq: usize,
    path: PathBuf,
}

impl HloScorer {
    /// Load + compile an artifact. `seq` must match the `max_seq` the
    /// artifact was lowered with (checked at execute time via shapes).
    pub fn load(path: &Path, batch: usize, seq: usize) -> Result<HloScorer> {
        HloScorer::load_with_client(cpu_client()?, path, batch, seq)
    }

    /// Same, sharing an existing client (`PjRtClient` is an `Rc` handle —
    /// the table harness compiles dozens of artifacts on one client).
    pub fn load_with_client(
        client: xla::PjRtClient,
        path: &Path,
        batch: usize,
        seq: usize,
    ) -> Result<HloScorer> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("loading HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(HloScorer { client, exe, batch, seq, path: path.to_path_buf() })
    }

    /// Convenience: locate + load the scoring artifact for (config, opts).
    pub fn for_model(artifacts: &Path, cfg: &ModelConfig, opts: &EngineOpts) -> Result<HloScorer> {
        let act = super::act_tag(opts)
            .ok_or_else(|| anyhow!("activation format {:?} has no HLO artifact", opts.act))?;
        let path = artifacts.join(super::score_artifact_name(cfg, act));
        if !path.exists() {
            bail!("missing artifact {} (run `make artifacts`)", path.display());
        }
        HloScorer::load(&path, SCORE_BATCH, cfg.max_seq)
    }

    /// Upload the checkpoint weights once; reuse across many score calls.
    pub fn upload_weights(&self, ck: &Checkpoint) -> Result<WeightSet> {
        let mut bufs = Vec::with_capacity(ck.tensors.len());
        let mut literals = Vec::with_capacity(ck.tensors.len());
        // BTreeMap iterates name-sorted — the artifact's parameter order.
        for (_name, m) in &ck.tensors {
            let lit = xla::Literal::vec1(&m.data).reshape(&[m.rows as i64, m.cols as i64])?;
            bufs.push(self.client.buffer_from_host_literal(None, &lit)?);
            // PJRT's CopyFromLiteral is asynchronous: the literal must stay
            // alive until the device copy completes, so WeightSet owns it.
            literals.push(lit);
        }
        Ok(WeightSet { bufs, _literals: literals })
    }

    /// Score `batch` windows of `seq` tokens; returns per-window NLL sums
    /// (summed over the `seq-1` predicted positions).
    pub fn score_batch(&self, tokens: &[u16], weights: &WeightSet) -> Result<Vec<f32>> {
        if tokens.len() != self.batch * self.seq {
            bail!(
                "score_batch: got {} tokens, artifact {} expects {}x{}",
                tokens.len(),
                self.path.display(),
                self.batch,
                self.seq
            );
        }
        let toks_i32: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        let tok_lit =
            xla::Literal::vec1(&toks_i32).reshape(&[self.batch as i64, self.seq as i64])?;
        let tok_buf = self.client.buffer_from_host_literal(None, &tok_lit)?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + weights.bufs.len());
        args.push(&tok_buf);
        for b in &weights.bufs {
            args.push(b);
        }
        let out = self.exe.execute_b(&args)?;
        let lit = out[0][0].to_literal_sync()?;
        let nll = lit.to_tuple1()?.to_vec::<f32>()?;
        Ok(nll)
    }

    /// Perplexity of a token stream with already-uploaded weights.
    pub fn ppl_with(&self, weights: &WeightSet, tokens: &[u16]) -> Result<PplResult> {
        let win = self.seq;
        let windows: Vec<&[u16]> = tokens.chunks_exact(win).collect();
        let mut total = PplResult { nll_sum: 0.0, tokens: 0 };
        let mut batch_buf: Vec<u16> = Vec::with_capacity(self.batch * win);
        let mut i = 0;
        while i < windows.len() {
            let n = (windows.len() - i).min(self.batch);
            batch_buf.clear();
            for w in &windows[i..i + n] {
                batch_buf.extend_from_slice(w);
            }
            // pad with the first window; padded outputs are discarded
            for _ in n..self.batch {
                batch_buf.extend_from_slice(windows[i]);
            }
            let nll = self.score_batch(&batch_buf, weights)?;
            for &v in nll.iter().take(n) {
                total.nll_sum += v as f64;
                total.tokens += win - 1;
            }
            i += n;
        }
        Ok(total)
    }
}

/// Device-resident weight buffers for one (quantized) checkpoint. Owns the
/// host literals too — PJRT's host→device copies are asynchronous and
/// xla_extension 0.5.1 does not pin the source (use-after-free otherwise).
pub struct WeightSet {
    bufs: Vec<xla::PjRtBuffer>,
    _literals: Vec<xla::Literal>,
}

/// A compiled Pallas fused W4A8 matmul artifact:
/// `f(x f32[M,K], codes i32[N,K], scales f32[N,G]) -> (y f32[M,N],)` where
/// the kernel token-wise-quantizes `x` to FP8 E4M3, decodes the FP4 E2M1
/// codes with their FGQ group scales, and contracts — the paper's W4A8
/// GEMM as one fused device op.
pub struct QMatmulArtifact {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub groups: usize,
}

impl QMatmulArtifact {
    pub fn load(path: &Path, m: usize, k: usize, n: usize, groups: usize) -> Result<Self> {
        let client = cpu_client()?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(QMatmulArtifact { client, exe, m, k, n, groups })
    }

    pub fn run(&self, x: &[f32], codes: &[i32], scales: &[f32]) -> Result<Vec<f32>> {
        if x.len() != self.m * self.k
            || codes.len() != self.n * self.k
            || scales.len() != self.n * self.groups
        {
            bail!("qmatmul: shape mismatch");
        }
        // host->device copies are async in xla_extension 0.5.1: stage via
        // buffers and keep the literals alive until the output sync below.
        let xl = xla::Literal::vec1(x).reshape(&[self.m as i64, self.k as i64])?;
        let cl = xla::Literal::vec1(codes).reshape(&[self.n as i64, self.k as i64])?;
        let sl = xla::Literal::vec1(scales).reshape(&[self.n as i64, self.groups as i64])?;
        let xb = self.client.buffer_from_host_literal(None, &xl)?;
        let cb = self.client.buffer_from_host_literal(None, &cl)?;
        let sb = self.client.buffer_from_host_literal(None, &sl)?;
        let out = self.exe.execute_b(&[&xb, &cb, &sb])?;
        let lit = out[0][0].to_literal_sync()?;
        drop((xl, cl, sl));
        Ok(lit.to_tuple1()?.to_vec::<f32>()?)
    }
}
