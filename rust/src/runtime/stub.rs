//! API-identical stand-in for the PJRT runtime, used when the crate is
//! built without the `pjrt` feature (the default — the xla_extension
//! bindings are not in the offline vendor set).
//!
//! Every constructor returns a descriptive error; none of the types can
//! actually be instantiated, so the methods are unreachable and exist only
//! to keep callers (coordinator, experiments, benches, CLI) compiling
//! unchanged. The serving stack detects the failure and falls back to the
//! prepacked in-process engine ([`crate::plan::CompiledModel`]).

use std::path::Path;

use crate::bail;
use crate::engine::EngineOpts;
use crate::error::Result;
use crate::eval::PplResult;
use crate::model::{Checkpoint, ModelConfig};

const NO_PJRT: &str =
    "built without the `pjrt` feature: PJRT artifacts cannot be executed \
     (enable the feature with the vendored xla_extension bindings, or use \
     the compiled in-process engine)";

/// Stub scoring executable — see the module docs.
pub struct HloScorer {
    pub batch: usize,
    pub seq: usize,
    // Not constructible: every `load` path errors out first.
    _priv: (),
}

impl HloScorer {
    pub fn load(_path: &Path, _batch: usize, _seq: usize) -> Result<HloScorer> {
        bail!("{NO_PJRT}");
    }

    pub fn for_model(
        _artifacts: &Path,
        _cfg: &ModelConfig,
        _opts: &EngineOpts,
    ) -> Result<HloScorer> {
        bail!("{NO_PJRT}");
    }

    pub fn upload_weights(&self, _ck: &Checkpoint) -> Result<WeightSet> {
        bail!("{NO_PJRT}");
    }

    pub fn score_batch(&self, _tokens: &[u16], _weights: &WeightSet) -> Result<Vec<f32>> {
        bail!("{NO_PJRT}");
    }

    pub fn ppl_with(&self, _weights: &WeightSet, _tokens: &[u16]) -> Result<PplResult> {
        bail!("{NO_PJRT}");
    }
}

/// Stub device-resident weight set.
pub struct WeightSet {
    _priv: (),
}

/// Stub fused-W4A8-matmul artifact.
pub struct QMatmulArtifact {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub groups: usize,
    _priv: (),
}

impl QMatmulArtifact {
    pub fn load(_path: &Path, _m: usize, _k: usize, _n: usize, _groups: usize) -> Result<Self> {
        bail!("{NO_PJRT}");
    }

    pub fn run(&self, _x: &[f32], _codes: &[i32], _scales: &[f32]) -> Result<Vec<f32>> {
        bail!("{NO_PJRT}");
    }
}
