//! PJRT runtime — loads the AOT artifacts produced by `python/compile/aot.py`
//! and executes them from the Rust request path (Python is never loaded).
//!
//! ## Artifact contract (shared with `aot.py`)
//!
//! * Interchange is **HLO text** (`HloModuleProto::from_text_file`): the
//!   image's xla_extension 0.5.1 rejects jax≥0.5 serialized protos
//!   (64-bit instruction ids), while the text parser reassigns ids.
//! * Scoring artifact `score_{arch}_d{d}_l{layers}_{act}.hlo.txt` computes
//!   teacher-forced NLL sums:
//!   `f(tokens i32[B,S], w₀, w₁, … sorted by tensor name) -> (nll f32[B],)`
//!   with `B = 8`, `S = max_seq`, and `act ∈ {a16, a8int, a8fp}` selecting
//!   the token-wise activation fake-quant baked into the graph.
//! * Weight parameters are the checkpoint tensors as `[rows, cols]` f32,
//!   ordered by byte-wise-sorted tensor name (BTreeMap order — identical to
//!   Python's `sorted()`).
//! * `score_selfcheck_{act}.hlo.txt` is a miniature config (opt, vocab 48,
//!   d 24, heads 3, layers 2, ff 48, S 16, B 2) used by `zqfp selfcheck`
//!   to cross-validate PJRT numerics against the Rust engine.
//! * `qmatmul_*.hlo.txt` artifacts carry the Pallas fused W4A8 kernel
//!   (lowered with interpret=True) — see [`QMatmulArtifact`].
//!
//! ## Feature gating
//!
//! The xla_extension bindings are not part of the offline vendor set, so
//! the PJRT execution path is behind the `pjrt` cargo feature. The default
//! build substitutes [`stub`] — an API-identical module whose entry points
//! return descriptive errors — and the serving stack falls back to the
//! prepacked in-process engine ([`crate::plan::CompiledModel`]).

use std::path::Path;

use crate::bail;
use crate::engine::EngineOpts;
use crate::error::Result;
use crate::eval::PplResult;
use crate::formats::NumericFormat;
use crate::model::{Checkpoint, ModelConfig};

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::*;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::*;

/// Batch size every scoring artifact is lowered with.
pub const SCORE_BATCH: usize = 8;

/// True when this build can actually execute PJRT artifacts.
pub const PJRT_AVAILABLE: bool = cfg!(feature = "pjrt");

/// Activation tag an [`EngineOpts`] maps to in artifact names.
pub fn act_tag(opts: &EngineOpts) -> Option<&'static str> {
    match opts.act.format {
        NumericFormat::F16 => Some("a16"),
        NumericFormat::INT8 => Some("a8int"),
        NumericFormat::FP8_E4M3 => Some("a8fp"),
        _ => None,
    }
}

/// Artifact filename for a model config + activation scheme.
pub fn score_artifact_name(cfg: &ModelConfig, act: &str) -> String {
    format!(
        "score_{}_d{}_l{}_{}.hlo.txt",
        cfg.arch.name(),
        cfg.d_model,
        cfg.n_layers,
        act
    )
}

/// Perplexity through the PJRT path (the serving-grade evaluator the table
/// harness uses; much faster than the interpretive Rust engine on this
/// host, same numerics up to f32 reduction order).
pub fn hlo_perplexity(
    artifacts: &Path,
    ck: &Checkpoint,
    opts: &EngineOpts,
    tokens: &[u16],
    seq: usize,
) -> Result<PplResult> {
    let scorer = HloScorer::for_model(artifacts, &ck.config, opts)?;
    if seq != scorer.seq {
        bail!("hlo path requires seq == {} (got {seq})", scorer.seq);
    }
    let weights = scorer.upload_weights(ck)?;
    scorer.ppl_with(&weights, tokens)
}

/// The miniature config `score_selfcheck_*.hlo.txt` is lowered with.
pub fn selfcheck_config() -> ModelConfig {
    ModelConfig {
        name: "selfcheck".into(),
        arch: crate::model::Arch::Opt,
        vocab_size: 48,
        d_model: 24,
        n_heads: 3,
        n_layers: 2,
        d_ff: 48,
        max_seq: 16,
    }
}

/// `zqfp selfcheck`: PJRT vs Rust-engine numerics parity on a random tiny
/// checkpoint, for each activation scheme with an artifact.
pub fn selfcheck(args: &crate::cli::Args) -> std::result::Result<(), String> {
    let artifacts = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    args.finish()?;
    selfcheck_impl(&artifacts).map_err(|e| format!("{e:#}"))
}

pub fn selfcheck_impl(artifacts: &Path) -> Result<()> {
    let cfg = selfcheck_config();
    let mut rng = crate::rng::Rng::seeded(4242);
    let ck = Checkpoint::random(&cfg, &mut rng);
    let tokens: Vec<u16> = (0..cfg.max_seq * 6)
        .map(|_| rng.below(cfg.vocab_size) as u16)
        .collect();
    for fmt in [NumericFormat::F16, NumericFormat::INT8, NumericFormat::FP8_E4M3] {
        let opts = EngineOpts::with_act(fmt);
        let act = act_tag(&opts).unwrap();
        let path = artifacts.join(format!("score_selfcheck_{act}.hlo.txt"));
        if !path.exists() {
            bail!("missing {}", path.display());
        }
        let scorer = HloScorer::load(&path, 2, cfg.max_seq)?;
        let weights = scorer.upload_weights(&ck)?;
        let hlo = scorer.ppl_with(&weights, &tokens)?;
        let eng = crate::eval::perplexity(&ck, opts, &tokens, cfg.max_seq);
        let rel = (hlo.ppl() - eng.ppl()).abs() / eng.ppl();
        println!(
            "selfcheck {act}: engine ppl {:.6}  hlo ppl {:.6}  rel {:.2e}",
            eng.ppl(),
            hlo.ppl(),
            rel
        );
        if rel > 2e-3 {
            bail!("selfcheck {act} FAILED: rel {rel}");
        }
    }
    println!("selfcheck OK");
    Ok(())
}
