//! Synthetic corpora — the WikiText-2 / PTB / C4 surrogates (DESIGN.md §2).
//!
//! Each corpus is a seeded Markov process over a 512-token vocabulary with
//! Zipfian unigram statistics. The *structure* (transition graph) is fixed
//! per corpus name; the *sampling* stream differs between train and eval
//! splits — so eval is held-out but in-distribution, like the paper's
//! setting where the calibration and test sets share a domain.
//!
//! Three presets with deliberately different statistics (the paper averages
//! PPL over three datasets precisely because the deltas vary by domain):
//!
//! * `wiki` — strongly structured (λ=0.85, branch 3): low-entropy text.
//! * `ptb`  — loosely structured (λ=0.60, branch 8): high-entropy text.
//! * `c4`   — mixed-domain: two transition graphs, switching every ~64
//!   tokens (web crawl heterogeneity).
//!
//! Token streams serialize as little-endian u16 (`.tok`) — the interchange
//! the build-time JAX trainer consumes (`python/compile/pretrain.py`), so
//! Rust is the single source of truth for data.

use std::io::{self, Read};
use std::path::Path;

use crate::rng::Rng;

pub const VOCAB_SIZE: usize = 512;

/// Identifies a synthetic corpus.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CorpusKind {
    Wiki,
    Ptb,
    C4,
}

impl CorpusKind {
    pub const ALL: [CorpusKind; 3] = [CorpusKind::Wiki, CorpusKind::Ptb, CorpusKind::C4];

    pub fn name(&self) -> &'static str {
        match self {
            CorpusKind::Wiki => "wiki",
            CorpusKind::Ptb => "ptb",
            CorpusKind::C4 => "c4",
        }
    }

    pub fn parse(s: &str) -> Option<CorpusKind> {
        match s.to_ascii_lowercase().as_str() {
            "wiki" | "wikitext" | "wikitext2" => Some(CorpusKind::Wiki),
            "ptb" => Some(CorpusKind::Ptb),
            "c4" => Some(CorpusKind::C4),
            _ => None,
        }
    }

    fn structure_seed(&self) -> u64 {
        match self {
            CorpusKind::Wiki => 0x1111_2222_3333_4444,
            CorpusKind::Ptb => 0x5555_6666_7777_8888,
            CorpusKind::C4 => 0x9999_aaaa_bbbb_cccc,
        }
    }

    fn params(&self) -> CorpusParams {
        match self {
            CorpusKind::Wiki => CorpusParams { lambda: 0.85, branch: 3, zipf_s: 1.1, domains: 1 },
            CorpusKind::Ptb => CorpusParams { lambda: 0.60, branch: 8, zipf_s: 1.05, domains: 1 },
            CorpusKind::C4 => CorpusParams { lambda: 0.75, branch: 5, zipf_s: 0.9, domains: 2 },
        }
    }
}

#[derive(Debug, Clone, Copy)]
struct CorpusParams {
    /// probability of following the transition graph (vs unigram draw)
    lambda: f64,
    /// preferred successors per token
    branch: usize,
    /// Zipf exponent of the unigram distribution
    zipf_s: f64,
    /// number of alternating transition graphs (domain mixing)
    domains: usize,
}

/// A seeded synthetic corpus generator.
pub struct Corpus {
    kind: CorpusKind,
    params: CorpusParams,
    /// `domains × vocab × branch` preferred-successor table
    succ: Vec<u16>,
    /// cumulative Zipf distribution for inverse-transform sampling
    zipf_cdf: Vec<f64>,
}

impl Corpus {
    pub fn new(kind: CorpusKind) -> Corpus {
        let params = kind.params();
        let mut srng = Rng::seeded(kind.structure_seed());
        let mut succ = Vec::with_capacity(params.domains * VOCAB_SIZE * params.branch);
        for _dom in 0..params.domains {
            for _tok in 0..VOCAB_SIZE {
                for _b in 0..params.branch {
                    succ.push(srng.below(VOCAB_SIZE) as u16);
                }
            }
        }
        // Zipf CDF over a structure-seeded permutation of the vocab (so the
        // "frequent" tokens differ per corpus).
        let perm = srng.permutation(VOCAB_SIZE);
        let mut weights = vec![0.0f64; VOCAB_SIZE];
        for (rank, &tok) in perm.iter().enumerate() {
            weights[tok] = 1.0 / ((rank + 1) as f64).powf(params.zipf_s);
        }
        let total: f64 = weights.iter().sum();
        let mut acc = 0.0;
        let zipf_cdf = weights
            .iter()
            .map(|w| {
                acc += w / total;
                acc
            })
            .collect();
        Corpus { kind, params, succ, zipf_cdf }
    }

    pub fn kind(&self) -> CorpusKind {
        self.kind
    }

    fn zipf_sample(&self, rng: &mut Rng) -> u16 {
        let u = rng.uniform();
        // binary search the CDF
        let mut lo = 0usize;
        let mut hi = VOCAB_SIZE - 1;
        while lo < hi {
            let mid = (lo + hi) / 2;
            if self.zipf_cdf[mid] < u {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        lo as u16
    }

    /// Generate `n` tokens using the given sampling stream. `split_seed`
    /// distinguishes train (0) from eval (1) and calibration (2) draws.
    pub fn generate(&self, n: usize, split_seed: u64) -> Vec<u16> {
        let mut rng = Rng::seeded(self.kind.structure_seed() ^ (split_seed.wrapping_mul(0x517c_c1b7_2722_0a95)).wrapping_add(1));
        let mut out = Vec::with_capacity(n);
        let mut cur = self.zipf_sample(&mut rng);
        let mut domain = 0usize;
        for i in 0..n {
            if self.params.domains > 1 && i % 64 == 0 {
                domain = rng.below(self.params.domains);
            }
            out.push(cur);
            cur = if rng.uniform() < self.params.lambda {
                let b = rng.below(self.params.branch);
                self.succ[(domain * VOCAB_SIZE + cur as usize) * self.params.branch + b]
            } else {
                self.zipf_sample(&mut rng)
            };
        }
        out
    }

    /// The training mixture: equal thirds of each corpus, interleaved in
    /// 256-token segments (so every eval set is in-domain for the model).
    pub fn training_mixture(n: usize) -> Vec<u16> {
        let corpora: Vec<Corpus> = CorpusKind::ALL.iter().map(|&k| Corpus::new(k)).collect();
        let seg = 256usize;
        let per = n / 3 + seg;
        let streams: Vec<Vec<u16>> = corpora.iter().map(|c| c.generate(per, 0)).collect();
        let mut out = Vec::with_capacity(n);
        let mut offsets = [0usize; 3];
        let mut which = 0usize;
        while out.len() < n {
            let s = &streams[which];
            let o = offsets[which];
            let end = (o + seg).min(s.len());
            out.extend_from_slice(&s[o..end]);
            offsets[which] = end;
            which = (which + 1) % 3;
        }
        out.truncate(n);
        out
    }
}

/// Write a `.tok` file (little-endian u16).
pub fn write_tokens(path: &Path, tokens: &[u16]) -> io::Result<()> {
    let mut buf = Vec::with_capacity(tokens.len() * 2);
    for &t in tokens {
        buf.extend_from_slice(&t.to_le_bytes());
    }
    std::fs::write(path, buf)
}

/// Read a `.tok` file.
pub fn read_tokens(path: &Path) -> io::Result<Vec<u16>> {
    let mut f = std::fs::File::open(path)?;
    let mut data = Vec::new();
    f.read_to_end(&mut data)?;
    if data.len() % 2 != 0 {
        return Err(io::Error::new(io::ErrorKind::InvalidData, "odd byte count"));
    }
    Ok(data
        .chunks_exact(2)
        .map(|c| u16::from_le_bytes([c[0], c[1]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let c = Corpus::new(CorpusKind::Wiki);
        assert_eq!(c.generate(100, 1), c.generate(100, 1));
        assert_ne!(c.generate(100, 1), c.generate(100, 2));
    }

    #[test]
    fn corpora_differ() {
        let a = Corpus::new(CorpusKind::Wiki).generate(200, 0);
        let b = Corpus::new(CorpusKind::Ptb).generate(200, 0);
        assert_ne!(a, b);
    }

    #[test]
    fn tokens_in_vocab() {
        for kind in CorpusKind::ALL {
            let toks = Corpus::new(kind).generate(1000, 3);
            assert!(toks.iter().all(|&t| (t as usize) < VOCAB_SIZE));
        }
    }

    #[test]
    fn unigram_is_zipfian() {
        let toks = Corpus::new(CorpusKind::Wiki).generate(50_000, 0);
        let mut counts = vec![0usize; VOCAB_SIZE];
        for &t in &toks {
            counts[t as usize] += 1;
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        // head token much more frequent than the median token
        assert!(counts[0] > counts[VOCAB_SIZE / 2].max(1) * 10);
    }

    #[test]
    fn structure_is_learnable() {
        // bigram entropy must be far below unigram entropy for wiki —
        // otherwise there is nothing for the LM to learn.
        let toks = Corpus::new(CorpusKind::Wiki).generate(200_000, 0);
        let mut uni = vec![0f64; VOCAB_SIZE];
        let mut big = std::collections::HashMap::<(u16, u16), f64>::new();
        for w in toks.windows(2) {
            uni[w[0] as usize] += 1.0;
            *big.entry((w[0], w[1])).or_default() += 1.0;
        }
        let n = (toks.len() - 1) as f64;
        let h_uni: f64 = uni
            .iter()
            .filter(|&&c| c > 0.0)
            .map(|&c| -(c / n) * (c / n).log2())
            .sum();
        // conditional entropy H(next|cur)
        let h_joint: f64 = big
            .values()
            .map(|&c| -(c / n) * (c / n).log2())
            .sum();
        let h_cond = h_joint - h_uni;
        assert!(h_cond < h_uni * 0.75, "h_uni={h_uni:.2} h_cond={h_cond:.2}");
    }

    #[test]
    fn ptb_entropy_higher_than_wiki() {
        let entropy = |kind: CorpusKind| {
            let toks = Corpus::new(kind).generate(100_000, 0);
            let mut big = std::collections::HashMap::<(u16, u16), f64>::new();
            let mut uni = std::collections::HashMap::<u16, f64>::new();
            for w in toks.windows(2) {
                *big.entry((w[0], w[1])).or_default() += 1.0;
                *uni.entry(w[0]).or_default() += 1.0;
            }
            let n = (toks.len() - 1) as f64;
            let h_joint: f64 = big.values().map(|&c| -(c / n) * (c / n).log2()).sum();
            let h_uni: f64 = uni.values().map(|&c| -(c / n) * (c / n).log2()).sum();
            h_joint - h_uni
        };
        assert!(entropy(CorpusKind::Ptb) > entropy(CorpusKind::Wiki));
    }

    #[test]
    fn tok_file_roundtrip() {
        let dir = std::env::temp_dir().join("zqfp_test_tok");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.tok");
        let toks: Vec<u16> = (0..1000).map(|i| (i * 7 % 512) as u16).collect();
        write_tokens(&path, &toks).unwrap();
        assert_eq!(read_tokens(&path).unwrap(), toks);
    }

    #[test]
    fn mixture_covers_all_corpora() {
        let mix = Corpus::training_mixture(3000);
        assert_eq!(mix.len(), 3000);
        // segments from each corpus present: check first tokens of each
        // 256-segment cycle differ in distribution (weak check: non-constant)
        assert!(mix.iter().collect::<std::collections::HashSet<_>>().len() > 50);
    }
}
