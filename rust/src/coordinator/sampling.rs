//! Reproducible token sampling over decode logits.
//!
//! Temperature / top-k / top-p sampling driven by the in-crate
//! deterministic [`crate::rng::Rng`] — no global RNG, no thread-local
//! state. Determinism here is *positional*, not sequential: the draw for
//! the token at position `p` of a sequence is seeded from a hash of the
//! recipe seed and every token before `p` (see [`seed_hash`] /
//! [`extend_hash`]). That gives three properties the serving stack
//! depends on:
//!
//! * **Run reproducibility** — the same seed and the same prompt produce
//!   the same continuation, across processes and platforms.
//! * **Batch-composition invariance** — a sequence samples the same
//!   tokens whether it decodes alone, in a batch of 8, or after being
//!   preempted and replayed: nothing about *other* sequences enters the
//!   hash, and replaying a prefix recomputes the identical hash chain.
//! * **Session ≡ one-shot identity** — a multi-turn session that decodes
//!   the conversation incrementally draws the exact bits a one-shot
//!   generate over the concatenated history would, because both walk the
//!   same token prefix.
//!
//! Temperature 0 bypasses sampling entirely and routes through the same
//! [`crate::plan::argmax`] the greedy decode loop uses, so a
//! `temperature = 0` recipe is bit-for-bit the historical greedy path.

use crate::plan::argmax;
use crate::rng::Rng;

/// The sampling knobs of a recipe (`QuantRecipe::sampling`,
/// `zqfp serve --temperature/--top-k/--top-p/--seed`).
///
/// The default is greedy: `temperature = 0` short-circuits to
/// [`crate::plan::argmax`] and the other knobs are inert.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SamplingConfig {
    /// Softmax temperature. `0` = greedy argmax (the knobs below are
    /// ignored); `> 0` = sample from `softmax(logits / temperature)`.
    pub temperature: f32,
    /// Keep only the `top_k` highest-logit tokens before sampling
    /// (`0` = no top-k cut).
    pub top_k: usize,
    /// Nucleus sampling: keep the smallest prefix of the
    /// probability-sorted vocabulary whose mass reaches `top_p`, then
    /// renormalize (`1.0` = no cut). Must be in `(0, 1]`.
    pub top_p: f32,
    /// Recipe-level seed every sequence's per-position draws derive from.
    pub seed: u64,
}

impl Default for SamplingConfig {
    fn default() -> Self {
        SamplingConfig { temperature: 0.0, top_k: 0, top_p: 1.0, seed: 0 }
    }
}

impl SamplingConfig {
    /// True when this config is the greedy path (`temperature == 0`).
    pub fn is_greedy(&self) -> bool {
        self.temperature == 0.0
    }
}

/// splitmix64-style finalizer — the avalanche stage only (the additive
/// walk lives in the callers' token folds). `rng::splitmix64` is private
/// to its module on purpose; this is an independent mix with the same
/// pedigree, pinned here so sampling hashes never drift with rng
/// internals.
#[inline]
fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Fold one more token into a position hash: the hash for position
/// `p + 1` given the hash for position `p` and the token at `p`.
#[inline]
pub fn extend_hash(h: u64, tok: u16) -> u64 {
    mix(h ^ (tok as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15))
}

/// The position hash after an entire token prefix: seed the chain from
/// the recipe seed and fold every token in order. Incremental callers
/// keep the running hash and call [`extend_hash`] per appended token —
/// `seed_hash(s, &all)` ≡ folding `extend_hash` over the same tokens.
pub fn seed_hash(seed: u64, tokens: &[u16]) -> u64 {
    let mut h = mix(seed ^ 0x5EEDu64.wrapping_mul(0x9E3779B97F4A7C15));
    for &t in tokens {
        h = extend_hash(h, t);
    }
    h
}

/// Sample the next token from one logits row.
///
/// `hash` is the position hash of the prefix *before* this token
/// ([`seed_hash`] / [`extend_hash`]); exactly one uniform draw is made
/// from `Rng::seeded(hash)`. Temperature 0 returns `argmax(row)` without
/// touching the RNG — bit-for-bit the greedy decode path.
///
/// Pipeline: scale logits by `1/temperature` (f64, max-subtracted
/// softmax), sort descending (index-ascending tiebreak, matching
/// `argmax`'s first-max-wins), truncate to `top_k`, softmax, truncate to
/// the smallest prefix with cumulative mass ≥ `top_p` (never below one
/// candidate), renormalize, inverse-CDF walk on the single draw.
pub fn sample_token(cfg: &SamplingConfig, row: &[f32], hash: u64) -> u16 {
    if cfg.is_greedy() {
        return argmax(row) as u16;
    }
    let mut idx: Vec<usize> = (0..row.len()).collect();
    idx.sort_by(|&a, &b| row[b].total_cmp(&row[a]).then(a.cmp(&b)));
    if cfg.top_k > 0 && cfg.top_k < idx.len() {
        idx.truncate(cfg.top_k);
    }
    let inv_t = 1.0 / cfg.temperature as f64;
    // idx is logit-descending and inv_t > 0, so idx[0] carries the max.
    let m = row[idx[0]] as f64 * inv_t;
    let mut probs: Vec<f64> = idx.iter().map(|&i| (row[i] as f64 * inv_t - m).exp()).collect();
    let z: f64 = probs.iter().sum();
    for p in probs.iter_mut() {
        *p /= z;
    }
    if cfg.top_p < 1.0 {
        let mut cum = 0.0;
        let mut cut = probs.len();
        for (i, &p) in probs.iter().enumerate() {
            cum += p;
            if cum >= cfg.top_p as f64 {
                cut = i + 1;
                break;
            }
        }
        idx.truncate(cut);
        probs.truncate(cut);
        let z2: f64 = probs.iter().sum();
        for p in probs.iter_mut() {
            *p /= z2;
        }
    }
    let u = Rng::seeded(hash).uniform();
    let mut acc = 0.0;
    for (i, &p) in probs.iter().enumerate() {
        acc += p;
        if u < acc {
            return idx[i] as u16;
        }
    }
    idx[idx.len() - 1] as u16
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Brute-force reference: materialize the full truncated-renormalized
    /// distribution independently of `sample_token`'s incremental walk,
    /// then invert the same single uniform draw against it.
    fn reference_sample(cfg: &SamplingConfig, row: &[f32], hash: u64) -> u16 {
        assert!(cfg.temperature > 0.0);
        let mut order: Vec<usize> = (0..row.len()).collect();
        order.sort_by(|&a, &b| row[b].total_cmp(&row[a]).then(a.cmp(&b)));
        if cfg.top_k > 0 && cfg.top_k < order.len() {
            order.truncate(cfg.top_k);
        }
        let m = order.iter().map(|&i| row[i] as f64).fold(f64::NEG_INFINITY, f64::max)
            / cfg.temperature as f64;
        let weights: Vec<f64> = order
            .iter()
            .map(|&i| (row[i] as f64 / cfg.temperature as f64 - m).exp())
            .collect();
        let z: f64 = weights.iter().sum();
        let mut probs: Vec<f64> = weights.iter().map(|w| w / z).collect();
        if cfg.top_p < 1.0 {
            let mut cum = 0.0;
            let mut keep = probs.len();
            for (i, &p) in probs.iter().enumerate() {
                cum += p;
                if cum >= cfg.top_p as f64 {
                    keep = i + 1;
                    break;
                }
            }
            order.truncate(keep);
            probs.truncate(keep);
            let z2: f64 = probs.iter().sum();
            for p in probs.iter_mut() {
                *p /= z2;
            }
        }
        // the renormalized mass must be unity — the top-p cut must not
        // leave a deflated distribution behind
        let total: f64 = probs.iter().sum();
        assert!((total - 1.0).abs() < 1e-12, "mass {total}");
        let u = Rng::seeded(hash).uniform();
        let mut acc = 0.0;
        for (i, &p) in probs.iter().enumerate() {
            acc += p;
            if u < acc {
                return order[i] as u16;
            }
        }
        order[order.len() - 1] as u16
    }

    fn adversarial_rows(n: usize, width: usize) -> Vec<Vec<f32>> {
        let mut rng = Rng::seeded(0xBADC0DE);
        (0..n)
            .map(|k| {
                (0..width)
                    .map(|j| {
                        let base = rng.normal_f32() * 4.0;
                        // fold in ties and extremes to stress the sort
                        // tiebreak and the max-subtracted softmax
                        match (k + j) % 7 {
                            0 => 0.0,
                            1 => base.round(),
                            _ => base,
                        }
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn matches_brute_force_reference_across_knobs() {
        let rows = adversarial_rows(24, 48);
        let knobs = [
            (0.7, 0, 1.0),
            (1.0, 5, 1.0),
            (1.3, 0, 0.9),
            (0.5, 8, 0.75),
            (2.0, 3, 0.5),
            (1.0, 1, 1.0), // top-k 1 ≡ greedy regardless of the draw
        ];
        for (r, row) in rows.iter().enumerate() {
            for (t, k, p) in knobs {
                let cfg =
                    SamplingConfig { temperature: t, top_k: k, top_p: p, seed: 99 };
                let hash = seed_hash(cfg.seed, &[r as u16, 7, 11]);
                assert_eq!(
                    sample_token(&cfg, row, hash),
                    reference_sample(&cfg, row, hash),
                    "row {r} knobs T={t} k={k} p={p}"
                );
            }
        }
    }

    #[test]
    fn temperature_zero_is_argmax_bit_for_bit() {
        for row in adversarial_rows(16, 48) {
            let cfg = SamplingConfig { seed: 12345, ..SamplingConfig::default() };
            assert_eq!(
                sample_token(&cfg, &row, seed_hash(cfg.seed, &[1, 2, 3])),
                argmax(&row) as u16
            );
        }
    }

    #[test]
    fn vanishing_temperature_degenerates_to_greedy() {
        // as T → 0 the softmax collapses onto the argmax long before the
        // draw can pick anything else (rows get a unique max: with exact
        // ties the limit distribution is uniform over the tie set, which
        // is not what argmax-first-wins picks)
        let rows: Vec<Vec<f32>> = adversarial_rows(16, 48)
            .into_iter()
            .map(|mut row| {
                let top = argmax(&row);
                row[top] += 1.0;
                row
            })
            .collect();
        for (i, row) in rows.into_iter().enumerate() {
            let cfg = SamplingConfig {
                temperature: 1e-4,
                seed: 7,
                ..SamplingConfig::default()
            };
            let hash = seed_hash(cfg.seed, &[i as u16]);
            assert_eq!(sample_token(&cfg, &row, hash), argmax(&row) as u16);
        }
    }

    #[test]
    fn top_k_one_is_greedy_at_any_temperature() {
        for row in adversarial_rows(8, 32) {
            let cfg =
                SamplingConfig { temperature: 3.0, top_k: 1, top_p: 1.0, seed: 5 };
            assert_eq!(
                sample_token(&cfg, &row, seed_hash(5, &[9])),
                argmax(&row) as u16
            );
        }
    }

    #[test]
    fn same_seed_same_prefix_reproduces_across_runs() {
        let rows = adversarial_rows(8, 48);
        let cfg = SamplingConfig { temperature: 0.9, top_k: 10, top_p: 0.95, seed: 42 };
        let draw = |_: usize| -> Vec<u16> {
            let mut out = Vec::new();
            let mut h = seed_hash(cfg.seed, &[3, 1, 4]);
            for row in &rows {
                let t = sample_token(&cfg, row, h);
                h = extend_hash(h, t);
                out.push(t);
            }
            out
        };
        assert_eq!(draw(0), draw(1));
    }

    #[test]
    fn hash_is_positional_not_sequential() {
        // incremental extend_hash over a growing prefix lands on exactly
        // seed_hash of the whole prefix — the invariant that makes
        // delta-prefilled sessions and preemption replay sample the same
        // tokens as a fresh one-shot walk
        let tokens = [5u16, 0, 17, 3, 3, 29];
        let mut h = seed_hash(77, &[]);
        for (i, &t) in tokens.iter().enumerate() {
            assert_eq!(h, seed_hash(77, &tokens[..i]), "prefix {i}");
            h = extend_hash(h, t);
        }
        assert_eq!(h, seed_hash(77, &tokens));
    }

    #[test]
    fn different_seeds_or_prefixes_diverge() {
        assert_ne!(seed_hash(1, &[2, 3]), seed_hash(2, &[2, 3]));
        assert_ne!(seed_hash(1, &[2, 3]), seed_hash(1, &[3, 2]));
        assert_ne!(seed_hash(1, &[2]), seed_hash(1, &[2, 2]));
    }

    #[test]
    fn top_p_keeps_at_least_one_candidate() {
        // one spiked logit: its probability alone exceeds any top_p, so
        // the nucleus is a single token
        let mut row = vec![0.0f32; 16];
        row[11] = 50.0;
        let cfg = SamplingConfig { temperature: 1.0, top_k: 0, top_p: 0.01, seed: 0 };
        for extra in 0..32u16 {
            assert_eq!(sample_token(&cfg, &row, seed_hash(0, &[extra])), 11);
        }
    }

    #[test]
    fn sampled_distribution_tracks_probabilities() {
        // statistical sanity on the inverse-CDF walk: over many prefix
        // hashes the empirical frequencies approach the softmax
        let row = vec![2.0f32, 1.0, 0.0, -1.0];
        let cfg = SamplingConfig { temperature: 1.0, top_k: 0, top_p: 1.0, seed: 31 };
        let mut counts = [0usize; 4];
        let n = 20_000;
        for i in 0..n {
            counts[sample_token(&cfg, &row, seed_hash(31, &[i as u16, (i >> 16) as u16]))
                as usize] += 1;
        }
        let z: f64 = row.iter().map(|&l| (l as f64).exp()).sum();
        for (i, &l) in row.iter().enumerate() {
            let expect = (l as f64).exp() / z;
            let got = counts[i] as f64 / n as f64;
            assert!(
                (got - expect).abs() < 0.02,
                "token {i}: expected {expect:.3}, got {got:.3}"
            );
        }
    }
}
