//! Latency/throughput metrics for the serving coordinator.
//!
//! Two sample recorders ([`LatencyStats`] for durations, [`RateStats`] for
//! per-request token rates) feed one [`ServeReport`], which covers both
//! workload shapes the coordinator serves: window *scoring* (requests,
//! batches, request latency) and incremental *generation* (prefill vs
//! decode token counts, aggregate and per-request decode tokens/s).

use std::time::Duration;

/// Online latency recorder with percentile support.
#[derive(Debug, Default, Clone)]
pub struct LatencyStats {
    samples_us: Vec<u64>,
}

impl LatencyStats {
    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_micros() as u64);
    }

    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    pub fn mean_ms(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<u64>() as f64 / self.samples_us.len() as f64 / 1000.0
    }

    /// Percentile in milliseconds, or `None` with no samples — the
    /// safe form for a [`ServeReport`] built before any request completed
    /// (`v.len() - 1` must never be evaluated on an empty sample set).
    /// Out-of-range or non-finite `p` clamps into [0, 100].
    pub fn try_percentile_ms(&self, p: f64) -> Option<f64> {
        if self.samples_us.is_empty() {
            return None;
        }
        let p = if p.is_finite() { p.clamp(0.0, 100.0) } else { 100.0 };
        let mut v = self.samples_us.clone();
        v.sort_unstable();
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        Some(v[idx.min(v.len() - 1)] as f64 / 1000.0)
    }

    /// Percentile in milliseconds (p in [0, 100]); 0.0 with no samples.
    pub fn percentile_ms(&self, p: f64) -> f64 {
        self.try_percentile_ms(p).unwrap_or(0.0)
    }
}

/// Per-request rate recorder (decode tokens/s of each finished generation).
#[derive(Debug, Default, Clone)]
pub struct RateStats {
    samples: Vec<f64>,
}

impl RateStats {
    pub fn record(&mut self, rate: f64) {
        self.samples.push(rate);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().cloned().fold(0.0, f64::max)
    }
}

/// Aggregated serving-run report.
///
/// The scoring fields (`requests`, `batches`, `latency`, …) are filled by
/// every backend; the generation fields (`gen_requests` onward) only move
/// off zero on the compiled backend's continuous-batching loop.
#[derive(Debug, Clone, Default)]
pub struct ServeReport {
    /// Completed requests of any kind (scores + generations).
    pub requests: usize,
    /// Admission groups pulled off the queue.
    pub batches: usize,
    pub wall: Duration,
    /// Submit→respond latency, every request kind.
    pub latency: LatencyStats,
    pub mean_batch_size: f64,
    /// Generation requests completed.
    pub gen_requests: usize,
    /// Prompt tokens run through `prefill`.
    pub prefill_tokens: usize,
    /// Tokens produced by interleaved `decode_step_batch` calls.
    pub decode_tokens: usize,
    /// Interleaved decode steps executed.
    pub decode_steps: usize,
    /// Wall time spent inside `decode_step_batch`.
    pub decode_wall: Duration,
    /// Per-request decode tokens/s (recorded when a generation finishes).
    pub request_tok_s: RateStats,
    /// Requests rejected at submit because the bounded queue was full.
    pub shed_overloaded: usize,
    /// Requests whose deadline had already passed at admission.
    pub expired_admission: usize,
    /// Requests that expired mid-flight (during prefill or between decode
    /// steps) and returned `DeadlineExceeded` with partial tokens.
    pub expired_midflight: usize,
    /// Responses answered with `Faulted` (a panic was caught and isolated).
    pub faulted: usize,
    /// KV caches quarantined after a panic unwound out of their layer walk
    /// (dropped, never recycled into the free pool).
    pub quarantined_caches: usize,
    /// Queued requests answered `ShuttingDown` during a graceful drain.
    pub rejected_shutdown: usize,
    /// True when the run ended via the shutdown signal (graceful drain)
    /// rather than by every client hanging up.
    pub drained: bool,
    /// KV bytes resident when the run ended (paged: pages checked out to
    /// sequences; ring: in-flight rings × ring size).
    pub kv_resident_bytes: usize,
    /// High-water mark of resident KV bytes over the run.
    pub kv_peak_bytes: usize,
    /// Total KV bytes owned by the backing store (paged: the whole pool,
    /// free pages included; ring: recycled + in-flight rings).
    pub kv_pool_bytes: usize,
    /// Pages owned by the [`KvPagePool`] (0 when serving from rings).
    pub kv_pages_total: usize,
    /// Pages on the free list when the run ended.
    pub kv_pages_free: usize,
    /// Pages checked out to sequences when the run ended.
    pub kv_pages_resident: usize,
    /// High-water mark of resident pages over the run.
    pub kv_pages_peak: usize,
    /// Pages leaked by quarantined caches (free + resident + leaked
    /// = total, always).
    pub kv_pages_leaked: usize,
    /// Sequences evicted mid-decode because the page pool ran dry.
    pub kv_preemptions: usize,
    /// Preempted sequences re-admitted for re-prefill.
    pub kv_requeues: usize,
    /// Speculative draft/verify rounds executed (0 when the run does not
    /// speculate).
    pub spec_rounds: usize,
    /// Tokens proposed by the draft plan across all rounds.
    pub spec_drafted: usize,
    /// Proposed tokens the target plan accepted. Every round additionally
    /// commits one correction/bonus token of the target's own, so
    /// committed tokens = `spec_accepted + spec_rounds` (before the
    /// final-round clamp to each request's budget).
    pub spec_accepted: usize,
    /// KV positions rolled back from the two caches by rejections.
    pub spec_rolled_back: usize,
    /// Sequences that fell back to target-only decode (a draft-site fault
    /// or a dry page pool at draft-cache creation). Their token streams
    /// are unchanged — speculation only ever changes the rate.
    pub spec_fallbacks: usize,
    /// Sessions still open in the `SessionManager` when the run ended.
    pub sessions_active: usize,
    /// Idle session caches dropped by the LRU under capacity pressure
    /// (the sessions stay open; their next turn re-prefills).
    pub sessions_evicted: usize,
    /// Turns that re-prefilled a whole session history because the
    /// resident cache was gone (evicted, or quarantined by a fault).
    pub session_restores: usize,
    /// Tokens streamed to turn clients as per-step `TurnEvent::Token`
    /// items (before each turn's final typed result).
    pub streamed_tokens: usize,
}

impl ServeReport {
    pub fn throughput_rps(&self) -> f64 {
        self.requests as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    /// Aggregate decode throughput: generated tokens per second of time
    /// spent decoding (the number continuous batching is meant to raise).
    pub fn decode_tok_s(&self) -> f64 {
        self.decode_tokens as f64 / self.decode_wall.as_secs_f64().max(1e-9)
    }

    /// Mean sequences in flight per decode step.
    pub fn mean_decode_batch(&self) -> f64 {
        self.decode_tokens as f64 / self.decode_steps.max(1) as f64
    }

    /// Fraction of drafted tokens the target accepted (0 with no
    /// speculation).
    pub fn spec_acceptance_rate(&self) -> f64 {
        if self.spec_drafted == 0 {
            0.0
        } else {
            self.spec_accepted as f64 / self.spec_drafted as f64
        }
    }

    /// Mean tokens committed per speculative round (≥ 1 once rounds ran;
    /// the per-round speedup lever — a plain decode step commits exactly
    /// one).
    pub fn spec_tokens_per_round(&self) -> f64 {
        if self.spec_rounds == 0 {
            0.0
        } else {
            // each round commits its accepted prefix + 1 correction/bonus
            (self.spec_accepted + self.spec_rounds) as f64 / self.spec_rounds as f64
        }
    }

    /// Responses that were something other than `Ok` — the sum of every
    /// robustness counter (shed, expired, faulted, drained-away).
    pub fn degraded(&self) -> usize {
        self.shed_overloaded
            + self.expired_admission
            + self.expired_midflight
            + self.faulted
            + self.rejected_shutdown
    }

    pub fn print(&self) {
        println!(
            "requests={} batches={} mean_batch={:.2} wall={:.2}s",
            self.requests,
            self.batches,
            self.mean_batch_size,
            self.wall.as_secs_f64()
        );
        println!(
            "throughput {:.1} req/s | latency mean {:.2} ms  p50 {:.2}  p95 {:.2}  p99 {:.2}",
            self.throughput_rps(),
            self.latency.mean_ms(),
            self.latency.percentile_ms(50.0),
            self.latency.percentile_ms(95.0),
            self.latency.percentile_ms(99.0),
        );
        if self.gen_requests > 0 {
            println!(
                "generation: {} requests | prefill {} tok | decode {} tok in {} steps \
                 (mean batch {:.2})",
                self.gen_requests,
                self.prefill_tokens,
                self.decode_tokens,
                self.decode_steps,
                self.mean_decode_batch(),
            );
            println!(
                "decode {:.0} tok/s aggregate | per-request mean {:.0} tok/s \
                 (min {:.0}, max {:.0})",
                self.decode_tok_s(),
                self.request_tok_s.mean(),
                self.request_tok_s.min(),
                self.request_tok_s.max(),
            );
        }
        if self.spec_rounds > 0 || self.spec_fallbacks > 0 {
            println!(
                "speculative: {} rounds | drafted {} accepted {} ({:.0}% acceptance) | \
                 {:.2} tok/round | rolled back {} kv positions | fallbacks {}",
                self.spec_rounds,
                self.spec_drafted,
                self.spec_accepted,
                100.0 * self.spec_acceptance_rate(),
                self.spec_tokens_per_round(),
                self.spec_rolled_back,
                self.spec_fallbacks,
            );
        }
        if self.kv_pool_bytes > 0 {
            println!(
                "kv memory: resident {} B (peak {} B) of {} B pooled{}",
                self.kv_resident_bytes,
                self.kv_peak_bytes,
                self.kv_pool_bytes,
                if self.kv_pages_total > 0 {
                    format!(
                        " | pages {} free + {} resident + {} leaked of {} \
                         (peak {}) | preemptions {} requeues {}",
                        self.kv_pages_free,
                        self.kv_pages_resident,
                        self.kv_pages_leaked,
                        self.kv_pages_total,
                        self.kv_pages_peak,
                        self.kv_preemptions,
                        self.kv_requeues,
                    )
                } else {
                    String::new()
                },
            );
        }
        if self.sessions_active > 0
            || self.sessions_evicted > 0
            || self.session_restores > 0
            || self.streamed_tokens > 0
        {
            println!(
                "sessions: {} active | evicted {} restored {} | streamed {} tok",
                self.sessions_active,
                self.sessions_evicted,
                self.session_restores,
                self.streamed_tokens,
            );
        }
        if self.degraded() > 0 || self.drained {
            println!(
                "robustness: shed {} | expired {} at admission + {} mid-flight | \
                 faulted {} (caches quarantined {}) | shutdown-rejected {}{}",
                self.shed_overloaded,
                self.expired_admission,
                self.expired_midflight,
                self.faulted,
                self.quarantined_caches,
                self.rejected_shutdown,
                if self.drained { " | drained" } else { "" },
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut s = LatencyStats::default();
        for i in 1..=100u64 {
            s.record(Duration::from_millis(i));
        }
        assert_eq!(s.count(), 100);
        assert!((s.mean_ms() - 50.5).abs() < 0.01);
        assert!(s.percentile_ms(50.0) <= s.percentile_ms(95.0));
        assert!(s.percentile_ms(95.0) <= s.percentile_ms(99.0));
        assert!((s.percentile_ms(99.0) - 99.0).abs() <= 1.0);
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = LatencyStats::default();
        assert_eq!(s.mean_ms(), 0.0);
        assert_eq!(s.percentile_ms(99.0), 0.0);
        assert_eq!(s.try_percentile_ms(99.0), None);
        let r = RateStats::default();
        assert_eq!(r.count(), 0);
        assert_eq!(r.mean(), 0.0);
        assert_eq!(r.max(), 0.0);
    }

    #[test]
    fn report_before_any_request_completes_is_safe() {
        // Regression: a ServeReport built while the queue is still empty
        // (zero completed requests, zero samples) must survive every
        // derived metric and the full print path — the percentile index
        // `len() - 1` must never underflow.
        let report = ServeReport::default();
        assert_eq!(report.latency.percentile_ms(50.0), 0.0);
        assert_eq!(report.latency.percentile_ms(99.0), 0.0);
        assert_eq!(report.latency.try_percentile_ms(0.0), None);
        assert_eq!(report.throughput_rps(), 0.0);
        assert_eq!(report.mean_decode_batch(), 0.0);
        assert_eq!(report.request_tok_s.min(), 0.0);
        report.print(); // must not panic
        // degenerate percentile arguments on a single sample
        let mut one = LatencyStats::default();
        one.record(Duration::from_millis(7));
        for p in [-5.0, 0.0, 50.0, 100.0, 250.0, f64::NAN, f64::INFINITY] {
            let v = one.percentile_ms(p);
            assert!((v - 7.0).abs() < 0.01, "p={p}: {v}");
        }
    }

    #[test]
    fn degraded_sums_every_robustness_counter() {
        let report = ServeReport {
            shed_overloaded: 1,
            expired_admission: 2,
            expired_midflight: 3,
            faulted: 4,
            quarantined_caches: 4, // not a response — excluded from the sum
            rejected_shutdown: 5,
            drained: true,
            ..Default::default()
        };
        assert_eq!(report.degraded(), 15);
        report.print(); // robustness line must not panic
        assert_eq!(ServeReport::default().degraded(), 0);
    }

    #[test]
    fn session_counters_print_and_are_not_degradation() {
        // Session telemetry (active/evicted/restored/streamed) is reuse
        // accounting, not failed responses: degraded() must ignore it,
        // and both the populated and the empty report must print — the
        // empty-report regression contract of the PR 3 LatencyStats fix
        // extended to the new counters.
        let report = ServeReport {
            sessions_active: 3,
            sessions_evicted: 2,
            session_restores: 2,
            streamed_tokens: 40,
            ..Default::default()
        };
        assert_eq!(report.degraded(), 0);
        report.print(); // sessions line must not panic
        let empty = ServeReport::default();
        assert_eq!(empty.sessions_active, 0);
        assert_eq!(empty.streamed_tokens, 0);
        empty.print(); // no sessions line, no panic
    }

    #[test]
    fn kv_memory_accounting_is_not_degradation() {
        // Preemption/requeue churn and page accounting are memory-pressure
        // telemetry, not failed responses: degraded() must stay zero, and
        // the kv print block must hold the pool identity.
        let report = ServeReport {
            kv_resident_bytes: 4096,
            kv_peak_bytes: 8192,
            kv_pool_bytes: 16384,
            kv_pages_total: 8,
            kv_pages_free: 5,
            kv_pages_resident: 2,
            kv_pages_peak: 4,
            kv_pages_leaked: 1,
            kv_preemptions: 3,
            kv_requeues: 3,
            ..Default::default()
        };
        assert_eq!(
            report.kv_pages_free + report.kv_pages_resident + report.kv_pages_leaked,
            report.kv_pages_total
        );
        assert_eq!(report.degraded(), 0);
        report.print(); // kv memory block must not panic
        // ring-mode report: bytes without pages still prints
        let ring = ServeReport {
            kv_resident_bytes: 1024,
            kv_peak_bytes: 2048,
            kv_pool_bytes: 4096,
            ..Default::default()
        };
        ring.print();
    }

    #[test]
    fn two_sample_percentile_interpolation() {
        // n = 2 pins the index formula `round(p/100 · (n-1))` at its
        // smallest non-degenerate size: everything below the rounding
        // midpoint maps to the first sample, the midpoint and above to the
        // second (round-half-away-from-zero), and the endpoints are exact.
        let mut s = LatencyStats::default();
        s.record(Duration::from_millis(10));
        s.record(Duration::from_millis(20));
        assert_eq!(s.count(), 2);
        for (p, want) in [
            (0.0, 10.0),
            (25.0, 10.0),
            (49.0, 10.0),
            (50.0, 20.0), // 0.5 rounds away from zero → the upper sample
            (95.0, 20.0),
            (100.0, 20.0),
        ] {
            let v = s.percentile_ms(p);
            assert!((v - want).abs() < 0.01, "p={p}: got {v}, want {want}");
        }
        // clamped / non-finite arguments behave like the endpoints
        assert_eq!(s.percentile_ms(-10.0), s.percentile_ms(0.0));
        assert_eq!(s.percentile_ms(400.0), s.percentile_ms(100.0));
        assert_eq!(s.percentile_ms(f64::NAN), s.percentile_ms(100.0));
        // insertion order must not matter: the recorder sorts per query
        let mut rev = LatencyStats::default();
        rev.record(Duration::from_millis(20));
        rev.record(Duration::from_millis(10));
        assert_eq!(rev.percentile_ms(0.0), s.percentile_ms(0.0));
        assert_eq!(rev.percentile_ms(100.0), s.percentile_ms(100.0));
    }

    #[test]
    fn zero_decode_tokens_yields_zero_rate_not_nan() {
        // A run whose generations all faulted (or expired) before the
        // first decode step still spent wall time in the decode loop:
        // decode_tok_s must come back exactly 0.0 — finite, printable —
        // not NaN/∞ from a 0/0 or x/0.
        let report = ServeReport {
            gen_requests: 2,
            decode_tokens: 0,
            decode_steps: 0,
            decode_wall: Duration::from_millis(350),
            ..Default::default()
        };
        assert_eq!(report.decode_tok_s(), 0.0);
        assert!(report.decode_tok_s().is_finite());
        assert_eq!(report.mean_decode_batch(), 0.0);
        report.print(); // the generation block prints zeros, no panic
        // and with zero wall as well (nothing ever reached decode)
        let idle = ServeReport { gen_requests: 1, ..Default::default() };
        assert_eq!(idle.decode_tok_s(), 0.0);
        assert!(idle.decode_tok_s().is_finite());
    }

    #[test]
    fn spec_counters_derive_rates_and_print() {
        let report = ServeReport {
            spec_rounds: 4,
            spec_drafted: 16,
            spec_accepted: 12,
            spec_rolled_back: 4,
            spec_fallbacks: 1,
            ..Default::default()
        };
        assert!((report.spec_acceptance_rate() - 0.75).abs() < 1e-12);
        // 12 accepted + 4 corrections/bonuses over 4 rounds
        assert!((report.spec_tokens_per_round() - 4.0).abs() < 1e-12);
        assert_eq!(report.degraded(), 0, "speculation telemetry is not degradation");
        report.print(); // speculative block must not panic
        let none = ServeReport::default();
        assert_eq!(none.spec_acceptance_rate(), 0.0);
        assert_eq!(none.spec_tokens_per_round(), 0.0);
    }

    #[test]
    fn rate_stats_aggregate() {
        let mut r = RateStats::default();
        for v in [10.0, 20.0, 30.0] {
            r.record(v);
        }
        assert_eq!(r.count(), 3);
        assert!((r.mean() - 20.0).abs() < 1e-12);
        assert_eq!(r.min(), 10.0);
        assert_eq!(r.max(), 30.0);
    }

    #[test]
    fn decode_throughput_derivations() {
        let report = ServeReport {
            decode_tokens: 600,
            decode_steps: 200,
            decode_wall: Duration::from_secs(2),
            ..Default::default()
        };
        assert!((report.decode_tok_s() - 300.0).abs() < 1e-9);
        assert!((report.mean_decode_batch() - 3.0).abs() < 1e-12);
        // zero-field report stays finite
        let empty = ServeReport::default();
        assert_eq!(empty.mean_decode_batch(), 0.0);
        assert!(empty.decode_tok_s().abs() < 1e-3);
    }
}
