//! Latency/throughput metrics for the serving coordinator.

use std::time::Duration;

/// Online latency recorder with percentile support.
#[derive(Debug, Default, Clone)]
pub struct LatencyStats {
    samples_us: Vec<u64>,
}

impl LatencyStats {
    pub fn record(&mut self, d: Duration) {
        self.samples_us.push(d.as_micros() as u64);
    }

    pub fn count(&self) -> usize {
        self.samples_us.len()
    }

    pub fn mean_ms(&self) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        self.samples_us.iter().sum::<u64>() as f64 / self.samples_us.len() as f64 / 1000.0
    }

    /// Percentile in milliseconds (p in [0, 100]).
    pub fn percentile_ms(&self, p: f64) -> f64 {
        if self.samples_us.is_empty() {
            return 0.0;
        }
        let mut v = self.samples_us.clone();
        v.sort_unstable();
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)] as f64 / 1000.0
    }
}

/// Aggregated serving-run report.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub requests: usize,
    pub batches: usize,
    pub wall: Duration,
    pub latency: LatencyStats,
    pub mean_batch_size: f64,
}

impl ServeReport {
    pub fn throughput_rps(&self) -> f64 {
        self.requests as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    pub fn print(&self) {
        println!(
            "requests={} batches={} mean_batch={:.2} wall={:.2}s",
            self.requests,
            self.batches,
            self.mean_batch_size,
            self.wall.as_secs_f64()
        );
        println!(
            "throughput {:.1} req/s | latency mean {:.2} ms  p50 {:.2}  p95 {:.2}  p99 {:.2}",
            self.throughput_rps(),
            self.latency.mean_ms(),
            self.latency.percentile_ms(50.0),
            self.latency.percentile_ms(95.0),
            self.latency.percentile_ms(99.0),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut s = LatencyStats::default();
        for i in 1..=100u64 {
            s.record(Duration::from_millis(i));
        }
        assert_eq!(s.count(), 100);
        assert!((s.mean_ms() - 50.5).abs() < 0.01);
        assert!(s.percentile_ms(50.0) <= s.percentile_ms(95.0));
        assert!(s.percentile_ms(95.0) <= s.percentile_ms(99.0));
        assert!((s.percentile_ms(99.0) - 99.0).abs() <= 1.0);
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = LatencyStats::default();
        assert_eq!(s.mean_ms(), 0.0);
        assert_eq!(s.percentile_ms(99.0), 0.0);
    }
}
