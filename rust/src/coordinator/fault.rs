//! Deterministic fault injection for the serving loop.
//!
//! A [`FaultPlan`] names *where* faults strike (one of the five
//! [`FaultSite`]s the coordinator arms) and *when* ([`FaultSpec`]); a
//! [`FaultInjector`] executes the plan at run time. Every stochastic
//! trigger draws from the in-crate [`Rng`] seeded from the plan, so a
//! chaos run is reproducible bit-for-bit from `(--fault spec,
//! --fault-seed)` — the same discipline the synthetic corpora and
//! property tests already follow.
//!
//! Two fault kinds:
//!
//! * **panic** specs (`always`, `once`, `nth=K`, `every=K`, `p=F`) make
//!   [`FaultInjector::fire`] panic with a typed [`FaultPayload`] through
//!   the *real* panic machinery — the coordinator's `catch_unwind`
//!   isolation is exercised end to end, not simulated.
//! * **stall** specs (`stall=MS`) sleep at the site instead of
//!   panicking — the deterministic way to drive deadline expiry and
//!   drain-while-in-flight scenarios in tests without racing the clock.
//!
//! The plan is carried on [`CoordinatorConfig`](super::CoordinatorConfig)
//! (CLI: `zqfp serve --fault <site>:<spec>[,...]`), never on a
//! `QuantRecipe` — faults are a harness concern, not a reproducible
//! serving configuration.

use std::time::Duration;

use crate::rng::Rng;

/// Where the serving loop arms the injector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// Per request, as it is pulled off the queue (before any work).
    Admission,
    /// Inside the guarded prefill of a generation request.
    Prefill,
    /// Inside the guarded decode step (batched and solo-retry paths) and
    /// the speculative verify pass (both touch the *target* KV cache).
    Decode,
    /// Inside the guarded speculative draft phase (draft-plan prompt
    /// prefill and token proposal). A draft fault poisons only the
    /// sequence's draft cache: the coordinator quarantines it and the
    /// sequence falls back to target-only decode with its output
    /// unchanged — the client never sees the fault.
    Draft,
    /// Just before a response is sent back to the client.
    Respond,
}

impl FaultSite {
    pub fn name(self) -> &'static str {
        match self {
            FaultSite::Admission => "admission",
            FaultSite::Prefill => "prefill",
            FaultSite::Decode => "decode",
            FaultSite::Draft => "draft",
            FaultSite::Respond => "respond",
        }
    }

    fn parse(s: &str) -> Option<FaultSite> {
        match s {
            "admission" => Some(FaultSite::Admission),
            "prefill" => Some(FaultSite::Prefill),
            "decode" => Some(FaultSite::Decode),
            "draft" => Some(FaultSite::Draft),
            "respond" => Some(FaultSite::Respond),
            _ => None,
        }
    }
}

/// When a fault point strikes, counted in *armings* (calls to
/// [`FaultInjector::fire`] for the point's site).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultSpec {
    /// Panic on every arming.
    Always,
    /// Panic on the first arming only.
    Once,
    /// Panic on exactly the `n`th arming (1-based).
    Nth(u64),
    /// Panic on every `n`th arming.
    Every(u64),
    /// Panic with probability `p` per arming (seeded, reproducible).
    Prob(f64),
    /// Sleep this long on every arming instead of panicking.
    Stall(Duration),
}

impl FaultSpec {
    fn parse(s: &str) -> Result<FaultSpec, String> {
        let bad_num = |k: &str, v: &str| format!("fault spec {k}={v}: not a number");
        match s.split_once('=') {
            None => match s {
                "always" => Ok(FaultSpec::Always),
                "once" => Ok(FaultSpec::Once),
                other => Err(format!(
                    "unknown fault spec {other:?} (try always|once|nth=K|every=K|p=F|stall=MS)"
                )),
            },
            Some(("nth", v)) => {
                let n: u64 = v.parse().map_err(|_| bad_num("nth", v))?;
                if n == 0 {
                    return Err("fault spec nth=0: armings are 1-based".to_string());
                }
                Ok(FaultSpec::Nth(n))
            }
            Some(("every", v)) => {
                let n: u64 = v.parse().map_err(|_| bad_num("every", v))?;
                if n == 0 {
                    return Err("fault spec every=0 would never fire".to_string());
                }
                Ok(FaultSpec::Every(n))
            }
            Some(("p", v)) => {
                let p: f64 = v.parse().map_err(|_| bad_num("p", v))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(format!("fault spec p={v}: probability must be in [0, 1]"));
                }
                Ok(FaultSpec::Prob(p))
            }
            Some(("stall", v)) => {
                let ms: u64 = v.parse().map_err(|_| bad_num("stall", v))?;
                Ok(FaultSpec::Stall(Duration::from_millis(ms)))
            }
            Some((k, _)) => Err(format!(
                "unknown fault spec key {k:?} (try always|once|nth=K|every=K|p=F|stall=MS)"
            )),
        }
    }
}

/// A parsed, seedable fault schedule: one or more `(site, spec)` points.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct FaultPlan {
    points: Vec<(FaultSite, FaultSpec)>,
    seed: u64,
}

impl FaultPlan {
    /// Parse the CLI grammar: comma-separated `<site>:<spec>` points,
    /// e.g. `"prefill:p=0.3,decode:every=4,respond:once"`. Sites may
    /// repeat (each point keeps its own counter and rng stream).
    pub fn parse(text: &str) -> Result<FaultPlan, String> {
        let mut points = Vec::new();
        for part in text.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (site, spec) = part
                .split_once(':')
                .ok_or_else(|| format!("fault point {part:?}: expected <site>:<spec>"))?;
            let site = FaultSite::parse(site.trim()).ok_or_else(|| {
                format!(
                    "unknown fault site {site:?} (try admission|prefill|decode|draft|respond)"
                )
            })?;
            points.push((site, FaultSpec::parse(spec.trim())?));
        }
        if points.is_empty() {
            return Err("empty fault plan (expected <site>:<spec>[,...])".to_string());
        }
        Ok(FaultPlan { points, seed: 0 })
    }

    /// Build a plan directly (tests).
    pub fn new(points: Vec<(FaultSite, FaultSpec)>) -> FaultPlan {
        FaultPlan { points, seed: 0 }
    }

    /// Pin the rng seed the probabilistic specs draw from.
    pub fn with_seed(mut self, seed: u64) -> FaultPlan {
        self.seed = seed;
        self
    }

    pub fn points(&self) -> &[(FaultSite, FaultSpec)] {
        &self.points
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// One-line human form for the serve banner.
    pub fn summary(&self) -> String {
        let parts: Vec<String> = self
            .points
            .iter()
            .map(|(site, spec)| format!("{}:{spec:?}", site.name()))
            .collect();
        format!("{} (seed {})", parts.join(","), self.seed)
    }
}

/// The panic payload injected panics carry — typed so the coordinator
/// (and test panic hooks) can tell an injected fault from a genuine bug.
#[derive(Debug, Clone, Copy)]
pub struct FaultPayload {
    pub site: FaultSite,
}

/// One armed fault point at run time.
#[derive(Debug)]
struct Arm {
    site: FaultSite,
    spec: FaultSpec,
    /// Armings seen so far (incremented per `fire` at this site).
    count: u64,
    fired: bool,
    rng: Rng,
}

impl Arm {
    /// Advance the arming counter; true ⇒ this arming panics.
    fn trip(&mut self) -> bool {
        self.count += 1;
        match self.spec {
            FaultSpec::Always => true,
            FaultSpec::Once => {
                let first = !self.fired;
                self.fired = true;
                first
            }
            FaultSpec::Nth(n) => self.count == n,
            FaultSpec::Every(n) => self.count % n == 0,
            FaultSpec::Prob(p) => self.rng.uniform() < p,
            FaultSpec::Stall(_) => false,
        }
    }
}

/// Executes a [`FaultPlan`]: each point keeps its own arming counter and
/// forked rng stream, so schedules are reproducible regardless of how
/// sites interleave at run time.
#[derive(Debug)]
pub struct FaultInjector {
    arms: Vec<Arm>,
}

impl FaultInjector {
    pub fn new(plan: &FaultPlan) -> FaultInjector {
        let mut root = Rng::seeded(plan.seed);
        let arms = plan
            .points
            .iter()
            .enumerate()
            .map(|(i, &(site, spec))| Arm {
                site,
                spec,
                count: 0,
                fired: false,
                rng: root.fork(i as u64),
            })
            .collect();
        FaultInjector { arms }
    }

    /// Arm every point at `site`: stall points sleep, panic points that
    /// trip panic with a [`FaultPayload`] (callers wrap the enclosing
    /// work in `catch_unwind`). Sites with no points are free.
    pub fn fire(&mut self, site: FaultSite) {
        let mut tripped = false;
        for arm in self.arms.iter_mut().filter(|a| a.site == site) {
            if let FaultSpec::Stall(d) = arm.spec {
                arm.count += 1;
                std::thread::sleep(d);
            } else {
                tripped |= arm.trip();
            }
        }
        if tripped {
            std::panic::panic_any(FaultPayload { site });
        }
    }
}

/// Human-readable message for a caught panic payload: injected faults
/// name their site, genuine panics keep their message.
pub fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(p) = payload.downcast_ref::<FaultPayload>() {
        format!("injected fault at {}", p.site.name())
    } else if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic (non-string payload)".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn catches(f: impl FnOnce()) -> bool {
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)).is_err()
    }

    #[test]
    fn parse_grammar_round_trips() {
        let plan = FaultPlan::parse("prefill:p=0.3, decode:every=4,respond:once").unwrap();
        assert_eq!(
            plan.points(),
            &[
                (FaultSite::Prefill, FaultSpec::Prob(0.3)),
                (FaultSite::Decode, FaultSpec::Every(4)),
                (FaultSite::Respond, FaultSpec::Once),
            ]
        );
        let plan = FaultPlan::parse("admission:nth=3,decode:stall=20").unwrap();
        assert_eq!(
            plan.points(),
            &[
                (FaultSite::Admission, FaultSpec::Nth(3)),
                (FaultSite::Decode, FaultSpec::Stall(Duration::from_millis(20))),
            ]
        );
        assert_eq!(plan.with_seed(9).seed(), 9);
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "",
            "prefill",
            "warp:always",
            "decode:sometimes",
            "decode:nth=0",
            "decode:every=0",
            "decode:p=1.5",
            "decode:p=x",
            "decode:stall=fast",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn once_nth_every_schedules() {
        let plan = FaultPlan::parse("decode:once").unwrap();
        let mut fi = FaultInjector::new(&plan);
        assert!(catches(|| fi.fire(FaultSite::Decode)));
        assert!(!catches(|| fi.fire(FaultSite::Decode)));
        // other sites never trip
        assert!(!catches(|| fi.fire(FaultSite::Prefill)));

        let plan = FaultPlan::parse("decode:nth=3").unwrap();
        let mut fi = FaultInjector::new(&plan);
        let fires: Vec<bool> = (0..5).map(|_| catches(|| fi.fire(FaultSite::Decode))).collect();
        assert_eq!(fires, [false, false, true, false, false]);

        let plan = FaultPlan::parse("decode:every=2").unwrap();
        let mut fi = FaultInjector::new(&plan);
        let fires: Vec<bool> = (0..6).map(|_| catches(|| fi.fire(FaultSite::Decode))).collect();
        assert_eq!(fires, [false, true, false, true, false, true]);
    }

    #[test]
    fn prob_schedule_is_seed_reproducible() {
        let run = |seed: u64| -> Vec<bool> {
            let plan = FaultPlan::parse("respond:p=0.5").unwrap().with_seed(seed);
            let mut fi = FaultInjector::new(&plan);
            (0..64).map(|_| catches(|| fi.fire(FaultSite::Respond))).collect()
        };
        assert_eq!(run(7), run(7), "same seed, same schedule");
        assert_ne!(run(7), run(8), "different seeds diverge");
        let fired = run(7).iter().filter(|&&b| b).count();
        assert!((10..=54).contains(&fired), "p=0.5 over 64 armings fired {fired}");
    }

    #[test]
    fn repeated_sites_keep_independent_counters() {
        // two points on the same site: either tripping panics the arming
        let plan = FaultPlan::parse("decode:nth=2,decode:nth=4").unwrap();
        let mut fi = FaultInjector::new(&plan);
        let fires: Vec<bool> = (0..5).map(|_| catches(|| fi.fire(FaultSite::Decode))).collect();
        assert_eq!(fires, [false, true, false, true, false]);
    }

    #[test]
    fn stall_sleeps_instead_of_panicking() {
        let plan = FaultPlan::parse("admission:stall=15").unwrap();
        let mut fi = FaultInjector::new(&plan);
        let t0 = std::time::Instant::now();
        assert!(!catches(|| fi.fire(FaultSite::Admission)));
        assert!(t0.elapsed() >= Duration::from_millis(10));
    }

    #[test]
    fn payload_is_typed_and_message_extraction_works() {
        let plan = FaultPlan::parse("prefill:always").unwrap();
        let mut fi = FaultInjector::new(&plan);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            fi.fire(FaultSite::Prefill)
        }))
        .unwrap_err();
        let payload = err.downcast_ref::<FaultPayload>().expect("typed payload");
        assert_eq!(payload.site, FaultSite::Prefill);
        assert_eq!(panic_message(&*err), "injected fault at prefill");
        // genuine panics keep their message
        let err = std::panic::catch_unwind(|| panic!("kernel oob at row {}", 3)).unwrap_err();
        assert_eq!(panic_message(&*err), "kernel oob at row 3");
    }
}
