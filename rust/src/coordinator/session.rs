//! Persistent multi-turn sessions: KV reuse across turns, fork/revert,
//! and LRU eviction of idle caches.
//!
//! A session is a conversation the serving loop remembers between
//! requests: its committed token history plus (usually) a resident
//! [`KvCache`] holding the attention state of a *strict prefix* of that
//! history. Turn N+1 then prefills only the token delta since the last
//! committed position instead of the whole conversation — the serving-side
//! half of the paper's cheap-deployment economy, where re-prefilling a
//! long chat every turn would dwarf the W4A8 savings.
//!
//! # State machine
//!
//! ```text
//!           open                 checkout               commit
//!   (none) ─────→ idle{tokens,cache?} ─────→ busy ────────────→ idle
//!                      │    ↑                  │ abort (fault/deadline/drain)
//!               evict  │    │ restore          └────────────────→ idle
//!                      ▼    │ (next checkout re-prefills)
//!                 idle{tokens, cache=None}
//! ```
//!
//! * **One in-flight turn per session** — `checkout` flips `busy` and
//!   *takes* the cache out of the session; a second checkout (or any
//!   `close`/`fork`/`revert`) answers [`ServeError::SessionBusy`] until
//!   the turn commits or aborts.
//! * **The cache is always a strict prefix of `tokens`.** The final
//!   generated token of a turn is sampled from the last decode step's
//!   logits but never decoded *into* the cache, so after a committed turn
//!   the cache lags the history by exactly one position — which is also
//!   why the next turn's delta prefill is never empty.
//! * **Eviction is invisible.** `enforce_cap` drops the least-recently
//!   used idle caches beyond the capacity bound (paged caches hand their
//!   pages back to the pool); the tokens survive, and the next checkout
//!   simply re-prefills the whole history (the coordinator counts it as a
//!   `session_restores`). Busy sessions are never evicted — their cache
//!   is checked out anyway.
//!
//! Determinism: because sampling draws from a positional prefix hash
//! (see [`super::sampling`]), a restored (or forked, or preempted) session
//! regenerates bit-identical tokens — eviction and restore are observable
//! only in the counters, never in the stream.

use std::collections::BTreeMap;

use super::ServeError;
use crate::plan::{KvCache, KvPagePool};

/// Default LRU capacity: how many idle sessions may keep their KV cache
/// resident at once (the [`super::CoordinatorConfig::max_sessions`] /
/// `QuantRecipe.max_sessions` default). Sessions beyond the cap stay
/// open — only their caches are dropped, to be re-prefilled on the next
/// turn.
pub const DEFAULT_MAX_SESSIONS: usize = 64;

/// One persistent conversation.
struct Session {
    /// Committed history: every turn's full prompt (history + delta) plus
    /// its generated tokens.
    tokens: Vec<u16>,
    /// Resident KV state over a strict prefix of `tokens`; `None` after
    /// eviction, a mid-turn fault, or for a fresh session.
    cache: Option<KvCache>,
    /// A turn is in flight (the cache is checked out with it).
    busy: bool,
    /// LRU stamp: larger = touched more recently.
    last_touch: u64,
}

/// What [`SessionManager::checkout`] hands the serving loop for one turn.
pub struct TurnCheckout {
    /// The committed history (the turn's delta is appended to this to form
    /// the full prompt).
    pub tokens: Vec<u16>,
    /// The session's resident cache, taken for the duration of the turn;
    /// `None` means the turn must re-prefill the whole history.
    pub cache: Option<KvCache>,
}

/// Owns every persistent session of one serving loop. Single-threaded by
/// construction — it lives inside the coordinator's run loop, so no locks;
/// clients reach it through the same bounded queue as every other request.
pub struct SessionManager {
    sessions: BTreeMap<String, Session>,
    clock: u64,
    /// Capacity bound on *resident idle caches* (not on open sessions).
    max_resident: usize,
    evicted: usize,
}

impl SessionManager {
    pub fn new(max_resident: usize) -> SessionManager {
        SessionManager {
            sessions: BTreeMap::new(),
            clock: 0,
            max_resident: max_resident.max(1),
            evicted: 0,
        }
    }

    /// Open sessions (busy and idle).
    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    /// Idle caches dropped by the LRU so far.
    pub fn evicted(&self) -> usize {
        self.evicted
    }

    /// Sessions currently holding a resident cache (for ring-mode byte
    /// accounting — paged bytes are already visible in the pool).
    pub fn resident_caches(&self) -> usize {
        self.sessions.values().filter(|s| s.cache.is_some()).count()
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Create an empty session.
    pub fn open(&mut self, id: &str) -> Result<(), ServeError> {
        if self.sessions.contains_key(id) {
            return Err(ServeError::DuplicateSession(id.to_string()));
        }
        let stamp = self.tick();
        self.sessions.insert(
            id.to_string(),
            Session { tokens: Vec::new(), cache: None, busy: false, last_touch: stamp },
        );
        Ok(())
    }

    /// Close an idle session, returning its pages to the pool.
    pub fn close(&mut self, id: &str, pool: Option<&mut KvPagePool>) -> Result<(), ServeError> {
        let s = self
            .sessions
            .get(id)
            .ok_or_else(|| ServeError::SessionNotFound(id.to_string()))?;
        if s.busy {
            return Err(ServeError::SessionBusy(id.to_string()));
        }
        let mut s = self.sessions.remove(id).expect("looked up above");
        if let (Some(cache), Some(pp)) = (s.cache.as_mut(), pool) {
            if cache.is_paged() {
                pp.release(cache);
            }
        }
        Ok(())
    }

    /// Duplicate `src`'s dialog position as a new idle session `dst`.
    /// Ring caches deep-copy (`Clone`); paged caches copy page-by-page
    /// through [`KvPagePool::fork_cache`] — and when the pool cannot fit
    /// the copy, the fork degrades to an *evicted* duplicate (tokens only,
    /// first touch re-prefills) instead of failing, the same transparent
    /// contract as LRU eviction.
    pub fn fork(
        &mut self,
        src: &str,
        dst: &str,
        pool: Option<&mut KvPagePool>,
    ) -> Result<(), ServeError> {
        if self.sessions.contains_key(dst) {
            return Err(ServeError::DuplicateSession(dst.to_string()));
        }
        let s = self
            .sessions
            .get(src)
            .ok_or_else(|| ServeError::SessionNotFound(src.to_string()))?;
        if s.busy {
            return Err(ServeError::SessionBusy(src.to_string()));
        }
        let tokens = s.tokens.clone();
        let cache = match (s.cache.as_ref(), pool) {
            (Some(c), Some(pp)) if c.is_paged() => pp.fork_cache(c),
            (Some(c), _) => Some(c.clone()),
            (None, _) => None,
        };
        let stamp = self.tick();
        self.sessions.insert(
            dst.to_string(),
            Session { tokens, cache, busy: false, last_touch: stamp },
        );
        Ok(())
    }

    /// Truncate an idle session to its first `to_len` committed tokens;
    /// the cache truncates with it (paged positions hand their pages
    /// back). Returns the surviving history.
    pub fn revert(
        &mut self,
        id: &str,
        to_len: usize,
        pool: Option<&mut KvPagePool>,
    ) -> Result<Vec<u16>, ServeError> {
        let stamp = self.tick();
        let s = self
            .sessions
            .get_mut(id)
            .ok_or_else(|| ServeError::SessionNotFound(id.to_string()))?;
        if s.busy {
            return Err(ServeError::SessionBusy(id.to_string()));
        }
        if to_len > s.tokens.len() {
            return Err(ServeError::Invalid(format!(
                "revert({to_len}) past the session's {} committed tokens",
                s.tokens.len()
            )));
        }
        s.tokens.truncate(to_len);
        if let Some(cache) = s.cache.as_mut() {
            let keep = cache.len().min(to_len);
            match pool {
                Some(pp) if cache.is_paged() => pp.truncate(cache, keep),
                _ => cache.truncate(keep),
            }
        }
        s.last_touch = stamp;
        Ok(s.tokens.clone())
    }

    /// The committed history (readable while a turn is in flight — the
    /// history is immutable until that turn commits).
    pub fn tokens(&self, id: &str) -> Result<Vec<u16>, ServeError> {
        self.sessions
            .get(id)
            .map(|s| s.tokens.clone())
            .ok_or_else(|| ServeError::SessionNotFound(id.to_string()))
    }

    /// Start a turn: mark the session busy and take its cache. Exactly one
    /// of [`commit`](Self::commit) / [`abort`](Self::abort) must follow.
    pub fn checkout(&mut self, id: &str) -> Result<TurnCheckout, ServeError> {
        let stamp = self.tick();
        let s = self
            .sessions
            .get_mut(id)
            .ok_or_else(|| ServeError::SessionNotFound(id.to_string()))?;
        if s.busy {
            return Err(ServeError::SessionBusy(id.to_string()));
        }
        s.busy = true;
        s.last_touch = stamp;
        Ok(TurnCheckout { tokens: s.tokens.clone(), cache: s.cache.take() })
    }

    /// Finish a turn: store the new history and the advanced cache, then
    /// enforce the resident-cache cap (evicting *other* idle sessions
    /// LRU-first — the just-committed one is the most recently touched).
    pub fn commit(
        &mut self,
        id: &str,
        tokens: Vec<u16>,
        cache: KvCache,
        pool: Option<&mut KvPagePool>,
    ) {
        let stamp = self.tick();
        let s = self.sessions.get_mut(id).expect("commit() on a checked-out session");
        debug_assert!(s.busy, "commit() without checkout");
        debug_assert!(
            cache.len() < tokens.len(),
            "session cache must be a strict prefix of the committed history"
        );
        s.busy = false;
        s.tokens = tokens;
        s.cache = Some(cache);
        s.last_touch = stamp;
        self.enforce_cap(pool);
    }

    /// Abandon a turn: the history stays at its pre-turn state. `cache`
    /// is whatever survived — `Some` (truncated back to the committed
    /// prefix) after a deadline expiry, `None` after a fault quarantined
    /// it or a preemption released it; `None` makes the next checkout a
    /// restore.
    pub fn abort(&mut self, id: &str, cache: Option<KvCache>) {
        let stamp = self.tick();
        let s = self.sessions.get_mut(id).expect("abort() on a checked-out session");
        debug_assert!(s.busy, "abort() without checkout");
        s.busy = false;
        s.cache = cache;
        s.last_touch = stamp;
    }

    /// Drop least-recently-used idle caches until at most `max_resident`
    /// remain. Tokens survive; paged caches hand their pages back to the
    /// pool. Busy sessions are untouched (their cache is checked out).
    pub fn enforce_cap(&mut self, mut pool: Option<&mut KvPagePool>) {
        loop {
            let resident = self.resident_caches();
            if resident <= self.max_resident {
                return;
            }
            let victim = self
                .sessions
                .iter()
                .filter(|(_, s)| !s.busy && s.cache.is_some())
                .min_by_key(|(_, s)| s.last_touch)
                .map(|(id, _)| id.clone());
            let Some(id) = victim else { return };
            let s = self.sessions.get_mut(&id).expect("victim looked up above");
            let mut cache = s.cache.take().expect("victim holds a cache");
            if let (true, Some(pp)) = (cache.is_paged(), pool.as_deref_mut()) {
                pp.release(&mut cache);
            }
            self.evicted += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Arch, ModelConfig};

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "session-test".into(),
            arch: Arch::Opt,
            vocab_size: 32,
            d_model: 8,
            n_heads: 2,
            n_layers: 2,
            d_ff: 16,
            max_seq: 8,
        }
    }

    fn ring(cfg: &ModelConfig) -> KvCache {
        KvCache::new(cfg)
    }

    #[test]
    fn open_close_and_typed_errors() {
        let mut m = SessionManager::new(4);
        assert!(m.is_empty());
        m.open("a").unwrap();
        assert_eq!(m.len(), 1);
        assert_eq!(m.open("a"), Err(ServeError::DuplicateSession("a".into())));
        assert_eq!(m.close("b", None), Err(ServeError::SessionNotFound("b".into())));
        assert_eq!(m.tokens("b"), Err(ServeError::SessionNotFound("b".into())));
        m.close("a", None).unwrap();
        assert!(m.is_empty());
    }

    #[test]
    fn checkout_enforces_one_turn_per_session() {
        let mut m = SessionManager::new(4);
        m.open("s").unwrap();
        let co = m.checkout("s").unwrap();
        assert!(co.tokens.is_empty() && co.cache.is_none());
        // busy: second checkout and every mutation are typed-rejected
        assert!(matches!(m.checkout("s"), Err(ServeError::SessionBusy(_))));
        assert_eq!(m.close("s", None), Err(ServeError::SessionBusy("s".into())));
        assert_eq!(m.fork("s", "t", None), Err(ServeError::SessionBusy("s".into())));
        assert_eq!(m.revert("s", 0, None), Err(ServeError::SessionBusy("s".into())));
        // the committed history stays readable mid-turn
        assert_eq!(m.tokens("s").unwrap(), Vec::<u16>::new());
        let cfg = tiny_cfg();
        m.commit("s", vec![1, 2, 3], ring(&cfg), None);
        assert_eq!(m.tokens("s").unwrap(), vec![1, 2, 3]);
        assert_eq!(m.resident_caches(), 1);
        // idle again: checkout succeeds and takes the cache
        let co = m.checkout("s").unwrap();
        assert_eq!(co.tokens, vec![1, 2, 3]);
        assert!(co.cache.is_some());
        assert_eq!(m.resident_caches(), 0);
        m.abort("s", co.cache);
        assert_eq!(m.resident_caches(), 1);
    }

    #[test]
    fn abort_without_cache_marks_restore_path() {
        let mut m = SessionManager::new(4);
        let cfg = tiny_cfg();
        m.open("s").unwrap();
        let _ = m.checkout("s").unwrap();
        m.commit("s", vec![4, 5], ring(&cfg), None);
        // a fault mid-turn: tokens survive, cache gone
        let co = m.checkout("s").unwrap();
        drop(co.cache);
        m.abort("s", None);
        assert_eq!(m.tokens("s").unwrap(), vec![4, 5]);
        let co = m.checkout("s").unwrap();
        assert!(co.cache.is_none(), "next checkout re-prefills from scratch");
        assert_eq!(co.tokens, vec![4, 5]);
    }

    #[test]
    fn fork_copies_tokens_and_ring_cache() {
        let mut m = SessionManager::new(8);
        let cfg = tiny_cfg();
        m.open("src").unwrap();
        let _ = m.checkout("src").unwrap();
        m.commit("src", vec![7, 8, 9], ring(&cfg), None);
        m.fork("src", "dst", None).unwrap();
        assert_eq!(m.fork("src", "dst", None), Err(ServeError::DuplicateSession("dst".into())));
        assert_eq!(m.fork("gone", "x", None), Err(ServeError::SessionNotFound("gone".into())));
        assert_eq!(m.tokens("dst").unwrap(), vec![7, 8, 9]);
        assert_eq!(m.resident_caches(), 2, "ring fork deep-copies the cache");
        // the two sessions are independent: reverting one leaves the other
        m.revert("dst", 1, None).unwrap();
        assert_eq!(m.tokens("dst").unwrap(), vec![7]);
        assert_eq!(m.tokens("src").unwrap(), vec![7, 8, 9]);
    }

    #[test]
    fn revert_truncates_tokens_and_rejects_overshoot() {
        let mut m = SessionManager::new(4);
        let cfg = tiny_cfg();
        m.open("s").unwrap();
        let _ = m.checkout("s").unwrap();
        m.commit("s", vec![1, 2, 3, 4], ring(&cfg), None);
        assert!(matches!(m.revert("s", 9, None), Err(ServeError::Invalid(_))));
        assert_eq!(m.revert("s", 2, None).unwrap(), vec![1, 2]);
        assert_eq!(m.tokens("s").unwrap(), vec![1, 2]);
        // revert to zero keeps the session open but empty
        assert_eq!(m.revert("s", 0, None).unwrap(), Vec::<u16>::new());
    }

    #[test]
    fn lru_evicts_the_coldest_idle_cache_only() {
        let mut m = SessionManager::new(2);
        let cfg = tiny_cfg();
        for id in ["a", "b", "c"] {
            m.open(id).unwrap();
            let _ = m.checkout(id).unwrap();
            m.commit(id, vec![1], ring(&cfg), None);
        }
        // cap 2: committing "c" evicted the LRU ("a"); tokens survive
        assert_eq!(m.evicted(), 1);
        assert_eq!(m.resident_caches(), 2);
        assert_eq!(m.tokens("a").unwrap(), vec![1]);
        let co = m.checkout("a").unwrap();
        assert!(co.cache.is_none(), "evicted session restores on touch");
        // busy sessions are never evicted: with "a" busy, committing two
        // more sessions can only evict "b" then "c"
        for id in ["d", "e"] {
            m.open(id).unwrap();
            let _ = m.checkout(id).unwrap();
            m.commit(id, vec![2], ring(&cfg), None);
        }
        assert_eq!(m.evicted(), 3);
        m.commit("a", vec![1, 2], ring(&cfg), None);
        assert_eq!(m.len(), 5, "eviction never closes a session");
    }

    #[test]
    fn paged_eviction_returns_pages_to_the_pool() {
        let cfg = tiny_cfg();
        let mut pool = KvPagePool::new(&cfg, 4, 0, None);
        let total = pool.total_pages();
        let mut m = SessionManager::new(1);
        for id in ["a", "b"] {
            m.open(id).unwrap();
            let co = m.checkout(id).unwrap();
            assert!(co.cache.is_none());
            let mut cache = pool.new_cache();
            assert!(pool.reserve(&mut cache, 3));
            m.commit(id, vec![1], cache, Some(&mut pool));
        }
        // "a" was evicted when "b" committed; its page went back
        assert_eq!(m.evicted(), 1);
        assert_eq!(pool.resident_pages(), 1, "only \"b\"'s reservation stays");
        assert_eq!(
            pool.free_pages() + pool.resident_pages() + pool.leaked_pages(),
            total,
            "books balance through eviction"
        );
        // close returns the last reservation too
        m.close("b", Some(&mut pool)).unwrap();
        assert_eq!(pool.resident_pages(), 0);
        assert_eq!(pool.free_pages(), total);
    }
}
