//! Dynamic batching policy — collect requests into GEMM-efficient batches
//! without letting the head request wait beyond a deadline.
//!
//! # The latency/throughput dial
//!
//! Batching amortizes per-request fixed costs (weight-matrix streaming on
//! the compiled backend, the lowered batch dimension on PJRT) at the price
//! of making the *first* request of a batch wait for company. The two
//! [`BatchPolicy`] knobs are exactly that trade:
//!
//! * `max_batch` — the hard cap. On PJRT it is the executable's lowered
//!   batch size `B` (padded slots burn compute, so filling real slots is
//!   pure win). On the compiled backend it caps how many sequences decode
//!   interleaved. With contiguous-ring KV caches each slot pins a full
//!   `max_seq`-sized ring, so the cap doubles as the memory bound; under
//!   the paged pool (`kv_page_positions > 0`) memory is bounded by the
//!   pool's byte budget instead and `max_batch` is purely a concurrency
//!   cap — admission and preemption against the byte budget live in the
//!   coordinator's start phase, not here.
//! * `max_wait` — how long the head request may wait for the batch to
//!   fill. Longer windows raise mean batch size (throughput) and p50
//!   latency together; §Perf in EXPERIMENTS.md sweeps it.
//!
//! # Two consumption patterns
//!
//! [`next_batch`] is the *group* pull: block for the first request, then
//! wait out the deadline — the PJRT scoring loop's shape, and the idle
//! path of the compiled loop. [`try_fill`] is the *join* pull: grab
//! whatever is already queued, never block — the continuous-batching
//! loop calls it between decode steps so new sequences join mid-flight
//! without stalling the sequences already decoding (and departures free
//! slots for the next `try_fill`). A continuous loop therefore wants
//! `max_wait = 0`: the join path replaces the wait window.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::time::{Duration, Instant};

/// How long a blocking pull may park before re-checking the shutdown
/// flag ([`next_batch_watching`]) — the upper bound on how stale a drain
/// signal can go unnoticed while the loop is idle. Public (it used to be
/// a buried 5 ms magic number) so callers can reason about worst-case
/// wake latency; urgent work skips the slice entirely via
/// [`next_batch_watching_urgent`].
pub const POLL_SLICE: Duration = Duration::from_millis(5);

/// Batching policy parameters.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Hard cap (the artifact's lowered batch size).
    pub max_batch: usize,
    /// How long the first request of a batch may wait for company.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Pull one batch from `rx` under the policy. Blocks for the first item
/// (None = channel closed and drained). Subsequent items are awaited only
/// until the deadline.
pub fn next_batch<T>(rx: &Receiver<T>, policy: BatchPolicy) -> Option<Vec<T>> {
    let first = rx.recv().ok()?;
    let mut batch = vec![first];
    let deadline = Instant::now() + policy.max_wait;
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

/// What a blocking [`next_batch_watching`] pull woke up for.
#[derive(Debug, PartialEq, Eq)]
pub enum Wakeup<T> {
    /// At least one request (up to the policy's cap / wait window).
    Batch(Vec<T>),
    /// The shutdown flag was raised while waiting — no request consumed.
    Shutdown,
    /// Every sender is gone and the queue is drained.
    Closed,
}

/// [`next_batch`] that also watches a shutdown flag: waits in
/// [`POLL_SLICE`]-sized slices so a drain signal raised while the
/// loop is parked idle is observed within one slice instead of whenever
/// the next request happens to arrive. The shutdown check happens
/// *before* consuming a request, so a [`Wakeup::Shutdown`] return
/// leaves the queue untouched for the caller's drain pass.
pub fn next_batch_watching<T>(
    rx: &Receiver<T>,
    policy: BatchPolicy,
    stop: &AtomicBool,
) -> Wakeup<T> {
    next_batch_watching_urgent(rx, policy, stop, |_| false)
}

/// [`next_batch_watching`] with an urgency predicate: an item for which
/// `urgent` returns true flushes the batch immediately instead of
/// sleeping out the rest of the company window (or a full poll slice)
/// with latency-bound work pending. The serving loop marks streaming
/// session turns and session control ops urgent — a chat client waiting
/// for its first token should never pay `max_wait` for batch company.
pub fn next_batch_watching_urgent<T>(
    rx: &Receiver<T>,
    policy: BatchPolicy,
    stop: &AtomicBool,
    urgent: impl Fn(&T) -> bool,
) -> Wakeup<T> {
    let first = loop {
        if stop.load(Ordering::SeqCst) {
            return Wakeup::Shutdown;
        }
        match rx.recv_timeout(POLL_SLICE) {
            Ok(item) => break item,
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => return Wakeup::Closed,
        }
    };
    let mut batch = vec![first];
    if urgent(&batch[0]) {
        return Wakeup::Batch(batch);
    }
    let deadline = Instant::now() + policy.max_wait;
    while batch.len() < policy.max_batch && !stop.load(Ordering::SeqCst) {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout((deadline - now).min(POLL_SLICE)) {
            Ok(item) => {
                let hot = urgent(&item);
                batch.push(item);
                if hot {
                    break; // tokens pending: wake the loop now
                }
            }
            Err(RecvTimeoutError::Timeout) => continue, // re-check stop/deadline
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Wakeup::Batch(batch)
}

/// What a [`try_fill`] pull observed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Fill {
    /// Items appended to `out`.
    pub taken: usize,
    /// True when the channel is disconnected (every sender dropped) *and*
    /// drained — the loop-visible difference between "queue momentarily
    /// empty" and "all clients gone", which is what lets a drain know no
    /// further work can ever arrive.
    pub disconnected: bool,
}

/// Non-blocking pull of at most `slots` already-queued items into `out`
/// (appended; `out` is not cleared). This is the continuous-batching
/// *join* path: between decode steps the serving loop offers freed slots
/// to waiting requests without ever stalling the sequences currently in
/// flight. The returned [`Fill`] reports both how many items were taken
/// and whether the queue can ever produce more.
pub fn try_fill<T>(rx: &Receiver<T>, out: &mut Vec<T>, slots: usize) -> Fill {
    let mut taken = 0usize;
    let mut disconnected = false;
    while taken < slots {
        match rx.try_recv() {
            Ok(item) => {
                out.push(item);
                taken += 1;
            }
            Err(TryRecvError::Empty) => break,
            Err(TryRecvError::Disconnected) => {
                disconnected = true;
                break;
            }
        }
    }
    Fill { taken, disconnected }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn full_batch_returns_immediately() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(10) };
        let t0 = Instant::now();
        let b = next_batch(&rx, policy).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        assert!(t0.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let (tx, rx) = channel();
        tx.send(42).unwrap();
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(20) };
        let t0 = Instant::now();
        let b = next_batch(&rx, policy).unwrap();
        assert_eq!(b, vec![42]);
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(15), "{waited:?}");
        drop(tx);
    }

    #[test]
    fn closed_channel_yields_none() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        assert!(next_batch(&rx, BatchPolicy::default()).is_none());
    }

    #[test]
    fn try_fill_never_blocks_and_respects_slots() {
        let (tx, rx) = channel();
        let mut out = vec![0];
        // empty queue: returns immediately with nothing
        let t0 = Instant::now();
        assert_eq!(try_fill(&rx, &mut out, 4).taken, 0);
        assert!(t0.elapsed() < Duration::from_millis(50));
        assert_eq!(out, vec![0]);
        // queued items: appended up to the slot cap
        for i in 1..=5 {
            tx.send(i).unwrap();
        }
        assert_eq!(try_fill(&rx, &mut out, 3).taken, 3);
        assert_eq!(out, vec![0, 1, 2, 3]);
        assert_eq!(try_fill(&rx, &mut out, 10).taken, 2);
        assert_eq!(out, vec![0, 1, 2, 3, 4, 5]);
        // closed channel: still takes nothing
        drop(tx);
        assert_eq!(try_fill(&rx, &mut out, 4).taken, 0);
    }

    #[test]
    fn try_fill_distinguishes_empty_from_disconnected() {
        // regression: Disconnected used to be folded into Empty, so a
        // draining loop could not tell "no work right now" from "no work
        // ever again"
        let (tx, rx) = channel();
        let mut out: Vec<u32> = Vec::new();
        assert_eq!(try_fill(&rx, &mut out, 4), Fill { taken: 0, disconnected: false });
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        drop(tx);
        // queued items are still drained after the last sender is gone,
        // and the disconnect is reported alongside them
        assert_eq!(try_fill(&rx, &mut out, 4), Fill { taken: 2, disconnected: true });
        assert_eq!(out, vec![1, 2]);
        assert_eq!(try_fill(&rx, &mut out, 4), Fill { taken: 0, disconnected: true });
    }

    #[test]
    fn watching_pull_returns_batches_and_sees_shutdown() {
        use std::sync::atomic::AtomicBool;
        let stop = AtomicBool::new(false);
        let (tx, rx) = channel();
        tx.send(7).unwrap();
        tx.send(8).unwrap();
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) };
        assert_eq!(next_batch_watching(&rx, policy, &stop), Wakeup::Batch(vec![7, 8]));
        // a raised flag wins over queued work and consumes nothing
        tx.send(9).unwrap();
        stop.store(true, Ordering::SeqCst);
        assert_eq!(next_batch_watching(&rx, policy, &stop), Wakeup::<i32>::Shutdown);
        assert_eq!(rx.try_recv().unwrap(), 9, "shutdown wakeup left the queue untouched");
        // closed + drained reports Closed
        stop.store(false, Ordering::SeqCst);
        drop(tx);
        assert_eq!(next_batch_watching(&rx, policy, &stop), Wakeup::<i32>::Closed);
    }

    #[test]
    fn watching_pull_wakes_from_idle_block_on_shutdown() {
        use std::sync::atomic::AtomicBool;
        use std::sync::Arc;
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx) = channel::<u32>();
        let flag = stop.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            flag.store(true, Ordering::SeqCst);
        });
        let t0 = Instant::now();
        let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) };
        // no request ever arrives: only the flag can end this wait
        assert_eq!(next_batch_watching(&rx, policy, &stop), Wakeup::<u32>::Shutdown);
        assert!(t0.elapsed() < Duration::from_secs(5), "woke via the flag, not a hang");
        h.join().unwrap();
        drop(tx);
    }

    #[test]
    fn urgent_head_skips_the_company_wait() {
        use std::sync::atomic::AtomicBool;
        let stop = AtomicBool::new(false);
        let (tx, rx) = channel();
        tx.send(99).unwrap();
        // a wait window far longer than the test budget: only the urgency
        // predicate can return this fast
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_secs(30) };
        let t0 = Instant::now();
        let got = next_batch_watching_urgent(&rx, policy, &stop, |&v| v >= 50);
        assert_eq!(got, Wakeup::Batch(vec![99]));
        assert!(t0.elapsed() < Duration::from_secs(1), "urgent head returned immediately");
        drop(tx);
    }

    #[test]
    fn urgent_joiner_flushes_a_forming_batch_early() {
        use std::sync::atomic::AtomicBool;
        let stop = AtomicBool::new(false);
        let (tx, rx) = channel();
        tx.send(1).unwrap(); // ordinary head: starts the company wait
        let sender = tx.clone();
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(10));
            sender.send(2).unwrap(); // ordinary company
            sender.send(77).unwrap(); // urgent: must flush the batch
        });
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_secs(30) };
        let t0 = Instant::now();
        let got = next_batch_watching_urgent(&rx, policy, &stop, |&v| v >= 50);
        h.join().unwrap();
        assert_eq!(got, Wakeup::Batch(vec![1, 2, 77]));
        assert!(t0.elapsed() < Duration::from_secs(5), "urgent joiner ended the wait");
        // the never-urgent delegate preserves the old deadline behavior
        tx.send(3).unwrap();
        let quick = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) };
        assert_eq!(next_batch_watching(&rx, quick, &stop), Wakeup::Batch(vec![3]));
        drop(tx);
    }

    #[test]
    fn drains_remaining_after_close() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        drop(tx);
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) };
        assert_eq!(next_batch(&rx, policy).unwrap(), vec![1, 2]);
        assert!(next_batch(&rx, policy).is_none());
    }
}
