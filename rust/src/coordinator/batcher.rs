//! Dynamic batching policy — collect requests into GEMM-efficient batches
//! without letting the head request wait beyond a deadline.
//!
//! # The latency/throughput dial
//!
//! Batching amortizes per-request fixed costs (weight-matrix streaming on
//! the compiled backend, the lowered batch dimension on PJRT) at the price
//! of making the *first* request of a batch wait for company. The two
//! [`BatchPolicy`] knobs are exactly that trade:
//!
//! * `max_batch` — the hard cap. On PJRT it is the executable's lowered
//!   batch size `B` (padded slots burn compute, so filling real slots is
//!   pure win). On the compiled backend it caps how many sequences decode
//!   interleaved (each one holds a `max_seq`-sized KV cache, so this is
//!   also the memory bound).
//! * `max_wait` — how long the head request may wait for the batch to
//!   fill. Longer windows raise mean batch size (throughput) and p50
//!   latency together; §Perf in EXPERIMENTS.md sweeps it.
//!
//! # Two consumption patterns
//!
//! [`next_batch`] is the *group* pull: block for the first request, then
//! wait out the deadline — the PJRT scoring loop's shape, and the idle
//! path of the compiled loop. [`try_fill`] is the *join* pull: grab
//! whatever is already queued, never block — the continuous-batching
//! loop calls it between decode steps so new sequences join mid-flight
//! without stalling the sequences already decoding (and departures free
//! slots for the next `try_fill`). A continuous loop therefore wants
//! `max_wait = 0`: the join path replaces the wait window.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batching policy parameters.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Hard cap (the artifact's lowered batch size).
    pub max_batch: usize,
    /// How long the first request of a batch may wait for company.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Pull one batch from `rx` under the policy. Blocks for the first item
/// (None = channel closed and drained). Subsequent items are awaited only
/// until the deadline.
pub fn next_batch<T>(rx: &Receiver<T>, policy: BatchPolicy) -> Option<Vec<T>> {
    let first = rx.recv().ok()?;
    let mut batch = vec![first];
    let deadline = Instant::now() + policy.max_wait;
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

/// Non-blocking pull of at most `slots` already-queued items into `out`
/// (appended; `out` is not cleared). Returns how many were taken. This is
/// the continuous-batching *join* path: between decode steps the serving
/// loop offers freed slots to waiting requests without ever stalling the
/// sequences currently in flight.
pub fn try_fill<T>(rx: &Receiver<T>, out: &mut Vec<T>, slots: usize) -> usize {
    let mut taken = 0usize;
    while taken < slots {
        match rx.try_recv() {
            Ok(item) => {
                out.push(item);
                taken += 1;
            }
            Err(_) => break,
        }
    }
    taken
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn full_batch_returns_immediately() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(10) };
        let t0 = Instant::now();
        let b = next_batch(&rx, policy).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        assert!(t0.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let (tx, rx) = channel();
        tx.send(42).unwrap();
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(20) };
        let t0 = Instant::now();
        let b = next_batch(&rx, policy).unwrap();
        assert_eq!(b, vec![42]);
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(15), "{waited:?}");
        drop(tx);
    }

    #[test]
    fn closed_channel_yields_none() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        assert!(next_batch(&rx, BatchPolicy::default()).is_none());
    }

    #[test]
    fn try_fill_never_blocks_and_respects_slots() {
        let (tx, rx) = channel();
        let mut out = vec![0];
        // empty queue: returns immediately with nothing
        let t0 = Instant::now();
        assert_eq!(try_fill(&rx, &mut out, 4), 0);
        assert!(t0.elapsed() < Duration::from_millis(50));
        assert_eq!(out, vec![0]);
        // queued items: appended up to the slot cap
        for i in 1..=5 {
            tx.send(i).unwrap();
        }
        assert_eq!(try_fill(&rx, &mut out, 3), 3);
        assert_eq!(out, vec![0, 1, 2, 3]);
        assert_eq!(try_fill(&rx, &mut out, 10), 2);
        assert_eq!(out, vec![0, 1, 2, 3, 4, 5]);
        // closed channel: still just returns 0
        drop(tx);
        assert_eq!(try_fill(&rx, &mut out, 4), 0);
    }

    #[test]
    fn drains_remaining_after_close() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        drop(tx);
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) };
        assert_eq!(next_batch(&rx, policy).unwrap(), vec![1, 2]);
        assert!(next_batch(&rx, policy).is_none());
    }
}
