//! Dynamic batching policy — collect requests into GEMM-efficient batches
//! without letting the head request wait beyond a deadline.
//!
//! The PJRT scoring executable is lowered at a fixed batch `B`; padded
//! slots waste compute, so the batcher waits up to `max_wait` after the
//! first request for the batch to fill (the classic dynamic-batching
//! latency/throughput dial; §Perf sweeps it).

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Batching policy parameters.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Hard cap (the artifact's lowered batch size).
    pub max_batch: usize,
    /// How long the first request of a batch may wait for company.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// Pull one batch from `rx` under the policy. Blocks for the first item
/// (None = channel closed and drained). Subsequent items are awaited only
/// until the deadline.
pub fn next_batch<T>(rx: &Receiver<T>, policy: BatchPolicy) -> Option<Vec<T>> {
    let first = rx.recv().ok()?;
    let mut batch = vec![first];
    let deadline = Instant::now() + policy.max_wait;
    while batch.len() < policy.max_batch {
        let now = Instant::now();
        if now >= deadline {
            break;
        }
        match rx.recv_timeout(deadline - now) {
            Ok(item) => batch.push(item),
            Err(RecvTimeoutError::Timeout) => break,
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    Some(batch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn full_batch_returns_immediately() {
        let (tx, rx) = channel();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(10) };
        let t0 = Instant::now();
        let b = next_batch(&rx, policy).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        assert!(t0.elapsed() < Duration::from_secs(1));
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let (tx, rx) = channel();
        tx.send(42).unwrap();
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(20) };
        let t0 = Instant::now();
        let b = next_batch(&rx, policy).unwrap();
        assert_eq!(b, vec![42]);
        let waited = t0.elapsed();
        assert!(waited >= Duration::from_millis(15), "{waited:?}");
        drop(tx);
    }

    #[test]
    fn closed_channel_yields_none() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        assert!(next_batch(&rx, BatchPolicy::default()).is_none());
    }

    #[test]
    fn drains_remaining_after_close() {
        let (tx, rx) = channel();
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        drop(tx);
        let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) };
        assert_eq!(next_batch(&rx, policy).unwrap(), vec![1, 2]);
        assert!(next_batch(&rx, policy).is_none());
    }
}
