//! The serving coordinator — Layer 3's runtime contribution.
//!
//! A scoring service over a quantized model: clients submit fixed-length
//! token windows, the coordinator batches them dynamically, executes on a
//! [`ScoreBackend`], and returns per-window NLL. std::thread + mpsc (tokio
//! is not in the offline vendor set — the event loop is a plain loop and
//! channels).
//!
//! ```text
//!  client threads ──score(window)──▶ queue ──next_batch──▶ run() loop ──▶ backend
//!        ▲                                                      │
//!        └──────────────── per-request oneshot ◀────────────────┘
//! ```
//!
//! Two backends:
//!
//! * [`ScoreBackend::Pjrt`] — the AOT HLO executable (batch lowered at
//!   `B = SCORE_BATCH`). All PJRT work happens on the thread that calls
//!   [`Coordinator::run`] (xla_extension 0.5.1 deadlocks when a second CPU
//!   client is created on another thread while one is in use, so the
//!   process keeps a single per-thread client). Needs `make artifacts` and
//!   the `pjrt` cargo feature.
//! * [`ScoreBackend::Compiled`] — the prepacked in-process engine
//!   ([`crate::plan::CompiledModel`]): the checkpoint is compiled once at
//!   loop start and every request decodes allocation-free through the
//!   scratch arena. Always available; this is what `zqfp serve`, the
//!   serving bench and the e2e example fall back to when artifacts (or the
//!   feature) are missing.
//!
//! Client threads only touch channels. `run` returns when every
//! [`ScoreClient`] has been dropped and the queue is drained.

pub mod batcher;
pub mod metrics;

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender, SyncSender};
use std::time::Instant;

pub use batcher::{next_batch, BatchPolicy};
pub use metrics::{LatencyStats, ServeReport};

use crate::cli::Args;
use crate::data::{Corpus, CorpusKind};
use crate::ensure;
use crate::error::Result;
use crate::model::Checkpoint;
use crate::pipeline::quantize_checkpoint;
use crate::plan::CompiledModel;
use crate::quant::Scheme;
use crate::runtime::HloScorer;

/// Which execution engine serves scoring requests.
#[derive(Debug, Clone)]
pub enum ScoreBackend {
    /// AOT PJRT HLO artifacts under this directory.
    Pjrt { artifacts: PathBuf },
    /// The prepacked in-process engine (always available).
    Compiled,
}

/// One in-flight scoring request.
struct Request {
    window: Vec<u16>,
    submitted: Instant,
    respond: SyncSender<Result<f32>>,
}

/// Handle client threads use to talk to a running coordinator. The serving
/// loop exits once all clients are dropped.
#[derive(Clone)]
pub struct ScoreClient {
    tx: Sender<Request>,
    seq: usize,
}

impl ScoreClient {
    /// Score one window (blocking). Returns the summed NLL of the window.
    pub fn score(&self, window: Vec<u16>) -> Result<f32> {
        ensure!(window.len() == self.seq, "window must be {} tokens", self.seq);
        let (rtx, rrx) = std::sync::mpsc::sync_channel(1);
        self.tx
            .send(Request { window, submitted: Instant::now(), respond: rtx })
            .map_err(|_| crate::anyhow!("coordinator stopped"))?;
        rrx.recv()
            .map_err(|_| crate::anyhow!("coordinator dropped request"))?
    }
}

/// Everything the serving loop needs.
pub struct CoordinatorConfig {
    pub backend: ScoreBackend,
    pub ck: Checkpoint,
    pub opts: crate::engine::EngineOpts,
    pub policy: BatchPolicy,
}

/// The request queue + serving loop.
pub struct Coordinator {
    tx: Option<Sender<Request>>,
    rx: Receiver<Request>,
    cfg: CoordinatorConfig,
}

impl Coordinator {
    pub fn new(cfg: CoordinatorConfig) -> Coordinator {
        let (tx, rx) = channel();
        Coordinator { tx: Some(tx), rx, cfg }
    }

    /// A client handle. Create one per client thread **before** calling
    /// [`run`](Self::run); `run` drops the coordinator's own sender, so the
    /// loop ends when the last client handle is gone.
    pub fn client(&self) -> ScoreClient {
        ScoreClient {
            tx: self.tx.as_ref().expect("before run").clone(),
            seq: self.cfg.ck.config.max_seq,
        }
    }

    /// Run the serving loop on the current thread until every client is
    /// dropped and the queue is drained; returns the serving report.
    pub fn run(mut self) -> Result<ServeReport> {
        drop(self.tx.take()); // only client handles keep the queue open
        match self.cfg.backend.clone() {
            ScoreBackend::Pjrt { artifacts } => self.run_pjrt(&artifacts),
            ScoreBackend::Compiled => self.run_compiled(),
        }
    }

    fn run_pjrt(self, artifacts: &std::path::Path) -> Result<ServeReport> {
        let scorer = HloScorer::for_model(artifacts, &self.cfg.ck.config, &self.cfg.opts)?;
        let weights = scorer.upload_weights(&self.cfg.ck)?;
        let b = scorer.batch;
        let policy = BatchPolicy { max_batch: b, ..self.cfg.policy };
        let seq = scorer.seq;
        let mut flat: Vec<u16> = Vec::with_capacity(b * seq);
        let mut latency = LatencyStats::default();
        let mut batches = 0usize;
        let mut requests = 0usize;
        let t0 = Instant::now();
        while let Some(batch) = next_batch(&self.rx, policy) {
            flat.clear();
            for r in &batch {
                flat.extend_from_slice(&r.window);
            }
            for _ in batch.len()..b {
                flat.extend_from_slice(&batch[0].window); // pad, discarded
            }
            let result = scorer.score_batch(&flat, &weights);
            let now = Instant::now();
            batches += 1;
            requests += batch.len();
            for r in &batch {
                latency.record(now - r.submitted);
            }
            match result {
                Ok(nll) => {
                    for (r, &v) in batch.iter().zip(nll.iter()) {
                        let _ = r.respond.send(Ok(v));
                    }
                }
                Err(e) => {
                    for r in batch {
                        let _ = r.respond.send(Err(crate::anyhow!("{e:#}")));
                    }
                }
            }
        }
        Ok(ServeReport {
            requests,
            batches,
            wall: t0.elapsed(),
            latency,
            mean_batch_size: requests as f64 / batches.max(1) as f64,
        })
    }

    fn run_compiled(self) -> Result<ServeReport> {
        // Compile once; every request then decodes through the prepacked
        // plan with zero steady-state allocations.
        let model = CompiledModel::compile(&self.cfg.ck, self.cfg.opts);
        let mut scratch = model.scratch();
        // No batched GEMM to amortize on this backend — requests are decoded
        // one at a time — so waiting for a batch to fill would buy zero
        // throughput and only inflate head-request latency. Drain eagerly.
        let policy = BatchPolicy { max_wait: std::time::Duration::ZERO, ..self.cfg.policy };
        let vocab = self.cfg.ck.config.vocab_size;
        let mut latency = LatencyStats::default();
        let mut batches = 0usize;
        let mut requests = 0usize;
        let t0 = Instant::now();
        while let Some(batch) = next_batch(&self.rx, policy) {
            batches += 1;
            requests += batch.len();
            for r in batch {
                // Validate before decoding: an out-of-range token id would
                // panic inside the embedding lookup and take down the whole
                // serving loop, where the PJRT backend fails one request.
                let result = if r.window.len() < 2 {
                    Err(crate::anyhow!("window needs at least 2 tokens for scoring"))
                } else if let Some(&bad) = r.window.iter().find(|&&t| t as usize >= vocab) {
                    Err(crate::anyhow!("token id {bad} out of range (vocab size {vocab})"))
                } else {
                    Ok(model.score_nll(&r.window, &mut scratch))
                };
                latency.record(Instant::now() - r.submitted);
                let _ = r.respond.send(result);
            }
        }
        Ok(ServeReport {
            requests,
            batches,
            wall: t0.elapsed(),
            latency,
            mean_batch_size: requests as f64 / batches.max(1) as f64,
        })
    }
}

/// `zqfp serve` — load a checkpoint, quantize it under `--scheme`, start
/// the coordinator (PJRT when the artifact exists, otherwise the compiled
/// in-process engine), fire `--requests` scoring requests from `--clients`
/// threads, and print the latency/throughput report (the e2e serving
/// validation of DESIGN.md §5).
pub fn serve_command(args: &Args) -> std::result::Result<(), String> {
    let ckpt = args.get("ckpt").ok_or("--ckpt required")?;
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let data = PathBuf::from(args.get_or("data", "data"));
    let n_requests = args.get_usize("requests", 256)?;
    let n_clients = args.get_usize("clients", 4)?;
    let max_wait_ms = args.get_usize("max-wait-ms", 2)?;
    let alpha = args.get_f32("alpha", 1.0)?;
    let scheme_s = args.get_or("scheme", "w4a8-fp-fp");
    let scheme = Scheme::parse(&scheme_s).ok_or(format!("bad --scheme {scheme_s}"))?;
    let cfg = crate::cli::commands::ptq_config_from_args(args, scheme)?;
    args.finish()?;

    let ck = crate::cli::commands::load_ckpt_with_alpha(std::path::Path::new(&ckpt), alpha)?;
    let seq = ck.config.max_seq;
    let calib = crate::cli::commands::load_calib(&data, seq)?;
    println!("quantizing under {} ...", scheme.name());
    let (qck, report) = quantize_checkpoint(&ck, &calib, &cfg);
    println!(
        "  {} tensors, {:.2}x compression",
        report.layers.len(),
        report.compression()
    );

    let opts = cfg.engine_opts();
    let backend = pick_backend(&artifacts, &qck, &opts);
    match &backend {
        ScoreBackend::Pjrt { .. } => println!("backend: pjrt ({})", artifacts.display()),
        ScoreBackend::Compiled => println!("backend: compiled in-process engine"),
    }

    // workload: eval windows from the C4 surrogate
    let corpus = Corpus::new(CorpusKind::C4);
    let stream = corpus.generate(n_requests * seq, 7);
    let windows: Vec<Vec<u16>> = stream.chunks_exact(seq).map(|c| c.to_vec()).collect();
    let n_windows = windows.len();

    let coord = Coordinator::new(CoordinatorConfig {
        backend,
        ck: qck,
        opts,
        policy: BatchPolicy {
            max_batch: crate::runtime::SCORE_BATCH,
            max_wait: std::time::Duration::from_millis(max_wait_ms as u64),
        },
    });

    println!(
        "serving {n_windows} requests from {n_clients} clients (batch window {max_wait_ms} ms) ..."
    );
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let client = coord.client();
        let my: Vec<Vec<u16>> = windows.iter().skip(c).step_by(n_clients).cloned().collect();
        handles.push(std::thread::spawn(move || -> Result<f64> {
            let mut sum = 0.0f64;
            for w in my {
                sum += client.score(w)? as f64;
            }
            Ok(sum)
        }));
    }
    // backend loop on this thread (PJRT single-client-process rule)
    let report = coord.run().map_err(|e| e.to_string())?;
    let mut total_nll = 0.0f64;
    for h in handles {
        total_nll += h.join().map_err(|_| "client panicked")?.map_err(|e| e.to_string())?;
    }
    report.print();
    let tokens = (seq - 1) * n_windows;
    println!(
        "workload ppl {:.4} over {} scored tokens",
        (total_nll / tokens as f64).exp(),
        tokens
    );
    Ok(())
}

/// PJRT when this build can execute artifacts and the one we need exists;
/// otherwise the compiled in-process engine.
pub fn pick_backend(
    artifacts: &std::path::Path,
    ck: &Checkpoint,
    opts: &crate::engine::EngineOpts,
) -> ScoreBackend {
    let available = crate::runtime::PJRT_AVAILABLE
        && crate::runtime::act_tag(opts)
            .map(|act| {
                artifacts
                    .join(crate::runtime::score_artifact_name(&ck.config, act))
                    .exists()
            })
            .unwrap_or(false);
    if available {
        ScoreBackend::Pjrt { artifacts: artifacts.to_path_buf() }
    } else {
        ScoreBackend::Compiled
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineOpts;
    use crate::model::{Arch, Checkpoint, ModelConfig};
    use crate::rng::Rng;
    use std::time::Duration;

    fn tiny_ck() -> Checkpoint {
        let cfg = ModelConfig {
            name: "coord-test".into(),
            arch: Arch::Opt,
            vocab_size: 48,
            d_model: 24,
            n_heads: 3,
            n_layers: 2,
            d_ff: 48,
            max_seq: 8,
        };
        let mut rng = Rng::seeded(611);
        Checkpoint::random(&cfg, &mut rng)
    }

    #[test]
    fn compiled_backend_serves_requests() {
        let ck = tiny_ck();
        let coord = Coordinator::new(CoordinatorConfig {
            backend: ScoreBackend::Compiled,
            ck: ck.clone(),
            opts: EngineOpts::default(),
            policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        });
        let mut handles = Vec::new();
        for c in 0..3usize {
            let client = coord.client();
            handles.push(std::thread::spawn(move || -> Result<Vec<f32>> {
                let mut out = Vec::new();
                for i in 0..5u16 {
                    let window: Vec<u16> = (0..8).map(|t| (c as u16 + i + t) % 48).collect();
                    out.push(client.score(window)?);
                }
                Ok(out)
            }));
        }
        let report = coord.run().unwrap();
        for h in handles {
            let nlls = h.join().unwrap().unwrap();
            assert!(nlls.iter().all(|v| v.is_finite() && *v > 0.0));
        }
        assert_eq!(report.requests, 15);
        assert!(report.latency.count() == 15);

        // NLL parity with a direct compiled-model score.
        let model = CompiledModel::compile(&ck, EngineOpts::default());
        let mut s = model.scratch();
        let window: Vec<u16> = (0..8).map(|t| t % 48).collect();
        let direct = model.score_nll(&window, &mut s);
        let coord2 = Coordinator::new(CoordinatorConfig {
            backend: ScoreBackend::Compiled,
            ck,
            opts: EngineOpts::default(),
            policy: BatchPolicy::default(),
        });
        let client = coord2.client();
        let h = std::thread::spawn(move || client.score(window).unwrap());
        coord2.run().unwrap();
        assert_eq!(h.join().unwrap(), direct);
    }

    #[test]
    fn rejects_wrong_window_length() {
        let ck = tiny_ck();
        let coord = Coordinator::new(CoordinatorConfig {
            backend: ScoreBackend::Compiled,
            ck,
            opts: EngineOpts::default(),
            policy: BatchPolicy::default(),
        });
        let client = coord.client();
        assert!(client.score(vec![1, 2, 3]).is_err());
        drop(client);
        coord.run().unwrap();
    }
}
