//! The serving coordinator — Layer 3's runtime contribution.
//!
//! A scoring service over a quantized model: clients submit fixed-length
//! token windows, the coordinator batches them dynamically (the PJRT
//! executable is lowered at batch `B`), executes on the PJRT CPU device,
//! and returns per-window NLL. std::thread + mpsc (tokio is not in the
//! offline vendor set — the event loop is a plain loop and channels).
//!
//! ```text
//!  client threads ──score(window)──▶ queue ──next_batch──▶ run() loop ──▶ PJRT exe
//!        ▲                                                      │
//!        └──────────────── per-request oneshot ◀────────────────┘
//! ```
//!
//! Threading model: **all PJRT work happens on the thread that calls
//! [`Coordinator::run`]** (xla_extension 0.5.1 deadlocks when a second CPU
//! client is created on another thread while one is in use, so the process
//! keeps a single per-thread client — see `runtime::cpu_client`). Client
//! threads only touch channels. `run` returns when every
//! [`ScoreClient`] has been dropped and the queue is drained.

pub mod batcher;
pub mod metrics;

use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender, SyncSender};
use std::time::Instant;

pub use batcher::{next_batch, BatchPolicy};
pub use metrics::{LatencyStats, ServeReport};

use crate::cli::Args;
use crate::data::{Corpus, CorpusKind};
use crate::model::Checkpoint;
use crate::pipeline::quantize_checkpoint;
use crate::quant::Scheme;
use crate::runtime::HloScorer;

/// One in-flight scoring request.
struct Request {
    window: Vec<u16>,
    submitted: Instant,
    respond: SyncSender<anyhow::Result<f32>>,
}

/// Handle client threads use to talk to a running coordinator. The serving
/// loop exits once all clients are dropped.
#[derive(Clone)]
pub struct ScoreClient {
    tx: Sender<Request>,
    seq: usize,
}

impl ScoreClient {
    /// Score one window (blocking). Returns the summed NLL of the window.
    pub fn score(&self, window: Vec<u16>) -> anyhow::Result<f32> {
        anyhow::ensure!(window.len() == self.seq, "window must be {} tokens", self.seq);
        let (rtx, rrx) = std::sync::mpsc::sync_channel(1);
        self.tx
            .send(Request { window, submitted: Instant::now(), respond: rtx })
            .map_err(|_| anyhow::anyhow!("coordinator stopped"))?;
        rrx.recv().map_err(|_| anyhow::anyhow!("coordinator dropped request"))?
    }
}

/// Everything the serving loop needs.
pub struct CoordinatorConfig {
    pub artifacts: PathBuf,
    pub ck: Checkpoint,
    pub opts: crate::engine::EngineOpts,
    pub policy: BatchPolicy,
}

/// The request queue + serving loop.
pub struct Coordinator {
    tx: Option<Sender<Request>>,
    rx: Receiver<Request>,
    cfg: CoordinatorConfig,
}

impl Coordinator {
    pub fn new(cfg: CoordinatorConfig) -> Coordinator {
        let (tx, rx) = channel();
        Coordinator { tx: Some(tx), rx, cfg }
    }

    /// A client handle. Create one per client thread **before** calling
    /// [`run`](Self::run); `run` drops the coordinator's own sender, so the
    /// loop ends when the last client handle is gone.
    pub fn client(&self) -> ScoreClient {
        ScoreClient {
            tx: self.tx.as_ref().expect("before run").clone(),
            seq: self.cfg.ck.config.max_seq,
        }
    }

    /// Run the serving loop on the current thread until every client is
    /// dropped and the queue is drained; returns the serving report.
    pub fn run(mut self) -> anyhow::Result<ServeReport> {
        drop(self.tx.take()); // only client handles keep the queue open
        let scorer = HloScorer::for_model(&self.cfg.artifacts, &self.cfg.ck.config, &self.cfg.opts)?;
        let weights = scorer.upload_weights(&self.cfg.ck)?;
        let b = scorer.batch;
        let policy = BatchPolicy { max_batch: b, ..self.cfg.policy };
        let seq = scorer.seq;
        let mut flat: Vec<u16> = Vec::with_capacity(b * seq);
        let mut latency = LatencyStats::default();
        let mut batches = 0usize;
        let mut requests = 0usize;
        let t0 = Instant::now();
        while let Some(batch) = next_batch(&self.rx, policy) {
            flat.clear();
            for r in &batch {
                flat.extend_from_slice(&r.window);
            }
            for _ in batch.len()..b {
                flat.extend_from_slice(&batch[0].window); // pad, discarded
            }
            let result = scorer.score_batch(&flat, &weights);
            let now = Instant::now();
            batches += 1;
            requests += batch.len();
            for r in &batch {
                latency.record(now - r.submitted);
            }
            match result {
                Ok(nll) => {
                    for (r, &v) in batch.iter().zip(nll.iter()) {
                        let _ = r.respond.send(Ok(v));
                    }
                }
                Err(e) => {
                    for r in batch {
                        let _ = r.respond.send(Err(anyhow::anyhow!("{e:#}")));
                    }
                }
            }
        }
        Ok(ServeReport {
            requests,
            batches,
            wall: t0.elapsed(),
            latency,
            mean_batch_size: requests as f64 / batches.max(1) as f64,
        })
    }
}

/// `zqfp serve` — load a checkpoint, quantize it under `--scheme`, start
/// the coordinator on its PJRT artifact, fire `--requests` scoring
/// requests from `--clients` threads, and print the latency/throughput
/// report (the e2e serving validation of DESIGN.md §5).
pub fn serve_command(args: &Args) -> Result<(), String> {
    let ckpt = args.get("ckpt").ok_or("--ckpt required")?;
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let data = PathBuf::from(args.get_or("data", "data"));
    let n_requests = args.get_usize("requests", 256)?;
    let n_clients = args.get_usize("clients", 4)?;
    let max_wait_ms = args.get_usize("max-wait-ms", 2)?;
    let alpha = args.get_f32("alpha", 1.0)?;
    let scheme_s = args.get_or("scheme", "w4a8-fp-fp");
    let scheme = Scheme::parse(&scheme_s).ok_or(format!("bad --scheme {scheme_s}"))?;
    let cfg = crate::cli::commands::ptq_config_from_args(args, scheme)?;
    args.finish()?;

    let ck = crate::cli::commands::load_ckpt_with_alpha(std::path::Path::new(&ckpt), alpha)?;
    let seq = ck.config.max_seq;
    let calib = crate::cli::commands::load_calib(&data, seq)?;
    println!("quantizing under {} ...", scheme.name());
    let (qck, report) = quantize_checkpoint(&ck, &calib, &cfg);
    println!(
        "  {} tensors, {:.2}x compression",
        report.layers.len(),
        report.compression()
    );

    // workload: eval windows from the C4 surrogate
    let corpus = Corpus::new(CorpusKind::C4);
    let stream = corpus.generate(n_requests * seq, 7);
    let windows: Vec<Vec<u16>> = stream.chunks_exact(seq).map(|c| c.to_vec()).collect();
    let n_windows = windows.len();

    let opts = cfg.engine_opts();
    let coord = Coordinator::new(CoordinatorConfig {
        artifacts,
        ck: qck,
        opts,
        policy: BatchPolicy {
            max_batch: crate::runtime::SCORE_BATCH,
            max_wait: std::time::Duration::from_millis(max_wait_ms as u64),
        },
    });

    println!(
        "serving {n_windows} requests from {n_clients} clients (batch window {max_wait_ms} ms) ..."
    );
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let client = coord.client();
        let my: Vec<Vec<u16>> = windows.iter().skip(c).step_by(n_clients).cloned().collect();
        handles.push(std::thread::spawn(move || -> anyhow::Result<f64> {
            let mut sum = 0.0f64;
            for w in my {
                sum += client.score(w)? as f64;
            }
            Ok(sum)
        }));
    }
    // PJRT loop on this thread
    let report = coord.run().map_err(|e| e.to_string())?;
    let mut total_nll = 0.0f64;
    for h in handles {
        total_nll += h.join().map_err(|_| "client panicked")?.map_err(|e| e.to_string())?;
    }
    report.print();
    let tokens = (seq - 1) * n_windows;
    println!(
        "workload ppl {:.4} over {} scored tokens",
        (total_nll / tokens as f64).exp(),
        tokens
    );
    Ok(())
}
