//! The serving coordinator — Layer 3's runtime contribution.
//!
//! Two workloads over a quantized model, one request queue:
//!
//! * **Scoring** — clients submit fixed-length token windows and get the
//!   summed NLL back ([`ScoreClient::score`]). Windows are batched
//!   dynamically and executed in one shot.
//! * **Generation** — clients submit a prompt plus a token budget and get
//!   greedy-decoded tokens back ([`GenClient::generate`]). The compiled
//!   backend serves these with **continuous batching**: each prompt is
//!   [`prefill`](crate::plan::CompiledModel::prefill)ed into its own
//!   [`KvCache`], then every in-flight sequence advances one token per
//!   [`decode_step_batch`](crate::plan::CompiledModel::decode_step_batch)
//!   call. Sequences join mid-flight (the [`try_fill`] path runs between
//!   steps) and leave the moment their budget is spent — no
//!   wait-for-the-slowest batch barrier.
//!
//! std::thread + mpsc (tokio is not in the offline vendor set — the event
//! loop is a plain loop and channels).
//!
//! ```text
//!  client threads ──score/generate──▶ queue ─┬─ idle: next_batch ──▶ run() loop
//!        ▲                                   └─ busy: try_fill  (join mid-flight)
//!        │                                                      │
//!        └──────────────── per-request oneshot ◀────────────────┘
//! ```
//!
//! Two backends:
//!
//! * [`ScoreBackend::Pjrt`] — the AOT HLO executable (batch lowered at
//!   `B = SCORE_BATCH`). Scoring only — generation requests are answered
//!   with an error (the incremental-decode state lives in the compiled
//!   plan). All PJRT work happens on the thread that calls
//!   [`Coordinator::run`] (xla_extension 0.5.1 deadlocks when a second CPU
//!   client is created on another thread while one is in use, so the
//!   process keeps a single per-thread client). Needs `make artifacts` and
//!   the `pjrt` cargo feature.
//! * [`ScoreBackend::Compiled`] — the prepacked in-process engine
//!   ([`crate::plan::CompiledModel`]): the checkpoint is compiled once at
//!   loop start; scoring decodes allocation-free through the scratch
//!   arena, and generation runs the continuous-batching loop above.
//!   Finished sequences' caches return to a free pool, so the steady state
//!   allocates only per-request response buffers. Always available; this
//!   is what `zqfp serve`, the serving bench and the e2e example fall back
//!   to when artifacts (or the feature) are missing.
//!
//! Scoring requests share the loop with generation: they are executed at
//! admission time, between decode steps — a scoring burst therefore adds
//! head-of-line latency to in-flight generations (and vice versa), which
//! is the usual single-worker trade; [`ServeReport`] separates the two
//! workloads so the effect is visible.
//!
//! Client threads only touch channels. `run` returns when every client
//! handle has been dropped and the queue is drained.
//!
//! # Failure model & backpressure
//!
//! The fair-weather loop above is hardened by four mechanisms (see
//! ARCHITECTURE.md §"Failure model & backpressure" for the full map):
//!
//! * **Bounded admission** — the work queue is a `sync_channel` of
//!   [`CoordinatorConfig::queue_depth`] slots; submission *sheds* with a
//!   typed [`ServeError::Overloaded`] when the queue is full instead of
//!   hiding overload inside unbounded latency.
//! * **Deadlines** — requests may carry one (per-client default from
//!   [`CoordinatorConfig::deadline`], or per-call via `*_by`). It is
//!   checked at admission, between prefill chunks, and between decode
//!   steps; expired work returns [`ServeError::DeadlineExceeded`] with
//!   whatever tokens were already decoded.
//! * **Panic isolation** — plan execution runs under `catch_unwind`: a
//!   panic answers the poisoned request with [`ServeError::Faulted`],
//!   quarantines its KV cache (never recycled), and the loop keeps
//!   serving everyone else (a batched-step panic is retried solo, which
//!   is bit-safe because the layer walk commits KV cursors only at the
//!   end).
//! * **Graceful drain** — a [`ShutdownHandle`] stops admission, lets
//!   in-flight sequences finish, and answers queued work with
//!   [`ServeError::ShuttingDown`].
//!
//! The [`fault`] module injects deterministic panics/stalls at five
//! sites (admission, prefill, decode, draft, respond) so all of the above is
//! testable by seed (`zqfp serve --fault <site>:<spec>`); the invariant
//! under any schedule is *exactly one typed response per request* and a
//! loop that never hangs.

pub mod batcher;
pub mod fault;
pub mod metrics;
pub mod sampling;
pub mod session;

use std::collections::VecDeque;
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

pub use batcher::{
    next_batch, next_batch_watching, next_batch_watching_urgent, try_fill, BatchPolicy, Fill,
    Wakeup, POLL_SLICE,
};
pub use fault::{FaultInjector, FaultPayload, FaultPlan, FaultSite, FaultSpec};
pub use metrics::{LatencyStats, RateStats, ServeReport};
pub use sampling::{extend_hash, sample_token, seed_hash, SamplingConfig};
pub use session::{SessionManager, TurnCheckout, DEFAULT_MAX_SESSIONS};

use crate::cli::Args;
use crate::data::{Corpus, CorpusKind};
use crate::error::Result;
use crate::formats::FpFormat;
use crate::model::Checkpoint;
use crate::pipeline::{ptq, PtqReport};
use crate::plan::speculate::{draft_propose, verify_commit, AdaptiveK, SpecSequence, SpecStats};
use crate::plan::{argmax, CompiledModel, KvCache, KvPagePool};
use crate::quant::QuantSidecar;
use crate::recipe::{QuantRecipe, RecipeError, SpeculateConfig};
use crate::runtime::HloScorer;

/// Which execution engine serves scoring requests.
#[derive(Debug, Clone)]
pub enum ScoreBackend {
    /// AOT PJRT HLO artifacts under this directory.
    Pjrt { artifacts: PathBuf },
    /// The prepacked in-process engine (always available).
    Compiled,
}

/// Default bound of the admission queue (requests), used when a recipe
/// or config does not override it.
pub const DEFAULT_QUEUE_DEPTH: usize = 256;

/// Deadline probe granularity during prefill: the guarded prefill checks
/// the request's deadline every this many prompt tokens, so an expiring
/// prompt aborts without burning the rest of its prefill.
const PREFILL_CHUNK: usize = 8;

/// The typed outcome of one serving request — every client gets exactly
/// one of these per submission, no matter what faults strike the loop.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The request was malformed (bad window length, token out of vocab,
    /// budget exceeding `max_seq`, …). Checked client-side for fast
    /// failure and loop-side for defense.
    Invalid(String),
    /// Shed at submit: the bounded admission queue was full.
    Overloaded,
    /// The deadline passed — at admission (`partial` empty) or mid-flight
    /// (`partial` holds the tokens decoded before expiry).
    DeadlineExceeded { partial: Vec<u16> },
    /// A panic was caught while executing this request; the message names
    /// the injected fault site or carries the genuine panic text.
    Faulted(String),
    /// The coordinator is draining (or already gone) — the request was
    /// not executed.
    ShuttingDown,
    /// No session with this id is open.
    SessionNotFound(String),
    /// The session already has a turn in flight (one turn per session),
    /// or a control op (close/fork/revert) raced an in-flight turn.
    SessionBusy(String),
    /// `open` (or a fork destination) collided with an existing session.
    DuplicateSession(String),
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Invalid(msg) => write!(f, "invalid request: {msg}"),
            ServeError::Overloaded => write!(f, "overloaded: admission queue full"),
            ServeError::DeadlineExceeded { partial } => {
                write!(f, "deadline exceeded ({} partial tokens)", partial.len())
            }
            ServeError::Faulted(msg) => write!(f, "request faulted: {msg}"),
            ServeError::ShuttingDown => write!(f, "coordinator shutting down"),
            ServeError::SessionNotFound(id) => write!(f, "session not found: {id}"),
            ServeError::SessionBusy(id) => {
                write!(f, "session busy: {id} already has a turn in flight")
            }
            ServeError::DuplicateSession(id) => write!(f, "session already exists: {id}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Per-request result type of the serving API.
pub type ServeResult<T> = std::result::Result<T, ServeError>;

/// Misuse of the [`Coordinator`] lifecycle itself (as opposed to
/// [`ServeError`], which covers per-request outcomes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CoordinatorError {
    /// Client handles must be created before [`Coordinator::run`]
    /// consumes the queue's sender.
    NotAcceptingClients,
}

impl fmt::Display for CoordinatorError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CoordinatorError::NotAcceptingClients => {
                write!(f, "coordinator is not accepting new clients (create handles before run)")
            }
        }
    }
}

impl std::error::Error for CoordinatorError {}

/// One in-flight scoring request.
struct ScoreRequest {
    window: Vec<u16>,
    submitted: Instant,
    deadline: Option<Instant>,
    respond: SyncSender<ServeResult<f32>>,
}

/// One in-flight generation request.
struct GenRequest {
    prompt: Vec<u16>,
    max_new: usize,
    submitted: Instant,
    deadline: Option<Instant>,
    respond: GenRespond,
}

/// One streamed item of a session turn: every decoded token as it lands,
/// then exactly one final typed result.
#[derive(Debug, Clone, PartialEq)]
pub enum TurnEvent {
    /// One freshly decoded token (sent per decode step, before `Done`).
    Token(u16),
    /// The turn's single terminal result — same contract as a one-shot
    /// generate: exactly one `Done` per turn, whatever faults strike.
    Done(ServeResult<Generated>),
}

/// The response side of a streamed turn: `Token`s as they decode, then
/// one `Done`. The channel is sized `max_new + 1`, so the loop's sends
/// never block on a slow stream consumer.
pub type TurnTicket = Receiver<TurnEvent>;

/// Where a generation's results go: the classic oneshot, or a session
/// turn's token stream. Keeping both behind one responder lets the
/// continuous-batching loop treat turns as ordinary generations
/// everywhere except the commit/stream points.
enum GenRespond {
    Oneshot(SyncSender<ServeResult<Generated>>),
    Stream(SyncSender<TurnEvent>),
}

impl GenRespond {
    /// Stream one decoded token (no-op for oneshot responders). True when
    /// the event was actually delivered to a listening client.
    fn stream_token(&self, tok: u16) -> bool {
        match self {
            GenRespond::Oneshot(_) => false,
            GenRespond::Stream(tx) => tx.send(TurnEvent::Token(tok)).is_ok(),
        }
    }
}

/// One session turn: decode `max_new` tokens after the session's
/// committed history extended by `delta`.
struct TurnRequest {
    session: String,
    delta: Vec<u16>,
    max_new: usize,
    submitted: Instant,
    deadline: Option<Instant>,
    respond: SyncSender<TurnEvent>,
}

/// Session control verbs (admission-phase ops — they never decode).
enum SessionOp {
    Open,
    Close,
    Fork { dst: String },
    Revert { to_len: usize },
    Tokens,
}

/// One session control request; answers with the session's committed
/// tokens where that is meaningful (revert/tokens), empty otherwise.
struct SessionCtl {
    id: String,
    op: SessionOp,
    submitted: Instant,
    respond: SyncSender<ServeResult<Vec<u16>>>,
}

/// Everything a client can ask of the coordinator.
enum Work {
    Score(ScoreRequest),
    Generate(GenRequest),
    Turn(TurnRequest),
    Session(SessionCtl),
}

/// A finished generation.
#[derive(Debug, Clone, PartialEq)]
pub struct Generated {
    /// The `max_new` greedily-decoded tokens (prompt not included).
    pub tokens: Vec<u16>,
    /// Prompt length that was prefilled.
    pub prompt_len: usize,
    /// This request's decode-phase rate (tokens/s over the interleaved
    /// steps it was in flight; 0.0 when `max_new == 1`, which needs no
    /// decode step).
    pub decode_tok_s: f64,
}

/// Submit one `Work` item through the bounded queue, shedding typed
/// errors instead of blocking: a full queue is [`ServeError::Overloaded`]
/// (counted in the shared shed counter), a closed one is
/// [`ServeError::ShuttingDown`].
fn submit_work(
    tx: &SyncSender<Work>,
    shed: &AtomicUsize,
    work: Work,
) -> std::result::Result<(), ServeError> {
    match tx.try_send(work) {
        Ok(()) => Ok(()),
        Err(TrySendError::Full(_)) => {
            shed.fetch_add(1, Ordering::SeqCst);
            Err(ServeError::Overloaded)
        }
        Err(TrySendError::Disconnected(_)) => Err(ServeError::ShuttingDown),
    }
}

/// Handle client threads use to submit scoring requests. The serving loop
/// exits once all client handles (score and generation) are dropped.
#[derive(Clone)]
pub struct ScoreClient {
    tx: SyncSender<Work>,
    seq: usize,
    deadline: Option<Duration>,
    shed: Arc<AtomicUsize>,
}

impl ScoreClient {
    /// Score one window (blocking). Returns the summed NLL of the window.
    /// Carries the coordinator's default deadline, if any.
    pub fn score(&self, window: Vec<u16>) -> ServeResult<f32> {
        let deadline = self.deadline.map(|d| Instant::now() + d);
        self.score_by(window, deadline)
    }

    /// [`score`](Self::score) with an explicit per-request deadline
    /// (`None` = no deadline, overriding the coordinator default).
    pub fn score_by(&self, window: Vec<u16>, deadline: Option<Instant>) -> ServeResult<f32> {
        if window.len() != self.seq {
            return Err(ServeError::Invalid(format!("window must be {} tokens", self.seq)));
        }
        let (rtx, rrx) = sync_channel(1);
        submit_work(
            &self.tx,
            &self.shed,
            Work::Score(ScoreRequest {
                window,
                submitted: Instant::now(),
                deadline,
                respond: rtx,
            }),
        )?;
        rrx.recv().map_err(|_| ServeError::ShuttingDown)?
    }
}

/// The response side of a [`GenClient::submit`] call: receives exactly
/// one typed result (dropping it mid-generation is safe — the loop's
/// response send just fails silently).
pub type GenTicket = Receiver<ServeResult<Generated>>;

/// Handle client threads use to submit generation requests.
#[derive(Clone)]
pub struct GenClient {
    tx: SyncSender<Work>,
    max_seq: usize,
    vocab: usize,
    deadline: Option<Duration>,
    shed: Arc<AtomicUsize>,
}

impl GenClient {
    /// Greedily generate `max_new` tokens after `prompt` (blocking).
    /// Carries the coordinator's default deadline, if any.
    pub fn generate(&self, prompt: Vec<u16>, max_new: usize) -> ServeResult<Generated> {
        let deadline = self.deadline.map(|d| Instant::now() + d);
        self.generate_by(prompt, max_new, deadline)
    }

    /// [`generate`](Self::generate) with an explicit per-request deadline.
    pub fn generate_by(
        &self,
        prompt: Vec<u16>,
        max_new: usize,
        deadline: Option<Instant>,
    ) -> ServeResult<Generated> {
        let ticket = self.submit_by(prompt, max_new, deadline)?;
        ticket.recv().map_err(|_| ServeError::ShuttingDown)?
    }

    /// Non-blocking submit: the request is queued (or shed, typed) and
    /// the returned [`GenTicket`] delivers the one response later.
    pub fn submit(&self, prompt: Vec<u16>, max_new: usize) -> ServeResult<GenTicket> {
        let deadline = self.deadline.map(|d| Instant::now() + d);
        self.submit_by(prompt, max_new, deadline)
    }

    /// [`submit`](Self::submit) with an explicit per-request deadline.
    pub fn submit_by(
        &self,
        prompt: Vec<u16>,
        max_new: usize,
        deadline: Option<Instant>,
    ) -> ServeResult<GenTicket> {
        validate_gen(&prompt, max_new, self.max_seq, self.vocab)?;
        let (rtx, rrx) = sync_channel(1);
        submit_work(
            &self.tx,
            &self.shed,
            Work::Generate(GenRequest {
                prompt,
                max_new,
                submitted: Instant::now(),
                deadline,
                respond: GenRespond::Oneshot(rtx),
            }),
        )?;
        Ok(rrx)
    }
}

/// Handle client threads use to drive persistent sessions: open/close,
/// fork, revert, and token-streaming turns. Same lifetime rules as
/// [`ScoreClient`] — create handles before [`Coordinator::run`].
#[derive(Clone)]
pub struct SessionClient {
    tx: SyncSender<Work>,
    max_seq: usize,
    vocab: usize,
    deadline: Option<Duration>,
    shed: Arc<AtomicUsize>,
}

impl SessionClient {
    fn ctl(&self, id: &str, op: SessionOp) -> ServeResult<Vec<u16>> {
        let (rtx, rrx) = sync_channel(1);
        submit_work(
            &self.tx,
            &self.shed,
            Work::Session(SessionCtl {
                id: id.to_string(),
                op,
                submitted: Instant::now(),
                respond: rtx,
            }),
        )?;
        rrx.recv().map_err(|_| ServeError::ShuttingDown)?
    }

    /// Create an empty session ([`ServeError::DuplicateSession`] if taken).
    pub fn open(&self, id: &str) -> ServeResult<()> {
        self.ctl(id, SessionOp::Open).map(|_| ())
    }

    /// Close an idle session, freeing its KV state.
    pub fn close(&self, id: &str) -> ServeResult<()> {
        self.ctl(id, SessionOp::Close).map(|_| ())
    }

    /// Duplicate `src`'s dialog position as a new session `dst` (paged
    /// caches copy page-by-page; rings deep-copy).
    pub fn fork(&self, src: &str, dst: &str) -> ServeResult<()> {
        self.ctl(src, SessionOp::Fork { dst: dst.to_string() }).map(|_| ())
    }

    /// Truncate a session to its first `to_len` committed tokens; returns
    /// the surviving history.
    pub fn revert(&self, id: &str, to_len: usize) -> ServeResult<Vec<u16>> {
        self.ctl(id, SessionOp::Revert { to_len })
    }

    /// The session's committed token history.
    pub fn tokens(&self, id: &str) -> ServeResult<Vec<u16>> {
        self.ctl(id, SessionOp::Tokens)
    }

    /// Run one turn to completion (blocking), discarding the intermediate
    /// stream: append `delta` to the session's history, decode `max_new`
    /// tokens, commit. The returned [`Generated::prompt_len`] covers the
    /// full conversation (history + delta), even though only the delta
    /// was prefilled.
    pub fn turn(&self, id: &str, delta: Vec<u16>, max_new: usize) -> ServeResult<Generated> {
        let ticket = self.turn_stream(id, delta, max_new)?;
        loop {
            match ticket.recv() {
                Ok(TurnEvent::Token(_)) => continue,
                Ok(TurnEvent::Done(result)) => return result,
                Err(_) => return Err(ServeError::ShuttingDown),
            }
        }
    }

    /// Submit one turn and stream it: the [`TurnTicket`] yields a
    /// [`TurnEvent::Token`] per decode step, then exactly one
    /// [`TurnEvent::Done`]. Carries the coordinator's default deadline.
    pub fn turn_stream(
        &self,
        id: &str,
        delta: Vec<u16>,
        max_new: usize,
    ) -> ServeResult<TurnTicket> {
        let deadline = self.deadline.map(|d| Instant::now() + d);
        self.turn_stream_by(id, delta, max_new, deadline)
    }

    /// [`turn_stream`](Self::turn_stream) with an explicit deadline.
    /// Client-side validation covers what is knowable without the session
    /// history (the full-length check happens loop-side at checkout).
    pub fn turn_stream_by(
        &self,
        id: &str,
        delta: Vec<u16>,
        max_new: usize,
        deadline: Option<Instant>,
    ) -> ServeResult<TurnTicket> {
        if delta.is_empty() {
            return Err(ServeError::Invalid("turn delta must be non-empty".into()));
        }
        if max_new < 1 {
            return Err(ServeError::Invalid("max_new must be at least 1".into()));
        }
        if delta.len() >= self.max_seq {
            return Err(ServeError::Invalid(format!(
                "turn delta ({}) leaves no room in max_seq {}",
                delta.len(),
                self.max_seq
            )));
        }
        if let Some(&bad) = delta.iter().find(|&&t| t as usize >= self.vocab) {
            return Err(ServeError::Invalid(format!(
                "token id {bad} out of range (vocab size {})",
                self.vocab
            )));
        }
        // max_new tokens + the final Done always fit: the loop never
        // blocks streaming into this ticket
        let (rtx, rrx) = sync_channel(max_new + 1);
        submit_work(
            &self.tx,
            &self.shed,
            Work::Turn(TurnRequest {
                session: id.to_string(),
                delta,
                max_new,
                submitted: Instant::now(),
                deadline,
                respond: rtx,
            }),
        )?;
        Ok(rrx)
    }
}

/// Shared request validation (client side for fast failure, coordinator
/// side for defense — an invalid token id would otherwise panic the
/// loop). This is the *single* admission rule: `prompt + max_new` must
/// fit `max_seq` (the CLI pre-check in [`serve_command`] delegates here
/// rather than keeping its own drifted copy).
fn validate_gen(
    prompt: &[u16],
    max_new: usize,
    max_seq: usize,
    vocab: usize,
) -> std::result::Result<(), ServeError> {
    if prompt.is_empty() {
        return Err(ServeError::Invalid("prompt must be non-empty".into()));
    }
    if max_new < 1 {
        return Err(ServeError::Invalid("max_new must be at least 1".into()));
    }
    // saturating: `prompt.len() + max_new` could wrap for adversarial
    // max_new and sneak past the guard into a capacity-overflow panic
    if max_new > max_seq.saturating_sub(prompt.len()) {
        return Err(ServeError::Invalid(format!(
            "prompt ({}) + max_new ({max_new}) exceeds max_seq {max_seq}",
            prompt.len()
        )));
    }
    if let Some(&bad) = prompt.iter().find(|&&t| t as usize >= vocab) {
        return Err(ServeError::Invalid(format!(
            "token id {bad} out of range (vocab size {vocab})"
        )));
    }
    Ok(())
}

/// True when a request's deadline (if any) has already passed.
fn expired(deadline: Option<Instant>) -> bool {
    deadline.is_some_and(|d| Instant::now() >= d)
}

/// Run `f` with panics caught; a panic becomes its human-readable
/// message (injected faults name their site). `AssertUnwindSafe` is
/// sound here because the loop never reuses state a panic may have
/// poisoned: the scratch arena is fully rewritten by the next request
/// and the touched KV cache is quarantined by the caller.
fn guard<T>(f: impl FnOnce() -> T) -> std::result::Result<T, String> {
    catch_unwind(AssertUnwindSafe(f)).map_err(|p| fault::panic_message(&*p))
}

/// Arm the injector at `site` with the panic caught, so fault sites
/// outside the guarded plan sections (admission, respond) still turn
/// into typed errors instead of killing the loop.
fn fire(fi: &mut Option<FaultInjector>, site: FaultSite) -> std::result::Result<(), String> {
    match fi.as_mut() {
        Some(f) => guard(|| f.fire(site)),
        None => Ok(()),
    }
}

/// Send one typed response through a request's oneshot, arming the
/// respond-site fault point first (a respond fault replaces the payload
/// with [`ServeError::Faulted`] — the client still gets exactly one
/// response). `faulted` counts every `Faulted` actually delivered.
fn deliver<T>(
    fi: &mut Option<FaultInjector>,
    faulted: &mut usize,
    respond: &SyncSender<ServeResult<T>>,
    mut result: ServeResult<T>,
) {
    if let Err(msg) = fire(fi, FaultSite::Respond) {
        result = Err(ServeError::Faulted(msg));
    }
    if matches!(&result, Err(ServeError::Faulted(_))) {
        *faulted += 1;
    }
    let _ = respond.send(result); // a dropped client is not an error
}

/// [`deliver`] for generation responders: the same respond-site fault
/// arming and `faulted` accounting, routed to the oneshot channel or
/// wrapped as a stream's final [`TurnEvent::Done`] — either way the
/// client gets exactly one terminal result.
fn deliver_gen(
    fi: &mut Option<FaultInjector>,
    faulted: &mut usize,
    respond: &GenRespond,
    mut result: ServeResult<Generated>,
) {
    if let Err(msg) = fire(fi, FaultSite::Respond) {
        result = Err(ServeError::Faulted(msg));
    }
    if matches!(&result, Err(ServeError::Faulted(_))) {
        *faulted += 1;
    }
    match respond {
        GenRespond::Oneshot(tx) => {
            let _ = tx.send(result);
        }
        GenRespond::Stream(tx) => {
            let _ = tx.send(TurnEvent::Done(result));
        }
    }
}

/// Everything the serving loop needs.
pub struct CoordinatorConfig {
    pub backend: ScoreBackend,
    pub ck: Checkpoint,
    pub opts: crate::engine::EngineOpts,
    pub policy: BatchPolicy,
    /// `Some(fmt)` ⇒ the compiled backend stores generation K/V caches
    /// fake-quantized to this FP format (the paper's activation formats
    /// applied to the dominant serving memory stream). `None` = exact f32
    /// caches, bit-identical to full recompute.
    pub kv_quant: Option<FpFormat>,
    /// Quantized-artifact sidecar of the PTQ run (codes + optional LoRC
    /// factors per linear, [`crate::pipeline::ptq`]) — required when
    /// `opts.weights` selects the packed layout; ignored otherwise.
    pub sidecar: Option<QuantSidecar>,
    /// `> 0` ⇒ generation K/V lives in a shared block-paged
    /// [`KvPagePool`] with this many positions per page: resident bytes
    /// scale with live tokens, admission is gated on free pages, and a
    /// dry pool preempts (requeues) the youngest sequence instead of
    /// deadlocking. `0` = the classic per-sequence `max_seq` rings.
    pub kv_page_positions: usize,
    /// Byte budget of the paged pool (whole pages; clamped up so one
    /// `max_seq` sequence always fits). `0` = auto: `max_batch` full
    /// sequences' worth of pages — the ring plan's bound, paged. Ignored
    /// when `kv_page_positions == 0`.
    pub kv_budget_bytes: usize,
    /// Bound of the admission queue (requests). Submissions beyond it
    /// shed with [`ServeError::Overloaded`]; clamped to at least 1.
    pub queue_depth: usize,
    /// Default per-request deadline handed to every client (`None` = no
    /// deadline; `*_by` calls override per request).
    pub deadline: Option<Duration>,
    /// Deterministic fault schedule for chaos runs (`None` in
    /// production — injection compiled in but disarmed costs nothing on
    /// the hot path beyond an `Option` check).
    pub faults: Option<FaultPlan>,
    /// `Some` ⇒ the compiled backend decodes speculatively: a second
    /// (cheaper) plan of the same checkpoint drafts `k` tokens per round
    /// and the target plan verifies them in one batched pass — exact
    /// greedy parity, see [`crate::plan::speculate`]. Every in-flight
    /// sequence then carries a draft KV cache next to its target cache.
    pub speculate: Option<SpeculateConfig>,
    /// How decode steps pick the next token: greedy argmax at
    /// `temperature == 0` (bit-identical to the historical path), else
    /// temperature/top-k/top-p sampling seeded per position from a prefix
    /// hash — reproducible across runs, batch compositions, preemption
    /// replays and session restores (see [`sampling`]).
    pub sampling: SamplingConfig,
    /// LRU capacity on resident idle session caches (sessions beyond it
    /// stay open; their caches re-prefill on the next turn). Clamped to
    /// at least 1.
    pub max_sessions: usize,
}

/// The checkpoint→sidecar→[`CompiledModel`]→[`Coordinator`] wiring that
/// `zqfp serve`/`eval`, `examples/e2e_serve.rs` and the serving benches
/// all share, driven by one validated [`QuantRecipe`].
///
/// [`build`](Self::build) runs PTQ under the recipe and keeps the three
/// artifacts together; [`compile`](Self::compile) produces the execution
/// plan in the recipe's weight layout (dense or bit-packed), and
/// [`coordinator`](Self::coordinator) wires a full serving loop. The
/// equivalence suites (`tests/{plan,packed,lorc,kv}_equivalence.rs`)
/// drive their models through this path, so the recipe → plan wiring is
/// covered by the same bit-identity contracts as the plans themselves.
pub struct ServingStack {
    /// The effective (fake-quantized, LoRC-folded) checkpoint.
    pub checkpoint: Checkpoint,
    /// Codes + optional LoRC factors per linear (empty only for W16).
    pub sidecar: QuantSidecar,
    pub report: PtqReport,
    pub recipe: QuantRecipe,
}

impl ServingStack {
    /// Quantize `ck` under `recipe` (calibrating from `calib` when the
    /// recipe uses GPTQ) and wire the serving artifacts. The recipe is
    /// re-validated here so a hand-mutated invalid one fails with its
    /// typed [`RecipeError`] instead of a downstream panic.
    pub fn build(
        ck: &Checkpoint,
        calib: &[Vec<u16>],
        recipe: &QuantRecipe,
    ) -> std::result::Result<ServingStack, RecipeError> {
        recipe.validate()?;
        let out = ptq(ck, calib, None, recipe);
        Ok(ServingStack {
            checkpoint: out.checkpoint,
            sidecar: out.sidecar,
            report: out.report,
            recipe: recipe.clone(),
        })
    }

    /// Re-wire the same PTQ artifacts under a different recipe — e.g. a
    /// dense scoring stack and a packed generation stack from one
    /// quantization run, or a GEMV-shard sweep over fixed codes. The new
    /// recipe's serving side is honored; its PTQ side is assumed to match
    /// the artifacts (they are not re-quantized).
    pub fn with_recipe(
        &self,
        recipe: &QuantRecipe,
    ) -> std::result::Result<ServingStack, RecipeError> {
        recipe.validate()?;
        Ok(ServingStack {
            checkpoint: self.checkpoint.clone(),
            sidecar: self.sidecar.clone(),
            report: self.report.clone(),
            recipe: recipe.clone(),
        })
    }

    /// Compile the execution plan in the recipe's weight layout. The
    /// packed layout compiles from the sidecar codes (bit-identical
    /// logits, a fraction of the resident weight bytes); validation
    /// guarantees the sidecar is non-empty whenever the layout is packed.
    pub fn compile(&self) -> CompiledModel {
        if self.recipe.weights.is_dense() {
            CompiledModel::compile(&self.checkpoint, self.recipe.engine_opts())
        } else {
            CompiledModel::compile_quantized(
                &self.checkpoint,
                &self.sidecar,
                self.recipe.engine_opts(),
            )
        }
    }

    /// The dense twin of [`compile`](Self::compile): the same effective
    /// checkpoint compiled in the dense f32 layout *regardless* of the
    /// recipe's serving layout — the oracle the packed plan is checked
    /// against in the equivalence suites and benches. Activation options
    /// still come from the recipe, so the two plans differ only in where
    /// the same bits are stored. The kernel tier is pinned to the oracle
    /// for the same reason: this plan is the reference side of every
    /// differential check, whatever tier the recipe serves with.
    pub fn compile_dense(&self) -> CompiledModel {
        let mut opts = self.recipe.engine_opts();
        opts.weights = crate::engine::WeightLayout::Dense;
        opts.kernels = crate::engine::KernelTier::Oracle;
        CompiledModel::compile(&self.checkpoint, opts)
    }

    /// Compile the **draft** plan of the recipe's
    /// [`speculate`](QuantRecipe::speculate) config — a second view of the
    /// same PTQ artifacts, or `None` when the recipe does not speculate.
    ///
    /// The draft recipe selects the view: a dense draft recompiles the
    /// effective checkpoint under the draft's activation/kernel options;
    /// a packed draft compiles from the sidecar codes, and a draft
    /// *without* LoRC strips the factors
    /// ([`QuantSidecar::without_lorc`]) so it is a genuine rank-0 W4
    /// plan — cheaper per token than the target it drafts for. Recipe
    /// validation guarantees the pairing is well-formed (the draft is
    /// strictly cheaper, and packed drafts only appear when the target
    /// run produced codes).
    pub fn compile_draft(&self) -> Option<CompiledModel> {
        let sc = self.recipe.speculate.as_ref()?;
        Some(compile_draft_plan(&self.checkpoint, Some(&self.sidecar), &sc.draft))
    }

    /// A coordinator on the compiled in-process backend (consumes the
    /// stack — the coordinator owns the checkpoint and sidecar).
    pub fn coordinator(self) -> Coordinator {
        self.coordinator_with_backend(ScoreBackend::Compiled)
    }

    /// Same, with an explicit scoring backend (PJRT when artifacts exist;
    /// see [`pick_backend`]).
    pub fn coordinator_with_backend(self, backend: ScoreBackend) -> Coordinator {
        let mut cfg = self.recipe.coordinator_config(self.checkpoint, Some(self.sidecar));
        cfg.backend = backend;
        Coordinator::new(cfg)
    }
}

/// The draft-plan compile rule [`ServingStack::compile_draft`] and the
/// serving loop share: dense drafts recompile the effective checkpoint
/// under the draft's engine options; packed drafts compile from the
/// sidecar codes, stripping the LoRC factors when the draft recipe
/// carries none (a genuine rank-0 W4 view of a LoRC target's artifacts).
/// Panics when a packed draft is requested without a sidecar — recipe
/// validation and the coordinator's own sidecar check make that
/// unreachable from validated configs.
fn compile_draft_plan(
    ck: &Checkpoint,
    sidecar: Option<&QuantSidecar>,
    draft: &QuantRecipe,
) -> CompiledModel {
    if draft.weights.is_dense() {
        CompiledModel::compile(ck, draft.engine_opts())
    } else {
        let sidecar = sidecar.expect("packed draft plan requires the quantized-code sidecar");
        if draft.lorc.is_none() {
            CompiledModel::compile_quantized(ck, &sidecar.without_lorc(), draft.engine_opts())
        } else {
            CompiledModel::compile_quantized(ck, sidecar, draft.engine_opts())
        }
    }
}

/// Raises the drain signal of one [`Coordinator`] from any thread: the
/// loop stops admitting, finishes in-flight sequences, answers queued
/// work with [`ServeError::ShuttingDown`], and returns its report.
#[derive(Clone)]
pub struct ShutdownHandle {
    stop: Arc<AtomicBool>,
}

impl ShutdownHandle {
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
    }

    pub fn is_shutdown(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }
}

/// The request queue + serving loop.
pub struct Coordinator {
    tx: Option<SyncSender<Work>>,
    rx: Receiver<Work>,
    cfg: CoordinatorConfig,
    stop: Arc<AtomicBool>,
    shed: Arc<AtomicUsize>,
}

/// Decode-side state of one in-flight generation (its [`KvCache`] lives in
/// a parallel vector so the caches can be borrowed as one slice per step).
struct ActiveGen {
    /// Tokens decoded so far; the last one is the next step's input.
    generated: Vec<u16>,
    max_new: usize,
    /// The original prompt — kept so a paged-pool preemption can requeue
    /// this sequence for re-prefill (greedy decode is deterministic, so
    /// the restarted request reproduces the same tokens).
    prompt: Vec<u16>,
    submitted: Instant,
    deadline: Option<Instant>,
    decode_start: Instant,
    /// Monotonic admission number: preemption evicts the *youngest*
    /// in-flight sequence (largest `seq_no`) — it loses the least work.
    seq_no: u64,
    respond: GenRespond,
    /// Speculative-decode state (`None` when the run does not speculate,
    /// or after a draft-site fault permanently downgraded this sequence
    /// to target-only decode — the degradation is invisible in the
    /// output, only in the rate).
    spec: Option<SpecState>,
    /// Session-turn bookkeeping (`None` for one-shot generations).
    /// Turn sequences never mint speculative state — their cache must end
    /// the turn as a strict prefix of the committed history, which the
    /// verify pass's bonus-token appends would violate.
    turn: Option<TurnState>,
    /// Positional sampling hash over `prompt ++ generated` (see
    /// [`sampling::seed_hash`]); unused (and unmaintained) on the greedy
    /// and speculative paths, which are argmax by construction.
    hash: u64,
}

/// Session bookkeeping of one in-flight (or waiting) turn.
struct TurnState {
    id: String,
    /// Committed history length at checkout; a deadline abort truncates
    /// the cache back to (at most) this prefix.
    committed: usize,
    /// Tokens of this turn already streamed — preserved across preemption
    /// replays so a re-decoded token is never re-sent.
    streamed: usize,
}

/// One admitted generation waiting for an in-flight slot.
struct PendingGen {
    g: GenRequest,
    /// A preemption requeue (counted as `kv_requeues` when it restarts,
    /// not as a new request).
    requeued: bool,
    /// Present for session turns: the checked-out cache rides to the
    /// start phase (`None` = restore or preemption — full re-prefill).
    turn: Option<(TurnState, Option<KvCache>)>,
}

/// The draft half of one speculating sequence: its own KV cache on the
/// draft plan, the catch-up/pending accounting, and the per-sequence
/// adaptive draft window.
struct SpecState {
    cache: KvCache,
    seq: SpecSequence,
    window: AdaptiveK,
}

impl Coordinator {
    pub fn new(cfg: CoordinatorConfig) -> Coordinator {
        let (tx, rx) = sync_channel(cfg.queue_depth.max(1));
        Coordinator {
            tx: Some(tx),
            rx,
            cfg,
            stop: Arc::new(AtomicBool::new(false)),
            shed: Arc::new(AtomicUsize::new(0)),
        }
    }

    /// A scoring client handle. Create handles **before** calling
    /// [`run`](Self::run); `run` drops the coordinator's own sender, so the
    /// loop ends when the last client handle is gone.
    pub fn client(&self) -> std::result::Result<ScoreClient, CoordinatorError> {
        let tx = self.tx.as_ref().ok_or(CoordinatorError::NotAcceptingClients)?.clone();
        Ok(ScoreClient {
            tx,
            seq: self.cfg.ck.config.max_seq,
            deadline: self.cfg.deadline,
            shed: self.shed.clone(),
        })
    }

    /// A generation client handle (same lifetime rules as
    /// [`client`](Self::client)).
    pub fn gen_client(&self) -> std::result::Result<GenClient, CoordinatorError> {
        let tx = self.tx.as_ref().ok_or(CoordinatorError::NotAcceptingClients)?.clone();
        Ok(GenClient {
            tx,
            max_seq: self.cfg.ck.config.max_seq,
            vocab: self.cfg.ck.config.vocab_size,
            deadline: self.cfg.deadline,
            shed: self.shed.clone(),
        })
    }

    /// A session client handle: persistent multi-turn conversations with
    /// delta prefill, fork/revert, and streamed turns (same lifetime
    /// rules as [`client`](Self::client); compiled backend only).
    pub fn session_client(&self) -> std::result::Result<SessionClient, CoordinatorError> {
        let tx = self.tx.as_ref().ok_or(CoordinatorError::NotAcceptingClients)?.clone();
        Ok(SessionClient {
            tx,
            max_seq: self.cfg.ck.config.max_seq,
            vocab: self.cfg.ck.config.vocab_size,
            deadline: self.cfg.deadline,
            shed: self.shed.clone(),
        })
    }

    /// A handle that triggers graceful drain from any thread.
    pub fn shutdown_handle(&self) -> ShutdownHandle {
        ShutdownHandle { stop: self.stop.clone() }
    }

    /// Arm a deterministic fault schedule for this run (chaos testing /
    /// `zqfp serve --fault`).
    pub fn inject_faults(&mut self, plan: FaultPlan) {
        self.cfg.faults = Some(plan);
    }

    /// Run the serving loop on the current thread until every client is
    /// dropped and the queue is drained (or a [`ShutdownHandle`] drains
    /// it); returns the serving report.
    pub fn run(mut self) -> Result<ServeReport> {
        drop(self.tx.take()); // only client handles keep the queue open
        match self.cfg.backend.clone() {
            ScoreBackend::Pjrt { artifacts } => self.run_pjrt(&artifacts),
            ScoreBackend::Compiled => self.run_compiled(),
        }
    }

    fn run_pjrt(self, artifacts: &std::path::Path) -> Result<ServeReport> {
        let scorer = HloScorer::for_model(artifacts, &self.cfg.ck.config, &self.cfg.opts)?;
        let weights = scorer.upload_weights(&self.cfg.ck)?;
        let b = scorer.batch;
        let policy = BatchPolicy { max_batch: b, ..self.cfg.policy };
        let seq = scorer.seq;
        let mut fi: Option<FaultInjector> = self.cfg.faults.as_ref().map(FaultInjector::new);
        let mut flat: Vec<u16> = Vec::with_capacity(b * seq);
        let mut latency = LatencyStats::default();
        let mut batches = 0usize;
        let mut requests = 0usize;
        let mut expired_admission = 0usize;
        let mut faulted = 0usize;
        let mut rejected_shutdown = 0usize;
        let mut drained = false;
        let t0 = Instant::now();
        loop {
            let urgent = |w: &Work| matches!(w, Work::Turn(_) | Work::Session(_));
            let work = match next_batch_watching_urgent(&self.rx, policy, &self.stop, urgent) {
                Wakeup::Batch(work) => work,
                Wakeup::Shutdown => {
                    // graceful drain: nothing is ever in flight between
                    // batches here, so answer the queue and stop
                    drained = true;
                    while let Ok(w) = self.rx.try_recv() {
                        requests += 1;
                        rejected_shutdown += 1;
                        match w {
                            Work::Score(r) => {
                                latency.record(Instant::now() - r.submitted);
                                deliver(
                                    &mut fi,
                                    &mut faulted,
                                    &r.respond,
                                    Err(ServeError::ShuttingDown),
                                );
                            }
                            Work::Generate(g) => {
                                latency.record(Instant::now() - g.submitted);
                                deliver_gen(
                                    &mut fi,
                                    &mut faulted,
                                    &g.respond,
                                    Err(ServeError::ShuttingDown),
                                );
                            }
                            Work::Turn(t) => {
                                latency.record(Instant::now() - t.submitted);
                                deliver_gen(
                                    &mut fi,
                                    &mut faulted,
                                    &GenRespond::Stream(t.respond),
                                    Err(ServeError::ShuttingDown),
                                );
                            }
                            Work::Session(c) => {
                                latency.record(Instant::now() - c.submitted);
                                deliver(
                                    &mut fi,
                                    &mut faulted,
                                    &c.respond,
                                    Err(ServeError::ShuttingDown),
                                );
                            }
                        }
                    }
                    break;
                }
                Wakeup::Closed => break,
            };
            let mut batch = Vec::with_capacity(work.len());
            for w in work {
                match w {
                    Work::Score(r) => {
                        if let Err(msg) = fire(&mut fi, FaultSite::Admission) {
                            requests += 1;
                            latency.record(Instant::now() - r.submitted);
                            deliver(
                                &mut fi,
                                &mut faulted,
                                &r.respond,
                                Err(ServeError::Faulted(msg)),
                            );
                        } else if expired(r.deadline) {
                            requests += 1;
                            expired_admission += 1;
                            latency.record(Instant::now() - r.submitted);
                            deliver(
                                &mut fi,
                                &mut faulted,
                                &r.respond,
                                Err(ServeError::DeadlineExceeded { partial: Vec::new() }),
                            );
                        } else {
                            batch.push(r);
                        }
                    }
                    Work::Generate(g) => {
                        // the incremental-decode state lives in the
                        // compiled plan; the AOT scoring executable has no
                        // generation entry point. Counted like any other
                        // answered request so backend reports stay
                        // comparable for identical traffic.
                        requests += 1;
                        latency.record(Instant::now() - g.submitted);
                        deliver_gen(
                            &mut fi,
                            &mut faulted,
                            &g.respond,
                            Err(ServeError::Invalid(
                                "generation requires the compiled backend".into(),
                            )),
                        );
                    }
                    Work::Turn(t) => {
                        // sessions decode incrementally — compiled backend
                        // only, same rule as plain generation
                        requests += 1;
                        latency.record(Instant::now() - t.submitted);
                        deliver_gen(
                            &mut fi,
                            &mut faulted,
                            &GenRespond::Stream(t.respond),
                            Err(ServeError::Invalid(
                                "sessions require the compiled backend".into(),
                            )),
                        );
                    }
                    Work::Session(c) => {
                        requests += 1;
                        latency.record(Instant::now() - c.submitted);
                        deliver(
                            &mut fi,
                            &mut faulted,
                            &c.respond,
                            Err(ServeError::Invalid(
                                "sessions require the compiled backend".into(),
                            )),
                        );
                    }
                }
            }
            if batch.is_empty() {
                continue;
            }
            flat.clear();
            for r in &batch {
                flat.extend_from_slice(&r.window);
            }
            for _ in batch.len()..b {
                flat.extend_from_slice(&batch[0].window); // pad, discarded
            }
            let result = guard(|| scorer.score_batch(&flat, &weights));
            let now = Instant::now();
            batches += 1;
            requests += batch.len();
            for r in &batch {
                latency.record(now - r.submitted);
            }
            match result {
                Ok(Ok(nll)) => {
                    for (r, &v) in batch.iter().zip(nll.iter()) {
                        deliver(&mut fi, &mut faulted, &r.respond, Ok(v));
                    }
                }
                // a failed (or panicked) batch faults every request in
                // it — each still gets its one typed response
                Ok(Err(e)) => {
                    let msg = format!("{e:#}");
                    for r in &batch {
                        deliver(
                            &mut fi,
                            &mut faulted,
                            &r.respond,
                            Err(ServeError::Faulted(msg.clone())),
                        );
                    }
                }
                Err(msg) => {
                    for r in &batch {
                        deliver(
                            &mut fi,
                            &mut faulted,
                            &r.respond,
                            Err(ServeError::Faulted(msg.clone())),
                        );
                    }
                }
            }
        }
        Ok(ServeReport {
            requests,
            batches,
            wall: t0.elapsed(),
            latency,
            mean_batch_size: requests as f64 / batches.max(1) as f64,
            shed_overloaded: self.shed.load(Ordering::SeqCst),
            expired_admission,
            faulted,
            rejected_shutdown,
            drained,
            ..ServeReport::default()
        })
    }

    /// The compiled backend: immediate scoring plus continuous-batching
    /// generation (see the module docs for the loop shape).
    fn run_compiled(mut self) -> Result<ServeReport> {
        // Compile once; every request then decodes through the prepacked
        // plan with zero steady-state allocations in the model itself.
        // The packed weight layout compiles from the quantized-code
        // sidecar and serves bit-identical logits at a fraction of the
        // resident weight bytes.
        let model = if self.cfg.opts.weights.is_dense() {
            CompiledModel::compile(&self.cfg.ck, self.cfg.opts)
        } else {
            let sidecar = self.cfg.sidecar.as_ref().ok_or_else(|| {
                crate::anyhow!("packed weight layout requires the quantized-code sidecar")
            })?;
            CompiledModel::compile_quantized(&self.cfg.ck, sidecar, self.cfg.opts)
        };
        // Speculative decoding: compile the cheap draft plan as a second
        // view of the same artifacts — it must happen *before* the
        // artifacts are freed below.
        let draft: Option<(CompiledModel, usize)> = match &self.cfg.speculate {
            Some(sc) => {
                if !sc.draft.weights.is_dense() && self.cfg.sidecar.is_none() {
                    return Err(crate::anyhow!(
                        "speculative draft in the packed layout requires the \
                         quantized-code sidecar"
                    ));
                }
                Some((
                    compile_draft_plan(&self.cfg.ck, self.cfg.sidecar.as_ref(), &sc.draft),
                    sc.k.max(1),
                ))
            }
            None => None,
        };
        let mut draft_scratch = draft.as_ref().map(|(m, _)| m.scratch());
        // The plan owns copies of everything it serves (prepacked or
        // bit-packed weights, factor codes, embeddings, norms). Free the
        // PTQ artifacts for the serving run's lifetime: the sidecar
        // (codes + dense f32 LoRC factor matrices) and the checkpoint's
        // dense tensors — the latter dominate resident memory on a packed
        // run and would otherwise defeat the packed footprint. Only
        // `ck.config` is read below.
        self.cfg.sidecar = None;
        self.cfg.ck.tensors.clear();
        let mut scratch = model.scratch();
        let vocab = self.cfg.ck.config.vocab_size;
        let max_seq = self.cfg.ck.config.max_seq;
        let kv_quant = self.cfg.kv_quant;
        let sampling = self.cfg.sampling;
        // No lowered batch dimension to fill on this backend, and joins
        // happen between decode steps anyway — drain the queue eagerly
        // instead of holding the head request for company. In-flight
        // sequences are additionally clamped to max_seq: the scratch arena
        // is pre-sized for max_seq rows and decode_step_batch asserts it.
        let policy = BatchPolicy { max_wait: Duration::ZERO, ..self.cfg.policy };
        let max_active = policy.max_batch.max(1).min(max_seq);
        let mut fi: Option<FaultInjector> = self.cfg.faults.as_ref().map(FaultInjector::new);
        // Bytes one per-sequence ring pins (f32 storage even under FP8
        // fake-quant) — the unit of ring-mode KV accounting.
        let ring_bytes = {
            let c = &self.cfg.ck.config;
            c.n_layers * 2 * max_seq * c.d_model * std::mem::size_of::<f32>()
        };
        // Paged mode: one shared pool, eagerly allocated. Auto budget
        // (`0`) buys `max_active` full sequences' worth of pages — the
        // ring plan's bound — so paging can only tighten admission when a
        // budget is set explicitly. A speculative run doubles the
        // per-sequence cache count (draft + target), so the auto budget
        // doubles with it and the minimum clamp covers both caches —
        // admission never deadlocks on the second cache.
        let caches_per_seq = if draft.is_some() { 2 } else { 1 };
        let mut page_pool: Option<KvPagePool> = if self.cfg.kv_page_positions > 0 {
            let p = self.cfg.kv_page_positions;
            let budget = if self.cfg.kv_budget_bytes > 0 {
                self.cfg.kv_budget_bytes
            } else {
                let c = &self.cfg.ck.config;
                let page_bytes = c.n_layers * 2 * p * c.d_model * std::mem::size_of::<f32>();
                caches_per_seq * max_active * max_seq.div_ceil(p) * page_bytes
            };
            Some(KvPagePool::sized_for(&self.cfg.ck.config, p, budget, kv_quant, caches_per_seq))
        } else {
            None
        };
        // Sessions: resident caches survive between turns so the next
        // turn prefills only its delta. Evicted sessions keep their
        // transcript and re-prefill transparently on the next touch.
        let mut mgr = SessionManager::new(self.cfg.max_sessions);
        let mut streamed_tokens = 0usize;
        let mut session_restores = 0usize;

        let mut latency = LatencyStats::default();
        let mut request_tok_s = RateStats::default();
        let mut batches = 0usize;
        let mut requests = 0usize;
        let mut gen_requests = 0usize;
        let mut prefill_tokens = 0usize;
        let mut decode_tokens = 0usize;
        let mut decode_steps = 0usize;
        let mut decode_wall = Duration::ZERO;
        let mut expired_admission = 0usize;
        let mut expired_midflight = 0usize;
        let mut faulted = 0usize;
        let mut quarantined_caches = 0usize;
        let mut rejected_shutdown = 0usize;
        let mut drained = false;
        let mut kv_peak_bytes = 0usize;
        let mut kv_preemptions = 0usize;
        let mut kv_requeues = 0usize;
        let mut spec_stats = SpecStats::default();
        let mut spec_fallbacks = 0usize;
        let mut next_seq_no = 0u64;

        let mut active: Vec<ActiveGen> = Vec::new();
        let mut caches: Vec<KvCache> = Vec::new();
        // Recycled cache husks (rings, or paged caches holding no pages).
        // Retention is capped at `max_active`: the loop never decodes more
        // sequences at once, so a burst of departures must not pin a
        // burst's worth of rings forever.
        let mut pool: Vec<KvCache> = Vec::new();
        // Admitted generation prompts awaiting an in-flight slot (and, in
        // paged mode, enough free pages). `requeued` marks a preemption
        // requeue (counted once when it re-enters flight); `turn` carries
        // a checked-out session turn's state and cache alongside.
        let mut waiting: VecDeque<PendingGen> = VecDeque::new();
        let mut step_tokens: Vec<u16> = Vec::with_capacity(max_active);
        let mut step_hash: Vec<u64> = Vec::with_capacity(max_active);
        let mut step_out: Vec<u16> = Vec::with_capacity(max_active);
        let mut admit: Vec<Work> = Vec::with_capacity(max_active);
        // set once try_fill observes every sender gone: the queue can
        // never produce work again, so the loop ends when `active` drains
        let mut queue_closed = false;

        let t0 = Instant::now();
        loop {
            if self.stop.load(Ordering::SeqCst) {
                // ---- graceful drain: stop admission, answer the queue,
                // keep decoding what is already in flight ----------------
                drained = true;
                while let Ok(w) = self.rx.try_recv() {
                    requests += 1;
                    rejected_shutdown += 1;
                    match w {
                        Work::Score(r) => {
                            latency.record(Instant::now() - r.submitted);
                            deliver(
                                &mut fi,
                                &mut faulted,
                                &r.respond,
                                Err(ServeError::ShuttingDown),
                            );
                        }
                        Work::Generate(g) => {
                            latency.record(Instant::now() - g.submitted);
                            deliver_gen(
                                &mut fi,
                                &mut faulted,
                                &g.respond,
                                Err(ServeError::ShuttingDown),
                            );
                        }
                        Work::Turn(t) => {
                            // never checked out: the session stays idle
                            latency.record(Instant::now() - t.submitted);
                            deliver_gen(
                                &mut fi,
                                &mut faulted,
                                &GenRespond::Stream(t.respond),
                                Err(ServeError::ShuttingDown),
                            );
                        }
                        Work::Session(c) => {
                            latency.record(Instant::now() - c.submitted);
                            deliver(
                                &mut fi,
                                &mut faulted,
                                &c.respond,
                                Err(ServeError::ShuttingDown),
                            );
                        }
                    }
                }
                // admitted-but-not-started prompts are not in flight:
                // answer them too (already counted in `requests`)
                for p in waiting.drain(..) {
                    rejected_shutdown += 1;
                    latency.record(Instant::now() - p.g.submitted);
                    if let Some((t, cache)) = p.turn {
                        mgr.abort(&t.id, cache);
                    }
                    deliver_gen(
                        &mut fi,
                        &mut faulted,
                        &p.g.respond,
                        Err(ServeError::ShuttingDown),
                    );
                }
                if active.is_empty() {
                    break;
                }
            } else {
                // ---- admission: block when idle, join mid-flight when
                // busy ---------------------------------------------------
                admit.clear();
                if active.is_empty() && waiting.is_empty() {
                    if queue_closed {
                        break;
                    }
                    // session traffic wakes the loop immediately: a turn's
                    // first token should not wait out the batching window
                    let urgent = |w: &Work| matches!(w, Work::Turn(_) | Work::Session(_));
                    match next_batch_watching_urgent(&self.rx, policy, &self.stop, urgent) {
                        Wakeup::Batch(work) => {
                            batches += 1;
                            admit.extend(work);
                        }
                        Wakeup::Shutdown => continue, // drain branch takes over
                        Wakeup::Closed => break,
                    }
                } else if active.len() + waiting.len() < max_active {
                    let fill =
                        try_fill(&self.rx, &mut admit, max_active - active.len() - waiting.len());
                    queue_closed |= fill.disconnected;
                    if fill.taken > 0 {
                        batches += 1;
                    }
                }
                for work in admit.drain(..) {
                    match work {
                        Work::Score(r) => {
                            requests += 1;
                            // Validate before decoding: an out-of-range
                            // token id would panic inside the embedding
                            // lookup; with the guard that is survivable but
                            // it should still be an Invalid, not a Faulted.
                            let result = if let Err(msg) = fire(&mut fi, FaultSite::Admission)
                            {
                                Err(ServeError::Faulted(msg))
                            } else if expired(r.deadline) {
                                expired_admission += 1;
                                Err(ServeError::DeadlineExceeded { partial: Vec::new() })
                            } else if r.window.len() < 2 {
                                Err(ServeError::Invalid(
                                    "window needs at least 2 tokens for scoring".into(),
                                ))
                            } else if let Some(&bad) =
                                r.window.iter().find(|&&t| t as usize >= vocab)
                            {
                                Err(ServeError::Invalid(format!(
                                    "token id {bad} out of range (vocab size {vocab})"
                                )))
                            } else {
                                guard(|| model.score_nll(&r.window, &mut scratch))
                                    .map_err(ServeError::Faulted)
                            };
                            latency.record(Instant::now() - r.submitted);
                            deliver(&mut fi, &mut faulted, &r.respond, result);
                        }
                        Work::Generate(g) => {
                            requests += 1;
                            if let Err(msg) = fire(&mut fi, FaultSite::Admission) {
                                latency.record(Instant::now() - g.submitted);
                                deliver_gen(
                                    &mut fi,
                                    &mut faulted,
                                    &g.respond,
                                    Err(ServeError::Faulted(msg)),
                                );
                                continue;
                            }
                            if expired(g.deadline) {
                                expired_admission += 1;
                                latency.record(Instant::now() - g.submitted);
                                deliver_gen(
                                    &mut fi,
                                    &mut faulted,
                                    &g.respond,
                                    Err(ServeError::DeadlineExceeded { partial: Vec::new() }),
                                );
                                continue;
                            }
                            if let Err(e) = validate_gen(&g.prompt, g.max_new, max_seq, vocab)
                            {
                                latency.record(Instant::now() - g.submitted);
                                deliver_gen(&mut fi, &mut faulted, &g.respond, Err(e));
                                continue;
                            }
                            // admission checks passed: queue for the start
                            // phase below (which additionally gates on free
                            // pool pages in paged mode)
                            waiting.push_back(PendingGen { g, requeued: false, turn: None });
                        }
                        Work::Session(c) => {
                            // control-plane ops run inline at admission:
                            // they touch only the manager's books (and the
                            // page pool for close/fork/revert), never the
                            // model, so they cannot stall a decode step
                            requests += 1;
                            let result = if let Err(msg) = fire(&mut fi, FaultSite::Admission)
                            {
                                Err(ServeError::Faulted(msg))
                            } else {
                                match c.op {
                                    SessionOp::Open => mgr.open(&c.id).map(|_| Vec::new()),
                                    SessionOp::Close => {
                                        mgr.close(&c.id, page_pool.as_mut()).map(|_| Vec::new())
                                    }
                                    SessionOp::Fork { dst } => mgr
                                        .fork(&c.id, &dst, page_pool.as_mut())
                                        .map(|_| Vec::new())
                                        .inspect(|_| mgr.enforce_cap(page_pool.as_mut())),
                                    SessionOp::Revert { to_len } => {
                                        mgr.revert(&c.id, to_len, page_pool.as_mut())
                                    }
                                    SessionOp::Tokens => mgr.tokens(&c.id),
                                }
                            };
                            latency.record(Instant::now() - c.submitted);
                            deliver(&mut fi, &mut faulted, &c.respond, result);
                        }
                        Work::Turn(t) => {
                            requests += 1;
                            let respond = GenRespond::Stream(t.respond);
                            if let Err(msg) = fire(&mut fi, FaultSite::Admission) {
                                latency.record(Instant::now() - t.submitted);
                                deliver_gen(
                                    &mut fi,
                                    &mut faulted,
                                    &respond,
                                    Err(ServeError::Faulted(msg)),
                                );
                                continue;
                            }
                            if expired(t.deadline) {
                                expired_admission += 1;
                                latency.record(Instant::now() - t.submitted);
                                deliver_gen(
                                    &mut fi,
                                    &mut faulted,
                                    &respond,
                                    Err(ServeError::DeadlineExceeded { partial: Vec::new() }),
                                );
                                continue;
                            }
                            // checkout marks the session busy (one turn in
                            // flight per session) and hands us its resident
                            // cache, if the LRU still holds one
                            let co = match mgr.checkout(&t.session) {
                                Ok(co) => co,
                                Err(e) => {
                                    latency.record(Instant::now() - t.submitted);
                                    deliver_gen(&mut fi, &mut faulted, &respond, Err(e));
                                    continue;
                                }
                            };
                            if t.delta.is_empty() {
                                mgr.abort(&t.session, co.cache);
                                latency.record(Instant::now() - t.submitted);
                                deliver_gen(
                                    &mut fi,
                                    &mut faulted,
                                    &respond,
                                    Err(ServeError::Invalid(
                                        "turn delta needs at least 1 token".into(),
                                    )),
                                );
                                continue;
                            }
                            let committed = co.tokens.len();
                            let mut full = co.tokens;
                            full.extend_from_slice(&t.delta);
                            if let Err(e) = validate_gen(&full, t.max_new, max_seq, vocab) {
                                mgr.abort(&t.session, co.cache);
                                latency.record(Instant::now() - t.submitted);
                                deliver_gen(&mut fi, &mut faulted, &respond, Err(e));
                                continue;
                            }
                            waiting.push_back(PendingGen {
                                g: GenRequest {
                                    prompt: full,
                                    max_new: t.max_new,
                                    submitted: t.submitted,
                                    deadline: t.deadline,
                                    respond,
                                },
                                requeued: false,
                                turn: Some((
                                    TurnState {
                                        id: t.session,
                                        committed,
                                        streamed: 0,
                                    },
                                    co.cache,
                                )),
                            });
                        }
                    }
                }

                // ---- start phase: move waiting prompts into flight while
                // slots and (paged) free pages allow ----------------------
                while active.len() < max_active {
                    let Some(front) = waiting.front() else { break };
                    if expired(front.g.deadline) {
                        let p = waiting.pop_front().expect("front checked");
                        expired_admission += 1;
                        latency.record(Instant::now() - p.g.submitted);
                        if let Some((t, cache)) = p.turn {
                            mgr.abort(&t.id, cache);
                        }
                        deliver_gen(
                            &mut fi,
                            &mut faulted,
                            &p.g.respond,
                            Err(ServeError::DeadlineExceeded { partial: Vec::new() }),
                        );
                        continue;
                    }
                    if let Some(pp) = page_pool.as_ref() {
                        // a turn with a resident cache only prefills its
                        // delta — only the delta's positions need pages
                        let held = front
                            .turn
                            .as_ref()
                            .and_then(|(_, c)| c.as_ref())
                            .map_or(0, KvCache::len);
                        if !pp.can_reserve(front.g.prompt.len() - held) {
                            if active.is_empty() {
                                // nothing in flight will ever release pages
                                // (resident is 0, so free == total − leaked):
                                // this prompt can *never* fit — answer it
                                // rather than livelock
                                let p = waiting.pop_front().expect("front checked");
                                latency.record(Instant::now() - p.g.submitted);
                                if let Some((t, cache)) = p.turn {
                                    mgr.abort(&t.id, cache);
                                }
                                deliver_gen(
                                    &mut fi,
                                    &mut faulted,
                                    &p.g.respond,
                                    Err(ServeError::Faulted(format!(
                                        "kv page pool cannot fit a {}-token prompt \
                                         ({} of {} pages leaked by quarantine)",
                                        p.g.prompt.len(),
                                        pp.leaked_pages(),
                                        pp.total_pages()
                                    ))),
                                );
                                continue;
                            }
                            // in-flight completions will release pages —
                            // retry next loop turn
                            break;
                        }
                    }
                    let PendingGen { g, requeued, mut turn } = waiting.pop_front().expect("front checked");
                    if requeued {
                        kv_requeues += 1;
                    } else {
                        gen_requests += 1;
                    }
                    // A fresh (never-requeued) turn on a session with
                    // committed history but no resident cache means the LRU
                    // evicted it: this prefill transparently restores it.
                    if let Some((t, cache)) = turn.as_ref() {
                        if !requeued && t.committed > 0 && cache.is_none() {
                            session_restores += 1;
                        }
                    }
                    let mut cache = match turn.as_mut().and_then(|(_, c)| c.take()) {
                        // a session's resident cache arrives mid-sequence:
                        // keep its committed positions, prefill the delta
                        Some(c) => c,
                        None => {
                            let mut c = match pool.pop() {
                                Some(c) => c,
                                None => match page_pool.as_ref() {
                                    Some(pp) => pp.new_cache(),
                                    None => match kv_quant {
                                        Some(fmt) => model.kv_cache_quantized(fmt),
                                        None => model.kv_cache(),
                                    },
                                },
                            };
                            c.reset();
                            c
                        }
                    };
                    if let Some(pp) = page_pool.as_mut() {
                        let reserved = pp.reserve(&mut cache, g.prompt.len() - cache.len());
                        debug_assert!(reserved, "start phase verified page availability");
                        let _ = reserved;
                    }
                    // Guarded delta prefill: the fault site fires inside
                    // the guard, and the probe adds abort points between
                    // chunks so an expiring prompt stops without burning
                    // the rest of its prefill. `Ok(None)` = deadline
                    // expired mid-prefill. Chunked prefill is
                    // split-invariant, so prefilling only the suffix past
                    // `cache.len()` is bit-identical to a fresh prefill of
                    // the whole prompt.
                    let dl = g.deadline;
                    let start_len = cache.len();
                    let h0 = seed_hash(sampling.seed, &g.prompt);
                    let outcome = guard(|| {
                        if let Some(f) = fi.as_mut() {
                            f.fire(FaultSite::Prefill);
                        }
                        let mut probe = |_done: usize| dl.map_or(true, |d| Instant::now() < d);
                        let logits = match model.prefill_delta(
                            &g.prompt,
                            &mut cache,
                            &mut scratch,
                            PREFILL_CHUNK,
                            &mut probe,
                        ) {
                            Some(m) => m,
                            None => return None,
                        };
                        Some(sample_token(&sampling, logits.row(logits.rows - 1), h0))
                    });
                    match outcome {
                        Err(msg) => {
                            // the walk may have unwound mid-layer: poison
                            // the cache and drop it on the floor, never
                            // back into the pool — a paged cache leaks
                            // exactly its own pages. A turn's session
                            // survives with its transcript intact (the next
                            // touch re-prefills from scratch).
                            cache.quarantine();
                            quarantined_caches += 1;
                            if let Some(pp) = page_pool.as_mut() {
                                pp.release(&mut cache);
                            }
                            if let Some((t, _)) = turn.take() {
                                mgr.abort(&t.id, None);
                            }
                            latency.record(Instant::now() - g.submitted);
                            deliver_gen(
                                &mut fi,
                                &mut faulted,
                                &g.respond,
                                Err(ServeError::Faulted(msg)),
                            );
                        }
                        Ok(None) => {
                            expired_midflight += 1;
                            match turn.take() {
                                Some((t, _)) => {
                                    // aborted cleanly mid-delta: rewind to
                                    // the committed history and hand the
                                    // cache back to the session
                                    let keep = cache.len().min(t.committed);
                                    match page_pool.as_mut() {
                                        Some(pp) => pp.truncate(&mut cache, keep),
                                        None => cache.truncate(keep),
                                    }
                                    mgr.abort(&t.id, Some(cache));
                                }
                                None => {
                                    // pages back, husk recyclable
                                    if let Some(pp) = page_pool.as_mut() {
                                        pp.release(&mut cache);
                                    }
                                    if pool.len() < max_active {
                                        pool.push(cache);
                                    }
                                }
                            }
                            latency.record(Instant::now() - g.submitted);
                            deliver_gen(
                                &mut fi,
                                &mut faulted,
                                &g.respond,
                                Err(ServeError::DeadlineExceeded { partial: Vec::new() }),
                            );
                        }
                        Ok(Some(first)) => {
                            prefill_tokens += g.prompt.len() - start_len;
                            let mut generated = Vec::with_capacity(g.max_new);
                            generated.push(first);
                            if let Some((t, _)) = turn.as_mut() {
                                if t.streamed == 0 {
                                    if g.respond.stream_token(first) {
                                        streamed_tokens += 1;
                                    }
                                    t.streamed = 1;
                                }
                            }
                            if g.max_new == 1 {
                                latency.record(Instant::now() - g.submitted);
                                match turn.take() {
                                    Some((t, _)) => {
                                        // commit: transcript grows by delta
                                        // + generated; the cache (holding
                                        // everything but the last sampled
                                        // token) stays resident for the
                                        // next turn's delta prefill
                                        let mut hist = g.prompt.clone();
                                        hist.extend_from_slice(&generated);
                                        mgr.commit(&t.id, hist, cache, page_pool.as_mut());
                                    }
                                    None => {
                                        if let Some(pp) = page_pool.as_mut() {
                                            pp.release(&mut cache);
                                        }
                                        if pool.len() < max_active {
                                            pool.push(cache);
                                        }
                                    }
                                }
                                deliver_gen(
                                    &mut fi,
                                    &mut faulted,
                                    &g.respond,
                                    Ok(Generated {
                                        tokens: generated,
                                        prompt_len: g.prompt.len(),
                                        decode_tok_s: 0.0,
                                    }),
                                );
                            } else {
                                // Speculation: mint this sequence's draft
                                // cache and prefill the prompt into it under
                                // the draft-site guard. Failure is never
                                // fatal — the sequence just decodes
                                // target-only (same tokens, no draft rate),
                                // and a dry paged pool skips the draft cache
                                // the same way. Session turns never mint
                                // spec state: a verify pass can overshoot by
                                // the bonus token, which would break the
                                // session cache's strict-prefix invariant.
                                let spec = if turn.is_some() {
                                    None
                                } else if let Some((dm, dk)) = draft.as_ref() {
                                    let ds = draft_scratch
                                        .as_mut()
                                        .expect("draft scratch exists with the draft plan");
                                    let mut dcache = match pool.pop() {
                                        Some(c) => c,
                                        None => match page_pool.as_ref() {
                                            Some(pp) => pp.new_cache(),
                                            None => match kv_quant {
                                                Some(fmt) => model.kv_cache_quantized(fmt),
                                                None => model.kv_cache(),
                                            },
                                        },
                                    };
                                    dcache.reset();
                                    let reserved = match page_pool.as_mut() {
                                        Some(pp) => pp.reserve(&mut dcache, g.prompt.len()),
                                        None => true,
                                    };
                                    if !reserved {
                                        if pool.len() < max_active {
                                            pool.push(dcache);
                                        }
                                        spec_fallbacks += 1;
                                        None
                                    } else {
                                        let ok = guard(|| {
                                            if let Some(f) = fi.as_mut() {
                                                f.fire(FaultSite::Draft);
                                            }
                                            let _ = dm.prefill(&g.prompt, &mut dcache, &mut *ds);
                                        });
                                        match ok {
                                            Ok(()) => Some(SpecState {
                                                cache: dcache,
                                                seq: SpecSequence::start(first),
                                                window: AdaptiveK::new(*dk),
                                            }),
                                            Err(_) => {
                                                dcache.quarantine();
                                                quarantined_caches += 1;
                                                if let Some(pp) = page_pool.as_mut() {
                                                    pp.release(&mut dcache);
                                                }
                                                spec_fallbacks += 1;
                                                None
                                            }
                                        }
                                    }
                                } else {
                                    None
                                };
                                active.push(ActiveGen {
                                    generated,
                                    max_new: g.max_new,
                                    prompt: g.prompt,
                                    submitted: g.submitted,
                                    deadline: g.deadline,
                                    decode_start: Instant::now(),
                                    seq_no: next_seq_no,
                                    respond: g.respond,
                                    spec,
                                    turn: turn.take().map(|(t, _)| t),
                                    hash: extend_hash(h0, first),
                                });
                                next_seq_no += 1;
                                caches.push(cache);
                            }
                        }
                    }
                }
            }
            // ---- KV accounting high-water mark (in-flight growth happens
            // only in the start phase above and the per-step reserve below,
            // which tracks the paged peak inside the pool) ----------------
            match page_pool.as_ref() {
                Some(pp) => kv_peak_bytes = kv_peak_bytes.max(pp.resident_bytes()),
                None => {
                    // draft rings pin the same bytes as target rings, and
                    // idle sessions' resident rings pin theirs too
                    let spec_rings = active.iter().filter(|a| a.spec.is_some()).count();
                    kv_peak_bytes = kv_peak_bytes
                        .max((caches.len() + spec_rings + mgr.resident_caches()) * ring_bytes);
                }
            }
            if active.is_empty() {
                continue;
            }

            // ---- deadline sweep: shed expired sequences before spending
            // a step on them; their caches are healthy, so recycle -------
            let mut i = 0;
            while i < active.len() {
                if expired(active[i].deadline) {
                    let mut done = active.swap_remove(i);
                    let mut cache = caches.swap_remove(i);
                    match done.turn.take() {
                        Some(t) => {
                            // rewind to the committed history so the session
                            // cache stays a strict prefix of its transcript
                            let keep = cache.len().min(t.committed);
                            match page_pool.as_mut() {
                                Some(pp) => pp.truncate(&mut cache, keep),
                                None => cache.truncate(keep),
                            }
                            mgr.abort(&t.id, Some(cache));
                        }
                        None => {
                            if let Some(pp) = page_pool.as_mut() {
                                pp.release(&mut cache);
                            }
                            if pool.len() < max_active {
                                pool.push(cache);
                            }
                        }
                    }
                    if let Some(mut sp) = done.spec.take() {
                        if let Some(pp) = page_pool.as_mut() {
                            pp.release(&mut sp.cache);
                        }
                        if pool.len() < max_active {
                            pool.push(sp.cache);
                        }
                    }
                    expired_midflight += 1;
                    latency.record(Instant::now() - done.submitted);
                    deliver(
                        &mut fi,
                        &mut faulted,
                        &done.respond,
                        Err(ServeError::DeadlineExceeded { partial: done.generated }),
                    );
                } else {
                    i += 1;
                }
            }
            if active.is_empty() {
                continue;
            }

            // ---- paged mode: every sequence needs a reserved position for
            // the token this step appends. If the pool runs dry, preempt
            // the *youngest* sequence (largest seq_no): release its pages
            // and requeue it at the front of `waiting` for re-prefill —
            // greedy decode is deterministic, so the re-served request
            // regenerates the identical tokens. Terminates because each
            // evicted sequence frees at least one page. ------------------
            if page_pool.is_some() {
                let mut i = 0;
                while i < caches.len() {
                    let pp = page_pool.as_mut().expect("paged mode checked");
                    if caches[i].remaining() == 0 && !pp.reserve(&mut caches[i], 1) {
                        let y = active
                            .iter()
                            .enumerate()
                            .max_by_key(|(_, a)| a.seq_no)
                            .map(|(j, _)| j)
                            .expect("active is non-empty");
                        let mut done = active.swap_remove(y);
                        let mut cache = caches.swap_remove(y);
                        pp.release(&mut cache);
                        if pool.len() < max_active {
                            pool.push(cache);
                        }
                        // the draft cache restarts from scratch with the
                        // requeued prompt — its pages go back too
                        if let Some(mut sp) = done.spec.take() {
                            pp.release(&mut sp.cache);
                            if pool.len() < max_active {
                                pool.push(sp.cache);
                            }
                        }
                        kv_preemptions += 1;
                        // a preempted turn keeps its TurnState (streamed
                        // count suppresses re-streaming after the
                        // bit-identical replay) but restarts from an empty
                        // cache; the session stays busy throughout
                        waiting.push_front(PendingGen {
                            g: GenRequest {
                                prompt: done.prompt,
                                max_new: done.max_new,
                                submitted: done.submitted,
                                deadline: done.deadline,
                                respond: done.respond,
                            },
                            requeued: true,
                            turn: done.turn.take().map(|t| (t, None)),
                        });
                        i = 0; // indices shifted; rescan from the top
                        continue;
                    }
                    i += 1;
                }
                if let Some(pp) = page_pool.as_ref() {
                    kv_peak_bytes = kv_peak_bytes.max(pp.resident_bytes());
                }
                if active.is_empty() {
                    continue;
                }
            }

            let ts = Instant::now();
            if let Some((dm, _)) = draft.as_ref() {
                // ---- speculative decode: one draft/verify round per
                // in-flight sequence. The draft phase runs under its own
                // guard and fault site: a draft panic poisons only that
                // sequence's draft cache — quarantine it, permanently
                // downgrade the sequence to target-only decode, and its
                // token stream is unchanged (exact greedy parity means
                // the draft can only change speed, never content). The
                // verify phase touches the target cache and carries the
                // same site/quarantine contract as a plain decode step.
                let ds = draft_scratch
                    .as_mut()
                    .expect("draft scratch exists with the draft plan");
                let mut i = 0;
                while i < active.len() {
                    let remaining = active[i].max_new - active[i].generated.len();
                    let mut proposal: Option<Vec<u16>> = None;
                    if active[i].spec.is_some() {
                        // clamp the window so the verify chunk stays
                        // inside max_seq: committed + remaining ==
                        // prompt + max_new <= max_seq (validate_gen)
                        let kr = {
                            let sp = active[i].spec.as_ref().expect("checked above");
                            sp.window.current().min(remaining)
                        };
                        // paged: the whole round's appends are reserved up
                        // front; a dry pool falls back to a plain step
                        // this turn — speculation is opportunistic, and
                        // the pending chunk catches the draft cache up
                        // next round
                        let reserved = match page_pool.as_mut() {
                            Some(pp) => {
                                let sp = active[i].spec.as_mut().expect("checked above");
                                pp.reserve(&mut caches[i], sp.seq.verify_positions(kr))
                                    && pp.reserve(&mut sp.cache, sp.seq.draft_positions(kr))
                            }
                            None => true,
                        };
                        if reserved {
                            let sp = active[i].spec.as_mut().expect("checked above");
                            let drafted = guard(|| {
                                if let Some(f) = fi.as_mut() {
                                    f.fire(FaultSite::Draft);
                                }
                                draft_propose(dm, &mut sp.cache, &sp.seq, kr, &mut *ds)
                            });
                            match drafted {
                                Ok(d) => proposal = Some(d),
                                Err(_) => {
                                    let mut sp =
                                        active[i].spec.take().expect("checked above");
                                    sp.cache.quarantine();
                                    quarantined_caches += 1;
                                    if let Some(pp) = page_pool.as_mut() {
                                        pp.release(&mut sp.cache); // leaks its pages
                                    }
                                    spec_fallbacks += 1;
                                }
                            }
                        }
                    }
                    match proposal {
                        Some(drafts) => {
                            let out = {
                                let sp = active[i].spec.as_mut().expect("proposal has spec");
                                guard(|| {
                                    if let Some(f) = fi.as_mut() {
                                        f.fire(FaultSite::Decode);
                                    }
                                    verify_commit(
                                        &model,
                                        &mut caches[i],
                                        &mut sp.cache,
                                        page_pool.as_mut(),
                                        &mut sp.seq,
                                        &drafts,
                                        &mut scratch,
                                    )
                                })
                            };
                            decode_steps += 1;
                            match out {
                                Ok(out) => {
                                    {
                                        let sp =
                                            active[i].spec.as_mut().expect("proposal has spec");
                                        sp.window.observe(out.drafted, out.agreed);
                                    }
                                    spec_stats.record(&out);
                                    // a fully accepted last round overshoots
                                    // max_new by the bonus token — clamp
                                    let take = out.committed.len().min(remaining);
                                    decode_tokens += take;
                                    active[i].generated.extend_from_slice(&out.committed[..take]);
                                    i += 1;
                                }
                                Err(msg) => {
                                    // the verify pass may have unwound with
                                    // either cache mid-mutation: quarantine
                                    // both, answer Faulted
                                    let mut done = active.swap_remove(i);
                                    let mut cache = caches.swap_remove(i);
                                    cache.quarantine();
                                    quarantined_caches += 1;
                                    if let Some(pp) = page_pool.as_mut() {
                                        pp.release(&mut cache); // leaks its pages
                                    }
                                    drop(cache); // poisoned: never recycled
                                    if let Some(mut sp) = done.spec.take() {
                                        sp.cache.quarantine();
                                        quarantined_caches += 1;
                                        if let Some(pp) = page_pool.as_mut() {
                                            pp.release(&mut sp.cache);
                                        }
                                    }
                                    if let Some(t) = done.turn.take() {
                                        mgr.abort(&t.id, None);
                                    }
                                    latency.record(Instant::now() - done.submitted);
                                    deliver_gen(
                                        &mut fi,
                                        &mut faulted,
                                        &done.respond,
                                        Err(ServeError::Faulted(msg)),
                                    );
                                }
                            }
                        }
                        None => {
                            // plain guarded target step: a downgraded
                            // sequence, a draft fault this turn, or a dry
                            // paged pool
                            let tok =
                                *active[i].generated.last().expect("active seq has a token");
                            let solo = guard(|| {
                                if let Some(f) = fi.as_mut() {
                                    f.fire(FaultSite::Decode);
                                }
                                let row = model.decode_step(tok, &mut caches[i], &mut scratch);
                                argmax(row.row(0)) as u16
                            });
                            decode_steps += 1;
                            match solo {
                                Ok(next) => {
                                    decode_tokens += 1;
                                    let a = &mut active[i];
                                    a.generated.push(next);
                                    if let Some(sp) = a.spec.as_mut() {
                                        // the draft cache did not see this
                                        // token: it joins the catch-up chunk
                                        sp.seq.append_committed(next);
                                    }
                                    i += 1;
                                }
                                Err(msg) => {
                                    let mut done = active.swap_remove(i);
                                    let mut cache = caches.swap_remove(i);
                                    cache.quarantine();
                                    quarantined_caches += 1;
                                    if let Some(pp) = page_pool.as_mut() {
                                        pp.release(&mut cache); // leaks its pages
                                    }
                                    drop(cache); // poisoned: never recycled
                                    if let Some(mut sp) = done.spec.take() {
                                        // the draft cache was not involved
                                        // in the faulted step: healthy,
                                        // pages and husk are recyclable
                                        if let Some(pp) = page_pool.as_mut() {
                                            pp.release(&mut sp.cache);
                                        }
                                        if pool.len() < max_active {
                                            pool.push(sp.cache);
                                        }
                                    }
                                    if let Some(t) = done.turn.take() {
                                        mgr.abort(&t.id, None);
                                    }
                                    latency.record(Instant::now() - done.submitted);
                                    deliver_gen(
                                        &mut fi,
                                        &mut faulted,
                                        &done.respond,
                                        Err(ServeError::Faulted(msg)),
                                    );
                                }
                            }
                        }
                    }
                }
            } else {
                // ---- one interleaved decode step for every in-flight seq
                step_tokens.clear();
                step_hash.clear();
                for a in &active {
                    step_tokens.push(*a.generated.last().expect("active seq has a token"));
                    step_hash.push(a.hash);
                }
                // The whole batched step runs under the guard. A panic
                // unwinds *before* any KV cursor commits (the layer walk
                // advances caches only at its end), so retrying each
                // sequence solo below replays the exact same step —
                // bit-identical for the survivors — and pins the fault on
                // the poisoned sequence(s) alone.
                let stepped = guard(|| {
                    if let Some(f) = fi.as_mut() {
                        f.fire(FaultSite::Decode);
                    }
                    let logits = model.decode_step_batch(&step_tokens, &mut caches, &mut scratch);
                    // sample by original row index — swap_remove in the
                    // completion sweep reorders `active`, the logits rows
                    // do not move with it. Each row samples under its own
                    // prefix hash, so the drawn token is independent of
                    // the batch composition around it.
                    step_out.clear();
                    for row in 0..step_tokens.len() {
                        step_out.push(sample_token(&sampling, logits.row(row), step_hash[row]));
                    }
                });
                decode_steps += 1;
                match stepped {
                    Ok(()) => {
                        decode_tokens += active.len();
                        for (a, &tok) in active.iter_mut().zip(step_out.iter()) {
                            a.generated.push(tok);
                            a.hash = extend_hash(a.hash, tok);
                        }
                    }
                    Err(_) => {
                        // solo retry: find the poisoned sequence(s), answer
                        // them Faulted with quarantined caches, keep everyone
                        // else moving
                        let mut i = 0;
                        while i < active.len() {
                            let tok =
                                *active[i].generated.last().expect("active seq has a token");
                            let h = active[i].hash;
                            let solo = guard(|| {
                                if let Some(f) = fi.as_mut() {
                                    f.fire(FaultSite::Decode);
                                }
                                let row = model.decode_step(tok, &mut caches[i], &mut scratch);
                                sample_token(&sampling, row.row(0), h)
                            });
                            match solo {
                                Ok(next) => {
                                    decode_tokens += 1;
                                    active[i].generated.push(next);
                                    active[i].hash = extend_hash(h, next);
                                    i += 1;
                                }
                                Err(msg) => {
                                    let mut done = active.swap_remove(i);
                                    let mut cache = caches.swap_remove(i);
                                    cache.quarantine();
                                    quarantined_caches += 1;
                                    if let Some(pp) = page_pool.as_mut() {
                                        pp.release(&mut cache); // leaks its pages
                                    }
                                    drop(cache); // poisoned: never recycled
                                    if let Some(t) = done.turn.take() {
                                        mgr.abort(&t.id, None);
                                    }
                                    latency.record(Instant::now() - done.submitted);
                                    deliver_gen(
                                        &mut fi,
                                        &mut faulted,
                                        &done.respond,
                                        Err(ServeError::Faulted(msg)),
                                    );
                                }
                            }
                        }
                    }
                }
            }
            decode_wall += ts.elapsed();
            // ---- stream sweep: every turn's unstreamed tokens go out the
            // moment the step that produced them lands — the client sees
            // token-by-token progress, not one burst at completion -------
            for a in active.iter_mut() {
                let ActiveGen { respond, generated, turn, .. } = a;
                if let Some(t) = turn.as_mut() {
                    while t.streamed < generated.len() {
                        if respond.stream_token(generated[t.streamed]) {
                            streamed_tokens += 1;
                        }
                        t.streamed += 1;
                    }
                }
            }
            let mut i = 0;
            while i < active.len() {
                if active[i].generated.len() >= active[i].max_new {
                    let mut done = active.swap_remove(i);
                    let mut cache = caches.swap_remove(i);
                    if let Some(mut sp) = done.spec.take() {
                        if let Some(pp) = page_pool.as_mut() {
                            pp.release(&mut sp.cache);
                        }
                        if pool.len() < max_active {
                            pool.push(sp.cache);
                        }
                    }
                    let now = Instant::now();
                    let steps = done.generated.len() - 1;
                    let rate =
                        steps as f64 / (now - done.decode_start).as_secs_f64().max(1e-9);
                    request_tok_s.record(rate);
                    latency.record(now - done.submitted);
                    match done.turn.take() {
                        Some(t) => {
                            // commit: the cache holds prompt + generated
                            // minus the final sampled token — a strict
                            // prefix of the new transcript, so the next
                            // turn's delta prefill is never empty
                            let mut hist = done.prompt.clone();
                            hist.extend_from_slice(&done.generated);
                            mgr.commit(&t.id, hist, cache, page_pool.as_mut());
                        }
                        None => {
                            if let Some(pp) = page_pool.as_mut() {
                                pp.release(&mut cache); // pages back to the free list
                            }
                            if pool.len() < max_active {
                                pool.push(cache); // recycle the husk for the next join
                            }
                        }
                    }
                    deliver_gen(
                        &mut fi,
                        &mut faulted,
                        &done.respond,
                        Ok(Generated {
                            tokens: done.generated,
                            prompt_len: done.prompt.len(),
                            decode_tok_s: rate,
                        }),
                    );
                } else {
                    i += 1;
                }
            }
        }
        Ok(ServeReport {
            requests,
            batches,
            wall: t0.elapsed(),
            latency,
            mean_batch_size: requests as f64 / batches.max(1) as f64,
            gen_requests,
            prefill_tokens,
            decode_tokens,
            decode_steps,
            decode_wall,
            request_tok_s,
            shed_overloaded: self.shed.load(Ordering::SeqCst),
            expired_admission,
            expired_midflight,
            faulted,
            quarantined_caches,
            rejected_shutdown,
            drained,
            kv_resident_bytes: match page_pool.as_ref() {
                Some(pp) => pp.resident_bytes(),
                None => (caches.len() + mgr.resident_caches()) * ring_bytes,
            },
            kv_peak_bytes,
            kv_pool_bytes: match page_pool.as_ref() {
                Some(pp) => pp.total_bytes(),
                None => (pool.len() + caches.len() + mgr.resident_caches()) * ring_bytes,
            },
            spec_rounds: spec_stats.rounds,
            spec_drafted: spec_stats.drafted,
            spec_accepted: spec_stats.accepted,
            spec_rolled_back: spec_stats.rolled_back,
            spec_fallbacks,
            kv_pages_total: page_pool.as_ref().map_or(0, KvPagePool::total_pages),
            kv_pages_free: page_pool.as_ref().map_or(0, KvPagePool::free_pages),
            kv_pages_resident: page_pool.as_ref().map_or(0, KvPagePool::resident_pages),
            kv_pages_peak: page_pool.as_ref().map_or(0, KvPagePool::peak_resident_pages),
            kv_pages_leaked: page_pool.as_ref().map_or(0, KvPagePool::leaked_pages),
            kv_preemptions,
            kv_requeues,
            sessions_active: mgr.len(),
            sessions_evicted: mgr.evicted(),
            session_restores,
            streamed_tokens,
        })
    }
}

/// `zqfp serve` — load a checkpoint, quantize it under the recipe
/// (`--recipe <path|preset>` plus any overriding flags; default preset
/// `w4a8-fp`), build the [`ServingStack`], fire `--requests` requests
/// from `--clients` threads, and print the latency/throughput report (the
/// e2e serving validation of DESIGN.md §5). Scoring runs on PJRT when the
/// artifact exists, otherwise the compiled in-process engine. With
/// `--generate N` the workload is continuous-batching generation (N new
/// tokens per request, compiled backend) instead of window scoring;
/// `--kv-cache e4m3|e5m2` additionally stores the generation K/V caches
/// in that FP8 format. `--packed` serves from the bit-packed weight
/// layout (compiled backend; bit-identical logits, ~1/7 the resident
/// weight bytes for W4), composable with `--lorc [--lorc-rank N]
/// [--lorc-format fp8|f16]` — the low-rank compensation factors ride
/// along as codes and the GEMV folds them into each decoded row, so
/// W4A8+LoRC (the paper's best small-model recipe) serves at
/// packed-memory footprint. `--gemv-threads N` shards the packed GEMV
/// rows across N workers.
///
/// Robustness knobs: `--queue-depth N` bounds admission (overflow sheds
/// typed `Overloaded`), `--deadline-ms MS` gives every request a
/// deadline, and `--fault <site>:<spec>[,...]` (with `--fault-seed S`)
/// arms the deterministic fault injector for chaos runs.
pub fn serve_command(args: &Args) -> std::result::Result<(), String> {
    let ckpt = args.get("ckpt").ok_or("--ckpt required")?;
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let data = PathBuf::from(args.get_or("data", "data"));
    let n_requests = args.get_usize("requests", 256)?;
    let n_clients = args.get_usize("clients", 4)?;
    let gen_new = args.get_usize("generate", 0)?;
    // Multi-turn chat mode: each request becomes a session whose prompt
    // arrives split across `--turns` turns; every turn after the first
    // prefills only its delta against the session's resident KV cache.
    let turns = args.get_usize("turns", 1)?;
    let alpha = args.get_f32("alpha", 1.0)?;
    // Deterministic fault schedule (chaos harness — a run-time knob, not
    // part of the serving recipe).
    if args.flag("fault") && args.get("fault").is_none() {
        return Err("--fault needs a value: <site>:<spec>[,<site>:<spec>...]".into());
    }
    if args.flag("fault-seed") && args.get("fault-seed").is_none() {
        return Err("--fault-seed needs a value".into());
    }
    let fault_spec = args.get("fault");
    let fault_seed = args.get_usize("fault-seed", 0)? as u64;
    let faults = match &fault_spec {
        Some(spec) => Some(FaultPlan::parse(spec)?.with_seed(fault_seed)),
        None if args.flag("fault-seed") => {
            return Err("--fault-seed requires --fault".into());
        }
        None => None,
    };
    // One flag→recipe translation, shared with `zqfp quantize`/`eval`.
    // serve keeps the paper's headline W4A8 FP-FP as its default recipe.
    let recipe = QuantRecipe::from_args(args, "w4a8-fp")?;
    args.finish()?;
    let packed = !recipe.weights.is_dense();

    let ck = crate::cli::commands::load_ckpt_with_alpha(std::path::Path::new(&ckpt), alpha)?;
    let seq = ck.config.max_seq;
    if turns == 0 {
        return Err("--turns must be at least 1".into());
    }
    if turns > 1 {
        if gen_new == 0 {
            return Err("--turns requires --generate".into());
        }
        let prompt_len = seq.saturating_sub(gen_new);
        if turns > prompt_len || turns > gen_new {
            return Err(format!(
                "--turns {turns} exceeds the per-session budget \
                 ({prompt_len}-token prompts, {gen_new} new tokens)"
            ));
        }
    }
    if gen_new > 0 {
        // same admission rule the serving loop enforces (validate_gen),
        // applied to the workload shape serve generates below: prompts of
        // `seq - gen_new` tokens plus `gen_new` new ones
        let prompt = vec![0u16; seq.saturating_sub(gen_new)];
        validate_gen(&prompt, gen_new, seq, ck.config.vocab_size)
            .map_err(|e| format!("--generate {gen_new}: {e}"))?;
    }
    let calib = if recipe.needs_calibration() {
        crate::cli::commands::load_calib(&data, seq)?
    } else {
        Vec::new()
    };
    println!("quantizing under {} (recipe {}) ...", recipe.scheme.name(), recipe.name);
    let stack = ServingStack::build(&ck, &calib, &recipe).map_err(|e| e.to_string())?;
    drop(ck); // the stack owns everything the serving run needs
    println!(
        "  {} tensors, {:.2}x compression",
        stack.report.layers.len(),
        stack.report.compression()
    );

    let backend = if gen_new > 0 || packed || faults.is_some() {
        // generation / packed path: compiled plan only; chaos runs force
        // the compiled backend so every fault site is armed in-process
        ScoreBackend::Compiled
    } else {
        pick_backend(&artifacts, &stack.checkpoint, &recipe.engine_opts())
    };
    match &backend {
        ScoreBackend::Pjrt { .. } => println!("backend: pjrt ({})", artifacts.display()),
        ScoreBackend::Compiled => println!("backend: compiled in-process engine"),
    }
    if let Some(fmt) = recipe.kv_quant {
        println!("kv cache: {}", fmt.name());
    }
    if recipe.kernel_tier.is_fast() {
        println!(
            "kernels: fast tier (8-lane GEMV, {} pool workers; \
             tolerance-gated by tests/kernel_tolerance.rs)",
            recipe.weights.threads()
        );
    }
    if let Some(sc) = &recipe.speculate {
        println!(
            "speculative decode: draft recipe {} ({} layout, {} kernels) proposes \
             k={} tokens/round; output is exactly target-only greedy decode",
            sc.draft.name,
            if sc.draft.weights.is_dense() { "dense" } else { "packed" },
            sc.draft.kernel_tier.name(),
            sc.k,
        );
    }
    if !recipe.sampling.is_greedy() {
        println!(
            "sampling: temperature {} top-k {} top-p {} seed {} \
             (prefix-hash positional draws: reproducible and batch-invariant)",
            recipe.sampling.temperature,
            recipe.sampling.top_k,
            recipe.sampling.top_p,
            recipe.sampling.seed,
        );
    }
    println!(
        "admission: queue depth {}, deadline {}",
        recipe.queue_depth,
        if recipe.deadline_ms > 0 {
            format!("{} ms", recipe.deadline_ms)
        } else {
            "none".to_string()
        }
    );
    if recipe.kv_page_positions > 0 {
        println!(
            "kv paging: {}-position pages, budget {}",
            recipe.kv_page_positions,
            if recipe.kv_budget_bytes > 0 {
                format!("{} B", recipe.kv_budget_bytes)
            } else {
                "auto (ring-equivalent)".to_string()
            }
        );
    }
    if let Some(plan) = &faults {
        println!("fault injection: {}", plan.summary());
    }
    if packed {
        // Banner from the accounting already in hand — no extra compile or
        // pack pass (the serving loop builds the real packed plan once,
        // and `zqfp eval --packed` / the benches print the exact resident
        // bytes including scale/shift metadata).
        let report = &stack.report;
        let dense_b = 2 * report.fp16_bytes; // f32 plan = 2 × fp16 accounting
        println!(
            "weights: ~{} B packed (codes + f16-scale accounting) vs {} B f32 plan \
             (~{:.1}x smaller), {} gemv threads",
            report.quant_bytes,
            dense_b,
            dense_b as f64 / report.quant_bytes.max(1) as f64,
            recipe.weights.threads(),
        );
        if recipe.lorc.is_some() {
            let lorc_b: usize = report.layers.iter().map(|l| l.lorc_bytes).sum();
            // quant_bytes already includes the factors — subtract them so
            // the printed ratio is factors : codes, as labeled
            let code_b = report.quant_bytes.saturating_sub(lorc_b);
            println!(
                "  lorc: factors ride along packed ({} B, +{:.1}% on the packed code bytes)",
                lorc_b,
                100.0 * lorc_b as f64 / code_b.max(1) as f64
            );
        }
    }

    // workload: eval windows from the C4 surrogate
    let corpus = Corpus::new(CorpusKind::C4);
    let stream = corpus.generate(n_requests * seq, 7);
    let windows: Vec<Vec<u16>> = stream.chunks_exact(seq).map(|c| c.to_vec()).collect();
    let n_windows = windows.len();
    let max_batch = recipe.max_batch;

    let mut coord = stack.coordinator_with_backend(backend);
    if let Some(plan) = faults {
        coord.inject_faults(plan);
    }

    // Client threads tally typed degradations (Overloaded / Deadline-
    // Exceeded / Faulted / ShuttingDown) instead of aborting on them —
    // that is the point of the hardened loop. Invalid still aborts: it
    // means the workload itself is malformed.
    type Tally = std::result::Result<(f64, usize, usize), String>;
    let mut handles: Vec<std::thread::JoinHandle<Tally>> = Vec::new();
    let report = if gen_new > 0 && turns > 1 {
        let prompt_len = seq - gen_new;
        println!(
            "serving {n_windows} chat sessions ({prompt_len}-token prompts over \
             {turns} turns, {gen_new} new tokens) from {n_clients} clients \
             (max {max_batch} in flight) ..."
        );
        for c in 0..n_clients {
            let client = coord.session_client().map_err(|e| e.to_string())?;
            let my: Vec<(usize, Vec<u16>)> = windows
                .iter()
                .enumerate()
                .skip(c)
                .step_by(n_clients)
                .map(|(i, w)| (i, w.clone()))
                .collect();
            handles.push(std::thread::spawn(move || -> Tally {
                let (mut tokens, mut ok, mut degraded) = (0usize, 0usize, 0usize);
                for (wi, w) in my {
                    let id = format!("c{c}-w{wi}");
                    if let Err(e) = client.open(&id) {
                        match e {
                            ServeError::Invalid(e) => return Err(e),
                            _ => {
                                degraded += 1;
                                continue;
                            }
                        }
                    }
                    // split the prompt into `turns` deltas and the token
                    // budget into per-turn quotas; remainders land on the
                    // last turn so the totals match the one-shot workload
                    let mut session_ok = true;
                    for t in 0..turns {
                        let d0 = t * prompt_len / turns;
                        let d1 = (t + 1) * prompt_len / turns;
                        let quota =
                            (t + 1) * gen_new / turns - t * gen_new / turns;
                        match client.turn(&id, w[d0..d1].to_vec(), quota) {
                            Ok(g) => tokens += g.tokens.len(),
                            Err(ServeError::Invalid(e)) => return Err(e),
                            Err(_) => {
                                degraded += 1;
                                session_ok = false;
                                break;
                            }
                        }
                    }
                    if session_ok {
                        ok += 1;
                    }
                    let _ = client.close(&id);
                }
                Ok((tokens as f64, ok, degraded))
            }));
        }
        coord.run().map_err(|e| e.to_string())?
    } else if gen_new > 0 {
        let prompt_len = seq - gen_new;
        println!(
            "serving {n_windows} generation requests ({prompt_len}-token prompts, \
             {gen_new} new tokens) from {n_clients} clients (max {max_batch} in flight) ..."
        );
        for c in 0..n_clients {
            let client = coord.gen_client().map_err(|e| e.to_string())?;
            let my: Vec<Vec<u16>> =
                windows.iter().skip(c).step_by(n_clients).cloned().collect();
            handles.push(std::thread::spawn(move || -> Tally {
                let (mut tokens, mut ok, mut degraded) = (0usize, 0usize, 0usize);
                for w in my {
                    match client.generate(w[..prompt_len].to_vec(), gen_new) {
                        Ok(g) => {
                            ok += 1;
                            tokens += g.tokens.len();
                        }
                        Err(ServeError::Invalid(e)) => return Err(e),
                        Err(_) => degraded += 1,
                    }
                }
                Ok((tokens as f64, ok, degraded))
            }));
        }
        coord.run().map_err(|e| e.to_string())?
    } else {
        println!(
            "serving {n_windows} scoring requests from {n_clients} clients \
             (batch window {} ms) ...",
            recipe.max_wait_ms
        );
        for c in 0..n_clients {
            let client = coord.client().map_err(|e| e.to_string())?;
            let my: Vec<Vec<u16>> =
                windows.iter().skip(c).step_by(n_clients).cloned().collect();
            handles.push(std::thread::spawn(move || -> Tally {
                let (mut sum, mut ok, mut degraded) = (0.0f64, 0usize, 0usize);
                for w in my {
                    match client.score(w) {
                        Ok(nll) => {
                            ok += 1;
                            sum += nll as f64;
                        }
                        Err(ServeError::Invalid(e)) => return Err(e),
                        Err(_) => degraded += 1,
                    }
                }
                Ok((sum, ok, degraded))
            }));
        }
        coord.run().map_err(|e| e.to_string())?
    };
    let (mut total, mut ok_requests, mut degraded) = (0.0f64, 0usize, 0usize);
    for h in handles {
        let (v, o, d) = h.join().map_err(|_| "client panicked".to_string())??;
        total += v;
        ok_requests += o;
        degraded += d;
    }
    report.print();
    if gen_new > 0 {
        println!(
            "generated {} tokens total ({ok_requests} requests ok, {degraded} degraded)",
            total as usize
        );
    } else {
        let tokens = (seq - 1) * ok_requests;
        if tokens > 0 {
            println!(
                "workload ppl {:.4} over {} scored tokens ({degraded} degraded)",
                (total / tokens as f64).exp(),
                tokens
            );
        } else {
            println!("no scoring requests succeeded ({degraded} degraded)");
        }
    }
    Ok(())
}

/// PJRT when this build can execute artifacts and the one we need exists;
/// otherwise the compiled in-process engine.
pub fn pick_backend(
    artifacts: &std::path::Path,
    ck: &Checkpoint,
    opts: &crate::engine::EngineOpts,
) -> ScoreBackend {
    let available = crate::runtime::PJRT_AVAILABLE
        && crate::runtime::act_tag(opts)
            .map(|act| {
                artifacts
                    .join(crate::runtime::score_artifact_name(&ck.config, act))
                    .exists()
            })
            .unwrap_or(false);
    if available {
        ScoreBackend::Pjrt { artifacts: artifacts.to_path_buf() }
    } else {
        ScoreBackend::Compiled
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::EngineOpts;
    use crate::model::{Arch, Checkpoint, ModelConfig};
    use crate::rng::Rng;
    use std::time::Duration;

    fn tiny_ck() -> Checkpoint {
        let cfg = ModelConfig {
            name: "coord-test".into(),
            arch: Arch::Opt,
            vocab_size: 48,
            d_model: 24,
            n_heads: 3,
            n_layers: 2,
            d_ff: 48,
            max_seq: 8,
        };
        let mut rng = Rng::seeded(611);
        Checkpoint::random(&cfg, &mut rng)
    }

    fn compiled_cfg(ck: Checkpoint, policy: BatchPolicy) -> CoordinatorConfig {
        CoordinatorConfig {
            backend: ScoreBackend::Compiled,
            ck,
            opts: EngineOpts::default(),
            policy,
            kv_quant: None,
            sidecar: None,
            queue_depth: DEFAULT_QUEUE_DEPTH,
            deadline: None,
            faults: None,
            speculate: None,
            kv_page_positions: 0,
            kv_budget_bytes: 0,
            sampling: SamplingConfig::default(),
            max_sessions: DEFAULT_MAX_SESSIONS,
        }
    }

    #[test]
    fn compiled_backend_serves_requests() {
        let ck = tiny_ck();
        let coord = Coordinator::new(compiled_cfg(
            ck.clone(),
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        ));
        let mut handles = Vec::new();
        for c in 0..3usize {
            let client = coord.client().unwrap();
            handles.push(std::thread::spawn(move || -> Result<Vec<f32>> {
                let mut out = Vec::new();
                for i in 0..5u16 {
                    let window: Vec<u16> = (0..8).map(|t| (c as u16 + i + t) % 48).collect();
                    out.push(client.score(window)?);
                }
                Ok(out)
            }));
        }
        let report = coord.run().unwrap();
        for h in handles {
            let nlls = h.join().unwrap().unwrap();
            assert!(nlls.iter().all(|v| v.is_finite() && *v > 0.0));
        }
        assert_eq!(report.requests, 15);
        assert!(report.latency.count() == 15);
        assert_eq!(report.gen_requests, 0);
        assert_eq!(report.decode_tokens, 0);

        // NLL parity with a direct compiled-model score.
        let model = CompiledModel::compile(&ck, EngineOpts::default());
        let mut s = model.scratch();
        let window: Vec<u16> = (0..8).map(|t| t % 48).collect();
        let direct = model.score_nll(&window, &mut s);
        let coord2 = Coordinator::new(compiled_cfg(ck, BatchPolicy::default()));
        let client = coord2.client().unwrap();
        let h = std::thread::spawn(move || client.score(window).unwrap());
        coord2.run().unwrap();
        assert_eq!(h.join().unwrap(), direct);
    }

    #[test]
    fn rejects_wrong_window_length() {
        let ck = tiny_ck();
        let coord = Coordinator::new(compiled_cfg(ck, BatchPolicy::default()));
        let client = coord.client().unwrap();
        assert!(client.score(vec![1, 2, 3]).is_err());
        drop(client);
        coord.run().unwrap();
    }

    #[test]
    fn generation_matches_direct_greedy_decode() {
        let ck = tiny_ck();
        // direct: prefill + greedy decode on a compiled model
        let model = CompiledModel::compile(&ck, EngineOpts::default());
        let mut s = model.scratch();
        let prompt: Vec<u16> = vec![5, 11, 17];
        let max_new = 4usize;
        let mut cache = model.kv_cache();
        let logits = model.prefill(&prompt, &mut cache, &mut s);
        let mut expect = vec![argmax(logits.row(logits.rows - 1)) as u16];
        while expect.len() < max_new {
            let last = *expect.last().unwrap();
            let row = model.decode_step(last, &mut cache, &mut s);
            expect.push(argmax(row.row(0)) as u16);
        }

        let coord = Coordinator::new(compiled_cfg(ck, BatchPolicy::default()));
        let client = coord.gen_client().unwrap();
        let p = prompt.clone();
        let h = std::thread::spawn(move || client.generate(p, max_new).unwrap());
        let report = coord.run().unwrap();
        let got = h.join().unwrap();
        assert_eq!(got.tokens, expect);
        assert_eq!(got.prompt_len, 3);
        assert_eq!(report.gen_requests, 1);
        assert_eq!(report.prefill_tokens, 3);
        assert_eq!(report.decode_tokens, max_new - 1);
        assert_eq!(report.request_tok_s.count(), 1);
    }

    #[test]
    fn free_cache_pool_retention_is_capped_at_max_batch() {
        // regression: a burst of B ≫ max_batch generations must not leave
        // B recycled rings parked in the free pool — retention is capped
        // at the concurrency limit, observable through kv_pool_bytes
        let ck = tiny_ck();
        let ring_bytes = 2 * 2 * 8 * 24 * 4; // n_layers × {K,V} × max_seq × d_model × f32
        let coord = Coordinator::new(compiled_cfg(
            ck,
            BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(1) },
        ));
        let mut handles = Vec::new();
        for c in 0..6usize {
            let client = coord.gen_client().unwrap();
            handles.push(std::thread::spawn(move || {
                (0..2)
                    .map(|i| {
                        let prompt: Vec<u16> =
                            (0..4).map(|k| ((c * 7 + i * 3 + k) % 48) as u16).collect();
                        client.generate(prompt, 3).unwrap().tokens.len()
                    })
                    .sum::<usize>()
            }));
        }
        let report = coord.run().unwrap();
        for h in handles {
            assert_eq!(h.join().unwrap(), 6);
        }
        assert_eq!(report.gen_requests, 12);
        assert_eq!(report.kv_resident_bytes, 0, "every ring is recycled by drain");
        assert!(
            report.kv_pool_bytes <= 2 * ring_bytes,
            "free pool retained more rings than max_batch: {} B of {} B allowed",
            report.kv_pool_bytes,
            2 * ring_bytes
        );
        assert!(report.kv_peak_bytes >= ring_bytes, "at least one ring was live mid-run");
        assert_eq!(report.kv_pages_total, 0, "ring mode mints no pages");
        assert_eq!(report.kv_preemptions, 0);
    }

    #[test]
    fn continuous_batching_joins_and_leaves_midflight() {
        let ck = tiny_ck();
        let coord = Coordinator::new(compiled_cfg(
            ck.clone(),
            BatchPolicy { max_batch: 3, max_wait: Duration::from_millis(1) },
        ));
        // mixed lengths/budgets so sequences finish at different steps,
        // plus a scoring request sharing the same loop
        let score_client = coord.client().unwrap();
        let mut handles = Vec::new();
        for (c, (plen, max_new)) in
            [(1usize, 2usize), (2, 5), (3, 4), (1, 6), (4, 3)].iter().enumerate()
        {
            let client = coord.gen_client().unwrap();
            let prompt: Vec<u16> = (0..*plen).map(|t| ((c + t) % 48) as u16).collect();
            let n = *max_new;
            handles.push(std::thread::spawn(move || client.generate(prompt, n).unwrap()));
        }
        let sh = std::thread::spawn(move || {
            let window: Vec<u16> = (0..8).map(|t| t % 48).collect();
            score_client.score(window).unwrap()
        });
        let report = coord.run().unwrap();
        let results: Vec<Generated> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert!(sh.join().unwrap().is_finite());
        for (r, (_, max_new)) in
            results.iter().zip([(1usize, 2usize), (2, 5), (3, 4), (1, 6), (4, 3)])
        {
            assert_eq!(r.tokens.len(), max_new);
            assert!(r.tokens.iter().all(|&t| (t as usize) < 48));
        }
        assert_eq!(report.gen_requests, 5);
        assert_eq!(report.requests, 6);
        // 5 requests, budgets (2+5+4+6+3) = 20 tokens, first token of each
        // comes from prefill => 15 decode-step tokens
        assert_eq!(report.decode_tokens, 15);
        assert!(report.decode_steps >= 5, "longest budget needs >= 5 steps");
        assert_eq!(report.request_tok_s.count(), 5);
        // continuity: a sequence's result must not depend on batch mates —
        // re-serve one request alone and compare
        let coord2 = Coordinator::new(compiled_cfg(ck, BatchPolicy::default()));
        let client = coord2.gen_client().unwrap();
        let prompt: Vec<u16> = (0..2).map(|t| ((1 + t) % 48) as u16).collect();
        let h = std::thread::spawn(move || client.generate(prompt, 5).unwrap());
        coord2.run().unwrap();
        assert_eq!(h.join().unwrap().tokens, results[1].tokens);
    }

    #[test]
    fn generation_rejects_bad_requests() {
        let ck = tiny_ck();
        let coord = Coordinator::new(compiled_cfg(ck, BatchPolicy::default()));
        let client = coord.gen_client().unwrap();
        assert!(client.generate(vec![], 3).is_err(), "empty prompt");
        assert!(client.generate(vec![1, 2], 0).is_err(), "zero budget");
        assert!(client.generate(vec![1, 2, 3, 4, 5, 6, 7], 2).is_err(), "exceeds max_seq");
        assert!(client.generate(vec![1, 200], 2).is_err(), "token out of vocab");
        drop(client);
        coord.run().unwrap();
    }

    #[test]
    fn packed_lorc_generation_matches_dense_generation() {
        // the serving-level contract, driven through the recipe API: a
        // coordinator built from the packed recipe (LoRC factors attached)
        // generates exactly the tokens the dense (folded-checkpoint)
        // coordinator generates — same PTQ artifacts, two ServingStack
        // rewirings
        use crate::lorc::LorcConfig;
        use crate::quant::Scheme;

        let ck = tiny_ck();
        let packed_recipe = QuantRecipe::builder(Scheme::parse("w4a8-fp-fp").unwrap())
            .use_gptq(false)
            .lorc(LorcConfig { rank: 2, factor_format: crate::formats::NumericFormat::FP8_E4M3 })
            .packed(1)
            .build()
            .unwrap();
        let dense_recipe = {
            let mut r = packed_recipe.clone();
            r.weights = crate::engine::WeightLayout::Dense;
            r.validate().unwrap();
            r
        };
        let stack = ServingStack::build(&ck, &[], &packed_recipe).unwrap();
        assert!(!stack.sidecar.is_empty() && stack.sidecar.has_lorc());
        let prompt: Vec<u16> = vec![3, 14, 15];

        let run = |stack: ServingStack| -> Vec<u16> {
            let coord = stack.coordinator();
            let client = coord.gen_client().unwrap();
            let p = prompt.clone();
            let h = std::thread::spawn(move || client.generate(p, 4).unwrap());
            coord.run().unwrap();
            h.join().unwrap().tokens
        };
        let dense = run(stack.with_recipe(&dense_recipe).unwrap());
        let packed = run(stack);
        assert_eq!(dense, packed);
        assert_eq!(dense.len(), 4);
    }

    #[test]
    fn quantized_kv_generation_is_deterministic() {
        let ck = tiny_ck();
        let prompt: Vec<u16> = vec![9, 21, 33];
        let mut runs = Vec::new();
        for _ in 0..2 {
            let mut cfg = compiled_cfg(ck.clone(), BatchPolicy::default());
            cfg.kv_quant = Some(crate::formats::FpFormat::E4M3);
            let coord = Coordinator::new(cfg);
            let client = coord.gen_client().unwrap();
            let p = prompt.clone();
            let h = std::thread::spawn(move || client.generate(p, 4).unwrap());
            coord.run().unwrap();
            runs.push(h.join().unwrap().tokens);
        }
        assert_eq!(runs[0], runs[1]);
        assert_eq!(runs[0].len(), 4);
    }

    #[test]
    fn typed_errors_display_and_convert() {
        assert_eq!(
            ServeError::Invalid("bad".into()).to_string(),
            "invalid request: bad"
        );
        assert_eq!(ServeError::Overloaded.to_string(), "overloaded: admission queue full");
        assert_eq!(
            ServeError::DeadlineExceeded { partial: vec![1, 2] }.to_string(),
            "deadline exceeded (2 partial tokens)"
        );
        assert_eq!(ServeError::Faulted("boom".into()).to_string(), "request faulted: boom");
        assert_eq!(ServeError::ShuttingDown.to_string(), "coordinator shutting down");
        assert_eq!(
            ServeError::SessionNotFound("chat".into()).to_string(),
            "session not found: chat"
        );
        assert_eq!(
            ServeError::SessionBusy("chat".into()).to_string(),
            "session busy: chat already has a turn in flight"
        );
        assert_eq!(
            ServeError::DuplicateSession("chat".into()).to_string(),
            "session already exists: chat"
        );
        assert!(CoordinatorError::NotAcceptingClients.to_string().contains("before run"));
        // ServeError threads through `?` in crate-Result functions
        let e: crate::error::Error = ServeError::Overloaded.into();
        assert!(e.to_string().contains("overloaded"));
    }

    #[test]
    fn bounded_queue_sheds_typed_overload_before_run() {
        // queue depth 2, no loop consuming: the 3rd..5th submissions must
        // shed deterministically, client-side, with a typed Overloaded
        let ck = tiny_ck();
        let mut cfg = compiled_cfg(ck, BatchPolicy::default());
        cfg.queue_depth = 2;
        let coord = Coordinator::new(cfg);
        let client = coord.gen_client().unwrap();
        let mut tickets = Vec::new();
        let mut shed = 0usize;
        for _ in 0..5 {
            match client.submit(vec![1, 2, 3], 2) {
                Ok(t) => tickets.push(t),
                Err(e) => {
                    assert_eq!(e, ServeError::Overloaded);
                    shed += 1;
                }
            }
        }
        assert_eq!((tickets.len(), shed), (2, 3));
        drop(client);
        let report = coord.run().unwrap();
        assert_eq!(report.shed_overloaded, 3);
        assert_eq!(report.requests, 2);
        for t in tickets {
            assert_eq!(t.recv().unwrap().unwrap().tokens.len(), 2);
        }
    }

    #[test]
    fn expired_deadline_is_shed_at_admission() {
        let ck = tiny_ck();
        let coord = Coordinator::new(compiled_cfg(ck, BatchPolicy::default()));
        let client = coord.gen_client().unwrap();
        let past = Instant::now() - Duration::from_millis(5);
        let h = std::thread::spawn(move || client.generate_by(vec![1, 2, 3], 3, Some(past)));
        let report = coord.run().unwrap();
        assert_eq!(
            h.join().unwrap(),
            Err(ServeError::DeadlineExceeded { partial: Vec::new() })
        );
        assert_eq!(report.expired_admission, 1);
        assert_eq!(report.gen_requests, 0, "no compute was spent on the expired request");
        assert_eq!(report.requests, 1);
    }

    #[test]
    fn shutdown_handle_drains_gracefully_when_idle() {
        let ck = tiny_ck();
        let coord = Coordinator::new(compiled_cfg(ck, BatchPolicy::default()));
        let client = coord.client().unwrap();
        let stopper = coord.shutdown_handle();
        assert!(!stopper.is_shutdown());
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            stopper.shutdown();
        });
        // the client handle stays alive the whole run: only the shutdown
        // signal can end the loop
        let report = coord.run().unwrap();
        h.join().unwrap();
        assert!(report.drained);
        assert_eq!(report.requests, 0);
        drop(client);
    }
}
