//! Dense linear algebra substrate (no LAPACK offline): Cholesky, triangular
//! ops, symmetric inverse, and a one-sided Jacobi SVD.
//!
//! Consumers:
//! * `gptq` — damped Cholesky factorization/inversion of the Hessian
//!   `H = 2·X·Xᵀ + λI` (f64 accumulation for stability at in-dims ≤ 1024).
//! * `lorc` — truncated SVD of the quantization error matrix.

use crate::tensor::Matrix;

/// Errors from numerical routines.
#[derive(Debug, Clone, PartialEq)]
pub enum LinalgError {
    /// Matrix was not positive-definite even after damping.
    NotPositiveDefinite { pivot: usize, value: f64 },
    /// Iterative routine failed to converge.
    NoConvergence { iters: usize },
}

impl std::fmt::Display for LinalgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LinalgError::NotPositiveDefinite { pivot, value } => {
                write!(f, "matrix not positive definite (pivot {pivot} = {value})")
            }
            LinalgError::NoConvergence { iters } => {
                write!(f, "no convergence after {iters} iterations")
            }
        }
    }
}

impl std::error::Error for LinalgError {}

/// Lower-triangular Cholesky factor of a symmetric positive-definite matrix
/// (f64 accumulation). `a` is read as symmetric from its lower triangle.
pub fn cholesky_lower(a: &Matrix) -> Result<Matrix, LinalgError> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a.at(i, j) as f64;
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if s <= 0.0 {
                    return Err(LinalgError::NotPositiveDefinite { pivot: i, value: s });
                }
                l[i * n + j] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Ok(Matrix::from_vec(n, n, l.iter().map(|&x| x as f32).collect()))
}

/// Invert a lower-triangular matrix (forward substitution per column).
pub fn invert_lower(l: &Matrix) -> Matrix {
    let n = l.rows;
    let mut inv = vec![0.0f64; n * n];
    for j in 0..n {
        inv[j * n + j] = 1.0 / l.at(j, j) as f64;
        for i in (j + 1)..n {
            let mut s = 0.0f64;
            for k in j..i {
                s -= l.at(i, k) as f64 * inv[k * n + j];
            }
            inv[i * n + j] = s / l.at(i, i) as f64;
        }
    }
    Matrix::from_vec(n, n, inv.iter().map(|&x| x as f32).collect())
}

/// Symmetric positive-definite inverse via Cholesky: A⁻¹ = L⁻ᵀ L⁻¹.
pub fn spd_inverse(a: &Matrix) -> Result<Matrix, LinalgError> {
    let l = cholesky_lower(a)?;
    let linv = invert_lower(&l);
    // A^-1 = linv^T @ linv
    let n = a.rows;
    let mut out = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let mut s = 0.0f64;
            // (linv^T linv)[i,j] = sum_k linv[k,i] * linv[k,j]; linv lower
            // triangular so k >= max(i, j).
            for k in i.max(j)..n {
                s += linv.at(k, i) as f64 * linv.at(k, j) as f64;
            }
            *out.at_mut(i, j) = s as f32;
        }
    }
    Ok(out)
}

/// The factorization GPTQ consumes: the **upper** Cholesky factor of A⁻¹
/// (`A⁻¹ = Uᵀ·U` with U upper-triangular… GPTQ indexes `U[i, j≥i]`).
/// Following the reference implementation this is computed as
/// `U = chol(A⁻¹)ᵀ`.
pub fn cholesky_inverse_upper(a: &Matrix) -> Result<Matrix, LinalgError> {
    let inv = spd_inverse(a)?;
    let l = cholesky_lower(&inv)?;
    Ok(l.transpose())
}

/// Result of a (thin) SVD: `a = u · diag(s) · vᵀ`, singular values
/// descending.
#[derive(Debug, Clone)]
pub struct Svd {
    pub u: Matrix,
    pub s: Vec<f32>,
    pub v: Matrix,
}

/// One-sided Jacobi SVD. Robust and simple; O(m·n²·sweeps) — fine for the
/// weight-matrix sizes in this repo (≤ 1024²). For m < n the routine runs
/// on the transpose and swaps U/V back.
pub fn jacobi_svd(a: &Matrix) -> Result<Svd, LinalgError> {
    if a.rows < a.cols {
        let t = jacobi_svd(&a.transpose())?;
        return Ok(Svd { u: t.v, s: t.s, v: t.u });
    }
    let m = a.rows;
    let n = a.cols;
    // Work on columns of A (f64 for accumulation stability).
    let mut u: Vec<f64> = a.data.iter().map(|&x| x as f64).collect(); // m x n row-major
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let max_sweeps = 60;
    let eps = 1e-12;
    let mut converged = false;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Gram entries over columns p, q
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..m {
                    let up = u[i * n + p];
                    let uq = u[i * n + q];
                    app += up * up;
                    aqq += uq * uq;
                    apq += up * uq;
                }
                if apq.abs() <= eps * (app * aqq).sqrt().max(f64::MIN_POSITIVE) {
                    continue;
                }
                off += apq.abs();
                // Jacobi rotation zeroing the (p,q) Gram entry
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let up = u[i * n + p];
                    let uq = u[i * n + q];
                    u[i * n + p] = c * up - s * uq;
                    u[i * n + q] = s * up + c * uq;
                }
                for i in 0..n {
                    let vp = v[i * n + p];
                    let vq = v[i * n + q];
                    v[i * n + p] = c * vp - s * vq;
                    v[i * n + q] = s * vp + c * vq;
                }
            }
        }
        if off < 1e-14 {
            converged = true;
            break;
        }
    }
    if !converged {
        // Jacobi always makes progress; a slack tolerance miss is still a
        // usable factorization for LoRC. Only hard-fail on NaN.
        if u.iter().any(|x| !x.is_finite()) {
            return Err(LinalgError::NoConvergence { iters: max_sweeps });
        }
    }
    // Column norms are the singular values.
    let mut order: Vec<usize> = (0..n).collect();
    let mut sigmas = vec![0.0f64; n];
    for (j, sig) in sigmas.iter_mut().enumerate() {
        let mut s = 0.0f64;
        for i in 0..m {
            s += u[i * n + j] * u[i * n + j];
        }
        *sig = s.sqrt();
    }
    order.sort_by(|&x, &y| sigmas[y].partial_cmp(&sigmas[x]).unwrap());
    let mut um = Matrix::zeros(m, n);
    let mut vm = Matrix::zeros(n, n);
    let mut sv = vec![0.0f32; n];
    for (newj, &oldj) in order.iter().enumerate() {
        let sig = sigmas[oldj];
        sv[newj] = sig as f32;
        let inv = if sig > 1e-300 { 1.0 / sig } else { 0.0 };
        for i in 0..m {
            *um.at_mut(i, newj) = (u[i * n + oldj] * inv) as f32;
        }
        for i in 0..n {
            *vm.at_mut(i, newj) = v[i * n + oldj] as f32;
        }
    }
    Ok(Svd { u: um, s: sv, v: vm })
}

/// Rank-`r` truncation of an SVD: returns (A_r = U_r Σ_r V_rᵀ as factors)
/// `(U·Σ^{1/2} [m×r], Σ^{1/2}·Vᵀ [r×n])` — the two low-rank matrices LoRC
/// stores (Section 3 of the paper: "two low-rank matrices derived from the
/// matrices in the first step").
pub fn truncate_svd(svd: &Svd, r: usize) -> (Matrix, Matrix) {
    let m = svd.u.rows;
    let n = svd.v.rows;
    let r = r.min(svd.s.len());
    let mut e1 = Matrix::zeros(m, r);
    let mut e2 = Matrix::zeros(r, n);
    for j in 0..r {
        let root = svd.s[j].max(0.0).sqrt();
        for i in 0..m {
            *e1.at_mut(i, j) = svd.u.at(i, j) * root;
        }
        for i in 0..n {
            *e2.at_mut(j, i) = svd.v.at(i, j) * root;
        }
    }
    (e1, e2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn random_spd(n: usize, rng: &mut Rng) -> Matrix {
        let a = Matrix::randn(n, n, 1.0, rng);
        let mut h = a.matmul_t(&a); // A Aᵀ is PSD
        for i in 0..n {
            *h.at_mut(i, i) += 0.5; // damp to PD
        }
        h
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::seeded(31);
        let h = random_spd(24, &mut rng);
        let l = cholesky_lower(&h).unwrap();
        let rec = l.matmul_t(&l); // L Lᵀ
        assert!(rec.mse(&h) < 1e-6, "mse={}", rec.mse(&h));
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigen -1, 3
        assert!(matches!(
            cholesky_lower(&a),
            Err(LinalgError::NotPositiveDefinite { .. })
        ));
    }

    #[test]
    fn invert_lower_works() {
        let mut rng = Rng::seeded(32);
        let h = random_spd(16, &mut rng);
        let l = cholesky_lower(&h).unwrap();
        let linv = invert_lower(&l);
        let prod = l.matmul(&linv);
        assert!(prod.mse(&Matrix::eye(16)) < 1e-8);
    }

    #[test]
    fn spd_inverse_works() {
        let mut rng = Rng::seeded(33);
        let h = random_spd(20, &mut rng);
        let hinv = spd_inverse(&h).unwrap();
        let prod = h.matmul(&hinv);
        assert!(prod.mse(&Matrix::eye(20)) < 1e-5, "mse={}", prod.mse(&Matrix::eye(20)));
    }

    #[test]
    fn cholesky_inverse_upper_identity() {
        let mut rng = Rng::seeded(34);
        let h = random_spd(12, &mut rng);
        let u = cholesky_inverse_upper(&h).unwrap();
        // U should be upper triangular with Uᵀ U = H⁻¹
        for i in 0..12 {
            for j in 0..i {
                assert_eq!(u.at(i, j), 0.0);
            }
        }
        let uut = u.transpose().matmul(&u);
        let hinv = spd_inverse(&h).unwrap();
        assert!(uut.mse(&hinv) < 1e-6);
    }

    #[test]
    fn svd_reconstructs_random() {
        let mut rng = Rng::seeded(35);
        for (m, n) in [(10, 6), (6, 10), (16, 16), (1, 5), (32, 8)] {
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let svd = jacobi_svd(&a).unwrap();
            // full reconstruction
            let k = svd.s.len();
            let mut usv = Matrix::zeros(m, n);
            for i in 0..m {
                for j in 0..n {
                    let mut s = 0.0f64;
                    for t in 0..k {
                        s += svd.u.at(i, t) as f64 * svd.s[t] as f64 * svd.v.at(j, t) as f64;
                    }
                    *usv.at_mut(i, j) = s as f32;
                }
            }
            assert!(usv.mse(&a) < 1e-8, "({m},{n}) mse={}", usv.mse(&a));
            // singular values descending and non-negative
            for t in 1..k {
                assert!(svd.s[t - 1] >= svd.s[t] - 1e-6);
                assert!(svd.s[t] >= 0.0);
            }
        }
    }

    #[test]
    fn svd_orthogonality() {
        let mut rng = Rng::seeded(36);
        let a = Matrix::randn(20, 12, 1.0, &mut rng);
        let svd = jacobi_svd(&a).unwrap();
        let utu = svd.u.transpose().matmul(&svd.u);
        let vtv = svd.v.transpose().matmul(&svd.v);
        assert!(utu.mse(&Matrix::eye(12)) < 1e-8);
        assert!(vtv.mse(&Matrix::eye(12)) < 1e-8);
    }

    #[test]
    fn truncated_svd_is_best_rank_r() {
        // Eckart–Young sanity: rank-r truncation error equals the tail
        // singular values' energy.
        let mut rng = Rng::seeded(37);
        let a = Matrix::randn(16, 12, 1.0, &mut rng);
        let svd = jacobi_svd(&a).unwrap();
        let r = 4;
        let (e1, e2) = truncate_svd(&svd, r);
        let approx = e1.matmul(&e2);
        let err = a.sub(&approx).fro_norm();
        let tail: f64 = svd.s[r..].iter().map(|&s| (s as f64) * (s as f64)).sum();
        assert!((err * err - tail).abs() / tail.max(1e-12) < 1e-4);
    }

    #[test]
    fn svd_rank_deficient() {
        // rank-1 matrix: one singular value, rest ~0
        let u = Matrix::from_vec(4, 1, vec![1., 2., 3., 4.]);
        let v = Matrix::from_vec(1, 3, vec![1., 0., -1.]);
        let a = u.matmul(&v);
        let svd = jacobi_svd(&a).unwrap();
        assert!(svd.s[0] > 1.0);
        for &s in &svd.s[1..] {
            assert!(s < 1e-5, "s={s}");
        }
    }
}
