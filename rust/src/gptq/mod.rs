//! GPTQ — the lightweight optimization-based PTQ algorithm the paper builds
//! on (Frantar et al., 2022; lineage OBD → OBS → OBC).
//!
//! Per linear layer with weight `W [out, in]` and calibration inputs
//! `X [tokens, in]`:
//!
//! 1. accumulate the Hessian `H = 2·XᵀX` (input-covariance, f64),
//! 2. damp: `H += λI`, `λ = percdamp · mean(diag H)`,
//! 3. compute `U = chol(H⁻¹)ᵀ` (upper),
//! 4. sweep columns left→right in blocks; quantize column `j` with its FGQ
//!    group scale, then push the weighted residual into the not-yet-quantized
//!    columns: `W[:, k>j] -= err · U[j,k]/U[j,j]`,
//! 5. FGQ group scales are (re)computed from the *error-compensated* weights
//!    at each group boundary, then projected by the scale constraint
//!    (M1/M2) before encoding — so constrained scales see the same GPTQ
//!    error feedback as unconstrained ones.
//!
//! The implementation is format-agnostic: the same sweep quantizes to INT4,
//! INT8, FP4 or FP8 through [`crate::formats::NumericFormat`], which is
//! exactly the paper's experimental design (GPTQ held fixed, format varied).

use crate::formats::{GroupParams, NumericFormat};
use crate::linalg::{cholesky_inverse_upper, LinalgError};
use crate::quant::{constrain_scales, QuantizedWeight, WeightQuantConfig};
use crate::tensor::Matrix;

/// GPTQ hyper-parameters (defaults follow the reference implementation).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GptqConfig {
    /// Dampening fraction of mean(diag(H)).
    pub percdamp: f64,
    /// Column block size for the lazy-update sweep.
    pub block_size: usize,
}

impl Default for GptqConfig {
    fn default() -> Self {
        GptqConfig { percdamp: 0.01, block_size: 128 }
    }
}

/// Streaming Hessian accumulator for one linear layer.
///
/// Feed it every calibration activation batch that flows *into* the layer;
/// it maintains `H = 2·XᵀX / n` in f64 like the reference implementation
/// (which renormalizes by sample count as batches arrive).
#[derive(Debug, Clone)]
pub struct HessianAccumulator {
    pub dim: usize,
    h: Vec<f64>,
    pub samples: usize,
}

impl HessianAccumulator {
    pub fn new(dim: usize) -> Self {
        HessianAccumulator { dim, h: vec![0.0; dim * dim], samples: 0 }
    }

    /// Add a batch of input rows `x [tokens, dim]`.
    pub fn add_batch(&mut self, x: &Matrix) {
        assert_eq!(x.cols, self.dim, "activation dim mismatch");
        self.samples += x.rows;
        // H += 2 xᵀx, accumulated in f64, lower triangle then mirrored on
        // finalize. Row-major friendly: iterate row vectors.
        for r in 0..x.rows {
            let row = x.row(r);
            for i in 0..self.dim {
                let xi = row[i] as f64 * 2.0;
                if xi == 0.0 {
                    continue;
                }
                let base = i * self.dim;
                for (j, &xj) in row.iter().enumerate().take(i + 1) {
                    self.h[base + j] += xi * xj as f64;
                }
            }
        }
    }

    /// Finalize into a symmetric, normalized f32 Hessian.
    pub fn finalize(&self) -> Matrix {
        let n = self.dim;
        let norm = 1.0 / self.samples.max(1) as f64;
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = (self.h[i * n + j] * norm) as f32;
                *m.at_mut(i, j) = v;
                *m.at_mut(j, i) = v;
            }
        }
        m
    }
}

/// Outcome of quantizing one layer.
#[derive(Debug)]
pub struct GptqResult {
    pub weight: QuantizedWeight,
    /// Sum over columns of `err² / U[j,j]²` — GPTQ's internal loss proxy.
    pub loss: f64,
    /// Fraction of dead (never-activated) input dims.
    pub dead_frac: f64,
}

/// Run GPTQ on one weight matrix.
///
/// `w` is `[out, in]`; `hessian` is the finalized `[in, in]` matrix from
/// [`HessianAccumulator`]. Falls back to escalating damping if the damped
/// Hessian is still not positive-definite (rank-deficient calibration).
pub fn gptq_quantize(
    w: &Matrix,
    hessian: &Matrix,
    wcfg: &WeightQuantConfig,
    cfg: &GptqConfig,
) -> Result<GptqResult, LinalgError> {
    assert_eq!(hessian.rows, w.cols);
    let (rows, cols) = (w.rows, w.cols);
    let group = wcfg.group_for(cols);
    let ng = cols.div_ceil(group);

    // --- prepare Hessian ---------------------------------------------------
    let mut h = hessian.clone();
    let mut work = w.clone();
    let mut dead = 0usize;
    for i in 0..cols {
        if h.at(i, i) <= 0.0 {
            dead += 1;
            *h.at_mut(i, i) = 1.0;
            for r in 0..rows {
                *work.at_mut(r, i) = 0.0;
            }
        }
    }
    let mean_diag: f64 =
        (0..cols).map(|i| h.at(i, i) as f64).sum::<f64>() / cols as f64;
    let mut damp = (cfg.percdamp * mean_diag).max(1e-8);
    let uinv = loop {
        let mut hd = h.clone();
        for i in 0..cols {
            *hd.at_mut(i, i) += damp as f32;
        }
        match cholesky_inverse_upper(&hd) {
            Ok(u) => break u,
            Err(_) if damp < mean_diag * 16.0 => damp *= 10.0,
            Err(e) => return Err(e),
        }
    };

    // --- column sweep --------------------------------------------------------
    let asym = matches!(wcfg.format, NumericFormat::Int(i) if !i.symmetric);
    let mut codes = vec![0u8; rows * cols];
    let mut scales = vec![1.0f32; rows * ng];
    let mut zeros = if asym { vec![0i32; rows * ng] } else { Vec::new() };
    let mut total_loss = 0.0f64;

    let bs = cfg.block_size.max(1);
    let mut col_err = vec![0.0f32; rows]; // err for current column
    let mut block_err = Matrix::zeros(rows, bs); // errs within block

    for i1 in (0..cols).step_by(bs) {
        let i2 = (i1 + bs).min(cols);
        block_err.data.iter_mut().for_each(|v| *v = 0.0);

        for j in i1..i2 {
            // FGQ boundary: derive (and constrain) scales from the current
            // error-compensated weights over the whole group.
            if j % group == 0 {
                let g = j / group;
                let c1 = (j + group).min(cols);
                let mut gscales = vec![0.0f32; rows];
                let mut gzeros = vec![0i32; rows];
                for r in 0..rows {
                    let (mut mn, mut mx) = (f32::INFINITY, f32::NEG_INFINITY);
                    for c in j..c1 {
                        let v = work.at(r, c);
                        mn = mn.min(v);
                        mx = mx.max(v);
                    }
                    let p = wcfg.format.group_params(mn, mx);
                    gscales[r] = p.scale;
                    gzeros[r] = p.zero_point;
                }
                constrain_scales(&mut gscales, rows, 1, wcfg.constraint);
                for r in 0..rows {
                    scales[r * ng + g] = gscales[r];
                    if asym {
                        zeros[r * ng + g] = gzeros[r];
                    }
                }
            }
            let g = j / group;
            let ujj = uinv.at(j, j).max(1e-12);
            // quantize column j
            for r in 0..rows {
                let p = GroupParams {
                    scale: scales[r * ng + g],
                    zero_point: if asym { zeros[r * ng + g] } else { 0 },
                };
                let x = work.at(r, j);
                let (code, deq) = crate::quant::weight::encode_value(wcfg.format, x, p);
                codes[r * cols + j] = code;
                let e = (x - deq) / ujj;
                col_err[r] = e;
                *block_err.at_mut(r, j - i1) = e;
                total_loss += (e as f64) * (e as f64) * 0.5;
            }
            // propagate into the rest of the block
            for r in 0..rows {
                let e = col_err[r];
                if e == 0.0 {
                    continue;
                }
                let wrow = work.row_mut(r);
                for k in (j + 1)..i2 {
                    wrow[k] -= e * uinv.at(j, k);
                }
            }
        }
        // lazy batch update of all columns right of the block:
        // W[:, i2:] -= E_block @ U[i1:i2, i2:]
        if i2 < cols {
            for r in 0..rows {
                let wrow = work.row_mut(r);
                for j in i1..i2 {
                    let e = block_err.at(r, j - i1);
                    if e == 0.0 {
                        continue;
                    }
                    let urow = uinv.row(j);
                    for (k, wk) in wrow.iter_mut().enumerate().skip(i2) {
                        *wk -= e * urow[k];
                    }
                }
            }
        }
    }

    Ok(GptqResult {
        weight: QuantizedWeight {
            rows,
            cols,
            group_size: group,
            format: wcfg.format,
            codes,
            scales,
            zeros,
            cast_fp4_to_e5m2: wcfg.cast_fp4_to_e5m2
                && matches!(wcfg.format, NumericFormat::Fp(f) if f.total_bits() == 4),
            constraint: wcfg.constraint,
        },
        loss: total_loss,
        dead_frac: dead as f64 / cols as f64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::weight::quantize_weight_rtn;
    use crate::rng::Rng;

    /// Proxy objective GPTQ minimizes: ‖(W - Ŵ)·Xᵀ‖² over calibration data.
    fn output_mse(w: &Matrix, q: &QuantizedWeight, x: &Matrix) -> f64 {
        let y_ref = x.matmul_t(w);
        let y_q = x.matmul_t(&q.dequantize());
        y_ref.mse(&y_q)
    }

    fn calib(rows: usize, dim: usize, rng: &mut Rng) -> Matrix {
        // correlated inputs (what makes GPTQ matter vs RTN)
        let base = Matrix::randn(rows, dim / 4, 1.0, rng);
        let mix = Matrix::randn(dim / 4, dim, 0.5, rng);
        let mut x = base.matmul(&mix);
        for v in x.data.iter_mut() {
            *v += rng.normal_f32() * 0.05;
        }
        x
    }

    #[test]
    fn gptq_beats_rtn_on_correlated_inputs() {
        let mut rng = Rng::seeded(71);
        let dim = 64;
        let w = Matrix::randn(48, dim, 0.1, &mut rng);
        let x = calib(256, dim, &mut rng);
        let mut acc = HessianAccumulator::new(dim);
        acc.add_batch(&x);
        let h = acc.finalize();
        for fmt in [NumericFormat::INT4, NumericFormat::FP4_E2M1] {
            let wcfg = WeightQuantConfig::new(fmt).with_group_size(32);
            let gptq = gptq_quantize(&w, &h, &wcfg, &GptqConfig::default()).unwrap();
            let rtn = quantize_weight_rtn(&w, &wcfg);
            let e_gptq = output_mse(&w, &gptq.weight, &x);
            let e_rtn = output_mse(&w, &rtn, &x);
            assert!(
                e_gptq < e_rtn,
                "{}: gptq={e_gptq} rtn={e_rtn}",
                fmt.name()
            );
        }
    }

    #[test]
    fn hessian_matches_direct_computation() {
        let mut rng = Rng::seeded(72);
        let x = Matrix::randn(40, 16, 1.0, &mut rng);
        let mut acc = HessianAccumulator::new(16);
        // feed in two chunks to exercise streaming
        let x1 = Matrix::from_vec(20, 16, x.data[..320].to_vec());
        let x2 = Matrix::from_vec(20, 16, x.data[320..].to_vec());
        acc.add_batch(&x1);
        acc.add_batch(&x2);
        let h = acc.finalize();
        let mut direct = x.transpose().matmul(&x);
        direct.scale(2.0 / 40.0);
        assert!(h.mse(&direct) < 1e-9, "mse={}", h.mse(&direct));
    }

    #[test]
    fn gptq_8bit_is_near_lossless_in_output_space() {
        // GPTQ deliberately trades weight-space error for output-space
        // fidelity, so the lossless-ness claim is about ‖(W-Ŵ)X‖.
        let mut rng = Rng::seeded(73);
        let w = Matrix::randn(32, 64, 0.1, &mut rng);
        let x = calib(128, 64, &mut rng);
        let mut acc = HessianAccumulator::new(64);
        acc.add_batch(&x);
        let wcfg = WeightQuantConfig::new(NumericFormat::FP8_E4M3);
        let r = gptq_quantize(&w, &acc.finalize(), &wcfg, &GptqConfig::default()).unwrap();
        let y_ref = x.matmul_t(&w);
        let y_q = x.matmul_t(&r.weight.dequantize());
        let rel = y_ref.sub(&y_q).fro_norm() / y_ref.fro_norm();
        assert!(rel < 0.02, "rel={rel}");
    }

    #[test]
    fn dead_columns_are_neutralized() {
        let mut rng = Rng::seeded(74);
        let w = Matrix::randn(8, 16, 0.1, &mut rng);
        let mut x = Matrix::randn(64, 16, 1.0, &mut rng);
        for r in 0..64 {
            x.row_mut(r)[5] = 0.0; // input dim 5 never fires
        }
        let mut acc = HessianAccumulator::new(16);
        acc.add_batch(&x);
        let wcfg = WeightQuantConfig::new(NumericFormat::INT4).with_group_size(0);
        let r = gptq_quantize(&w, &acc.finalize(), &wcfg, &GptqConfig::default()).unwrap();
        assert!(r.dead_frac > 0.0);
        // dead column quantizes to 0
        for row in 0..8 {
            assert_eq!(r.weight.dequant_at(row, 5), 0.0);
        }
    }

    #[test]
    fn gptq_respects_scale_constraints() {
        use crate::quant::ScaleConstraint;
        let mut rng = Rng::seeded(75);
        let w = Matrix::randn(16, 64, 0.1, &mut rng);
        let x = calib(128, 64, &mut rng);
        let mut acc = HessianAccumulator::new(64);
        acc.add_batch(&x);
        let wcfg = WeightQuantConfig::new(NumericFormat::FP4_E2M1)
            .with_group_size(32)
            .with_constraint(ScaleConstraint::M1);
        let r = gptq_quantize(&w, &acc.finalize(), &wcfg, &GptqConfig::default()).unwrap();
        for &s in &r.weight.scales {
            assert!(crate::quant::constraints::is_pow2(s), "{s}");
        }
    }

    #[test]
    fn block_boundaries_do_not_change_result_class() {
        // tiny block size must still produce a valid (finite, bounded-error)
        // quantization — exercises the lazy batch update path heavily.
        let mut rng = Rng::seeded(76);
        let w = Matrix::randn(8, 48, 0.1, &mut rng);
        let x = calib(96, 48, &mut rng);
        let mut acc = HessianAccumulator::new(48);
        acc.add_batch(&x);
        let h = acc.finalize();
        let wcfg = WeightQuantConfig::new(NumericFormat::INT4).with_group_size(16);
        let small = gptq_quantize(&w, &h, &wcfg, &GptqConfig { percdamp: 0.01, block_size: 4 })
            .unwrap();
        let big = gptq_quantize(&w, &h, &wcfg, &GptqConfig { percdamp: 0.01, block_size: 128 })
            .unwrap();
        let es = output_mse(&w, &small.weight, &x);
        let eb = output_mse(&w, &big.weight, &x);
        assert!(es.is_finite() && eb.is_finite());
        // identical math, different batching: must agree closely
        assert!((es - eb).abs() / eb.max(1e-12) < 0.2, "es={es} eb={eb}");
    }
}
