//! Minimal error plumbing for the serving/runtime layers.
//!
//! The offline vendor set has no `anyhow`, so this module supplies the tiny
//! subset the crate actually uses: a string-backed [`Error`], a [`Result`]
//! alias, the [`anyhow!`](crate::anyhow)/[`bail!`](crate::bail)/
//! [`ensure!`](crate::ensure) macros, and a [`Context`] extension trait.
//! Everything is deliberately boring — errors here are operator-facing
//! messages, not recoverable values.

use std::fmt;

/// A string-backed error with optional context frames (outermost first).
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (what `anyhow!` expands to).
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }

    /// Wrap with an outer context line, anyhow-style (`context: cause`).
    pub fn context(self, c: impl fmt::Display) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{:#}` (anyhow's whole-chain form) and `{}` are the same here:
        // the chain is already flattened into one line.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// NOTE: `Error` intentionally does NOT implement `std::error::Error`, so
// this blanket conversion cannot overlap the reflexive `From<Error>`.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::error::Error::msg(format!($($arg)*)) };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

/// `anyhow::Context`-alike for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 42)
    }

    #[test]
    fn macros_and_context() {
        let e = fails().unwrap_err();
        assert_eq!(format!("{e}"), "boom 42");
        assert_eq!(format!("{e:#}"), "boom 42");

        let io: std::io::Result<()> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let e = io.context("loading artifact").unwrap_err();
        assert!(format!("{e}").starts_with("loading artifact: "));

        let n: Option<u32> = None;
        assert!(n.with_context(|| "empty").is_err());

        let ok: Result<u32> = (|| {
            ensure!(1 + 1 == 2, "math broke");
            Ok(7)
        })();
        assert_eq!(ok.unwrap(), 7);
    }

    #[test]
    fn question_mark_on_std_errors() {
        fn inner() -> Result<String> {
            let s = String::from_utf8(vec![0xff])?; // FromUtf8Error: std::error::Error
            Ok(s)
        }
        assert!(inner().is_err());
    }
}
