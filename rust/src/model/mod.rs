//! Model substrate: architecture configs, the `.zqckpt` checkpoint format,
//! and the function-preserving outlier injection (DESIGN.md §4).

pub mod checkpoint;
pub mod config;
pub mod outliers;

pub use checkpoint::Checkpoint;
pub use config::{Arch, ModelConfig};
pub use outliers::{inject_outliers, OutlierSpec};
