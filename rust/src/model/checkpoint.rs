//! `.zqckpt` — the binary checkpoint interchange format.
//!
//! Written by the build-time JAX trainer (`python/compile/pretrain.py`) and
//! by the Rust PTQ pipeline (quantized checkpoints are stored dequantized
//! for engine replay plus a sidecar of quant metadata); read by the engine,
//! the pipeline and the AOT lowering step. Deliberately dumb and fully
//! specified so two independent implementations can't drift:
//!
//! ```text
//! magic  b"ZQCKPT01"
//! u32    arch            (0 = opt, 1 = llama)
//! u32×6  vocab, d_model, n_heads, n_layers, d_ff, max_seq
//! u32    n_tensors
//! repeat n_tensors:
//!   u32  name_len, name (utf-8)
//!   u32  rows, u32 cols
//!   f32×(rows·cols)     row-major little-endian
//! ```
//!
//! Linear weights are `[out_features, in_features]`; a linear computes
//! `y = x·Wᵀ + b`. Embeddings are `[vocab, d]` and the LM head is tied.

use std::collections::BTreeMap;
use std::io::{self, Read, Write};
use std::path::Path;

use crate::model::config::{Arch, ModelConfig};
use crate::rng::Rng;
use crate::tensor::Matrix;

const MAGIC: &[u8; 8] = b"ZQCKPT01";

/// A named-tensor checkpoint plus its architecture config.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub config: ModelConfig,
    /// BTreeMap so iteration (and thus serialization) is deterministic.
    pub tensors: BTreeMap<String, Matrix>,
}

impl Checkpoint {
    /// Canonical tensor names for a config (the schema both the Python
    /// trainer and the Rust engine agree on).
    pub fn tensor_schema(config: &ModelConfig) -> Vec<(String, usize, usize)> {
        let d = config.d_model;
        let ff = config.d_ff;
        let mut names: Vec<(String, usize, usize)> = vec![
            ("embed".into(), config.vocab_size, d),
            ("pos_embed".into(), config.max_seq, d),
        ];
        for i in 0..config.n_layers {
            let p = format!("layers.{i}");
            names.push((format!("{p}.ln1.g"), 1, d));
            if config.arch == Arch::Opt {
                names.push((format!("{p}.ln1.b"), 1, d));
            }
            for proj in ["q", "k", "v", "o"] {
                names.push((format!("{p}.attn.{proj}.w"), d, d));
                names.push((format!("{p}.attn.{proj}.b"), 1, d));
            }
            names.push((format!("{p}.ln2.g"), 1, d));
            if config.arch == Arch::Opt {
                names.push((format!("{p}.ln2.b"), 1, d));
                names.push((format!("{p}.mlp.fc1.w"), ff, d));
                names.push((format!("{p}.mlp.fc1.b"), 1, ff));
                names.push((format!("{p}.mlp.fc2.w"), d, ff));
                names.push((format!("{p}.mlp.fc2.b"), 1, d));
            } else {
                names.push((format!("{p}.mlp.gate.w"), ff, d));
                names.push((format!("{p}.mlp.up.w"), ff, d));
                names.push((format!("{p}.mlp.down.w"), d, ff));
                names.push((format!("{p}.mlp.down.b"), 1, d));
            }
        }
        names.push(("final_norm.g".into(), 1, d));
        if config.arch == Arch::Opt {
            names.push(("final_norm.b".into(), 1, d));
        }
        names
    }

    /// Randomly-initialized checkpoint (GPT-2-style init). Used by tests
    /// and as a fallback when no trained checkpoint is present.
    pub fn random(config: &ModelConfig, rng: &mut Rng) -> Checkpoint {
        let mut tensors = BTreeMap::new();
        let d = config.d_model as f32;
        for (name, rows, cols) in Checkpoint::tensor_schema(config) {
            let m = if name.ends_with(".b") && name.contains('.') {
                Matrix::zeros(rows, cols)
            } else if name.ends_with("norm.g") || name.contains("ln1.g") || name.contains("ln2.g")
            {
                Matrix::from_fn(rows, cols, |_, _| 1.0)
            } else if name == "embed" || name == "pos_embed" {
                Matrix::randn(rows, cols, 0.02, rng)
            } else {
                // residual-scaled init
                let std = 0.4 / d.sqrt();
                Matrix::randn(rows, cols, std, rng)
            };
            tensors.insert(name, m);
        }
        Checkpoint { config: config.clone(), tensors }
    }

    pub fn get(&self, name: &str) -> &Matrix {
        self.tensors
            .get(name)
            .unwrap_or_else(|| panic!("missing tensor {name}"))
    }

    pub fn get_mut(&mut self, name: &str) -> &mut Matrix {
        self.tensors
            .get_mut(name)
            .unwrap_or_else(|| panic!("missing tensor {name}"))
    }

    /// Validate the tensor set against the schema.
    pub fn validate(&self) -> Result<(), String> {
        for (name, rows, cols) in Checkpoint::tensor_schema(&self.config) {
            match self.tensors.get(&name) {
                None => return Err(format!("missing tensor {name}")),
                Some(m) if m.rows != rows || m.cols != cols => {
                    return Err(format!(
                        "tensor {name}: expected [{rows},{cols}], got [{},{}]",
                        m.rows, m.cols
                    ))
                }
                _ => {}
            }
        }
        if self.tensors.len() != Checkpoint::tensor_schema(&self.config).len() {
            return Err(format!(
                "unexpected extra tensors: have {}, schema {}",
                self.tensors.len(),
                Checkpoint::tensor_schema(&self.config).len()
            ));
        }
        Ok(())
    }

    pub fn save(&self, path: &Path) -> io::Result<()> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        let arch = match self.config.arch {
            Arch::Opt => 0u32,
            Arch::Llama => 1u32,
        };
        for v in [
            arch,
            self.config.vocab_size as u32,
            self.config.d_model as u32,
            self.config.n_heads as u32,
            self.config.n_layers as u32,
            self.config.d_ff as u32,
            self.config.max_seq as u32,
            self.tensors.len() as u32,
        ] {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        for (name, m) in &self.tensors {
            buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
            buf.extend_from_slice(name.as_bytes());
            buf.extend_from_slice(&(m.rows as u32).to_le_bytes());
            buf.extend_from_slice(&(m.cols as u32).to_le_bytes());
            for &x in &m.data {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        std::fs::write(path, buf)
    }

    pub fn load(path: &Path) -> io::Result<Checkpoint> {
        let mut f = std::fs::File::open(path)?;
        let mut data = Vec::new();
        f.read_to_end(&mut data)?;
        Checkpoint::from_bytes(&data)
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidData, e))
    }

    pub fn from_bytes(data: &[u8]) -> Result<Checkpoint, String> {
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> Result<&[u8], String> {
            if *pos + n > data.len() {
                return Err(format!("truncated at {pos}"));
            }
            let s = &data[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        let magic = take(&mut pos, 8)?;
        if magic != MAGIC {
            return Err("bad magic (not a .zqckpt file)".into());
        }
        let ru32 = |pos: &mut usize| -> Result<u32, String> {
            let b = take(pos, 4)?;
            Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        };
        let arch = match ru32(&mut pos)? {
            0 => Arch::Opt,
            1 => Arch::Llama,
            x => return Err(format!("unknown arch {x}")),
        };
        let vocab = ru32(&mut pos)? as usize;
        let d_model = ru32(&mut pos)? as usize;
        let n_heads = ru32(&mut pos)? as usize;
        let n_layers = ru32(&mut pos)? as usize;
        let d_ff = ru32(&mut pos)? as usize;
        let max_seq = ru32(&mut pos)? as usize;
        let n_tensors = ru32(&mut pos)? as usize;
        let mut tensors = BTreeMap::new();
        for _ in 0..n_tensors {
            let name_len = ru32(&mut pos)? as usize;
            let name = String::from_utf8(take(&mut pos, name_len)?.to_vec())
                .map_err(|e| e.to_string())?;
            let rows = ru32(&mut pos)? as usize;
            let cols = ru32(&mut pos)? as usize;
            let bytes = take(&mut pos, rows * cols * 4)?;
            let mut v = Vec::with_capacity(rows * cols);
            for c in bytes.chunks_exact(4) {
                v.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
            }
            tensors.insert(name, Matrix::from_vec(rows, cols, v));
        }
        if pos != data.len() {
            return Err(format!("{} trailing bytes", data.len() - pos));
        }
        let config = ModelConfig {
            name: "loaded".into(),
            arch,
            vocab_size: vocab,
            d_model,
            n_heads,
            n_layers,
            d_ff,
            max_seq,
        };
        let ck = Checkpoint { config, tensors };
        ck.validate()?;
        Ok(ck)
    }
}

// `Write` is used via buf writes above; silence unused-import pedantry by
// keeping the trait in scope for future streaming writers.
#[allow(unused)]
fn _assert_write_usable<W: Write>(_: W) {}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelConfig {
        ModelConfig {
            name: "test-tiny".into(),
            arch: Arch::Opt,
            vocab_size: 32,
            d_model: 16,
            n_heads: 2,
            n_layers: 2,
            d_ff: 32,
            max_seq: 8,
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let mut rng = Rng::seeded(91);
        let cfg = tiny();
        let ck = Checkpoint::random(&cfg, &mut rng);
        let dir = std::env::temp_dir().join("zqfp_test_ckpt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tiny.zqckpt");
        ck.save(&path).unwrap();
        let ck2 = Checkpoint::load(&path).unwrap();
        assert_eq!(ck.tensors.len(), ck2.tensors.len());
        for (name, m) in &ck.tensors {
            assert_eq!(m, ck2.get(name), "{name}");
        }
        assert_eq!(ck2.config.d_model, 16);
        assert_eq!(ck2.config.arch, Arch::Opt);
    }

    #[test]
    fn llama_schema_differs() {
        let mut cfg = tiny();
        cfg.arch = Arch::Llama;
        let schema = Checkpoint::tensor_schema(&cfg);
        assert!(schema.iter().any(|(n, _, _)| n.contains("mlp.gate")));
        assert!(!schema.iter().any(|(n, _, _)| n.contains("ln1.b")));
        let mut rng = Rng::seeded(92);
        let ck = Checkpoint::random(&cfg, &mut rng);
        assert!(ck.validate().is_ok());
    }

    #[test]
    fn validate_catches_missing_and_misshapen() {
        let mut rng = Rng::seeded(93);
        let cfg = tiny();
        let mut ck = Checkpoint::random(&cfg, &mut rng);
        ck.tensors.remove("embed");
        assert!(ck.validate().unwrap_err().contains("missing"));
        let mut ck = Checkpoint::random(&cfg, &mut rng);
        *ck.get_mut("embed") = Matrix::zeros(3, 3);
        assert!(ck.validate().unwrap_err().contains("expected"));
    }

    #[test]
    fn from_bytes_rejects_garbage() {
        assert!(Checkpoint::from_bytes(b"not a checkpoint").is_err());
        assert!(Checkpoint::from_bytes(b"ZQCKPT01").is_err()); // truncated
    }

    #[test]
    fn random_init_statistics() {
        let mut rng = Rng::seeded(94);
        let cfg = tiny();
        let ck = Checkpoint::random(&cfg, &mut rng);
        // norms init to 1, biases to 0
        assert!(ck.get("layers.0.ln1.g").data.iter().all(|&x| x == 1.0));
        assert!(ck.get("layers.0.attn.q.b").data.iter().all(|&x| x == 0.0));
        // weights non-degenerate
        let w = ck.get("layers.0.attn.q.w");
        assert!(w.fro_norm() > 0.1);
    }
}
