//! Function-preserving activation-outlier injection — the model-size
//! surrogate (DESIGN.md §4).
//!
//! The paper attributes the INT8-activation collapse of ≥6.7B models to
//! *emergent outlier channels* in the activations feeding `attn.out_proj`
//! and `fc2` (Figure 1, Table 1). Our synthetic models are far below the
//! emergence scale, so we reproduce the mechanism directly: pick `k`
//! channels of a positively-homogeneous pair of linears and rescale
//!
//! ```text
//!   producer.weight[ch, :] *= α      producer.bias[ch] *= α
//!   consumer.weight[:, ch] /= α
//! ```
//!
//! For `fc1 → relu → fc2` this is *exact* (relu(αz) = α·relu(z), α > 0);
//! for `v_proj → attention-mix → out_proj` it is exact because attention
//! mixes value vectors linearly per channel; for the LLaMA gated MLP we
//! rescale the `up` path (`down(silu(gate)·(up·x))` is linear in `up`).
//! The FP16 model's function is unchanged (up to f32 rounding); only the
//! *intermediate activations* gain outlier channels of relative magnitude
//! α — exactly the distribution pathology the paper quantizes against.

use crate::model::config::Arch;
use crate::model::Checkpoint;
use crate::rng::Rng;

/// Outlier injection parameters.
#[derive(Debug, Clone, Copy)]
pub struct OutlierSpec {
    /// Amplification factor (1.0 = no-op). The family default maps model
    /// size to severity: xs→1, s→4, m→16, l→64.
    pub alpha: f32,
    /// Number of channels amplified per site (paper models show a handful
    /// of dominant channels).
    pub channels: usize,
}

impl OutlierSpec {
    pub fn new(alpha: f32) -> Self {
        OutlierSpec { alpha, channels: 4 }
    }

    pub fn is_noop(&self) -> bool {
        self.alpha == 1.0 || self.channels == 0
    }
}

/// Apply outlier injection to every layer of the checkpoint, in place.
/// Channel choices are deterministic under `rng`.
pub fn inject_outliers(ck: &mut Checkpoint, spec: OutlierSpec, rng: &mut Rng) {
    if spec.is_noop() {
        return;
    }
    let n_layers = ck.config.n_layers;
    let d = ck.config.d_model;
    let ff = ck.config.d_ff;
    let arch = ck.config.arch;
    for layer in 0..n_layers {
        let p = format!("layers.{layer}");
        // --- MLP site: producer rows scaled by α, consumer cols by 1/α ---
        let (prod_w, prod_b, cons_w) = match arch {
            Arch::Opt => (
                format!("{p}.mlp.fc1.w"),
                Some(format!("{p}.mlp.fc1.b")),
                format!("{p}.mlp.fc2.w"),
            ),
            Arch::Llama => (format!("{p}.mlp.up.w"), None, format!("{p}.mlp.down.w")),
        };
        let chans: Vec<usize> = (0..spec.channels).map(|_| rng.below(ff)).collect();
        scale_pair(ck, &prod_w, prod_b.as_deref(), &cons_w, &chans, spec.alpha);
        // --- attention value site ---
        let chans: Vec<usize> = (0..spec.channels).map(|_| rng.below(d)).collect();
        scale_pair(
            ck,
            &format!("{p}.attn.v.w"),
            Some(&format!("{p}.attn.v.b")),
            &format!("{p}.attn.o.w"),
            &chans,
            spec.alpha,
        );
    }
}

fn scale_pair(
    ck: &mut Checkpoint,
    producer_w: &str,
    producer_b: Option<&str>,
    consumer_w: &str,
    channels: &[usize],
    alpha: f32,
) {
    {
        let w = ck.get_mut(producer_w);
        for &ch in channels {
            for v in w.row_mut(ch) {
                *v *= alpha;
            }
        }
    }
    if let Some(b) = producer_b {
        let bm = ck.get_mut(b);
        for &ch in channels {
            bm.data[ch] *= alpha;
        }
    }
    {
        let w = ck.get_mut(consumer_w);
        let inv = 1.0 / alpha;
        for r in 0..w.rows {
            let row = w.row_mut(r);
            for &ch in channels {
                row[ch] *= inv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Engine;
    use crate::model::config::ModelConfig;

    fn tiny(arch: Arch) -> ModelConfig {
        ModelConfig {
            name: "outlier-test".into(),
            arch,
            vocab_size: 32,
            d_model: 16,
            n_heads: 2,
            n_layers: 2,
            d_ff: 32,
            max_seq: 16,
        }
    }

    #[test]
    fn injection_preserves_function() {
        for arch in [Arch::Opt, Arch::Llama] {
            let cfg = tiny(arch);
            let mut rng = Rng::seeded(101);
            let ck = Checkpoint::random(&cfg, &mut rng);
            let mut ck2 = ck.clone();
            inject_outliers(&mut ck2, OutlierSpec { alpha: 32.0, channels: 3 }, &mut rng);

            let tokens: Vec<u16> = (0..12).map(|i| (i * 5 % 32) as u16).collect();
            let e1 = Engine::new(&ck);
            let e2 = Engine::new(&ck2);
            let l1 = e1.forward(&tokens);
            let l2 = e2.forward(&tokens);
            let rel = l1.sub(&l2).fro_norm() / l1.fro_norm().max(1e-12);
            assert!(rel < 2e-4, "{arch:?}: function changed, rel={rel}");
        }
    }

    #[test]
    fn injection_creates_activation_outliers() {
        let cfg = tiny(Arch::Opt);
        let mut rng = Rng::seeded(102);
        let ck = Checkpoint::random(&cfg, &mut rng);
        let mut ck2 = ck.clone();
        inject_outliers(&mut ck2, OutlierSpec { alpha: 64.0, channels: 2 }, &mut rng);
        let tokens: Vec<u16> = (0..12).map(|i| (i * 7 % 32) as u16).collect();

        let kurt = |ck: &Checkpoint| -> f64 {
            let eng = Engine::new(ck);
            let mut cap = crate::engine::ActivationCapture::default();
            eng.forward_observed(&tokens, &mut |site, x| cap.record(site, x));
            // max |fc2 input| relative to its rms across all layers
            cap.peak_to_rms(crate::engine::LinearSite::Fc2)
        };
        let before = kurt(&ck);
        let after = kurt(&ck2);
        // peak-to-rms saturates near sqrt(n/outlier_count) when the outlier
        // channels dominate the energy; 2x is already a strong signal at
        // this tiny width.
        assert!(after > before * 2.0, "before={before} after={after}");
    }

    #[test]
    fn noop_spec_changes_nothing() {
        let cfg = tiny(Arch::Opt);
        let mut rng = Rng::seeded(103);
        let ck = Checkpoint::random(&cfg, &mut rng);
        let mut ck2 = ck.clone();
        inject_outliers(&mut ck2, OutlierSpec::new(1.0), &mut rng);
        for (name, m) in &ck.tensors {
            assert_eq!(m, ck2.get(name), "{name}");
        }
    }
}
