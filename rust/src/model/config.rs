//! Model architecture configuration and the size family.
//!
//! Two architecture *variants* mirror the paper's two model families:
//!
//! * `Opt` — pre-LN decoder, LayerNorm, ReLU MLP (fc1/fc2), learned
//!   positions. This is the architecture whose fc2-input skew drives the
//!   paper's Figure 1 / Table 1 story.
//! * `Llama` — RMSNorm, gated-SiLU MLP (gate/up/down). (Rotary embeddings
//!   are replaced by learned positions on both variants to keep the Rust
//!   engine and the JAX model bit-comparable; positional encoding is
//!   orthogonal to quantization behaviour — noted in DESIGN.md.)
//!
//! The size family (`xs…l`) is the substitution for the paper's 1.3B–30B
//! axis; the emergent-outlier property of the large models is reproduced by
//! [`crate::model::outliers`] with a per-size default α.

/// MLP / norm flavour.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arch {
    /// LayerNorm + ReLU MLP (OPT-like).
    Opt,
    /// RMSNorm + gated SiLU MLP (LLaMA-like).
    Llama,
}

impl Arch {
    pub fn name(&self) -> &'static str {
        match self {
            Arch::Opt => "opt",
            Arch::Llama => "llama",
        }
    }

    pub fn parse(s: &str) -> Option<Arch> {
        match s.to_ascii_lowercase().as_str() {
            "opt" => Some(Arch::Opt),
            "llama" => Some(Arch::Llama),
            _ => None,
        }
    }
}

/// Full architecture hyper-parameters.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelConfig {
    pub name: String,
    pub arch: Arch,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub max_seq: usize,
}

impl ModelConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Parameter count (embeddings tied with the LM head).
    pub fn n_params(&self) -> usize {
        let d = self.d_model;
        let attn = 4 * d * d + 4 * d; // q,k,v,o + biases
        let mlp = match self.arch {
            Arch::Opt => 2 * self.d_ff * d + self.d_ff + d,
            Arch::Llama => 3 * self.d_ff * d + d, // gate/up/down, down bias only
        };
        let norms = match self.arch {
            Arch::Opt => 2 * 2 * d, // gain+bias per LN
            Arch::Llama => 2 * d,   // gain per RMSNorm
        };
        let per_layer = attn + mlp + norms;
        let final_norm = match self.arch {
            Arch::Opt => 2 * d,
            Arch::Llama => d,
        };
        self.vocab_size * d + self.max_seq * d + self.n_layers * per_layer + final_norm
    }

    /// The size family used throughout the experiments. The outlier α
    /// returned alongside is the per-size default injected amplification
    /// standing in for the paper's emergent-outlier severity (larger model
    /// ⇒ stronger outliers; see DESIGN.md §4).
    pub fn family(arch: Arch) -> Vec<(ModelConfig, f32)> {
        let mk = |tag: &str, d: usize, h: usize, l: usize| ModelConfig {
            name: format!("{}-{}", arch.name(), tag),
            arch,
            vocab_size: 512,
            d_model: d,
            n_heads: h,
            n_layers: l,
            d_ff: 4 * d,
            max_seq: 128,
        };
        // alpha calibrated so the INT8-activation collapse spreads across
        // the size axis like the paper's Table 1 (xs unaffected, l collapses
        // like OPT-66b; see EXPERIMENTS.md for the alpha sweep).
        vec![
            (mk("xs", 64, 2, 2), 1.0),
            (mk("s", 96, 4, 3), 32.0),
            (mk("m", 128, 4, 4), 192.0),
            (mk("l", 192, 6, 4), 768.0),
        ]
    }

    /// Look up a family member by its tag ("xs"…"l") or full name.
    pub fn by_name(name: &str) -> Option<(ModelConfig, f32)> {
        for arch in [Arch::Opt, Arch::Llama] {
            for (cfg, alpha) in ModelConfig::family(arch) {
                if cfg.name == name || cfg.name.ends_with(&format!("-{name}")) && name.len() <= 2 {
                    return Some((cfg, alpha));
                }
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_sizes_increase() {
        for arch in [Arch::Opt, Arch::Llama] {
            let fam = ModelConfig::family(arch);
            let mut last = 0;
            for (cfg, alpha) in &fam {
                let n = cfg.n_params();
                assert!(n > last, "{}: {n}", cfg.name);
                last = n;
                assert!(*alpha >= 1.0);
                assert_eq!(cfg.d_model % cfg.n_heads, 0);
            }
        }
    }

    #[test]
    fn lookup_by_name() {
        let (cfg, _) = ModelConfig::by_name("opt-m").unwrap();
        assert_eq!(cfg.d_model, 128);
        let (cfg, _) = ModelConfig::by_name("llama-xs").unwrap();
        assert_eq!(cfg.arch, Arch::Llama);
        assert!(ModelConfig::by_name("gpt-99").is_none());
    }

    #[test]
    fn param_counts_are_plausible() {
        let (cfg, _) = ModelConfig::by_name("opt-l").unwrap();
        // d=192, L=4: in the ~2-3M range
        let n = cfg.n_params();
        assert!((1_000_000..6_000_000).contains(&n), "{n}");
    }
}
