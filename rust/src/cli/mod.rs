//! The `zqfp` command-line interface (Layer-3 driver).

pub mod args;
pub mod commands;

pub use args::Args;

const USAGE: &str = "\
zqfp — ZeroQuant-FP: W4A8 post-training quantization with FP formats

USAGE: zqfp <command> [options]

Quantization + serving knobs are one typed recipe. `--recipe <name|path>`
pins a run to a preset or a saved JSON artifact; explicit flags override
recipe fields, and every boolean knob has an off-switch so a pinned
recipe is fully overridable (--no-lorc, --no-cast, --dense, --rtn/--gptq,
--kv-cache none). `zqfp recipe list` shows the in-tree presets.

commands:
  gen-corpus   --out data/ [--train-tokens N] [--eval-tokens N] [--calib-seqs N]
               write synthetic train/calib/eval token streams (.tok)
  info         --ckpt m.zqckpt           inspect a checkpoint
  recipe       list | show <name|path>   the named presets (w4a8-fp,
               w4a8-fp-m1, w4a8-fp-m2, w4a8-fp-lorc, w8a8-int, w16) and
               the JSON form of any recipe
  quantize     --ckpt m.zqckpt --out q.zqckpt [--recipe <name|path>]
               [--scheme w4a8-fp-fp]
               [--lorc [--lorc-rank N] [--lorc-format fp8|e5m2|f16]]
               [--constraint none|m1|m2|m2:<rows>]
               [--group N] [--rtn] [--cast] [--alpha A] [--data data/]
  eval         --ckpt m.zqckpt [--recipe <name|path>] [--scheme ...]
               [--corpus wiki|ptb|c4|all] [--data data/] [--seq N]
               [--max-tokens N] [--alpha A] [--runtime hlo|engine]
               [--artifacts artifacts/] [--packed [--gemv-threads N]]
               [--kernels oracle|fast]
               evaluate through the bit-packed weight plan (same bits,
               ~1/7 the weight bytes; composes with --lorc — factors
               ride along as codes); --kernels fast scores through the
               tolerance-gated 8-lane GEMV tier instead of the bit-exact
               oracle
  table        --id 1|2|3|a1 [--data data/] [--ckpt-dir ckpt/] [--fast]
               [--runtime hlo|engine] regenerate a paper table
  figure       --id 1|2 [--ckpt m.zqckpt] regenerate a paper figure
  serve        --ckpt m.zqckpt [--recipe <name|path>] [--requests N]
               [--clients N] [--scheme ...] [--max-batch N]
               [--max-wait-ms MS] [--artifacts artifacts/]
               window-scoring demo (PJRT when artifacts exist, else the
               compiled engine); with --generate N [--kv-cache e4m3|e5m2]
               serves continuous-batching KV-cached generation instead;
               --kv-page P stores generation K/V in a block-paged pool
               (P positions per page; resident bytes track live tokens)
               with --kv-budget BYTES capping the pool (admission waits
               and the youngest sequence is preempted + requeued when it
               runs dry; 0/absent = auto ring-equivalent budget);
               --packed [--gemv-threads N] serves from bit-packed weights
               (composes with --lorc: W4A8+LoRC at packed footprint);
               --kernels oracle|fast picks the kernel tier (fast = 8-lane
               GEMV + persistent decode worker pool, ULP/NLL
               tolerance-gated vs the bit-exact oracle default);
               --speculate <name|path> [--draft-k N] decodes
               speculatively: the named (strictly cheaper) draft recipe
               proposes up to N tokens per round and the target plan
               verifies them in one batched pass — output is exactly
               target-only greedy decode, only faster (--no-speculate
               strips a recipe-pinned draft);
               sampling knobs: --temperature T draws from
               softmax(logits/T) instead of greedy argmax (0 = greedy,
               the default, bit-for-bit), shaped by --top-k K and
               --top-p P, seeded by --seed S — draws hash the seed plus
               the token prefix, so outputs are reproducible and
               batch-composition-invariant;
               multi-turn sessions: --turns N splits each generation
               into an N-turn chat over a persistent session whose KV
               cache survives between turns (turn N+1 prefills only the
               token delta; output is bit-identical to the one-shot),
               --max-sessions N caps resident idle session caches (LRU
               eviction; an evicted session's next turn transparently
               re-prefills from its committed history);
               robustness knobs: --queue-depth N bounds admission (full
               queue sheds with a typed Overloaded), --deadline-ms MS
               puts a per-request deadline on every submission (0 = none),
               --fault <site>:<spec>[,...] injects deterministic faults
               for chaos drills (sites admission|prefill|decode|draft|
               respond; specs always|once|nth=K|every=K|p=F|stall=MS)
               with --fault-seed S pinning the probabilistic arms
  selfcheck    cross-check rust engine vs PJRT HLO on a tiny model
";

/// Entry point used by `main.rs` (and by integration tests).
pub fn run(argv: &[String]) -> Result<(), String> {
    if argv.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    let cmd = argv[0].as_str();
    let args = Args::parse(&argv[1..])?;
    match cmd {
        "gen-corpus" => commands::gen_corpus(&args),
        "info" => commands::info(&args),
        "recipe" => commands::recipe(&args),
        "quantize" => commands::quantize(&args),
        "eval" => commands::eval(&args),
        "table" => crate::experiments::run_table(&args),
        "figure" => crate::experiments::run_figure(&args),
        "serve" => commands::serve(&args),
        "selfcheck" => commands::selfcheck(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown command '{other}' (try `zqfp help`)")),
    }
}
