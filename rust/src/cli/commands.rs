//! Implementations of the non-experiment CLI commands.

use std::path::{Path, PathBuf};

use crate::cli::Args;
use crate::data::{read_tokens, write_tokens, Corpus, CorpusKind};
use crate::engine::EngineOpts;
use crate::formats::NumericFormat;
use crate::lorc::LorcConfig;
use crate::model::{inject_outliers, Checkpoint, OutlierSpec};
use crate::pipeline::{quantize_checkpoint, PtqConfig};
use crate::quant::{ScaleConstraint, Scheme};
use crate::rng::Rng;

pub fn gen_corpus(args: &Args) -> Result<(), String> {
    let out = PathBuf::from(args.get_or("out", "data"));
    let train_tokens = args.get_usize("train-tokens", 2_000_000)?;
    let eval_tokens = args.get_usize("eval-tokens", 8_192)?;
    let calib_seqs = args.get_usize("calib-seqs", 32)?;
    let seq = args.get_usize("seq", 128)?;
    args.finish()?;
    std::fs::create_dir_all(&out).map_err(|e| e.to_string())?;

    let train = Corpus::training_mixture(train_tokens);
    write_tokens(&out.join("train.tok"), &train).map_err(|e| e.to_string())?;
    println!("wrote {} train tokens -> {}", train.len(), out.join("train.tok").display());

    for kind in CorpusKind::ALL {
        let c = Corpus::new(kind);
        let eval = c.generate(eval_tokens, 1);
        let path = out.join(format!("eval_{}.tok", kind.name()));
        write_tokens(&path, &eval).map_err(|e| e.to_string())?;
        println!("wrote {} eval tokens -> {}", eval.len(), path.display());
    }
    // Calibration: like the paper, random sentences from the C4 surrogate.
    let calib = Corpus::new(CorpusKind::C4).generate(calib_seqs * seq, 2);
    write_tokens(&out.join("calib.tok"), &calib).map_err(|e| e.to_string())?;
    println!("wrote {} calib tokens ({} seqs x {})", calib.len(), calib_seqs, seq);
    Ok(())
}

pub fn info(args: &Args) -> Result<(), String> {
    let path = args.get("ckpt").ok_or("--ckpt required")?;
    args.finish()?;
    let ck = Checkpoint::load(Path::new(&path)).map_err(|e| e.to_string())?;
    let c = &ck.config;
    println!(
        "arch={} vocab={} d_model={} heads={} layers={} d_ff={} max_seq={}",
        c.arch.name(),
        c.vocab_size,
        c.d_model,
        c.n_heads,
        c.n_layers,
        c.d_ff,
        c.max_seq
    );
    println!("params={} tensors={}", c.n_params(), ck.tensors.len());
    let mut names: Vec<_> = ck.tensors.keys().collect();
    names.sort();
    for n in names.iter().take(8) {
        let m = ck.get(n);
        println!("  {n} [{}x{}] fro={:.4}", m.rows, m.cols, m.fro_norm());
    }
    if names.len() > 8 {
        println!("  ... {} more", names.len() - 8);
    }
    Ok(())
}

/// Shared: load checkpoint and optionally apply outlier injection.
pub fn load_ckpt_with_alpha(path: &Path, alpha: f32) -> Result<Checkpoint, String> {
    let mut ck = Checkpoint::load(path).map_err(|e| e.to_string())?;
    if alpha != 1.0 {
        let mut rng = Rng::seeded(0xA11CE);
        inject_outliers(&mut ck, OutlierSpec::new(alpha), &mut rng);
    }
    Ok(ck)
}

/// The one wording of the `--packed`-without-codes rejection, shared by
/// `zqfp eval` and `zqfp serve` so the restriction lives (and is tested)
/// in exactly one place. Only W16 trips it now — LoRC runs keep their
/// codes (+ factors) in the sidecar and serve packed.
pub const PACKED_NEEDS_CODES: &str =
    "--packed needs quantized codes: pick a quantized --scheme (W16 leaves nothing to pack)";

/// Shared: build a PtqConfig from CLI flags.
pub fn ptq_config_from_args(args: &Args, scheme: Scheme) -> Result<PtqConfig, String> {
    let mut cfg = PtqConfig::new(scheme);
    cfg.group_size = args.get_usize("group", 64)?;
    cfg.use_gptq = !args.flag("rtn");
    cfg.cast_fp4_to_e5m2 = args.flag("cast");
    if let Some(c) = args.get("constraint") {
        cfg.constraint =
            ScaleConstraint::parse(&c).ok_or(format!("bad --constraint {c}"))?;
    }
    if args.flag("lorc") {
        // a valueless `--lorc-rank`/`--lorc-format`/`--rank` would
        // silently fall back to the default (Args stores a sentinel `get`
        // reports as absent) — reject instead of guessing
        for knob in ["lorc-rank", "lorc-format", "rank"] {
            if args.flag(knob) && args.get(knob).is_none() {
                return Err(format!("--{knob} needs a value"));
            }
        }
        // --rank is the historical spelling; --lorc-rank wins when both
        // are given.
        let rank = args.get_usize("lorc-rank", args.get_usize("rank", 8)?)?;
        if rank == 0 {
            return Err("--lorc-rank must be at least 1".to_string());
        }
        let fmt_s = args.get_or("lorc-format", "fp8-e4m3");
        let factor_format = match NumericFormat::parse(&fmt_s) {
            Some(f @ (NumericFormat::F16 | NumericFormat::Fp(_))) => f,
            Some(_) => {
                return Err(format!(
                    "--lorc-format: factors are stored FP or F16, not integer: {fmt_s}"
                ))
            }
            None => return Err(format!("bad --lorc-format {fmt_s}")),
        };
        cfg.lorc = Some(LorcConfig { rank, factor_format });
    } else {
        let _ = args.get_usize("rank", 8)?; // historical knob: consumed leniently
        // the new knobs without --lorc are almost certainly a dropped flag —
        // silently serving without compensation would be a quality surprise.
        // (`flag`, not `get`: a valueless knob must trip this too.)
        if args.flag("lorc-rank") || args.flag("lorc-format") {
            return Err("--lorc-rank/--lorc-format have no effect without --lorc".to_string());
        }
    }
    Ok(cfg)
}

/// Load calibration sequences from `<data>/calib.tok`.
pub fn load_calib(data: &Path, seq: usize) -> Result<Vec<Vec<u16>>, String> {
    let toks = read_tokens(&data.join("calib.tok"))
        .map_err(|e| format!("calib.tok: {e} (run `zqfp gen-corpus` first)"))?;
    Ok(toks.chunks_exact(seq).map(|c| c.to_vec()).collect())
}

pub fn quantize(args: &Args) -> Result<(), String> {
    let ckpt = args.get("ckpt").ok_or("--ckpt required")?;
    let out = args.get("out").ok_or("--out required")?;
    let scheme_s = args.get_or("scheme", "w4a8-fp-fp");
    let scheme = Scheme::parse(&scheme_s).ok_or(format!("bad --scheme {scheme_s}"))?;
    let data = PathBuf::from(args.get_or("data", "data"));
    let seq = args.get_usize("seq", 128)?;
    let alpha = args.get_f32("alpha", 1.0)?;
    let cfg = ptq_config_from_args(args, scheme)?;
    args.finish()?;

    let ck = load_ckpt_with_alpha(Path::new(&ckpt), alpha)?;
    let calib = load_calib(&data, seq.min(ck.config.max_seq))?;
    let t0 = std::time::Instant::now();
    let (qck, report) = quantize_checkpoint(&ck, &calib, &cfg);
    qck.save(Path::new(&out)).map_err(|e| e.to_string())?;
    println!(
        "{}: quantized {} tensors in {:?}",
        report.scheme_name,
        report.layers.len(),
        t0.elapsed()
    );
    println!(
        "  fp16 {} B -> quant {} B  ({:.2}x compression)",
        report.fp16_bytes,
        report.quant_bytes,
        report.compression()
    );
    println!("  mean weight-mse {:.3e}", report.total_weight_mse());
    println!("  wrote effective checkpoint -> {out}");
    Ok(())
}

pub fn eval(args: &Args) -> Result<(), String> {
    let ckpt = args.get("ckpt").ok_or("--ckpt required")?;
    let data = PathBuf::from(args.get_or("data", "data"));
    let seq = args.get_usize("seq", 128)?;
    let max_tokens = args.get_usize("max-tokens", usize::MAX)?;
    let alpha = args.get_f32("alpha", 1.0)?;
    let corpus = args.get_or("corpus", "all");
    let runtime = args.get_or("runtime", "engine");
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let packed = args.flag("packed");
    let gemv_threads = args.get_usize("gemv-threads", 1)?;
    let scheme_s = args.get("scheme");

    let ck = load_ckpt_with_alpha(Path::new(&ckpt), alpha)?;
    // If a scheme is given, quantize first (weights) and set act format.
    let (ck, mut opts, sidecar) = match &scheme_s {
        None => {
            args.finish()?;
            (ck, EngineOpts::default(), crate::quant::QuantSidecar::new())
        }
        Some(s) => {
            let scheme = Scheme::parse(s).ok_or(format!("bad --scheme {s}"))?;
            let cfg = ptq_config_from_args(args, scheme)?;
            args.finish()?;
            let calib = load_calib(&data, seq.min(ck.config.max_seq))?;
            let (qck, sidecar, _) = crate::pipeline::quantize_checkpoint_full(&ck, &calib, &cfg);
            (qck, cfg.engine_opts(), sidecar)
        }
    };

    // --packed: evaluate through the bit-packed weight plan (bit-identical
    // logits; this flag changes memory and speed, never numbers).
    let packed_model = if packed {
        if runtime == "hlo" {
            return Err("--packed runs in-process; drop --runtime hlo".to_string());
        }
        if sidecar.is_empty() {
            return Err(PACKED_NEEDS_CODES.to_string());
        }
        opts = opts.packed(gemv_threads);
        let model = crate::plan::CompiledModel::compile_quantized(&ck, &sidecar, opts);
        println!(
            "packed plan: {} B of linear weights{} ({} gemv threads)",
            model.linear_weight_bytes(),
            if sidecar.has_lorc() { " incl. LoRC factors" } else { "" },
            opts.weights.threads()
        );
        Some(model)
    } else {
        None
    };

    let kinds: Vec<CorpusKind> = if corpus == "all" {
        CorpusKind::ALL.to_vec()
    } else {
        vec![CorpusKind::parse(&corpus).ok_or(format!("bad --corpus {corpus}"))?]
    };
    let mut ppls = Vec::new();
    for kind in kinds {
        let toks = read_tokens(&data.join(format!("eval_{}.tok", kind.name())))
            .map_err(|e| format!("eval_{}.tok: {e}", kind.name()))?;
        let toks = &toks[..toks.len().min(max_tokens)];
        let seqn = seq.min(ck.config.max_seq);
        let r = if let Some(model) = &packed_model {
            crate::eval::perplexity_model(model, toks, seqn)
        } else if runtime == "hlo" {
            crate::runtime::hlo_perplexity(&artifacts, &ck, &opts, toks, seqn)
                .map_err(|e| e.to_string())?
        } else {
            crate::eval::perplexity(&ck, opts, toks, seqn)
        };
        println!("{}: ppl {:.4}  ({} tokens)", kind.name(), r.ppl(), r.tokens);
        ppls.push(r.ppl());
    }
    if ppls.len() > 1 {
        println!("mean: {:.4}", ppls.iter().sum::<f64>() / ppls.len() as f64);
    }
    Ok(())
}

pub fn serve(args: &Args) -> Result<(), String> {
    crate::coordinator::serve_command(args)
}

pub fn selfcheck(args: &Args) -> Result<(), String> {
    crate::runtime::selfcheck(args)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn constraint_m2_rows_threads_through_cli() {
        let scheme = Scheme::parse("w4a8-fp-fp").unwrap();
        let args = Args::parse(&argv(&["--constraint", "m2:16"])).unwrap();
        let cfg = ptq_config_from_args(&args, scheme).unwrap();
        assert_eq!(cfg.constraint, ScaleConstraint::M2 { rows: 16 });
        // zero-row compute groups are rejected with a parse error
        let bad = Args::parse(&argv(&["--constraint", "m2:0"])).unwrap();
        assert!(ptq_config_from_args(&bad, scheme).is_err());
        // default stays the paper's 32-row group
        let dflt = Args::parse(&argv(&["--constraint", "m2"])).unwrap();
        assert_eq!(
            ptq_config_from_args(&dflt, scheme).unwrap().constraint,
            ScaleConstraint::M2 { rows: 32 }
        );
    }

    #[test]
    fn lorc_rank_and_format_thread_through_cli() {
        let scheme = Scheme::parse("w4a8-fp-fp").unwrap();
        let args =
            Args::parse(&argv(&["--lorc", "--lorc-rank", "16", "--lorc-format", "f16"])).unwrap();
        let l = ptq_config_from_args(&args, scheme).unwrap().lorc.unwrap();
        assert_eq!(l.rank, 16);
        assert!(matches!(l.factor_format, NumericFormat::F16));
        // the historical --rank spelling still works (and FP8 E4M3 stays
        // the default factor format)
        let args = Args::parse(&argv(&["--lorc", "--rank", "4"])).unwrap();
        let l = ptq_config_from_args(&args, scheme).unwrap().lorc.unwrap();
        assert_eq!(l.rank, 4);
        assert_eq!(l.factor_format, NumericFormat::FP8_E4M3);
        // integer factor formats and rank 0 are rejected
        let bad = Args::parse(&argv(&["--lorc", "--lorc-format", "int8"])).unwrap();
        assert!(ptq_config_from_args(&bad, scheme).is_err());
        let bad = Args::parse(&argv(&["--lorc", "--lorc-rank", "0"])).unwrap();
        assert!(ptq_config_from_args(&bad, scheme).is_err());
        // LoRC knobs without --lorc are a dropped-flag mistake, not a no-op
        // — with a value or bare (the bare form parses as a sentinel flag)
        let off = Args::parse(&argv(&["--lorc-rank", "4"])).unwrap();
        assert!(ptq_config_from_args(&off, scheme).is_err());
        let bare = Args::parse(&argv(&["--lorc-format"])).unwrap();
        assert!(ptq_config_from_args(&bare, scheme).is_err());
        // a valueless knob under --lorc is rejected, not defaulted
        let noval = Args::parse(&argv(&["--lorc", "--lorc-rank"])).unwrap();
        assert!(ptq_config_from_args(&noval, scheme).is_err());
        // ...but the bare run (no LoRC flags at all) stays clean
        let none = Args::parse(&argv(&[])).unwrap();
        assert!(ptq_config_from_args(&none, scheme).unwrap().lorc.is_none());
        assert!(none.finish().is_ok());
    }
}
