//! Implementations of the non-experiment CLI commands.
//!
//! Every quantization/serving knob flows through one translation —
//! [`QuantRecipe::from_args`] — so `quantize`, `eval` and `serve` cannot
//! drift apart, and any run can be pinned to a reproducible artifact with
//! `--recipe <path|preset>` (explicit flags still override; see
//! `zqfp recipe list`).

use std::path::{Path, PathBuf};

use crate::cli::Args;
use crate::coordinator::ServingStack;
use crate::data::{read_tokens, write_tokens, Corpus, CorpusKind};
use crate::model::{inject_outliers, Checkpoint, OutlierSpec};
use crate::pipeline::ptq;
use crate::recipe::{PRESET_NAMES, QuantRecipe};
use crate::rng::Rng;

pub fn gen_corpus(args: &Args) -> Result<(), String> {
    let out = PathBuf::from(args.get_or("out", "data"));
    let train_tokens = args.get_usize("train-tokens", 2_000_000)?;
    let eval_tokens = args.get_usize("eval-tokens", 8_192)?;
    let calib_seqs = args.get_usize("calib-seqs", 32)?;
    let seq = args.get_usize("seq", 128)?;
    args.finish()?;
    std::fs::create_dir_all(&out).map_err(|e| e.to_string())?;

    let train = Corpus::training_mixture(train_tokens);
    write_tokens(&out.join("train.tok"), &train).map_err(|e| e.to_string())?;
    println!("wrote {} train tokens -> {}", train.len(), out.join("train.tok").display());

    for kind in CorpusKind::ALL {
        let c = Corpus::new(kind);
        let eval = c.generate(eval_tokens, 1);
        let path = out.join(format!("eval_{}.tok", kind.name()));
        write_tokens(&path, &eval).map_err(|e| e.to_string())?;
        println!("wrote {} eval tokens -> {}", eval.len(), path.display());
    }
    // Calibration: like the paper, random sentences from the C4 surrogate.
    let calib = Corpus::new(CorpusKind::C4).generate(calib_seqs * seq, 2);
    write_tokens(&out.join("calib.tok"), &calib).map_err(|e| e.to_string())?;
    println!("wrote {} calib tokens ({} seqs x {})", calib.len(), calib_seqs, seq);
    Ok(())
}

pub fn info(args: &Args) -> Result<(), String> {
    let path = args.get("ckpt").ok_or("--ckpt required")?;
    args.finish()?;
    let ck = Checkpoint::load(Path::new(&path)).map_err(|e| e.to_string())?;
    let c = &ck.config;
    println!(
        "arch={} vocab={} d_model={} heads={} layers={} d_ff={} max_seq={}",
        c.arch.name(),
        c.vocab_size,
        c.d_model,
        c.n_heads,
        c.n_layers,
        c.d_ff,
        c.max_seq
    );
    println!("params={} tensors={}", c.n_params(), ck.tensors.len());
    let mut names: Vec<_> = ck.tensors.keys().collect();
    names.sort();
    for n in names.iter().take(8) {
        let m = ck.get(n);
        println!("  {n} [{}x{}] fro={:.4}", m.rows, m.cols, m.fro_norm());
    }
    if names.len() > 8 {
        println!("  ... {} more", names.len() - 8);
    }
    Ok(())
}

/// `zqfp recipe list` / `zqfp recipe show <name|path>` — inspect the typed
/// configuration artifacts every quantize/eval/serve run is driven by.
pub fn recipe(args: &Args) -> Result<(), String> {
    let sub = args.positional.first().map(String::as_str).unwrap_or("list");
    match sub {
        "list" => {
            args.finish()?;
            for name in PRESET_NAMES {
                let r = QuantRecipe::preset(name).map_err(|e| e.to_string())?;
                println!("{name:<14} {}", r.summary());
            }
            println!("\nuse with: zqfp serve|eval|quantize --recipe <name|path> [overrides]");
            println!("inspect:  zqfp recipe show <name|path>");
            Ok(())
        }
        "show" => {
            let spec = args
                .positional
                .get(1)
                .ok_or("usage: zqfp recipe show <name|path>")?
                .clone();
            args.finish()?;
            let r = QuantRecipe::load(&spec)?;
            println!("{}", r.to_json_pretty());
            Ok(())
        }
        other => Err(format!("unknown recipe subcommand '{other}' (try: list, show <name|path>)")),
    }
}

/// Shared: load checkpoint and optionally apply outlier injection.
pub fn load_ckpt_with_alpha(path: &Path, alpha: f32) -> Result<Checkpoint, String> {
    let mut ck = Checkpoint::load(path).map_err(|e| e.to_string())?;
    if alpha != 1.0 {
        let mut rng = Rng::seeded(0xA11CE);
        inject_outliers(&mut ck, OutlierSpec::new(alpha), &mut rng);
    }
    Ok(ck)
}

/// Load calibration sequences from `<data>/calib.tok`.
pub fn load_calib(data: &Path, seq: usize) -> Result<Vec<Vec<u16>>, String> {
    let toks = read_tokens(&data.join("calib.tok"))
        .map_err(|e| format!("calib.tok: {e} (run `zqfp gen-corpus` first)"))?;
    Ok(toks.chunks_exact(seq).map(|c| c.to_vec()).collect())
}

/// Calibration data for `recipe`: loaded only when the recipe actually
/// consumes it (GPTQ), so RTN/W16 runs work without a calib.tok.
fn calib_for(recipe: &QuantRecipe, data: &Path, seq: usize) -> Result<Vec<Vec<u16>>, String> {
    if recipe.needs_calibration() {
        load_calib(data, seq)
    } else {
        Ok(Vec::new())
    }
}

pub fn quantize(args: &Args) -> Result<(), String> {
    let ckpt = args.get("ckpt").ok_or("--ckpt required")?;
    let out = args.get("out").ok_or("--out required")?;
    let data = PathBuf::from(args.get_or("data", "data"));
    let seq = args.get_usize("seq", 128)?;
    let alpha = args.get_f32("alpha", 1.0)?;
    let recipe = QuantRecipe::from_args(args, "w4a8-fp")?;
    args.finish()?;

    let ck = load_ckpt_with_alpha(Path::new(&ckpt), alpha)?;
    let calib = calib_for(&recipe, &data, seq.min(ck.config.max_seq))?;
    let t0 = std::time::Instant::now();
    let result = ptq(&ck, &calib, None, &recipe);
    drop(ck); // only the effective checkpoint is written out
    result.checkpoint.save(Path::new(&out)).map_err(|e| e.to_string())?;
    let report = &result.report;
    println!(
        "{}: quantized {} tensors in {:?}",
        report.scheme_name,
        report.layers.len(),
        t0.elapsed()
    );
    println!(
        "  fp16 {} B -> quant {} B  ({:.2}x compression)",
        report.fp16_bytes,
        report.quant_bytes,
        report.compression()
    );
    println!("  mean weight-mse {:.3e}", report.total_weight_mse());
    println!("  wrote effective checkpoint -> {out}");
    Ok(())
}

pub fn eval(args: &Args) -> Result<(), String> {
    let ckpt = args.get("ckpt").ok_or("--ckpt required")?;
    let data = PathBuf::from(args.get_or("data", "data"));
    let seq = args.get_usize("seq", 128)?;
    let max_tokens = args.get_usize("max-tokens", usize::MAX)?;
    let alpha = args.get_f32("alpha", 1.0)?;
    let corpus = args.get_or("corpus", "all");
    let runtime = args.get_or("runtime", "engine");
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    // eval defaults to the no-op W16 recipe: with no quantization flags it
    // scores the checkpoint exactly as stored (the pre-recipe behavior).
    let recipe = QuantRecipe::from_args(args, "w16")?;
    args.finish()?;
    let packed = !recipe.weights.is_dense();
    if packed && runtime == "hlo" {
        return Err("--packed runs in-process; drop --runtime hlo".to_string());
    }

    let ck = load_ckpt_with_alpha(Path::new(&ckpt), alpha)?;
    let max_seq = ck.config.max_seq;
    let calib = calib_for(&recipe, &data, seq.min(max_seq))?;
    let stack = ServingStack::build(&ck, &calib, &recipe).map_err(|e| e.to_string())?;
    drop(ck); // the stack's effective checkpoint is the one being scored
    let opts = recipe.engine_opts();

    // --packed (or a packed recipe): evaluate through the bit-packed
    // weight plan (bit-identical logits; this knob changes memory and
    // speed, never numbers).
    let packed_model = if packed {
        let model = stack.compile();
        println!(
            "packed plan: {} B of linear weights{} ({} gemv threads, {} kernels)",
            model.linear_weight_bytes(),
            if stack.sidecar.has_lorc() { " incl. LoRC factors" } else { "" },
            recipe.weights.threads(),
            recipe.kernel_tier.name()
        );
        Some(model)
    } else {
        None
    };

    let kinds: Vec<CorpusKind> = if corpus == "all" {
        CorpusKind::ALL.to_vec()
    } else {
        vec![CorpusKind::parse(&corpus).ok_or(format!("bad --corpus {corpus}"))?]
    };
    let mut ppls = Vec::new();
    for kind in kinds {
        let toks = read_tokens(&data.join(format!("eval_{}.tok", kind.name())))
            .map_err(|e| format!("eval_{}.tok: {e}", kind.name()))?;
        let toks = &toks[..toks.len().min(max_tokens)];
        let seqn = seq.min(max_seq);
        let r = if let Some(model) = &packed_model {
            crate::eval::perplexity_model(model, toks, seqn)
        } else if runtime == "hlo" {
            crate::runtime::hlo_perplexity(&artifacts, &stack.checkpoint, &opts, toks, seqn)
                .map_err(|e| e.to_string())?
        } else {
            crate::eval::perplexity(&stack.checkpoint, opts, toks, seqn)
        };
        println!("{}: ppl {:.4}  ({} tokens)", kind.name(), r.ppl(), r.tokens);
        ppls.push(r.ppl());
    }
    if ppls.len() > 1 {
        println!("mean: {:.4}", ppls.iter().sum::<f64>() / ppls.len() as f64);
    }
    Ok(())
}

pub fn serve(args: &Args) -> Result<(), String> {
    crate::coordinator::serve_command(args)
}

pub fn selfcheck(args: &Args) -> Result<(), String> {
    crate::runtime::selfcheck(args)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::WeightLayout;
    use crate::quant::ScaleConstraint;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn recipe_list_and_show_run() {
        let list = Args::parse(&argv(&["list"])).unwrap();
        recipe(&list).unwrap();
        for name in PRESET_NAMES {
            let show = Args::parse(&argv(&["show", name])).unwrap();
            recipe(&show).unwrap();
        }
        let bogus = Args::parse(&argv(&["show", "not-a-preset-or-file"])).unwrap();
        assert!(recipe(&bogus).is_err());
        let bad_sub = Args::parse(&argv(&["frobnicate"])).unwrap();
        assert!(recipe(&bad_sub).is_err());
    }

    #[test]
    fn serve_and_eval_share_one_translation() {
        // the drift-prone knobs — constraint, LoRC, packed, kv-cache —
        // resolve identically no matter which command parses them, because
        // both go through QuantRecipe::from_args (with their own default
        // preset)
        let flags = argv(&[
            "--scheme",
            "w4a8-fp-fp",
            "--constraint",
            "m2:16",
            "--lorc",
            "--lorc-rank",
            "4",
            "--packed",
            "--gemv-threads",
            "2",
        ]);
        let serve_r = QuantRecipe::from_args(&Args::parse(&flags).unwrap(), "w4a8-fp").unwrap();
        let eval_r = QuantRecipe::from_args(&Args::parse(&flags).unwrap(), "w16").unwrap();
        assert_eq!(serve_r.constraint, eval_r.constraint);
        assert_eq!(serve_r.constraint, ScaleConstraint::M2 { rows: 16 });
        assert_eq!(serve_r.lorc, eval_r.lorc);
        assert_eq!(serve_r.weights, WeightLayout::Packed { threads: 2 });
        assert_eq!(serve_r.weights, eval_r.weights);
        assert_eq!(serve_r.scheme, eval_r.scheme);
        // only the per-command default differs — and only when the flag
        // soup doesn't pin the scheme
        let bare_serve = QuantRecipe::from_args(&Args::parse(&argv(&[])).unwrap(), "w4a8-fp");
        let bare_eval = QuantRecipe::from_args(&Args::parse(&argv(&[])).unwrap(), "w16");
        assert_eq!(bare_serve.unwrap().name, "w4a8-fp");
        assert_eq!(bare_eval.unwrap().name, "w16");
    }
}
