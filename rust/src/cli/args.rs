//! Minimal argument parsing (clap is not in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, and positional arguments. Unknown
//! flags are an error; every accessor records the keys it saw so
//! [`Args::finish`] can report typos.

use std::collections::{BTreeMap, BTreeSet};

#[derive(Debug)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    used: std::cell::RefCell<BTreeSet<String>>,
}

pub const FLAG_SENTINEL: &str = "\u{1}true";

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args, String> {
        let mut positional = Vec::new();
        let mut flags = BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                let key = key.to_string();
                if key.is_empty() {
                    return Err("bare '--' not supported".into());
                }
                // `--key=value` or `--key value` or boolean `--key`
                if let Some((k, v)) = key.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key, argv[i + 1].clone());
                    i += 1;
                } else {
                    flags.insert(key, FLAG_SENTINEL.to_string());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Args { positional, flags, used: Default::default() })
    }

    pub fn flag(&self, key: &str) -> bool {
        self.used.borrow_mut().insert(key.to_string());
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<String> {
        self.used.borrow_mut().insert(key.to_string());
        let v = self.flags.get(key)?;
        if v == FLAG_SENTINEL {
            None
        } else {
            Some(v.clone())
        }
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or_else(|| default.to_string())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: not a number: {v}")),
        }
    }

    pub fn get_f32(&self, key: &str, default: f32) -> Result<f32, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{key}: not a number: {v}")),
        }
    }

    /// Error on any flag never consumed by an accessor.
    pub fn finish(&self) -> Result<(), String> {
        let used = self.used.borrow();
        let unknown: Vec<&String> =
            self.flags.keys().filter(|k| !used.contains(*k)).collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(format!("unknown flags: {unknown:?}"))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        // `--key value` is greedy; boolean flags must precede another flag
        // or the end (documented semantics).
        let a = Args::parse(&argv(&["cmd", "--n", "5", "pos2", "--k=v", "--fast"])).unwrap();
        assert_eq!(a.positional, vec!["cmd", "pos2"]);
        assert_eq!(a.get_usize("n", 0).unwrap(), 5);
        assert!(a.flag("fast"));
        assert_eq!(a.get("k").unwrap(), "v");
        assert!(a.finish().is_ok());
    }

    #[test]
    fn unknown_flags_detected() {
        let a = Args::parse(&argv(&["--oops", "1"])).unwrap();
        assert!(a.finish().is_err());
        let _ = a.get("oops");
        assert!(a.finish().is_ok());
    }

    #[test]
    fn boolean_flag_before_positional() {
        let a = Args::parse(&argv(&["--verbose", "--n", "3"])).unwrap();
        // "--verbose" greedily consumed "--n"? no: next starts with -- so
        // verbose is boolean.
        assert!(a.flag("verbose"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 3);
    }

    #[test]
    fn numeric_errors() {
        let a = Args::parse(&argv(&["--n", "abc"])).unwrap();
        assert!(a.get_usize("n", 0).is_err());
    }
}
