//! `zqfp` — the ZeroQuant-FP command-line driver (Layer 3 entrypoint).
//!
//! Subcommands:
//!   gen-corpus   write the synthetic train/calib/eval token streams
//!   info         inspect a .zqckpt checkpoint
//!   quantize     run the PTQ pipeline on a checkpoint
//!   eval         perplexity of a (quantized) checkpoint on the corpora
//!   table        regenerate a paper table   (1 | 2 | 3 | a1)
//!   figure       regenerate a paper figure  (1 | 2)
//!   serve        PJRT serving demo through the coordinator
//!
//! No clap offline — a small hand-rolled arg parser in `cli`.

use zeroquant_fp::cli;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = cli::run(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
