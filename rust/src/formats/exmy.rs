//! Generic `ExMy` low-bit floating-point codec.
//!
//! ZeroQuant-FP quantizes to floating-point *values* rather than integer
//! levels. A format `ExMy` allocates `x` exponent bits and `y` mantissa bits
//! (plus one sign bit). This module implements the codec the paper actually
//! used: **qtorch semantics** (footnote 3) — IEEE-style subnormals,
//! round-to-nearest-even, *no* reserved NaN/Inf encodings, saturate to the
//! largest finite value — plus the NVIDIA H100 `E4M3` variant that reserves
//! the all-ones mantissa pattern at the top exponent for NaN (max 448
//! instead of 480).
//!
//! All arithmetic goes through `f64` intermediates; every scaling step is by
//! a power of two, so the rounding decision (`round_ties_even`) is exact and
//! the codec is bit-reproducible. `python/compile/kernels/fpq.py` mirrors
//! this algorithm in jnp and is held bit-equal by cross-layer tests.

/// A low-bit floating-point format description (sign + exponent + mantissa).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FpFormat {
    /// Number of exponent bits (`x` in `ExMy`). Must be >= 1.
    pub exp_bits: u32,
    /// Number of mantissa bits (`y` in `ExMy`). May be 0 (e.g. E3M0).
    pub man_bits: u32,
    /// Exponent bias. IEEE-style default is `2^(x-1) - 1`.
    pub bias: i32,
    /// If true, the all-ones-exponent/all-ones-mantissa code is reserved for
    /// NaN (NVIDIA E4M3 convention), shrinking the max finite value.
    pub nan_reserved: bool,
    /// If true, the whole top exponent field is reserved for Inf/NaN (IEEE
    /// convention, used by E5M2/F16/BF16), shrinking the max finite value
    /// by one binade.
    pub inf_reserved: bool,
}

impl FpFormat {
    /// Construct an IEEE-biased format: bias = 2^(x-1) - 1, no reserved
    /// codes (the qtorch / OCP-MX convention for the narrow formats).
    pub const fn new(exp_bits: u32, man_bits: u32) -> Self {
        FpFormat {
            exp_bits,
            man_bits,
            bias: (1 << (exp_bits - 1)) - 1,
            nan_reserved: false,
            inf_reserved: false,
        }
    }

    /// Same but with the IEEE top-exponent Inf/NaN reservation.
    pub const fn new_ieee(exp_bits: u32, man_bits: u32) -> Self {
        FpFormat {
            exp_bits,
            man_bits,
            bias: (1 << (exp_bits - 1)) - 1,
            nan_reserved: false,
            inf_reserved: true,
        }
    }

    /// FP8 E4M3, qtorch semantics (max finite 480). The paper's default FP8
    /// weight/activation format (Section 4: E4M3 outperforms E5M2).
    pub const E4M3: FpFormat = FpFormat::new(4, 3);
    /// FP8 E5M2, IEEE/OCP semantics (max finite 57344; exponent 31 is
    /// Inf/NaN). Used as the cast target when converting FP4 weights to FP8
    /// (footnote 4).
    pub const E5M2: FpFormat = FpFormat::new_ieee(5, 2);
    /// FP4 E2M1 (values 0, .5, 1, 1.5, 2, 3, 4, 6). The paper's best FP4.
    pub const E2M1: FpFormat = FpFormat::new(2, 1);
    /// FP4 E3M0 (pure powers of two, 0.25 .. 16). Table A.1 baseline.
    pub const E3M0: FpFormat = FpFormat::new(3, 0);
    /// NVIDIA H100 E4M3 (max finite 448; all-ones code is NaN).
    pub const E4M3_NV: FpFormat = FpFormat {
        exp_bits: 4,
        man_bits: 3,
        bias: 7,
        nan_reserved: true,
        inf_reserved: false,
    };
    /// FP16 (IEEE binary16), used for LoRC factor storage experiments.
    pub const F16: FpFormat = FpFormat::new_ieee(5, 10);
    /// BF16 (truncation of f32), the MXU-native activation dtype on TPU.
    pub const BF16: FpFormat = FpFormat::new_ieee(8, 7);

    /// Total number of code bits, including sign.
    pub fn total_bits(&self) -> u32 {
        1 + self.exp_bits + self.man_bits
    }

    /// Number of distinct codes (2^bits).
    pub fn code_count(&self) -> usize {
        1usize << self.total_bits()
    }

    /// Largest biased exponent field value that encodes a finite number.
    fn max_exp_field(&self) -> i32 {
        let all_ones = (1i32 << self.exp_bits) - 1;
        if self.inf_reserved {
            all_ones - 1
        } else {
            all_ones
        }
    }

    /// Largest finite representable magnitude.
    pub fn max_finite(&self) -> f64 {
        let e = self.max_exp_field() - self.bias;
        let man_max = if self.nan_reserved && self.man_bits > 0 {
            // top mantissa pattern at top exponent is NaN -> one step below.
            (2.0 - 2.0 * half_ulp(self.man_bits)) - half_ulp(self.man_bits) * 2.0
        } else {
            2.0 - 2.0 * half_ulp(self.man_bits)
        };
        man_max * pow2(e)
    }

    /// Smallest positive normal magnitude: 2^(1 - bias).
    pub fn min_normal(&self) -> f64 {
        pow2(1 - self.bias)
    }

    /// Smallest positive subnormal magnitude: 2^(1 - bias - man_bits).
    pub fn min_subnormal(&self) -> f64 {
        pow2(1 - self.bias - self.man_bits as i32)
    }

    /// Quantize `x` to the nearest representable value of this format
    /// (round-to-nearest-even, saturating). This is the "fake quant" the
    /// whole paper is built on: the returned value is exactly representable
    /// in the format but carried in f32.
    pub fn quantize(&self, x: f32) -> f32 {
        if x.is_nan() {
            return f32::NAN;
        }
        let a = x.abs() as f64;
        if a == 0.0 {
            // preserve signed zero (harmless either way)
            return 0.0 * x.signum();
        }
        let sign = if x < 0.0 { -1.0f64 } else { 1.0f64 };
        let max = self.max_finite();
        // Saturating quantization: values past the midpoint between max and
        // the (nonexistent) next step clamp to max. qtorch saturates, and
        // absmax scaling means in-range inputs anyway.
        let q = if a >= max {
            max
        } else if a < self.min_normal() {
            // Subnormal range: fixed quantum.
            let quantum = self.min_subnormal();
            (a / quantum).round_ties_even() * quantum
        } else {
            // Normal range: quantum = 2^(floor(log2 a) - man_bits).
            let e = exponent_floor(a);
            let quantum = pow2(e - self.man_bits as i32);
            let r = (a / quantum).round_ties_even() * quantum;
            // Rounding up may cross into the next binade (e.g. 1.96 -> 2.0);
            // that result is still exactly representable, but it can also
            // exceed max_finite at the top binade -> saturate.
            if r > max {
                max
            } else {
                r
            }
        };
        (sign * q) as f32
    }

    /// Encode `x` to its code (sign | exponent | mantissa) in the low bits
    /// of a `u16`. The value encoded is `self.quantize(x)`.
    pub fn encode(&self, x: f32) -> u16 {
        let q = self.quantize(x);
        let sign_bit = if q.is_sign_negative() { 1u16 } else { 0u16 };
        let a = q.abs() as f64;
        let (exp_field, man_field) = if a == 0.0 {
            (0i32, 0u16)
        } else if a < self.min_normal() {
            // subnormal: exponent field 0, mantissa counts quanta
            let m = (a / self.min_subnormal()).round() as u16;
            (0i32, m)
        } else {
            let e = exponent_floor(a);
            let frac = a / pow2(e); // in [1, 2)
            let m = ((frac - 1.0) * pow2(self.man_bits as i32)).round() as u16;
            (e + self.bias, m)
        };
        debug_assert!(exp_field >= 0 && exp_field <= self.max_exp_field());
        (sign_bit << (self.exp_bits + self.man_bits))
            | ((exp_field as u16) << self.man_bits)
            | man_field
    }

    /// Decode a code produced by [`encode`](Self::encode) back to f32.
    pub fn decode(&self, code: u16) -> f32 {
        let man_mask = (1u16 << self.man_bits) - 1;
        let exp_mask = (1u16 << self.exp_bits) - 1;
        let m = (code & man_mask) as f64;
        let e_field = ((code >> self.man_bits) & exp_mask) as i32;
        let sign = if (code >> (self.exp_bits + self.man_bits)) & 1 == 1 {
            -1.0f64
        } else {
            1.0f64
        };
        if self.inf_reserved && e_field == (1i32 << self.exp_bits) - 1 {
            return if m == 0.0 {
                (sign as f32) * f32::INFINITY
            } else {
                f32::NAN
            };
        }
        let mag = if e_field == 0 {
            m * self.min_subnormal()
        } else {
            (1.0 + m * half_ulp(self.man_bits) * 2.0) * pow2(e_field - self.bias)
        };
        (sign * mag) as f32
    }

    /// Enumerate every non-negative representable value, ascending.
    /// Useful for tests and for building LUT-based quantizers.
    pub fn positive_values(&self) -> Vec<f32> {
        let mut v = Vec::new();
        let half = 1u16 << (self.exp_bits + self.man_bits);
        for code in 0..half {
            let x = self.decode(code);
            if !x.is_finite() || (x as f64) > self.max_finite() {
                continue;
            }
            v.push(x);
        }
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v.dedup();
        v
    }

    /// Human-readable name like "E4M3".
    pub fn name(&self) -> String {
        let base = format!("E{}M{}", self.exp_bits, self.man_bits);
        if self.nan_reserved {
            format!("{base}nv")
        } else {
            base
        }
    }
}

/// 2^e as f64 (exact for the exponent ranges used here).
#[inline]
pub fn pow2(e: i32) -> f64 {
    f64::from_bits(((e + 1023) as u64) << 52)
}

/// floor(log2(a)) for finite positive `a`, via the f64 bit pattern.
/// Exact, unlike `a.log2().floor()` which can misplace binade boundaries.
#[inline]
pub fn exponent_floor(a: f64) -> i32 {
    debug_assert!(a > 0.0 && a.is_finite());
    let bits = a.to_bits();
    let e = ((bits >> 52) & 0x7ff) as i32;
    if e == 0 {
        // f64 subnormal — far below any ExMy min_subnormal we use, but keep
        // it correct: normalize via log2.
        a.log2().floor() as i32
    } else {
        e - 1023
    }
}

/// Half-ULP of a 1.m mantissa with `m` bits: 2^-(m+1) ... helper returns
/// 2^-(m+1) * 2 = 2^-m / 2. We expose 2^-(m+1) as "half ulp at 1.0".
#[inline]
fn half_ulp(man_bits: u32) -> f64 {
    pow2(-(man_bits as i32) - 1)
}

/// The exponent `n` with `x == 2^n`, if `x` is a positive power of two in
/// the f32 **normal** range — the precondition for multiplying by `x` via
/// a pure add on the f32 exponent field (the packed-weight shift-dequant
/// path). Subnormal powers of two return `None`: an exponent-field add
/// cannot represent them.
#[inline]
pub fn pow2_exponent(x: f32) -> Option<i32> {
    if !(x > 0.0) || !x.is_finite() {
        return None;
    }
    let bits = x.to_bits();
    if bits & 0x007f_ffff != 0 {
        return None; // mantissa bits set: subnormal, or not a power of two
    }
    let e = ((bits >> 23) & 0xff) as i32;
    if e == 0 {
        None // subnormal (0.mantissa form)
    } else {
        Some(e - 127)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e2m1_value_set() {
        // The canonical FP4 E2M1 set from the paper / OCP MX spec.
        let vals = FpFormat::E2M1.positive_values();
        assert_eq!(vals, vec![0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]);
    }

    #[test]
    fn e3m0_value_set() {
        let vals = FpFormat::E3M0.positive_values();
        assert_eq!(vals, vec![0.0, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0]);
    }

    #[test]
    fn e4m3_extremes() {
        let f = FpFormat::E4M3;
        assert_eq!(f.max_finite(), 480.0); // qtorch semantics
        assert_eq!(f.min_normal(), pow2(-6));
        assert_eq!(f.min_subnormal(), pow2(-9));
        assert_eq!(f.quantize(1e6), 480.0);
        assert_eq!(f.quantize(-1e6), -480.0);
    }

    #[test]
    fn e4m3_nv_max_is_448() {
        assert_eq!(FpFormat::E4M3_NV.max_finite(), 448.0);
        assert_eq!(FpFormat::E4M3_NV.quantize(1e3), 448.0);
    }

    #[test]
    fn e5m2_extremes() {
        let f = FpFormat::E5M2;
        assert_eq!(f.max_finite(), 57344.0);
        assert_eq!(f.min_subnormal(), pow2(-16));
    }

    #[test]
    fn round_ties_even_at_midpoints() {
        let f = FpFormat::E2M1;
        // midpoint between 1.0 and 1.5 is 1.25 -> ties to even mantissa (1.0)
        assert_eq!(f.quantize(1.25), 1.0);
        // midpoint between 1.5 and 2.0 is 1.75 -> 2.0 (mantissa even after carry)
        assert_eq!(f.quantize(1.75), 2.0);
        // midpoint between 2 and 3 is 2.5 -> 2 (even)
        assert_eq!(f.quantize(2.5), 2.0);
        // midpoint between 3 and 4 is 3.5 -> 4
        assert_eq!(f.quantize(3.5), 4.0);
        // above max midpoint saturates
        assert_eq!(f.quantize(5.0), 4.0); // 5.0 is midpoint 4..6 -> ties-even -> 4
        assert_eq!(f.quantize(5.1), 6.0);
        assert_eq!(f.quantize(100.0), 6.0);
    }

    #[test]
    fn subnormal_rounding() {
        let f = FpFormat::E4M3; // min_subnormal = 2^-9
        let s = pow2(-9) as f32;
        assert_eq!(f.quantize(s * 0.49), 0.0);
        assert_eq!(f.quantize(s * 0.5), 0.0); // tie to even (0)
        assert_eq!(f.quantize(s * 0.51), s);
        assert_eq!(f.quantize(s * 1.5), 2.0 * s); // tie to even (2)
        assert_eq!(f.quantize(s * 2.5), 2.0 * s); // tie to even (2)
    }

    #[test]
    fn quantize_is_idempotent_on_all_codes() {
        for fmt in [
            FpFormat::E4M3,
            FpFormat::E5M2,
            FpFormat::E2M1,
            FpFormat::E3M0,
            FpFormat::F16,
        ] {
            for v in fmt.positive_values() {
                assert_eq!(fmt.quantize(v), v, "{} value {v}", fmt.name());
                assert_eq!(fmt.quantize(-v), -v, "{} value -{v}", fmt.name());
            }
        }
    }

    #[test]
    fn encode_decode_roundtrip_all_codes() {
        for fmt in [FpFormat::E4M3, FpFormat::E5M2, FpFormat::E2M1, FpFormat::E3M0] {
            for code in 0..fmt.code_count() as u16 {
                let v = fmt.decode(code);
                if !v.is_finite() || (v as f64) > fmt.max_finite() {
                    continue;
                }
                let code2 = fmt.encode(v);
                let v2 = fmt.decode(code2);
                assert_eq!(v, v2, "{} code {code}", fmt.name());
            }
        }
    }

    #[test]
    fn quantize_picks_nearest_value() {
        // brute-force nearest-value check against the enumerated set
        let mut rng = crate::rng::Rng::seeded(7);
        for fmt in [FpFormat::E4M3, FpFormat::E2M1, FpFormat::E3M0, FpFormat::E5M2] {
            let vals = fmt.positive_values();
            for _ in 0..2000 {
                let x = (rng.normal_f32()) * fmt.max_finite() as f32 * 0.4;
                let q = fmt.quantize(x);
                let a = x.abs();
                let best = vals
                    .iter()
                    .cloned()
                    .min_by(|u, v| {
                        (u - a)
                            .abs()
                            .partial_cmp(&(v - a).abs())
                            .unwrap()
                            .then(u.partial_cmp(v).unwrap())
                    })
                    .unwrap();
                assert!(
                    (q.abs() - best).abs() <= f32::EPSILON * best.max(1.0),
                    "{}: x={x} q={q} best={best}",
                    fmt.name()
                );
            }
        }
    }

    #[test]
    fn pow2_exponent_roundtrips() {
        for e in [-126i32, -10, -1, 0, 1, 10, 127] {
            let x = pow2(e) as f32;
            assert_eq!(pow2_exponent(x), Some(e), "e={e}");
        }
        assert_eq!(pow2_exponent(3.0), None);
        assert_eq!(pow2_exponent(0.0), None);
        assert_eq!(pow2_exponent(-2.0), None);
        assert_eq!(pow2_exponent(f32::INFINITY), None);
        assert_eq!(pow2_exponent(f32::NAN), None);
        // subnormal powers of two are excluded (exponent-add can't reach them)
        assert_eq!(pow2_exponent(f32::from_bits(1 << 22)), None);
    }

    #[test]
    fn bf16_matches_truncation_semantics() {
        let f = FpFormat::BF16;
        // 1 + 2^-8 is exactly halfway between bf16 neighbours 1.0 and 1+2^-7.
        assert_eq!(f.quantize(1.0 + pow2(-8) as f32), 1.0);
        assert_eq!(f.quantize(1.0 + pow2(-7) as f32), 1.0 + pow2(-7) as f32);
    }
}
