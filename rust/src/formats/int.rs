//! Integer (uniform) quantization codecs: the INT8/INT4 baselines.
//!
//! Implements equation (1) of the paper: `Q(x) = INT((x - Z)/S) - Z` with
//! symmetric (`Z = 0`) and asymmetric (`Z != 0`) variants, restricted
//! symmetric range (`[-2^(b-1)+1, 2^(b-1)-1]`, i.e. ±127 for INT8 — the
//! convention used by ZeroQuant / FasterTransformer so that `-S*qmax` and
//! `+S*qmax` are symmetric), and round-to-nearest-even.

/// An integer quantization format: bit-width + symmetry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IntFormat {
    /// Total bits including sign (8 for INT8, 4 for INT4).
    pub bits: u32,
    /// Symmetric (zero-point = 0, restricted range) or asymmetric
    /// (min/max affine mapping over the full 2^bits range).
    pub symmetric: bool,
}

impl IntFormat {
    pub const INT8_SYM: IntFormat = IntFormat { bits: 8, symmetric: true };
    pub const INT8_ASYM: IntFormat = IntFormat { bits: 8, symmetric: false };
    pub const INT4_SYM: IntFormat = IntFormat { bits: 4, symmetric: true };
    pub const INT4_ASYM: IntFormat = IntFormat { bits: 4, symmetric: false };

    /// Largest positive level in symmetric mode (127 for INT8, 7 for INT4).
    pub fn qmax(&self) -> i32 {
        (1i32 << (self.bits - 1)) - 1
    }

    /// Number of levels spanned in asymmetric mode (255 for INT8).
    pub fn levels(&self) -> i32 {
        (1i32 << self.bits) - 1
    }

    pub fn name(&self) -> String {
        format!(
            "INT{}{}",
            self.bits,
            if self.symmetric { "" } else { "a" }
        )
    }
}

/// Affine quantization parameters for one group of values.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IntQParams {
    /// Real-valued scale S.
    pub scale: f32,
    /// Integer zero point Z (0 in symmetric mode).
    pub zero_point: i32,
}

impl IntFormat {
    /// Compute quantization parameters from the observed `(min, max)` of a
    /// group. Symmetric mode uses absmax; asymmetric stretches the affine
    /// grid over `[min, max]` (with the grid forced to contain 0 so that
    /// padding/zeros stay exact, as in standard INT8 practice).
    pub fn params(&self, min: f32, max: f32) -> IntQParams {
        if self.symmetric {
            let absmax = min.abs().max(max.abs());
            let scale = if absmax > 0.0 {
                absmax / self.qmax() as f32
            } else {
                1.0
            };
            IntQParams { scale, zero_point: 0 }
        } else {
            let lo = min.min(0.0);
            let hi = max.max(0.0);
            let range = (hi - lo).max(f32::MIN_POSITIVE);
            let scale = range / self.levels() as f32;
            // zero_point chosen so that level 0 maps to `lo`:
            //   x ≈ S * (q - z_off) with q in [0, levels], z_off = -lo/S
            let zero_point = (-lo / scale).round_ties_even() as i32;
            IntQParams { scale, zero_point }
        }
    }

    /// Quantize to an integer level (the stored code). f32 division + f32
    /// round-to-nearest-even, bit-identical to the jnp mirror.
    pub fn encode(&self, x: f32, p: IntQParams) -> i32 {
        if self.symmetric {
            let q = (x / p.scale).round_ties_even() as i32;
            q.clamp(-self.qmax(), self.qmax())
        } else {
            let q = (x / p.scale).round_ties_even() as i32 + p.zero_point;
            q.clamp(0, self.levels())
        }
    }

    /// Decode an integer level back to f32.
    pub fn decode(&self, q: i32, p: IntQParams) -> f32 {
        if self.symmetric {
            q as f32 * p.scale
        } else {
            (q - p.zero_point) as f32 * p.scale
        }
    }

    /// Fake-quantize: `decode(encode(x))`.
    pub fn quantize(&self, x: f32, p: IntQParams) -> f32 {
        self.decode(self.encode(x, p), p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int8_sym_basics() {
        let f = IntFormat::INT8_SYM;
        let p = f.params(-2.0, 1.0);
        assert_eq!(p.zero_point, 0);
        assert!((p.scale - 2.0 / 127.0).abs() < 1e-9);
        assert_eq!(f.encode(2.0, p), 127);
        assert_eq!(f.encode(-2.0, p), -127);
        assert_eq!(f.encode(0.0, p), 0);
        assert_eq!(f.quantize(0.0, p), 0.0);
    }

    #[test]
    fn int8_asym_covers_range() {
        let f = IntFormat::INT8_ASYM;
        let p = f.params(-1.0, 3.0);
        // endpoints map near the code extremes
        assert_eq!(f.encode(-1.0, p), 0);
        assert_eq!(f.encode(3.0, p), 255);
        // zero stays near-exact
        assert!(f.quantize(0.0, p).abs() <= p.scale * 0.5 + 1e-7);
    }

    #[test]
    fn int4_sym_levels() {
        let f = IntFormat::INT4_SYM;
        assert_eq!(f.qmax(), 7);
        let p = f.params(-7.0, 7.0);
        assert!((p.scale - 1.0).abs() < 1e-7);
        for q in -7..=7 {
            assert_eq!(f.encode(q as f32, p), q);
        }
    }

    #[test]
    fn outlier_skew_matches_paper_figure2() {
        // Figure 2's story: with one outlier at 100, INT8-asym represents the
        // outlier well but the clustered small values coarsely.
        let f = IntFormat::INT8_ASYM;
        let p = f.params(-0.5, 100.0);
        // quantum is ~0.39 — much larger than the cluster spread
        assert!(p.scale > 0.3);
        let err = (f.quantize(0.05, p) - 0.05).abs();
        assert!(err > 0.01, "cluster error should be visible: {err}");
        // while FP8 E4M3 with absmax scale represents 0.05 well
        let fp = crate::formats::FpFormat::E4M3;
        let s = 100.0 / fp.max_finite() as f32;
        let fp_err = (fp.quantize(0.05 / s) * s - 0.05).abs();
        assert!(fp_err < err / 4.0, "fp_err={fp_err} int_err={err}");
    }

    #[test]
    fn zero_range_is_safe() {
        for f in [IntFormat::INT8_SYM, IntFormat::INT8_ASYM, IntFormat::INT4_SYM] {
            let p = f.params(0.0, 0.0);
            assert!(p.scale > 0.0);
            assert_eq!(f.quantize(0.0, p), 0.0);
        }
    }

    #[test]
    fn rne_on_encode() {
        let f = IntFormat::INT8_SYM;
        let p = IntQParams { scale: 1.0, zero_point: 0 };
        assert_eq!(f.encode(0.5, p), 0); // tie to even
        assert_eq!(f.encode(1.5, p), 2);
        assert_eq!(f.encode(2.5, p), 2);
        assert_eq!(f.encode(-0.5, p), 0);
        assert_eq!(f.encode(-1.5, p), -2);
    }
}
