//! Numeric format substrate: software codecs for every low-bit format the
//! paper evaluates (FP8 E4M3/E5M2, FP4 E2M1/E3M0, INT8/INT4 sym/asym), plus
//! a unified [`NumericFormat`] used by the quantization stack.
//!
//! Everything here is *bit-exact and deterministic*: round-to-nearest-even
//! through f64 intermediates (power-of-two scaling only, so rounding is
//! exact), mirrored 1:1 by `python/compile/kernels/fpq.py` on the JAX side.

mod exmy;
mod int;

pub use exmy::{exponent_floor, pow2, pow2_exponent, FpFormat};
pub use int::{IntFormat, IntQParams};

/// Any scalar format the quantizer can target.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum NumericFormat {
    /// Full-precision passthrough (the W16/A16 baseline; we simulate FP16
    /// models in f32 like the reference GPTQ code does on the GPU).
    F16,
    /// A floating-point ExMy format with absmax scaling.
    Fp(FpFormat),
    /// An integer format with absmax (sym) or min/max (asym) scaling.
    Int(IntFormat),
}

impl NumericFormat {
    pub const FP8_E4M3: NumericFormat = NumericFormat::Fp(FpFormat::E4M3);
    pub const FP8_E5M2: NumericFormat = NumericFormat::Fp(FpFormat::E5M2);
    pub const FP4_E2M1: NumericFormat = NumericFormat::Fp(FpFormat::E2M1);
    pub const FP4_E3M0: NumericFormat = NumericFormat::Fp(FpFormat::E3M0);
    pub const INT8: NumericFormat = NumericFormat::Int(IntFormat::INT8_SYM);
    pub const INT8_ASYM: NumericFormat = NumericFormat::Int(IntFormat::INT8_ASYM);
    pub const INT4: NumericFormat = NumericFormat::Int(IntFormat::INT4_SYM);
    pub const INT4_ASYM: NumericFormat = NumericFormat::Int(IntFormat::INT4_ASYM);

    /// Bit width of stored codes (16 for the F16 passthrough).
    pub fn bits(&self) -> u32 {
        match self {
            NumericFormat::F16 => 16,
            NumericFormat::Fp(f) => f.total_bits(),
            NumericFormat::Int(i) => i.bits,
        }
    }

    pub fn is_fp(&self) -> bool {
        matches!(self, NumericFormat::Fp(_))
    }

    pub fn name(&self) -> String {
        match self {
            NumericFormat::F16 => "F16".to_string(),
            NumericFormat::Fp(f) => format!("FP{}-{}", f.total_bits(), f.name()),
            NumericFormat::Int(i) => i.name(),
        }
    }

    /// Parse names like "fp8_e4m3", "e5m2", "int8", "int4a", "f16".
    pub fn parse(s: &str) -> Option<NumericFormat> {
        let t = s.to_ascii_lowercase().replace(['-', '_'], "");
        Some(match t.as_str() {
            "f16" | "fp16" | "none" | "w16" | "a16" => NumericFormat::F16,
            "fp8" | "e4m3" | "fp8e4m3" => NumericFormat::FP8_E4M3,
            "e5m2" | "fp8e5m2" => NumericFormat::FP8_E5M2,
            "fp4" | "e2m1" | "fp4e2m1" => NumericFormat::FP4_E2M1,
            "e3m0" | "fp4e3m0" => NumericFormat::FP4_E3M0,
            "e4m3nv" | "fp8nv" => NumericFormat::Fp(FpFormat::E4M3_NV),
            "int8" => NumericFormat::INT8,
            "int8a" | "int8asym" => NumericFormat::INT8_ASYM,
            "int4" => NumericFormat::INT4,
            "int4a" | "int4asym" => NumericFormat::INT4_ASYM,
            _ => return None,
        })
    }
}

/// Scale+zero-point bundle covering both families, attached to a quant group.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GroupParams {
    /// Multiplicative scale (for FP: input is divided by `scale` before the
    /// codec so that absmax maps to max_finite; for INT: the affine scale S).
    pub scale: f32,
    /// Zero point (INT asymmetric only; 0 otherwise).
    pub zero_point: i32,
}

impl GroupParams {
    pub const IDENTITY: GroupParams = GroupParams { scale: 1.0, zero_point: 0 };
}

impl NumericFormat {
    /// Compute group parameters from observed min/max of the group.
    pub fn group_params(&self, min: f32, max: f32) -> GroupParams {
        match self {
            NumericFormat::F16 => GroupParams::IDENTITY,
            NumericFormat::Fp(f) => {
                let absmax = min.abs().max(max.abs());
                let scale = if absmax > 0.0 {
                    absmax / f.max_finite() as f32
                } else {
                    1.0
                };
                GroupParams { scale, zero_point: 0 }
            }
            NumericFormat::Int(i) => {
                let p = i.params(min, max);
                GroupParams { scale: p.scale, zero_point: p.zero_point }
            }
        }
    }

    /// Fake-quantize one value under `p`.
    #[inline]
    pub fn fake_quant(&self, x: f32, p: GroupParams) -> f32 {
        match self {
            NumericFormat::F16 => x,
            NumericFormat::Fp(f) => f.quantize(x / p.scale) * p.scale,
            NumericFormat::Int(i) => i.quantize(
                x,
                IntQParams { scale: p.scale, zero_point: p.zero_point },
            ),
        }
    }

    /// Fake-quantize a slice in place under a single group's params.
    pub fn fake_quant_slice(&self, xs: &mut [f32], p: GroupParams) {
        match self {
            NumericFormat::F16 => {}
            NumericFormat::Fp(f) => {
                // f32 division (not reciprocal-multiply): bit-identical to
                // the jnp mirror in python/compile/kernels/fpq.py.
                for x in xs.iter_mut() {
                    *x = f.quantize(*x / p.scale) * p.scale;
                }
            }
            NumericFormat::Int(i) => {
                let ip = IntQParams { scale: p.scale, zero_point: p.zero_point };
                for x in xs.iter_mut() {
                    *x = i.quantize(*x, ip);
                }
            }
        }
    }

    /// True when group parameters depend only on `|x|` (absmax scaling):
    /// the FP formats, symmetric INT formats, and the F16 passthrough.
    /// Asymmetric INT needs the full (min, max) affine fit.
    pub fn is_symmetric(&self) -> bool {
        match self {
            NumericFormat::F16 => true,
            NumericFormat::Fp(_) => true,
            NumericFormat::Int(i) => i.symmetric,
        }
    }

    /// The group params the dynamic (absmax) path derives for a symmetric
    /// format over `xs`, without quantizing anything: one fused absmax
    /// scan, then [`group_params`](Self::group_params). `None` when the
    /// scan degenerates (non-finite absmax), in which case the dynamic
    /// quantizer leaves the data untouched.
    ///
    /// This is the **single** definition of that derivation — both
    /// [`fake_quant_slice_dynamic`](Self::fake_quant_slice_dynamic) and
    /// the LoRC factor-code encoder (`crate::lorc`) go through it, which
    /// is what keeps factor codes bit-equal to the fake-quant fold.
    pub fn dynamic_symmetric_params(&self, xs: &[f32]) -> Option<GroupParams> {
        debug_assert!(self.is_symmetric());
        let mut am = 0.0f32;
        for &x in xs.iter() {
            am = am.max(x.abs());
        }
        if !am.is_finite() {
            return None;
        }
        Some(self.group_params(-am, am))
    }

    /// Absmax-style one-shot fake quantization of a slice: compute params
    /// from the slice itself, then quantize. Returns the params used.
    ///
    /// Symmetric formats (the A8 hot path) use a single fused absmax scan —
    /// one read of the row instead of a min/max pass followed by a quantize
    /// pass re-deriving absmax. Asymmetric INT keeps the two-sided scan.
    /// NaNs are ignored by the scan either way (f32 min/max semantics);
    /// a non-finite range degenerates to the identity params.
    pub fn fake_quant_slice_dynamic(&self, xs: &mut [f32]) -> GroupParams {
        let p = if self.is_symmetric() {
            match self.dynamic_symmetric_params(xs) {
                Some(p) => p,
                None => return GroupParams::IDENTITY,
            }
        } else {
            let (mut mn, mut mx) = (f32::INFINITY, f32::NEG_INFINITY);
            for &x in xs.iter() {
                mn = mn.min(x);
                mx = mx.max(x);
            }
            if !mn.is_finite() || !mx.is_finite() {
                return GroupParams::IDENTITY;
            }
            self.group_params(mn, mx)
        };
        self.fake_quant_slice(xs, p);
        p
    }

    /// Quantization MSE of a slice under dynamic absmax params — the metric
    /// Figure 2 visualizes and the LoRC/GPTQ objective decomposes over.
    pub fn quant_mse(&self, xs: &[f32]) -> f64 {
        let mut ys = xs.to_vec();
        self.fake_quant_slice_dynamic(&mut ys);
        xs.iter()
            .zip(&ys)
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum::<f64>()
            / xs.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for s in ["fp8_e4m3", "e5m2", "fp4", "e3m0", "int8", "int4", "int8a", "f16"] {
            assert!(NumericFormat::parse(s).is_some(), "{s}");
        }
        assert!(NumericFormat::parse("bogus").is_none());
    }

    #[test]
    fn fp8_beats_int8_on_skewed_data() {
        // The paper's core observation, as a unit test: with an outlier,
        // FP8 E4M3 absmax quantization has lower MSE on the cluster than
        // INT8 symmetric absmax.
        let mut data: Vec<f32> = (0..64).map(|i| (i as f32 - 32.0) * 0.01).collect();
        data.push(100.0);
        let fp = NumericFormat::FP8_E4M3.quant_mse(&data);
        let int = NumericFormat::INT8.quant_mse(&data);
        assert!(fp < int, "fp={fp} int={int}");
    }

    #[test]
    fn int8_beats_fp8_on_uniform_data() {
        // And the flip side (van Baalen et al.): on uniformly-spread data
        // without outliers, INT8's equal spacing wins.
        let data: Vec<f32> = (0..257).map(|i| (i as f32 - 128.0) / 128.0).collect();
        let fp = NumericFormat::FP8_E4M3.quant_mse(&data);
        let int = NumericFormat::INT8.quant_mse(&data);
        assert!(int < fp, "fp={fp} int={int}");
    }

    #[test]
    fn dynamic_quant_preserves_absmax_sign() {
        let mut xs = vec![-3.0f32, 0.1, 2.0];
        NumericFormat::FP8_E4M3.fake_quant_slice_dynamic(&mut xs);
        assert_eq!(xs[0], -3.0); // absmax maps exactly to a representable point
    }

    #[test]
    fn fused_absmax_matches_two_pass_scan() {
        // The single-pass symmetric scan must produce the same params (and
        // therefore the same quantized values) as an explicit min/max scan.
        let mut rng = crate::rng::Rng::seeded(9001);
        for fmt in [
            NumericFormat::FP8_E4M3,
            NumericFormat::FP4_E2M1,
            NumericFormat::INT8,
            NumericFormat::INT4,
            NumericFormat::INT8_ASYM, // asym path must be untouched
        ] {
            for _ in 0..20 {
                let xs: Vec<f32> = (0..64).map(|_| rng.normal_f32() * 5.0).collect();
                let (mut mn, mut mx) = (f32::INFINITY, f32::NEG_INFINITY);
                for &x in &xs {
                    mn = mn.min(x);
                    mx = mx.max(x);
                }
                let expect = fmt.group_params(mn, mx);
                let mut ys = xs.clone();
                let got = fmt.fake_quant_slice_dynamic(&mut ys);
                assert_eq!(got.scale.to_bits(), expect.scale.to_bits(), "{}", fmt.name());
                assert_eq!(got.zero_point, expect.zero_point, "{}", fmt.name());
                let mut zs = xs.clone();
                fmt.fake_quant_slice(&mut zs, expect);
                for (a, b) in ys.iter().zip(&zs) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{}", fmt.name());
                }
            }
        }
        // degenerate inputs keep the old guarantees
        for fmt in [NumericFormat::FP8_E4M3, NumericFormat::INT8] {
            let mut empty: Vec<f32> = vec![];
            assert_eq!(fmt.fake_quant_slice_dynamic(&mut empty).scale, 1.0);
            let mut inf = vec![1.0f32, f32::INFINITY];
            assert_eq!(fmt.fake_quant_slice_dynamic(&mut inf), GroupParams::IDENTITY);
            assert_eq!(inf[0], 1.0, "non-finite range must leave data untouched");
        }
    }

    #[test]
    fn f16_passthrough() {
        let mut xs = vec![1.2345f32, -9.87];
        let orig = xs.clone();
        NumericFormat::F16.fake_quant_slice_dynamic(&mut xs);
        assert_eq!(xs, orig);
    }

    #[test]
    fn fp4_group_scale_maps_absmax_to_six() {
        let p = NumericFormat::FP4_E2M1.group_params(-12.0, 3.0);
        assert!((p.scale - 2.0).abs() < 1e-6); // 12/6
        assert_eq!(NumericFormat::FP4_E2M1.fake_quant(-12.0, p), -12.0);
    }
}
