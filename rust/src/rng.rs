//! Deterministic PRNG substrate (no `rand` crate in the offline vendor set).
//!
//! xoshiro256** with splitmix64 seeding — fast, well-distributed, and fully
//! reproducible across platforms. Every stochastic component in the repo
//! (synthetic corpora, weight init fallback, property tests, workload
//! generators) draws from this so experiments are bit-reproducible.

/// xoshiro256** generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box-Muller
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically from a u64.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (for per-layer / per-shard rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::seeded(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our (non-crypto) purposes:
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with N(0, std^2).
    pub fn fill_normal(&mut self, xs: &mut [f32], std: f32) {
        for x in xs.iter_mut() {
            *x = self.normal_f32() * std;
        }
    }

    /// Sample from a categorical distribution given (unnormalized,
    /// non-negative) weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Zipf(s) sample over ranks [0, n): P(k) ∝ 1/(k+1)^s via precomputed
    /// CDF would be faster; this inverse-transform over harmonic weights is
    /// O(n) worst case but only used in corpus *construction*, not serving.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // rejection-inversion (Hörmann) would be overkill; n is ≤ vocab.
        let u = self.uniform();
        // binary search over an implicit CDF is avoided by caching in the
        // corpus generator; here do straightforward linear walk.
        let h: f64 = (1..=n).map(|k| (k as f64).powf(-s)).sum();
        let mut acc = 0.0;
        let target = u * h;
        for k in 1..=n {
            acc += (k as f64).powf(-s);
            if acc >= target {
                return k - 1;
            }
        }
        n - 1
    }

    /// Random permutation of 0..n (Fisher–Yates).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seeded(42);
        let mut b = Rng::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::seeded(1);
        let mut b = Rng::seeded(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::seeded(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seeded(4);
        let n = 100_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::seeded(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn zipf_is_skewed() {
        let mut r = Rng::seeded(6);
        let mut counts = [0usize; 16];
        for _ in 0..4000 {
            counts[r.zipf(16, 1.2)] += 1;
        }
        assert!(counts[0] > counts[8] * 3, "{counts:?}");
    }

    #[test]
    fn permutation_is_valid() {
        let mut r = Rng::seeded(7);
        let p = r.permutation(64);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::seeded(8);
        let w = [0.0, 10.0, 0.0];
        for _ in 0..100 {
            assert_eq!(r.categorical(&w), 1);
        }
    }
}
