//! Perplexity evaluation — the paper's quality metric for every table.
//!
//! Matches the GPTQ-repo protocol the paper used: the eval stream is cut
//! into non-overlapping `seq_len` windows, each window is scored
//! teacher-forced, and PPL = exp(mean NLL over all predicted positions).

use crate::engine::{Engine, EngineOpts};
use crate::model::Checkpoint;
use crate::tensor::Matrix;

/// Result of a perplexity run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PplResult {
    pub nll_sum: f64,
    pub tokens: usize,
}

impl PplResult {
    pub fn ppl(&self) -> f64 {
        (self.nll_sum / self.tokens.max(1) as f64).exp()
    }

    pub fn merge(&mut self, other: PplResult) {
        self.nll_sum += other.nll_sum;
        self.tokens += other.tokens;
    }
}

/// Numerically-stable mean NLL of `targets` under `logits` rows.
/// `logits[t]` predicts `targets[t]`.
pub fn cross_entropy(logits: &Matrix, targets: &[u16]) -> PplResult {
    assert_eq!(logits.rows, targets.len());
    let mut nll_sum = 0.0f64;
    for (t, &target) in targets.iter().enumerate() {
        let row = logits.row(t);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse: f64 = row.iter().map(|&x| ((x - mx) as f64).exp()).sum::<f64>().ln()
            + mx as f64;
        nll_sum += lse - row[target as usize] as f64;
    }
    PplResult { nll_sum, tokens: targets.len() }
}

/// Perplexity of a checkpoint over a token stream, cut into non-overlapping
/// windows of `seq_len` (each window predicts positions 1..seq_len).
pub fn perplexity(
    ck: &Checkpoint,
    opts: EngineOpts,
    tokens: &[u16],
    seq_len: usize,
) -> PplResult {
    let engine = Engine::with_opts(ck, opts);
    let seq_len = seq_len.min(ck.config.max_seq);
    let mut total = PplResult { nll_sum: 0.0, tokens: 0 };
    for window in tokens.chunks_exact(seq_len) {
        let logits = engine.forward(window);
        // logits[t] predicts window[t+1]
        let pred = Matrix::from_vec(
            seq_len - 1,
            logits.cols,
            logits.data[..(seq_len - 1) * logits.cols].to_vec(),
        );
        total.merge(cross_entropy(&pred, &window[1..]));
    }
    total
}

/// Perplexity through an already-compiled plan — the entry point for the
/// packed weight layout (`zqfp eval --packed`), and allocation-free per
/// window either way. Bit-identical to [`perplexity`] for any layout,
/// since the compiled plan's logits match the reference engine's.
pub fn perplexity_model(
    model: &crate::plan::CompiledModel,
    tokens: &[u16],
    seq_len: usize,
) -> PplResult {
    let seq_len = seq_len.min(model.config.max_seq);
    let mut s = model.scratch();
    let mut total = PplResult { nll_sum: 0.0, tokens: 0 };
    for window in tokens.chunks_exact(seq_len) {
        let logits = model.forward(window, &mut s);
        total.merge(PplResult {
            nll_sum: crate::plan::logits_nll(logits, window),
            tokens: seq_len - 1,
        });
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Arch, Checkpoint, ModelConfig};
    use crate::rng::Rng;

    #[test]
    fn cross_entropy_uniform_logits() {
        let v = 64usize;
        let logits = Matrix::zeros(10, v);
        let targets: Vec<u16> = (0..10).collect();
        let r = cross_entropy(&logits, &targets);
        let expect = (v as f64).ln();
        assert!((r.nll_sum / 10.0 - expect).abs() < 1e-9);
        assert!((r.ppl() - v as f64).abs() < 1e-6);
    }

    #[test]
    fn cross_entropy_confident_correct() {
        let mut logits = Matrix::zeros(4, 8);
        for t in 0..4 {
            *logits.at_mut(t, t) = 30.0;
        }
        let r = cross_entropy(&logits, &[0, 1, 2, 3]);
        assert!(r.ppl() < 1.0001, "{}", r.ppl());
    }

    #[test]
    fn random_model_ppl_near_vocab() {
        let cfg = ModelConfig {
            name: "ppl-test".into(),
            arch: Arch::Opt,
            vocab_size: 64,
            d_model: 16,
            n_heads: 2,
            n_layers: 1,
            d_ff: 32,
            max_seq: 16,
        };
        let mut rng = Rng::seeded(121);
        let ck = Checkpoint::random(&cfg, &mut rng);
        let tokens: Vec<u16> = (0..256).map(|_| rng.below(64) as u16).collect();
        let r = perplexity(&ck, EngineOpts::default(), &tokens, 16);
        // untrained model on uniform tokens: ppl within a factor ~2 of vocab
        assert!(r.ppl() > 25.0 && r.ppl() < 160.0, "{}", r.ppl());
        assert_eq!(r.tokens, (256 / 16) * 15);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = PplResult { nll_sum: 10.0, tokens: 5 };
        a.merge(PplResult { nll_sum: 20.0, tokens: 10 });
        assert_eq!(a.nll_sum, 30.0);
        assert_eq!(a.tokens, 15);
        assert!((a.ppl() - (2.0f64).exp()).abs() < 1e-12);
    }
}
