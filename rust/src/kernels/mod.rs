//! The two-tier kernel contract: one operator trait, two backends.
//!
//! Every numeric primitive the compiled plan executes — dense GEMV, fused
//! dequant-GEMV over packed codes, RMSNorm, attention softmax — dispatches
//! through the [`Kernels`] trait. Two tiers implement it:
//!
//! * [`OracleKernels`] — the crate's original scalar path, unchanged. It is
//!   the **bit-identity reference**: every existing equivalence suite
//!   (plan/packed/lorc/kv/recipes) runs against this tier and its outputs
//!   are bit-equal to the reference [`crate::engine::Engine`] by the
//!   contracts documented in [`crate::tensor::packed_matmul`].
//! * [`FastKernels`] — a blocked, 8-lane unrolled dequant-GEMV plus a
//!   persistent [`WorkerPool`] that shards output features across threads
//!   per decode step (replacing the oracle's per-call `std::thread::scope`
//!   spawning). The fast tier is *not* bit-identical to the oracle — its
//!   dot products reduce through eight independent accumulator lanes — but
//!   it is **tolerance-gated**: `tests/kernel_tolerance.rs` proves every
//!   GEMV element within a few ULP at the problem's scale, end-to-end NLL
//!   within 1e-4 relative, and greedy decode token-identical over long
//!   generations. The fast tier *is* bit-deterministic with respect to
//!   itself: results are identical for any worker count, because each
//!   output scalar's reduction is self-contained.
//!
//! The norm and softmax primitives are default trait methods shared by both
//! tiers — they are bandwidth-trivial next to the GEMVs, so both tiers run
//! the oracle's exact arithmetic and the bit-identity of those stages is
//! structural. A third backend (e.g. a PJRT-offloaded tier) overrides
//! whichever methods it accelerates and inherits the rest; see
//! ARCHITECTURE.md §"Kernel tiers & tolerance contract" for the checklist.

pub mod pool;

pub use pool::{ScopedTask, WorkerPool};

use std::sync::Arc;

use crate::engine::KernelTier;
use crate::lorc::PackedLorc;
use crate::quant::PackedWeight;
use crate::tensor::packed_matmul::{self, GemvScratch};
use crate::tensor::{matmul, Matrix};

/// The operator set of the compiled plan. Implementations must be
/// shareable across the serving stack (`Send + Sync`) because one kernel
/// backend instance is held by the compiled model and used from the
/// coordinator's decode thread and the pool workers.
pub trait Kernels: Send + Sync + std::fmt::Debug {
    /// Which tier this backend implements (drives recipe/CLI reporting).
    fn tier(&self) -> KernelTier;

    /// `out += x · dequant(w + E₁E₂)ᵀ` over bit-packed codes. `out` must be
    /// pre-seeded (zeros or bias rows) and shaped `[x.rows, w.rows]`; `s`
    /// provides the decode strips (grown on demand if undersized).
    fn packed_gemv(
        &self,
        x: &Matrix,
        w: &PackedWeight,
        lorc: Option<&PackedLorc>,
        out: &mut Matrix,
        s: &mut GemvScratch,
    );

    /// `out += x · wt` with `wt` prepacked `[d_in, d_out]`. Default: the
    /// reference axpy kernel — bit-identical for both tiers (the dense
    /// plan's k-blocked accumulation order *is* the contract, and the
    /// blocked kernel already streams unit-stride).
    fn gemv(&self, x: &Matrix, wt: &Matrix, out: &mut Matrix) {
        matmul::matmul_into(x, wt, out);
    }

    /// RMSNorm each row of `x` into `out` (gain applied, eps `1e-5`).
    /// Default: the exact arithmetic of the reference engine's norm —
    /// shared by both tiers, so norm bit-identity is structural.
    fn rms_norm(&self, x: &Matrix, gain: &[f32], out: &mut Matrix) {
        out.resize_to(x.rows, x.cols);
        let eps = 1e-5f32;
        for r in 0..x.rows {
            let row = x.row(r);
            let ms = row.iter().map(|&v| v * v).sum::<f32>() / row.len() as f32;
            let inv = 1.0 / (ms + eps).sqrt();
            let orow = out.row_mut(r);
            for c in 0..row.len() {
                orow[c] = row[c] * inv * gain[c];
            }
        }
    }

    /// In-place max-subtracted softmax over one attention score row.
    /// Default: the exact operation order of the reference attention
    /// (max fold, sequential exp/accumulate, multiply by the reciprocal)
    /// — shared by both tiers.
    fn softmax(&self, scores: &mut [f32]) {
        let mut mx = f32::NEG_INFINITY;
        for &sc in scores.iter() {
            mx = mx.max(sc);
        }
        let mut denom = 0.0f32;
        for sc in scores.iter_mut() {
            *sc = (*sc - mx).exp();
            denom += *sc;
        }
        let inv = 1.0 / denom;
        for sc in scores.iter_mut() {
            *sc *= inv;
        }
    }
}

/// Build the backend for a tier. `threads` is the GEMV worker count (the
/// recipe's `gemv_threads` knob): the oracle tier passes it to the
/// scoped-thread row sharding, the fast tier sizes its persistent pool.
pub fn for_tier(tier: KernelTier, threads: usize) -> Arc<dyn Kernels> {
    match tier {
        KernelTier::Oracle => Arc::new(OracleKernels::new(threads)),
        KernelTier::Fast => Arc::new(FastKernels::new(threads)),
    }
}

/// The scalar reference tier — delegates wholesale to the crate's original
/// kernels, so its outputs are bit-identical to the pre-trait code paths
/// by construction (the delegation adds no floating-point operation).
#[derive(Debug, Clone, Copy)]
pub struct OracleKernels {
    threads: usize,
}

impl OracleKernels {
    /// Oracle backend sharding packed GEMV rows across `threads` scoped
    /// threads per call (1 = inline, the zero-allocation path).
    pub fn new(threads: usize) -> OracleKernels {
        OracleKernels { threads: threads.max(1) }
    }
}

impl Kernels for OracleKernels {
    fn tier(&self) -> KernelTier {
        KernelTier::Oracle
    }

    fn packed_gemv(
        &self,
        x: &Matrix,
        w: &PackedWeight,
        lorc: Option<&PackedLorc>,
        out: &mut Matrix,
        s: &mut GemvScratch,
    ) {
        packed_matmul::packed_matmul_into(x, w, lorc, out, s, self.threads);
    }
}

/// The fast tier: 8-lane unrolled dequant-GEMV + persistent worker pool.
///
/// Each output scalar is `seed + dot8(x_row, decoded_row)` where [`dot8`]
/// reduces through eight independent accumulator lanes — the loop LLVM
/// autovectorizes to packed f32 lanes on every target the crate builds for,
/// without `std::simd`. Because every output scalar's reduction is
/// self-contained (the decoded row is private to its worker, the lanes
/// combine pairwise in a fixed order), the result is bit-identical for any
/// worker count — asserted by `tests/kernel_tolerance.rs` across
/// `threads ∈ {1, 2, 4}`.
#[derive(Debug)]
pub struct FastKernels {
    pool: WorkerPool,
}

impl FastKernels {
    /// Fast backend with a persistent pool of `threads` workers
    /// (1 = inline: no pool threads, no per-call allocation).
    pub fn new(threads: usize) -> FastKernels {
        FastKernels { pool: WorkerPool::new(threads) }
    }

    /// Worker count of the persistent pool (>= 1; 1 means inline).
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }
}

impl Kernels for FastKernels {
    fn tier(&self) -> KernelTier {
        KernelTier::Fast
    }

    fn packed_gemv(
        &self,
        x: &Matrix,
        w: &PackedWeight,
        lorc: Option<&PackedLorc>,
        out: &mut Matrix,
        s: &mut GemvScratch,
    ) {
        assert_eq!(x.cols, w.cols, "gemv input dim mismatch");
        assert_eq!(out.rows, x.rows);
        assert_eq!(out.cols, w.rows);
        if x.rows == 0 || w.rows == 0 {
            return;
        }
        if let Some(l) = lorc {
            assert_eq!((l.d_out, l.d_in), (w.rows, w.cols), "lorc factor shape mismatch");
            if s.e2.len() < l.e2_elems() {
                s.e2.resize(l.e2_elems(), 0.0);
            }
            if s.err.len() < w.cols {
                s.err.resize(w.cols, 0.0);
            }
            l.decode_e2_into(&mut s.e2);
        }
        if s.deq.len() < w.cols {
            s.deq.resize(w.cols, 0.0);
        }
        let threads = self.pool.threads().min(w.rows);
        if threads <= 1 {
            let deq = &mut s.deq[..w.cols];
            let err = &mut s.err[..];
            for j in 0..w.rows {
                decode_effective_row(w, lorc, j, deq, &s.e2, err);
                for i in 0..x.rows {
                    out.data[i * out.cols + j] += dot8(x.row(i), deq);
                }
            }
            return;
        }

        // Shard output features across the persistent pool. Each worker
        // computes the pure dot contributions of its row range into a
        // private strip (the seed already sits in `out`); the strips are
        // scattered with one add per element after the join — the same
        // single `seed + dot` add as the inline path, so the result is
        // bit-identical for any worker count.
        let chunk = w.rows.div_ceil(threads);
        let mut strips: Vec<(std::ops::Range<usize>, Vec<f32>)> = (0..threads)
            .map(|t| {
                let r = (t * chunk).min(w.rows)..((t + 1) * chunk).min(w.rows);
                let len = x.rows * r.len();
                (r, vec![0.0f32; len])
            })
            .collect();
        let e2: &[f32] = &s.e2;
        let tasks: Vec<ScopedTask<'_>> = strips
            .iter_mut()
            .map(|(r, strip)| {
                let r = r.clone();
                let strip: &mut [f32] = strip;
                let t: ScopedTask<'_> = Box::new(move || {
                    let span = r.len();
                    let mut deq = vec![0.0f32; w.cols];
                    let mut err = vec![0.0f32; if lorc.is_some() { w.cols } else { 0 }];
                    for (jj, j) in r.enumerate() {
                        decode_effective_row(w, lorc, j, &mut deq, e2, &mut err);
                        for i in 0..x.rows {
                            strip[i * span + jj] = dot8(x.row(i), &deq);
                        }
                    }
                });
                t
            })
            .collect();
        self.pool.run(tasks);
        for (r, strip) in &strips {
            let span = r.len();
            for i in 0..x.rows {
                let orow = &mut out.data[i * out.cols..(i + 1) * out.cols];
                for (jj, j) in r.clone().enumerate() {
                    orow[j] += strip[i * span + jj];
                }
            }
        }
    }
}

/// Decode weight row `j` into `deq`, folding the LoRC error row in place
/// when the linear carries compensation — the same effective-row contract
/// as the oracle GEMV ([`crate::tensor::packed_matmul`]).
fn decode_effective_row(
    w: &PackedWeight,
    lorc: Option<&PackedLorc>,
    j: usize,
    deq: &mut [f32],
    e2: &[f32],
    err: &mut [f32],
) {
    w.dequant_row_into(j, deq);
    if let Some(l) = lorc {
        l.err_row_into(j, e2, err);
        for (d, &e) in deq[..w.cols].iter_mut().zip(err[..w.cols].iter()) {
            *d += e;
        }
    }
}

/// Eight-lane unrolled dot product. The body of the fast GEMV: eight
/// independent f32 accumulators consume aligned 8-element blocks (LLVM
/// lowers the fixed-size-array loop to packed vector FMAs/mul-adds), a
/// scalar tail handles `len % 8`, and the lanes combine pairwise in a
/// fixed order — so the reduction tree is deterministic and identical
/// regardless of how rows are sharded across workers.
#[inline]
pub fn dot8(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let mut acc = [0.0f32; 8];
    let mut k = 0usize;
    while k + 8 <= n {
        let av: &[f32; 8] = a[k..k + 8].try_into().unwrap();
        let bv: &[f32; 8] = b[k..k + 8].try_into().unwrap();
        for l in 0..8 {
            acc[l] += av[l] * bv[l];
        }
        k += 8;
    }
    let mut tail = 0.0f32;
    while k < n {
        tail += a[k] * b[k];
        k += 1;
    }
    (((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))) + tail
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::{FpFormat, NumericFormat};
    use crate::quant::{quantize_weight_rtn, WeightQuantConfig};
    use crate::rng::Rng;

    fn packed_fixture(rows: usize, cols: usize, seed: u64) -> (Matrix, PackedWeight) {
        let mut rng = Rng::seeded(seed);
        let wm = Matrix::randn(rows, cols, 0.05, &mut rng);
        let cfg = WeightQuantConfig::new(NumericFormat::Fp(FpFormat::E2M1)).with_group_size(8);
        let q = quantize_weight_rtn(&wm, &cfg);
        let x = Matrix::randn(3, cols, 0.3, &mut rng);
        (x, PackedWeight::from_quantized(&q))
    }

    fn run(k: &dyn Kernels, x: &Matrix, w: &PackedWeight) -> Matrix {
        let mut out = Matrix::zeros(x.rows, w.rows);
        let mut s = GemvScratch::sized(w.cols, 0);
        k.packed_gemv(x, w, None, &mut out, &mut s);
        out
    }

    #[test]
    fn dot8_matches_reference_reduction_closely() {
        let mut rng = Rng::seeded(7);
        for n in [1usize, 7, 8, 9, 24, 37, 64] {
            let a = Matrix::randn(1, n, 1.0, &mut rng);
            let b = Matrix::randn(1, n, 1.0, &mut rng);
            let fast = dot8(a.row(0), b.row(0));
            let exact: f64 = a
                .row(0)
                .iter()
                .zip(b.row(0))
                .map(|(&x, &y)| x as f64 * y as f64)
                .sum();
            assert!(
                (fast as f64 - exact).abs() <= 1e-4 * exact.abs().max(1.0),
                "n={n}: dot8={fast} exact={exact}"
            );
        }
    }

    #[test]
    fn fast_tier_tracks_oracle_on_packed_gemv() {
        let (x, w) = packed_fixture(17, 29, 42); // odd dims exercise the tail
        let oracle = run(&OracleKernels::new(1), &x, &w);
        let fast = run(&FastKernels::new(1), &x, &w);
        for (o, f) in oracle.data.iter().zip(fast.data.iter()) {
            assert!((o - f).abs() <= 1e-4 * o.abs().max(1e-3), "oracle={o} fast={f}");
        }
    }

    #[test]
    fn fast_tier_is_bit_identical_across_worker_counts() {
        let (x, w) = packed_fixture(33, 40, 99);
        let solo = run(&FastKernels::new(1), &x, &w);
        for threads in [2usize, 4] {
            let pooled = run(&FastKernels::new(threads), &x, &w);
            assert_eq!(
                solo.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                pooled.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "fast tier must be deterministic at {threads} workers"
            );
        }
    }

    #[test]
    fn default_softmax_normalizes_and_matches_attention_order() {
        let oracle = OracleKernels::new(1);
        let mut scores = [1.5f32, -0.25, 3.0, 0.0];
        let mut reference = scores;
        oracle.softmax(&mut scores);
        // reference: the attention kernel's exact operation order
        let mx = reference.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0.0f32;
        for sc in reference.iter_mut() {
            *sc = (*sc - mx).exp();
            denom += *sc;
        }
        let inv = 1.0 / denom;
        for (got, p) in scores.iter().zip(reference.iter()) {
            assert_eq!(got.to_bits(), (p * inv).to_bits());
        }
        let sum: f32 = scores.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6);
    }

    #[test]
    fn for_tier_builds_the_right_backend() {
        assert_eq!(for_tier(KernelTier::Oracle, 2).tier(), KernelTier::Oracle);
        assert_eq!(for_tier(KernelTier::Fast, 2).tier(), KernelTier::Fast);
    }
}
