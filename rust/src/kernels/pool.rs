//! A persistent worker pool for decode-step parallelism.
//!
//! The packed GEMV path used to shard rows across short-lived
//! `std::thread::scope` threads on *every* call — a spawn/join pair per
//! linear, per decode step. `WorkerPool` replaces that with a fixed set of
//! threads that live as long as the compiled plan and pull closures off a
//! shared channel. `run` blocks until every submitted task has completed,
//! which is what makes the (internally unsafe) lifetime erasure in
//! [`WorkerPool::run`] sound: no task can outlive the borrow it captures.
//!
//! Panic behaviour is part of the serving fault contract: a panic inside a
//! pooled task is caught on the worker (the worker itself survives and keeps
//! serving future jobs), ferried back over the ack channel, and re-raised on
//! the caller via `resume_unwind` with the *original payload*. Typed fault
//! payloads (`FaultPayload`) therefore reach the coordinator's quarantine
//! logic exactly as they would from a solo run.

use std::any::Any;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// A borrowed task submitted to [`WorkerPool::run`]. The lifetime ties the
/// closure to the caller's stack frame; `run` erases it only after arranging
/// to block until the task has finished.
pub type ScopedTask<'s> = Box<dyn FnOnce() + Send + 's>;

type ErasedTask = Box<dyn FnOnce() + Send + 'static>;
type PanicPayload = Box<dyn Any + Send>;

struct Job {
    task: ErasedTask,
    ack: Sender<Result<(), PanicPayload>>,
}

/// Fixed-size pool of persistent worker threads.
///
/// With `threads <= 1` the pool spawns nothing and [`run`](Self::run)
/// executes tasks inline on the caller, so a solo configuration has zero
/// threading overhead and trivially identical results.
pub struct WorkerPool {
    threads: usize,
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl std::fmt::Debug for WorkerPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkerPool")
            .field("threads", &self.threads)
            .finish()
    }
}

impl WorkerPool {
    /// Build a pool with `threads` workers (0 and 1 both mean "inline").
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        if threads == 1 {
            return WorkerPool {
                threads,
                tx: None,
                workers: Vec::new(),
            };
        }
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || loop {
                    let job = {
                        let guard = rx.lock().unwrap_or_else(|p| p.into_inner());
                        guard.recv()
                    };
                    let Ok(Job { task, ack }) = job else {
                        return; // channel closed: pool is being dropped
                    };
                    let outcome = catch_unwind(AssertUnwindSafe(task));
                    // The caller may itself be unwinding from an earlier
                    // task's panic; a dead ack receiver is fine.
                    let _ = ack.send(outcome);
                })
            })
            .collect();
        WorkerPool {
            threads,
            tx: Some(tx),
            workers,
        }
    }

    /// Number of workers this pool was built with (>= 1; 1 means inline).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run every task to completion, then return. Tasks may borrow from the
    /// caller's stack. If any task panicked, the first panic payload (in
    /// submission order) is re-raised here via `resume_unwind` after all
    /// tasks have finished, so no task is left running against freed stack.
    pub fn run(&self, tasks: Vec<ScopedTask<'_>>) {
        let Some(tx) = &self.tx else {
            // Inline path: execute sequentially on the caller. A panic
            // propagates naturally with its original payload.
            for task in tasks {
                task();
            }
            return;
        };
        let n = tasks.len();
        let (ack_tx, ack_rx) = channel::<Result<(), PanicPayload>>();
        for task in tasks {
            // SAFETY: we block on `n` acks below before returning, and
            // workers send an ack only after the task has run (or been
            // consumed by a panic). The closure therefore cannot outlive
            // the borrows it captures.
            let erased: ErasedTask = unsafe {
                std::mem::transmute::<ScopedTask<'_>, ErasedTask>(task)
            };
            tx.send(Job {
                task: erased,
                ack: ack_tx.clone(),
            })
            .expect("worker pool channel closed while pool is alive");
        }
        drop(ack_tx);
        let mut first_panic: Option<PanicPayload> = None;
        for _ in 0..n {
            match ack_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(payload)) => {
                    if first_panic.is_none() {
                        first_panic = Some(payload);
                    }
                }
                Err(_) => unreachable!("worker dropped ack without sending"),
            }
        }
        if let Some(payload) = first_panic {
            resume_unwind(payload);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing the job channel makes every worker's recv fail and return.
        self.tx = None;
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_borrowed_tasks_to_completion() {
        let pool = WorkerPool::new(4);
        let mut out = vec![0usize; 64];
        let tasks: Vec<ScopedTask<'_>> = out
            .chunks_mut(16)
            .enumerate()
            .map(|(i, chunk)| {
                let t: ScopedTask<'_> = Box::new(move || {
                    for (j, slot) in chunk.iter_mut().enumerate() {
                        *slot = i * 100 + j;
                    }
                });
                t
            })
            .collect();
        pool.run(tasks);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, (i / 16) * 100 + (i % 16));
        }
    }

    #[test]
    fn inline_pool_spawns_no_threads() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.threads(), 1);
        assert!(pool.workers.is_empty());
        let counter = AtomicUsize::new(0);
        let tasks: Vec<ScopedTask<'_>> = (0..8)
            .map(|_| {
                let t: ScopedTask<'_> = Box::new(|| {
                    counter.fetch_add(1, Ordering::SeqCst);
                });
                t
            })
            .collect();
        pool.run(tasks);
        assert_eq!(counter.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn panic_payload_survives_the_pool() {
        #[derive(Debug, PartialEq)]
        struct Typed(u32);
        let pool = WorkerPool::new(2);
        let result = catch_unwind(AssertUnwindSafe(|| {
            let tasks: Vec<ScopedTask<'_>> = vec![
                Box::new(|| {}),
                Box::new(|| std::panic::panic_any(Typed(7))),
                Box::new(|| {}),
            ];
            pool.run(tasks);
        }));
        let payload = result.expect_err("panic must propagate to the caller");
        let typed = payload.downcast::<Typed>().expect("payload type preserved");
        assert_eq!(*typed, Typed(7));
    }

    #[test]
    fn pool_survives_a_panicking_task() {
        let pool = WorkerPool::new(2);
        let _ = catch_unwind(AssertUnwindSafe(|| {
            let tasks: Vec<ScopedTask<'_>> = vec![Box::new(|| panic!("boom"))];
            pool.run(tasks);
        }));
        // All workers are still alive and serving.
        let counter = AtomicUsize::new(0);
        let tasks: Vec<ScopedTask<'_>> = (0..16)
            .map(|_| {
                let t: ScopedTask<'_> = Box::new(|| {
                    counter.fetch_add(1, Ordering::SeqCst);
                });
                t
            })
            .collect();
        pool.run(tasks);
        assert_eq!(counter.load(Ordering::SeqCst), 16);
    }

    #[test]
    fn all_tasks_finish_even_when_one_panics() {
        let pool = WorkerPool::new(4);
        let done = AtomicUsize::new(0);
        let _ = catch_unwind(AssertUnwindSafe(|| {
            let tasks: Vec<ScopedTask<'_>> = (0..8)
                .map(|i| {
                    let done = &done;
                    let t: ScopedTask<'_> = Box::new(move || {
                        if i == 3 {
                            panic!("shard fault");
                        }
                        done.fetch_add(1, Ordering::SeqCst);
                    });
                    t
                })
                .collect();
            pool.run(tasks);
        }));
        assert_eq!(done.load(Ordering::SeqCst), 7);
    }
}
