//! Experiment harness — regenerates every table and figure of the paper
//! (DESIGN.md §5 maps each to its module/command).
//!
//! `zqfp table --id 1|2|3|a1` and `zqfp figure --id 1|2` print the
//! paper-shaped rows and write them under `results/`.

mod figures;
mod tables;

use std::collections::HashMap;
use std::path::PathBuf;

use crate::cli::Args;
use crate::data::{read_tokens, CorpusKind};
use crate::engine::EngineOpts;
use crate::eval::PplResult;
use crate::model::{inject_outliers, Checkpoint, ModelConfig, OutlierSpec};
use crate::rng::Rng;
use crate::runtime::{act_tag, score_artifact_name, HloScorer, SCORE_BATCH};

pub fn run_table(args: &Args) -> Result<(), String> {
    let id = args.get("id").ok_or("--id required (1|2|3|a1)")?;
    let mut ctx = ExpContext::from_args(args)?;
    args.finish()?;
    let out = match id.as_str() {
        "1" => tables::table1(&mut ctx)?,
        "2" => tables::table2(&mut ctx)?,
        "3" => tables::table3(&mut ctx)?,
        "a1" | "A1" => tables::table_a1(&mut ctx)?,
        other => return Err(format!("unknown table id {other}")),
    };
    println!("{out}");
    let path = ctx.results.join(format!("table{id}.txt"));
    std::fs::write(&path, &out).map_err(|e| e.to_string())?;
    println!("[written to {}]", path.display());
    Ok(())
}

pub fn run_figure(args: &Args) -> Result<(), String> {
    let id = args.get("id").ok_or("--id required (1|2)")?;
    let mut ctx = ExpContext::from_args(args)?;
    args.finish()?;
    let out = match id.as_str() {
        "1" => figures::figure1(&mut ctx)?,
        "2" => figures::figure2()?,
        other => return Err(format!("unknown figure id {other}")),
    };
    println!("{out}");
    let path = ctx.results.join(format!("figure{id}.txt"));
    std::fs::write(&path, &out).map_err(|e| e.to_string())?;
    println!("[written to {}]", path.display());
    Ok(())
}

/// Which backend evaluates perplexity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RuntimeKind {
    /// PJRT HLO artifacts (fast, the serving path).
    Hlo,
    /// The in-process Rust engine (slow, always available).
    Engine,
}

/// Shared state for one experiment run: directories, eval streams,
/// checkpoint cache, scorer cache.
pub struct ExpContext {
    pub data: PathBuf,
    pub ckpt_dir: PathBuf,
    pub artifacts: PathBuf,
    pub results: PathBuf,
    pub runtime: RuntimeKind,
    pub fast: bool,
    pub seq: usize,
    pub calib_seqs: Vec<Vec<u16>>,
    eval_streams: HashMap<&'static str, Vec<u16>>,
    ckpt_cache: HashMap<String, Checkpoint>,
    pub(crate) hessian_cache: HashMap<String, crate::pipeline::FinalizedHessians>,
    scorers: HashMap<String, HloScorer>,
    pub eval_tokens: usize,
}

impl ExpContext {
    pub fn from_args(args: &Args) -> Result<ExpContext, String> {
        let data = PathBuf::from(args.get_or("data", "data"));
        let ckpt_dir = PathBuf::from(args.get_or("ckpt-dir", "ckpt"));
        let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
        let results = PathBuf::from(args.get_or("results", "results"));
        let runtime = match args.get_or("runtime", "hlo").as_str() {
            "hlo" => RuntimeKind::Hlo,
            "engine" => RuntimeKind::Engine,
            other => return Err(format!("bad --runtime {other}")),
        };
        let fast = args.flag("fast");
        let seq = args.get_usize("seq", 128)?;
        let eval_tokens = args.get_usize("eval-tokens", if fast { 4096 } else { 8192 })?;
        let calib_n = args.get_usize("calib-seqs", if fast { 16 } else { 32 })?;
        std::fs::create_dir_all(&results).map_err(|e| e.to_string())?;

        let calib_all = read_tokens(&data.join("calib.tok"))
            .map_err(|e| format!("calib.tok: {e} (run `zqfp gen-corpus`)"))?;
        let calib_seqs: Vec<Vec<u16>> = calib_all
            .chunks_exact(seq)
            .take(calib_n)
            .map(|c| c.to_vec())
            .collect();

        let mut eval_streams = HashMap::new();
        for kind in CorpusKind::ALL {
            let toks = read_tokens(&data.join(format!("eval_{}.tok", kind.name())))
                .map_err(|e| format!("eval_{}.tok: {e}", kind.name()))?;
            let n = toks.len().min(eval_tokens);
            eval_streams.insert(kind.name(), toks[..n].to_vec());
        }

        Ok(ExpContext {
            data,
            ckpt_dir,
            artifacts,
            results,
            runtime,
            fast,
            seq,
            calib_seqs,
            eval_streams,
            ckpt_cache: HashMap::new(),
            hessian_cache: HashMap::new(),
            scorers: HashMap::new(),
            eval_tokens,
        })
    }

    /// Load (and cache) a family checkpoint with its per-size outlier α
    /// applied (DESIGN.md §4: α is the model-size surrogate).
    pub fn load_model(&mut self, cfg: &ModelConfig, alpha: f32) -> Result<Checkpoint, String> {
        let key = format!("{}@{alpha}", cfg.name);
        if let Some(ck) = self.ckpt_cache.get(&key) {
            return Ok(ck.clone());
        }
        let path = self.ckpt_dir.join(format!("{}.zqckpt", cfg.name));
        let mut ck = Checkpoint::load(&path)
            .map_err(|e| format!("{}: {e} (run `make ckpt`)", path.display()))?;
        ck.config.name = cfg.name.clone();
        if ck.config.d_model != cfg.d_model || ck.config.n_layers != cfg.n_layers {
            return Err(format!("{}: config mismatch with family", path.display()));
        }
        if alpha != 1.0 {
            let mut rng = Rng::seeded(0xA11CE);
            inject_outliers(&mut ck, OutlierSpec::new(alpha), &mut rng);
        }
        self.ckpt_cache.insert(key, ck.clone());
        Ok(ck)
    }

    /// Perplexity of `ck` under `opts` on one corpus (via the configured
    /// runtime; HLO falls back to the engine if the act format has no
    /// artifact).
    pub fn ppl(
        &mut self,
        ck: &Checkpoint,
        opts: EngineOpts,
        corpus: CorpusKind,
    ) -> Result<f64, String> {
        let toks = self.eval_streams.get(corpus.name()).unwrap().clone();
        let seq = self.seq.min(ck.config.max_seq);
        let r: PplResult = if self.runtime == RuntimeKind::Hlo && act_tag(&opts).is_some() {
            self.hlo_ppl(ck, &opts, &toks, seq)?
        } else {
            crate::eval::perplexity(ck, opts, &toks, seq)
        };
        Ok(r.ppl())
    }

    fn hlo_ppl(
        &mut self,
        ck: &Checkpoint,
        opts: &EngineOpts,
        toks: &[u16],
        seq: usize,
    ) -> Result<PplResult, String> {
        if seq != ck.config.max_seq {
            return Err(format!("hlo runtime requires seq == max_seq ({seq})"));
        }
        let name = score_artifact_name(&ck.config, act_tag(opts).unwrap());
        if !self.scorers.contains_key(&name) {
            // HloScorer::load reuses the per-thread PJRT client, so loading
            // dozens of artifacts here still shares one client.
            let path = self.artifacts.join(&name);
            let scorer = HloScorer::load(&path, SCORE_BATCH, ck.config.max_seq)
                .map_err(|e| format!("{e:#}"))?;
            self.scorers.insert(name.clone(), scorer);
        }
        let scorer = self.scorers.get(&name).unwrap();
        let weights = scorer.upload_weights(ck).map_err(|e| format!("{e:#}"))?;
        scorer.ppl_with(&weights, toks).map_err(|e| format!("{e:#}"))
    }

    /// Mean + per-corpus PPL, formatted the paper's way
    /// (`Mean  WIKI/PTB/C4`).
    pub fn ppl_row(&mut self, ck: &Checkpoint, opts: EngineOpts) -> Result<PplRow, String> {
        let mut per = Vec::new();
        for kind in CorpusKind::ALL {
            per.push(self.ppl(ck, opts, kind)?);
        }
        Ok(PplRow { wiki: per[0], ptb: per[1], c4: per[2] })
    }
}

/// One table cell: mean + per-dataset breakdown.
#[derive(Debug, Clone, Copy)]
pub struct PplRow {
    pub wiki: f64,
    pub ptb: f64,
    pub c4: f64,
}

impl PplRow {
    pub fn mean(&self) -> f64 {
        (self.wiki + self.ptb + self.c4) / 3.0
    }

    pub fn fmt(&self) -> String {
        format!(
            "{:>7.2} {:>6.2}/{:>6.2}/{:>6.2}",
            self.mean(),
            self.wiki,
            self.ptb,
            self.c4
        )
    }
}
