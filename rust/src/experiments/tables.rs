//! Table generators: the paper's Tables 1, 2, 3 and A.1 re-run on the
//! synthetic model family (see DESIGN.md §2 for the substitutions and §5
//! for the expected *shape* of each result).

use std::fmt::Write as _;

use super::{ExpContext, PplRow};
use crate::engine::EngineOpts;
use crate::formats::NumericFormat;
use crate::lorc::LorcConfig;
use crate::model::{Arch, ModelConfig};
use crate::pipeline::{calibrate_finalized, ptq, FinalizedHessians};
use crate::quant::{ScaleConstraint, Scheme};
use crate::recipe::{QuantRecipe, RecipeBuilder};

fn family_for(ctx: &ExpContext, arch: Arch) -> Vec<(ModelConfig, f32)> {
    let fam = ModelConfig::family(arch);
    if ctx.fast {
        // fast mode: smallest + largest only
        vec![fam[0].clone(), fam[3].clone()]
    } else {
        fam
    }
}

fn act_opts(fmt: NumericFormat) -> EngineOpts {
    EngineOpts::with_act(fmt)
}

/// Table 1 — FP16 vs INT8 activation (weights untouched): the activation-
/// outlier collapse across model sizes. We add the W16-A8(FP8) row the
/// paper's Section 2 motivates.
pub fn table1(ctx: &mut ExpContext) -> Result<String, String> {
    let mut out = String::new();
    writeln!(out, "Table 1: FP16 vs INT8/FP8 activation quantization (weights FP16).").ok();
    writeln!(
        out,
        "Model size axis is reproduced as (width, depth, outlier-alpha); see DESIGN.md §4.\n"
    )
    .ok();
    for arch in [Arch::Opt, Arch::Llama] {
        let fam = family_for(ctx, arch);
        let mut header = format!("{:<14}", "Precision");
        for (cfg, alpha) in &fam {
            header.push_str(&format!("{:>22}", format!("{} (α={alpha})", cfg.name)));
        }
        writeln!(out, "{header}").ok();
        for (label, fmt) in [
            ("W16-A16", NumericFormat::F16),
            ("W16-A8 (INT8)", NumericFormat::INT8),
            ("W16-A8 (FP8)", NumericFormat::FP8_E4M3),
        ] {
            let mut row = format!("{label:<14}");
            for (cfg, alpha) in &fam {
                let ck = ctx.load_model(cfg, *alpha)?;
                let cell = ctx.ppl_row(&ck, act_opts(fmt))?;
                row.push_str(&format!("{:>22.2}", cell.mean()));
            }
            writeln!(out, "{row}").ok();
        }
        writeln!(out).ok();
    }
    writeln!(
        out,
        "expected shape: INT8 activation degrades sharply as alpha grows;\n\
         FP8 stays near W16A16 (paper Table 1: OPT-66b 10.33 -> 561.35 under INT8)."
    )
    .ok();
    Ok(out)
}

/// The Q-type block structure of Table 2: (group label, schemes, lorc).
fn table2_rows() -> Vec<(&'static str, Vec<&'static str>, bool)> {
    vec![
        ("W16A16", vec!["w16a16"], false),
        ("W8A8", vec!["w8a8-int-int", "w8a8-int-fp", "w8a8-fp-fp"], false),
        ("W4A8", vec!["w4a8-int-int", "w4a8-int-fp", "w4a8-fp-fp"], false),
        ("W4A8+LoRC", vec!["w4a8-int-int", "w4a8-int-fp", "w4a8-fp-fp"], true),
    ]
}

fn scheme_kind_label(s: &str) -> &'static str {
    if s == "w16a16" {
        "N/A"
    } else if s.ends_with("int-int") {
        "INT-INT"
    } else if s.ends_with("int-fp") {
        "INT-FP"
    } else {
        "FP-FP"
    }
}

/// Quantize (Hessians cached by the caller) + evaluate one recipe cell.
fn cell(
    ctx: &mut ExpContext,
    ck: &crate::model::Checkpoint,
    hessians: &FinalizedHessians,
    recipe: &QuantRecipe,
) -> Result<PplRow, String> {
    let out = ptq(ck, &ctx.calib_seqs, Some(hessians), recipe);
    ctx.ppl_row(&out.checkpoint, recipe.engine_opts())
}

/// Table 2 — the main result: INT vs FP quantization for weight and
/// activation across both model families, with and without LoRC.
pub fn table2(ctx: &mut ExpContext) -> Result<String, String> {
    let mut out = String::new();
    writeln!(
        out,
        "Table 2: INT vs FP quantization (GPTQ + FGQ weights, token-wise activations).\n\
         Cells are `mean  wiki/ptb/c4` perplexity.\n"
    )
    .ok();
    for arch in [Arch::Llama, Arch::Opt] {
        let fam = family_for(ctx, arch);
        let mut header = format!("{:<11}{:<9}", "Q-type", "W-A");
        for (cfg, _) in &fam {
            header.push_str(&format!("{:>30}", cfg.name));
        }
        writeln!(out, "{header}").ok();
        for (qtype, schemes, lorc) in table2_rows() {
            for s in schemes {
                let mut row = format!("{qtype:<11}{:<9}", scheme_kind_label(s));
                for (mcfg, alpha) in &fam {
                    let ck = ctx.load_model(mcfg, *alpha)?;
                    let scheme = Scheme::parse(s).unwrap();
                    let mut b = RecipeBuilder::new(scheme);
                    if lorc {
                        b = b.lorc(LorcConfig::default());
                    }
                    let recipe = b.build().map_err(|e| e.to_string())?;
                    let hessians = ctx.hessians_for(&ck)?;
                    let cell = cell(ctx, &ck, &hessians, &recipe)?;
                    row.push_str(&format!("{:>30}", cell.fmt()));
                }
                writeln!(out, "{row}").ok();
            }
        }
        writeln!(out).ok();
    }
    writeln!(
        out,
        "expected shape: (i) A8 INT-INT >> FP rows at large alpha; (ii) W4A8 FP-FP <=\n\
         W4A8 INT-FP <= W4A8 INT-INT; (iii) LoRC shrinks the W4A8 gap, most for small models."
    )
    .ok();
    Ok(out)
}

/// Table 3 — power-of-2 scale constraints (✗ / M1 / M2) on W4A8 FP-FP,
/// with and without LoRC.
pub fn table3(ctx: &mut ExpContext) -> Result<String, String> {
    let mut out = String::new();
    writeln!(
        out,
        "Table 3: scale constraints S=2^n for FP4 weights (FP8 activations).\n\
         Cells are `mean  wiki/ptb/c4` perplexity.\n"
    )
    .ok();
    let scheme = Scheme::parse("w4a8-fp-fp").unwrap();
    for arch in [Arch::Llama, Arch::Opt] {
        let fam = family_for(ctx, arch);
        let mut header = format!("{:<11}{:<8}", "Q-type", "S=2^n");
        for (cfg, _) in &fam {
            header.push_str(&format!("{:>30}", cfg.name));
        }
        writeln!(out, "{header}").ok();
        for lorc in [false, true] {
            let qtype = if lorc { "W4A8+LoRC" } else { "W4A8" };
            for (clabel, constraint) in [
                ("x", ScaleConstraint::None),
                ("M1", ScaleConstraint::M1),
                ("M2", ScaleConstraint::M2 { rows: 32 }),
            ] {
                let mut row = format!("{qtype:<11}{clabel:<8}");
                for (mcfg, alpha) in &fam {
                    let ck = ctx.load_model(mcfg, *alpha)?;
                    // constrained scales are what the bit-shift cast needs;
                    // exercise the footnote-4 E5M2 cast in the same run
                    // (exactly the w4a8-fp-m1 / w4a8-fp-m2 presets)
                    let mut b = RecipeBuilder::new(scheme)
                        .constraint(constraint)
                        .cast_fp4_to_e5m2(!matches!(constraint, ScaleConstraint::None));
                    if lorc {
                        b = b.lorc(LorcConfig::default());
                    }
                    let recipe = b.build().map_err(|e| e.to_string())?;
                    let hessians = ctx.hessians_for(&ck)?;
                    let c = cell(ctx, &ck, &hessians, &recipe)?;
                    row.push_str(&format!("{:>30}", c.fmt()));
                }
                writeln!(out, "{row}").ok();
            }
        }
        writeln!(out).ok();
    }
    writeln!(
        out,
        "expected shape: minor degradation from x -> M1/M2; M2 >= M1 on average;\n\
         LoRC mitigates the constrained rows."
    )
    .ok();
    Ok(out)
}

/// Table A.1 — FP4 E2M1 vs E3M0 weight formats (FP8 activations), without
/// (top block) and with (bottom block) LoRC, OPT family.
pub fn table_a1(ctx: &mut ExpContext) -> Result<String, String> {
    let mut out = String::new();
    writeln!(out, "Table A.1: FP4 exponent/mantissa split for weights (act FP8 E4M3).\n").ok();
    let fam = family_for(ctx, Arch::Opt);
    let mut header = format!("{:<26}", "Weight-FP4");
    for (cfg, _) in &fam {
        header.push_str(&format!("{:>12}", cfg.name));
    }
    writeln!(out, "{header}").ok();
    for lorc in [true, false] {
        for (label, s) in [
            ("E3M0", "w4a8-fpe3m0-fp"),
            ("E2M1", "w4a8-fp-fp"),
        ] {
            let tag = if lorc { "+LoRC" } else { "" };
            let mut row = format!("{:<26}", format!("{label}{tag}"));
            for (mcfg, alpha) in &fam {
                let ck = ctx.load_model(mcfg, *alpha)?;
                let scheme = Scheme::parse(s).unwrap();
                let mut b = RecipeBuilder::new(scheme);
                if lorc {
                    b = b.lorc(LorcConfig::default());
                }
                let recipe = b.build().map_err(|e| e.to_string())?;
                let hessians = ctx.hessians_for(&ck)?;
                let c = cell(ctx, &ck, &hessians, &recipe)?;
                row.push_str(&format!("{:>12.2}", c.mean()));
            }
            writeln!(out, "{row}").ok();
        }
    }
    writeln!(out, "\nexpected shape: E2M1 < E3M0 on every size (paper Table A.1).").ok();
    Ok(out)
}

impl ExpContext {
    /// Cached finalized Hessians per (model, alpha) — shared across every
    /// scheme in a table (the paper holds the GPTQ data fixed too).
    pub fn hessians_for(
        &mut self,
        ck: &crate::model::Checkpoint,
    ) -> Result<FinalizedHessians, String> {
        // key by name+layers (name carries the alpha-injected cache key)
        let key = format!("hess:{}:{}", ck.config.name, ck.config.n_layers);
        if let Some(h) = self.hessian_cache.get(&key) {
            return Ok(h.clone());
        }
        let h = calibrate_finalized(ck, &self.calib_seqs);
        self.hessian_cache.insert(key, h.clone());
        Ok(h)
    }
}
