//! Figure generators: activation-distribution histograms (Figure 1) and the
//! INT8-vs-FP8 outlier-vector contrast (Figure 2), rendered as text.

use std::fmt::Write as _;

use super::ExpContext;
use crate::engine::{ActivationCapture, Engine, LinearSite, Site};
use crate::formats::{FpFormat, IntFormat, NumericFormat};
use crate::model::ModelConfig;

/// Figure 1 — distribution of activation values at the inputs of
/// `attn.q_proj`, `attn.out_proj`, `fc1`, `fc2` for an early, middle and
/// final layer. The paper runs a random C4 sentence through OPT-1.3b; we
/// run a C4-surrogate window through the largest OPT-family member (outlier
/// alpha applied) and render 50-bin ASCII histograms.
pub fn figure1(ctx: &mut ExpContext) -> Result<String, String> {
    let (cfg, alpha) = ModelConfig::by_name("opt-l").ok_or("missing opt-l in family")?;
    let ck = ctx.load_model(&cfg, alpha)?;
    let tokens: Vec<u16> = {
        let c = crate::data::Corpus::new(crate::data::CorpusKind::C4);
        c.generate(cfg.max_seq.min(ctx.seq), 11)
    };
    let engine = Engine::new(&ck);
    let mut cap = ActivationCapture::default();
    engine.forward_observed(&tokens, &mut |s, x| cap.record(s, x));

    let layers = [0usize, cfg.n_layers / 2, cfg.n_layers - 1];
    let mut out = String::new();
    writeln!(
        out,
        "Figure 1: activation value distributions, {} (alpha={alpha}), one C4 window.\n",
        cfg.name
    )
    .ok();
    for layer in layers {
        writeln!(out, "--- layer {layer} ---").ok();
        for site in LinearSite::ALL {
            let st = cap
                .stats
                .get(&Site { layer, site })
                .ok_or("missing capture")?;
            writeln!(
                out,
                "{:<15} min {:>9.3}  max {:>9.3}  rms {:>8.4}  peak/rms {:>7.1}",
                site.paper_name(),
                st.min,
                st.max,
                st.rms(),
                st.peak_to_rms()
            )
            .ok();
            out.push_str(&render_hist(&st.hist, st.hist_lo, st.hist_hi, 50));
        }
        writeln!(out).ok();
    }
    writeln!(
        out,
        "expected shape: q_proj ~normal (post-LN); out_proj and fc2 skewed with\n\
         outlier channels; fc2 clusters at 0 (ReLU) with a positive tail."
    )
    .ok();
    Ok(out)
}

/// Render a histogram as a compact ASCII sparkline block.
fn render_hist(hist: &[u64], lo: f32, hi: f32, cols: usize) -> String {
    // re-bin to `cols`
    let mut bins = vec![0u64; cols];
    for (i, &c) in hist.iter().enumerate() {
        bins[i * cols / hist.len()] += c;
    }
    let max = *bins.iter().max().unwrap_or(&1).max(&1);
    let glyphs = [' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
    let mut line = String::with_capacity(cols + 32);
    line.push_str("  |");
    for &b in &bins {
        let g = if b == 0 {
            0
        } else {
            1 + ((b as f64).ln() / (max as f64).ln().max(1e-9) * 8.0) as usize
        };
        line.push(glyphs[g.min(9)]);
    }
    line.push('|');
    format!("{line}  [{lo:.2} .. {hi:.2}] log-scale\n")
}

/// Figure 2 — a 15-element vector with an outlier at 100, quantized with
/// INT8-asymmetric vs FP8 E5M2/E4M3 (absmax scaling), exactly as in the
/// paper's illustration.
pub fn figure2() -> Result<String, String> {
    // A clustered vector + one outlier, mirroring the paper's figure.
    let original: [f32; 15] = [
        -0.35, -0.28, -0.21, -0.15, -0.08, -0.03, 0.02, 0.07, 0.12, 0.18, 0.25, 0.31, 0.38,
        0.45, 100.0,
    ];
    let mut out = String::new();
    writeln!(
        out,
        "Figure 2: INT8 vs FP8 quantization of a 15-element vector with outlier 100.\n"
    )
    .ok();
    let fmt_row = |label: &str, vals: &[f32]| -> String {
        let mut s = format!("{label:<14}");
        for v in vals {
            s.push_str(&format!("{v:>8.3}"));
        }
        s.push('\n');
        s
    };
    out.push_str(&fmt_row("original", &original));

    // INT8 asymmetric over [min, max]
    let int8 = IntFormat::INT8_ASYM;
    let (mn, mx) = original
        .iter()
        .fold((f32::INFINITY, f32::NEG_INFINITY), |(a, b), &v| (a.min(v), b.max(v)));
    let p = int8.params(mn, mx);
    let int_vals: Vec<f32> = original.iter().map(|&v| int8.quantize(v, p)).collect();
    out.push_str(&fmt_row("INT8 asym", &int_vals));

    for (label, f) in [("FP8-E5M2", FpFormat::E5M2), ("FP8-E4M3", FpFormat::E4M3)] {
        let scale = mx.abs().max(mn.abs()) / f.max_finite() as f32;
        let vals: Vec<f32> = original.iter().map(|&v| f.quantize(v / scale) * scale).collect();
        out.push_str(&fmt_row(label, &vals));
    }

    // quantization error on the clustered part (excluding the outlier)
    writeln!(out).ok();
    let cluster = &original[..14];
    for (label, fmtv) in [
        ("INT8 asym", NumericFormat::INT8_ASYM),
        ("FP8-E5M2", NumericFormat::FP8_E5M2),
        ("FP8-E4M3", NumericFormat::FP8_E4M3),
    ] {
        // quantize the full vector (outlier included in the range), then
        // measure error on the cluster only
        let mut all = original.to_vec();
        fmtv.fake_quant_slice_dynamic(&mut all);
        let mse: f64 = cluster
            .iter()
            .zip(&all[..14])
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / 14.0;
        writeln!(out, "cluster MSE {label:<10} {mse:.3e}").ok();
    }
    writeln!(
        out,
        "\nexpected shape: INT8 nails the outlier but flattens the cluster;\n\
         FP8 (either split) preserves the cluster to ~1e-5 MSE."
    )
    .ok();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure2_renders_and_shows_the_effect() {
        let s = figure2().unwrap();
        assert!(s.contains("original"));
        assert!(s.contains("INT8 asym"));
        assert!(s.contains("FP8-E4M3"));
        // INT8 cluster values collapse to multiples of ~0.39
        assert!(s.contains("cluster MSE"));
    }

    #[test]
    fn hist_rendering_is_bounded() {
        let h = vec![0u64, 5, 100, 3, 0, 0, 9];
        let s = render_hist(&h, -1.0, 1.0, 20);
        assert!(s.contains('|'));
        assert!(s.len() < 120);
    }
}
