//! Power-of-2 scale constraints — Section 3, "Casting the FP4 to FP8".
//!
//! On H100 the W4A8 GEMM must cast FP4 weights up to FP8 before the MXU/
//! tensor-core multiply. If the weight scale S is an arbitrary real, the
//! cast is a dequant+requant (slow); if `S = 2^n` the cast is a pure
//! exponent-field add (bit shift). The paper proposes two projections:
//!
//! * **M1** — snap each scale independently: `Ŝ = 2^⌈log2 S⌉`.
//! * **M2** — per *compute group* (several rows of the matrix sharing one
//!   GEMM tile): keep one arbitrary `S_max = max_i S_i` per group and make
//!   every member's *ratio* a power of two:
//!   `Ŝ_i = S_max / 2^⌈log2(S_max / S_i)⌉`. Only the ratios need to be
//!   shifts at compute time, so M2 approximates the original scales far
//!   better than M1 (Table 3: M2 ≳ M1).

/// Which constraint to apply to the FGQ scale tensor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleConstraint {
    /// Unconstrained real scales (the paper's ✗ rows).
    None,
    /// M1: snap every scale to the next power of two.
    M1,
    /// M2: power-of-two *ratios* within compute groups of `rows` rows.
    /// The paper's compute group is "a (multiple) row(s) of the weight
    /// matrix"; scales of the same column-group across `rows` consecutive
    /// rows form one group.
    M2 { rows: usize },
}

impl ScaleConstraint {
    /// Parse `none`/`x`/`off`, `m1`, `m2` (compute group of 32 rows), or
    /// `m2:<rows>` for an explicit compute-group height (`m2:0` is
    /// rejected — a zero-row group is meaningless).
    pub fn parse(s: &str) -> Option<ScaleConstraint> {
        let t = s.to_ascii_lowercase();
        if let Some(rows) = t.strip_prefix("m2:") {
            let rows: usize = rows.parse().ok()?;
            if rows == 0 {
                return None;
            }
            return Some(ScaleConstraint::M2 { rows });
        }
        match t.as_str() {
            "none" | "x" | "off" => Some(ScaleConstraint::None),
            "m1" => Some(ScaleConstraint::M1),
            "m2" => Some(ScaleConstraint::M2 { rows: 32 }),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ScaleConstraint::None => "none",
            ScaleConstraint::M1 => "M1",
            ScaleConstraint::M2 { .. } => "M2",
        }
    }

    /// Round-trippable label (`m2:16` parses back to `M2 { rows: 16 }`).
    pub fn label(&self) -> String {
        match self {
            ScaleConstraint::None => "none".to_string(),
            ScaleConstraint::M1 => "m1".to_string(),
            ScaleConstraint::M2 { rows } => format!("m2:{rows}"),
        }
    }
}

/// `2^⌈log2 x⌉`, exact at powers of two. Total over the degenerate inputs
/// real scale tensors produce: `0.0` (an all-zero weight group) maps to
/// `0.0`, negative/NaN/infinite inputs pass through unchanged, and the
/// result is clamped into the f32 *normal* range — a subnormal scale snaps
/// up to at least `f32::MIN_POSITIVE` so downstream `x / scale` divisions
/// never hit a flushed-to-zero or subnormal divisor.
#[inline]
pub fn next_pow2(x: f32) -> f32 {
    if !(x > 0.0) || !x.is_finite() {
        return x; // zero, negative, NaN, inf: passthrough
    }
    let e = crate::formats::exponent_floor(x as f64);
    let p = crate::formats::pow2(e);
    let e = if (x as f64) == p { e } else { e + 1 };
    // f32 normal exponents span [-126, 127]; outside that, bit-shift
    // dequant is meaningless anyway, so clamp rather than produce a
    // subnormal (or zero/inf) power of two.
    crate::formats::pow2(e.clamp(-126, 127)) as f32
}

/// Apply a constraint to an FGQ scale tensor laid out `[rows, n_groups]`
/// row-major (the layout [`crate::quant::QuantizedWeight`] uses).
pub fn constrain_scales(
    scales: &mut [f32],
    rows: usize,
    n_groups: usize,
    constraint: ScaleConstraint,
) {
    debug_assert_eq!(scales.len(), rows * n_groups);
    match constraint {
        ScaleConstraint::None => {}
        ScaleConstraint::M1 => {
            // Zero scales (an absmax so tiny the `absmax / max_finite`
            // division underflowed) stay zero — such a group quantizes to
            // all-zero codes either way. Subnormal scales are snapped up
            // into the normal range by `next_pow2`.
            for s in scales.iter_mut() {
                if *s > 0.0 && s.is_finite() {
                    *s = next_pow2(*s);
                }
            }
        }
        ScaleConstraint::M2 { rows: block } => {
            let block = block.max(1);
            // Group = same column-group across `block` consecutive rows.
            for g in 0..n_groups {
                for r0 in (0..rows).step_by(block) {
                    let r1 = (r0 + block).min(rows);
                    let mut smax = 0.0f32;
                    for r in r0..r1 {
                        let s = scales[r * n_groups + g];
                        if s.is_finite() {
                            smax = smax.max(s);
                        }
                    }
                    if smax <= 0.0 {
                        continue; // all-zero compute group: nothing to snap
                    }
                    for r in r0..r1 {
                        let s = scales[r * n_groups + g];
                        if s <= 0.0 || !s.is_finite() {
                            continue; // zero group inside a nonzero block
                        }
                        let ratio = smax / s; // >= 1
                        if !ratio.is_finite() {
                            // `s` is so far below `smax` (subnormal vs
                            // normal) that the ratio overflows; no finite
                            // power-of-two shift exists — leave the scale
                            // as-is (the packed path validates and falls
                            // back to multiply for such groups).
                            continue;
                        }
                        let shift = next_pow2(ratio); // 2^ceil(log2 ratio)
                        let snapped = smax / shift;
                        if snapped > 0.0 {
                            scales[r * n_groups + g] = snapped;
                        }
                    }
                }
            }
        }
    }
}

/// True if `x` is exactly a power of two (sanity helper for tests and for
/// the bit-shift cast path).
pub fn is_pow2(x: f32) -> bool {
    if !(x > 0.0) || !x.is_finite() {
        return false;
    }
    let bits = (x as f64).to_bits();
    bits & ((1u64 << 52) - 1) == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn next_pow2_basics() {
        assert_eq!(next_pow2(1.0), 1.0);
        assert_eq!(next_pow2(1.1), 2.0);
        assert_eq!(next_pow2(2.0), 2.0);
        assert_eq!(next_pow2(0.3), 0.5);
        assert_eq!(next_pow2(0.25), 0.25);
        assert_eq!(next_pow2(1000.0), 1024.0);
    }

    #[test]
    fn m1_makes_all_scales_pow2() {
        let mut s = vec![0.013, 0.9, 3.7, 0.0625];
        constrain_scales(&mut s, 2, 2, ScaleConstraint::M1);
        for &x in &s {
            assert!(is_pow2(x), "{x}");
        }
        // and each is >= original (ceil)
        assert!(s[0] >= 0.013 && s[0] < 0.026);
    }

    #[test]
    fn m2_ratios_are_pow2_and_max_preserved() {
        let mut s = vec![0.5, 0.011, 0.32, 0.07];
        let orig = s.clone();
        constrain_scales(&mut s, 4, 1, ScaleConstraint::M2 { rows: 4 });
        let smax = orig.iter().cloned().fold(0.0f32, f32::max);
        // the max scale is untouched
        assert!(s.contains(&smax));
        for &x in &s {
            assert!(is_pow2(smax / x), "ratio {}", smax / x);
            // Ŝ_i = smax / 2^ceil(...) <= S_i
        }
        for (a, b) in s.iter().zip(&orig) {
            assert!(*a <= *b + 1e-9);
            assert!(*a >= *b / 2.0 - 1e-9, "within one shift: {a} vs {b}");
        }
    }

    #[test]
    fn m2_blocks_are_independent() {
        let mut s = vec![1.0, 0.3, /* block 2 */ 0.011, 0.004];
        constrain_scales(&mut s, 4, 1, ScaleConstraint::M2 { rows: 2 });
        // block 1 max = 1.0 preserved; block 2 max = 0.011 preserved
        assert_eq!(s[0], 1.0);
        assert_eq!(s[2], 0.011);
        assert!(is_pow2(1.0 / s[1]));
        assert!(is_pow2(0.011 / s[3]));
    }

    #[test]
    fn m2_exact_on_clustered_scales_where_m1_is_not() {
        // The mechanism behind "M2 provides a far superior approximation":
        // M2 keeps one arbitrary-precision S_max per compute group and only
        // quantizes the *ratios*. When a group's scales coincide (common for
        // rows of the same layer), M2 reproduces them exactly, while M1
        // forces every scale to a power of two.
        let s0 = 0.0137f32; // not a power of two
        let mut m1 = vec![s0; 16];
        let mut m2 = vec![s0; 16];
        constrain_scales(&mut m1, 16, 1, ScaleConstraint::M1);
        constrain_scales(&mut m2, 16, 1, ScaleConstraint::M2 { rows: 16 });
        assert!(m2.iter().all(|&x| x == s0), "M2 must be exact here");
        assert!(m1.iter().all(|&x| x != s0), "M1 cannot represent 0.0137");
        // scales exactly a pow2 ratio below smax are also exact under M2
        let mut m2b = vec![s0, s0 / 2.0, s0 / 8.0, s0];
        let orig = m2b.clone();
        constrain_scales(&mut m2b, 4, 1, ScaleConstraint::M2 { rows: 4 });
        assert_eq!(m2b, orig);
    }

    #[test]
    fn both_constraints_bounded_by_one_binade() {
        // Worst-case scale distortion for either method is < 2x.
        let mut rng = crate::rng::Rng::seeded(51);
        let orig: Vec<f32> = (0..256).map(|_| rng.uniform_f32(0.001, 0.1)).collect();
        for c in [ScaleConstraint::M1, ScaleConstraint::M2 { rows: 8 }] {
            let mut s = orig.clone();
            constrain_scales(&mut s, 8, 32, c);
            for (a, o) in s.iter().zip(&orig) {
                let ratio = a / o;
                assert!(
                    (0.5..2.0).contains(&ratio) || (ratio - 0.5).abs() < 1e-6,
                    "{:?}: ratio {ratio}",
                    c
                );
            }
        }
    }

    #[test]
    fn none_is_identity() {
        let mut s = vec![0.123, 4.56];
        let orig = s.clone();
        constrain_scales(&mut s, 1, 2, ScaleConstraint::None);
        assert_eq!(s, orig);
    }

    #[test]
    fn parse_names() {
        assert_eq!(ScaleConstraint::parse("m1"), Some(ScaleConstraint::M1));
        assert_eq!(
            ScaleConstraint::parse("M2"),
            Some(ScaleConstraint::M2 { rows: 32 })
        );
        assert_eq!(ScaleConstraint::parse("none"), Some(ScaleConstraint::None));
        assert_eq!(ScaleConstraint::parse("m3"), None);
    }

    #[test]
    fn parse_m2_with_explicit_rows() {
        assert_eq!(
            ScaleConstraint::parse("m2:16"),
            Some(ScaleConstraint::M2 { rows: 16 })
        );
        assert_eq!(
            ScaleConstraint::parse("M2:1"),
            Some(ScaleConstraint::M2 { rows: 1 })
        );
        assert_eq!(ScaleConstraint::parse("m2:0"), None, "zero-row group rejected");
        assert_eq!(ScaleConstraint::parse("m2:"), None);
        assert_eq!(ScaleConstraint::parse("m2:abc"), None);
        assert_eq!(ScaleConstraint::parse("m2:-4"), None);
        // labels round-trip through parse
        for c in [
            ScaleConstraint::None,
            ScaleConstraint::M1,
            ScaleConstraint::M2 { rows: 16 },
        ] {
            assert_eq!(ScaleConstraint::parse(&c.label()), Some(c));
        }
    }

    #[test]
    fn next_pow2_degenerate_inputs() {
        // zero (all-zero weight group) maps to zero — no panic
        assert_eq!(next_pow2(0.0), 0.0);
        // subnormal scales snap up into the normal range
        let sub = f32::from_bits(1); // smallest positive subnormal
        let p = next_pow2(sub);
        assert!(p >= f32::MIN_POSITIVE && is_pow2(p), "{p}");
        assert!(p >= sub);
        // non-finite passthrough (callers skip these)
        assert!(next_pow2(f32::INFINITY).is_infinite());
        assert!(next_pow2(f32::NAN).is_nan());
        assert_eq!(next_pow2(-2.0), -2.0);
    }

    #[test]
    fn m1_handles_zero_and_subnormal_scales() {
        let sub = f32::from_bits(3);
        let mut s = vec![0.0f32, sub, 0.013, 0.0];
        constrain_scales(&mut s, 2, 2, ScaleConstraint::M1);
        assert_eq!(s[0], 0.0, "zero scale stays zero");
        assert_eq!(s[3], 0.0);
        assert!(s[1] >= f32::MIN_POSITIVE && is_pow2(s[1]));
        assert!(is_pow2(s[2]));
    }

    #[test]
    fn m2_handles_zero_and_subnormal_scales() {
        // block contains a zero scale, a subnormal (ratio overflows to inf)
        // and two normal scales — must not panic, and the normal members
        // must still get power-of-two ratios.
        let sub = f32::from_bits(1);
        let mut s = vec![1.0e30f32, 0.0, sub, 0.3e30];
        constrain_scales(&mut s, 4, 1, ScaleConstraint::M2 { rows: 4 });
        assert_eq!(s[0], 1.0e30, "max preserved");
        assert_eq!(s[1], 0.0, "zero member untouched");
        assert_eq!(s[2], sub, "unshiftable subnormal member untouched");
        assert!(is_pow2(s[0] / s[3]), "normal member ratio snapped");
        // an all-zero compute group is a no-op
        let mut z = vec![0.0f32; 8];
        constrain_scales(&mut z, 8, 1, ScaleConstraint::M2 { rows: 4 });
        assert!(z.iter().all(|&x| x == 0.0));
    }
}
