//! True packed storage for quantized weights — the layout the paper ships.
//!
//! [`crate::quant::QuantizedWeight`] keeps one byte per code so the PTQ
//! algorithms stay simple; its `packed_bytes()` only *accounts* for the
//! memory a deployment would save. [`PackedWeight`] realizes it: 4-bit
//! codes are bit-packed two per byte, 8-bit codes one per byte, with the
//! per-(row, column-group) scales alongside, and the fused GEMV
//! ([`crate::tensor::packed_matmul`]) decodes codes on the fly against the
//! activation stream.
//!
//! ## Layout
//!
//! * Codes are row-major over the **original** `[out_features,
//!   in_features]` orientation (the GEMV walks a weight row per output
//!   feature, unit-stride, like `matmul_bt_into`). Row stride is
//!   `cols.div_ceil(2)` bytes for nibble formats (even column in the low
//!   nibble, odd column in the high nibble; a trailing odd column leaves
//!   the last high nibble zero) and `cols` bytes for byte formats.
//! * FP codes store the ExMy bit pattern unchanged. INT4 codes are
//!   re-based to fit a nibble: symmetric stores `level + 8` (level ∈
//!   [-7, 7]), asymmetric stores the raw level (∈ [0, 15]) with the
//!   group's dequant offset folded into `offs`. INT8 keeps the container's
//!   `level + 128` byte.
//! * `scales` is `[rows, n_groups]` f32, row-major — bit-for-bit the
//!   container's scale tensor (an f16 scale would change the dequant
//!   values and break the bit-identity contract).
//!
//! ## Shift dequant (Section 3, "Casting the FP4 to FP8")
//!
//! Dequantizing a code is `decode(code) * scale`. When the scale tensor
//! went through the paper's power-of-two projections, that multiply is a
//! pure **add on the f32 exponent field**:
//!
//! * **M1** — every scale is `2^n`: each group's 16-entry dequant table is
//!   the base decode table with `n << 23` added to each entry's bits
//!   (`ScalePlan::Shift`).
//! * **M2** — scales are `S_max / 2^k` per compute block: the base table
//!   premultiplied by the block's one arbitrary-precision `S_max` is built
//!   at pack time, and each member row applies only its ratio as an
//!   exponent subtract (`ScalePlan::BlockShift`) — exactly the paper's
//!   "only the ratios need to be shifts at compute time".
//!
//! Both plans are **validated at pack time**: every group's shift-built
//! table is compared bit-for-bit against the multiply reference; any
//! mismatch (exponent over/underflow, subnormal scales, asymmetric
//! offsets) falls the whole matrix back to `ScalePlan::Mul`. The packed
//! path is therefore bit-identical to the fake-quant reference by
//! construction, never by hope.

use std::collections::BTreeMap;

use crate::formats::{pow2_exponent, FpFormat, NumericFormat};
use crate::tensor::Matrix;

use super::constraints::ScaleConstraint;
use super::weight::QuantizedWeight;

/// One transformer linear's PTQ artifacts: the quantized codes, plus the
/// LoRC low-rank compensation factors when the run used LoRC. The packed
/// execution plan compiles both — codes into a [`PackedWeight`], factors
/// into a [`crate::lorc::PackedLorc`] attachment — and together they
/// reproduce the *effective* (folded) checkpoint weight bit-for-bit:
/// `entry.weight.dequantize() + factors.approx_error()` is exactly what
/// the pipeline wrote into the effective checkpoint.
#[derive(Debug, Clone)]
pub struct SidecarEntry {
    pub weight: QuantizedWeight,
    pub lorc: Option<crate::lorc::LorcFactors>,
}

/// Quantized-artifact sidecar of a PTQ run: tensor name → codes (+ optional
/// LoRC factors), the input the packed execution plan compiles from (see
/// [`crate::pipeline::ptq`]). Empty only for W16 runs,
/// where nothing was quantized.
#[derive(Debug, Clone, Default)]
pub struct QuantSidecar {
    entries: BTreeMap<String, SidecarEntry>,
}

impl QuantSidecar {
    pub fn new() -> QuantSidecar {
        QuantSidecar::default()
    }

    /// Insert codes without factors (non-LoRC runs).
    pub fn insert(&mut self, name: String, weight: QuantizedWeight) {
        self.entries.insert(name, SidecarEntry { weight, lorc: None });
    }

    /// Insert codes with their optional LoRC factors.
    pub fn insert_with_lorc(
        &mut self,
        name: String,
        weight: QuantizedWeight,
        lorc: Option<crate::lorc::LorcFactors>,
    ) {
        self.entries.insert(name, SidecarEntry { weight, lorc });
    }

    /// The quantized codes of one tensor.
    pub fn get(&self, name: &str) -> Option<&QuantizedWeight> {
        self.entries.get(name).map(|e| &e.weight)
    }

    /// The full entry (codes + factors) of one tensor.
    pub fn entry(&self, name: &str) -> Option<&SidecarEntry> {
        self.entries.get(name)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// True when any entry carries LoRC factors.
    pub fn has_lorc(&self) -> bool {
        self.entries.values().any(|e| e.lorc.is_some())
    }

    /// A copy with every LoRC attachment stripped: the same quantized
    /// codes, rank 0. This is how a speculative *draft* plan is compiled
    /// from a LoRC target's artifacts — packing the stripped sidecar
    /// yields the cheap uncompensated W4 model (the paper's accuracy/cost
    /// grid, one rung down) while the target keeps the factors.
    pub fn without_lorc(&self) -> QuantSidecar {
        QuantSidecar {
            entries: self
                .entries
                .iter()
                .map(|(n, e)| (n.clone(), SidecarEntry { weight: e.weight.clone(), lorc: None }))
                .collect(),
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &SidecarEntry)> {
        self.entries.iter()
    }
}

/// How group dequant tables are materialized at GEMV time.
#[derive(Debug, Clone)]
enum ScalePlan {
    /// Arbitrary scales: table entry = `fl(base · scale)` (f32 multiply).
    Mul,
    /// Every scale is a power of two (M1): per-(row, group) exponent-field
    /// add on the base table bits. Exponents are stored narrow (i16, they
    /// live in [-126, 127]) and widened to `e << 23` once per group.
    Shift { shift_exp: Vec<i16> },
    /// Power-of-two ratios to one anchor per M2 compute block: per-block
    /// anchor-premultiplied tables plus a per-(row, group) exponent
    /// subtract for the ratio. 4-bit formats only (a 256-entry premul
    /// table per block would rival the codes themselves).
    BlockShift {
        block_rows: usize,
        /// `[n_blocks * n_groups * 16]` — `fl(base · S_max)` per block.
        premul: Vec<f32>,
        /// `[rows * n_groups]` ratio exponents (≤ 0: ratios are ≥ 1).
        shift_exp: Vec<i16>,
    },
}

/// A quantized weight matrix in true packed form, ready for the fused
/// dequant GEMV. Constructed from one or more [`QuantizedWeight`]s sharing
/// a format (row-stacked, preserving the compiled plan's fused q|k|v and
/// gate|up layouts).
#[derive(Debug, Clone)]
pub struct PackedWeight {
    pub rows: usize,
    pub cols: usize,
    pub group_size: usize,
    pub format: NumericFormat,
    /// Bit-packed codes (see module docs for the layout).
    pub data: Vec<u8>,
    /// `[rows, n_groups]` scales, row-major.
    pub scales: Vec<f32>,
    /// Per-(row, group) dequant offsets (asymmetric INT only; empty
    /// otherwise), pre-folded to reproduce the container's `dequantize`
    /// arithmetic exactly.
    offs: Vec<f32>,
    pub cast_fp4_to_e5m2: bool,
    /// Raw-code decode table: 16 entries for nibble formats, 256 for byte.
    base: Vec<f32>,
    plan: ScalePlan,
}

/// `v · 2^(shift_bits >> 23)` as a pure exponent-field add. Exact (equal to
/// the f32 multiply) whenever `v` and the result are normal or zero —
/// which pack-time validation guarantees before this path is selected.
#[inline(always)]
fn shift_f32(v: f32, shift_bits: i32) -> f32 {
    if v == 0.0 {
        v // ±0 has no exponent field to add to
    } else {
        f32::from_bits((v.to_bits() as i32).wrapping_add(shift_bits) as u32)
    }
}

impl PackedWeight {
    /// Pack one container.
    pub fn from_quantized(q: &QuantizedWeight) -> PackedWeight {
        PackedWeight::pack(&[q])
    }

    /// Pack one or more containers that share `cols`, `group_size`,
    /// `format`, cast flag and constraint, stacking their rows — the fused
    /// q|k|v / gate|up layout of the compiled plan.
    pub fn pack(parts: &[&QuantizedWeight]) -> PackedWeight {
        assert!(!parts.is_empty(), "nothing to pack");
        let head = parts[0];
        let format = head.format;
        assert!(
            !matches!(format, NumericFormat::F16),
            "F16 weights are dense — the packed layout needs a quantized format"
        );
        for p in parts {
            assert_eq!(p.cols, head.cols, "fused parts must share the input dim");
            assert_eq!(p.group_size, head.group_size, "fused parts must share the group size");
            assert_eq!(p.format, head.format, "fused parts must share the format");
            assert_eq!(p.cast_fp4_to_e5m2, head.cast_fp4_to_e5m2, "cast policy mismatch");
            assert_eq!(p.constraint, head.constraint, "constraint mismatch");
        }
        let cols = head.cols;
        let group_size = head.group_size;
        let rows: usize = parts.iter().map(|p| p.rows).sum();
        let ng = cols.div_ceil(group_size);
        let nibble = format.bits() == 4;
        let stride = if nibble { cols.div_ceil(2) } else { cols };
        let asym = matches!(format, NumericFormat::Int(i) if !i.symmetric);

        let base = base_table(format);
        let mut data = vec![0u8; rows * stride];
        let mut scales = Vec::with_capacity(rows * ng);
        let mut offs: Vec<f32> = if asym { Vec::with_capacity(rows * ng) } else { Vec::new() };

        let mut out_r = 0usize;
        for p in parts {
            for r in 0..p.rows {
                scales.extend_from_slice(&p.scales[r * ng..(r + 1) * ng]);
                if asym {
                    // Fold the container's dequant arithmetic into one
                    // integer offset per group (exact: all quantities are
                    // small integers). The container stores `level - z +
                    // 128` and dequantizes `(code - 128 - z) · s`; we
                    // re-base nibbles to the raw level, so the offset
                    // doubles for 4-bit codes.
                    for g in 0..ng {
                        let z = p.zeros[r * ng + g];
                        offs.push(if nibble { (2 * z) as f32 } else { z as f32 });
                    }
                }
                let dst = &mut data[out_r * stride..(out_r + 1) * stride];
                for c in 0..cols {
                    let code8 = p.codes[r * p.cols + c] as i32;
                    let packed = if !nibble {
                        code8
                    } else {
                        match format {
                            // FP4: the 4-bit ExMy pattern, stored as-is.
                            NumericFormat::Fp(_) => code8,
                            NumericFormat::Int(i) if i.symmetric => {
                                // container byte = level + 128, level ∈ [-7, 7]
                                code8 - 128 + 8
                            }
                            NumericFormat::Int(_) => {
                                // container byte = level - z + 128 → raw level
                                let z = p.zeros[r * ng + c / group_size];
                                code8 - 128 + z
                            }
                            NumericFormat::F16 => unreachable!(),
                        }
                    };
                    assert!(
                        (0..if nibble { 16 } else { 256 }).contains(&packed),
                        "code {packed} out of packed range"
                    );
                    if nibble {
                        dst[c / 2] |= (packed as u8) << ((c & 1) * 4);
                    } else {
                        dst[c] = packed as u8;
                    }
                }
                out_r += 1;
            }
        }

        let mut pw = PackedWeight {
            rows,
            cols,
            group_size,
            format,
            data,
            scales,
            offs,
            cast_fp4_to_e5m2: head.cast_fp4_to_e5m2,
            base,
            plan: ScalePlan::Mul,
        };
        pw.plan = pw.plan_shift(head.constraint);
        pw
    }

    pub fn n_groups(&self) -> usize {
        self.cols.div_ceil(self.group_size)
    }

    /// Bytes per packed row of codes.
    pub fn row_stride(&self) -> usize {
        if self.format.bits() == 4 {
            self.cols.div_ceil(2)
        } else {
            self.cols
        }
    }

    /// True when this matrix dequantizes through exponent-field adds
    /// (the M1/M2 bit-shift cast) rather than per-group multiplies.
    pub fn uses_shift_dequant(&self) -> bool {
        !matches!(self.plan, ScalePlan::Mul)
    }

    /// Actual resident bytes of the packed representation: codes + scales
    /// + offsets + decode/premul tables + shift metadata.
    pub fn mem_bytes(&self) -> usize {
        let plan = match &self.plan {
            ScalePlan::Mul => 0,
            ScalePlan::Shift { shift_exp } => 2 * shift_exp.len(),
            ScalePlan::BlockShift { premul, shift_exp, .. } => {
                4 * premul.len() + 2 * shift_exp.len()
            }
        };
        self.data.len() + 4 * self.scales.len() + 4 * self.offs.len() + 4 * self.base.len() + plan
    }

    /// The packed code of one element (tests / tooling).
    pub fn code_at(&self, row: usize, col: usize) -> u8 {
        let stride = self.row_stride();
        if self.format.bits() == 4 {
            let b = self.data[row * stride + col / 2];
            (b >> ((col & 1) * 4)) & 0xf
        } else {
            self.data[row * stride + col]
        }
    }

    /// The dequant value of packed code `c` in group `(row, g)`, computed
    /// the reference way (multiply + offset + optional cast). This is the
    /// ground truth the shift plans are validated against, and the slow
    /// path [`Self::dequant_at`] uses.
    #[inline]
    fn ref_entry(&self, c: usize, gi: usize) -> f32 {
        let off = if self.offs.is_empty() { 0.0 } else { self.offs[gi] };
        let v = (self.base[c] - off) * self.scales[gi];
        if self.cast_fp4_to_e5m2 {
            FpFormat::E5M2.quantize(v)
        } else {
            v
        }
    }

    /// Dequantize one element (slow; the GEMV uses the row decoder).
    pub fn dequant_at(&self, row: usize, col: usize) -> f32 {
        let gi = row * self.n_groups() + col / self.group_size;
        self.ref_entry(self.code_at(row, col) as usize, gi)
    }

    /// Fill `t` with the 16-entry dequant table of group `(row, g)` —
    /// nibble formats only. One table serves `group_size` elements, so the
    /// inner GEMV loop is a pure nibble→table load with **zero multiplies
    /// per weight** on every plan.
    #[inline]
    fn fill_group_table(&self, row: usize, g: usize, t: &mut [f32; 16]) {
        let gi = row * self.n_groups() + g;
        match &self.plan {
            ScalePlan::Mul => {
                let s = self.scales[gi];
                let off = if self.offs.is_empty() { 0.0 } else { self.offs[gi] };
                for (c, tv) in t.iter_mut().enumerate() {
                    *tv = (self.base[c] - off) * s;
                }
            }
            ScalePlan::Shift { shift_exp } => {
                let sb = (shift_exp[gi] as i32) << 23;
                for (c, tv) in t.iter_mut().enumerate() {
                    *tv = shift_f32(self.base[c], sb);
                }
            }
            ScalePlan::BlockShift { block_rows, premul, shift_exp } => {
                let ng = self.n_groups();
                let block = (row / block_rows) * ng + g;
                let p = &premul[block * 16..block * 16 + 16];
                let sb = (shift_exp[gi] as i32) << 23;
                for (c, tv) in t.iter_mut().enumerate() {
                    *tv = shift_f32(p[c], sb);
                }
            }
        }
        if self.cast_fp4_to_e5m2 {
            for tv in t.iter_mut() {
                *tv = FpFormat::E5M2.quantize(*tv);
            }
        }
    }

    /// Decode one whole weight row into `out[..cols]` — the stream the
    /// fused GEMV dots against the activations. Bit-identical to the
    /// corresponding row of [`QuantizedWeight::dequantize`].
    pub fn dequant_row_into(&self, row: usize, out: &mut [f32]) {
        assert!(out.len() >= self.cols, "decode scratch too small");
        let ng = self.n_groups();
        let stride = self.row_stride();
        let bytes = &self.data[row * stride..(row + 1) * stride];
        if self.format.bits() == 4 {
            let mut t = [0.0f32; 16];
            for g in 0..ng {
                self.fill_group_table(row, g, &mut t);
                let c0 = g * self.group_size;
                let c1 = (c0 + self.group_size).min(self.cols);
                for (c, ov) in out[c0..c1].iter_mut().enumerate() {
                    let c = c0 + c;
                    let b = bytes[c / 2];
                    *ov = t[((b >> ((c & 1) * 4)) & 0xf) as usize];
                }
            }
        } else {
            // Byte codes: 256-entry tables are too large to rebuild per
            // group — dequantize per element, with the scale applied as an
            // exponent add when the plan allows.
            for g in 0..ng {
                let gi = row * ng + g;
                let c0 = g * self.group_size;
                let c1 = (c0 + self.group_size).min(self.cols);
                match &self.plan {
                    ScalePlan::Shift { shift_exp } => {
                        let sb = (shift_exp[gi] as i32) << 23;
                        for (c, ov) in out[c0..c1].iter_mut().enumerate() {
                            *ov = shift_f32(self.base[bytes[c0 + c] as usize], sb);
                        }
                    }
                    _ => {
                        let s = self.scales[gi];
                        let off = if self.offs.is_empty() { 0.0 } else { self.offs[gi] };
                        for (c, ov) in out[c0..c1].iter_mut().enumerate() {
                            *ov = (self.base[bytes[c0 + c] as usize] - off) * s;
                        }
                    }
                }
            }
        }
    }

    /// Dequantize the whole matrix (tests / the dense-fallback path).
    pub fn dequantize(&self) -> Matrix {
        let mut m = Matrix::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let row = &mut m.data[r * self.cols..(r + 1) * self.cols];
            self.dequant_row_into(r, row);
        }
        m
    }

    /// Try to plan shift dequant for this matrix's scale tensor; falls
    /// back to `ScalePlan::Mul` unless **every** group's shift-built
    /// table reproduces the multiply reference bit-for-bit.
    fn plan_shift(&self, constraint: ScaleConstraint) -> ScalePlan {
        if !self.offs.is_empty() || self.cast_fp4_to_e5m2 {
            // Asymmetric offsets break the pure-multiply structure, and the
            // E5M2 re-quantization makes the shift a dequant+requant anyway
            // (the cast is applied after either path — correctness would
            // hold, but validation cost buys nothing; keep it simple).
            return ScalePlan::Mul;
        }
        let ng = self.n_groups();

        // M1 / naturally power-of-two scales: one shift per (row, group).
        let m1 = || -> Option<ScalePlan> {
            let mut shift_exp = Vec::with_capacity(self.scales.len());
            for &s in &self.scales {
                shift_exp.push(pow2_exponent(s)? as i16);
            }
            let plan = ScalePlan::Shift { shift_exp };
            self.validate_plan(&plan).then_some(plan)
        };
        if let Some(p) = m1() {
            return p;
        }

        // M2: power-of-two ratios against one anchor per compute block.
        if let ScaleConstraint::M2 { rows: block_rows } = constraint {
            if self.format.bits() == 4 {
                let block_rows = block_rows.max(1);
                if let Some(p) = self.plan_block_shift(block_rows, ng) {
                    return p;
                }
            }
        }
        ScalePlan::Mul
    }

    fn plan_block_shift(&self, block_rows: usize, ng: usize) -> Option<ScalePlan> {
        let n_blocks = self.rows.div_ceil(block_rows);
        let mut premul = vec![0.0f32; n_blocks * ng * 16];
        let mut shift_exp = vec![0i16; self.scales.len()];
        for g in 0..ng {
            for b in 0..n_blocks {
                let r0 = b * block_rows;
                let r1 = (r0 + block_rows).min(self.rows);
                let mut smax = 0.0f32;
                for r in r0..r1 {
                    let s = self.scales[r * ng + g];
                    if s.is_finite() {
                        smax = smax.max(s);
                    }
                }
                let tb = &mut premul[(b * ng + g) * 16..(b * ng + g) * 16 + 16];
                for (c, tv) in tb.iter_mut().enumerate() {
                    *tv = self.base[c] * smax;
                }
                for r in r0..r1 {
                    let s = self.scales[r * ng + g];
                    if s == 0.0 {
                        // all-zero group: every code decodes to ±0 either
                        // way; shift 0 against the premul table would be
                        // wrong unless the base entry is 0 too, so bail
                        // out to Mul for safety via validation below.
                        shift_exp[r * ng + g] = 0;
                        continue;
                    }
                    // ratio must be an exact power of two (the M2 invariant)
                    let k = pow2_exponent(smax / s)?;
                    shift_exp[r * ng + g] = -(k as i16);
                }
            }
        }
        let plan = ScalePlan::BlockShift { block_rows, premul, shift_exp };
        self.validate_plan(&plan).then_some(plan)
    }

    /// Bit-compare every group's plan-built table against the multiply
    /// reference. Non-finite base entries (inf/NaN codes of IEEE-style
    /// formats, which a saturating encoder never emits) are skipped.
    fn validate_plan(&self, plan: &ScalePlan) -> bool {
        let ng = self.n_groups();
        let tbl = self.base.len(); // 16 or 256
        for r in 0..self.rows {
            for g in 0..ng {
                let gi = r * ng + g;
                for c in 0..tbl {
                    if !self.base[c].is_finite() {
                        continue;
                    }
                    let want = self.base[c] * self.scales[gi];
                    let got = match plan {
                        ScalePlan::Mul => want,
                        ScalePlan::Shift { shift_exp } => {
                            shift_f32(self.base[c], (shift_exp[gi] as i32) << 23)
                        }
                        ScalePlan::BlockShift { block_rows, premul, shift_exp } => {
                            let block = (r / block_rows) * ng + g;
                            shift_f32(premul[block * tbl + c], (shift_exp[gi] as i32) << 23)
                        }
                    };
                    if got.to_bits() != want.to_bits() {
                        return false;
                    }
                }
            }
        }
        true
    }
}

/// Decode table over raw packed codes (the scale-free part of dequant).
fn base_table(format: NumericFormat) -> Vec<f32> {
    match format {
        NumericFormat::F16 => unreachable!("checked by pack"),
        NumericFormat::Fp(f) if f.total_bits() == 4 => {
            (0..16).map(|c| f.decode(c as u16)).collect()
        }
        NumericFormat::Fp(f) => (0..256).map(|c| f.decode(c as u16)).collect(),
        NumericFormat::Int(i) if i.bits == 4 => {
            if i.symmetric {
                // nibble = level + 8
                (0..16i32).map(|c| (c - 8) as f32).collect()
            } else {
                // nibble = raw level; group offset folded into `offs`
                (0..16i32).map(|c| c as f32).collect()
            }
        }
        NumericFormat::Int(_) => {
            // container byte = level(+z-fold) + 128
            (0..256i32).map(|c| (c - 128) as f32).collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::weight::{quantize_weight_rtn, WeightQuantConfig};
    use crate::rng::Rng;

    const FORMATS: [NumericFormat; 7] = [
        NumericFormat::FP4_E2M1,
        NumericFormat::FP4_E3M0,
        NumericFormat::INT4,
        NumericFormat::INT4_ASYM,
        NumericFormat::FP8_E4M3,
        NumericFormat::INT8,
        NumericFormat::INT8_ASYM,
    ];

    const CONSTRAINTS: [ScaleConstraint; 4] = [
        ScaleConstraint::None,
        ScaleConstraint::M1,
        ScaleConstraint::M2 { rows: 4 },
        ScaleConstraint::M2 { rows: 3 }, // ragged blocks
    ];

    fn assert_matches_container(q: &QuantizedWeight, what: &str) {
        let p = PackedWeight::from_quantized(q);
        let reference = q.dequantize();
        let packed = p.dequantize();
        for (i, (a, b)) in reference.data.iter().zip(&packed.data).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{what}: element {i} reference={a} packed={b}"
            );
        }
        // and the element accessor agrees
        for r in [0, q.rows - 1] {
            for c in [0, q.cols / 2, q.cols - 1] {
                assert_eq!(p.dequant_at(r, c).to_bits(), q.dequant_at(r, c).to_bits());
            }
        }
    }

    #[test]
    fn packed_dequant_bit_identical_across_formats_and_constraints() {
        let mut rng = Rng::seeded(0xBAC);
        for fmt in FORMATS {
            for cst in CONSTRAINTS {
                for cols in [64usize, 65, 130] {
                    // odd cols: trailing nibble
                    let w = Matrix::randn(9, cols, 0.05, &mut rng);
                    let q = quantize_weight_rtn(
                        &w,
                        &WeightQuantConfig::new(fmt).with_group_size(32).with_constraint(cst),
                    );
                    assert_matches_container(
                        &q,
                        &format!("{} {} cols={cols}", fmt.name(), cst.name()),
                    );
                }
            }
        }
    }

    #[test]
    fn m1_and_m2_select_shift_plans() {
        let mut rng = Rng::seeded(0xBAD);
        let w = Matrix::randn(16, 64, 0.05, &mut rng);
        for (cst, fmt) in [
            (ScaleConstraint::M1, NumericFormat::FP4_E2M1),
            (ScaleConstraint::M1, NumericFormat::FP8_E4M3),
            (ScaleConstraint::M2 { rows: 4 }, NumericFormat::FP4_E2M1),
            (ScaleConstraint::M2 { rows: 4 }, NumericFormat::INT4),
        ] {
            let q = quantize_weight_rtn(
                &w,
                &WeightQuantConfig::new(fmt).with_group_size(32).with_constraint(cst),
            );
            let p = PackedWeight::from_quantized(&q);
            assert!(
                p.uses_shift_dequant(),
                "{} {} should dequantize by exponent-add",
                fmt.name(),
                cst.name()
            );
        }
        // unconstrained scales are arbitrary → multiply fallback
        let q = quantize_weight_rtn(&w, &WeightQuantConfig::new(NumericFormat::FP4_E2M1));
        assert!(!PackedWeight::from_quantized(&q).uses_shift_dequant());
    }

    #[test]
    fn cast_policy_flows_through_packed_path() {
        let mut rng = Rng::seeded(0xCAF);
        let w = Matrix::randn(6, 48, 0.1, &mut rng);
        let q = quantize_weight_rtn(
            &w,
            &WeightQuantConfig::new(NumericFormat::FP4_E2M1)
                .with_group_size(16)
                .with_cast(true),
        );
        assert!(q.cast_fp4_to_e5m2);
        assert_matches_container(&q, "fp4 cast");
    }

    #[test]
    fn all_zero_group_packs_and_dequantizes() {
        // the end-to-end regression for the zero-scale constraint fix: an
        // all-zero weight survives quantize → constrain → pack → decode
        // under every constraint, for both a 4-bit and an 8-bit format.
        let w = Matrix::zeros(8, 64);
        for fmt in [NumericFormat::FP4_E2M1, NumericFormat::INT8] {
            for cst in CONSTRAINTS {
                let q = quantize_weight_rtn(
                    &w,
                    &WeightQuantConfig::new(fmt).with_group_size(32).with_constraint(cst),
                );
                let p = PackedWeight::from_quantized(&q);
                let d = p.dequantize();
                assert!(
                    d.data.iter().all(|&x| x == 0.0),
                    "{} {}: zero weight must decode to zero",
                    fmt.name(),
                    cst.name()
                );
            }
        }
    }

    #[test]
    fn fused_pack_stacks_rows() {
        let mut rng = Rng::seeded(0xFAB);
        let cfg = WeightQuantConfig::new(NumericFormat::FP4_E2M1).with_group_size(32);
        let a = quantize_weight_rtn(&Matrix::randn(5, 64, 0.05, &mut rng), &cfg);
        let b = quantize_weight_rtn(&Matrix::randn(3, 64, 0.05, &mut rng), &cfg);
        let fused = PackedWeight::pack(&[&a, &b]);
        assert_eq!((fused.rows, fused.cols), (8, 64));
        let da = a.dequantize();
        let db = b.dequantize();
        let df = fused.dequantize();
        for r in 0..5 {
            assert_eq!(&df.data[r * 64..(r + 1) * 64], &da.data[r * 64..(r + 1) * 64]);
        }
        for r in 0..3 {
            assert_eq!(
                &df.data[(5 + r) * 64..(6 + r) * 64],
                &db.data[r * 64..(r + 1) * 64]
            );
        }
    }

    #[test]
    fn packed_memory_is_a_fraction_of_dense() {
        let mut rng = Rng::seeded(0xFEE);
        let w = Matrix::randn(64, 256, 0.05, &mut rng);
        let q = quantize_weight_rtn(
            &w,
            &WeightQuantConfig::new(NumericFormat::FP4_E2M1).with_group_size(64),
        );
        let p = PackedWeight::from_quantized(&q);
        let dense = 4 * w.rows * w.cols;
        assert!(
            p.mem_bytes() * 6 <= dense,
            "packed {} vs dense {dense}: not ≤ 1/6",
            p.mem_bytes()
        );
        // and packing really used nibbles
        assert_eq!(p.data.len(), 64 * 128);
    }
}
