//! Token-wise activation quantization.
//!
//! The paper (following ZeroQuant) quantizes activations **per token**: each
//! row of the `[tokens, features]` activation matrix gets its own dynamic
//! absmax scale, computed on the fly at inference time ("to accommodate the
//! latency requirements", Appendix A). This module is the Rust mirror of
//! the Pallas kernel `python/compile/kernels/act_quant.py`.

use crate::formats::NumericFormat;
use crate::tensor::Matrix;

/// Activation quantization config.
#[derive(Debug, Clone, Copy)]
pub struct ActQuantConfig {
    pub format: NumericFormat,
}

impl ActQuantConfig {
    pub fn new(format: NumericFormat) -> Self {
        ActQuantConfig { format }
    }

    pub fn is_noop(&self) -> bool {
        matches!(self.format, NumericFormat::F16)
    }
}

/// Fake-quantize each row (token) of `x` with its own dynamic absmax scale.
/// Returns the per-token scales (useful for capture/telemetry).
pub fn fake_quant_tokenwise(x: &mut Matrix, cfg: &ActQuantConfig) -> Vec<f32> {
    if cfg.is_noop() {
        return vec![1.0; x.rows];
    }
    let mut scales = Vec::with_capacity(x.rows);
    for r in 0..x.rows {
        let p = cfg.format.fake_quant_slice_dynamic(x.row_mut(r));
        scales.push(p.scale);
    }
    scales
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn tokenwise_isolation() {
        // An outlier token must not affect other tokens' quantization —
        // the whole point of token-wise over per-tensor.
        let mut rng = Rng::seeded(61);
        let mut x = Matrix::randn(4, 64, 0.1, &mut rng);
        x.row_mut(3).iter_mut().for_each(|v| *v *= 1000.0);
        let clean_row = x.row(0).to_vec();

        let mut tw = x.clone();
        fake_quant_tokenwise(&mut tw, &ActQuantConfig::new(NumericFormat::INT8));

        // per-tensor for contrast
        let mut pt = x.clone();
        NumericFormat::INT8.fake_quant_slice_dynamic(&mut pt.data);

        let err_tw: f64 = tw.row(0).iter().zip(&clean_row).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        let err_pt: f64 = pt.row(0).iter().zip(&clean_row).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
        assert!(err_tw < err_pt / 100.0, "tw={err_tw} pt={err_pt}");
    }

    #[test]
    fn fp8_tokenwise_tracks_outlier_rows_better_than_int8() {
        // Within a single token with in-row outliers (the fc2-input case),
        // FP8 wins over INT8 even token-wise — Table 1's mechanism.
        let mut rng = Rng::seeded(62);
        let mut x = Matrix::zeros(8, 512);
        for r in 0..8 {
            for c in 0..512 {
                // ReLU-like skew: mostly near-zero, a few big positives
                let v = rng.normal_f32().max(0.0) * 0.05;
                *x.at_mut(r, c) = v;
            }
            *x.at_mut(r, 7) = 8.0 + rng.uniform_f32(0.0, 2.0); // outlier channel
        }
        let orig = x.clone();
        let mut xfp = x.clone();
        let mut xint = x.clone();
        fake_quant_tokenwise(&mut xfp, &ActQuantConfig::new(NumericFormat::FP8_E4M3));
        fake_quant_tokenwise(&mut xint, &ActQuantConfig::new(NumericFormat::INT8));
        assert!(xfp.mse(&orig) < xint.mse(&orig));
    }

    #[test]
    fn noop_for_f16() {
        let mut rng = Rng::seeded(63);
        let x0 = Matrix::randn(3, 16, 1.0, &mut rng);
        let mut x = x0.clone();
        let scales = fake_quant_tokenwise(&mut x, &ActQuantConfig::new(NumericFormat::F16));
        assert_eq!(x, x0);
        assert!(scales.iter().all(|&s| s == 1.0));
    }

    #[test]
    fn scales_count_matches_tokens() {
        let mut rng = Rng::seeded(64);
        let mut x = Matrix::randn(7, 32, 1.0, &mut rng);
        let scales = fake_quant_tokenwise(&mut x, &ActQuantConfig::new(NumericFormat::FP8_E4M3));
        assert_eq!(scales.len(), 7);
        assert!(scales.iter().all(|&s| s > 0.0));
    }
}
